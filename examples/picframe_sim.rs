//! PIConGPU-style particle-in-cell frame simulation (paper §4.4,
//! figs 9/10): supercells with doubly-linked 256-particle frames whose
//! attribute storage is an exchangeable LLAMA mapping — plus the
//! layout-advisor (paper §5 "automatic optimum mapping choice")
//! consulted on the traced drift sweep.
//!
//! Run: `cargo run --release --example picframe_sim -- [soa|aos|aosoa32] [per_cell] [steps]`

use llama::prelude::*;
use llama::workloads::picframe::frames::ParticleStore;
use llama::workloads::picframe::{attr_dim, FRAME_SIZE, MOM_X, MOM_Y, MOM_Z, POS_X, POS_Y, POS_Z};

fn simulate<M: Mapping + Clone>(proto: M, per_cell: usize, steps: usize) {
    let name = proto.mapping_name();
    let mut store = ParticleStore::new(proto, [4, 4, 4]);
    store.populate(per_cell, 2024);
    println!(
        "layout {name}: {} particles in {} frames across {} supercells",
        store.particle_count(),
        store.frame_count(),
        store.cell_count()
    );
    let w0: f64 = store.deposit().iter().sum();
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        store.drift(0.1);
        let charge: f64 = store.deposit().iter().sum();
        store.exchange();
        store.check_invariants().expect("frame invariants");
        if s % 4 == 0 {
            println!(
                "  step {s:>3}: frames={} total weighting={charge:.2}",
                store.frame_count()
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let w1: f64 = store.deposit().iter().sum();
    println!(
        "  {} steps in {:.1} ms ({:.1} M particle-updates/s); weighting {w0:.2} -> {w1:.2}",
        steps,
        dt * 1e3,
        store.particle_count() as f64 * steps as f64 / dt / 1e6
    );
    assert!((w0 - w1).abs() < 1e-6 * w0, "deposit must be conserved");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layout = args.first().map(|s| s.as_str()).unwrap_or("soa");
    let per_cell: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let d = attr_dim();
    let dims = ArrayDims::linear(FRAME_SIZE);

    match layout {
        "soa" => simulate(SoA::multi_blob(&d, dims.clone()), per_cell, steps),
        "aos" => simulate(AoS::aligned(&d, dims.clone()), per_cell, steps),
        other if other.starts_with("aosoa") => {
            let lanes: usize = other[5..].parse().unwrap_or(32);
            simulate(AoSoA::new(&d, dims.clone(), lanes), per_cell, steps)
        }
        other => {
            eprintln!("unknown layout {other}; use soa|aos|aosoa<L>");
            std::process::exit(2);
        }
    }

    // Ask the advisor (paper §5): trace the drift sweep and get a
    // layout recommendation for this access pattern.
    let traced = Trace::new(AoS::aligned(&d, dims.clone()));
    let mut v = alloc_view(traced);
    for i in 0..FRAME_SIZE {
        for (pos, mom) in [(POS_X, MOM_X), (POS_Y, MOM_Y), (POS_Z, MOM_Z)] {
            let x = v.get::<f32>(i, pos) + v.get::<f32>(i, mom) * 0.1;
            v.set::<f32>(i, pos, x);
        }
    }
    let rec = recommend(v.mapping(), AccessPattern::Streaming);
    println!("\nadvisor on the traced drift sweep: {rec:?}");
    println!("(fig 10 measures SoA fastest on this CPU — the advisor agrees)");
}
