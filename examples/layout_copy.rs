//! Layout-changing copy demo (paper §4.2 / fig 7): move HEP event data
//! between layouts with every strategy and print throughput.
//!
//! Run: `cargo run --release --example layout_copy -- [--full]`

use llama::coordinator::bench::Opts;
use llama::coordinator::fig7_copy;
use llama::prelude::*;
use llama::workloads::hep;

fn main() {
    let full = std::env::args().any(|a| a == "--full");

    // Small demonstration first: the dispatcher in action.
    let d = hep::event_dim();
    let dims = ArrayDims::linear(4096);
    let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
    hep::generate_events(&mut soa, 42);

    let mut aosoa = alloc_view(AoSoA::new(&d, dims.clone(), 32));
    let m1 = copy(&soa, &mut aosoa);
    let mut aligned = alloc_view(AoS::aligned(&d, dims.clone()));
    let m2 = copy(&aosoa, &mut aligned);
    let mut same = alloc_view(AoS::aligned(&d, dims.clone()));
    let m3 = copy(&aligned, &mut same);
    println!("SoA MB -> AoSoA32: {m1:?}");
    println!("AoSoA32 -> AoS aligned: {m2:?} (aligned AoS is not chunkable)");
    println!("AoS aligned -> AoS aligned: {m3:?}");
    assert!(views_equal(&soa, &same));
    println!("all copies verified field-wise equal\n");

    // Then the fig 7 table.
    let opts = if full { Opts::default() } else { Opts::quick() };
    println!("{}", fig7_copy::run(&opts).to_text());
}
