//! D3Q19 lattice-Boltzmann (SPEC 619.lbm_s analog, paper §4.3): run a
//! real flow past obstacles with the layout chosen on the command line,
//! report MLUPS and physics diagnostics.
//!
//! Run: `cargo run --release --example lbm_sim -- [aos|split|soa|aosoa64] [grid] [steps]`

use llama::prelude::*;
use llama::workloads::lbm::split4::build_split4;
use llama::workloads::lbm::step::{init, macroscopic, step_parallel, total_mass};
use llama::workloads::lbm::{cell_dim, Geometry};

fn simulate<M: Mapping + Clone>(mapping: M, geo: &Geometry, steps: usize) {
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut a = alloc_view(mapping.clone());
    let mut b = alloc_view(mapping.clone());
    init(&mut a, geo);
    init(&mut b, geo);
    let m0 = total_mass(&a);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        step_parallel(&a, &mut b, threads);
        std::mem::swap(&mut a, &mut b);
    }
    let dt = t0.elapsed().as_secs_f64();
    let mlups = geo.dims.count() as f64 * steps as f64 / dt / 1e6;
    let m1 = total_mass(&a);
    // Bulk velocity in the wake.
    let probe = geo
        .obstacle
        .iter()
        .enumerate()
        .find(|(_, &o)| !o)
        .map(|(i, _)| i)
        .unwrap();
    let (rho, u) = macroscopic(&a, probe);
    println!("layout: {}", mapping.mapping_name());
    println!("  {steps} steps on {:?} with {threads} thread(s)", geo.dims.extents());
    println!("  {dt:.3} s -> {mlups:.1} MLUPS");
    println!("  mass {m0:.3} -> {m1:.3} (drift {:.2e})", (m1 - m0).abs() / m0);
    println!("  probe cell {probe}: rho={rho:.4}, u=({:+.4}, {:+.4}, {:+.4})", u[0], u[1], u[2]);
    assert!((m1 - m0).abs() / m0 < 1e-9, "mass must be conserved");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layout = args.first().map(|s| s.as_str()).unwrap_or("soa");
    let g: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);
    let geo = Geometry::channel_with_sphere(g, g, g, 11);
    println!(
        "D3Q19 channel, {} cells, {} obstacle cells\n",
        geo.dims.count(),
        geo.dims.count() - geo.fluid_cells()
    );
    let d = cell_dim();
    match layout {
        "aos" => simulate(AoS::aligned(&d, geo.dims.clone()), &geo, steps),
        "soa" => simulate(SoA::multi_blob(&d, geo.dims.clone()), &geo, steps),
        "soa-sb" => simulate(SoA::single_blob(&d, geo.dims.clone()), &geo, steps),
        "split" => {
            let groups = llama::coordinator::fig8_lbm::trace_derived_groups(&geo);
            simulate(build_split4(&d, geo.dims.clone(), &groups), &geo, steps)
        }
        other if other.starts_with("aosoa") => {
            let lanes: usize = other[5..].parse().unwrap_or(64);
            simulate(AoSoA::new(&d, geo.dims.clone(), lanes), &geo, steps)
        }
        other => {
            eprintln!("unknown layout {other}; use aos|soa|soa-sb|split|aosoa<L>");
            std::process::exit(2);
        }
    }
}
