//! The paper's §4.1 n-body across memory layouts: one generic kernel,
//! the layout switched by a single line — plus the fig 5 timing table.
//!
//! Run: `cargo run --release --example nbody_layouts -- [--quick] [--n K]`

use llama::coordinator::bench::Opts;
use llama::coordinator::fig5_nbody;
use llama::prelude::*;
use llama::workloads::nbody::{self, llama_impl};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts::quick();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => opts.quick = true,
            "--full" => opts = Opts::default(),
            "--n" => opts.n = it.next().and_then(|v| v.parse().ok()),
            _ => {}
        }
    }

    // Demonstrate the one-line layout switch on a tiny run first.
    let n = 512;
    let d = nbody::particle_dim();
    let state = nbody::init_particles(n, 7);
    let dims = ArrayDims::linear(n);

    println!("one generic kernel, four layouts (N={n}, 1 step):");
    // --- the only line that changes between runs: the mapping ---
    run_one("AoS aligned", AoS::aligned(&d, dims.clone()), &state);
    run_one("SoA multi-blob", SoA::multi_blob(&d, dims.clone()), &state);
    run_one("AoSoA16", AoSoA::new(&d, dims.clone(), 16), &state);
    run_one(
        "Split(pos | rest)",
        Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![0]),
            |sd, ad| SoA::multi_blob(sd, ad),
            |sd, ad| AoS::aligned(sd, ad),
        ),
        &state,
    );

    // Then the fig 5 measurement tables.
    let (update, mv) = fig5_nbody::run(&opts);
    println!("{}", update.to_text());
    println!("{}", mv.to_text());
}

fn run_one<M: Mapping>(name: &str, mapping: M, state: &nbody::ParticleSoA) {
    let mut view = alloc_view(mapping);
    llama_impl::load_state(&mut view, state);
    llama_impl::update(&mut view);
    llama_impl::mv(&mut view);
    let out = llama_impl::store_state(&view);
    println!(
        "  {name:>18}: vel[0] = ({:+.6}, {:+.6}, {:+.6})  E_kin = {:.4}",
        out.vel[0][0],
        out.vel[1][0],
        out.vel[2][0],
        nbody::kinetic_energy(&out)
    );
}
