//! Quickstart: define a record dimension, allocate views with different
//! mappings, access data through the layout-independent API, and copy
//! between layouts — the paper's §3 walkthrough end to end.
//!
//! Run: `cargo run --release --example quickstart`

use llama::prelude::*;

fn main() {
    // §3.3 — describe the data structure (paper listing 1).
    let particle = llama::record_dim! {
        id: u16,
        pos: { x: f32, y: f32, z: f32 },
        mass: f64,
        flags: [bool; 3],
    };
    let dims = ArrayDims::from([128, 256, 32]);
    println!(
        "record: {} leaf fields, packed {} B, aligned {} B; array dims {:?} = {} records",
        particle.leaf_count(),
        particle.packed_size(),
        RecordInfo::new(&particle).aligned_size,
        dims.extents(),
        dims.count()
    );

    // §3.4 — create a view. The layout is ONE line; everything below is
    // layout-independent.
    let mapping = SoA::multi_blob(&particle, dims.clone());
    let mut view = alloc_view(mapping);

    // Resolve field handles once (the "compile-time" record coords).
    let info = view.mapping().info().clone();
    let mass = info.leaf_by_path("mass").unwrap();
    let pos_x = info.leaf_by_path("pos.x").unwrap();

    // §3.5 — write through flat accessors and virtual records.
    for i in 0..view.count() {
        view.set::<f64>(i, mass, 1.0);
        view.set::<f32>(i, pos_x, i as f32 * 0.5);
    }
    let mut rec = view.record_mut(5);
    rec.set_path::<bool>("flags.1", true);
    let p5 = view.record(5);
    println!(
        "record 5: pos.x={}, mass={}, flags.1={}",
        p5.get_path::<f32>("pos.x"),
        p5.get_path::<f64>("mass"),
        p5.get_path::<bool>("flags.1"),
    );

    // §3.6 — iterate like the STL.
    let total_mass: f64 = (&view).into_iter().map(|r| r.get_path::<f64>("mass")).sum();
    println!("total mass = {total_mass}");

    // §3.9 — switch to a different layout via the layout-aware copy.
    let mut aosoa = alloc_view(AoSoA::new(&particle, dims.clone(), 16));
    let method = copy(&view, &mut aosoa);
    println!("copied SoA-MB -> AoSoA16 via {method:?}");
    assert!(views_equal(&view, &aosoa));
    println!(
        "AoSoA16 view agrees field-wise; record 5 pos.x = {}",
        aosoa.record(5).get_path::<f32>("pos.x")
    );

    // §3.7 — dump the layout as SVG (paper fig 4).
    let svg = dump_svg(&AoS::packed(&particle, ArrayDims::linear(4)), 4, 64);
    std::fs::create_dir_all("artifacts/dumps").unwrap();
    std::fs::write("artifacts/dumps/quickstart_aos.svg", svg).unwrap();
    println!("wrote artifacts/dumps/quickstart_aos.svg");
}
