//! Instrumented mappings (paper §3.7 Trace/Heatmap, fig 4d, §4.3):
//! trace per-field access counts of an LBM step, derive the hot/cold
//! Split the paper built for SPEC lbm, and render a byte heatmap of the
//! n-body move phase.
//!
//! Run: `cargo run --release --example heatmap_dump`

use llama::prelude::*;
use llama::workloads::lbm::split4::build_split4;
use llama::workloads::lbm::step as lbm_step;
use llama::workloads::lbm::{cell_dim, Geometry};
use llama::workloads::nbody::{self, llama_impl};

fn main() {
    // --- Trace: count field accesses of one lbm step (paper §4.3). ---
    let geo = Geometry::channel_with_sphere(12, 12, 12, 3);
    let d = cell_dim();
    let traced = Trace::new(AoS::aligned(&d, geo.dims.clone()));
    let mut src = alloc_view(traced);
    let mut dst = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    lbm_step::init(&mut src, &geo);
    lbm_step::step(&src, &mut dst);

    println!("per-field access counts of one D3Q19 step:");
    print!("{}", src.mapping().to_table());

    let groups = src.mapping().equal_count_groups(4);
    println!("\n4 equal-access-count groups (paper's Split derivation):");
    for (i, g) in groups.iter().enumerate() {
        let names: Vec<&str> = g
            .iter()
            .map(|&l| src.mapping().info().fields[l].path.as_str())
            .collect();
        println!("  group {i}: {names:?}");
    }
    let split = build_split4(&d, geo.dims.clone(), &groups);
    println!("derived mapping: {}", split.mapping_name());

    // --- Heatmap: byte-level access counts of the n-body move. ---
    let n = 128;
    let pd = nbody::particle_dim();
    let h = Heatmap::with_granularity(AoS::packed(&pd, ArrayDims::linear(n)), 4);
    let mut view = alloc_view(h);
    let s = nbody::init_particles(n, 1);
    llama_impl::load_state(&mut view, &s);
    view.mapping().reset(); // drop the load traffic, keep the kernel's
    llama_impl::mv(&mut view);

    println!("\nbyte heatmap of one `move` sweep over packed AoS");
    println!("(hot = pos/vel, cold = mass — the 1/7 wasted-load of fig 5):");
    print!("{}", heatmap_ascii(view.mapping(), 112));

    std::fs::create_dir_all("artifacts/dumps").unwrap();
    let pgm = llama::dump::heatmap_pgm(view.mapping(), 0, 112);
    std::fs::write("artifacts/dumps/nbody_move_heat.pgm", pgm).unwrap();
    println!("wrote artifacts/dumps/nbody_move_heat.pgm");
}
