//! END-TO-END driver: proves all three layers compose.
//!
//! Rust (L3) owns particle memory in LLAMA views and reshuffles layouts
//! with the layout-aware copy; the compute is the JAX (L2) step
//! function wrapping the Pallas (L1) tiled kernel, AOT-lowered by
//! `make artifacts` and executed here through the PJRT CPU client.
//! Python is not involved at runtime.
//!
//! Run: `make artifacts && cargo run --release --example e2e_xla_nbody`

use llama::coordinator::fig6_xla;
use llama::prelude::*;
use llama::runtime::Runtime;
use llama::workloads::nbody::{self, llama_impl};

fn main() -> llama::error::Result<()> {
    let artifacts = std::env::var("LLAMA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    let mut rt = Runtime::cpu(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());

    // 1. Correctness gate: XLA stack vs the Rust LLAMA kernel.
    let opts = llama::coordinator::bench::Opts {
        artifacts: artifacts.clone(),
        ..Default::default()
    };
    let rel = fig6_xla::verify_against_rust(&opts)?;
    println!("L1/L2 (Pallas/JAX via PJRT) vs L3 (Rust kernel): max rel err = {rel:.2e}");
    llama::ensure!(rel < 1e-4, "stack mismatch");

    // 2. LLAMA-managed memory: state lives in a multi-blob SoA view
    //    whose blobs are exactly the f32[N] buffers the artifact wants.
    let exe = rt.load("nbody_step_soa")?;
    let n = exe.meta().n;
    let d = nbody::particle_dim();
    let mut view = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    llama_impl::load_state(&mut view, &nbody::init_particles(n, 2024));

    let mut inputs: Vec<Vec<f32>> = view
        .blobs()
        .iter()
        .map(|b| b.chunks_exact(4).map(|c| f32::from_ne_bytes(c.try_into().unwrap())).collect())
        .collect();

    // 3. Run the loop; log the kinetic-energy curve (EXPERIMENTS.md).
    println!("running {steps} steps of N={n} all-pairs n-body on the PJRT CPU client:");
    let t0 = std::time::Instant::now();
    let mut energy_log = Vec::new();
    for step in 0..steps {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut out = exe.run_f32(&refs)?;
        let e = out.pop().unwrap()[0];
        energy_log.push((step, e));
        inputs = out;
    }
    let dt = t0.elapsed();
    for (s, e) in &energy_log {
        println!("  step {s:>3}: E_kin = {e:.6}");
    }
    println!(
        "{} steps in {:.1} ms ({:.2} ms/step, {:.1} M pairs/s)",
        steps,
        dt.as_secs_f64() * 1e3,
        dt.as_secs_f64() * 1e3 / steps as f64,
        (n * n * steps) as f64 / dt.as_secs_f64() / 1e6
    );

    // 4. Pull the final state back into LLAMA views and reshuffle into
    //    an AoSoA16 layout with the chunked copy (L3's contribution).
    let info = view.mapping().info().clone();
    for (leaf, data) in inputs.iter().enumerate() {
        for (i, v) in data.iter().enumerate() {
            view.set::<f32>(i, leaf, *v);
        }
    }
    let _ = info;
    let mut aosoa = alloc_view(AoSoA::new(&d, ArrayDims::linear(n), 16));
    let method = copy(&view, &mut aosoa);
    assert!(views_equal(&view, &aosoa));
    println!("final state reshuffled SoA-MB -> AoSoA16 via {method:?} and verified");
    Ok(())
}
