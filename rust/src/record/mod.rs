//! The **record dimension**: LLAMA's compile-time description of nested,
//! structured data (paper §3.3).
//!
//! In the C++ original the record dimension is a type-level tree
//! (`llama::Record<llama::Field<Tag, Type>...>`). In this Rust
//! reproduction it is a value-level tree ([`RecordDim`]) that is built
//! once, *ahead of the hot loop*, and flattened into a leaf-field table
//! ([`RecordInfo`]) whose per-field strides and offsets are plain
//! integers. Mappings capture those integers at construction, so every
//! terminal access inlines to `linear_index * stride + constant` — the
//! same "compiler sees through it" property the paper demonstrates via
//! identical disassembly (its Listings 10/11).

pub mod coord;
pub mod dim;
pub mod flatten;
pub mod permute;
#[macro_use]
pub mod macros;

pub use coord::RecordCoord;
pub use dim::{Field, RecordDim, Scalar, Type};
pub use flatten::{FlatField, RecordInfo};
