//! Flattening the record tree into a leaf-field table.
//!
//! [`RecordInfo`] is the per-record-dimension data every mapping is
//! constructed from: for each terminal field its scalar type, its byte
//! offset within a packed record and within an aligned (C++-struct-rule)
//! record, and its [`RecordCoord`]. This is computed once; hot-path
//! accesses only index into these precomputed arrays.

use super::coord::RecordCoord;
use super::dim::{RecordDim, Scalar, Type};

/// One terminal (leaf) field of the flattened record dimension.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlatField {
    /// Path from the root of the record tree to this leaf.
    pub coord: RecordCoord,
    /// Dotted name path, e.g. `"pos.x"` or `"flags.2"`.
    pub path: String,
    /// Elemental type of the leaf.
    pub scalar: Scalar,
    /// Byte offset inside one *packed* (padding-free) record.
    pub offset_packed: usize,
    /// Byte offset inside one *aligned* record (C++ struct layout rules:
    /// each field aligned to its natural alignment; tail padding pads
    /// the record to its max alignment).
    pub offset_aligned: usize,
}

impl FlatField {
    #[inline]
    pub fn size(&self) -> usize {
        self.scalar.size()
    }
}

/// Flattened description of a record dimension. Shared (via `Arc` in
/// mappings) between all views of the same record dimension.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecordInfo {
    /// The original tree (kept for dumps, name lookup, splitting).
    pub dim: RecordDim,
    /// Leaf fields in declaration order.
    pub fields: Vec<FlatField>,
    /// Byte size of one packed record.
    pub packed_size: usize,
    /// Byte size of one aligned record, tail padding included.
    pub aligned_size: usize,
    /// Max leaf alignment.
    pub max_align: usize,
}

fn align_up(x: usize, a: usize) -> usize {
    debug_assert!(a.is_power_of_two());
    (x + a - 1) & !(a - 1)
}

impl RecordInfo {
    /// Flatten a record dimension. Cost is proportional to the number of
    /// leaves; run once at mapping construction.
    pub fn new(dim: &RecordDim) -> Self {
        let mut fields = Vec::with_capacity(dim.leaf_count());
        let mut packed = 0usize;
        let mut aligned = 0usize;
        fn walk(
            ty: &Type,
            coord: &RecordCoord,
            path: &str,
            fields: &mut Vec<FlatField>,
            packed: &mut usize,
            aligned: &mut usize,
        ) {
            match ty {
                Type::Scalar(s) => {
                    *aligned = align_up(*aligned, s.align());
                    fields.push(FlatField {
                        coord: coord.clone(),
                        path: path.to_string(),
                        scalar: *s,
                        offset_packed: *packed,
                        offset_aligned: *aligned,
                    });
                    *packed += s.size();
                    *aligned += s.size();
                }
                Type::Record(fs) => {
                    // C++ rule: a struct is aligned to its max member
                    // alignment.
                    *aligned = align_up(*aligned, ty.max_align());
                    for (i, f) in fs.iter().enumerate() {
                        let sub = if path.is_empty() {
                            f.name.clone()
                        } else {
                            format!("{path}.{}", f.name)
                        };
                        walk(&f.ty, &coord.child(i), &sub, fields, packed, aligned);
                    }
                    *aligned = align_up(*aligned, ty.max_align());
                }
                Type::Array(inner, n) => {
                    *aligned = align_up(*aligned, inner.max_align());
                    for i in 0..*n {
                        let sub = if path.is_empty() {
                            format!("{i}")
                        } else {
                            format!("{path}.{i}")
                        };
                        walk(inner, &coord.child(i), &sub, fields, packed, aligned);
                    }
                }
            }
        }
        for (i, f) in dim.fields.iter().enumerate() {
            walk(
                &f.ty,
                &RecordCoord::new(vec![i]),
                &f.name,
                &mut fields,
                &mut packed,
                &mut aligned,
            );
        }
        let max_align = dim.max_align();
        let aligned_size = align_up(aligned, max_align);
        RecordInfo {
            dim: dim.clone(),
            fields,
            packed_size: packed,
            aligned_size,
            max_align,
        }
    }

    /// Number of leaf fields.
    #[inline]
    pub fn leaf_count(&self) -> usize {
        self.fields.len()
    }

    /// Find the flat index of a leaf by dotted name path (`"pos.x"`).
    /// Slow path — resolve once outside hot loops.
    pub fn leaf_by_path(&self, path: &str) -> Option<usize> {
        self.fields.iter().position(|f| f.path == path)
    }

    /// Find the flat index of a leaf by record coordinate.
    pub fn leaf_by_coord(&self, coord: &RecordCoord) -> Option<usize> {
        self.fields.iter().position(|f| &f.coord == coord)
    }

    /// All flat leaf indices under the subtree rooted at `prefix`
    /// (paper's non-terminal access: `particle(Pos{})` selects pos.*).
    pub fn leaves_under(&self, prefix: &RecordCoord) -> Vec<usize> {
        self.fields
            .iter()
            .enumerate()
            .filter(|(_, f)| prefix.is_prefix_of(&f.coord))
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::dim::Field;

    /// The paper's listing-1 Particle record.
    pub fn particle() -> RecordDim {
        let vec3 = Type::Record(vec![
            Field::new("x", Type::Scalar(Scalar::F32)),
            Field::new("y", Type::Scalar(Scalar::F32)),
        ]);
        RecordDim::new()
            .scalar("id", Scalar::U16)
            .field("pos", vec3)
            .scalar("mass", Scalar::F64)
            .array("flags", Type::Scalar(Scalar::Bool), 3)
    }

    #[test]
    fn flatten_paths_and_coords() {
        let info = RecordInfo::new(&particle());
        let paths: Vec<&str> = info.fields.iter().map(|f| f.path.as_str()).collect();
        assert_eq!(
            paths,
            vec!["id", "pos.x", "pos.y", "mass", "flags.0", "flags.1", "flags.2"]
        );
        assert_eq!(info.fields[1].coord, RecordCoord::new(vec![1, 0]));
        assert_eq!(info.fields[6].coord, RecordCoord::new(vec![3, 2]));
    }

    #[test]
    fn packed_offsets_have_no_holes() {
        let info = RecordInfo::new(&particle());
        let mut expect = 0;
        for f in &info.fields {
            assert_eq!(f.offset_packed, expect);
            expect += f.size();
        }
        assert_eq!(info.packed_size, expect);
        assert_eq!(info.packed_size, 2 + 4 + 4 + 8 + 3);
    }

    #[test]
    fn aligned_offsets_respect_alignment() {
        let info = RecordInfo::new(&particle());
        for f in &info.fields {
            assert_eq!(
                f.offset_aligned % f.scalar.align(),
                0,
                "field {} misaligned",
                f.path
            );
        }
        // u16 id @0, pad→4, pos.x @4, pos.y @8, mass @16 (aligned 8),
        // flags @24..27, tail pad → 32.
        assert_eq!(info.fields[0].offset_aligned, 0);
        assert_eq!(info.fields[1].offset_aligned, 4);
        assert_eq!(info.fields[3].offset_aligned, 16);
        assert_eq!(info.aligned_size, 32);
        assert_eq!(info.max_align, 8);
    }

    #[test]
    fn leaf_lookup() {
        let info = RecordInfo::new(&particle());
        assert_eq!(info.leaf_by_path("pos.y"), Some(2));
        assert_eq!(info.leaf_by_path("nope"), None);
        assert_eq!(info.leaf_by_coord(&RecordCoord::new(vec![2])), Some(3));
    }

    #[test]
    fn leaves_under_subtree() {
        let info = RecordInfo::new(&particle());
        assert_eq!(info.leaves_under(&RecordCoord::new(vec![1])), vec![1, 2]);
        assert_eq!(info.leaves_under(&RecordCoord::new(vec![3])), vec![4, 5, 6]);
        assert_eq!(
            info.leaves_under(&RecordCoord::root()),
            (0..7).collect::<Vec<_>>()
        );
    }

    #[test]
    fn aligned_size_multiple_of_align() {
        let info = RecordInfo::new(&particle());
        assert_eq!(info.aligned_size % info.max_align, 0);
    }
}
