//! `record_dim!` — ergonomic DSL for defining record dimensions, the
//! analogue of the paper's listing 1.
//!
//! ```
//! use llama::record_dim;
//! use llama::record::Scalar;
//! let particle = record_dim! {
//!     id: u16,
//!     pos: { x: f32, y: f32, z: f32 },
//!     mass: f64,
//!     flags: [bool; 3],
//! };
//! assert_eq!(particle.leaf_count(), 8);
//! ```

/// Map a Rust scalar type token to a [`crate::record::Scalar`].
#[macro_export]
macro_rules! llama_scalar {
    (f32) => {
        $crate::record::Scalar::F32
    };
    (f64) => {
        $crate::record::Scalar::F64
    };
    (i8) => {
        $crate::record::Scalar::I8
    };
    (i16) => {
        $crate::record::Scalar::I16
    };
    (i32) => {
        $crate::record::Scalar::I32
    };
    (i64) => {
        $crate::record::Scalar::I64
    };
    (u8) => {
        $crate::record::Scalar::U8
    };
    (u16) => {
        $crate::record::Scalar::U16
    };
    (u32) => {
        $crate::record::Scalar::U32
    };
    (u64) => {
        $crate::record::Scalar::U64
    };
    (bool) => {
        $crate::record::Scalar::Bool
    };
}

/// Build a [`crate::record::Type`] from a field-type token.
#[macro_export]
macro_rules! llama_type {
    ({ $($name:ident : $ty:tt),+ $(,)? }) => {
        $crate::record::Type::Record(vec![
            $($crate::record::Field::new(
                stringify!($name),
                $crate::llama_type!($ty),
            )),+
        ])
    };
    ([ $ty:tt ; $n:expr ]) => {
        $crate::record::Type::Array(Box::new($crate::llama_type!($ty)), $n)
    };
    ($s:ident) => {
        $crate::record::Type::Scalar($crate::llama_scalar!($s))
    };
}

/// Define a [`crate::record::RecordDim`] with struct-like syntax.
#[macro_export]
macro_rules! record_dim {
    ( $($name:ident : $ty:tt),+ $(,)? ) => {
        $crate::record::RecordDim {
            fields: vec![
                $($crate::record::Field::new(
                    stringify!($name),
                    $crate::llama_type!($ty),
                )),+
            ],
        }
    };
}

#[cfg(test)]
mod tests {
    use crate::record::{RecordInfo, Scalar, Type};

    #[test]
    fn macro_builds_nested_record() {
        let d = record_dim! {
            id: u16,
            pos: { x: f32, y: f32, z: f32 },
            mass: f64,
            flags: [bool; 3],
        };
        assert_eq!(d.fields.len(), 4);
        assert_eq!(d.leaf_count(), 8);
        let info = RecordInfo::new(&d);
        assert_eq!(info.leaf_by_path("pos.z"), Some(3));
        assert_eq!(info.fields[0].scalar, Scalar::U16);
        match &d.fields[3].ty {
            Type::Array(inner, 3) => assert_eq!(**inner, Type::Scalar(Scalar::Bool)),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn macro_deep_nesting() {
        let d = record_dim! {
            a: { b: { c: { d: f32 } } },
        };
        let info = RecordInfo::new(&d);
        assert_eq!(info.fields[0].path, "a.b.c.d");
        assert_eq!(info.fields[0].coord.0, vec![0, 0, 0, 0]);
    }
}
