//! [`RecordCoord`]: a path of child indices into the record-dimension
//! tree — the paper's `llama::RecordCoord<Is...>` (§3.6, `forEachLeaf`).

use std::fmt;

/// A coordinate into the record tree: a sequence of child indices from
/// the root to some node (usually a leaf).
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default, PartialOrd, Ord)]
pub struct RecordCoord(pub Vec<usize>);

impl RecordCoord {
    pub fn root() -> Self {
        RecordCoord(Vec::new())
    }

    pub fn new(path: impl Into<Vec<usize>>) -> Self {
        RecordCoord(path.into())
    }

    /// Append one more child index (descend a level).
    pub fn child(&self, i: usize) -> Self {
        let mut p = self.0.clone();
        p.push(i);
        RecordCoord(p)
    }

    pub fn depth(&self) -> usize {
        self.0.len()
    }

    pub fn is_root(&self) -> bool {
        self.0.is_empty()
    }

    /// True if `self` is a (non-strict) prefix of `other`: the node at
    /// `self` contains the node at `other`.
    pub fn is_prefix_of(&self, other: &RecordCoord) -> bool {
        other.0.len() >= self.0.len() && other.0[..self.0.len()] == self.0[..]
    }
}

impl fmt::Display for RecordCoord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "RecordCoord<")?;
        for (i, c) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{c}")?;
        }
        write!(f, ">")
    }
}

impl From<Vec<usize>> for RecordCoord {
    fn from(v: Vec<usize>) -> Self {
        RecordCoord(v)
    }
}

impl<const N: usize> From<[usize; N]> for RecordCoord {
    fn from(v: [usize; N]) -> Self {
        RecordCoord(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_relation() {
        let pos: RecordCoord = [1].into();
        let pos_x: RecordCoord = [1, 0].into();
        let mass: RecordCoord = [2].into();
        assert!(pos.is_prefix_of(&pos_x));
        assert!(pos.is_prefix_of(&pos));
        assert!(!pos.is_prefix_of(&mass));
        assert!(!pos_x.is_prefix_of(&pos));
        assert!(RecordCoord::root().is_prefix_of(&mass));
    }

    #[test]
    fn child_and_display() {
        let c = RecordCoord::root().child(3).child(1);
        assert_eq!(c, RecordCoord::new(vec![3, 1]));
        assert_eq!(c.to_string(), "RecordCoord<3,1>");
        assert_eq!(c.depth(), 2);
        assert!(!c.is_root());
        assert!(RecordCoord::root().is_root());
    }
}
