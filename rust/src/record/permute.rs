//! Field-permutation helpers (paper §3.7: "type list algorithms to
//! permute the record dimension to minimize padding introduced by
//! alignment").

use super::dim::{RecordDim, Type};

/// Return a copy of the record dimension with its *top-level* fields
/// sorted by decreasing alignment (stable within equal alignment), which
/// minimizes alignment padding for the aligned-AoS layout.
pub fn minimize_padding(dim: &RecordDim) -> RecordDim {
    let mut fields = dim.fields.clone();
    fields.sort_by(|a, b| b.ty.max_align().cmp(&a.ty.max_align()));
    RecordDim { fields }
}

/// Like [`minimize_padding`] but recursing into nested records.
pub fn minimize_padding_deep(dim: &RecordDim) -> RecordDim {
    fn fix(ty: &Type) -> Type {
        match ty {
            Type::Scalar(s) => Type::Scalar(*s),
            Type::Record(fs) => {
                let inner = RecordDim { fields: fs.iter().cloned().collect() };
                let mut sorted = minimize_padding(&inner).fields;
                for f in &mut sorted {
                    f.ty = fix(&f.ty);
                }
                Type::Record(sorted)
            }
            Type::Array(inner, n) => Type::Array(Box::new(fix(inner)), *n),
        }
    }
    let mut out = minimize_padding(dim);
    for f in &mut out.fields {
        f.ty = fix(&f.ty);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::dim::Scalar;
    use crate::record::flatten::RecordInfo;

    #[test]
    fn permutation_reduces_aligned_size() {
        // u8, f64, u8, f64 → aligned = 1+7pad+8+1+7pad+8 = 32.
        let bad = RecordDim::new()
            .scalar("a", Scalar::U8)
            .scalar("b", Scalar::F64)
            .scalar("c", Scalar::U8)
            .scalar("d", Scalar::F64);
        let bad_info = RecordInfo::new(&bad);
        assert_eq!(bad_info.aligned_size, 32);

        let good = minimize_padding(&bad);
        let good_info = RecordInfo::new(&good);
        // f64, f64, u8, u8 → 8+8+1+1 = 18 → pad to 24.
        assert_eq!(good_info.aligned_size, 24);
        // Packed size is invariant under permutation.
        assert_eq!(good_info.packed_size, bad_info.packed_size);
    }

    #[test]
    fn permutation_is_stable_for_equal_align() {
        let d = RecordDim::new()
            .scalar("x", Scalar::F32)
            .scalar("y", Scalar::F32)
            .scalar("z", Scalar::F32);
        let p = minimize_padding(&d);
        let names: Vec<&str> = p.fields.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["x", "y", "z"]);
    }

    #[test]
    fn deep_permutation_recurses() {
        let inner = RecordDim::new()
            .scalar("flag", Scalar::U8)
            .scalar("val", Scalar::F64);
        let d = RecordDim::new().scalar("tiny", Scalar::U8).record("sub", inner);
        let p = minimize_padding_deep(&d);
        // sub (align 8) must come before tiny (align 1).
        assert_eq!(p.fields[0].name, "sub");
        if let Type::Record(fs) = &p.fields[0].ty {
            assert_eq!(fs[0].name, "val"); // f64 before u8 inside too
        } else {
            panic!("expected record");
        }
    }
}
