//! Record-dimension type tree: [`Scalar`], [`Type`], [`Field`], [`RecordDim`].

use std::fmt;

/// Elemental types LLAMA does not decompose further (paper §3.3: "The
/// `Type` type is either an elemental type not further decomposed by
/// LLAMA or another `Record`").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scalar {
    F32,
    F64,
    I8,
    I16,
    I32,
    I64,
    U8,
    U16,
    U32,
    U64,
    Bool,
}

impl Scalar {
    /// Size of the scalar in bytes.
    #[inline]
    pub const fn size(self) -> usize {
        match self {
            Scalar::I8 | Scalar::U8 | Scalar::Bool => 1,
            Scalar::I16 | Scalar::U16 => 2,
            Scalar::F32 | Scalar::I32 | Scalar::U32 => 4,
            Scalar::F64 | Scalar::I64 | Scalar::U64 => 8,
        }
    }

    /// Natural alignment of the scalar in bytes (== size for all
    /// supported elemental types, like on x86-64/SysV).
    #[inline]
    pub const fn align(self) -> usize {
        self.size()
    }

    /// Short lowercase name, matching Rust spelling (`f32`, `u8`, ...).
    pub const fn name(self) -> &'static str {
        match self {
            Scalar::F32 => "f32",
            Scalar::F64 => "f64",
            Scalar::I8 => "i8",
            Scalar::I16 => "i16",
            Scalar::I32 => "i32",
            Scalar::I64 => "i64",
            Scalar::U8 => "u8",
            Scalar::U16 => "u16",
            Scalar::U32 => "u32",
            Scalar::U64 => "u64",
            Scalar::Bool => "bool",
        }
    }
}

impl fmt::Display for Scalar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A node in the record-dimension tree.
///
/// Mirrors the paper's `Field<Name, Type>` where `Type` is an elemental
/// type, a nested `Record`, or a static array (which LLAMA §3.3 replaces
/// by a record with as many fields as the array's extent — we keep the
/// array node explicit and expand it during flattening).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Type {
    /// Elemental leaf type.
    Scalar(Scalar),
    /// Nested record with named fields.
    Record(Vec<Field>),
    /// Static array `[T; n]`; flattened as fields named `0..n`.
    Array(Box<Type>, usize),
}

impl Type {
    /// Number of leaf (terminal) fields in this subtree.
    pub fn leaf_count(&self) -> usize {
        match self {
            Type::Scalar(_) => 1,
            Type::Record(fields) => fields.iter().map(|f| f.ty.leaf_count()).sum(),
            Type::Array(inner, n) => inner.leaf_count() * n,
        }
    }

    /// Sum of leaf sizes: the packed (padding-free) byte size.
    pub fn packed_size(&self) -> usize {
        match self {
            Type::Scalar(s) => s.size(),
            Type::Record(fields) => fields.iter().map(|f| f.ty.packed_size()).sum(),
            Type::Array(inner, n) => inner.packed_size() * n,
        }
    }

    /// Largest leaf alignment in this subtree.
    pub fn max_align(&self) -> usize {
        match self {
            Type::Scalar(s) => s.align(),
            Type::Record(fields) => fields.iter().map(|f| f.ty.max_align()).max().unwrap_or(1),
            Type::Array(inner, _) => inner.max_align(),
        }
    }
}

/// A named field of a record: the paper's `llama::Field<Name, Type>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    /// Compile-time tag in C++ LLAMA; here a string name.
    pub name: String,
    pub ty: Type,
}

impl Field {
    pub fn new(name: impl Into<String>, ty: Type) -> Self {
        Field { name: name.into(), ty }
    }
}

/// A complete record dimension: the root of the type tree.
///
/// Build either with the fluent helpers here or the [`record_dim!`]
/// macro (see `record::macros`).
///
/// ```
/// use llama::record::{RecordDim, Scalar, Type};
/// let vec3 = Type::Record(vec![
///     llama::record::Field::new("x", Type::Scalar(Scalar::F32)),
///     llama::record::Field::new("y", Type::Scalar(Scalar::F32)),
/// ]);
/// let particle = RecordDim::new()
///     .field("pos", vec3.clone())
///     .scalar("mass", Scalar::F64)
///     .array("flags", Type::Scalar(Scalar::Bool), 3);
/// assert_eq!(particle.leaf_count(), 6);
/// ```
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct RecordDim {
    pub fields: Vec<Field>,
}

impl RecordDim {
    pub fn new() -> Self {
        RecordDim { fields: Vec::new() }
    }

    /// Append a field of arbitrary type.
    pub fn field(mut self, name: impl Into<String>, ty: Type) -> Self {
        self.fields.push(Field::new(name, ty));
        self
    }

    /// Append an elemental field.
    pub fn scalar(self, name: impl Into<String>, s: Scalar) -> Self {
        self.field(name, Type::Scalar(s))
    }

    /// Append a nested record field.
    pub fn record(self, name: impl Into<String>, inner: RecordDim) -> Self {
        self.field(name, Type::Record(inner.fields))
    }

    /// Append a static-array field.
    pub fn array(self, name: impl Into<String>, elem: Type, n: usize) -> Self {
        self.field(name, Type::Array(Box::new(elem), n))
    }

    /// View the record dimension as a [`Type::Record`] node.
    pub fn as_type(&self) -> Type {
        Type::Record(self.fields.clone())
    }

    pub fn leaf_count(&self) -> usize {
        self.fields.iter().map(|f| f.ty.leaf_count()).sum()
    }

    pub fn packed_size(&self) -> usize {
        self.fields.iter().map(|f| f.ty.packed_size()).sum()
    }

    pub fn max_align(&self) -> usize {
        self.fields.iter().map(|f| f.ty.max_align()).max().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn particle() -> RecordDim {
        let vec3 = RecordDim::new()
            .scalar("x", Scalar::F32)
            .scalar("y", Scalar::F32)
            .scalar("z", Scalar::F32);
        RecordDim::new()
            .scalar("id", Scalar::U16)
            .record("pos", vec3)
            .scalar("mass", Scalar::F64)
            .array("flags", Type::Scalar(Scalar::Bool), 3)
    }

    #[test]
    fn scalar_sizes() {
        assert_eq!(Scalar::F32.size(), 4);
        assert_eq!(Scalar::F64.size(), 8);
        assert_eq!(Scalar::Bool.size(), 1);
        assert_eq!(Scalar::U16.align(), 2);
        assert_eq!(Scalar::I64.name(), "i64");
    }

    #[test]
    fn leaf_count_nested() {
        // id + pos.{x,y,z} + mass + flags[0..3] = 8 leaves — the paper's
        // listing-1 Particle.
        assert_eq!(particle().leaf_count(), 8);
    }

    #[test]
    fn packed_size_nested() {
        // 2 + 3*4 + 8 + 3*1 = 25 bytes packed.
        assert_eq!(particle().packed_size(), 25);
    }

    #[test]
    fn max_align_is_largest_leaf() {
        assert_eq!(particle().max_align(), 8); // mass: f64
    }

    #[test]
    fn array_expansion_counts() {
        let d = RecordDim::new().array("a", Type::Scalar(Scalar::F32), 5);
        assert_eq!(d.leaf_count(), 5);
        assert_eq!(d.packed_size(), 20);
    }

    #[test]
    fn empty_record() {
        let d = RecordDim::new();
        assert_eq!(d.leaf_count(), 0);
        assert_eq!(d.packed_size(), 0);
        assert_eq!(d.max_align(), 1);
    }
}
