//! Uninhabitable stand-ins for [`Runtime`]/[`Executable`] when the
//! `xla` feature (vendored PJRT bindings) is off. Constructors return a
//! descriptive error; every other method is statically unreachable, so
//! callers compile unchanged and degrade to their "artifacts missing /
//! runtime skipped" paths.

use std::convert::Infallible;
use std::path::Path;

use crate::error::Result;

use super::manifest::{Artifact, Manifest};

/// Stub PJRT runtime (build with `--features xla` for the real one).
pub struct Runtime {
    never: Infallible,
}

impl Runtime {
    pub fn cpu(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        crate::bail!(
            "PJRT runtime not compiled in: rebuild with `--features xla` \
             (requires the vendored xla crate, see README.md §Runtime)"
        )
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }

    pub fn load(&mut self, _name: &str) -> Result<&Executable> {
        match self.never {}
    }
}

/// Stub compiled artifact.
pub struct Executable {
    never: Infallible,
}

impl Executable {
    pub fn meta(&self) -> &Artifact {
        match self.never {}
    }

    pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructor_reports_missing_feature() {
        let err = Runtime::cpu("artifacts").err().expect("stub must refuse");
        assert!(err.to_string().contains("--features xla"), "{err}");
        assert!(!crate::runtime::available());
    }
}
