//! Artifact manifest: `python -m compile.aot` writes one line per
//! lowered variant; this parser is the contract between the compile
//! path and the Rust runtime (plain whitespace format — no serde in the
//! vendored dependency set).

use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Result};

/// One AOT artifact's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    pub name: String,
    /// File name relative to the manifest directory.
    pub file: String,
    /// Problem size the artifact was lowered for.
    pub n: usize,
    /// Pallas tile (0 = untiled / plain-XLA variant).
    pub tile: usize,
    pub dtype: String,
    /// `soa` or `aos` — the fig 6 global-memory-layout axis.
    pub layout: String,
    pub inputs: usize,
    pub outputs: usize,
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

fn kv<'a>(parts: &'a [&str], key: &str) -> Result<&'a str> {
    parts
        .iter()
        .find_map(|p| p.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
        .with_context(|| format!("manifest line missing {key}="))
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 3 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            artifacts.push(Artifact {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                n: kv(&parts, "n")?.parse().context("n")?,
                tile: kv(&parts, "tile")?.parse().context("tile")?,
                dtype: kv(&parts, "dtype")?.to_string(),
                layout: kv(&parts, "layout")?.to_string(),
                inputs: kv(&parts, "inputs")?.parse().context("inputs")?,
                outputs: kv(&parts, "outputs")?.parse().context("outputs")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn find(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
nbody_update_soa nbody_update_soa.hlo.txt n=1024 tile=256 dtype=f32 layout=soa inputs=7 outputs=3
nbody_move_aos nbody_move_aos.hlo.txt n=65536 tile=256 dtype=f32 layout=aos inputs=1 outputs=1

# comment line
";

    #[test]
    fn parses_lines_and_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("nbody_update_soa").unwrap();
        assert_eq!(a.n, 1024);
        assert_eq!(a.tile, 256);
        assert_eq!(a.layout, "soa");
        assert_eq!(a.inputs, 7);
        assert_eq!(
            m.path_of(a),
            PathBuf::from("/tmp/a/nbody_update_soa.hlo.txt")
        );
        assert!(m.find("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("oops", PathBuf::new()).is_err());
        assert!(Manifest::parse("a b c", PathBuf::new()).is_err()); // no kv
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration hook: parse the actual artifacts dir when present.
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.find("nbody_step_soa").is_ok());
            for a in &m.artifacts {
                assert!(m.path_of(a).exists(), "{} missing", a.file);
            }
        }
    }
}
