//! Manifests: the plain whitespace `key=value` line format shared by
//! the AOT artifact index (`python -m compile.aot` writes one line per
//! lowered variant) and the [`WireManifest`] that travels in front of a
//! serialized view (see `copy::wire`). No serde in the vendored
//! dependency set — both are parsed by the same `kv` helper.

use std::path::{Path, PathBuf};

use crate::array::ArrayDims;
use crate::error::{Context, Result};
use crate::mapping::{Byteswap, DynMapping, Mapping, WireRecipe};
use crate::record::{Field, RecordDim, Scalar, Type};
use crate::{bail, ensure};

/// One AOT artifact's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    pub name: String,
    /// File name relative to the manifest directory.
    pub file: String,
    /// Problem size the artifact was lowered for.
    pub n: usize,
    /// Pallas tile (0 = untiled / plain-XLA variant).
    pub tile: usize,
    pub dtype: String,
    /// `soa` or `aos` — the fig 6 global-memory-layout axis.
    pub layout: String,
    pub inputs: usize,
    pub outputs: usize,
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

/// Exact-key `key=value` lookup in a whitespace-split manifest line.
///
/// Keys must match exactly up to the first `=`: a `strip_prefix` lookup
/// would let any key that prefixes another (`n` vs `name`, `in` vs
/// `inputs`) resolve to the wrong part. Values may themselves contain
/// `=` — only the first one splits.
fn kv<'a>(parts: &'a [&str], key: &str) -> Result<&'a str> {
    kv_opt(parts, key).with_context(|| format!("manifest line missing {key}="))
}

/// Like [`kv`], but for optional keys: `None` when the key is absent
/// (older peers omit keys newer ones emit) instead of an error.
fn kv_opt<'a>(parts: &'a [&str], key: &str) -> Option<&'a str> {
    parts
        .iter()
        .find_map(|p| p.split_once('=').and_then(|(k, v)| (k == key).then_some(v)))
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 3 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            artifacts.push(Artifact {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                n: kv(&parts, "n")?.parse().context("n")?,
                tile: kv(&parts, "tile")?.parse().context("tile")?,
                dtype: kv(&parts, "dtype")?.to_string(),
                layout: kv(&parts, "layout")?.to_string(),
                inputs: kv(&parts, "inputs")?.parse().context("inputs")?,
                outputs: kv(&parts, "outputs")?.parse().context("outputs")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn find(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

// ---------------------------------------------------------------------
// Wire manifest: the self-describing layout header of `copy::wire`
// ---------------------------------------------------------------------

/// Byte order of a wire payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WireEndian {
    Little,
    Big,
}

impl WireEndian {
    /// This process's byte order.
    pub fn native() -> Self {
        if cfg!(target_endian = "big") {
            WireEndian::Big
        } else {
            WireEndian::Little
        }
    }

    /// True when a payload in this order needs no swap here.
    pub fn is_native(self) -> bool {
        self == Self::native()
    }

    /// The opposite byte order — what a cross-endian peer writes.
    pub fn swapped(self) -> Self {
        match self {
            WireEndian::Little => WireEndian::Big,
            WireEndian::Big => WireEndian::Little,
        }
    }

    /// Manifest token (`little` / `big`).
    pub fn token(self) -> &'static str {
        match self {
            WireEndian::Little => "little",
            WireEndian::Big => "big",
        }
    }

    /// Parse a manifest token.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "little" => Ok(WireEndian::Little),
            "big" => Ok(WireEndian::Big),
            other => bail!("unknown endianness {other:?} (expected little/big)"),
        }
    }
}

/// Self-describing layout header of a serialized view: enough for the
/// receiving process to rebuild a [`crate::view::View`] from the raw
/// payload bytes alone.
///
/// One line of the whitespace manifest format:
///
/// ```text
/// wire record={id:u16,pos:{x:f32,y:f32,z:f32},mass:f64,flags:[bool;3]} \
///      dims=5x7 layout=aos:packed endian=little blobs=875
/// ```
///
/// * `record=` — the record dimension in the grammar of
///   [`format_record`] (no whitespace, so it stays one token).
/// * `dims=` — `x`-separated array extents.
/// * `layout=` — a [`WireRecipe`] token naming the payload's mapping.
/// * `endian=` — the payload's byte order; a receiver whose native
///   order differs wraps the rebuilt mapping in [`Byteswap`].
/// * `blobs=` — comma-separated byte size of each payload blob, in
///   order; the payload is their concatenation. Cross-checked against
///   the rebuilt mapping on parse, so a corrupted length never reaches
///   the payload reader.
/// * `range=<begin>..<end>` — optional: the payload carries only the
///   linearized records `begin..end` of the `dims=` data space, packed
///   densely (the recipe is built over `end - begin` records). Absent
///   for whole-view messages, so PR 8 peers keep parsing unchanged.
/// * `step=<k>` — optional sequencing tag for multiplexed links: frames
///   for different time steps share one connection and the receiver
///   dispatches them by `(step, range)` whatever order they arrive in.
///   The tag does not change the payload layout at all; absent for
///   untagged messages, so older peers keep parsing unchanged.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireManifest {
    pub record: RecordDim,
    pub dims: ArrayDims,
    pub recipe: WireRecipe,
    pub endian: WireEndian,
    pub blob_sizes: Vec<usize>,
    /// Linearized record sub-range `begin..end` the payload covers;
    /// `None` means the whole `dims` data space.
    pub range: Option<(usize, usize)>,
    /// Sequencing tag for multiplexed links; `None` means untagged.
    pub step: Option<usize>,
}

impl WireManifest {
    /// Describe a `record` × `dims` data space stored as `recipe` in
    /// `endian` byte order (blob sizes are derived from the recipe).
    pub fn describe(
        record: RecordDim,
        dims: ArrayDims,
        recipe: WireRecipe,
        endian: WireEndian,
    ) -> Result<Self> {
        ensure!(dims.rank() > 0, "wire manifest needs at least one array extent");
        let m = recipe.build(&record, dims.clone());
        let blob_sizes = (0..m.blob_count()).map(|b| m.blob_size(b)).collect();
        Ok(WireManifest { record, dims, recipe, endian, blob_sizes, range: None, step: None })
    }

    /// Describe a payload carrying only the linearized records
    /// `begin..end` of the `record` × `dims` data space: the recipe is
    /// built over the *range length*, so the blob sizes (and payload)
    /// cover exactly `end - begin` densely packed records, while `dims`
    /// still names the full space the range indexes into.
    pub fn describe_range(
        record: RecordDim,
        dims: ArrayDims,
        recipe: WireRecipe,
        endian: WireEndian,
        begin: usize,
        end: usize,
    ) -> Result<Self> {
        ensure!(dims.rank() > 0, "wire manifest needs at least one array extent");
        ensure!(
            begin < end && end <= dims.count(),
            "wire range {begin}..{end} out of bounds for {} records",
            dims.count()
        );
        let m = recipe.build(&record, ArrayDims::linear(end - begin));
        let blob_sizes = (0..m.blob_count()).map(|b| m.blob_size(b)).collect();
        Ok(WireManifest {
            record,
            dims,
            recipe,
            endian,
            blob_sizes,
            range: Some((begin, end)),
            step: None,
        })
    }

    /// Tag this manifest with a multiplexing step (builder style). The
    /// tag is pure addressing — payload layout and sizes are untouched.
    pub fn with_step(mut self, step: usize) -> Self {
        self.step = Some(step);
        self
    }

    /// Record count the payload actually carries: the range length for
    /// range-restricted messages, the full `dims` count otherwise.
    pub fn payload_records(&self) -> usize {
        match self.range {
            Some((begin, end)) => end - begin,
            None => self.dims.count(),
        }
    }

    /// Total payload length: the blobs are concatenated in order.
    pub fn payload_len(&self) -> usize {
        self.blob_sizes.iter().sum()
    }

    /// Rebuild the payload's mapping: the recipe's concrete layout —
    /// over the range length for range-restricted payloads — wrapped in
    /// [`Byteswap`] when the payload's byte order is not this process's
    /// native order. Fails if the manifest's blob sizes disagree with
    /// the rebuilt layout (a corrupt manifest).
    pub fn build_mapping(&self) -> Result<DynMapping> {
        if let Some((begin, end)) = self.range {
            ensure!(
                begin < end && end <= self.dims.count(),
                "wire range {begin}..{end} out of bounds for {} records",
                self.dims.count()
            );
        }
        let payload_dims = match self.range {
            Some((begin, end)) => ArrayDims::linear(end - begin),
            None => self.dims.clone(),
        };
        let m = self.recipe.build(&self.record, payload_dims);
        let sizes: Vec<usize> = (0..m.blob_count()).map(|b| m.blob_size(b)).collect();
        ensure!(
            sizes == self.blob_sizes,
            "wire manifest blob sizes {:?} disagree with the rebuilt {} layout ({:?})",
            self.blob_sizes,
            m.mapping_name(),
            sizes
        );
        Ok(if self.endian.is_native() { m } else { Box::new(Byteswap::new(m)) })
    }

    /// Format as one manifest line (see the type-level grammar).
    pub fn to_line(&self) -> Result<String> {
        ensure!(self.dims.rank() > 0, "wire manifest needs at least one array extent");
        let record = format_record(&self.record)?;
        let dims: Vec<String> = self.dims.extents().iter().map(|e| e.to_string()).collect();
        let blobs: Vec<String> = self.blob_sizes.iter().map(|s| s.to_string()).collect();
        let mut line = format!(
            "wire record={record} dims={} layout={} endian={} blobs={}",
            dims.join("x"),
            self.recipe.token(),
            self.endian.token(),
            blobs.join(",")
        );
        if let Some((begin, end)) = self.range {
            line.push_str(&format!(" range={begin}..{end}"));
        }
        if let Some(step) = self.step {
            line.push_str(&format!(" step={step}"));
        }
        Ok(line)
    }

    /// Parse one manifest line, rejecting anything that does not
    /// rebuild into a self-consistent layout.
    pub fn parse_line(line: &str) -> Result<Self> {
        let parts: Vec<&str> = line.split_whitespace().collect();
        ensure!(
            parts.first() == Some(&"wire"),
            "not a wire manifest line: {line:?}"
        );
        let record = parse_record(kv(&parts, "record")?)?;
        let dims: Vec<usize> = kv(&parts, "dims")?
            .split('x')
            .map(|e| e.parse::<usize>().context("array extent"))
            .collect::<Result<_>>()?;
        ensure!(!dims.is_empty(), "wire manifest needs at least one array extent");
        let recipe = WireRecipe::parse(kv(&parts, "layout")?)?;
        let endian = WireEndian::parse(kv(&parts, "endian")?)?;
        let blob_sizes: Vec<usize> = kv(&parts, "blobs")?
            .split(',')
            .map(|s| s.parse::<usize>().context("blob size"))
            .collect::<Result<_>>()?;
        let range = match kv_opt(&parts, "range") {
            None => None,
            Some(tok) => {
                let (b, e) = tok
                    .split_once("..")
                    .with_context(|| format!("wire range {tok:?} is not <begin>..<end>"))?;
                Some((
                    b.parse::<usize>().context("range begin")?,
                    e.parse::<usize>().context("range end")?,
                ))
            }
        };
        let step = match kv_opt(&parts, "step") {
            None => None,
            Some(tok) => Some(tok.parse::<usize>().context("wire step tag")?),
        };
        let wm = WireManifest {
            record,
            dims: ArrayDims::new(dims),
            recipe,
            endian,
            blob_sizes,
            range,
            step,
        };
        // Cross-check the declared blob sizes against the rebuilt
        // layout right away: a corrupted size must never reach the
        // payload reader.
        wm.build_mapping()?;
        Ok(wm)
    }
}

/// Format a record dimension in the wire grammar:
/// `{name:type,...}` where `type` is a scalar name (`f32`, `u8`, ...),
/// a nested `{...}` record, or a static array `[type;N]`. No
/// whitespace, so the result is a single manifest token. Fails on
/// field names that would collide with the grammar.
pub fn format_record(d: &RecordDim) -> Result<String> {
    let mut out = String::new();
    format_fields(&d.fields, &mut out)?;
    Ok(out)
}

/// Characters with structural meaning in the record grammar (plus
/// whitespace, which would split the manifest token).
const RECORD_GRAMMAR_CHARS: &str = "{}[]:;,=";

fn name_ok(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| !c.is_whitespace() && !RECORD_GRAMMAR_CHARS.contains(c))
}

fn format_fields(fields: &[Field], out: &mut String) -> Result<()> {
    out.push('{');
    for (i, f) in fields.iter().enumerate() {
        ensure!(
            name_ok(&f.name),
            "field name {:?} cannot appear in a wire manifest",
            f.name
        );
        if i > 0 {
            out.push(',');
        }
        out.push_str(&f.name);
        out.push(':');
        format_type(&f.ty, out)?;
    }
    out.push('}');
    Ok(())
}

fn format_type(t: &Type, out: &mut String) -> Result<()> {
    match t {
        Type::Scalar(s) => out.push_str(s.name()),
        Type::Record(fields) => format_fields(fields, out)?,
        Type::Array(inner, n) => {
            out.push('[');
            format_type(inner, out)?;
            out.push(';');
            out.push_str(&n.to_string());
            out.push(']');
        }
    }
    Ok(())
}

/// Parse the record grammar of [`format_record`] back into a
/// [`RecordDim`]; the round trip is exact (array nodes stay arrays).
pub fn parse_record(s: &str) -> Result<RecordDim> {
    let mut p = RecParser { s, i: 0 };
    let fields = p.fields().context("wire record grammar")?;
    ensure!(
        p.i == s.len(),
        "trailing bytes after wire record: {:?}",
        &s[p.i..]
    );
    Ok(RecordDim { fields })
}

fn scalar_by_name(name: &str) -> Result<Scalar> {
    Ok(match name {
        "f32" => Scalar::F32,
        "f64" => Scalar::F64,
        "i8" => Scalar::I8,
        "i16" => Scalar::I16,
        "i32" => Scalar::I32,
        "i64" => Scalar::I64,
        "u8" => Scalar::U8,
        "u16" => Scalar::U16,
        "u32" => Scalar::U32,
        "u64" => Scalar::U64,
        "bool" => Scalar::Bool,
        other => bail!("unknown scalar type {other:?}"),
    })
}

/// Recursive-descent parser over the record grammar. All structural
/// characters are ASCII, so single-byte advances stay on char
/// boundaries; identifiers are sliced as whole prefixes.
struct RecParser<'a> {
    s: &'a str,
    i: usize,
}

impl<'a> RecParser<'a> {
    fn peek(&self) -> Option<char> {
        self.s[self.i..].chars().next()
    }

    fn eat(&mut self, c: char) -> Result<()> {
        match self.peek() {
            Some(got) if got == c => {
                self.i += c.len_utf8();
                Ok(())
            }
            got => bail!("expected {c:?} at byte {} of record, found {got:?}", self.i),
        }
    }

    /// Longest nonempty run of non-structural, non-whitespace chars.
    fn ident(&mut self) -> Result<&'a str> {
        let rest = &self.s[self.i..];
        let len = rest
            .char_indices()
            .find(|(_, c)| c.is_whitespace() || RECORD_GRAMMAR_CHARS.contains(*c))
            .map_or(rest.len(), |(i, _)| i);
        ensure!(len > 0, "expected a name at byte {} of record", self.i);
        self.i += len;
        Ok(&rest[..len])
    }

    fn ty(&mut self) -> Result<Type> {
        match self.peek() {
            Some('{') => Ok(Type::Record(self.fields()?)),
            Some('[') => {
                self.eat('[')?;
                let inner = self.ty()?;
                self.eat(';')?;
                let n: usize = self.ident()?.parse().context("array extent")?;
                self.eat(']')?;
                Ok(Type::Array(Box::new(inner), n))
            }
            _ => Ok(Type::Scalar(scalar_by_name(self.ident()?)?)),
        }
    }

    fn fields(&mut self) -> Result<Vec<Field>> {
        self.eat('{')?;
        let mut fields = Vec::new();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(fields);
        }
        loop {
            let name = self.ident()?;
            self.eat(':')?;
            let ty = self.ty()?;
            fields.push(Field::new(name, ty));
            match self.peek() {
                Some(',') => self.i += 1,
                Some('}') => {
                    self.i += 1;
                    return Ok(fields);
                }
                got => bail!("expected ',' or '}}' at byte {} of record, found {got:?}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
nbody_update_soa nbody_update_soa.hlo.txt n=1024 tile=256 dtype=f32 layout=soa inputs=7 outputs=3
nbody_move_aos nbody_move_aos.hlo.txt n=65536 tile=256 dtype=f32 layout=aos inputs=1 outputs=1

# comment line
";

    #[test]
    fn parses_lines_and_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("nbody_update_soa").unwrap();
        assert_eq!(a.n, 1024);
        assert_eq!(a.tile, 256);
        assert_eq!(a.layout, "soa");
        assert_eq!(a.inputs, 7);
        assert_eq!(
            m.path_of(a),
            PathBuf::from("/tmp/a/nbody_update_soa.hlo.txt")
        );
        assert!(m.find("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("oops", PathBuf::new()).is_err());
        assert!(Manifest::parse("a b c", PathBuf::new()).is_err()); // no kv
    }

    #[test]
    fn kv_matches_keys_exactly() {
        // Regression: the old strip_prefix lookup resolved "n" to the
        // first part *starting* with "n=", but also let "n" match
        // "name=..." and "in" match "inputs=..." when the exact key was
        // absent or later in the line.
        let parts = ["name=outer", "inputs=3", "n=7", "in=9"];
        assert_eq!(kv(&parts, "n").unwrap(), "7");
        assert_eq!(kv(&parts, "in").unwrap(), "9");
        assert_eq!(kv(&parts, "name").unwrap(), "outer");
        assert_eq!(kv(&parts, "inputs").unwrap(), "3");
        assert!(kv(&parts, "npu").is_err());
        assert!(kv(&["input=1"], "inputs").is_err(), "prefix of the key must not match");
        // Values may themselves contain '=': only the first splits.
        assert_eq!(kv(&["eq=a=b"], "eq").unwrap(), "a=b");
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration hook: parse the actual artifacts dir when present.
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.find("nbody_step_soa").is_ok());
            for a in &m.artifacts {
                assert!(m.path_of(a).exists(), "{} missing", a.file);
            }
        }
    }

    // -- wire manifest ------------------------------------------------

    #[test]
    fn record_grammar_round_trips() {
        let d = crate::mapping_demo_dim();
        let text = format_record(&d).unwrap();
        assert_eq!(
            text,
            "{id:u16,pos:{x:f32,y:f32,z:f32},mass:f64,flags:[bool;3]}"
        );
        assert_eq!(parse_record(&text).unwrap(), d);
        // Nested arrays-of-records round-trip too.
        let odd = RecordDim::new()
            .array("m", RecordDim::new().scalar("v", Scalar::I8).as_type(), 2)
            .scalar("t", Scalar::Bool);
        let text = format_record(&odd).unwrap();
        assert_eq!(parse_record(&text).unwrap(), odd);
        // Empty record is representable.
        assert_eq!(parse_record("{}").unwrap(), RecordDim::new());
    }

    #[test]
    fn record_grammar_rejects_garbage() {
        for bad in [
            "",          // not a record
            "{a:f32",    // unterminated
            "{a:f32}x",  // trailing bytes
            "{a:f99}",   // unknown scalar
            "{a}",       // missing type
            "{:f32}",    // missing name
            "{a:f32,,b:u8}", // empty field
            "{a:[f32;x]}",   // non-numeric extent
            "{a:[f32;3}",    // unterminated array
        ] {
            assert!(parse_record(bad).is_err(), "accepted {bad:?}");
        }
        // Names that collide with the grammar cannot be formatted.
        let bad = RecordDim::new().scalar("a b", Scalar::F32);
        assert!(format_record(&bad).is_err());
        let bad = RecordDim::new().scalar("a:b", Scalar::F32);
        assert!(format_record(&bad).is_err());
    }

    #[test]
    fn wire_line_round_trips() {
        let d = crate::mapping_demo_dim();
        let wm = WireManifest::describe(
            d.clone(),
            ArrayDims::new(vec![5, 7]),
            WireRecipe::AosPacked,
            WireEndian::native(),
        )
        .unwrap();
        // Packed AoS: one blob of 25 B/record × 35 records.
        assert_eq!(wm.blob_sizes, vec![875]);
        assert_eq!(wm.payload_len(), 875);
        let line = wm.to_line().unwrap();
        assert!(line.starts_with("wire record={id:u16,"), "{line}");
        assert!(line.contains("dims=5x7"), "{line}");
        assert!(line.contains("blobs=875"), "{line}");
        let back = WireManifest::parse_line(&line).unwrap();
        assert_eq!(back, wm);
        assert_eq!(back.record, d);
        assert!(back.build_mapping().unwrap().is_native_representation());
    }

    #[test]
    fn wire_multi_blob_and_cross_endian() {
        let d = crate::mapping_demo_dim();
        let wm = WireManifest::describe(
            d,
            ArrayDims::linear(16),
            WireRecipe::SoaMulti,
            WireEndian::native().swapped(),
        )
        .unwrap();
        assert_eq!(wm.blob_sizes.len(), 8); // one blob per leaf
        let line = wm.to_line().unwrap();
        let back = WireManifest::parse_line(&line).unwrap();
        assert_eq!(back, wm);
        // A cross-endian payload rebuilds as a Byteswap-wrapped layout.
        let m = back.build_mapping().unwrap();
        assert!(!m.is_native_representation());
        assert!(m.mapping_name().starts_with("Byteswap("), "{}", m.mapping_name());
    }

    #[test]
    fn wire_range_line_round_trips() {
        let d = crate::mapping_demo_dim();
        // Records 10..22 of a 5×7 space: 12 densely packed records.
        let wm = WireManifest::describe_range(
            d,
            ArrayDims::new(vec![5, 7]),
            WireRecipe::AosPacked,
            WireEndian::native(),
            10,
            22,
        )
        .unwrap();
        assert_eq!(wm.range, Some((10, 22)));
        assert_eq!(wm.payload_records(), 12);
        // Packed AoS over the *range*: 25 B/record × 12 records.
        assert_eq!(wm.blob_sizes, vec![300]);
        assert_eq!(wm.payload_len(), 300);
        let line = wm.to_line().unwrap();
        assert!(line.ends_with("range=10..22"), "{line}");
        let back = WireManifest::parse_line(&line).unwrap();
        assert_eq!(back, wm);
        // The rebuilt mapping covers the range length, not the space.
        assert_eq!(back.build_mapping().unwrap().dims().count(), 12);
    }

    #[test]
    fn wire_range_rejects_out_of_bounds_and_garbage() {
        let d = crate::mapping_demo_dim();
        let dims = ArrayDims::new(vec![5, 7]); // 35 records
        for (b, e) in [(10, 10), (12, 10), (0, 36), (36, 36)] {
            assert!(
                WireManifest::describe_range(
                    d.clone(),
                    dims.clone(),
                    WireRecipe::AosPacked,
                    WireEndian::native(),
                    b,
                    e,
                )
                .is_err(),
                "accepted range {b}..{e}"
            );
        }
        let wm = WireManifest::describe_range(
            d,
            dims,
            WireRecipe::AosPacked,
            WireEndian::native(),
            10,
            22,
        )
        .unwrap();
        let line = wm.to_line().unwrap();
        for broken in [
            line.replace("range=10..22", "range=10..99"), // beyond dims
            line.replace("range=10..22", "range=22..10"), // inverted
            line.replace("range=10..22", "range=10..10"), // empty
            line.replace("range=10..22", "range=ten..22"), // non-numeric
            line.replace("range=10..22", "range=10-22"),  // wrong separator
            // Range dropped but blob sizes still range-sized: the
            // rebuilt whole-space layout disagrees.
            line.replace(" range=10..22", ""),
        ] {
            assert!(WireManifest::parse_line(&broken).is_err(), "accepted {broken:?}");
        }
    }

    #[test]
    fn wire_step_tag_round_trips_and_rejects_garbage() {
        let d = crate::mapping_demo_dim();
        let wm = WireManifest::describe_range(
            d.clone(),
            ArrayDims::new(vec![5, 7]),
            WireRecipe::AosPacked,
            WireEndian::native(),
            10,
            22,
        )
        .unwrap()
        .with_step(4);
        assert_eq!(wm.step, Some(4));
        // Tagging is pure addressing: payload layout is untouched.
        assert_eq!(wm.payload_records(), 12);
        assert_eq!(wm.blob_sizes, vec![300]);
        let line = wm.to_line().unwrap();
        assert!(line.ends_with("range=10..22 step=4"), "{line}");
        let back = WireManifest::parse_line(&line).unwrap();
        assert_eq!(back, wm);
        // Untagged lines parse to step=None (older peers omit the key).
        let untagged = WireManifest::parse_line(&line.replace(" step=4", "")).unwrap();
        assert_eq!(untagged.step, None);
        assert_eq!(untagged.range, wm.range);
        // Whole-view messages may be tagged too.
        let whole = WireManifest::describe(
            d,
            ArrayDims::new(vec![5, 7]),
            WireRecipe::AosPacked,
            WireEndian::native(),
        )
        .unwrap()
        .with_step(0);
        let back = WireManifest::parse_line(&whole.to_line().unwrap()).unwrap();
        assert_eq!(back.step, Some(0));
        for broken in [
            line.replace("step=4", "step=four"), // non-numeric
            line.replace("step=4", "step="),     // empty
            line.replace("step=4", "step=-1"),   // negative
        ] {
            assert!(WireManifest::parse_line(&broken).is_err(), "accepted {broken:?}");
        }
    }

    #[test]
    fn wire_line_rejects_corruption() {
        let d = crate::mapping_demo_dim();
        let wm = WireManifest::describe(
            d,
            ArrayDims::new(vec![5, 7]),
            WireRecipe::AosPacked,
            WireEndian::Little,
        )
        .unwrap();
        let line = wm.to_line().unwrap();
        // A tampered blob size disagrees with the rebuilt layout.
        assert!(WireManifest::parse_line(&line.replace("blobs=875", "blobs=874")).is_err());
        // A tampered extent changes the rebuilt sizes too.
        assert!(WireManifest::parse_line(&line.replace("dims=5x7", "dims=5x8")).is_err());
        for broken in [
            line.replace("endian=little", "endian=mixed"),
            line.replace("layout=aos:packed", "layout=aos:zerocopy"),
            line.replace("record={", "record={{"),
            line.replace("wire ", "spam "),
            line.replace(" blobs=875", ""),
            "wire".to_string(),
        ] {
            assert!(WireManifest::parse_line(&broken).is_err(), "accepted {broken:?}");
        }
    }
}
