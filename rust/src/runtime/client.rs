//! [`Runtime`]: a PJRT client plus artifact loading/compilation cache.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Context, Result};

use super::executable::Executable;
use super::manifest::Manifest;

/// PJRT CPU client wrapper. One compiled executable per artifact,
/// cached by name (the "one compiled executable per model variant"
/// rule of the architecture).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, usize>,
    executables: Vec<Executable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory (must contain
    /// `manifest.txt`; run `make artifacts` first).
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), executables: Vec::new() })
    }

    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if let Some(&idx) = self.cache.get(name) {
            return Ok(&self.executables[idx]);
        }
        let meta = self.manifest.find(name)?.clone();
        let path = self.manifest.path_of(&meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let idx = self.executables.len();
        self.executables.push(Executable::new(exe, meta));
        self.cache.insert(name.to_string(), idx);
        Ok(&self.executables[idx])
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests live in rust/tests/runtime_e2e.rs (they
    // need the artifacts directory built by `make artifacts`).
}
