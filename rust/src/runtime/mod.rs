//! PJRT runtime: load the AOT artifacts produced by `python/compile/`
//! and execute them from Rust — Python is never on this path.
//!
//! Flow (see /opt/xla-example/load_hlo): HLO *text* →
//! [`xla::HloModuleProto::from_text_file`] → [`xla::XlaComputation`] →
//! [`xla::PjRtClient::compile`] → execute with [`xla::Literal`] inputs
//! (or resident [`xla::PjRtBuffer`]s for step loops).

pub mod client;
pub mod executable;
pub mod manifest;

pub use client::Runtime;
pub use executable::Executable;
pub use manifest::{Artifact, Manifest};
