//! PJRT runtime: load the AOT artifacts produced by `python/compile/`
//! and execute them from Rust — Python is never on this path.
//!
//! Flow (see /opt/xla-example/load_hlo): HLO *text* →
//! `xla::HloModuleProto::from_text_file` → `xla::XlaComputation` →
//! `xla::PjRtClient::compile` → execute with `xla::Literal` inputs (or
//! resident `xla::PjRtBuffer`s for step loops).
//!
//! The PJRT bindings (`xla` crate) are an optional vendored dependency
//! behind the `xla` cargo feature. Without it, [`Runtime`]/
//! [`Executable`] are uninhabitable stubs whose constructors report the
//! missing feature, so every fig-6/e2e path degrades to a clean
//! "skipped" instead of a build break — manifest parsing and the whole
//! L3 layer stay fully functional.

pub mod manifest;

pub use manifest::{Artifact, Manifest, WireEndian, WireManifest};

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod executable;

#[cfg(feature = "xla")]
pub use client::Runtime;
#[cfg(feature = "xla")]
pub use executable::Executable;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Executable, Runtime};

/// True when the PJRT runtime was compiled in (`--features xla`).
pub fn available() -> bool {
    cfg!(feature = "xla")
}
