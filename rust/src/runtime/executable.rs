//! [`Executable`]: one compiled model variant with typed run helpers.

use crate::ensure;
use crate::error::{Context, Result};

use super::manifest::Artifact;

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    meta: Artifact,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, meta: Artifact) -> Self {
        Executable { exe, meta }
    }

    pub fn meta(&self) -> &Artifact {
        &self.meta
    }

    /// Shape of input `i` as the manifest's layout dictates: SoA
    /// artifacts take flat `(n,)` arrays, AoS artifacts one `(n, 7)`.
    fn input_dims(&self) -> Vec<i64> {
        if self.meta.layout == "aos" {
            vec![self.meta.n as i64, 7]
        } else {
            vec![self.meta.n as i64]
        }
    }

    /// Execute with f32 host slices (one per manifest input), returning
    /// f32 host vectors (one per output). The lowered module returns a
    /// tuple (`return_tuple=True` on the compile path).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            inputs.len() == self.meta.inputs,
            "{} expects {} inputs, got {}",
            self.meta.name,
            self.meta.inputs,
            inputs.len()
        );
        let dims = self.input_dims();
        let expect: usize = dims.iter().product::<i64>() as usize;
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            ensure!(
                data.len() == expect,
                "input {i} of {}: {} elements, expected {expect}",
                self.meta.name,
                data.len()
            );
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("untupling result")?;
        ensure!(
            parts.len() == self.meta.outputs,
            "{} returned {} outputs, manifest says {}",
            self.meta.name,
            parts.len(),
            self.meta.outputs
        );
        parts.into_iter().map(|l| l.to_vec::<f32>().map_err(Into::into)).collect()
    }

    /// Execute with device-resident buffers, returning the output
    /// buffers without copying to host — the fast path for step loops
    /// (state stays on device between calls).
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        ensure!(inputs.len() == self.meta.inputs, "wrong input count");
        let mut result = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(result.swap_remove(0))
    }

    /// Upload f32 host data as a device buffer with this artifact's
    /// input shape.
    pub fn upload(&self, client: &xla::PjRtClient, data: &[f32]) -> Result<xla::PjRtBuffer> {
        let dims_usize: Vec<usize> = self.input_dims().iter().map(|&d| d as usize).collect();
        client
            .buffer_from_host_buffer::<f32>(data, &dims_usize, None)
            .map_err(Into::into)
    }

    /// Download a device buffer to an f32 host vector.
    pub fn download(buffer: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buffer.to_literal_sync()?;
        lit.to_vec::<f32>().map_err(Into::into)
    }
}
