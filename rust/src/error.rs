//! Minimal error plumbing for the CLI/runtime paths (the vendored crate
//! set has no `anyhow`; hot paths never allocate errors — this is for
//! setup, I/O and artifact loading only).
//!
//! Provides the small surface those paths use: a string-y [`Error`]
//! that any `std::error::Error` converts into, a defaulted [`Result`],
//! the [`Context`] extension for `Result`/`Option`, and the
//! [`anyhow!`](crate::anyhow)/[`bail!`](crate::bail)/
//! [`ensure!`](crate::ensure) macros.

use std::fmt;

/// A chain of human-readable error messages (outermost context first).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string() }
    }

    /// Wrap with an outer context line.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: Error deliberately does NOT implement std::error::Error, so the
// blanket conversion below cannot collide with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors and empty options.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<usize> {
        let n: usize = v.parse()?; // std::num::ParseIntError -> Error
        ensure!(n > 0, "need a positive value, got {n}");
        Ok(n)
    }

    #[test]
    fn conversion_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert!(parse("0").unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let n: Option<usize> = None;
        let e = n.with_context(|| "missing thing").unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }
}
