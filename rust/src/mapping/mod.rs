//! **Mappings**: the core of LLAMA (paper §3.7, fig 3).
//!
//! A mapping translates an access to a terminal field at an array index
//! into `(blob number, byte offset)`. Mappings are constructed once from
//! a record dimension + array dimensions; all per-field strides are
//! precomputed so the hot-path translation is a couple of integer ops
//! that LLVM inlines and vectorizes through (the paper's zero-overhead
//! requirement).
//!
//! Provided mappings mirror the paper's list: [`AoS`] (aligned/packed),
//! [`SoA`] (single-/multi-blob), [`AoSoA`] (L lanes), [`One`],
//! [`Split`], [`Trace`], [`Heatmap`] — plus the extensions [`Byteswap`]
//! and [`Null`] (paper §5 future work).

pub mod advisor;
pub mod affine;
pub mod aos;
pub mod aosoa;
pub mod byteswap;
pub mod heatmap;
pub mod null;
pub mod one;
pub mod plan;
pub mod recipe;
pub mod soa;
pub mod split;
pub mod trace;

use std::sync::Arc;

use crate::array::ArrayDims;
use crate::record::RecordInfo;

pub use advisor::{
    estimated_bytes_per_record, migration_gain, recommend, recommend_stats, AccessPattern,
    CostModel, FieldStats, RecipeMapping, Recommendation, SplitHotColdMapping,
};
pub use affine::AffineLeaf;
pub use aos::AoS;
pub use aosoa::AoSoA;
pub use byteswap::Byteswap;
pub use heatmap::{Heatmap, HeatmapSnapshot};
pub use null::Null;
pub use one::One;
pub use plan::{AddrPlan, LayoutPlan, PiecewiseLeaf, PiecewisePlan};
pub use recipe::WireRecipe;
pub use soa::SoA;
pub use split::Split;
pub use trace::{Trace, TraceSnapshot};

/// The mapping concept (paper §3.7): `blobNrAndOffset<RecordCoord>(
/// ArrayDims) -> [blob, offset]`, plus blob count/size queries.
///
/// Terminology:
/// * **leaf** — flat index of a terminal field (see
///   [`RecordInfo::fields`]).
/// * **lin** — *canonical* row-major linear array index in
///   `0..dims().count()`.
/// * **slot** — the mapping's internal flat array position. For
///   row-major-linearized mappings `slot == lin`; space-filling-curve
///   mappings override [`Mapping::slot_of_lin`].
pub trait Mapping: Send + Sync {
    /// Flattened record-dimension info this mapping was built from.
    fn info(&self) -> &Arc<RecordInfo>;

    /// Array dimensions this mapping was built from.
    fn dims(&self) -> &ArrayDims;

    /// Number of blobs the view must supply (compile-time constant in
    /// C++ LLAMA).
    fn blob_count(&self) -> usize;

    /// Byte size of blob `nr`.
    fn blob_size(&self, nr: usize) -> usize;

    /// Number of internal array slots (≥ `dims().count()`; larger when
    /// the linearization pads, e.g. Morton).
    #[inline]
    fn slot_count(&self) -> usize {
        self.dims().count()
    }

    /// Canonical row-major linear index → internal slot. Identity for
    /// row-major mappings (the default).
    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        lin
    }

    /// N-dimensional index → internal slot.
    fn slot_of_nd(&self, idx: &[usize]) -> usize;

    /// The core translation: terminal field `leaf` at array `slot` →
    /// (blob nr, byte offset). Must be cheap; runs on every terminal
    /// access.
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize);

    /// Human-readable layout name for dumps and reports.
    fn mapping_name(&self) -> String;

    /// True if field values are stored as plain native-endian bytes
    /// (false for e.g. [`Byteswap`]); chunked copies require both sides
    /// to agree.
    fn is_native_representation(&self) -> bool {
        true
    }

    /// Compile this mapping into an executable [`LayoutPlan`] — the one
    /// method a new mapping implements to get every fast path (affine or
    /// piecewise cursors in the kernels, chunked copies). The default is
    /// the fully generic plan: correct for any mapping, with all
    /// accesses routed through [`Mapping::blob_nr_and_offset`].
    ///
    /// Contract: any `Some` returned by [`LayoutPlan::resolve`] must
    /// equal `blob_nr_and_offset(leaf, slot_of_lin(lin))` — i.e.
    /// closed-form addressing may only be claimed by row-major
    /// (slot == lin) layouts. Property-tested in
    /// `rust/tests/prop_mapping_invariants.rs`.
    ///
    /// ```
    /// use llama::prelude::*;
    ///
    /// let d = llama::record_dim! { x: f32, y: f32 };
    /// let plan = SoA::multi_blob(&d, ArrayDims::linear(8)).plan();
    /// // Multi-blob SoA compiles to one dense affine rule per leaf:
    /// // leaf 1 at record 3 lives in blob 1 at byte 3 * 4.
    /// assert!(matches!(plan.addr(), AddrPlan::Affine(_)));
    /// assert_eq!(plan.resolve(1, 3), Some((1, 12)));
    /// // ...and is chunk-copyable at whole-array runs.
    /// assert_eq!(plan.chunk_lanes(), Some(8));
    /// ```
    fn plan(&self) -> LayoutPlan {
        LayoutPlan::generic(self.dims().count(), self.is_native_representation(), None)
    }

    /// If this layout stores each record's fields in repeating groups of
    /// `L` contiguous scalars per field (AoSoA family), return `L`.
    /// AoS-packed is `Some(1)`, AoSoA-L is `Some(L)`, SoA is
    /// `Some(slot_count())`; `None` disables the chunked fast path.
    /// Derived from [`Mapping::plan`] — do not override.
    fn aosoa_lanes(&self) -> Option<usize> {
        self.plan().chunk_lanes()
    }

    /// Per-leaf rules when every leaf's byte address is affine in the
    /// canonical linear index — `blob[nr][base + lin * stride]`.
    /// Derived from [`Mapping::plan`] — do not override.
    fn affine_leaves(&self) -> Option<Vec<AffineLeaf>> {
        self.plan().affine_leaves()
    }
}

/// Blanket impl so `&M`, `Box<M>`, `Arc<M>` are mappings too.
macro_rules! forward_mapping {
    ($ptr:ty) => {
        impl<M: Mapping + ?Sized> Mapping for $ptr {
            fn info(&self) -> &Arc<RecordInfo> {
                (**self).info()
            }
            fn dims(&self) -> &ArrayDims {
                (**self).dims()
            }
            fn blob_count(&self) -> usize {
                (**self).blob_count()
            }
            fn blob_size(&self, nr: usize) -> usize {
                (**self).blob_size(nr)
            }
            fn slot_count(&self) -> usize {
                (**self).slot_count()
            }
            #[inline]
            fn slot_of_lin(&self, lin: usize) -> usize {
                (**self).slot_of_lin(lin)
            }
            #[inline]
            fn slot_of_nd(&self, idx: &[usize]) -> usize {
                (**self).slot_of_nd(idx)
            }
            #[inline]
            fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
                (**self).blob_nr_and_offset(leaf, slot)
            }
            fn mapping_name(&self) -> String {
                (**self).mapping_name()
            }
            fn plan(&self) -> LayoutPlan {
                (**self).plan()
            }
            fn aosoa_lanes(&self) -> Option<usize> {
                (**self).aosoa_lanes()
            }
            fn is_native_representation(&self) -> bool {
                (**self).is_native_representation()
            }
            fn affine_leaves(&self) -> Option<Vec<AffineLeaf>> {
                (**self).affine_leaves()
            }
        }
    };
}

forward_mapping!(&M);
forward_mapping!(Box<M>);
forward_mapping!(std::sync::Arc<M>);

/// Type-erased mapping for CLI/dump paths (not used on hot paths).
pub type DynMapping = Box<dyn Mapping>;

/// Total bytes across all blobs of a mapping.
pub fn total_blob_bytes<M: Mapping + ?Sized>(m: &M) -> usize {
    (0..m.blob_count()).map(|b| m.blob_size(b)).sum()
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::record::{RecordDim, Scalar, Type};

    /// The paper's listing-1 Particle: id u16, pos{x,y,z} f32, mass f64,
    /// flags bool[3] — 8 leaves, 27 packed bytes.
    pub fn particle_dim() -> RecordDim {
        let vec3 = RecordDim::new()
            .scalar("x", Scalar::F32)
            .scalar("y", Scalar::F32)
            .scalar("z", Scalar::F32);
        RecordDim::new()
            .scalar("id", Scalar::U16)
            .record("pos", vec3)
            .scalar("mass", Scalar::F64)
            .array("flags", Type::Scalar(Scalar::Bool), 3)
    }

    /// Exhaustively check that all (leaf, slot) byte ranges of a mapping
    /// are pairwise disjoint and inside their blobs — the fundamental
    /// mapping invariant.
    pub fn check_mapping_invariants<M: super::Mapping>(m: &M) {
        use std::collections::HashMap;
        let info = m.info().clone();
        let mut used: HashMap<usize, Vec<(usize, usize)>> = HashMap::new();
        for slot in 0..m.dims().count() {
            let slot = m.slot_of_lin(slot);
            for leaf in 0..info.leaf_count() {
                let size = info.fields[leaf].size();
                let (nr, off) = m.blob_nr_and_offset(leaf, slot);
                assert!(nr < m.blob_count(), "blob nr out of range");
                assert!(
                    off + size <= m.blob_size(nr),
                    "range [{off}, {}) exceeds blob {nr} size {} in {}",
                    off + size,
                    m.blob_size(nr),
                    m.mapping_name()
                );
                used.entry(nr).or_default().push((off, off + size));
            }
        }
        for (nr, mut ranges) in used {
            ranges.sort();
            for w in ranges.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "overlap in blob {nr} of {}: {:?} vs {:?}",
                    m.mapping_name(),
                    w[0],
                    w[1]
                );
            }
        }
    }
}
