//! The Trace mapping (paper §3.7, 72 LOCs in C++): counts accesses to
//! each record field at runtime, then forwards to an inner mapping. The
//! paper's §4.3 uses Trace counts to derive a hot/cold Split for the lbm
//! benchmark; we reproduce that workflow in `workloads::lbm`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::RecordInfo;

/// An epoch-consistent copy of a [`Trace`]'s per-field access counts.
///
/// Produced by [`Trace::snapshot`] / [`Trace::into_inner`], which take
/// the wrapper by exclusive reference (or by value): the borrow checker
/// then guarantees no concurrent writer exists, so the snapshot can
/// never observe a torn mid-epoch mixture of old and new counts — the
/// race that per-counter relaxed loads through a shared reference
/// ([`Trace::report`]) cannot rule out.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSnapshot {
    counts: Vec<u64>,
}

impl TraceSnapshot {
    /// Access count of leaf `leaf` during the snapshotted epoch.
    #[inline]
    pub fn count(&self, leaf: usize) -> u64 {
        self.counts[leaf]
    }

    /// All per-leaf counts, declaration order.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total accesses recorded during the epoch.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// All (field path, count) pairs, declaration order — the
    /// race-free replacement for the old live report: take a
    /// [`Trace::snapshot`] at the epoch boundary, then render.
    pub fn report(&self, info: &RecordInfo) -> Vec<(String, u64)> {
        info.fields.iter().zip(&self.counts).map(|(f, &c)| (f.path.clone(), c)).collect()
    }

    /// Render the counts as an aligned text table (the paper prints
    /// this "to help a user understand the access behavior of their
    /// program").
    pub fn to_table(&self, info: &RecordInfo) -> String {
        let rep = self.report(info);
        let w = rep.iter().map(|(p, _)| p.len()).max().unwrap_or(5).max(5);
        let mut out = format!("{:w$}  {:>12}\n", "field", "count");
        for (p, c) in rep {
            out.push_str(&format!("{p:w$}  {c:>12}\n"));
        }
        out
    }

    /// Group the leaves into `groups` buckets of roughly equal total
    /// access count (greedy, preserving declaration order) — the
    /// paper's §4.3 "split the record dimension into 4 groups of AoS
    /// layouts with equal access count", computed from epoch-consistent
    /// counts.
    pub fn equal_count_groups(&self, groups: usize) -> Vec<Vec<usize>> {
        equal_count_groups_of(&self.counts, groups)
    }
}

/// The greedy equal-count grouping shared by [`TraceSnapshot`] and the
/// (quiescent-only) live [`Trace::equal_count_groups`].
fn equal_count_groups_of(counts: &[u64], groups: usize) -> Vec<Vec<usize>> {
    assert!(groups > 0);
    let total: u64 = counts.iter().sum();
    let per_group = total / groups as u64;
    let mut out: Vec<Vec<usize>> = vec![Vec::new()];
    let mut acc = 0u64;
    for (leaf, &c) in counts.iter().enumerate() {
        let ngroups = out.len();
        let cur = out.last_mut().unwrap();
        if !cur.is_empty() && acc + c / 2 > per_group && ngroups < groups {
            out.push(vec![leaf]);
            acc = c;
        } else {
            cur.push(leaf);
            acc += c;
        }
    }
    out
}

/// Per-field access counting wrapper. Counting uses relaxed atomics so
/// the wrapper stays `Sync` and usable from parallel loops; the overhead
/// is intentional (instrumentation), as in the paper.
#[derive(Debug)]
pub struct Trace<M: Mapping> {
    inner: M,
    counts: Vec<AtomicU64>,
}

impl<M: Mapping> Trace<M> {
    /// Wrap `inner`, counting accesses to each of its leaves.
    pub fn new(inner: M) -> Self {
        let n = inner.info().leaf_count();
        Trace { inner, counts: (0..n).map(|_| AtomicU64::new(0)).collect() }
    }

    /// The wrapped mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// End the current counting epoch: swap the counter vector for a
    /// fresh zeroed one and return the old counts as an immutable
    /// [`TraceSnapshot`].
    ///
    /// The `&mut self` receiver is what makes this epoch-consistent:
    /// exclusive access proves no concurrent writer exists, so every
    /// count belongs to exactly one epoch — unlike [`Trace::report`],
    /// whose relaxed per-counter loads through `&self` can interleave
    /// with writers and hand the advisor a torn mixture. The reset is
    /// cheap (one small allocation, one pointer swap): the epoch
    /// boundary the adaptive engine sits on
    /// ([`crate::view::adapt::AdaptiveView`]).
    pub fn snapshot(&mut self) -> TraceSnapshot {
        let n = self.counts.len();
        let old = std::mem::replace(&mut self.counts, (0..n).map(|_| AtomicU64::new(0)).collect());
        TraceSnapshot { counts: old.into_iter().map(|c| c.into_inner()).collect() }
    }

    /// Consume the wrapper, returning the inner mapping and the final
    /// epoch's counts (epoch-consistent for the same reason as
    /// [`Trace::snapshot`]: ownership excludes concurrent writers).
    pub fn into_inner(self) -> (M, TraceSnapshot) {
        let counts = self.counts.into_iter().map(|c| c.into_inner()).collect();
        (self.inner, TraceSnapshot { counts })
    }

    /// Access count of leaf `leaf` so far.
    pub fn count(&self, leaf: usize) -> u64 {
        self.counts[leaf].load(Ordering::Relaxed)
    }

    /// Live (field path, count) pairs through `&self`.
    ///
    /// **Test helper only.** Each counter is loaded individually with
    /// relaxed ordering, so a report taken while writers run can mix
    /// counts from different moments. Every decision or display path
    /// must go through the epoch boundary instead:
    /// [`Trace::snapshot`], then [`TraceSnapshot::report`].
    #[doc(hidden)]
    pub fn report(&self) -> Vec<(String, u64)> {
        self.inner
            .info()
            .fields
            .iter()
            .zip(&self.counts)
            .map(|(f, c)| (f.path.clone(), c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Live text table through `&self` — **test helper only** (see
    /// [`Trace::report`]); the supported rendering path is
    /// [`TraceSnapshot::to_table`].
    #[doc(hidden)]
    pub fn to_table(&self) -> String {
        let rep = self.report();
        let w = rep.iter().map(|(p, _)| p.len()).max().unwrap_or(5).max(5);
        let mut out = format!("{:w$}  {:>12}\n", "field", "count");
        for (p, c) in rep {
            out.push_str(&format!("{p:w$}  {c:>12}\n"));
        }
        out
    }

    /// Group the leaves into `groups` buckets of roughly equal total
    /// access count (greedy, preserving declaration order) — the paper's
    /// §4.3 "split the record dimension into 4 groups of AoS layouts
    /// with equal access count".
    ///
    /// The counters are read live (relaxed loads), so call this only
    /// when the workload is quiescent — between phases, as the §4.3
    /// workflow does. For the concurrent path, snapshot first and use
    /// [`TraceSnapshot::equal_count_groups`].
    pub fn equal_count_groups(&self, groups: usize) -> Vec<Vec<usize>> {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        equal_count_groups_of(&counts, groups)
    }

    /// Zero every counter in place through `&self`.
    ///
    /// **Test helper only.** Concurrent writers may interleave with
    /// the stores, splitting one logical epoch across two counting
    /// windows. The race-free epoch boundary is [`Trace::snapshot`]
    /// (counter-vector swap under exclusive access) — the only reset
    /// the serving engine's sampling path uses.
    #[doc(hidden)]
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl<M: Mapping> Mapping for Trace<M> {
    fn info(&self) -> &Arc<RecordInfo> {
        self.inner.info()
    }

    fn dims(&self) -> &ArrayDims {
        self.inner.dims()
    }

    fn blob_count(&self) -> usize {
        self.inner.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        self.inner.blob_size(nr)
    }

    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.inner.slot_of_lin(lin)
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.inner.slot_of_nd(idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        self.counts[leaf].fetch_add(1, Ordering::Relaxed);
        self.inner.blob_nr_and_offset(leaf, slot)
    }

    fn mapping_name(&self) -> String {
        format!("Trace({})", self.inner.mapping_name())
    }

    fn is_native_representation(&self) -> bool {
        self.inner.is_native_representation()
    }

    fn plan(&self) -> super::LayoutPlan {
        // Never expose the inner addressing: closed-form resolution
        // would bypass the access counters. Chunked copies keep working
        // (byte moves are not field accesses, as in the C++ original).
        let inner = self.inner.plan();
        super::LayoutPlan::generic(inner.count(), inner.native(), inner.chunk_lanes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::AoS;

    #[test]
    fn counts_accesses_per_field() {
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            let _ = t.blob_nr_and_offset(1, slot); // pos.x
        }
        let _ = t.blob_nr_and_offset(4, 0); // mass
        assert_eq!(t.count(1), 4);
        assert_eq!(t.count(4), 1);
        assert_eq!(t.count(0), 0);
        let rep = t.report();
        assert_eq!(rep[1], ("pos.x".to_string(), 4));
        let table = t.to_table();
        assert!(table.contains("pos.x"));
        t.reset();
        assert_eq!(t.count(1), 0);
    }

    #[test]
    fn forwards_layout_unchanged() {
        let inner = AoS::aligned(&particle_dim(), ArrayDims::linear(4));
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            for leaf in 0..8 {
                assert_eq!(
                    t.blob_nr_and_offset(leaf, slot),
                    inner.blob_nr_and_offset(leaf, slot)
                );
            }
        }
        check_mapping_invariants(&t);
    }

    #[test]
    fn snapshot_swaps_counters_and_resets_epoch() {
        let mut t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for _ in 0..5 {
            let _ = t.blob_nr_and_offset(2, 1);
        }
        let _ = t.blob_nr_and_offset(0, 0);
        let snap = t.snapshot();
        assert_eq!(snap.count(2), 5);
        assert_eq!(snap.count(0), 1);
        assert_eq!(snap.total(), 6);
        // The epoch boundary left every live counter at zero...
        assert!((0..8).all(|l| t.count(l) == 0));
        // ...and a fresh snapshot sees only post-boundary accesses.
        let _ = t.blob_nr_and_offset(7, 3);
        let snap2 = t.snapshot();
        assert_eq!(snap2.counts(), &[0, 0, 0, 0, 0, 0, 0, 1]);
        let (inner, last) = t.into_inner();
        assert!(inner.mapping_name().starts_with("AoS(aligned"));
        assert_eq!(last.total(), 0);
    }

    /// The snapshot-side report/table/grouping (the concurrent-safe
    /// path) agree with the hidden live helpers on a quiescent trace.
    #[test]
    fn snapshot_report_and_table_match_live_helpers() {
        let mut t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for _ in 0..4 {
            let _ = t.blob_nr_and_offset(1, 0);
        }
        let _ = t.blob_nr_and_offset(4, 0);
        let live_report = t.report();
        let live_groups = t.equal_count_groups(2);
        let info = t.inner().info().clone();
        let snap = t.snapshot();
        assert_eq!(snap.report(&info), live_report);
        assert_eq!(snap.equal_count_groups(2), live_groups);
        let table = snap.to_table(&info);
        assert!(table.contains("pos.x"));
        assert!(table.contains("field"));
    }

    #[test]
    fn equal_count_grouping() {
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        // Simulate: leaf 0 hot (100), others cool (10 each).
        for _ in 0..100 {
            let _ = t.blob_nr_and_offset(0, 0);
        }
        for leaf in 1..8 {
            for _ in 0..10 {
                let _ = t.blob_nr_and_offset(leaf, 0);
            }
        }
        let groups = t.equal_count_groups(2);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![0]); // the hot field alone
        assert_eq!(groups.concat(), (0..8).collect::<Vec<_>>());
    }
}
