//! The Trace mapping (paper §3.7, 72 LOCs in C++): counts accesses to
//! each record field at runtime, then forwards to an inner mapping. The
//! paper's §4.3 uses Trace counts to derive a hot/cold Split for the lbm
//! benchmark; we reproduce that workflow in `workloads::lbm`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::RecordInfo;

/// Per-field access counting wrapper. Counting uses relaxed atomics so
/// the wrapper stays `Sync` and usable from parallel loops; the overhead
/// is intentional (instrumentation), as in the paper.
#[derive(Debug)]
pub struct Trace<M: Mapping> {
    inner: M,
    counts: Vec<AtomicU64>,
}

impl<M: Mapping> Trace<M> {
    pub fn new(inner: M) -> Self {
        let n = inner.info().leaf_count();
        Trace { inner, counts: (0..n).map(|_| AtomicU64::new(0)).collect() }
    }

    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Access count of leaf `leaf` so far.
    pub fn count(&self, leaf: usize) -> u64 {
        self.counts[leaf].load(Ordering::Relaxed)
    }

    /// All (field path, count) pairs, declaration order.
    pub fn report(&self) -> Vec<(String, u64)> {
        self.inner
            .info()
            .fields
            .iter()
            .zip(&self.counts)
            .map(|(f, c)| (f.path.clone(), c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Render the report as an aligned text table (the paper prints this
    /// "to help a user understand the access behavior of their program").
    pub fn to_table(&self) -> String {
        let rep = self.report();
        let w = rep.iter().map(|(p, _)| p.len()).max().unwrap_or(5).max(5);
        let mut out = format!("{:w$}  {:>12}\n", "field", "count");
        for (p, c) in rep {
            out.push_str(&format!("{p:w$}  {c:>12}\n"));
        }
        out
    }

    /// Group the leaves into `groups` buckets of roughly equal total
    /// access count (greedy, preserving declaration order) — the paper's
    /// §4.3 "split the record dimension into 4 groups of AoS layouts
    /// with equal access count".
    pub fn equal_count_groups(&self, groups: usize) -> Vec<Vec<usize>> {
        assert!(groups > 0);
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        let per_group = total / groups as u64;
        let mut out: Vec<Vec<usize>> = vec![Vec::new()];
        let mut acc = 0u64;
        for (leaf, &c) in counts.iter().enumerate() {
            let ngroups = out.len();
            let cur = out.last_mut().unwrap();
            if !cur.is_empty() && acc + c / 2 > per_group && ngroups < groups {
                out.push(vec![leaf]);
                acc = c;
            } else {
                cur.push(leaf);
                acc += c;
            }
        }
        out
    }

    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl<M: Mapping> Mapping for Trace<M> {
    fn info(&self) -> &Arc<RecordInfo> {
        self.inner.info()
    }

    fn dims(&self) -> &ArrayDims {
        self.inner.dims()
    }

    fn blob_count(&self) -> usize {
        self.inner.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        self.inner.blob_size(nr)
    }

    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.inner.slot_of_lin(lin)
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.inner.slot_of_nd(idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        self.counts[leaf].fetch_add(1, Ordering::Relaxed);
        self.inner.blob_nr_and_offset(leaf, slot)
    }

    fn mapping_name(&self) -> String {
        format!("Trace({})", self.inner.mapping_name())
    }

    fn is_native_representation(&self) -> bool {
        self.inner.is_native_representation()
    }

    fn plan(&self) -> super::LayoutPlan {
        // Never expose the inner addressing: closed-form resolution
        // would bypass the access counters. Chunked copies keep working
        // (byte moves are not field accesses, as in the C++ original).
        let inner = self.inner.plan();
        super::LayoutPlan::generic(inner.count(), inner.native(), inner.chunk_lanes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::AoS;

    #[test]
    fn counts_accesses_per_field() {
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            let _ = t.blob_nr_and_offset(1, slot); // pos.x
        }
        let _ = t.blob_nr_and_offset(4, 0); // mass
        assert_eq!(t.count(1), 4);
        assert_eq!(t.count(4), 1);
        assert_eq!(t.count(0), 0);
        let rep = t.report();
        assert_eq!(rep[1], ("pos.x".to_string(), 4));
        let table = t.to_table();
        assert!(table.contains("pos.x"));
        t.reset();
        assert_eq!(t.count(1), 0);
    }

    #[test]
    fn forwards_layout_unchanged() {
        let inner = AoS::aligned(&particle_dim(), ArrayDims::linear(4));
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            for leaf in 0..8 {
                assert_eq!(
                    t.blob_nr_and_offset(leaf, slot),
                    inner.blob_nr_and_offset(leaf, slot)
                );
            }
        }
        check_mapping_invariants(&t);
    }

    #[test]
    fn equal_count_grouping() {
        let t = Trace::new(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        // Simulate: leaf 0 hot (100), others cool (10 each).
        for _ in 0..100 {
            let _ = t.blob_nr_and_offset(0, 0);
        }
        for leaf in 1..8 {
            for _ in 0..10 {
                let _ = t.blob_nr_and_offset(leaf, 0);
            }
        }
        let groups = t.equal_count_groups(2);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![0]); // the hot field alone
        assert_eq!(groups.concat(), (0..8).collect::<Vec<_>>());
    }
}
