//! Struct-of-arrays mapping (paper §3.7, 77 LOCs in C++).
//!
//! For each leaf field, stores all array slots of that field
//! contiguously. Either one blob per field (**multi-blob**, `SoA MB` in
//! the paper's figures) or one blob for the whole layout (single-blob).

use std::sync::Arc;

use super::{AffineLeaf, Mapping};
use crate::array::{ArrayDims, Linearizer, RowMajor};
use crate::record::{RecordDim, RecordInfo};

/// SoA mapping, generic over the array-index linearization.
#[derive(Debug, Clone)]
pub struct SoA<L: Linearizer = RowMajor> {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    lin: L,
    lin_state: L::State,
    slots: usize,
    multiblob: bool,
    /// Per-leaf scalar size (cached off `info` for locality).
    sizes: Vec<usize>,
    /// Single-blob: byte offset where each field's subarray starts.
    bases: Vec<usize>,
}

impl SoA<RowMajor> {
    /// Multi-blob SoA: one blob per field (the paper's `SoA MB`).
    pub fn multi_blob(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_linearizer(dim, dims, RowMajor, true)
    }

    /// Single-blob SoA: all subarrays in one blob, back to back.
    pub fn single_blob(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_linearizer(dim, dims, RowMajor, false)
    }
}

impl<L: Linearizer> SoA<L> {
    /// SoA with an explicit array-index linearization.
    pub fn with_linearizer(dim: &RecordDim, dims: ArrayDims, lin: L, multiblob: bool) -> Self {
        let info = Arc::new(RecordInfo::new(dim));
        let lin_state = lin.prepare(&dims);
        let slots = lin.slot_count(&dims);
        let sizes: Vec<usize> = info.fields.iter().map(|f| f.size()).collect();
        let mut bases = Vec::with_capacity(sizes.len());
        let mut acc = 0usize;
        for s in &sizes {
            bases.push(acc);
            acc += s * slots;
        }
        SoA { info, dims, lin, lin_state, slots, multiblob, sizes, bases }
    }

    /// True in multi-blob mode (one blob per field).
    pub fn is_multiblob(&self) -> bool {
        self.multiblob
    }

    /// Byte offset of field `leaf`'s subarray within the single blob
    /// (single-blob mode), or 0 (multi-blob mode).
    pub fn field_base(&self, leaf: usize) -> usize {
        if self.multiblob {
            0
        } else {
            self.bases[leaf]
        }
    }
}

impl<L: Linearizer> Mapping for SoA<L> {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        if self.multiblob {
            self.sizes.len()
        } else {
            1
        }
    }

    fn blob_size(&self, nr: usize) -> usize {
        if self.multiblob {
            self.sizes[nr] * self.slots
        } else {
            debug_assert_eq!(nr, 0);
            self.info.packed_size * self.slots
        }
    }

    #[inline]
    fn slot_count(&self) -> usize {
        self.slots
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        if std::any::TypeId::of::<L>() == std::any::TypeId::of::<RowMajor>() {
            lin
        } else {
            let idx = self.dims.delinearize_row_major(lin);
            L::linearize(&self.lin_state, &idx)
        }
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        L::linearize(&self.lin_state, idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        if self.multiblob {
            (leaf, slot * self.sizes[leaf])
        } else {
            (0, self.bases[leaf] + slot * self.sizes[leaf])
        }
    }

    fn mapping_name(&self) -> String {
        format!(
            "SoA({}, {})",
            if self.multiblob { "multi-blob" } else { "single-blob" },
            self.lin.name()
        )
    }

    fn plan(&self) -> super::LayoutPlan {
        // SoA is AoSoA with L = slot count (paper §4.2) — but both the
        // closed-form addressing and the chunked copy walk *canonical*
        // index runs, so only the row-major linearization (slot == lin)
        // compiles to more than the generic plan.
        if std::any::TypeId::of::<L>() != std::any::TypeId::of::<RowMajor>() {
            return super::LayoutPlan::generic(self.dims.count(), true, None);
        }
        super::LayoutPlan::affine(
            self.dims.count(),
            true,
            Some(self.slots),
            self.sizes
                .iter()
                .enumerate()
                .map(|(leaf, &size)| {
                    if self.multiblob {
                        AffineLeaf { blob: leaf, base: 0, stride: size }
                    } else {
                        AffineLeaf { blob: 0, base: self.bases[leaf], stride: size }
                    }
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::MortonCurve;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};

    #[test]
    fn multiblob_one_blob_per_leaf() {
        let m = SoA::multi_blob(&particle_dim(), ArrayDims::linear(10));
        assert_eq!(m.blob_count(), 8);
        assert_eq!(m.blob_size(0), 2 * 10); // id: u16
        assert_eq!(m.blob_size(4), 8 * 10); // mass: f64
        assert_eq!(m.blob_nr_and_offset(4, 3), (4, 24));
    }

    #[test]
    fn singleblob_subarray_bases() {
        let m = SoA::single_blob(&particle_dim(), ArrayDims::linear(10));
        assert_eq!(m.blob_count(), 1);
        assert_eq!(m.blob_size(0), 25 * 10);
        // id base 0, pos.x base 20, pos.y base 60, pos.z base 100,
        // mass base 140, flags bases 220/230/240.
        assert_eq!(m.blob_nr_and_offset(0, 0), (0, 0));
        assert_eq!(m.blob_nr_and_offset(1, 0), (0, 20));
        assert_eq!(m.blob_nr_and_offset(4, 2), (0, 140 + 16));
        assert_eq!(m.blob_nr_and_offset(7, 9), (0, 240 + 9));
    }

    #[test]
    fn invariants_both_modes() {
        for mb in [true, false] {
            let m = SoA::with_linearizer(&particle_dim(), ArrayDims::from([4, 3]), RowMajor, mb);
            check_mapping_invariants(&m);
        }
    }

    #[test]
    fn invariants_morton() {
        let m =
            SoA::with_linearizer(&particle_dim(), ArrayDims::from([3, 3]), MortonCurve, true);
        check_mapping_invariants(&m);
        assert_eq!(m.slot_count(), 16);
    }

    #[test]
    fn soa_lanes_equal_slots() {
        let m = SoA::multi_blob(&particle_dim(), ArrayDims::linear(10));
        assert_eq!(m.aosoa_lanes(), Some(10));
    }
}
