//! The Heatmap mapping (paper §3.7, 60 LOCs in C++): counts accesses to
//! individual bytes (at configurable granularity) and forwards to an
//! inner mapping. The result can be rendered (`dump::heatmap_render`)
//! like the paper's fig 4d.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::RecordInfo;

/// Byte-granularity access-count wrapper.
#[derive(Debug)]
pub struct Heatmap<M: Mapping> {
    inner: M,
    /// Counter granularity in bytes (1 = per byte, 64 = per cache line).
    granularity: usize,
    /// Per blob: one counter per `granularity` bytes.
    counters: Vec<Vec<AtomicU64>>,
}

/// An epoch-consistent copy of a [`Heatmap`]'s per-granule counts,
/// taken through exclusive access ([`Heatmap::snapshot`] /
/// [`Heatmap::into_inner`]) so no concurrent writer can tear it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HeatmapSnapshot {
    granularity: usize,
    counters: Vec<Vec<u64>>,
}

impl HeatmapSnapshot {
    /// Counter granularity in bytes.
    pub fn granularity(&self) -> usize {
        self.granularity
    }

    /// Per-granule counts of blob `nr`.
    pub fn blob_counts(&self, nr: usize) -> &[u64] {
        &self.counters[nr]
    }

    /// Total accesses recorded during the epoch.
    pub fn total(&self) -> u64 {
        self.counters.iter().flatten().sum()
    }

    /// Bytes covered by granules touched at least once — the measured
    /// working set of the epoch (feeds the advisor's cost model as
    /// [`super::advisor::CostModel::measured_current`]).
    pub fn touched_bytes(&self) -> u64 {
        self.counters.iter().flatten().filter(|&&c| c > 0).count() as u64
            * self.granularity as u64
    }

    /// [`HeatmapSnapshot::touched_bytes`] averaged per record visit:
    /// the measured bytes-per-record the cost model compares layouts
    /// with. `records` is the epoch's record-visit count (usually
    /// `dims().count()` × sweeps).
    pub fn bytes_per_record(&self, records: usize) -> f64 {
        if records == 0 {
            return 0.0;
        }
        self.touched_bytes() as f64 / records as f64
    }
}

impl<M: Mapping> Heatmap<M> {
    /// Wrap `inner` with one counter per byte.
    pub fn new(inner: M) -> Self {
        Self::with_granularity(inner, 1)
    }

    /// Wrap `inner` with one counter per `granularity` bytes (64 =
    /// cache-line granularity).
    pub fn with_granularity(inner: M, granularity: usize) -> Self {
        assert!(granularity > 0);
        let counters = (0..inner.blob_count())
            .map(|b| {
                let n = inner.blob_size(b).div_ceil(granularity);
                (0..n).map(|_| AtomicU64::new(0)).collect()
            })
            .collect();
        Heatmap { inner, granularity, counters }
    }

    /// The wrapped mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Counter granularity in bytes.
    pub fn granularity(&self) -> usize {
        self.granularity
    }

    /// End the current counting epoch: swap the counter banks for
    /// fresh zeroed ones and return the old counts. As with
    /// [`super::Trace::snapshot`], the `&mut self` receiver is the
    /// consistency argument — exclusive access excludes concurrent
    /// writers, so the snapshot can never mix epochs the way the
    /// relaxed per-counter loads of [`Heatmap::blob_counts`] can.
    pub fn snapshot(&mut self) -> HeatmapSnapshot {
        let fresh: Vec<Vec<AtomicU64>> = self
            .counters
            .iter()
            .map(|b| (0..b.len()).map(|_| AtomicU64::new(0)).collect())
            .collect();
        let old = std::mem::replace(&mut self.counters, fresh);
        HeatmapSnapshot {
            granularity: self.granularity,
            counters: old
                .into_iter()
                .map(|b| b.into_iter().map(|c| c.into_inner()).collect())
                .collect(),
        }
    }

    /// Consume the wrapper, returning the inner mapping and the final
    /// epoch's counts.
    pub fn into_inner(self) -> (M, HeatmapSnapshot) {
        (
            self.inner,
            HeatmapSnapshot {
                granularity: self.granularity,
                counters: self
                    .counters
                    .into_iter()
                    .map(|b| b.into_iter().map(|c| c.into_inner()).collect())
                    .collect(),
            },
        )
    }

    /// Access counts of blob `nr`, one entry per granule.
    pub fn blob_counts(&self, nr: usize) -> Vec<u64> {
        self.counters[nr].iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Total accesses recorded across all blobs.
    pub fn total(&self) -> u64 {
        self.counters
            .iter()
            .flat_map(|b| b.iter())
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    /// Zero every counter in place through a shared reference.
    ///
    /// **Test helper only.** The stores may interleave with concurrent
    /// writers, splitting one logical epoch across two counting
    /// windows; every engine path uses the race-free
    /// [`Heatmap::snapshot`] swap instead.
    #[doc(hidden)]
    pub fn reset(&self) {
        for b in &self.counters {
            for c in b {
                c.store(0, Ordering::Relaxed);
            }
        }
    }
}

impl<M: Mapping> Mapping for Heatmap<M> {
    fn info(&self) -> &Arc<RecordInfo> {
        self.inner.info()
    }

    fn dims(&self) -> &ArrayDims {
        self.inner.dims()
    }

    fn blob_count(&self) -> usize {
        self.inner.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        self.inner.blob_size(nr)
    }

    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.inner.slot_of_lin(lin)
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.inner.slot_of_nd(idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        let (nr, off) = self.inner.blob_nr_and_offset(leaf, slot);
        let size = self.inner.info().fields[leaf].size();
        let first = off / self.granularity;
        let last = (off + size - 1) / self.granularity;
        for g in first..=last {
            self.counters[nr][g].fetch_add(1, Ordering::Relaxed);
        }
        (nr, off)
    }

    fn mapping_name(&self) -> String {
        format!("Heatmap({}, g={})", self.inner.mapping_name(), self.granularity)
    }

    fn is_native_representation(&self) -> bool {
        self.inner.is_native_representation()
    }

    fn plan(&self) -> super::LayoutPlan {
        // As with Trace: closed-form addressing would bypass the byte
        // counters, so the plan stays generic.
        let inner = self.inner.plan();
        super::LayoutPlan::generic(inner.count(), inner.native(), inner.chunk_lanes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::{AoS, SoA};

    #[test]
    fn per_byte_counting() {
        let h = Heatmap::new(AoS::packed(&particle_dim(), ArrayDims::linear(2)));
        let _ = h.blob_nr_and_offset(1, 0); // pos.x: bytes 2..6
        let counts = h.blob_counts(0);
        assert_eq!(&counts[0..8], &[0, 0, 1, 1, 1, 1, 0, 0]);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn cacheline_granularity() {
        let h = Heatmap::with_granularity(
            SoA::multi_blob(&particle_dim(), ArrayDims::linear(100)),
            64,
        );
        // mass (leaf 4, f64) at slot 9 -> blob 4 bytes 72..80 -> granule 1.
        let _ = h.blob_nr_and_offset(4, 9);
        let counts = h.blob_counts(4);
        assert_eq!(counts[1], 1);
        assert_eq!(counts[0], 0);
    }

    #[test]
    fn straddling_access_touches_both_granules() {
        let h = Heatmap::with_granularity(
            AoS::packed(&particle_dim(), ArrayDims::linear(2)),
            4,
        );
        // pos.x occupies bytes 2..6 packed -> granules 0 and 1.
        let _ = h.blob_nr_and_offset(1, 0);
        let counts = h.blob_counts(0);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
    }

    #[test]
    fn snapshot_swaps_banks_and_measures_touched_bytes() {
        let mut h = Heatmap::with_granularity(
            AoS::packed(&particle_dim(), ArrayDims::linear(2)),
            4,
        );
        let _ = h.blob_nr_and_offset(1, 0); // pos.x: bytes 2..6 -> granules 0, 1
        let snap = h.snapshot();
        assert_eq!(snap.granularity(), 4);
        assert_eq!(snap.total(), 2);
        assert_eq!(snap.touched_bytes(), 8);
        assert_eq!(snap.bytes_per_record(2), 4.0);
        // The epoch boundary zeroed the live counters.
        assert_eq!(h.total(), 0);
        let (inner, last) = h.into_inner();
        assert!(inner.mapping_name().starts_with("AoS(packed"));
        assert_eq!(last.total(), 0);
    }

    #[test]
    fn forwards_layout_and_invariants() {
        let h = Heatmap::new(AoS::aligned(&particle_dim(), ArrayDims::from([2, 3])));
        check_mapping_invariants(&h);
        h.reset();
        assert_eq!(h.total(), 0); // reset clears; invariant check counted
    }
}
