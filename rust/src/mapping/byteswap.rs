//! Byteswap mapping (an extension in the spirit of the paper's §5
//! "further enrich LLAMA's mapping capabilities"; upstream LLAMA later
//! grew `mapping::Byteswap`). Stores every field with reversed byte
//! order — useful for interoperating with big-endian file formats while
//! keeping the program written against the abstract data space.
//!
//! The swap itself happens in the accessor layer (`view`), keyed off
//! [`Mapping::is_native_representation`]; this mapping only flags the
//! representation and forwards the address computation.

use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::RecordInfo;

/// Opposite-endian representation wrapper over any mapping.
#[derive(Debug, Clone)]
pub struct Byteswap<M: Mapping> {
    inner: M,
}

impl<M: Mapping> Byteswap<M> {
    /// Wrap `inner`, flagging its stored bytes as opposite-endian.
    pub fn new(inner: M) -> Self {
        Byteswap { inner }
    }

    /// The wrapped mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }
}

impl<M: Mapping> Mapping for Byteswap<M> {
    fn info(&self) -> &Arc<RecordInfo> {
        self.inner.info()
    }

    fn dims(&self) -> &ArrayDims {
        self.inner.dims()
    }

    fn blob_count(&self) -> usize {
        self.inner.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        self.inner.blob_size(nr)
    }

    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.inner.slot_of_lin(lin)
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.inner.slot_of_nd(idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        self.inner.blob_nr_and_offset(leaf, slot)
    }

    fn mapping_name(&self) -> String {
        format!("Byteswap({})", self.inner.mapping_name())
    }

    fn is_native_representation(&self) -> bool {
        false
    }

    fn plan(&self) -> super::LayoutPlan {
        // Forward the inner plan's addressing and chunkability with the
        // native flag cleared: the copy engine moves swapped bytes
        // verbatim between equal-representation pairs, compiles
        // native ↔ swapped affine pairs into per-leaf swap runs
        // (`copy::CopyOp::SwapRun`), and cursors key off `!native` to
        // refuse raw-byte extraction (the accessor layer swaps).
        self.inner.plan().with_native(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::AoS;

    #[test]
    fn address_computation_is_forwarded() {
        let inner = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let bs = Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            for leaf in 0..8 {
                assert_eq!(
                    bs.blob_nr_and_offset(leaf, slot),
                    inner.blob_nr_and_offset(leaf, slot)
                );
            }
        }
        check_mapping_invariants(&bs);
    }

    #[test]
    fn non_native_flag() {
        let bs = Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        assert!(!bs.is_native_representation());
        assert!(!bs.plan().native());
    }

    #[test]
    fn plan_forwards_inner_addressing() {
        use crate::mapping::{AddrPlan, SoA};
        // The wrapper's plan is the inner plan with `native` cleared:
        // addressing and chunk lanes carry through untouched.
        let inner = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let bs = Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        assert_eq!(bs.plan(), inner.plan().with_native(false));
        assert!(matches!(bs.plan().addr(), AddrPlan::Affine(_)));
        assert_eq!(bs.aosoa_lanes(), inner.aosoa_lanes());
        let soa = Byteswap::new(SoA::multi_blob(&particle_dim(), ArrayDims::linear(4)));
        assert_eq!(soa.plan().chunk_lanes(), Some(4));
        assert!(!soa.plan().native());
    }
}
