//! Byteswap mapping (an extension in the spirit of the paper's §5
//! "further enrich LLAMA's mapping capabilities"; upstream LLAMA later
//! grew `mapping::Byteswap`). Stores every field with reversed byte
//! order — useful for interoperating with big-endian file formats while
//! keeping the program written against the abstract data space.
//!
//! The swap itself happens in the accessor layer (`view`), keyed off
//! [`Mapping::is_native_representation`]; this mapping only flags the
//! representation and forwards the address computation.

use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::RecordInfo;

/// Opposite-endian representation wrapper over any mapping.
#[derive(Debug, Clone)]
pub struct Byteswap<M: Mapping> {
    inner: M,
}

impl<M: Mapping> Byteswap<M> {
    /// Wrap `inner`, flagging its stored bytes as opposite-endian.
    pub fn new(inner: M) -> Self {
        Byteswap { inner }
    }

    /// The wrapped mapping.
    pub fn inner(&self) -> &M {
        &self.inner
    }
}

impl<M: Mapping> Mapping for Byteswap<M> {
    fn info(&self) -> &Arc<RecordInfo> {
        self.inner.info()
    }

    fn dims(&self) -> &ArrayDims {
        self.inner.dims()
    }

    fn blob_count(&self) -> usize {
        self.inner.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        self.inner.blob_size(nr)
    }

    fn slot_count(&self) -> usize {
        self.inner.slot_count()
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        self.inner.slot_of_lin(lin)
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        self.inner.slot_of_nd(idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        self.inner.blob_nr_and_offset(leaf, slot)
    }

    fn mapping_name(&self) -> String {
        format!("Byteswap({})", self.inner.mapping_name())
    }

    fn is_native_representation(&self) -> bool {
        false
    }

    fn plan(&self) -> super::LayoutPlan {
        // Chunked copies would move swapped bytes verbatim (only legal
        // between two byteswapped views) and cursors would bypass the
        // swap in the accessor layer: non-native, no chunking, generic.
        super::LayoutPlan::generic(self.inner.dims().count(), false, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::AoS;

    #[test]
    fn address_computation_is_forwarded() {
        let inner = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let bs = Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            for leaf in 0..8 {
                assert_eq!(
                    bs.blob_nr_and_offset(leaf, slot),
                    inner.blob_nr_and_offset(leaf, slot)
                );
            }
        }
        check_mapping_invariants(&bs);
    }

    #[test]
    fn non_native_flag() {
        let bs = Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        assert!(!bs.is_native_representation());
        assert_eq!(bs.aosoa_lanes(), None);
    }
}
