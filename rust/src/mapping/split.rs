//! The Split mapping (paper §3.7, 139 LOCs in C++): selects part of the
//! record dimension by record coordinate(s) and maps the selected part
//! with one mapping and the rest with another. Nesting Splits composes
//! arbitrary per-field layouts (paper fig 4c); the paper's §4.3 uses a
//! Trace-derived Split to separate hot from cold lbm fields.

use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::{RecordCoord, RecordDim, RecordInfo, Type};

/// Split mapping over two sub-mappings.
///
/// The child record dimensions are the *flattened* selected/remaining
/// leaves (layout semantics only depend on leaf order and types, which
/// flattening preserves).
#[derive(Debug, Clone)]
pub struct Split<MA: Mapping, MB: Mapping> {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    selectors: Vec<RecordCoord>,
    a: MA,
    b: MB,
    /// Full-record leaf index -> (in_a, child leaf index).
    route: Vec<(bool, usize)>,
    a_blobs: usize,
    /// Canonical row-major strides for slot_of_nd.
    strides: Vec<usize>,
    /// Both children store native-endian bytes.
    native: bool,
}

/// Build a flat record dim from a subset of leaves of `info`.
fn sub_record(info: &RecordInfo, leaves: &[usize]) -> RecordDim {
    let mut dim = RecordDim::new();
    for &l in leaves {
        let f = &info.fields[l];
        dim = dim.field(f.path.clone(), Type::Scalar(f.scalar));
    }
    dim
}

impl<MA: Mapping, MB: Mapping> Split<MA, MB> {
    /// Split `dim` at `selector`: leaves under `selector` go to the
    /// mapping built by `make_a`, the rest to `make_b`.
    pub fn new(
        dim: &RecordDim,
        dims: ArrayDims,
        selector: RecordCoord,
        make_a: impl FnOnce(&RecordDim, ArrayDims) -> MA,
        make_b: impl FnOnce(&RecordDim, ArrayDims) -> MB,
    ) -> Self {
        Self::by_selectors(dim, dims, vec![selector], make_a, make_b)
    }

    /// Split with multiple selector coordinates (a leaf is selected if
    /// any selector is a prefix of its coordinate).
    pub fn by_selectors(
        dim: &RecordDim,
        dims: ArrayDims,
        selectors: Vec<RecordCoord>,
        make_a: impl FnOnce(&RecordDim, ArrayDims) -> MA,
        make_b: impl FnOnce(&RecordDim, ArrayDims) -> MB,
    ) -> Self {
        let info = Arc::new(RecordInfo::new(dim));
        let selected: Vec<usize> = (0..info.leaf_count())
            .filter(|&l| selectors.iter().any(|s| s.is_prefix_of(&info.fields[l].coord)))
            .collect();
        let rest: Vec<usize> =
            (0..info.leaf_count()).filter(|l| !selected.contains(l)).collect();
        assert!(
            !selected.is_empty(),
            "Split selector selects no leaves: {selectors:?}"
        );
        assert!(!rest.is_empty(), "Split selector selects every leaf");

        let dim_a = sub_record(&info, &selected);
        let dim_b = sub_record(&info, &rest);
        let a = make_a(&dim_a, dims.clone());
        let b = make_b(&dim_b, dims.clone());
        assert_eq!(a.info().leaf_count(), selected.len());
        assert_eq!(b.info().leaf_count(), rest.len());

        let mut route = vec![(false, 0usize); info.leaf_count()];
        for (child_idx, &l) in selected.iter().enumerate() {
            route[l] = (true, child_idx);
        }
        for (child_idx, &l) in rest.iter().enumerate() {
            route[l] = (false, child_idx);
        }
        let a_blobs = a.blob_count();
        let strides = dims.row_major_strides();
        let native = a.is_native_representation() && b.is_native_representation();
        Split { info, dims, selectors, a, b, route, a_blobs, strides, native }
    }

    /// The mapping of the selected leaves.
    pub fn part_a(&self) -> &MA {
        &self.a
    }

    /// The mapping of the remaining leaves.
    pub fn part_b(&self) -> &MB {
        &self.b
    }

    /// Whether full-record leaf `leaf` is routed to part A.
    pub fn routes_to_a(&self, leaf: usize) -> bool {
        self.route[leaf].0
    }
}

impl<MA: Mapping, MB: Mapping> Mapping for Split<MA, MB> {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        self.a_blobs + self.b.blob_count()
    }

    fn blob_size(&self, nr: usize) -> usize {
        if nr < self.a_blobs {
            self.a.blob_size(nr)
        } else {
            self.b.blob_size(nr - self.a_blobs)
        }
    }

    // Split's slot is the canonical row-major lin; each child converts
    // with its own linearizer inside blob_nr_and_offset.
    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        lin
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        idx.iter().zip(&self.strides).map(|(i, s)| i * s).sum()
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        let (in_a, child_leaf) = self.route[leaf];
        if in_a {
            self.a.blob_nr_and_offset(child_leaf, self.a.slot_of_lin(slot))
        } else {
            let (nr, off) = self.b.blob_nr_and_offset(child_leaf, self.b.slot_of_lin(slot));
            (nr + self.a_blobs, off)
        }
    }

    fn is_native_representation(&self) -> bool {
        // A Split is native only if both children are; a mixed Split
        // (e.g. a Byteswap child) must neither memcpy nor chunk-copy.
        self.native
    }

    fn plan(&self) -> super::LayoutPlan {
        // Compose the children's plans; the B side's blob numbers shift
        // by the A side's blob count, exactly like blob_nr_and_offset.
        super::LayoutPlan::compose_split(
            &self.a.plan(),
            &self.b.plan(),
            &self.route,
            self.a_blobs,
            self.is_native_representation(),
        )
    }

    fn mapping_name(&self) -> String {
        format!(
            "Split({:?} -> {}, rest -> {})",
            self.selectors.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            self.a.mapping_name(),
            self.b.mapping_name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::mapping::{AoS, One, SoA};

    #[test]
    fn split_pos_to_soa_rest_aos() {
        // Paper fig 4c without the inner second split: pos -> SoA MB,
        // rest -> aligned AoS.
        let m = Split::new(
            &particle_dim(),
            ArrayDims::linear(8),
            RecordCoord::new(vec![1]),
            |d, ad| SoA::multi_blob(d, ad),
            |d, ad| AoS::aligned(d, ad),
        );
        // pos has 3 leaves -> 3 SoA blobs + 1 AoS blob.
        assert_eq!(m.blob_count(), 4);
        check_mapping_invariants(&m);
        // pos.x routes to blob 0.
        assert_eq!(m.blob_nr_and_offset(1, 0).0, 0);
        assert_eq!(m.blob_nr_and_offset(1, 2), (0, 8));
        // id routes to the AoS blob (index 3).
        assert_eq!(m.blob_nr_and_offset(0, 0).0, 3);
    }

    #[test]
    fn nested_split_like_fig4c() {
        // pos -> SoA MB; then of the remainder, mass -> One, rest -> AoS.
        let m = Split::new(
            &particle_dim(),
            ArrayDims::linear(8),
            RecordCoord::new(vec![1]),
            |d, ad| SoA::multi_blob(d, ad),
            |d, ad| {
                // In the remainder (id, mass, flags.*), mass is field 1.
                Split::new(
                    d,
                    ad,
                    RecordCoord::new(vec![1]),
                    |d2, ad2| One::new(d2, ad2),
                    |d2, ad2| AoS::aligned(d2, ad2),
                )
            },
        );
        assert_eq!(m.blob_count(), 3 + 1 + 1);
        // Every index's mass aliases the same One storage: offsets equal.
        assert_eq!(m.blob_nr_and_offset(4, 0), m.blob_nr_and_offset(4, 7));
        let name = m.mapping_name();
        assert!(name.contains("One"), "{name}");
        assert!(name.contains("SoA"), "{name}");
    }

    #[test]
    fn multi_selector_split() {
        // Select id and mass together (hot/cold style, paper §4.3).
        let m = Split::by_selectors(
            &particle_dim(),
            ArrayDims::linear(4),
            vec![RecordCoord::new(vec![0]), RecordCoord::new(vec![2])],
            |d, ad| SoA::single_blob(d, ad),
            |d, ad| AoS::packed(d, ad),
        );
        check_mapping_invariants(&m);
        assert!(m.routes_to_a(0)); // id
        assert!(!m.routes_to_a(1)); // pos.x
        assert!(!m.routes_to_a(3)); // pos.z
        assert!(m.routes_to_a(4)); // mass
        // Total bytes conserved: (2+8)*4 + (4*3+3)*4.
        let total: usize = (0..m.blob_count()).map(|b| m.blob_size(b)).sum();
        assert_eq!(total, 10 * 4 + 15 * 4);
    }

    #[test]
    #[should_panic(expected = "selects no leaves")]
    fn empty_selection_panics() {
        let _ = Split::new(
            &particle_dim(),
            ArrayDims::linear(4),
            RecordCoord::new(vec![9]),
            |d, ad| SoA::multi_blob(d, ad),
            |d, ad| AoS::aligned(d, ad),
        );
    }
}
