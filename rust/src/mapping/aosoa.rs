//! Array-of-structs-of-arrays mapping (paper §3.7, 61 LOCs in C++).
//!
//! Records are grouped into blocks of `L` (the *lane count*); within a
//! block each field repeats `L` times contiguously. AoSoA is the sweet
//! spot between AoS locality and SoA vectorizability (paper §2.1).

use std::sync::Arc;

use super::{AffineLeaf, Mapping};
use crate::array::{ArrayDims, Linearizer, RowMajor};
use crate::record::{RecordDim, RecordInfo};

/// AoSoA mapping with a runtime lane count (compile-time `L` in C++;
/// here captured once at construction — still loop-invariant).
#[derive(Debug, Clone)]
pub struct AoSoA<L: Linearizer = RowMajor> {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    lin: L,
    lin_state: L::State,
    slots: usize,
    lanes: usize,
    /// Number of lane-blocks: ceil(slots / lanes).
    blocks: usize,
    /// Bytes per block: lanes * packed record size.
    block_size: usize,
    /// Per-leaf: packed offset * lanes (start of the field's lane group
    /// within a block).
    field_block_off: Vec<usize>,
    sizes: Vec<usize>,
}

impl AoSoA<RowMajor> {
    /// AoSoA with `lanes` records per block, row-major.
    pub fn new(dim: &RecordDim, dims: ArrayDims, lanes: usize) -> Self {
        Self::with_linearizer(dim, dims, RowMajor, lanes)
    }
}

impl<L: Linearizer> AoSoA<L> {
    /// AoSoA with an explicit array-index linearization.
    pub fn with_linearizer(dim: &RecordDim, dims: ArrayDims, lin: L, lanes: usize) -> Self {
        assert!(lanes > 0, "AoSoA lane count must be positive");
        let info = Arc::new(RecordInfo::new(dim));
        let lin_state = lin.prepare(&dims);
        let slots = lin.slot_count(&dims);
        let blocks = slots.div_ceil(lanes);
        let block_size = lanes * info.packed_size;
        let field_block_off = info.fields.iter().map(|f| f.offset_packed * lanes).collect();
        let sizes = info.fields.iter().map(|f| f.size()).collect();
        AoSoA {
            info,
            dims,
            lin,
            lin_state,
            slots,
            lanes,
            blocks,
            block_size,
            field_block_off,
            sizes,
        }
    }

    /// Records per block (the `L` in AoSoA-L).
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of lane-blocks covering all slots (incl. a partial tail).
    #[inline]
    pub fn blocks(&self) -> usize {
        self.blocks
    }
}

impl<L: Linearizer> Mapping for AoSoA<L> {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        1
    }

    fn blob_size(&self, nr: usize) -> usize {
        debug_assert_eq!(nr, 0);
        self.blocks * self.block_size
    }

    #[inline]
    fn slot_count(&self) -> usize {
        self.slots
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        if std::any::TypeId::of::<L>() == std::any::TypeId::of::<RowMajor>() {
            lin
        } else {
            let idx = self.dims.delinearize_row_major(lin);
            L::linearize(&self.lin_state, &idx)
        }
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        L::linearize(&self.lin_state, idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        // The i -> (i / L, i % L) split the paper discusses in §4.1.
        let block = slot / self.lanes;
        let lane = slot % self.lanes;
        (
            0,
            block * self.block_size + self.field_block_off[leaf] + lane * self.sizes[leaf],
        )
    }

    fn mapping_name(&self) -> String {
        format!("AoSoA{}({})", self.lanes, self.lin.name())
    }

    fn plan(&self) -> super::LayoutPlan {
        // Chunked copies walk canonical index runs: valid when
        // slot == lin (row-major) or when runs degenerate to single
        // elements (lanes == 1, safe under any slot permutation).
        let row_major = std::any::TypeId::of::<L>() == std::any::TypeId::of::<RowMajor>();
        if !row_major {
            let chunk = if self.lanes == 1 { Some(1) } else { None };
            return super::LayoutPlan::generic(self.dims.count(), true, chunk);
        }
        if self.lanes == 1 {
            // Degenerate 1-lane case == packed AoS: affine.
            return super::LayoutPlan::affine(
                self.dims.count(),
                true,
                Some(1),
                self.info
                    .fields
                    .iter()
                    .map(|f| AffineLeaf {
                        blob: 0,
                        base: f.offset_packed,
                        stride: self.info.packed_size,
                    })
                    .collect(),
            );
        }
        super::LayoutPlan::piecewise(
            self.dims.count(),
            true,
            self.lanes,
            self.field_block_off
                .iter()
                .zip(&self.sizes)
                .map(|(&off, &size)| super::PiecewiseLeaf {
                    blob: 0,
                    block_stride: self.block_size,
                    lane_offset: off,
                    lane_stride: size,
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};
    use crate::record::{RecordDim, Scalar};

    fn xy() -> RecordDim {
        RecordDim::new().scalar("x", Scalar::F32).scalar("y", Scalar::F32)
    }

    #[test]
    fn layout_structure_two_fields() {
        // {x,y} f32, lanes=4: block = x x x x y y y y (32 bytes).
        let m = AoSoA::new(&xy(), ArrayDims::linear(8), 4);
        assert_eq!(m.blocks(), 2);
        assert_eq!(m.blob_size(0), 64);
        assert_eq!(m.blob_nr_and_offset(0, 0), (0, 0));
        assert_eq!(m.blob_nr_and_offset(0, 3), (0, 12));
        assert_eq!(m.blob_nr_and_offset(1, 0), (0, 16));
        assert_eq!(m.blob_nr_and_offset(1, 3), (0, 28));
        // Second block starts at 32.
        assert_eq!(m.blob_nr_and_offset(0, 4), (0, 32));
        assert_eq!(m.blob_nr_and_offset(1, 7), (0, 60));
    }

    #[test]
    fn partial_tail_block_is_padded() {
        let m = AoSoA::new(&xy(), ArrayDims::linear(5), 4);
        assert_eq!(m.blocks(), 2);
        assert_eq!(m.blob_size(0), 2 * 4 * 8);
        check_mapping_invariants(&m);
    }

    #[test]
    fn invariants_heterogeneous_record() {
        for lanes in [1, 2, 4, 16, 64] {
            let m = AoSoA::new(&particle_dim(), ArrayDims::from([5, 3]), lanes);
            check_mapping_invariants(&m);
        }
    }

    #[test]
    fn lanes_exposed_for_copy() {
        let m = AoSoA::new(&xy(), ArrayDims::linear(8), 4);
        assert_eq!(m.aosoa_lanes(), Some(4));
    }

    #[test]
    fn aosoa1_matches_packed_aos_offsets() {
        use crate::mapping::{AoS, Mapping};
        let a1 = AoSoA::new(&particle_dim(), ArrayDims::linear(6), 1);
        let aos = AoS::packed(&particle_dim(), ArrayDims::linear(6));
        for slot in 0..6 {
            for leaf in 0..a1.info().leaf_count() {
                assert_eq!(
                    a1.blob_nr_and_offset(leaf, slot),
                    aos.blob_nr_and_offset(leaf, slot)
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_lanes_panics() {
        let _ = AoSoA::new(&xy(), ArrayDims::linear(8), 0);
    }
}
