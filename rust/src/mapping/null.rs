//! Null mapping (extension, paper §5 future work; upstream LLAMA later
//! grew `mapping::Null`): maps every field of every record to the same
//! scratch bytes, so writes are discarded and reads return whatever was
//! last written anywhere. Useful to "delete" cold fields from a layout
//! (as the B side of a [`super::Split`]) when benchmarking what a field
//! costs.

use std::sync::Arc;

use super::Mapping;
use crate::array::ArrayDims;
use crate::record::{RecordDim, RecordInfo};

/// The Null mapping: all fields of all records share one scratch slot.
#[derive(Debug, Clone)]
pub struct Null {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    /// One record's worth of scratch bytes, shared by all slots/fields.
    scratch: usize,
}

impl Null {
    /// Null storage for `(dim, dims)` (one scratch slot).
    pub fn new(dim: &RecordDim, dims: ArrayDims) -> Self {
        let info = Arc::new(RecordInfo::new(dim));
        let scratch = info.fields.iter().map(|f| f.size()).max().unwrap_or(1);
        Null { info, dims, scratch }
    }
}

impl Mapping for Null {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        1
    }

    fn blob_size(&self, nr: usize) -> usize {
        debug_assert_eq!(nr, 0);
        self.scratch
    }

    #[inline]
    fn slot_of_nd(&self, _idx: &[usize]) -> usize {
        0
    }

    #[inline]
    fn slot_of_lin(&self, _lin: usize) -> usize {
        0
    }

    #[inline]
    fn blob_nr_and_offset(&self, _leaf: usize, _slot: usize) -> (usize, usize) {
        (0, 0)
    }

    fn mapping_name(&self) -> String {
        "Null".to_string()
    }

    fn is_native_representation(&self) -> bool {
        // Not a faithful store (all fields alias, reads are garbage by
        // design): exclude from byte-exact copy paths. The derived
        // default plan is generic with no chunk lanes, so Null never
        // takes part in chunked copies either.
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::particle_dim;
    use crate::array::ArrayDims;

    #[test]
    fn single_scratch_slot() {
        let m = Null::new(&particle_dim(), ArrayDims::linear(1000));
        assert_eq!(m.blob_count(), 1);
        assert_eq!(m.blob_size(0), 8); // largest leaf: f64 mass
        assert_eq!(m.blob_nr_and_offset(0, 0), (0, 0));
        assert_eq!(m.blob_nr_and_offset(7, 999), (0, 0));
    }
}
