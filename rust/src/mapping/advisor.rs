//! Automatic mapping choice (paper §5: "building facilities for ...
//! automatic optimum mapping choice are well within the reach of
//! LLAMA's existing capabilities").
//!
//! The advisor consumes what LLAMA already produces — per-field access
//! counts from a [`super::Trace`] run of the user's real program — plus
//! a coarse hardware/access-pattern hint, and recommends a layout:
//!
//! * fields are ranked by access density (accesses × size);
//! * a utilization model scores AoS (locality: good when most of the
//!   record is touched together), SoA (streaming: good when few fields
//!   are touched over many records) and a hot/cold Split;
//! * the winner is returned as a ready-to-use mapping recipe.
//!
//! This is intentionally a *first-order* model (cache-line utilization,
//! the same arithmetic the paper uses in §4.1 to explain the move
//! phase: AoS wastes `1 - touched/record` of each line); it is
//! validated against the measured fig-5/fig-8 orderings in the tests.

use super::{Mapping, Trace};
use crate::record::RecordInfo;

/// How the program walks the array dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// Linear sweeps over all records (streaming, bandwidth-bound).
    Streaming,
    /// Random/irregular positions, most of the record used per visit.
    RandomFullRecord,
}

/// The advisor's verdict.
#[derive(Debug, Clone, PartialEq)]
pub enum Recommendation {
    Aos,
    SoaMultiBlob,
    /// Hot leaves (by flat index) split off into SoA, rest AoS.
    SplitHotCold { hot: Vec<usize> },
}

/// Per-field access statistics, extracted from a [`Trace`].
#[derive(Debug, Clone)]
pub struct FieldStats {
    /// (leaf, accesses, size in bytes), declaration order.
    pub fields: Vec<(usize, u64, usize)>,
}

impl FieldStats {
    pub fn from_trace<M: Mapping>(trace: &Trace<M>) -> Self {
        let info = trace.info().clone();
        FieldStats {
            fields: (0..info.leaf_count())
                .map(|l| (l, trace.count(l), info.fields[l].size()))
                .collect(),
        }
    }

    fn total_accessed_bytes(&self) -> f64 {
        self.fields.iter().map(|&(_, c, s)| c as f64 * s as f64).sum()
    }

    /// Fraction of the record's bytes that belong to fields touched at
    /// least once per record visit (the paper's §4.1 bandwidth-use
    /// argument).
    fn touched_fraction(&self, info: &RecordInfo) -> f64 {
        let max_count = self.fields.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        if max_count == 0 {
            return 1.0;
        }
        // A field counts as "hot" if it sees at least half the maximum
        // access rate.
        let hot_bytes: usize = self
            .fields
            .iter()
            .filter(|&&(_, c, _)| c * 2 >= max_count)
            .map(|&(_, _, s)| s)
            .sum();
        hot_bytes as f64 / info.packed_size as f64
    }

    /// Leaves carrying at least half the maximum access rate.
    pub fn hot_leaves(&self) -> Vec<usize> {
        let max_count = self.fields.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        self.fields
            .iter()
            .filter(|&&(_, c, _)| c * 2 >= max_count)
            .map(|&(l, _, _)| l)
            .collect()
    }
}

/// Recommend a layout from traced statistics and an access-pattern
/// hint.
pub fn recommend<M: Mapping>(trace: &Trace<M>, pattern: AccessPattern) -> Recommendation {
    let stats = FieldStats::from_trace(trace);
    let info = trace.info().clone();
    if stats.total_accessed_bytes() == 0.0 {
        // No data: default to the general-purpose streaming layout.
        return Recommendation::SoaMultiBlob;
    }
    let touched = stats.touched_fraction(&info);
    match pattern {
        AccessPattern::RandomFullRecord => {
            // Irregular positions + (almost) whole record: locality of
            // reference wins (paper §2.1: "If the access is at
            // irregular array positions and to almost all of the inner
            // structure, AoS layouts provide better locality").
            if touched > 0.6 {
                Recommendation::Aos
            } else {
                // Random but narrow: split the hot fields off.
                Recommendation::SplitHotCold { hot: stats.hot_leaves() }
            }
        }
        AccessPattern::Streaming => {
            if touched >= 0.99 {
                // Everything is hot: SoA streams every byte usefully
                // and vectorizes; AoS only matches it when lines are
                // fully used *and* the loop is compute-bound.
                Recommendation::SoaMultiBlob
            } else if touched >= 0.5 {
                Recommendation::SoaMultiBlob
            } else {
                Recommendation::SplitHotCold { hot: stats.hot_leaves() }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoS, Trace};
    use crate::view::alloc_view;
    use crate::workloads::nbody::{self, llama_impl};

    /// The n-body move phase (streams 6 of 7 fields) must be advised
    /// towards SoA — the layout fig 5 measures as fastest for it.
    #[test]
    fn move_phase_recommends_soa() {
        let d = nbody::particle_dim();
        let n = 64;
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(n)));
        let mut v = alloc_view(t);
        let s = nbody::init_particles(n, 1);
        llama_impl::load_state(&mut v, &s);
        v.mapping().reset();
        llama_impl::mv(&mut v);
        let rec = recommend(v.mapping(), AccessPattern::Streaming);
        assert_eq!(rec, Recommendation::SoaMultiBlob);
    }

    /// A workload touching only one field of a wide record must be
    /// advised towards a hot/cold split containing that field.
    #[test]
    fn narrow_access_recommends_split() {
        let d = crate::workloads::hep::event_dim();
        let t = Trace::new(AoS::aligned(&d, ArrayDims::linear(32)));
        let v = alloc_view(t);
        // Touch only field 2 (energy of object 0), heavily.
        for lin in 0..32 {
            for _ in 0..50 {
                let _ = v.get::<f32>(lin, 2);
            }
        }
        match recommend(v.mapping(), AccessPattern::Streaming) {
            Recommendation::SplitHotCold { hot } => {
                assert!(hot.contains(&2));
                assert!(hot.len() < 10, "split must be selective, got {hot:?}");
            }
            other => panic!("expected split, got {other:?}"),
        }
    }

    /// Random full-record access (the paper's §2.1 AoS case).
    #[test]
    fn random_full_record_recommends_aos() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(16)));
        let v = alloc_view(t);
        for lin in [3usize, 9, 1, 14, 7] {
            for leaf in 0..7 {
                let _ = v.get::<f32>(lin, leaf);
            }
        }
        assert_eq!(
            recommend(v.mapping(), AccessPattern::RandomFullRecord),
            Recommendation::Aos
        );
    }

    #[test]
    fn no_data_defaults_to_soa() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(4)));
        let v = alloc_view(t);
        assert_eq!(
            recommend(v.mapping(), AccessPattern::Streaming),
            Recommendation::SoaMultiBlob
        );
    }

    #[test]
    fn stats_extraction() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(4)));
        let v = alloc_view(t);
        let _ = v.get::<f32>(0, 0);
        let _ = v.get::<f32>(0, 0);
        let stats = FieldStats::from_trace(v.mapping());
        assert_eq!(stats.fields[0], (0, 2, 4));
        assert_eq!(stats.hot_leaves(), vec![0]);
    }
}
