//! Automatic mapping choice (paper §5: "building facilities for ...
//! automatic optimum mapping choice are well within the reach of
//! LLAMA's existing capabilities").
//!
//! The advisor consumes what LLAMA already produces — per-field access
//! counts from a [`super::Trace`] run of the user's real program — plus
//! a coarse hardware/access-pattern hint, and recommends a layout:
//!
//! * fields are ranked by access density (accesses × size);
//! * a utilization model scores AoS (locality: good when most of the
//!   record is touched together), SoA (streaming: good when few fields
//!   are touched over many records) and a hot/cold Split;
//! * the winner is returned as a ready-to-use mapping recipe.
//!
//! This is intentionally a *first-order* model (cache-line utilization,
//! the same arithmetic the paper uses in §4.1 to explain the move
//! phase: AoS wastes `1 - touched/record` of each line); it is
//! validated against the measured fig-5/fig-8 orderings in the tests.

use std::sync::Arc;

use super::trace::TraceSnapshot;
use super::{AoS, Mapping, SoA, Split, Trace};
use crate::array::ArrayDims;
use crate::record::{RecordCoord, RecordDim, RecordInfo, Type};

/// How the program walks the array dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// Linear sweeps over all records (streaming, bandwidth-bound).
    Streaming,
    /// Random/irregular positions, most of the record used per visit.
    RandomFullRecord,
}

/// The advisor's verdict.
#[derive(Debug, Clone, PartialEq)]
pub enum Recommendation {
    /// Aligned array-of-structs: locality for irregular full-record
    /// access.
    Aos,
    /// Multi-blob struct-of-arrays: every streamed byte is useful.
    SoaMultiBlob,
    /// Hot leaves (by flat index) split off into SoA, rest AoS.
    SplitHotCold {
        /// Flat leaf indices of the hot group, declaration order.
        hot: Vec<usize>,
    },
}

/// The hot/cold Split shape the advisor materializes: hot leaves in a
/// multi-blob SoA, the cold rest in one aligned AoS blob.
pub type SplitHotColdMapping = Split<SoA, AoS>;

impl Recommendation {
    /// Materialize the recommendation as a concrete, ready-to-allocate
    /// mapping over `(dim, dims)` — the step that turns the advisor's
    /// verdict into something a view (and the adaptive engine's
    /// migration) can run on.
    ///
    /// Degenerate hot sets fall back gracefully: an empty set or one
    /// covering every leaf yields the SoA recipe (a Split needs both
    /// sides populated).
    pub fn to_mapping(&self, dim: &RecordDim, dims: ArrayDims) -> RecipeMapping {
        match self {
            Recommendation::Aos => RecipeMapping::Aos(AoS::aligned(dim, dims)),
            Recommendation::SoaMultiBlob => RecipeMapping::Soa(SoA::multi_blob(dim, dims)),
            Recommendation::SplitHotCold { hot } => {
                let info = RecordInfo::new(dim);
                if hot.is_empty() || hot.len() >= info.leaf_count() {
                    return RecipeMapping::Soa(SoA::multi_blob(dim, dims));
                }
                let selectors: Vec<RecordCoord> =
                    hot.iter().map(|&l| info.fields[l].coord.clone()).collect();
                RecipeMapping::Split(Split::by_selectors(
                    dim,
                    dims,
                    selectors,
                    |sd, ad| SoA::multi_blob(sd, ad),
                    |sd, ad| AoS::aligned(sd, ad),
                ))
            }
        }
    }
}

/// A concrete mapping materialized from a [`Recommendation`] (or
/// wrapping an arbitrary starting layout), with one runtime type for
/// every layout the adaptive engine can hold — the closed set lets
/// [`crate::view::adapt::AdaptiveView`] change layout at runtime while
/// kernels stay statically dispatched per variant.
#[derive(Clone)]
pub enum RecipeMapping {
    /// Aligned AoS ([`Recommendation::Aos`]).
    Aos(AoS),
    /// Multi-blob SoA ([`Recommendation::SoaMultiBlob`]).
    Soa(SoA),
    /// Hot/cold split ([`Recommendation::SplitHotCold`]).
    Split(SplitHotColdMapping),
    /// Any other layout (type-erased) — the adaptive engine's wrapper
    /// for arbitrary starting mappings.
    Other(Arc<dyn Mapping>),
}

impl std::fmt::Debug for RecipeMapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RecipeMapping({})", self.mapping_name())
    }
}

macro_rules! recipe_delegate {
    ($self:ident, $m:ident => $body:expr) => {
        match $self {
            RecipeMapping::Aos($m) => $body,
            RecipeMapping::Soa($m) => $body,
            RecipeMapping::Split($m) => $body,
            RecipeMapping::Other($m) => $body,
        }
    };
}

impl Mapping for RecipeMapping {
    fn info(&self) -> &Arc<RecordInfo> {
        recipe_delegate!(self, m => m.info())
    }

    fn dims(&self) -> &ArrayDims {
        recipe_delegate!(self, m => m.dims())
    }

    fn blob_count(&self) -> usize {
        recipe_delegate!(self, m => m.blob_count())
    }

    fn blob_size(&self, nr: usize) -> usize {
        recipe_delegate!(self, m => m.blob_size(nr))
    }

    fn slot_count(&self) -> usize {
        recipe_delegate!(self, m => m.slot_count())
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        recipe_delegate!(self, m => m.slot_of_lin(lin))
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        recipe_delegate!(self, m => m.slot_of_nd(idx))
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        recipe_delegate!(self, m => m.blob_nr_and_offset(leaf, slot))
    }

    fn mapping_name(&self) -> String {
        recipe_delegate!(self, m => m.mapping_name())
    }

    fn is_native_representation(&self) -> bool {
        recipe_delegate!(self, m => m.is_native_representation())
    }

    fn plan(&self) -> super::LayoutPlan {
        recipe_delegate!(self, m => m.plan())
    }
}

/// Per-field access statistics, extracted from a [`Trace`].
#[derive(Debug, Clone)]
pub struct FieldStats {
    /// (leaf, accesses, size in bytes), declaration order.
    pub fields: Vec<(usize, u64, usize)>,
}

impl FieldStats {
    /// Extract statistics from a live [`Trace`] (relaxed per-counter
    /// loads — for epoch-consistent stats under concurrent writers,
    /// take a [`Trace::snapshot`] and use
    /// [`FieldStats::from_snapshot`]).
    pub fn from_trace<M: Mapping>(trace: &Trace<M>) -> Self {
        let info = trace.info().clone();
        FieldStats {
            fields: (0..info.leaf_count())
                .map(|l| (l, trace.count(l), info.fields[l].size()))
                .collect(),
        }
    }

    /// Extract statistics from an epoch-consistent [`TraceSnapshot`].
    pub fn from_snapshot(snapshot: &TraceSnapshot, info: &RecordInfo) -> Self {
        FieldStats {
            fields: (0..info.leaf_count())
                .map(|l| (l, snapshot.count(l), info.fields[l].size()))
                .collect(),
        }
    }

    fn total_accessed_bytes(&self) -> f64 {
        self.fields.iter().map(|&(_, c, s)| c as f64 * s as f64).sum()
    }

    /// Fraction of the record's bytes that belong to fields touched at
    /// least once per record visit (the paper's §4.1 bandwidth-use
    /// argument).
    fn touched_fraction(&self, info: &RecordInfo) -> f64 {
        let max_count = self.fields.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        if max_count == 0 {
            return 1.0;
        }
        // A field counts as "hot" if it sees at least half the maximum
        // access rate.
        let hot_bytes: usize = self
            .fields
            .iter()
            .filter(|&&(_, c, _)| c * 2 >= max_count)
            .map(|&(_, _, s)| s)
            .sum();
        hot_bytes as f64 / info.packed_size as f64
    }

    /// Leaves carrying at least half the maximum access rate.
    pub fn hot_leaves(&self) -> Vec<usize> {
        let max_count = self.fields.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        self.fields
            .iter()
            .filter(|&&(_, c, _)| c * 2 >= max_count)
            .map(|&(l, _, _)| l)
            .collect()
    }
}

/// Recommend a layout from traced statistics and an access-pattern
/// hint.
pub fn recommend<M: Mapping>(trace: &Trace<M>, pattern: AccessPattern) -> Recommendation {
    recommend_stats(&FieldStats::from_trace(trace), trace.info(), pattern)
}

/// [`recommend`] over pre-extracted statistics — the entry point for
/// epoch-consistent snapshots ([`FieldStats::from_snapshot`]) and the
/// adaptive engine, which decides at epoch boundaries rather than from
/// a live trace.
pub fn recommend_stats(
    stats: &FieldStats,
    info: &RecordInfo,
    pattern: AccessPattern,
) -> Recommendation {
    if stats.total_accessed_bytes() == 0.0 {
        // No data: default to the general-purpose streaming layout.
        return Recommendation::SoaMultiBlob;
    }
    let touched = stats.touched_fraction(info);
    match pattern {
        AccessPattern::RandomFullRecord => {
            // Irregular positions + (almost) whole record: locality of
            // reference wins (paper §2.1: "If the access is at
            // irregular array positions and to almost all of the inner
            // structure, AoS layouts provide better locality").
            if touched > 0.6 {
                Recommendation::Aos
            } else {
                // Random but narrow: split the hot fields off.
                Recommendation::SplitHotCold { hot: stats.hot_leaves() }
            }
        }
        AccessPattern::Streaming => {
            if touched >= 0.99 {
                // Everything is hot: SoA streams every byte usefully
                // and vectorizes; AoS only matches it when lines are
                // fully used *and* the loop is compute-bound.
                Recommendation::SoaMultiBlob
            } else if touched >= 0.5 {
                Recommendation::SoaMultiBlob
            } else {
                Recommendation::SplitHotCold { hot: stats.hot_leaves() }
            }
        }
    }
}

/// Hooks for replacing the model's estimates with measured data.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostModel {
    /// Measured bytes-per-record of the *current* layout, e.g. from a
    /// [`super::Heatmap`] epoch
    /// ([`super::heatmap::HeatmapSnapshot::bytes_per_record`]). When
    /// present it overrides [`estimated_bytes_per_record`] for the
    /// current side of a [`migration_gain`] comparison — the paper's
    /// §4.1 bandwidth-use arithmetic fed with observed rather than
    /// modeled line utilization.
    pub measured_current: Option<f64>,
}

/// First-order cost model: estimated bytes pulled through the cache
/// per record visit under the candidate layout (the §4.1 argument —
/// AoS pays the whole record per visit, SoA only the touched fields,
/// a hot/cold Split the dense hot group plus, if any cold field is
/// touched, the cold AoS record).
pub fn estimated_bytes_per_record(
    stats: &FieldStats,
    info: &RecordInfo,
    rec: &Recommendation,
) -> f64 {
    let touched_size = |leaf: usize| -> Option<usize> {
        stats
            .fields
            .iter()
            .find(|&&(l, c, _)| l == leaf && c > 0)
            .map(|&(_, _, s)| s)
    };
    let any_touched = stats.fields.iter().any(|&(_, c, _)| c > 0);
    if !any_touched {
        return 0.0;
    }
    match rec {
        Recommendation::Aos => info.aligned_size as f64,
        Recommendation::SoaMultiBlob => (0..info.leaf_count())
            .filter_map(touched_size)
            .sum::<usize>() as f64,
        Recommendation::SplitHotCold { hot } => {
            let hot_bytes: usize =
                hot.iter().map(|&l| info.fields[l].size()).sum();
            let cold_touched = stats
                .fields
                .iter()
                .any(|&(l, c, _)| c > 0 && !hot.contains(&l));
            let cold_bytes = if cold_touched {
                // The cold side materializes as *aligned* AoS
                // ([`Recommendation::to_mapping`]), so a touched cold
                // field pulls the aligned cold record — padding
                // included — not the packed sum of cold sizes.
                let mut cold = RecordDim::new();
                for l in (0..info.leaf_count()).filter(|l| !hot.contains(l)) {
                    let f = &info.fields[l];
                    cold = cold.field(f.path.clone(), Type::Scalar(f.scalar));
                }
                RecordInfo::new(&cold).aligned_size
            } else {
                0
            };
            (hot_bytes + cold_bytes) as f64
        }
    }
}

/// Predicted speedup factor of migrating `current` → `candidate` under
/// the observed stats: the ratio of bytes-per-record, with the current
/// side overridable by a measured value ([`CostModel`]). Values above
/// 1.0 favor migrating; the adaptive engine compares against
/// `1.0 + hysteresis` so marginal wins never trigger a relayout.
pub fn migration_gain(
    stats: &FieldStats,
    info: &RecordInfo,
    current: &Recommendation,
    candidate: &Recommendation,
    cost: &CostModel,
) -> f64 {
    let cur = cost
        .measured_current
        .filter(|&m| m > 0.0)
        .unwrap_or_else(|| estimated_bytes_per_record(stats, info, current));
    let cand = estimated_bytes_per_record(stats, info, candidate);
    if cand <= 0.0 {
        return 1.0;
    }
    cur / cand
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoS, Trace};
    use crate::view::alloc_view;
    use crate::workloads::nbody::{self, llama_impl};

    /// The n-body move phase (streams 6 of 7 fields) must be advised
    /// towards SoA — the layout fig 5 measures as fastest for it.
    #[test]
    fn move_phase_recommends_soa() {
        let d = nbody::particle_dim();
        let n = 64;
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(n)));
        let mut v = alloc_view(t);
        let s = nbody::init_particles(n, 1);
        llama_impl::load_state(&mut v, &s);
        v.mapping().reset();
        llama_impl::mv(&mut v);
        let rec = recommend(v.mapping(), AccessPattern::Streaming);
        assert_eq!(rec, Recommendation::SoaMultiBlob);
    }

    /// A workload touching only one field of a wide record must be
    /// advised towards a hot/cold split containing that field.
    #[test]
    fn narrow_access_recommends_split() {
        let d = crate::workloads::hep::event_dim();
        let t = Trace::new(AoS::aligned(&d, ArrayDims::linear(32)));
        let v = alloc_view(t);
        // Touch only field 2 (energy of object 0), heavily.
        for lin in 0..32 {
            for _ in 0..50 {
                let _ = v.get::<f32>(lin, 2);
            }
        }
        match recommend(v.mapping(), AccessPattern::Streaming) {
            Recommendation::SplitHotCold { hot } => {
                assert!(hot.contains(&2));
                assert!(hot.len() < 10, "split must be selective, got {hot:?}");
            }
            other => panic!("expected split, got {other:?}"),
        }
    }

    /// Random full-record access (the paper's §2.1 AoS case).
    #[test]
    fn random_full_record_recommends_aos() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(16)));
        let v = alloc_view(t);
        for lin in [3usize, 9, 1, 14, 7] {
            for leaf in 0..7 {
                let _ = v.get::<f32>(lin, leaf);
            }
        }
        assert_eq!(
            recommend(v.mapping(), AccessPattern::RandomFullRecord),
            Recommendation::Aos
        );
    }

    #[test]
    fn no_data_defaults_to_soa() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(4)));
        let v = alloc_view(t);
        assert_eq!(
            recommend(v.mapping(), AccessPattern::Streaming),
            Recommendation::SoaMultiBlob
        );
    }

    #[test]
    fn to_mapping_materializes_every_recipe() {
        let d = nbody::particle_dim();
        let dims = ArrayDims::linear(12);
        let aos = Recommendation::Aos.to_mapping(&d, dims.clone());
        assert!(aos.mapping_name().starts_with("AoS(aligned"));
        let soa = Recommendation::SoaMultiBlob.to_mapping(&d, dims.clone());
        assert!(soa.mapping_name().starts_with("SoA(multi-blob"));
        let split =
            Recommendation::SplitHotCold { hot: vec![0, 1, 2] }.to_mapping(&d, dims.clone());
        assert!(split.mapping_name().starts_with("Split("), "{}", split.mapping_name());
        // pos.{x,y,z} hot -> 3 SoA blobs + 1 cold AoS blob.
        assert_eq!(split.blob_count(), 4);
        crate::mapping::test_support::check_mapping_invariants(&split);
        // Degenerate hot sets fall back to SoA instead of panicking.
        let all: Vec<usize> = (0..7).collect();
        for hot in [vec![], all] {
            let m = Recommendation::SplitHotCold { hot }.to_mapping(&d, dims.clone());
            assert!(m.mapping_name().starts_with("SoA("));
        }
    }

    #[test]
    fn recipe_mapping_delegates_and_plans() {
        use crate::mapping::LayoutPlan;
        let d = nbody::particle_dim();
        let dims = ArrayDims::linear(9);
        let concrete = crate::mapping::SoA::multi_blob(&d, dims.clone());
        let recipe = Recommendation::SoaMultiBlob.to_mapping(&d, dims.clone());
        assert_eq!(recipe.blob_count(), concrete.blob_count());
        for lin in 0..9 {
            for leaf in 0..7 {
                assert_eq!(
                    recipe.blob_nr_and_offset(leaf, lin),
                    concrete.blob_nr_and_offset(leaf, lin)
                );
            }
        }
        let rp: LayoutPlan = recipe.plan();
        assert_eq!(rp, concrete.plan());
        // Arbitrary layouts ride along type-erased.
        let other = RecipeMapping::Other(std::sync::Arc::new(crate::mapping::AoSoA::new(
            &d,
            dims.clone(),
            4,
        )));
        assert_eq!(other.plan(), crate::mapping::AoSoA::new(&d, dims, 4).plan());
    }

    #[test]
    fn snapshot_stats_drive_the_same_recommendation() {
        let d = nbody::particle_dim();
        let mut t = Trace::new(AoS::packed(&d, ArrayDims::linear(64)));
        let mut v = alloc_view(&t);
        let s = nbody::init_particles(64, 1);
        llama_impl::load_state(&mut v, &s);
        v.mapping().reset();
        llama_impl::mv(&mut v);
        drop(v);
        let snap = t.snapshot();
        let stats = FieldStats::from_snapshot(&snap, t.info());
        assert_eq!(
            recommend_stats(&stats, t.info(), AccessPattern::Streaming),
            Recommendation::SoaMultiBlob
        );
    }

    #[test]
    fn cost_model_orders_layouts_by_bytes_per_record() {
        let d = nbody::particle_dim();
        let info = RecordInfo::new(&d);
        // Only pos.{x,y,z} touched: 12 of 28 packed bytes.
        let stats = FieldStats {
            fields: (0..7).map(|l| (l, if l < 3 { 100 } else { 0 }, 4)).collect(),
        };
        let aos = estimated_bytes_per_record(&stats, &info, &Recommendation::Aos);
        let soa = estimated_bytes_per_record(&stats, &info, &Recommendation::SoaMultiBlob);
        let split = estimated_bytes_per_record(
            &stats,
            &info,
            &Recommendation::SplitHotCold { hot: vec![0, 1, 2] },
        );
        assert_eq!(aos, info.aligned_size as f64);
        assert_eq!(soa, 12.0);
        assert_eq!(split, 12.0); // no cold field touched
        assert!(aos > soa);
        // Gain of AoS -> SoA exceeds any sane hysteresis; the reverse
        // direction never looks like a win.
        let cost = CostModel::default();
        let aos_rec = Recommendation::Aos;
        let soa_rec = Recommendation::SoaMultiBlob;
        let gain = migration_gain(&stats, &info, &aos_rec, &soa_rec, &cost);
        assert!(gain > 1.5, "gain {gain}");
        let back = migration_gain(&stats, &info, &soa_rec, &aos_rec, &cost);
        assert!(back < 1.0, "back {back}");
        // A cold-touched split pays the *aligned* cold record — the
        // layout to_mapping actually materializes — not the packed sum
        // of cold sizes. Mixed-size record: hot id (u16), cold
        // {3×f32, f64, 3×bool} → aligned 32 (packed would be 23).
        let d2 = crate::mapping::test_support::particle_dim();
        let info2 = RecordInfo::new(&d2);
        let all_touched = FieldStats {
            fields: (0..info2.leaf_count())
                .map(|l| (l, 10, info2.fields[l].size()))
                .collect(),
        };
        let split_cold = estimated_bytes_per_record(
            &all_touched,
            &info2,
            &Recommendation::SplitHotCold { hot: vec![0] },
        );
        assert_eq!(split_cold, 2.0 + 32.0);

        // A measured working set overrides the modeled current cost.
        let measured = CostModel { measured_current: Some(6.0) };
        let g = migration_gain(
            &stats,
            &info,
            &Recommendation::Aos,
            &Recommendation::SoaMultiBlob,
            &measured,
        );
        assert_eq!(g, 0.5);
    }

    #[test]
    fn stats_extraction() {
        let d = nbody::particle_dim();
        let t = Trace::new(AoS::packed(&d, ArrayDims::linear(4)));
        let v = alloc_view(t);
        let _ = v.get::<f32>(0, 0);
        let _ = v.get::<f32>(0, 0);
        let stats = FieldStats::from_trace(v.mapping());
        assert_eq!(stats.fields[0], (0, 2, 4));
        assert_eq!(stats.hot_leaves(), vec![0]);
    }
}
