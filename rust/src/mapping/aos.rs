//! Array-of-structs mapping (paper §3.7, 48 LOCs in C++).
//!
//! Places the record's fields after each other and repeats that layout
//! once per array slot. Field offsets follow either C++ alignment rules
//! (`aligned`, the default, with padding) or are tightly packed.

use std::sync::Arc;

use super::{AffineLeaf, Mapping};
use crate::array::{ArrayDims, Linearizer, RowMajor};
use crate::record::{RecordDim, RecordInfo};

/// AoS mapping, generic over the array-index linearization.
#[derive(Debug, Clone)]
pub struct AoS<L: Linearizer = RowMajor> {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    lin: L,
    lin_state: L::State,
    slots: usize,
    aligned: bool,
    record_size: usize,
    /// Per-leaf byte offset within one record (aligned or packed).
    offsets: Vec<usize>,
}

impl AoS<RowMajor> {
    /// Aligned AoS (C++ struct layout), row-major.
    pub fn aligned(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_linearizer(dim, dims, RowMajor, true)
    }

    /// Packed AoS (no padding), row-major.
    pub fn packed(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_linearizer(dim, dims, RowMajor, false)
    }
}

impl<L: Linearizer> AoS<L> {
    /// AoS with an explicit array-index linearization.
    pub fn with_linearizer(dim: &RecordDim, dims: ArrayDims, lin: L, aligned: bool) -> Self {
        let info = Arc::new(RecordInfo::new(dim));
        let lin_state = lin.prepare(&dims);
        let slots = lin.slot_count(&dims);
        let record_size = if aligned { info.aligned_size } else { info.packed_size };
        let offsets = info
            .fields
            .iter()
            .map(|f| if aligned { f.offset_aligned } else { f.offset_packed })
            .collect();
        AoS { info, dims, lin, lin_state, slots, aligned, record_size, offsets }
    }

    /// True when field offsets follow C++ alignment rules.
    pub fn is_aligned(&self) -> bool {
        self.aligned
    }

    /// Bytes per stored record (aligned or packed).
    pub fn record_size(&self) -> usize {
        self.record_size
    }
}

impl<L: Linearizer> Mapping for AoS<L> {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        1
    }

    fn blob_size(&self, nr: usize) -> usize {
        debug_assert_eq!(nr, 0);
        self.slots * self.record_size
    }

    #[inline]
    fn slot_count(&self) -> usize {
        self.slots
    }

    #[inline]
    fn slot_of_lin(&self, lin: usize) -> usize {
        // Row-major canonical == slot only when L is row-major; other
        // linearizers route through slot_of_nd. We detect the common
        // case cheaply: RowMajor's state is the canonical strides.
        if std::any::TypeId::of::<L>() == std::any::TypeId::of::<RowMajor>() {
            lin
        } else {
            let idx = self.dims.delinearize_row_major(lin);
            L::linearize(&self.lin_state, &idx)
        }
    }

    #[inline]
    fn slot_of_nd(&self, idx: &[usize]) -> usize {
        L::linearize(&self.lin_state, idx)
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, slot: usize) -> (usize, usize) {
        (0, slot * self.record_size + self.offsets[leaf])
    }

    fn mapping_name(&self) -> String {
        format!(
            "AoS({}, {})",
            if self.aligned { "aligned" } else { "packed" },
            self.lin.name()
        )
    }

    fn plan(&self) -> super::LayoutPlan {
        // Packed AoS == AoSoA with 1 lane (no padding between fields);
        // single-element runs stay chunk-correct under any slot
        // permutation, so chunkability has no row-major restriction.
        //
        // Aligned AoS deliberately reports `None` even though its
        // 1-element runs are just as contiguous: alignment padding
        // between fields means a record is NOT one dense span, so
        // `Some(1)` would only buy per-field 1-element memcpys — and it
        // would demote aligned-AoS ↔ affine pairs from the `Program`
        // strategy (one `StridedRun` per leaf, SIMD-gather executable)
        // to `AoSoAChunked`'s per-record op lists. `chunk_lanes` is a
        // copy-strategy decision, not a geometric property.
        let chunk = if self.aligned { None } else { Some(1) };
        if std::any::TypeId::of::<L>() != std::any::TypeId::of::<RowMajor>() {
            return super::LayoutPlan::generic(self.dims.count(), true, chunk);
        }
        super::LayoutPlan::affine(
            self.dims.count(),
            true,
            chunk,
            self.offsets
                .iter()
                .map(|&off| AffineLeaf { blob: 0, base: off, stride: self.record_size })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{ColMajor, MortonCurve};
    use crate::mapping::test_support::{check_mapping_invariants, particle_dim};

    #[test]
    fn packed_layout_offsets() {
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        // packed record = 2+4+4+4+8+1+1+1 = 25 bytes
        assert_eq!(m.record_size(), 25);
        assert_eq!(m.blob_count(), 1);
        assert_eq!(m.blob_size(0), 100);
        assert_eq!(m.blob_nr_and_offset(0, 0), (0, 0)); // id @ rec 0
        assert_eq!(m.blob_nr_and_offset(1, 0), (0, 2)); // pos.x
        assert_eq!(m.blob_nr_and_offset(0, 2), (0, 50)); // id @ rec 2
    }

    #[test]
    fn aligned_layout_offsets() {
        let m = AoS::aligned(&particle_dim(), ArrayDims::linear(4));
        assert_eq!(m.record_size(), 32); // padded to 8
        // id u16 @0, pad, pos.x @4, pos.y @8, pos.z @12, mass f64 @16.
        assert_eq!(m.blob_nr_and_offset(4, 0), (0, 16));
        assert_eq!(m.blob_nr_and_offset(4, 1), (0, 48));
    }

    #[test]
    fn invariants_packed_and_aligned() {
        for aligned in [false, true] {
            let m = AoS::with_linearizer(
                &particle_dim(),
                ArrayDims::from([3, 5]),
                RowMajor,
                aligned,
            );
            check_mapping_invariants(&m);
        }
    }

    #[test]
    fn invariants_col_major_and_morton() {
        let m = AoS::with_linearizer(&particle_dim(), ArrayDims::from([3, 5]), ColMajor, true);
        check_mapping_invariants(&m);
        let m = AoS::with_linearizer(&particle_dim(), ArrayDims::from([3, 5]), MortonCurve, false);
        check_mapping_invariants(&m);
        // Morton pads 3x5 -> 4x8 slots.
        assert_eq!(m.slot_count(), 32);
        assert_eq!(m.blob_size(0), 32 * 25);
    }

    #[test]
    fn packed_aos_is_aosoa1() {
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        assert_eq!(m.aosoa_lanes(), Some(1));
        let m = AoS::aligned(&particle_dim(), ArrayDims::linear(4));
        assert_eq!(m.aosoa_lanes(), None);
    }
}
