//! [`WireRecipe`]: a *nameable* storage-mapping recipe — the closed set
//! of layouts a wire manifest (`runtime::manifest::WireManifest`) can
//! describe with one token and the receiving process can rebuild from
//! the record dimension + array extents alone.
//!
//! Distinct from [`super::RecipeMapping`], which *holds* a materialized
//! mapping chosen by the advisor; a `WireRecipe` is pure data (it
//! survives `parse(token())`) and materializes on demand via
//! [`WireRecipe::build`].

use crate::array::ArrayDims;
use crate::error::{Context, Result};
use crate::record::RecordDim;
use crate::{bail, ensure};

use super::{AoS, AoSoA, DynMapping, SoA};

/// A parseable layout token naming one of the storage mappings.
///
/// Tokens: `aos:packed`, `aos:aligned`, `soa:sb`, `soa:mb`,
/// `aosoa:<L>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WireRecipe {
    /// Packed (padding-free) array-of-structs — the dense wire layout
    /// `copy::wire::serialize` always packs into.
    AosPacked,
    /// Aligned array-of-structs.
    AosAligned,
    /// Single-blob struct-of-arrays.
    SoaSingle,
    /// Multi-blob struct-of-arrays (one blob per leaf).
    SoaMulti,
    /// Array-of-struct-of-arrays with `L` lanes.
    AoSoA(usize),
}

impl WireRecipe {
    /// The manifest token (`parse(token())` is identity).
    pub fn token(&self) -> String {
        match self {
            WireRecipe::AosPacked => "aos:packed".into(),
            WireRecipe::AosAligned => "aos:aligned".into(),
            WireRecipe::SoaSingle => "soa:sb".into(),
            WireRecipe::SoaMulti => "soa:mb".into(),
            WireRecipe::AoSoA(l) => format!("aosoa:{l}"),
        }
    }

    /// Parse a manifest token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "aos:packed" => WireRecipe::AosPacked,
            "aos:aligned" => WireRecipe::AosAligned,
            "soa:sb" => WireRecipe::SoaSingle,
            "soa:mb" => WireRecipe::SoaMulti,
            other => {
                let Some(lanes) = other.strip_prefix("aosoa:") else {
                    bail!("unknown layout recipe {other:?}");
                };
                let lanes: usize = lanes.parse().context("aosoa lane count")?;
                ensure!(lanes >= 1, "aosoa lane count must be >= 1");
                WireRecipe::AoSoA(lanes)
            }
        })
    }

    /// Materialize the concrete mapping for `record` × `dims`.
    pub fn build(&self, record: &RecordDim, dims: ArrayDims) -> DynMapping {
        match self {
            WireRecipe::AosPacked => Box::new(AoS::packed(record, dims)),
            WireRecipe::AosAligned => Box::new(AoS::aligned(record, dims)),
            WireRecipe::SoaSingle => Box::new(SoA::single_blob(record, dims)),
            WireRecipe::SoaMulti => Box::new(SoA::multi_blob(record, dims)),
            WireRecipe::AoSoA(l) => Box::new(AoSoA::new(record, dims, *l)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::Mapping;

    #[test]
    fn tokens_round_trip() {
        for r in [
            WireRecipe::AosPacked,
            WireRecipe::AosAligned,
            WireRecipe::SoaSingle,
            WireRecipe::SoaMulti,
            WireRecipe::AoSoA(8),
            WireRecipe::AoSoA(3),
        ] {
            assert_eq!(WireRecipe::parse(&r.token()).unwrap(), r, "{}", r.token());
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["aos", "soa", "aosoa", "aosoa:", "aosoa:0", "aosoa:x", "packed", ""] {
            assert!(WireRecipe::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn build_materializes_the_named_layout() {
        let d = particle_dim();
        let dims = ArrayDims::linear(16);
        let packed = WireRecipe::AosPacked.build(&d, dims.clone());
        assert_eq!(packed.blob_count(), 1);
        assert_eq!(packed.blob_size(0), d.packed_size() * 16);
        let soa = WireRecipe::SoaMulti.build(&d, dims.clone());
        assert_eq!(soa.blob_count(), d.leaf_count());
        let aosoa = WireRecipe::AoSoA(4).build(&d, dims);
        assert_eq!(aosoa.aosoa_lanes(), Some(4));
    }
}
