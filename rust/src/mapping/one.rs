//! The One mapping (paper §3.7, 34 LOCs in C++): collapses the entire
//! array dimensions into a single stored record instance — every array
//! index aliases the same storage. Useful for broadcast-style fields
//! (and as the second child of a Split, as in the paper's fig 4c).

use std::sync::Arc;

use super::{AffineLeaf, Mapping};
use crate::array::ArrayDims;
use crate::record::{RecordDim, RecordInfo};

/// The One mapping: a single stored record aliased by every index.
#[derive(Debug, Clone)]
pub struct One {
    info: Arc<RecordInfo>,
    dims: ArrayDims,
    aligned: bool,
    offsets: Vec<usize>,
    record_size: usize,
}

impl One {
    /// Aligned single-record storage (C++ struct layout).
    pub fn new(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_alignment(dim, dims, true)
    }

    /// Packed single-record storage (no padding).
    pub fn packed(dim: &RecordDim, dims: ArrayDims) -> Self {
        Self::with_alignment(dim, dims, false)
    }

    /// One with explicit alignment choice.
    pub fn with_alignment(dim: &RecordDim, dims: ArrayDims, aligned: bool) -> Self {
        let info = Arc::new(RecordInfo::new(dim));
        let record_size = if aligned { info.aligned_size } else { info.packed_size };
        let offsets = info
            .fields
            .iter()
            .map(|f| if aligned { f.offset_aligned } else { f.offset_packed })
            .collect();
        One { info, dims, aligned, offsets, record_size }
    }
}

impl Mapping for One {
    fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    fn dims(&self) -> &ArrayDims {
        &self.dims
    }

    fn blob_count(&self) -> usize {
        1
    }

    fn blob_size(&self, nr: usize) -> usize {
        debug_assert_eq!(nr, 0);
        self.record_size
    }

    #[inline]
    fn slot_of_nd(&self, _idx: &[usize]) -> usize {
        0
    }

    #[inline]
    fn slot_of_lin(&self, _lin: usize) -> usize {
        0
    }

    #[inline]
    fn blob_nr_and_offset(&self, leaf: usize, _slot: usize) -> (usize, usize) {
        (0, self.offsets[leaf])
    }

    fn mapping_name(&self) -> String {
        format!("One({})", if self.aligned { "aligned" } else { "packed" })
    }

    fn plan(&self) -> super::LayoutPlan {
        // Every index aliases one record: affine with stride 0. Never
        // chunkable — the aliasing makes runs overlap.
        super::LayoutPlan::affine(
            self.dims.count(),
            true,
            None,
            self.offsets
                .iter()
                .map(|&off| AffineLeaf { blob: 0, base: off, stride: 0 })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::particle_dim;

    #[test]
    fn all_indices_alias_one_record() {
        let m = One::new(&particle_dim(), ArrayDims::from([128, 64]));
        assert_eq!(m.blob_size(0), m.info().aligned_size);
        assert_eq!(m.blob_nr_and_offset(4, 0), m.blob_nr_and_offset(4, 999));
        assert_eq!(m.slot_of_nd(&[100, 3]), 0);
    }

    #[test]
    fn packed_one_is_packed_size() {
        let m = One::packed(&particle_dim(), ArrayDims::linear(1000));
        assert_eq!(m.blob_size(0), 25);
    }
}
