//! [`LayoutPlan`]: a compiled, self-contained execution recipe for a
//! mapping (EXPERIMENTS.md §Plan).
//!
//! A mapping is a *function* from `(leaf, slot)` to `(blob, offset)`;
//! hot paths must not call that function per access, because the
//! mapping object lives behind the same reference as the blobs and LLVM
//! cannot hoist its table loads (see `mapping::affine`). A `LayoutPlan`
//! is the closed form of that function, extracted once per mapping:
//!
//! * [`AddrPlan::Affine`] — every leaf is `blob[nr][base + lin*stride]`
//!   (AoS, SoA, One, affine Splits);
//! * [`AddrPlan::PiecewiseAoSoA`] — leaves repeat in lane-blocks of `L`
//!   contiguous scalars, `blob[nr][(lin/L)*block_stride + lane_offset +
//!   (lin%L)*lane_stride]` — covers packed AoS (`L = 1`), AoSoA-L and
//!   SoA (`L = count`) uniformly, plus Split compositions thereof;
//! * [`AddrPlan::Generic`] — dynamic translation through the mapping
//!   object, preserving the semantics of instrumented (Trace, Heatmap)
//!   and space-filling-curve layouts.
//!
//! Besides addressing, a plan carries the two properties the copy
//! engine dispatches on: [`LayoutPlan::chunk_lanes`] (the AoSoA-family
//! lane count, valid in canonical index order — possibly present even
//! when addressing is `Generic`, e.g. packed AoS under a Morton order)
//! and [`LayoutPlan::native`]. Representation wrappers (Byteswap)
//! forward their inner plan's addressing with the native flag cleared
//! ([`LayoutPlan::with_native`]); cursors and the copy engine key every
//! raw-byte fast path off that flag. Kernels obtain per-leaf cursors from a
//! plan via `view::cursor`; the copy engine compares two plans to pick
//! its strategy. A new mapping gets every fast path by implementing the
//! one [`super::Mapping::plan`] method.

pub use super::affine::AffineLeaf;

/// One leaf's piecewise-affine address rule:
/// `blob[nr][(lin / lanes) * block_stride + lane_offset +
/// (lin % lanes) * lane_stride]` (the lane count lives on the enclosing
/// [`PiecewisePlan`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PiecewiseLeaf {
    /// Blob the leaf's values live in.
    pub blob: usize,
    /// Byte distance between consecutive lane-blocks.
    pub block_stride: usize,
    /// Byte offset of this leaf's lane group within a block.
    pub lane_offset: usize,
    /// Byte distance between consecutive lanes within the group.
    pub lane_stride: usize,
}

impl PiecewiseLeaf {
    /// Lift an affine rule to a piecewise rule at lane count `lanes`:
    /// `base + lin*stride == (lin/L)*(stride*L) + base + (lin%L)*stride`.
    pub fn from_affine(a: &AffineLeaf, lanes: usize) -> Self {
        PiecewiseLeaf {
            blob: a.blob,
            block_stride: a.stride * lanes,
            lane_offset: a.base,
            lane_stride: a.stride,
        }
    }
}

/// Per-leaf piecewise rules plus their shared lane count.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PiecewisePlan {
    /// Records per lane-block (the AoSoA `L`).
    pub lanes: usize,
    /// One address rule per leaf, declaration order.
    pub leaves: Vec<PiecewiseLeaf>,
}

/// The address-computation part of a [`LayoutPlan`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AddrPlan {
    /// `blob[nr][base + lin * stride]` per leaf.
    Affine(Vec<AffineLeaf>),
    /// Lane-block rules per leaf (packed AoS / AoSoA-L / SoA family).
    PiecewiseAoSoA(PiecewisePlan),
    /// Not closed-form: resolve through the mapping object.
    Generic,
}

/// A compiled mapping: everything the kernels, cursors and the copy
/// engine need, with no further calls into the mapping on resolvable
/// paths. Extract once per `(mapping, blobs)` pair, outside hot loops.
/// `Hash` + `Eq` make closed-form plans usable as cache keys (the copy
/// engine's [`crate::copy::ProgramCache`] fingerprints layout pairs).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LayoutPlan {
    count: usize,
    native: bool,
    chunk_lanes: Option<usize>,
    addr: AddrPlan,
}

impl LayoutPlan {
    /// Affine plan. `chunk_lanes` is independent of affineness: aligned
    /// AoS is affine but not chunkable (inter-field padding), One is
    /// affine but aliasing (never chunkable).
    pub fn affine(
        count: usize,
        native: bool,
        chunk_lanes: Option<usize>,
        leaves: Vec<AffineLeaf>,
    ) -> Self {
        LayoutPlan { count, native, chunk_lanes, addr: AddrPlan::Affine(leaves) }
    }

    /// Piecewise plan; lane-blocked layouts are chunk-copyable at their
    /// own lane count.
    pub fn piecewise(count: usize, native: bool, lanes: usize, leaves: Vec<PiecewiseLeaf>) -> Self {
        debug_assert!(lanes > 1, "1-lane layouts are affine; use LayoutPlan::affine");
        LayoutPlan {
            count,
            native,
            chunk_lanes: Some(lanes),
            addr: AddrPlan::PiecewiseAoSoA(PiecewisePlan { lanes, leaves }),
        }
    }

    /// Generic fallback. `chunk_lanes` may still be present: chunked
    /// copies only need leaf *runs* to be contiguous, which a curve
    /// order preserves for 1-element runs.
    pub fn generic(count: usize, native: bool, chunk_lanes: Option<usize>) -> Self {
        LayoutPlan { count, native, chunk_lanes, addr: AddrPlan::Generic }
    }

    /// The same plan with the native-representation flag replaced.
    /// Representation wrappers ([`crate::mapping::Byteswap`]) forward
    /// their inner mapping's addressing unchanged and only flip this
    /// flag — the copy engine then moves swapped bytes verbatim between
    /// equal-representation pairs and compiles native ↔ swapped affine
    /// pairs into per-leaf swap runs, while cursors refuse raw-byte
    /// extraction for any non-native plan.
    pub fn with_native(mut self, native: bool) -> Self {
        self.native = native;
        self
    }

    /// Canonical record count the plan was compiled for.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Whether stored bytes are plain native-endian values.
    #[inline]
    pub fn native(&self) -> bool {
        self.native
    }

    /// AoSoA-family lane count for the chunked copy (packed AoS = 1,
    /// AoSoA-L = L, SoA = count), `None` if the layout should not use
    /// the chunked strategy.
    ///
    /// `None` does not always mean "runs are not contiguous": aligned
    /// AoS has contiguous 1-element runs but reports `None` because its
    /// inter-field alignment padding makes per-record chunking
    /// pointless, and the affine `Program` strategy (per-leaf
    /// [`crate::copy::CopyOp::StridedRun`]s, SIMD-gather executable)
    /// serves those pairs strictly better — see `AoS::plan`. The copy
    /// compiler treats this value as the strategy gate
    /// (`plans_chunk_compatible`), so a mapping opts out by returning
    /// `None` regardless of geometry.
    #[inline]
    pub fn chunk_lanes(&self) -> Option<usize> {
        self.chunk_lanes
    }

    /// The address-computation rules (affine, piecewise, or generic).
    #[inline]
    pub fn addr(&self) -> &AddrPlan {
        &self.addr
    }

    /// Per-leaf affine rules, if this plan is affine.
    pub fn affine_leaves(&self) -> Option<Vec<AffineLeaf>> {
        match &self.addr {
            AddrPlan::Affine(leaves) => Some(leaves.clone()),
            _ => None,
        }
    }

    /// One leaf's affine rule, if this plan is affine (span extraction
    /// for the copy-program compiler — no per-call clone).
    #[inline]
    pub fn affine_leaf(&self, leaf: usize) -> Option<&AffineLeaf> {
        match &self.addr {
            AddrPlan::Affine(leaves) => Some(&leaves[leaf]),
            _ => None,
        }
    }

    /// End (exclusive) of the contiguous leaf-run containing `lin`:
    /// every leaf's bytes for records `lin .. chunk_run_end(lin)` are
    /// consecutive in storage (capped by the caller at the record
    /// count). `None` when runs are not contiguous. For Split plans
    /// `chunk_lanes` is the gcd of the children's lane counts — which
    /// may be *smaller* than the composed piecewise addressing lanes,
    /// so span extraction must use this, never `PiecewisePlan::lanes`.
    #[inline]
    pub fn chunk_run_end(&self, lin: usize) -> Option<usize> {
        match self.chunk_lanes {
            Some(l) if l > 0 => Some(((lin / l) + 1) * l),
            _ => None,
        }
    }

    /// The piecewise rules, if this plan is lane-blocked.
    pub fn piecewise(&self) -> Option<&PiecewisePlan> {
        match &self.addr {
            AddrPlan::PiecewiseAoSoA(p) => Some(p),
            _ => None,
        }
    }

    /// Resolve `(leaf, lin)` to `(blob, offset)` from the compiled
    /// rules; `None` for [`AddrPlan::Generic`].
    #[inline]
    pub fn resolve(&self, leaf: usize, lin: usize) -> Option<(usize, usize)> {
        match &self.addr {
            AddrPlan::Affine(leaves) => {
                let a = &leaves[leaf];
                Some((a.blob, a.base + lin * a.stride))
            }
            AddrPlan::PiecewiseAoSoA(p) => {
                let l = &p.leaves[leaf];
                let block = lin / p.lanes;
                let lane = lin % p.lanes;
                Some((
                    l.blob,
                    block * l.block_stride + l.lane_offset + lane * l.lane_stride,
                ))
            }
            AddrPlan::Generic => None,
        }
    }

    /// Resolve through the plan, falling back to the mapping for
    /// generic plans (the only place a generic plan pays the dynamic
    /// translation).
    #[inline]
    pub fn resolve_with<M: super::Mapping + ?Sized>(
        &self,
        m: &M,
        leaf: usize,
        lin: usize,
    ) -> (usize, usize) {
        match self.resolve(leaf, lin) {
            Some(r) => r,
            None => m.blob_nr_and_offset(leaf, m.slot_of_lin(lin)),
        }
    }

    /// Compose two child plans into a Split parent plan: `route[leaf] =
    /// (in_a, child leaf)`, blob numbers of the B side shifted by
    /// `a_blobs`. Addressing composes to the strongest common form
    /// (affine if both affine, a shared-lane piecewise otherwise,
    /// generic as the floor); chunkability composes to the gcd of the
    /// children's lane counts (runs of `gcd` lins stay contiguous on a
    /// layout chunkable at any multiple of it).
    pub fn compose_split(
        a: &LayoutPlan,
        b: &LayoutPlan,
        route: &[(bool, usize)],
        a_blobs: usize,
        native: bool,
    ) -> LayoutPlan {
        debug_assert_eq!(a.count, b.count);
        let count = a.count;
        let native = native && a.native && b.native;
        let chunk_lanes = match (a.chunk_lanes, b.chunk_lanes) {
            (Some(x), Some(y)) => Some(gcd(x, y)),
            _ => None,
        };

        let shift = |mut leaf: PiecewiseLeaf, in_a: bool| {
            if !in_a {
                leaf.blob += a_blobs;
            }
            leaf
        };

        let addr = match (&a.addr, &b.addr) {
            (AddrPlan::Affine(la), AddrPlan::Affine(lb)) => AddrPlan::Affine(
                route
                    .iter()
                    .map(|&(in_a, child)| {
                        if in_a {
                            la[child]
                        } else {
                            let mut l = lb[child];
                            l.blob += a_blobs;
                            l
                        }
                    })
                    .collect(),
            ),
            // One side lane-blocked: lift the other to the same lane
            // count when possible (affine lifts to any lane count;
            // piecewise only matches its own).
            (AddrPlan::PiecewiseAoSoA(pa), AddrPlan::Affine(lb)) => {
                AddrPlan::PiecewiseAoSoA(PiecewisePlan {
                    lanes: pa.lanes,
                    leaves: route
                        .iter()
                        .map(|&(in_a, child)| {
                            if in_a {
                                pa.leaves[child]
                            } else {
                                shift(PiecewiseLeaf::from_affine(&lb[child], pa.lanes), false)
                            }
                        })
                        .collect(),
                })
            }
            (AddrPlan::Affine(la), AddrPlan::PiecewiseAoSoA(pb)) => {
                AddrPlan::PiecewiseAoSoA(PiecewisePlan {
                    lanes: pb.lanes,
                    leaves: route
                        .iter()
                        .map(|&(in_a, child)| {
                            if in_a {
                                PiecewiseLeaf::from_affine(&la[child], pb.lanes)
                            } else {
                                shift(pb.leaves[child], false)
                            }
                        })
                        .collect(),
                })
            }
            (AddrPlan::PiecewiseAoSoA(pa), AddrPlan::PiecewiseAoSoA(pb))
                if pa.lanes == pb.lanes =>
            {
                AddrPlan::PiecewiseAoSoA(PiecewisePlan {
                    lanes: pa.lanes,
                    leaves: route
                        .iter()
                        .map(|&(in_a, child)| {
                            if in_a {
                                pa.leaves[child]
                            } else {
                                shift(pb.leaves[child], false)
                            }
                        })
                        .collect(),
                })
            }
            _ => AddrPlan::Generic,
        };
        LayoutPlan { count, native, chunk_lanes, addr }
    }
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, Heatmap, Mapping, One, SoA, Split, Trace};
    use crate::record::RecordCoord;

    /// Any Some(resolve) must equal the mapping everywhere.
    fn check_plan<M: Mapping>(m: &M) {
        let plan = m.plan();
        assert_eq!(plan.count(), m.dims().count(), "{}", m.mapping_name());
        assert_eq!(plan.native(), m.is_native_representation(), "{}", m.mapping_name());
        for lin in 0..m.dims().count() {
            for leaf in 0..m.info().leaf_count() {
                let want = m.blob_nr_and_offset(leaf, m.slot_of_lin(lin));
                assert_eq!(
                    plan.resolve_with(m, leaf, lin),
                    want,
                    "{} leaf {leaf} lin {lin}",
                    m.mapping_name()
                );
            }
        }
    }

    #[test]
    fn plans_of_all_storage_mappings_resolve() {
        let d = particle_dim();
        let dims = ArrayDims::from([3, 5]);
        check_plan(&AoS::aligned(&d, dims.clone()));
        check_plan(&AoS::packed(&d, dims.clone()));
        check_plan(&SoA::multi_blob(&d, dims.clone()));
        check_plan(&SoA::single_blob(&d, dims.clone()));
        check_plan(&One::new(&d, dims.clone()));
        for lanes in [1, 2, 4, 8, 16] {
            check_plan(&AoSoA::new(&d, dims.clone(), lanes));
        }
    }

    #[test]
    fn plan_kinds_match_expectations() {
        let d = particle_dim();
        let dims = ArrayDims::linear(10);
        assert!(matches!(AoS::aligned(&d, dims.clone()).plan().addr(), AddrPlan::Affine(_)));
        assert!(matches!(
            AoSoA::new(&d, dims.clone(), 4).plan().addr(),
            AddrPlan::PiecewiseAoSoA(_)
        ));
        // AoSoA1 degenerates to packed AoS: affine.
        assert!(matches!(AoSoA::new(&d, dims.clone(), 1).plan().addr(), AddrPlan::Affine(_)));
        assert!(matches!(
            Trace::new(AoS::packed(&d, dims.clone())).plan().addr(),
            AddrPlan::Generic
        ));
        assert!(matches!(
            Heatmap::new(AoS::packed(&d, dims.clone())).plan().addr(),
            AddrPlan::Generic
        ));
        // Byteswap forwards the inner plan's addressing — only the
        // native flag flips (packed AoS: affine, 1-lane chunkable).
        let bs = Byteswap::new(AoS::packed(&d, dims.clone())).plan();
        assert!(matches!(bs.addr(), AddrPlan::Affine(_)));
        assert_eq!(bs.chunk_lanes(), Some(1));
        assert!(!bs.native());
        check_plan(&Byteswap::new(AoS::packed(&d, dims.clone())));
        check_plan(&Byteswap::new(AoSoA::new(&d, dims, 4)));
    }

    #[test]
    fn chunk_lanes_follow_the_family() {
        let d = particle_dim();
        let dims = ArrayDims::linear(12);
        assert_eq!(AoS::packed(&d, dims.clone()).plan().chunk_lanes(), Some(1));
        // Aligned AoS pins `None` by design, not geometry: its runs are
        // contiguous 1-element runs too, but reporting a lane count
        // would demote aligned-AoS ↔ affine pairs from the `Program`
        // strategy (per-leaf StridedRuns — see
        // `golden_affine_pair_compiles_strided_runs`) to per-record
        // chunk op lists. See the `chunk_lanes` doc.
        assert_eq!(AoS::aligned(&d, dims.clone()).plan().chunk_lanes(), None);
        assert!(matches!(AoS::aligned(&d, dims.clone()).plan().addr(), AddrPlan::Affine(_)));
        assert_eq!(SoA::multi_blob(&d, dims.clone()).plan().chunk_lanes(), Some(12));
        assert_eq!(AoSoA::new(&d, dims.clone(), 4).plan().chunk_lanes(), Some(4));
        // One aliases every record: affine, never chunkable.
        let one = One::new(&d, dims.clone()).plan();
        assert!(matches!(one.addr(), AddrPlan::Affine(_)));
        assert_eq!(one.chunk_lanes(), None);
    }

    #[test]
    fn split_composes_affine_and_piecewise() {
        let d = particle_dim();
        let dims = ArrayDims::linear(13); // not a lane multiple: tail blocks
        // Affine + affine -> affine (pos -> SoA MB, rest -> aligned AoS).
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| SoA::multi_blob(sd, ad),
            |sd, ad| AoS::aligned(sd, ad),
        );
        assert!(matches!(m.plan().addr(), AddrPlan::Affine(_)));
        check_plan(&m);

        // AoSoA + affine -> piecewise at the AoSoA's lane count.
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        );
        let plan = m.plan();
        assert!(matches!(plan.addr(), AddrPlan::PiecewiseAoSoA(p) if p.lanes == 4));
        check_plan(&m);

        // Affine + AoSoA (B side blob shift exercised).
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoS::packed(sd, ad),
            |sd, ad| AoSoA::new(sd, ad, 8),
        );
        check_plan(&m);

        // Mismatched lane counts -> generic addressing, gcd chunking.
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 6),
        );
        let plan = m.plan();
        assert!(matches!(plan.addr(), AddrPlan::Generic));
        assert_eq!(plan.chunk_lanes(), Some(2));
        check_plan(&m);
    }

    #[test]
    fn affine_lifts_to_any_lane_count() {
        let a = AffineLeaf { blob: 2, base: 40, stride: 4 };
        for lanes in [1usize, 3, 8] {
            let p = PiecewiseLeaf::from_affine(&a, lanes);
            for lin in 0..30 {
                let addr =
                    (lin / lanes) * p.block_stride + p.lane_offset + (lin % lanes) * p.lane_stride;
                assert_eq!(addr, a.base + lin * a.stride, "lanes {lanes} lin {lin}");
            }
        }
    }

    #[test]
    fn span_helpers_expose_runs_and_affine_rules() {
        let d = particle_dim();
        let dims = ArrayDims::linear(10);
        let p = AoSoA::new(&d, dims.clone(), 4).plan();
        assert_eq!(p.chunk_run_end(0), Some(4));
        assert_eq!(p.chunk_run_end(5), Some(8));
        assert!(p.affine_leaf(0).is_none());
        let a = AoS::packed(&d, dims.clone()).plan();
        assert_eq!(a.chunk_run_end(7), Some(8));
        let leaf = *a.affine_leaf(1).expect("packed AoS is affine");
        assert_eq!((leaf.blob, leaf.base, leaf.stride), (0, 2, 25));
        assert_eq!(AoS::aligned(&d, dims).plan().chunk_run_end(3), None);
    }

    #[test]
    fn curve_layouts_keep_single_lane_chunking_only_when_packed() {
        use crate::array::MortonCurve;
        let d = particle_dim();
        let packed = AoS::with_linearizer(&d, ArrayDims::from([4, 4]), MortonCurve, false);
        let plan = packed.plan();
        assert!(matches!(plan.addr(), AddrPlan::Generic));
        assert_eq!(plan.chunk_lanes(), Some(1));
        let aligned = AoS::with_linearizer(&d, ArrayDims::from([4, 4]), MortonCurve, true);
        assert_eq!(aligned.plan().chunk_lanes(), None);
    }
}
