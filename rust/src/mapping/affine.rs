//! Affine layout descriptions — the performance backbone of the hot
//! kernels (EXPERIMENTS.md §Perf).
//!
//! Many mappings are *affine in the canonical linear index*: the byte
//! address of leaf `l` at index `i` is `base[l] + i * stride[l]` inside
//! a fixed blob. AoS (stride = record size), SoA (stride = field size),
//! Split-of-affine and One (stride = 0) all qualify; AoSoA (piecewise)
//! and the instrumented/represented wrappers do not.
//!
//! In C++ LLAMA the compiler proves this by inlining the constexpr
//! mapping; with identical disassembly as the result (paper listings
//! 10/11). In Rust, the mapping and the blobs live behind the same
//! `&mut View`, so LLVM must assume stores to blob bytes may alias the
//! mapping's offset tables and cannot hoist them. [`AffineLeaf`]
//! extracts the three integers per leaf *once*; kernels then run over
//! raw cursors with loop-invariant bases — restoring the zero-overhead
//! property (measured in `cargo bench --bench fig5_nbody`).

/// One leaf's affine address rule: `blob[nr][base + lin * stride]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AffineLeaf {
    /// Blob the leaf's values live in.
    pub blob: usize,
    /// Byte offset of record 0's value.
    pub base: usize,
    /// Byte distance between consecutive records' values.
    pub stride: usize,
}

#[cfg(test)]
mod tests {
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, Heatmap, Mapping, One, SoA, Split, Trace};
    use crate::record::RecordCoord;

    /// Every Some(affine) must agree with blob_nr_and_offset everywhere.
    fn check_affine<M: Mapping>(m: &M) {
        let Some(leaves) = m.affine_leaves() else {
            return;
        };
        assert_eq!(leaves.len(), m.info().leaf_count());
        for lin in 0..m.dims().count() {
            let slot = m.slot_of_lin(lin);
            for (leaf, a) in leaves.iter().enumerate() {
                let want = m.blob_nr_and_offset(leaf, slot);
                assert_eq!(
                    (a.blob, a.base + lin * a.stride),
                    want,
                    "{} leaf {leaf} lin {lin}",
                    m.mapping_name()
                );
            }
        }
    }

    #[test]
    fn affine_agreement_all_mappings() {
        let d = particle_dim();
        let dims = ArrayDims::from([3, 5]);
        check_affine(&AoS::aligned(&d, dims.clone()));
        check_affine(&AoS::packed(&d, dims.clone()));
        check_affine(&SoA::multi_blob(&d, dims.clone()));
        check_affine(&SoA::single_blob(&d, dims.clone()));
        check_affine(&One::new(&d, dims.clone()));
        check_affine(&Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| SoA::multi_blob(sd, ad),
            |sd, ad| AoS::aligned(sd, ad),
        ));
    }

    #[test]
    fn non_affine_mappings_decline() {
        let d = particle_dim();
        let dims = ArrayDims::linear(8);
        assert!(AoSoA::new(&d, dims.clone(), 4).affine_leaves().is_none());
        assert!(Trace::new(AoS::packed(&d, dims.clone())).affine_leaves().is_none());
        assert!(Heatmap::new(AoS::packed(&d, dims.clone())).affine_leaves().is_none());
        assert!(Byteswap::new(AoS::packed(&d, dims.clone())).affine_leaves().is_none());
        // AoSoA with 1 lane degenerates to packed AoS: affine.
        assert!(AoSoA::new(&d, dims.clone(), 1).affine_leaves().is_some());
        check_affine(&AoSoA::new(&d, dims, 1));
    }

    #[test]
    fn morton_linearized_declines() {
        use crate::array::MortonCurve;
        let d = particle_dim();
        let m = AoS::with_linearizer(&d, ArrayDims::from([4, 4]), MortonCurve, false);
        assert!(m.affine_leaves().is_none());
    }
}
