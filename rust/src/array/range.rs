//! [`ArrayIndexRange`]: iterate all N-d indices of an [`ArrayDims`] in
//! row-major order — the paper's `ArrayDimsIndexRange` (§3.6, listing 7).

use super::dims::ArrayDims;

/// Iterator over every index tuple within the given array dimensions,
/// last dimension fastest: `{0,0}, {0,1}, ..., {2,2}`.
#[derive(Debug, Clone)]
pub struct ArrayIndexRange {
    dims: ArrayDims,
    next: Option<Vec<usize>>,
}

impl ArrayIndexRange {
    pub fn new(dims: ArrayDims) -> Self {
        let next = if dims.count() == 0 {
            None
        } else {
            Some(vec![0; dims.rank()])
        };
        ArrayIndexRange { dims, next }
    }
}

impl Iterator for ArrayIndexRange {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        let current = self.next.clone()?;
        // Advance like an odometer.
        let mut idx = current.clone();
        let mut done = true;
        for d in (0..self.dims.rank()).rev() {
            idx[d] += 1;
            if idx[d] < self.dims.0[d] {
                done = false;
                break;
            }
            idx[d] = 0;
        }
        self.next = if done { None } else { Some(idx) };
        Some(current)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        // Exact count is cheap to compute but not tracked incrementally;
        // provide the total as upper bound.
        (0, Some(self.dims.count()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterates_row_major_3x3() {
        let v: Vec<Vec<usize>> = ArrayIndexRange::new(ArrayDims::from([3, 3])).collect();
        assert_eq!(v.len(), 9);
        assert_eq!(v[0], vec![0, 0]);
        assert_eq!(v[1], vec![0, 1]);
        assert_eq!(v[3], vec![1, 0]);
        assert_eq!(v[8], vec![2, 2]);
    }

    #[test]
    fn one_dimensional() {
        let v: Vec<Vec<usize>> = ArrayIndexRange::new(ArrayDims::linear(4)).collect();
        assert_eq!(v, vec![vec![0], vec![1], vec![2], vec![3]]);
    }

    #[test]
    fn empty_extent_yields_nothing() {
        let v: Vec<Vec<usize>> = ArrayIndexRange::new(ArrayDims::from([3, 0])).collect();
        assert!(v.is_empty());
    }

    #[test]
    fn zero_rank_yields_single_empty_index() {
        let v: Vec<Vec<usize>> = ArrayIndexRange::new(ArrayDims::new(vec![])).collect();
        assert_eq!(v, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn matches_delinearize() {
        let dims = ArrayDims::from([2, 3, 4]);
        for (lin, idx) in ArrayIndexRange::new(dims.clone()).enumerate() {
            assert_eq!(idx, dims.delinearize_row_major(lin));
        }
    }
}
