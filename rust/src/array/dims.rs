//! [`ArrayDims`]: runtime extents of the data space's array part.

use std::fmt;

/// Extents of the N-dimensional array part of a data space, the paper's
/// `llama::ArrayDims<N>{128, 256, 32}`. N is dynamic here; construction
/// happens outside hot loops and mappings precompute whatever strides
/// they need.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArrayDims(pub Vec<usize>);

impl ArrayDims {
    pub fn new(extents: impl Into<Vec<usize>>) -> Self {
        ArrayDims(extents.into())
    }

    /// 1-D convenience constructor.
    pub fn linear(n: usize) -> Self {
        ArrayDims(vec![n])
    }

    /// Number of array dimensions (the paper's compile-time `N`).
    #[inline]
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total number of records = product of extents.
    #[inline]
    pub fn count(&self) -> usize {
        self.0.iter().product()
    }

    #[inline]
    pub fn extents(&self) -> &[usize] {
        &self.0
    }

    /// Row-major strides (in elements) for each dimension.
    pub fn row_major_strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.0[i + 1];
        }
        strides
    }

    /// Column-major strides (in elements) for each dimension.
    pub fn col_major_strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.rank()];
        for i in 1..self.rank() {
            strides[i] = strides[i - 1] * self.0[i - 1];
        }
        strides
    }

    /// True if `idx` is inside the extents.
    pub fn contains(&self, idx: &[usize]) -> bool {
        idx.len() == self.rank() && idx.iter().zip(&self.0).all(|(i, e)| i < e)
    }

    /// Invert a row-major linear index back to an N-d index.
    pub fn delinearize_row_major(&self, mut lin: usize) -> Vec<usize> {
        let mut idx = vec![0usize; self.rank()];
        for i in (0..self.rank()).rev() {
            idx[i] = lin % self.0[i];
            lin /= self.0[i];
        }
        idx
    }
}

impl fmt::Display for ArrayDims {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ArrayDims{:?}", self.0)
    }
}

impl From<Vec<usize>> for ArrayDims {
    fn from(v: Vec<usize>) -> Self {
        ArrayDims(v)
    }
}

impl<const N: usize> From<[usize; N]> for ArrayDims {
    fn from(v: [usize; N]) -> Self {
        ArrayDims(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_and_rank() {
        let d = ArrayDims::from([128, 256, 32]);
        assert_eq!(d.rank(), 3);
        assert_eq!(d.count(), 128 * 256 * 32);
        assert_eq!(ArrayDims::linear(42).count(), 42);
    }

    #[test]
    fn strides() {
        let d = ArrayDims::from([4, 5, 6]);
        assert_eq!(d.row_major_strides(), vec![30, 6, 1]);
        assert_eq!(d.col_major_strides(), vec![1, 4, 20]);
    }

    #[test]
    fn contains_bounds() {
        let d = ArrayDims::from([2, 3]);
        assert!(d.contains(&[1, 2]));
        assert!(!d.contains(&[2, 0]));
        assert!(!d.contains(&[0])); // wrong rank
    }

    #[test]
    fn delinearize_roundtrip() {
        let d = ArrayDims::from([3, 4, 5]);
        let strides = d.row_major_strides();
        for lin in 0..d.count() {
            let idx = d.delinearize_row_major(lin);
            let relin: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
            assert_eq!(relin, lin);
            assert!(d.contains(&idx));
        }
    }

    #[test]
    fn zero_rank() {
        let d = ArrayDims::new(vec![]);
        assert_eq!(d.count(), 1); // empty product: one record (scalar view)
    }
}
