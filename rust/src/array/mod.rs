//! The **array dimensions**: the runtime-sized part of LLAMA's data
//! space (paper §3.3). `ArrayDims` holds the extents; linearizers turn
//! an N-dimensional index into a flat element index (paper §2.3 storage
//! orders, incl. space-filling curves).

pub mod dims;
pub mod linearize;
pub mod range;

pub use dims::ArrayDims;
pub use linearize::{ColMajor, HilbertCurve2D, Linearizer, MortonCurve, RowMajor};
pub use range::ArrayIndexRange;
