//! Array-index linearizers: the paper's "any array linearization"
//! feature (Table 1) — row-major, column-major, and a Morton
//! space-filling curve (§2.3).

use super::dims::ArrayDims;

/// Strategy turning an N-d array index into a flat element index.
///
/// `prepare` is called once at mapping construction and may precompute
/// strides; `linearize` runs on the hot path.
pub trait Linearizer: Clone + Send + Sync + 'static {
    /// Precomputed state (strides etc.).
    type State: Clone + Send + Sync;

    fn prepare(&self, dims: &ArrayDims) -> Self::State;

    fn linearize(state: &Self::State, idx: &[usize]) -> usize;

    /// Total number of flat slots this linearizer addresses. Equals
    /// `dims.count()` for bijective orders; may be larger for padded
    /// curves (Morton rounds up to powers of two).
    fn slot_count(&self, dims: &ArrayDims) -> usize;

    fn name(&self) -> &'static str;
}

/// C order: last index fastest (the paper's default).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RowMajor;

impl Linearizer for RowMajor {
    type State = Vec<usize>;

    fn prepare(&self, dims: &ArrayDims) -> Vec<usize> {
        dims.row_major_strides()
    }

    #[inline]
    fn linearize(strides: &Vec<usize>, idx: &[usize]) -> usize {
        debug_assert_eq!(strides.len(), idx.len());
        idx.iter().zip(strides).map(|(i, s)| i * s).sum()
    }

    fn slot_count(&self, dims: &ArrayDims) -> usize {
        dims.count()
    }

    fn name(&self) -> &'static str {
        "row-major"
    }
}

/// Fortran order: first index fastest.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ColMajor;

impl Linearizer for ColMajor {
    type State = Vec<usize>;

    fn prepare(&self, dims: &ArrayDims) -> Vec<usize> {
        dims.col_major_strides()
    }

    #[inline]
    fn linearize(strides: &Vec<usize>, idx: &[usize]) -> usize {
        debug_assert_eq!(strides.len(), idx.len());
        idx.iter().zip(strides).map(|(i, s)| i * s).sum()
    }

    fn slot_count(&self, dims: &ArrayDims) -> usize {
        dims.count()
    }

    fn name(&self) -> &'static str {
        "col-major"
    }
}

/// Morton (Z-order) space-filling curve. Extents are rounded up to the
/// next power of two, so the addressed slot count may exceed
/// `dims.count()` (trading memory for locality, as in the paper's
/// space-filling-curve mappings).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MortonCurve;

/// Per-dimension bit widths after rounding up to powers of two.
#[derive(Debug, Clone)]
pub struct MortonState {
    bits: Vec<u32>,
}

impl Linearizer for MortonCurve {
    type State = MortonState;

    fn prepare(&self, dims: &ArrayDims) -> MortonState {
        MortonState {
            bits: dims
                .extents()
                .iter()
                .map(|&e| (e.max(1) as u64).next_power_of_two().trailing_zeros())
                .collect(),
        }
    }

    #[inline]
    fn linearize(state: &MortonState, idx: &[usize]) -> usize {
        // Interleave bits across dimensions, LSB first, skipping
        // dimensions that have run out of bits.
        let max_bits = state.bits.iter().copied().max().unwrap_or(0);
        let mut out: usize = 0;
        let mut shift = 0;
        for bit in 0..max_bits {
            for (d, &db) in state.bits.iter().enumerate() {
                if bit < db {
                    out |= ((idx[d] >> bit) & 1) << shift;
                    shift += 1;
                }
            }
        }
        out
    }

    fn slot_count(&self, dims: &ArrayDims) -> usize {
        dims.extents()
            .iter()
            .map(|&e| (e.max(1)).next_power_of_two())
            .product()
    }

    fn name(&self) -> &'static str {
        "morton"
    }
}


/// Hilbert space-filling curve for 2-D array dimensions (paper §2.3
/// cites Hilbert curves next to Morton codes). Better locality than
/// Morton (no long diagonal jumps); extents are rounded up to a common
/// power-of-two square.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HilbertCurve2D;

/// Side length (power of two) of the padded square.
#[derive(Debug, Clone)]
pub struct HilbertState {
    side: usize,
}

impl Linearizer for HilbertCurve2D {
    type State = HilbertState;

    fn prepare(&self, dims: &ArrayDims) -> HilbertState {
        assert_eq!(dims.rank(), 2, "HilbertCurve2D needs exactly 2 array dimensions");
        let side = dims.extents().iter().map(|&e| e.max(1).next_power_of_two()).max().unwrap();
        HilbertState { side }
    }

    #[inline]
    fn linearize(state: &HilbertState, idx: &[usize]) -> usize {
        // Classic x/y -> d conversion (Wikipedia "Hilbert curve",
        // iterative rot-and-flip).
        let n = state.side;
        let (mut x, mut y) = (idx[0], idx[1]);
        let mut rx: usize;
        let mut ry: usize;
        let mut d = 0usize;
        let mut s = n / 2;
        while s > 0 {
            rx = usize::from((x & s) > 0);
            ry = usize::from((y & s) > 0);
            d += s * s * ((3 * rx) ^ ry);
            // Rotate the quadrant.
            if ry == 0 {
                if rx == 1 {
                    x = s.wrapping_sub(1).wrapping_sub(x) & (n - 1);
                    y = s.wrapping_sub(1).wrapping_sub(y) & (n - 1);
                }
                std::mem::swap(&mut x, &mut y);
            }
            s /= 2;
        }
        d
    }

    fn slot_count(&self, dims: &ArrayDims) -> usize {
        let side = dims.extents().iter().map(|&e| e.max(1).next_power_of_two()).max().unwrap();
        side * side
    }

    fn name(&self) -> &'static str {
        "hilbert-2d"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_order() {
        let d = ArrayDims::from([2, 3]);
        let st = RowMajor.prepare(&d);
        let lins: Vec<usize> = [[0, 0], [0, 1], [0, 2], [1, 0], [1, 1], [1, 2]]
            .iter()
            .map(|i| RowMajor::linearize(&st, i))
            .collect();
        assert_eq!(lins, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn col_major_order() {
        let d = ArrayDims::from([2, 3]);
        let st = ColMajor.prepare(&d);
        assert_eq!(ColMajor::linearize(&st, &[1, 0]), 1);
        assert_eq!(ColMajor::linearize(&st, &[0, 1]), 2);
        assert_eq!(ColMajor::linearize(&st, &[1, 2]), 5);
    }

    #[test]
    fn morton_2d_square() {
        let d = ArrayDims::from([4, 4]);
        let st = MortonCurve.prepare(&d);
        // Classic Z-order: (0,0)=0 (0,1)=1 (1,0)=2 (1,1)=3 (0,2)=4 ...
        // Note: our interleave puts dim 0's bit first (LSB), so
        // (y,x) pairs follow dim-order. Verify bijectivity + range.
        let mut seen = std::collections::HashSet::new();
        for a in 0..4 {
            for b in 0..4 {
                let l = MortonCurve::linearize(&st, &[a, b]);
                assert!(l < 16);
                assert!(seen.insert(l), "collision at ({a},{b})");
            }
        }
    }

    #[test]
    fn morton_non_pow2_is_injective() {
        let d = ArrayDims::from([3, 5]);
        let st = MortonCurve.prepare(&d);
        let cap = MortonCurve.slot_count(&d);
        assert_eq!(cap, 4 * 8);
        let mut seen = std::collections::HashSet::new();
        for a in 0..3 {
            for b in 0..5 {
                let l = MortonCurve::linearize(&st, &[a, b]);
                assert!(l < cap);
                assert!(seen.insert(l));
            }
        }
    }

    #[test]
    fn hilbert_2d_is_bijective_and_adjacent() {
        let d = ArrayDims::from([8, 8]);
        let st = HilbertCurve2D.prepare(&d);
        let mut seen = std::collections::HashSet::new();
        for x in 0..8 {
            for y in 0..8 {
                let l = HilbertCurve2D::linearize(&st, &[x, y]);
                assert!(l < 64);
                assert!(seen.insert(l), "collision at ({x},{y})");
            }
        }
        // The defining property: consecutive d values are grid
        // neighbours (Manhattan distance 1).
        let mut by_d = vec![(0usize, 0usize); 64];
        for x in 0..8 {
            for y in 0..8 {
                by_d[HilbertCurve2D::linearize(&st, &[x, y])] = (x, y);
            }
        }
        for w in by_d.windows(2) {
            let dist = w[0].0.abs_diff(w[1].0) + w[0].1.abs_diff(w[1].1);
            assert_eq!(dist, 1, "jump between {:?} and {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn hilbert_non_square_pads() {
        let d = ArrayDims::from([3, 6]);
        let st = HilbertCurve2D.prepare(&d);
        assert_eq!(HilbertCurve2D.slot_count(&d), 64);
        let mut seen = std::collections::HashSet::new();
        for x in 0..3 {
            for y in 0..6 {
                assert!(seen.insert(HilbertCurve2D::linearize(&st, &[x, y])));
            }
        }
    }

    #[test]
    fn all_linearizers_injective_3d() {
        let d = ArrayDims::from([3, 4, 2]);
        fn check<L: Linearizer>(lz: L, d: &ArrayDims) {
            let st = lz.prepare(d);
            let cap = lz.slot_count(d);
            let mut seen = std::collections::HashSet::new();
            for a in 0..3 {
                for b in 0..4 {
                    for c in 0..2 {
                        let l = L::linearize(&st, &[a, b, c]);
                        assert!(l < cap, "{} out of range", lz.name());
                        assert!(seen.insert(l), "{} collides", lz.name());
                    }
                }
            }
        }
        check(RowMajor, &d);
        check(ColMajor, &d);
        check(MortonCurve, &d);
    }
}
