//! Fig 10 driver: PIConGPU-style particle-frame sweep across attribute
//! layouts.
//!
//! Paper's expected shape (V100): LLAMA SoA ≈ the hand-tuned baseline,
//! AoSoA32 a hair faster (warp-width locality), AoS ~10% slower (no
//! coalescing). On CPU the analogous effect is cache-line utilization
//! of the drift sweep: SoA/AoSoA beat AoS.

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_ms, fmt_ratio, Table};
use crate::array::ArrayDims;
use crate::mapping::{AoS, AoSoA, Mapping, SoA};
use crate::workloads::picframe::frames::ParticleStore;
use crate::workloads::picframe::{attr_dim, FRAME_SIZE};

fn run_case<M: Mapping + Clone>(
    name: &str,
    proto: M,
    grid: [usize; 3],
    per_cell: usize,
    steps: usize,
    o: &Opts,
    rows: &mut Vec<(String, f64)>,
) {
    // The frame arena draws from a blob pool (layer 0): frames freed
    // by `exchange` recycle into the frames `push` allocates.
    let pool = crate::blob::BlobPool::new();
    let mut store = ParticleStore::with_allocator(proto, grid, pool);
    store.populate(per_cell, 99);
    let total = store.particle_count();
    let r = bench(name, 1, o.iters, || {
        for _ in 0..steps {
            store.drift(0.05);
            black_box(store.deposit());
            store.exchange();
        }
    });
    store.check_invariants().expect("frame invariants");
    assert_eq!(store.particle_count(), total, "{name}: lost particles");
    rows.push((name.to_string(), r.median_ns));
}

/// Run fig 10: drift + deposit + exchange sweep per attribute layout.
pub fn run(o: &Opts) -> Table {
    let grid = if o.quick { [3, 3, 3] } else { [6, 6, 6] };
    let per_cell = o.n.unwrap_or(if o.quick { 300 } else { 2000 });
    let steps = if o.quick { 2 } else { 4 };
    let d = attr_dim();
    let dims = ArrayDims::linear(FRAME_SIZE);
    let mut rows: Vec<(String, f64)> = Vec::new();

    // The paper's baseline data structure is SoA frames.
    run_case(
        "SoA (baseline)",
        SoA::multi_blob(&d, dims.clone()),
        grid,
        per_cell,
        steps,
        o,
        &mut rows,
    );
    run_case("SoA SB", SoA::single_blob(&d, dims.clone()), grid, per_cell, steps, o, &mut rows);
    for lanes in [8usize, 16, 32, 64, 128] {
        run_case(
            &format!("AoSoA{lanes}"),
            AoSoA::new(&d, dims.clone(), lanes),
            grid,
            per_cell,
            steps,
            o,
            &mut rows,
        );
    }
    run_case("AoS", AoS::aligned(&d, dims.clone()), grid, per_cell, steps, o, &mut rows);

    // The fig 9 layout-exchange path: one compiled CopyProgram replayed
    // over every frame of the store (SoA -> AoSoA32 and back).
    {
        let pool = crate::blob::BlobPool::new();
        let mut st =
            ParticleStore::with_allocator(SoA::multi_blob(&d, dims.clone()), grid, pool);
        st.populate(per_cell, 99);
        let total = st.particle_count();
        let r = bench("reshuffle", 1, o.iters, || {
            let aosoa = st.reshuffle(AoSoA::new(&d, dims.clone(), 32));
            black_box(aosoa.particle_count());
            st = aosoa.reshuffle(SoA::multi_blob(&d, dims.clone()));
        });
        st.check_invariants().expect("frame invariants after reshuffle");
        assert_eq!(st.particle_count(), total, "reshuffle lost particles");
        rows.push(("reshuffle SoA<->AoSoA32 (program)".to_string(), r.median_ns));
    }

    let mut t = Table::new(
        format!(
            "fig10 picframe (grid {grid:?}, {per_cell}/cell, {steps} steps of drift+deposit+exchange)"
        ),
        &["frame layout", "ms", "vs SoA baseline"],
    );
    let base = rows[0].1;
    for (name, ns) in rows {
        t.row(vec![name, fmt_ms(ns), fmt_ratio(ns, base)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_has_all_layouts() {
        let mut o = Opts::quick();
        o.n = Some(64);
        o.iters = 1;
        let t = run(&o);
        assert_eq!(t.rows.len(), 9);
        let txt = t.to_text();
        assert!(txt.contains("AoSoA32"));
        assert!(txt.contains("SoA (baseline)"));
        assert!(txt.contains("reshuffle SoA<->AoSoA32 (program)"));
        assert_eq!(t.rows[0][2], "1.000");
    }
}
