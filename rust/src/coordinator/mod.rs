//! L3 coordinator: benchmark drivers that regenerate every table and
//! figure of the paper's evaluation (§4), a self-contained bench
//! harness (criterion is not in the vendored crate set), table/CSV
//! reporting, and the CLI.
//!
//! Each `figN` module owns one paper figure and exposes `run(&Opts) ->
//! Vec<Row>`; the `cargo bench` targets and the `llama` CLI both call
//! into these, so the numbers in EXPERIMENTS.md are reproducible from
//! either entry point.

pub mod bench;
pub mod bench_adapt;
pub mod bench_alloc;
pub mod bench_serve;
pub mod bench_wire;
pub mod cli;
pub mod fig10_picframe;
pub mod halo;
pub mod fig5_nbody;
pub mod fig6_xla;
pub mod fig7_copy;
pub mod fig8_lbm;
pub mod report;
pub mod wire_demo;
pub mod wire_net;

pub use bench::{bench, BenchResult};
pub use report::Table;
