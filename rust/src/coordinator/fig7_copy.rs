//! Fig 7 driver: layout-changing copy throughput.
//!
//! Paper's expected shape: the layout-aware `aosoa_copy` beats the
//! field-wise naive/std::copy on AoSoA/SoA-MB pairs; parallel
//! aosoa_copy is best overall; (multi-threaded) memcpy is the roofline.

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_gib, Table};
use crate::array::ArrayDims;
use crate::copy::program::{execute_parallel, shard_programs};
use crate::copy::{
    aosoa_copy, aosoa_compatible, copy_aosoa_parallel, copy_naive, copy_naive_parallel,
    copy_stdcopy, views_equal, ChunkOrder, CopyOp, CopyProgram,
};
use crate::mapping::{total_blob_bytes, AoS, AoSoA, Mapping, SoA};
use crate::view::simd::{detect, simd_compiled, SimdPath};
use crate::view::{alloc_view, View};
use crate::workloads::hep;
use crate::workloads::nbody;

/// Total bytes of a view's blobs (what a copy moves).
fn view_bytes<M: Mapping>(m: &M) -> usize {
    total_blob_bytes(m)
}

/// memcpy reference: flat byte copy of the same volume.
fn memcpy_ref(name: &str, bytes: usize, threads: usize, o: &Opts, t: &mut Table) {
    let src = vec![0xA5u8; bytes];
    let mut dst = vec![0u8; bytes];
    let r = bench(name, 1, o.iters, || {
        if threads <= 1 {
            dst.copy_from_slice(&src);
        } else {
            let chunk = bytes.div_ceil(threads);
            std::thread::scope(|scope| {
                for (d, s) in dst.chunks_mut(chunk).zip(src.chunks(chunk)) {
                    scope.spawn(move || d.copy_from_slice(s));
                }
            });
        }
        black_box(&dst);
    });
    t.row(vec![name.to_string(), format!("{:.3}", r.median_ms()), fmt_gib(r.gib_per_s(bytes))]);
}

/// Bench every copy strategy for one (src mapping, dst mapping) pair.
fn strategies<MS, MD>(
    label: &str,
    src_m: MS,
    dst_m: MD,
    fill: impl Fn(&mut View<MS, Vec<u8>>),
    o: &Opts,
    t: &mut Table,
) where
    MS: Mapping + Sync + Clone,
    MD: Mapping + Sync + Clone,
{
    let bytes = view_bytes(&src_m);
    let chunkable = aosoa_compatible(&src_m, &dst_m);
    let mut src = alloc_view(src_m);
    fill(&mut src);
    let mut dst = alloc_view(dst_m);
    let threads = o.threads();
    // Compile once, replay every iteration — the program rows measure
    // exactly the amortization the compiler exists for.
    let prog = CopyProgram::compile(src.mapping(), dst.mapping());
    let shard_progs = shard_programs(src.mapping(), dst.mapping(), threads);

    let mut case = |name: &str, f: &mut dyn FnMut(&View<MS, Vec<u8>>, &mut View<MD, Vec<u8>>)| {
        let r = bench(name, 1, o.iters, || {
            f(&src, &mut dst);
            black_box(dst.blobs());
        });
        // Verify the copy really happened (once, after timing).
        assert!(views_equal(&src, &dst), "{label}/{name}: wrong copy");
        t.row(vec![
            format!("{label}: {name}"),
            format!("{:.3}", r.median_ms()),
            fmt_gib(r.gib_per_s(bytes)),
        ]);
    };

    case("naive", &mut |s, d| copy_naive(s, d));
    case("naive (p)", &mut |s, d| copy_naive_parallel(s, d, Some(threads)));
    case("std::copy", &mut |s, d| copy_stdcopy(s, d));
    if chunkable {
        case("aosoa_copy (r)", &mut |s, d| aosoa_copy(s, d, ChunkOrder::ReadContiguous));
        case("aosoa_copy (w)", &mut |s, d| aosoa_copy(s, d, ChunkOrder::WriteContiguous));
        case("aosoa_copy (r,p)", &mut |s, d| {
            copy_aosoa_parallel(s, d, ChunkOrder::ReadContiguous, Some(threads))
        });
        case("aosoa_copy (w,p)", &mut |s, d| {
            copy_aosoa_parallel(s, d, ChunkOrder::WriteContiguous, Some(threads))
        });
    }
    // The compiled CopyProgram: chunk intersections derived once
    // outside the timed loop (every pair compiles — chunked, strided or
    // gather), then replayed per iteration; (p) replays one
    // sub-program per plan-aligned shard on scoped threads.
    case("program", &mut |s, d| prog.execute(s, d));
    case("program (p)", &mut |s, d| execute_parallel(&shard_progs, s, d));
    // Scalar-vs-SIMD rows exist only where the program actually
    // compiled a StridedRun (the one op kind with a vector gather
    // path); memcpy-only programs would just measure the same code
    // twice. The row name records the dispatched path so the baseline
    // is auditable.
    if prog.ops().iter().any(|op| matches!(op, CopyOp::StridedRun { .. })) {
        let spath = detect();
        case(&format!("program (simd: {})", spath.name()), &mut |s, d| {
            prog.execute_with_path(s, d, spath)
        });
        case("program (scalar)", &mut |s, d| {
            prog.execute_with_path(s, d, SimdPath::Scalar)
        });
    }
}

/// Run fig 7: particle (7 floats) and HEP event (100 fields) copies.
pub fn run(o: &Opts) -> Table {
    let n_particles = o.n.unwrap_or(if o.quick { 1 << 16 } else { 1 << 21 });
    let n_events = if o.quick { 1 << 12 } else { 1 << 16 };
    let mut t = Table::new(
        format!("fig7 layout-changing copy (particles N={n_particles}, events N={n_events})"),
        &["case", "ms", "GiB/s"],
    );

    // --- 7-float particles ---
    let pd = nbody::particle_dim();
    let dims = ArrayDims::linear(n_particles);
    let fill_p = |v: &mut View<SoA, Vec<u8>>| {
        let s = nbody::init_particles(v.count(), 7);
        crate::workloads::nbody::llama_impl::load_state(v, &s);
    };
    strategies(
        "particle SoA MB -> AoSoA32",
        SoA::multi_blob(&pd, dims.clone()),
        AoSoA::new(&pd, dims.clone(), 32),
        fill_p,
        o,
        &mut t,
    );
    strategies(
        "particle AoSoA8 -> AoSoA32",
        AoSoA::new(&pd, dims.clone(), 8),
        AoSoA::new(&pd, dims.clone(), 32),
        |v| {
            let s = nbody::init_particles(v.count(), 7);
            crate::workloads::nbody::llama_impl::load_state(v, &s);
        },
        o,
        &mut t,
    );
    strategies(
        "particle AoS -> SoA MB",
        AoS::packed(&pd, dims.clone()),
        SoA::multi_blob(&pd, dims.clone()),
        |v| {
            let s = nbody::init_particles(v.count(), 7);
            crate::workloads::nbody::llama_impl::load_state(v, &s);
        },
        o,
        &mut t,
    );
    // Aligned AoS defeats chunking (inter-field padding means a record
    // is not one dense span), so this pair compiles to per-leaf
    // StridedRuns — the gather-executed Program rows (scalar vs simd).
    strategies(
        "particle AoS (aligned) -> SoA MB",
        AoS::aligned(&pd, dims.clone()),
        SoA::multi_blob(&pd, dims.clone()),
        |v| {
            let s = nbody::init_particles(v.count(), 7);
            crate::workloads::nbody::llama_impl::load_state(v, &s);
        },
        o,
        &mut t,
    );
    memcpy_ref("particle memcpy", view_bytes(&SoA::multi_blob(&pd, dims.clone())), 1, o, &mut t);
    memcpy_ref(
        "particle memcpy (p)",
        view_bytes(&SoA::multi_blob(&pd, dims)),
        o.threads(),
        o,
        &mut t,
    );

    // --- 100-field HEP events ---
    let ed = hep::event_dim();
    let dims = ArrayDims::linear(n_events);
    strategies(
        "event SoA MB -> AoSoA32",
        SoA::multi_blob(&ed, dims.clone()),
        AoSoA::new(&ed, dims.clone(), 32),
        |v| hep::generate_events(v, 11),
        o,
        &mut t,
    );
    strategies(
        "event AoS -> SoA MB",
        AoS::packed(&ed, dims.clone()),
        SoA::multi_blob(&ed, dims.clone()),
        |v| hep::generate_events(v, 12),
        o,
        &mut t,
    );
    memcpy_ref("event memcpy", view_bytes(&SoA::multi_blob(&ed, dims.clone())), 1, o, &mut t);
    memcpy_ref("event memcpy (p)", view_bytes(&SoA::multi_blob(&ed, dims)), o.threads(), o, &mut t);
    t
}

/// Returns the subset of `run` used by regression tests: confirms the
/// chunked copy and the precompiled program beat the naive copy for
/// the canonical pair. Returns `(naive, chunked, program)` median ns.
pub fn headline(o: &Opts) -> (f64, f64, f64) {
    let n = o.n.unwrap_or(1 << 16);
    let pd = nbody::particle_dim();
    let dims = ArrayDims::linear(n);
    let mut src = alloc_view(SoA::multi_blob(&pd, dims.clone()));
    let s = nbody::init_particles(n, 7);
    crate::workloads::nbody::llama_impl::load_state(&mut src, &s);
    let mut dst = alloc_view(AoSoA::new(&pd, dims, 32));
    let naive = bench("naive", 1, o.iters, || {
        copy_naive(&src, &mut dst);
        black_box(dst.blobs());
    });
    let chunked = bench("aosoa", 1, o.iters, || {
        aosoa_copy(&src, &mut dst, ChunkOrder::ReadContiguous);
        black_box(dst.blobs());
    });
    let prog = CopyProgram::compile(src.mapping(), dst.mapping());
    let program = bench("program", 1, o.iters, || {
        prog.execute(&src, &mut dst);
        black_box(dst.blobs());
    });
    (naive.median_ns, chunked.median_ns, program.median_ns)
}

/// Serialize a fig 7 run as the `BENCH_fig7.json` baseline document
/// (regenerate with `cargo run --release -- bench-fig7`; CI's
/// bench-fig7 smoke step regenerates + schema-checks it in quick
/// mode). Refuses structurally to write a baseline with an empty table
/// or without the program-path rows — those mean a broken run.
pub fn baseline_json_checked(o: &Opts) -> crate::error::Result<String> {
    // Refuse to record a "simd" baseline that silently dispatched to
    // scalar on a SIMD-capable build — that mislabels the whole column.
    // LLAMA_SIMD=scalar is the explicit escape hatch for a deliberate
    // scalar baseline.
    if simd_compiled() {
        crate::ensure!(
            detect().is_vector() || std::env::var("LLAMA_SIMD").is_ok(),
            "bench-fig7: built with `--features simd` but dispatch fell back to scalar \
             on this host; set LLAMA_SIMD=scalar to record a scalar baseline deliberately"
        );
    }
    let t = run(o);
    crate::ensure!(!t.rows.is_empty(), "bench-fig7: table produced no rows");
    crate::ensure!(
        t.rows.iter().any(|r| r[0].contains("program")),
        "bench-fig7: no program rows — copy path not routed through CopyProgram"
    );
    crate::ensure!(
        t.rows.iter().any(|r| r[0].contains("(simd: ")),
        "bench-fig7: no scalar-vs-simd rows — the strided pair is missing"
    );
    Ok(format!(
        "{{\n  \"figure\": \"fig7_copy\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"ms (median) / GiB per s\",\n  \
         \"simd\": {{ \"compiled\": {}, \"path\": \"{}\" }},\n  \"copy\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        simd_compiled(),
        detect().name(),
        t.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table_has_all_strategy_rows() {
        let mut o = Opts::quick();
        o.n = Some(1 << 12);
        o.iters = 1;
        let t = run(&o);
        let txt = t.to_text();
        assert!(txt.contains("aosoa_copy (r)"));
        assert!(txt.contains("naive (p)"));
        assert!(txt.contains("program"));
        assert!(txt.contains("program (p)"));
        assert!(txt.contains("particle memcpy (p)"));
        assert!(txt.contains("event AoS -> SoA MB"));
        // The aligned-AoS pair is the strided (non-chunkable) one: it
        // carries the scalar-vs-simd Program rows, and the simd row
        // records the dispatched path in its name.
        assert!(txt.contains("particle AoS (aligned) -> SoA MB"));
        assert!(txt.contains(&format!("program (simd: {})", detect().name())));
        assert!(txt.contains("program (scalar)"));
        // The 5 packed pairs are chunkable (packed AoS = 1 lane) with 9
        // strategy rows each; the aligned pair adds 5 base rows plus
        // the 2 path rows; 4 memcpy rows close the table.
        assert!(t.rows.len() >= 3 * 9 + 4 + 4 + 7);
    }

    #[test]
    fn chunked_and_program_copies_not_slower_than_naive() {
        let mut o = Opts::quick();
        o.n = Some(1 << 15);
        o.iters = 3;
        let (naive, chunked, program) = headline(&o);
        assert!(
            chunked < naive * 1.2,
            "aosoa_copy ({chunked} ns) should not lose to naive ({naive} ns)"
        );
        assert!(
            program < naive * 1.2,
            "precompiled program ({program} ns) should not lose to naive ({naive} ns)"
        );
    }

    #[test]
    fn baseline_json_carries_the_copy_table() {
        let mut o = Opts::quick();
        o.n = Some(1 << 10);
        o.iters = 1;
        let j = baseline_json_checked(&o).expect("populated run passes the gates");
        assert!(j.contains("\"figure\": \"fig7_copy\""), "{j}");
        assert!(j.contains("\"copy\": {"), "{j}");
        assert!(j.contains("program (p)"), "{j}");
        assert!(j.contains("\"simd\": {"), "{j}");
        assert!(j.contains("\"compiled\": "), "{j}");
        assert!(j.contains("\"path\": \""), "{j}");
        assert!(j.contains("(simd: "), "{j}");
        assert!(!j.contains("\"rows\": []"), "empty table in {j}");
    }
}
