//! `bench-serve` driver: concurrent serving engines under mixed traffic
//! with drifting hot fields, across many stores (EXPERIMENTS.md §Serve).
//!
//! Three engines serve the same request stream over the same fleet of
//! stores:
//!
//! * **adaptive-serving** — [`ServingEngine`] stores under an
//!   [`AdvisorPool`] budget: read requests pin a published generation
//!   (O(1), never traced), while sampling, publishing and budgeted
//!   migration run as maintenance *between* requests. Maintenance cost
//!   lands in throughput (wall clock), never in request latency.
//! * **stop-the-world** — a bare [`AdaptiveView`] per store: every read
//!   request steps the engine directly, so requests pay for tracing
//!   during sampling epochs and the unlucky request at an epoch end
//!   pays for the whole migration copy — the classic fat tail.
//! * **best-static** — a plain [`View`] per store in the best fixed
//!   layout (fastest of AoS/SoA/AoSoA over the full stream): no
//!   sampling, no migration, but also no adaptation as the hot fields
//!   drift.
//!
//! The table reports throughput (`req_per_s`, includes maintenance)
//! and request-latency percentiles (`p50_us` / `p99_us`, service time
//! only). Traffic is mixed: every [`Sizes::write_every`]-th request is
//! a point write; the rest are analytic scan queries whose hot fields
//! drift every maintenance interval (the hep window advances one
//! object; picframe alternates drift sweeps with deposits).

use std::time::Instant;

use super::bench::{black_box, Opts};
use super::report::Table;
use crate::array::ArrayDims;
use crate::blob::{BlobMut, BlobPool};
use crate::mapping::{AoS, AoSoA, Mapping, SoA};
use crate::record::RecordInfo;
use crate::view::adapt::{AdaptiveConfig, AdaptiveKernel, AdaptiveView};
use crate::view::serve::{AdvisorPool, ServingEngine};
use crate::view::{alloc_view_with, View};
use crate::workloads::{hep, picframe};

/// Problem sizes per workload (quick = CI smoke).
struct Sizes {
    /// Stores per fleet (each engine serves this many).
    stores: usize,
    /// Records per hep store.
    hep_n: usize,
    /// Records per picframe store.
    pic_n: usize,
    /// Requests per engine run.
    requests: usize,
    /// Requests between maintenance intervals (sampling + publish +
    /// budget cycle; the hot set drifts here too).
    epoch_every: usize,
    /// Every k-th request is a point write (mixed traffic).
    write_every: usize,
    /// Migration budget per [`AdvisorPool::cycle`].
    budget: usize,
    /// Objects per hep window query.
    window: usize,
}

fn sizes(o: &Opts) -> Sizes {
    if o.quick {
        Sizes {
            stores: 4,
            hep_n: o.n.unwrap_or(1 << 10),
            pic_n: o.n.unwrap_or(picframe::FRAME_SIZE * 4),
            requests: 240,
            epoch_every: 30,
            write_every: 7,
            budget: 1,
            window: 4,
        }
    } else {
        Sizes {
            stores: 8,
            hep_n: o.n.unwrap_or(1 << 13),
            pic_n: o.n.unwrap_or(picframe::FRAME_SIZE * 32),
            requests: 2400,
            epoch_every: 120,
            write_every: 7,
            budget: 2,
            window: 4,
        }
    }
}

/// Engine defaults for the serving runs: short steady phases so the
/// engines keep re-sampling as the hot fields drift.
fn serve_cfg() -> AdaptiveConfig {
    AdaptiveConfig { steady_steps: 4, ..Default::default() }
}

/// Nearest-rank percentile over pre-sorted samples.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One engine run's measurements.
struct RunStats {
    layout: String,
    elapsed_s: f64,
    lat_ns: Vec<f64>,
    migrations: usize,
}

fn push_row(t: &mut Table, workload: &str, engine: &str, s: &Sizes, r: RunStats) {
    let mut lat = r.lat_ns;
    lat.sort_by(|a, b| a.total_cmp(b));
    t.row(vec![
        workload.to_string(),
        engine.to_string(),
        r.layout,
        s.stores.to_string(),
        format!("{:.0}", s.requests as f64 / r.elapsed_s),
        format!("{:.1}", percentile(&lat, 0.50) / 1e3),
        format!("{:.1}", percentile(&lat, 0.99) / 1e3),
        r.migrations.to_string(),
    ]);
}

// ---- hep: drifting window queries over event stores ----

/// Fresh window-query kernel pinned to the driver's current window
/// (`steps_per_window: 0` — the *driver* drifts the windows, identically
/// for every engine).
fn window_kernel(s: &Sizes, obj_lo: usize) -> hep::AdaptiveWindow {
    hep::AdaptiveWindow {
        obj_lo,
        width: s.window,
        min_quality: 128,
        steps_per_window: 0,
        step: 0,
        total: 0.0,
    }
}

fn hep_energy_leaves() -> Vec<usize> {
    let info = RecordInfo::new(&hep::event_dim());
    (0..20)
        .map(|obj| info.leaf_by_path(&format!("obj{obj}_energy")).expect("energy leaf"))
        .collect()
}

fn hep_adaptive_serving(s: &Sizes) -> RunStats {
    let d = hep::event_dim();
    let dims = ArrayDims::linear(s.hep_n);
    let blobs = BlobPool::new();
    let mut pool = AdvisorPool::<BlobPool>::new(s.budget);
    for k in 0..s.stores {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), blobs.clone());
        hep::generate_events(&mut v, 40 + k as u64);
        pool.add(ServingEngine::with_recycler(v, serve_cfg(), blobs.clone()));
    }
    let energy = hep_energy_leaves();
    let mut windows: Vec<usize> = (0..s.stores).map(|k| k % 20).collect();
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let eng = pool.store(store);
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            eng.write::<f32>(r % s.hep_n, energy[windows[store]], 123.0);
        } else {
            let g = eng.pin();
            total += hep::energy_window(g.view(), windows[store], s.window, 128);
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            // Maintenance, off the request-latency path: sample the
            // head with representative traffic, publish, then let the
            // budget pick the fleet's best parked migrations.
            for (k, eng) in pool.stores().iter().enumerate() {
                let mut kernel = window_kernel(s, windows[k]);
                eng.update(&mut kernel);
                eng.publish();
            }
            pool.cycle();
            for w in &mut windows {
                *w = (*w + 1) % 20;
            }
        }
    }
    black_box(total);
    RunStats {
        layout: pool.store(0).mapping_name(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: pool.stores().iter().map(|e| e.migrations()).sum(),
    }
}

fn hep_stop_the_world(s: &Sizes) -> RunStats {
    let d = hep::event_dim();
    let dims = ArrayDims::linear(s.hep_n);
    let blobs = BlobPool::new();
    let mut stores: Vec<AdaptiveView<BlobPool>> = (0..s.stores)
        .map(|k| {
            let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), blobs.clone());
            hep::generate_events(&mut v, 40 + k as u64);
            AdaptiveView::with_recycler(v, serve_cfg(), blobs.clone())
        })
        .collect();
    let energy = hep_energy_leaves();
    let mut windows: Vec<usize> = (0..s.stores).map(|k| k % 20).collect();
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            stores[store].set::<f32>(r % s.hep_n, energy[windows[store]], 123.0);
        } else {
            // The request *is* an engine step: it pays tracing in
            // sampling epochs and the migration copy at epoch ends.
            let mut kernel = window_kernel(s, windows[store]);
            stores[store].step(&mut kernel);
            total += kernel.total;
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            for w in &mut windows {
                *w = (*w + 1) % 20;
            }
        }
    }
    black_box(total);
    RunStats {
        layout: stores[0].mapping_name(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: stores.iter().map(|a| a.migrations()).sum(),
    }
}

fn hep_static<M: Mapping + Clone>(mapping: M, s: &Sizes) -> RunStats {
    let blobs = BlobPool::new();
    let name = mapping.mapping_name();
    let mut stores: Vec<View<M, _>> = (0..s.stores)
        .map(|k| {
            let mut v = alloc_view_with(mapping.clone(), blobs.clone());
            hep::generate_events(&mut v, 40 + k as u64);
            v
        })
        .collect();
    let energy = hep_energy_leaves();
    let mut windows: Vec<usize> = (0..s.stores).map(|k| k % 20).collect();
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            stores[store].set::<f32>(r % s.hep_n, energy[windows[store]], 123.0);
        } else {
            total += hep::energy_window(&stores[store], windows[store], s.window, 128);
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            for w in &mut windows {
                *w = (*w + 1) % 20;
            }
        }
    }
    black_box(total);
    RunStats {
        layout: name,
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: 0,
    }
}

fn hep_case(s: &Sizes, t: &mut Table) {
    let d = hep::event_dim();
    let dims = ArrayDims::linear(s.hep_n);
    push_row(t, "hep", "adaptive-serving", s, hep_adaptive_serving(s));
    push_row(t, "hep", "stop-the-world", s, hep_stop_the_world(s));
    let statics = vec![
        hep_static(AoS::aligned(&d, dims.clone()), s),
        hep_static(SoA::multi_blob(&d, dims.clone()), s),
        hep_static(AoSoA::new(&d, dims.clone(), 16), s),
    ];
    let best = statics
        .into_iter()
        .min_by(|a, b| a.elapsed_s.total_cmp(&b.elapsed_s))
        .expect("static candidates");
    push_row(t, "hep", "best-static", s, best);
}

// ---- picframe: deposits interleaved with drift sweeps ----

/// The read-only charge-deposit request as an adaptive-engine kernel.
struct DepositReq {
    filled: usize,
    total: f64,
}

impl AdaptiveKernel for DepositReq {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        self.total += picframe::frames::deposit_view(view, self.filled);
    }
}

fn fill_attrs<M: Mapping, B: BlobMut>(v: &mut View<M, B>, seed: u64) {
    use crate::workloads::rng::SplitMix64;
    let mut rng = SplitMix64::new(seed);
    for lin in 0..v.count() {
        for leaf in [picframe::POS_X, picframe::POS_Y, picframe::POS_Z] {
            v.set::<f32>(lin, leaf, rng.next_f32());
        }
        for leaf in [picframe::MOM_X, picframe::MOM_Y, picframe::MOM_Z] {
            v.set::<f32>(lin, leaf, rng.range_f32(-0.3, 0.3));
        }
        v.set::<f32>(lin, picframe::WEIGHTING, rng.range_f32(0.5, 1.5));
        v.set::<i32>(lin, picframe::CELL_IDX, rng.below(picframe::FRAME_SIZE) as i32);
    }
}

fn pic_adaptive_serving(s: &Sizes) -> RunStats {
    let d = picframe::attr_dim();
    let dims = ArrayDims::linear(s.pic_n);
    let blobs = BlobPool::new();
    let mut pool = AdvisorPool::<BlobPool>::new(s.budget);
    for k in 0..s.stores {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), blobs.clone());
        fill_attrs(&mut v, 60 + k as u64);
        pool.add(ServingEngine::with_recycler(v, serve_cfg(), blobs.clone()));
    }
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let eng = pool.store(store);
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            eng.write::<f32>(r % s.pic_n, picframe::WEIGHTING, 2.0);
        } else {
            let g = eng.pin();
            total += picframe::frames::deposit_view(g.view(), s.pic_n);
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            // The hot set alternates between the deposit's weighting
            // read and the drift sweep's pos+mom traffic.
            for eng in pool.stores() {
                eng.update(&mut DepositReq { filled: s.pic_n, total: 0.0 });
                eng.update(&mut picframe::frames::AdaptiveDrift { dt: 0.05 });
                eng.publish();
            }
            pool.cycle();
        }
    }
    black_box(total);
    RunStats {
        layout: pool.store(0).mapping_name(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: pool.stores().iter().map(|e| e.migrations()).sum(),
    }
}

fn pic_stop_the_world(s: &Sizes) -> RunStats {
    let d = picframe::attr_dim();
    let dims = ArrayDims::linear(s.pic_n);
    let blobs = BlobPool::new();
    let mut stores: Vec<AdaptiveView<BlobPool>> = (0..s.stores)
        .map(|k| {
            let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), blobs.clone());
            fill_attrs(&mut v, 60 + k as u64);
            AdaptiveView::with_recycler(v, serve_cfg(), blobs.clone())
        })
        .collect();
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            stores[store].set::<f32>(r % s.pic_n, picframe::WEIGHTING, 2.0);
        } else {
            let mut kernel = DepositReq { filled: s.pic_n, total: 0.0 };
            stores[store].step(&mut kernel);
            total += kernel.total;
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            for av in &mut stores {
                av.step(&mut picframe::frames::AdaptiveDrift { dt: 0.05 });
            }
        }
    }
    black_box(total);
    RunStats {
        layout: stores[0].mapping_name(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: stores.iter().map(|a| a.migrations()).sum(),
    }
}

fn pic_static<M: Mapping + Clone>(mapping: M, s: &Sizes) -> RunStats {
    let blobs = BlobPool::new();
    let name = mapping.mapping_name();
    let mut stores: Vec<View<M, _>> = (0..s.stores)
        .map(|k| {
            let mut v = alloc_view_with(mapping.clone(), blobs.clone());
            fill_attrs(&mut v, 60 + k as u64);
            v
        })
        .collect();
    let mut lat_ns = Vec::with_capacity(s.requests);
    let mut total = 0.0f64;
    let t0 = Instant::now();
    for r in 0..s.requests {
        let store = r % s.stores;
        let t1 = Instant::now();
        if r % s.write_every == s.write_every - 1 {
            stores[store].set::<f32>(r % s.pic_n, picframe::WEIGHTING, 2.0);
        } else {
            total += picframe::frames::deposit_view(&stores[store], s.pic_n);
        }
        lat_ns.push(t1.elapsed().as_nanos() as f64);
        if (r + 1) % s.epoch_every == 0 {
            for v in &mut stores {
                picframe::frames::drift_view(v, s.pic_n, 0.05);
            }
        }
    }
    black_box(total);
    RunStats {
        layout: name,
        elapsed_s: t0.elapsed().as_secs_f64(),
        lat_ns,
        migrations: 0,
    }
}

fn pic_case(s: &Sizes, t: &mut Table) {
    let d = picframe::attr_dim();
    let dims = ArrayDims::linear(s.pic_n);
    push_row(t, "picframe", "adaptive-serving", s, pic_adaptive_serving(s));
    push_row(t, "picframe", "stop-the-world", s, pic_stop_the_world(s));
    let statics = vec![
        pic_static(AoS::aligned(&d, dims.clone()), s),
        pic_static(SoA::multi_blob(&d, dims.clone()), s),
        pic_static(AoSoA::new(&d, dims.clone(), 32), s),
    ];
    let best = statics
        .into_iter()
        .min_by(|a, b| a.elapsed_s.total_cmp(&b.elapsed_s))
        .expect("static candidates");
    push_row(t, "picframe", "best-static", s, best);
}

/// Run the serving comparison for both request-driven workloads.
pub fn run(o: &Opts) -> Table {
    let s = sizes(o);
    let mut t = Table::new(
        format!(
            "concurrent serving: adaptive-serving vs stop-the-world vs best-static \
             ({} requests x {} stores, budget {}, {})",
            s.requests,
            s.stores,
            s.budget,
            if o.quick { "quick" } else { "full" }
        ),
        &[
            "workload",
            "engine",
            "layout",
            "stores",
            "req_per_s",
            "p50_us",
            "p99_us",
            "migrations",
        ],
    );
    hep_case(&s, &mut t);
    pic_case(&s, &mut t);
    t
}

/// Serialize a bench-serve run as the `BENCH_serve.json` baseline.
/// Refuses structurally to emit a document missing any
/// workload × engine row or reporting a non-positive throughput.
pub fn baseline_json_checked(o: &Opts) -> crate::error::Result<String> {
    let t = run(o);
    for workload in ["hep", "picframe"] {
        for engine in ["adaptive-serving", "stop-the-world", "best-static"] {
            let row = t
                .rows
                .iter()
                .find(|r| r[0] == workload && r[1] == engine)
                .ok_or_else(|| crate::anyhow!("bench-serve: missing {workload}/{engine} row"))?;
            let req_per_s: f64 = row[4].parse()?;
            crate::ensure!(
                req_per_s > 0.0,
                "bench-serve: {workload}/{engine} throughput must be positive"
            );
        }
    }
    Ok(format!(
        "{{\n  \"figure\": \"bench_serve\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"req/s; latency us (p50/p99 service time, nearest rank)\",\n  \"serve\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        t.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> Opts {
        let mut o = Opts::quick();
        o.iters = 1;
        o.n = Some(256);
        o
    }

    #[test]
    fn both_workloads_produce_the_engine_triple() {
        let t = run(&tiny_opts());
        assert_eq!(t.rows.len(), 2 * 3);
        for workload in ["hep", "picframe"] {
            for engine in ["adaptive-serving", "stop-the-world", "best-static"] {
                let row = t
                    .rows
                    .iter()
                    .find(|r| r[0] == workload && r[1] == engine)
                    .unwrap_or_else(|| panic!("missing {workload}/{engine}"));
                let req_per_s: f64 = row[4].parse().expect("req_per_s parses");
                assert!(req_per_s > 0.0, "{workload}/{engine}: {row:?}");
                let p50: f64 = row[5].parse().expect("p50 parses");
                let p99: f64 = row[6].parse().expect("p99 parses");
                assert!(p50 <= p99, "{workload}/{engine}: p50 {p50} > p99 {p99}");
            }
        }
        // The static engines never migrate; the adaptive fleets did
        // (the drifting window parks decisions every interval and the
        // budget applies the best of them).
        for workload in ["hep", "picframe"] {
            let stat =
                t.rows.iter().find(|r| r[0] == workload && r[1] == "best-static").unwrap();
            assert_eq!(stat[7], "0");
            let adaptive =
                t.rows.iter().find(|r| r[0] == workload && r[1] == "adaptive-serving").unwrap();
            let migrations: usize = adaptive[7].parse().expect("migrations parse");
            assert!(migrations >= 1, "{workload}: adaptive fleet never migrated");
        }
    }

    #[test]
    fn baseline_json_gates_on_rows_and_throughput() {
        let j = baseline_json_checked(&tiny_opts()).expect("complete run passes");
        assert!(j.contains("\"figure\": \"bench_serve\""), "{j}");
        assert!(j.contains("\"serve\": {"), "{j}");
        assert!(j.contains("adaptive-serving"), "{j}");
        assert!(j.contains("req_per_s"), "{j}");
        assert!(j.contains("p99_us"), "{j}");
        assert!(!j.contains("\"rows\": []"), "{j}");
    }
}
