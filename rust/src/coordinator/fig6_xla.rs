//! Fig 6 driver (hardware-adapted): n-body through the L2/L1 compute
//! stack — JAX+Pallas AOT artifacts executed on the PJRT client from
//! Rust.
//!
//! The fig 6 axes translate as (DESIGN.md §Hardware-Adaptation):
//! * *global memory layout* → artifact input representation: SoA
//!   (seven `f32[N]` params) vs AoS (one `f32[N,7]` matrix);
//! * *shared-memory tiling* → the Pallas kernel's VMEM staging
//!   (`tile`-sized `pl.load`s) vs the untiled plain-XLA lowering.
//!
//! Absolute numbers come from the CPU PJRT plugin running the
//! interpret-lowered kernels; the comparison of interest is the
//! *relative* effect of layout and tiling, plus the zero-copy handoff
//! of LLAMA-managed memory into the executable.

use crate::error::Result;

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_ms, fmt_ratio, Table};
use crate::array::ArrayDims;
use crate::copy::{aosoa_copy, ChunkOrder};
use crate::mapping::{AoS, SoA};
use crate::runtime::Runtime;
use crate::view::alloc_view;
use crate::workloads::nbody::{self, llama_impl};

/// Build the SoA input slices for an artifact of size n from LLAMA-
/// managed memory: a multi-blob SoA view's blobs *are* the seven
/// `f32[N]` buffers the executable wants — zero reshuffling.
pub fn soa_inputs(n: usize, seed: u64) -> (Vec<Vec<f32>>, crate::workloads::nbody::ParticleSoA) {
    let state = nbody::init_particles(n, seed);
    let d = nbody::particle_dim();
    let mut view = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    llama_impl::load_state(&mut view, &state);
    let inputs = view
        .blobs()
        .iter()
        .map(|b| {
            b.chunks_exact(4).map(|c| f32::from_ne_bytes(c.try_into().unwrap())).collect()
        })
        .collect();
    (inputs, state)
}

/// Build the packed AoS input for the `_aos` artifacts via the
/// layout-aware copy (SoA view -> packed AoS view -> single blob).
pub fn aos_input(n: usize, seed: u64) -> Vec<f32> {
    let state = nbody::init_particles(n, seed);
    let d = nbody::particle_dim();
    let mut soa = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    llama_impl::load_state(&mut soa, &state);
    let mut aos = alloc_view(AoS::packed(&d, ArrayDims::linear(n)));
    aosoa_copy(&soa, &mut aos, ChunkOrder::ReadContiguous);
    aos.blobs()[0]
        .chunks_exact(4)
        .map(|c| f32::from_ne_bytes(c.try_into().unwrap()))
        .collect()
}

/// Run fig 6: update (tiled SoA / tiled AoS / untiled SoA) and move
/// (SoA / AoS) through the PJRT runtime.
pub fn run(o: &Opts) -> Result<Table> {
    let mut rt = Runtime::cpu(&o.artifacts)?;
    let mut t = Table::new(
        format!("fig6 n-body via XLA/PJRT ({})", rt.platform()),
        &["artifact", "ms", "vs first"],
    );
    let mut rows: Vec<(String, f64)> = Vec::new();

    // --- update variants ---
    let n = rt.manifest().find("nbody_update_soa")?.n;
    let (soa_in, _) = soa_inputs(n, 5);
    let soa_refs: Vec<&[f32]> = soa_in.iter().map(|v| v.as_slice()).collect();
    let aos_in = aos_input(n, 5);

    for name in ["nbody_update_soa", "nbody_update_aos", "nbody_update_soa_notile"] {
        let exe = rt.load(name)?;
        let inputs: Vec<&[f32]> =
            if exe.meta().layout == "aos" { vec![&aos_in] } else { soa_refs.clone() };
        let r = bench(name, 1, o.iters, || {
            let out = exe.run_f32(&inputs).expect("execute");
            black_box(out);
        });
        rows.push((format!("{name} (N={n})"), r.median_ns));
    }

    // --- move variants ---
    let n_move = rt.manifest().find("nbody_move_soa")?.n;
    let (soa_mv, _) = soa_inputs(n_move, 6);
    let soa_mv_refs: Vec<&[f32]> = soa_mv.iter().map(|v| v.as_slice()).collect();
    let aos_mv = aos_input(n_move, 6);
    for name in ["nbody_move_soa", "nbody_move_aos"] {
        let exe = rt.load(name)?;
        let inputs: Vec<&[f32]> = if exe.meta().layout == "aos" {
            vec![&aos_mv]
        } else {
            // move does not take mass: first 6 SoA arrays only.
            soa_mv_refs[..6].to_vec()
        };
        let r = bench(name, 1, o.iters, || {
            let out = exe.run_f32(&inputs).expect("execute");
            black_box(out);
        });
        rows.push((format!("{name} (N={n_move})"), r.median_ns));
    }

    let base = rows[0].1;
    for (name, ns) in rows {
        t.row(vec![name, fmt_ms(ns), fmt_ratio(ns, base)]);
    }
    Ok(t)
}

/// Correctness gate for the whole stack: the artifact's update must
/// match the Rust LLAMA kernel on the same state.
pub fn verify_against_rust(o: &Opts) -> Result<f64> {
    let mut rt = Runtime::cpu(&o.artifacts)?;
    let exe = rt.load("nbody_update_soa")?;
    let n = exe.meta().n;
    let (inputs, state) = soa_inputs(n, 5);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let out = exe.run_f32(&refs)?;

    // Rust-side reference over the same state.
    let d = nbody::particle_dim();
    let mut view = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    llama_impl::load_state(&mut view, &state);
    llama_impl::update(&mut view);
    let expect = llama_impl::store_state(&view);

    let mut max_rel = 0.0f64;
    for (d_idx, got) in out.iter().enumerate().take(3) {
        for (g, w) in got.iter().zip(&expect.vel[d_idx]) {
            let denom = g.abs().max(w.abs()).max(1e-12) as f64;
            max_rel = max_rel.max(((*g - *w).abs() as f64) / denom);
        }
    }
    Ok(max_rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_builders_are_consistent() {
        let n = 64;
        let (soa, state) = soa_inputs(n, 9);
        let aos = aos_input(n, 9);
        assert_eq!(soa.len(), 7);
        assert_eq!(aos.len(), n * 7);
        for i in 0..n {
            assert_eq!(aos[i * 7], soa[0][i]); // pos.x column
            assert_eq!(aos[i * 7 + 6], soa[6][i]); // mass column
            assert_eq!(soa[0][i], state.pos[0][i]);
        }
    }
}
