//! Fig 8 driver: the lbm benchmark (SPEC 619.lbm_s analog) across
//! layouts and CPU saturation levels.
//!
//! Paper's expected shape: with all cores busy, SoA ≈ 0.45–0.55× the
//! AoS runtime and the best AoSoA is on par or slightly better; Split
//! (trace-derived hot/cold) gains ~8–10% over AoS. With a single
//! thread on an idle machine the ordering reverses (AoS/Split win).

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_ms, fmt_ratio, Table};
use crate::mapping::{AoS, AoSoA, Mapping, RecipeMapping, SoA, Trace};
use crate::view::adapt::{AdaptiveConfig, AdaptiveView};
use crate::view::alloc_view;
use crate::workloads::lbm::step::{init, step_parallel, total_mass, AdaptiveStep};
use crate::workloads::lbm::{cell_dim, Geometry};

pub fn geometry(o: &Opts) -> Geometry {
    let g = o.n.unwrap_or(if o.quick { 16 } else { 48 });
    Geometry::channel_with_sphere(g, g, g, 2024)
}

fn run_case<M: Mapping + Clone>(
    name: &str,
    mapping: M,
    geo: &Geometry,
    steps: usize,
    threads: usize,
    o: &Opts,
    rows: &mut Vec<(String, f64)>,
) {
    // The ping-pong double buffers draw from a blob pool (layer 0):
    // the step kernel runs on pooled blobs through the same zip
    // executor, exercising blob-generic dispatch end to end.
    let pool = crate::blob::BlobPool::new();
    let mut a = crate::view::alloc_view_with(mapping.clone(), pool.clone());
    let mut b = crate::view::alloc_view_with(mapping, pool);
    init(&mut a, geo);
    init(&mut b, geo);
    let m0 = total_mass(&a);
    let r = bench(name, 1, o.iters, || {
        for _ in 0..steps {
            step_parallel(&a, &mut b, threads);
            std::mem::swap(&mut a, &mut b);
        }
        black_box(a.blobs());
    });
    // Physics sanity after timing: mass conserved.
    let m1 = total_mass(&a);
    assert!((m0 - m1).abs() / m0 < 1e-6, "{name}: mass drift");
    rows.push((name.to_string(), r.median_ns));
}

/// Derive the paper's hot/cold 4-group split from a traced step (kept
/// for the §4.3 manual-workflow ablation, `cargo bench --bench
/// ablations`).
pub fn trace_derived_groups(geo: &Geometry) -> Vec<Vec<usize>> {
    let d = cell_dim();
    let traced = Trace::new(AoS::aligned(&d, geo.dims.clone()));
    let mut a = alloc_view(traced);
    let mut b = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    init(&mut a, geo);
    crate::workloads::lbm::step::step(&a, &mut b);
    a.mapping().equal_count_groups(4)
}

/// Derive the hot/cold split through the adaptive engine — the
/// automated replacement for the hand-wired trace →
/// `equal_count_groups` → `build_split4` workflow: wrap an initialized
/// AoS view, run one traced step, and take whatever layout the
/// engine's advisor adopted (the pull-scheme step reads `flags` once
/// per direction, so the advisor splits it hot).
pub fn advisor_derived_mapping(geo: &Geometry) -> RecipeMapping {
    let d = cell_dim();
    let mut v = alloc_view(AoS::aligned(&d, geo.dims.clone()));
    init(&mut v, geo);
    let cfg = AdaptiveConfig { steady_steps: 0, ..Default::default() };
    let mut av = AdaptiveView::new(v, cfg);
    av.step_zip(&mut AdaptiveStep { threads: 1 });
    let (mapping, _) = av.into_view().into_parts();
    mapping
}

/// One saturation scenario of fig 8.
fn scenario(label: &str, geo: &Geometry, steps: usize, threads: usize, o: &Opts) -> Table {
    let d = cell_dim();
    let mut rows: Vec<(String, f64)> = Vec::new();

    run_case(
        "AoS (baseline)",
        AoS::aligned(&d, geo.dims.clone()),
        geo,
        steps,
        threads,
        o,
        &mut rows,
    );
    run_case(
        "Split (advisor hot/cold)",
        advisor_derived_mapping(geo),
        geo,
        steps,
        threads,
        o,
        &mut rows,
    );
    run_case("SoA SB", SoA::single_blob(&d, geo.dims.clone()), geo, steps, threads, o, &mut rows);
    run_case("SoA MB", SoA::multi_blob(&d, geo.dims.clone()), geo, steps, threads, o, &mut rows);
    for lanes in [4usize, 16, 64, 256] {
        run_case(
            &format!("AoSoA{lanes}"),
            AoSoA::new(&d, geo.dims.clone(), lanes),
            geo,
            steps,
            threads,
            o,
            &mut rows,
        );
    }

    let mut t = Table::new(
        format!(
            "fig8 lbm {label} (grid {:?}, {} steps, {} thread(s))",
            geo.dims.extents(),
            steps,
            threads
        ),
        &["layout", "ms", "vs AoS"],
    );
    let base = rows[0].1;
    let cells = geo.dims.count() * steps;
    for (name, ns) in rows {
        let mlups = cells as f64 / (ns / 1e9) / 1e6;
        t.row(vec![name, format!("{} ({mlups:.1} MLUPS)", fmt_ms(ns)), fmt_ratio(ns, base)]);
    }
    t
}

/// Run fig 8: saturated (all threads) and unsaturated (1 thread).
pub fn run(o: &Opts) -> Vec<Table> {
    let geo = geometry(o);
    let steps = if o.quick { 2 } else { 5 };
    vec![
        scenario("saturated", &geo, steps, o.threads(), o),
        scenario("single-thread", &geo, steps, 1, o),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scenarios_have_all_layout_rows() {
        let mut o = Opts::quick();
        o.n = Some(8);
        o.iters = 1;
        o.threads = Some(2);
        let tables = run(&o);
        assert_eq!(tables.len(), 2);
        for t in &tables {
            assert_eq!(t.rows.len(), 8);
            assert!(t.to_text().contains("Split (advisor hot/cold)"));
            assert_eq!(t.rows[0][2], "1.000");
        }
    }

    #[test]
    fn trace_groups_cover_all_fields() {
        let geo = Geometry::channel_with_sphere(6, 6, 6, 1);
        let groups = trace_derived_groups(&geo);
        assert_eq!(groups.len(), 4);
        let mut all = groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn advisor_mapping_is_a_hot_cold_split() {
        let geo = Geometry::channel_with_sphere(6, 6, 6, 1);
        let m = advisor_derived_mapping(&geo);
        // The pull-scheme step reads flags ~20x per cell vs ~2x per
        // distribution: the advisor must split it off hot.
        assert!(m.mapping_name().starts_with("Split("), "{}", m.mapping_name());
        crate::mapping::test_support::check_mapping_invariants(&m);
    }
}
