//! `bench-adapt` driver: the adaptive relayout engine vs the best and
//! worst static layout, per workload (EXPERIMENTS.md §Adapt).
//!
//! Each case is a complete run — build, load, N workload steps — so
//! the adaptive rows *include* the sampling epoch and the migration
//! copy: the comparison shows whether the relayout pays for itself
//! within the run. Static candidates are measured identically and the
//! fastest/slowest become the `best-static` / `worst-static` rows.

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_ms, Table};
use crate::array::ArrayDims;
use crate::blob::{BlobMut, BlobPool};
use crate::mapping::{AoS, AoSoA, Mapping, SoA};
use crate::view::adapt::{AdaptiveConfig, AdaptiveView};
use crate::view::{alloc_view_with, View};
use crate::workloads::rng::SplitMix64;
use crate::workloads::{hep, lbm, nbody, picframe};

/// Problem sizes per workload (quick = CI smoke).
struct Sizes {
    nbody_n: usize,
    lbm_g: usize,
    pic_n: usize,
    hep_n: usize,
    steps: usize,
}

fn sizes(o: &Opts) -> Sizes {
    if o.quick {
        Sizes {
            nbody_n: o.n.unwrap_or(1 << 14),
            lbm_g: 12,
            pic_n: picframe::FRAME_SIZE * 16,
            hep_n: 1 << 12,
            steps: 6,
        }
    } else {
        Sizes {
            nbody_n: o.n.unwrap_or(1 << 20),
            lbm_g: 32,
            pic_n: picframe::FRAME_SIZE * 256,
            hep_n: 1 << 16,
            steps: 12,
        }
    }
}

/// One measured full run: (layout label, median ns).
type Row = (String, f64);

/// Engine defaults for the benched runs: one traced step, then steady
/// for the rest of the run (the run *is* one epoch).
fn engine_cfg() -> AdaptiveConfig {
    AdaptiveConfig { steady_steps: 0, ..Default::default() }
}

fn push_rows(t: &mut Table, workload: &str, adaptive: Row, statics: Vec<Row>) {
    let best = statics
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).expect("finite medians"))
        .expect("static candidates")
        .clone();
    let worst = statics
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite medians"))
        .expect("static candidates")
        .clone();
    let rows = vec![
        ("adaptive".to_string(), adaptive),
        ("best-static".to_string(), best),
        ("worst-static".to_string(), worst),
    ];
    for (variant, (layout, ns)) in rows {
        t.row(vec![workload.to_string(), variant, layout, fmt_ms(ns)]);
    }
}

// ---- nbody: the memory-bound move sweep ----

fn nbody_static<M: Mapping + Clone>(
    mapping: M,
    state: &nbody::ParticleSoA,
    steps: usize,
    o: &Opts,
) -> f64 {
    // Every case rebuilds its buffers per iteration; a per-case pool
    // shared across iterations recycles them (blob::pool, §Alloc), so
    // the medians measure the workload, not allocator churn.
    let pool = BlobPool::new();
    bench("nbody static", 1, o.iters, || {
        let mut v = alloc_view_with(mapping.clone(), pool.clone());
        nbody::llama_impl::load_state(&mut v, state);
        for _ in 0..steps {
            nbody::llama_impl::mv(&mut v);
        }
        black_box(v.blobs());
    })
    .median_ns
}

fn nbody_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(s.nbody_n);
    let state = nbody::init_particles(s.nbody_n, 7);
    let statics: Vec<Row> = vec![
        (
            "AoS (aligned)".into(),
            nbody_static(AoS::aligned(&d, dims.clone()), &state, s.steps, o),
        ),
        (
            "SoA MB".into(),
            nbody_static(SoA::multi_blob(&d, dims.clone()), &state, s.steps, o),
        ),
        (
            "AoSoA16".into(),
            nbody_static(AoSoA::new(&d, dims.clone(), 16), &state, s.steps, o),
        ),
    ];
    let mut final_layout = String::new();
    // The adaptive run routes both its buffers *and* its migration
    // destinations through the pool (AdaptiveView::with_recycler):
    // iteration N's migration reuses iteration N-1's retired blobs.
    let pool = BlobPool::new();
    let r = bench("nbody adaptive", 1, o.iters, || {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), pool.clone());
        nbody::llama_impl::load_state(&mut v, &state);
        let mut av = AdaptiveView::with_recycler(v, engine_cfg(), pool.clone());
        let mut k = nbody::llama_impl::AdaptiveMove { threads: 1 };
        for _ in 0..s.steps {
            av.step(&mut k);
        }
        final_layout = av.mapping_name();
        black_box(av.count());
    });
    push_rows(t, "nbody", (final_layout, r.median_ns), statics);
}

// ---- lbm: the D3Q19 stream-collide step ----

fn lbm_static<M: Mapping + Clone>(
    mapping: M,
    geo: &lbm::Geometry,
    steps: usize,
    o: &Opts,
) -> f64 {
    // The classic double-buffer churn: both ping-pong buffers draw
    // from a pool shared across iterations.
    let pool = BlobPool::new();
    bench("lbm static", 1, o.iters, || {
        let mut a = alloc_view_with(mapping.clone(), pool.clone());
        let mut b = alloc_view_with(mapping.clone(), pool.clone());
        lbm::step::init(&mut a, geo);
        lbm::step::init(&mut b, geo);
        for _ in 0..steps {
            lbm::step::step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        black_box(a.blobs());
    })
    .median_ns
}

fn lbm_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = lbm::cell_dim();
    let geo = lbm::Geometry::channel_with_sphere(s.lbm_g, s.lbm_g, s.lbm_g, 2024);
    let statics: Vec<Row> = vec![
        (
            "AoS (aligned)".into(),
            lbm_static(AoS::aligned(&d, geo.dims.clone()), &geo, s.steps, o),
        ),
        (
            "SoA MB".into(),
            lbm_static(SoA::multi_blob(&d, geo.dims.clone()), &geo, s.steps, o),
        ),
        (
            "AoSoA16".into(),
            lbm_static(AoSoA::new(&d, geo.dims.clone(), 16), &geo, s.steps, o),
        ),
    ];
    let mut final_layout = String::new();
    let pool = BlobPool::new();
    let r = bench("lbm adaptive", 1, o.iters, || {
        let mut v = alloc_view_with(AoS::aligned(&d, geo.dims.clone()), pool.clone());
        lbm::step::init(&mut v, &geo);
        let mut av = AdaptiveView::with_recycler(v, engine_cfg(), pool.clone());
        let mut k = lbm::step::AdaptiveStep { threads: 1 };
        for _ in 0..s.steps {
            av.step_zip(&mut k);
        }
        final_layout = av.mapping_name();
        black_box(av.count());
    });
    push_rows(t, "lbm", (final_layout, r.median_ns), statics);
}

// ---- picframe: the drift sweep over an attribute store ----

fn fill_particles<M: Mapping, B: BlobMut>(v: &mut View<M, B>, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    for lin in 0..v.count() {
        for leaf in [picframe::POS_X, picframe::POS_Y, picframe::POS_Z] {
            v.set::<f32>(lin, leaf, rng.next_f32());
        }
        for leaf in [picframe::MOM_X, picframe::MOM_Y, picframe::MOM_Z] {
            v.set::<f32>(lin, leaf, rng.range_f32(-0.3, 0.3));
        }
        v.set::<f32>(lin, picframe::WEIGHTING, rng.range_f32(0.5, 1.5));
        v.set::<i32>(lin, picframe::CELL_IDX, rng.below(picframe::FRAME_SIZE) as i32);
    }
}

fn pic_static<M: Mapping + Clone>(mapping: M, steps: usize, o: &Opts) -> f64 {
    let pool = BlobPool::new();
    bench("picframe static", 1, o.iters, || {
        let mut v = alloc_view_with(mapping.clone(), pool.clone());
        fill_particles(&mut v, 23);
        let n = v.count();
        for _ in 0..steps {
            picframe::frames::drift_view(&mut v, n, 0.05);
        }
        black_box(v.blobs());
    })
    .median_ns
}

fn pic_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = picframe::attr_dim();
    let dims = ArrayDims::linear(s.pic_n);
    let statics: Vec<Row> = vec![
        ("AoS (aligned)".into(), pic_static(AoS::aligned(&d, dims.clone()), s.steps, o)),
        ("SoA MB".into(), pic_static(SoA::multi_blob(&d, dims.clone()), s.steps, o)),
        ("AoSoA32".into(), pic_static(AoSoA::new(&d, dims.clone(), 32), s.steps, o)),
    ];
    let mut final_layout = String::new();
    let pool = BlobPool::new();
    let r = bench("picframe adaptive", 1, o.iters, || {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), pool.clone());
        fill_particles(&mut v, 23);
        let mut av = AdaptiveView::with_recycler(v, engine_cfg(), pool.clone());
        let mut k = picframe::frames::AdaptiveDrift { dt: 0.05 };
        for _ in 0..s.steps {
            av.step(&mut k);
        }
        final_layout = av.mapping_name();
        black_box(av.count());
    });
    push_rows(t, "picframe", (final_layout, r.median_ns), statics);
}

// ---- hep: the 3-of-100-fields isolation sweep ----

fn hep_static<M: Mapping + Clone>(mapping: M, steps: usize, o: &Opts) -> (f64, f64) {
    let mut total = 0.0f64;
    let pool = BlobPool::new();
    let ns = bench("hep static", 1, o.iters, || {
        let mut v = alloc_view_with(mapping.clone(), pool.clone());
        hep::generate_events(&mut v, 77);
        total = 0.0;
        for _ in 0..steps {
            total += hep::isolated_energy(&v, 128);
        }
        black_box(total);
    })
    .median_ns;
    (ns, total)
}

fn hep_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = hep::event_dim();
    let dims = ArrayDims::linear(s.hep_n);
    let (aos_ns, expect) = hep_static(AoS::aligned(&d, dims.clone()), s.steps, o);
    let (soa_ns, soa_total) = hep_static(SoA::multi_blob(&d, dims.clone()), s.steps, o);
    let (aosoa_ns, aosoa_total) = hep_static(AoSoA::new(&d, dims.clone(), 16), s.steps, o);
    assert_eq!(expect, soa_total, "hep energy differs across layouts");
    assert_eq!(expect, aosoa_total, "hep energy differs across layouts");
    let statics: Vec<Row> = vec![
        ("AoS (aligned)".into(), aos_ns),
        ("SoA MB".into(), soa_ns),
        ("AoSoA16".into(), aosoa_ns),
    ];
    let mut final_layout = String::new();
    let mut adaptive_total = 0.0f64;
    let pool = BlobPool::new();
    let r = bench("hep adaptive", 1, o.iters, || {
        let mut v = alloc_view_with(AoS::aligned(&d, dims.clone()), pool.clone());
        hep::generate_events(&mut v, 77);
        let mut av = AdaptiveView::with_recycler(v, engine_cfg(), pool.clone());
        let mut k = hep::AdaptiveIsolation { min_quality: 128, threads: 1, total: 0.0 };
        for _ in 0..s.steps {
            av.step(&mut k);
        }
        final_layout = av.mapping_name();
        adaptive_total = k.total;
        black_box(k.total);
    });
    // Migration must not change physics: the adaptive sweep sums the
    // exact same energies as every static layout.
    assert_eq!(adaptive_total, expect, "adaptive hep energy drifted");
    push_rows(t, "hep", (final_layout, r.median_ns), statics);
}

/// Run the adaptive-vs-static comparison for all four workloads.
pub fn run(o: &Opts) -> Table {
    let s = sizes(o);
    let mut t = Table::new(
        format!(
            "adaptive relayout engine: adaptive vs static ({} steps per run, {})",
            s.steps,
            if o.quick { "quick" } else { "full" }
        ),
        &["workload", "variant", "layout", "ms"],
    );
    nbody_case(&s, o, &mut t);
    lbm_case(&s, o, &mut t);
    pic_case(&s, o, &mut t);
    hep_case(&s, o, &mut t);
    t
}

/// Serialize a bench-adapt run as the `BENCH_adapt.json` baseline.
/// Refuses structurally to emit a document missing the
/// adaptive/best-static/worst-static triple for any workload.
pub fn baseline_json_checked(o: &Opts) -> crate::error::Result<String> {
    let t = run(o);
    for workload in ["nbody", "lbm", "picframe", "hep"] {
        for variant in ["adaptive", "best-static", "worst-static"] {
            crate::ensure!(
                t.rows.iter().any(|r| r[0] == workload && r[1] == variant),
                "bench-adapt: missing {workload}/{variant} row"
            );
        }
    }
    Ok(format!(
        "{{\n  \"figure\": \"bench_adapt\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"ms (median, whole run incl. sampling + migration)\",\n  \"adapt\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        t.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> Opts {
        let mut o = Opts::quick();
        o.iters = 1;
        o.n = Some(512);
        o
    }

    #[test]
    fn all_workloads_produce_the_variant_triple() {
        let t = run(&tiny_opts());
        assert_eq!(t.rows.len(), 4 * 3);
        for workload in ["nbody", "lbm", "picframe", "hep"] {
            for variant in ["adaptive", "best-static", "worst-static"] {
                assert!(
                    t.rows.iter().any(|r| r[0] == workload && r[1] == variant),
                    "missing {workload}/{variant}"
                );
            }
        }
        // The adaptive rows name the layout the engine landed on.
        let nbody_adaptive =
            t.rows.iter().find(|r| r[0] == "nbody" && r[1] == "adaptive").unwrap();
        assert!(nbody_adaptive[2].starts_with("SoA("), "{nbody_adaptive:?}");
        let lbm_adaptive = t.rows.iter().find(|r| r[0] == "lbm" && r[1] == "adaptive").unwrap();
        assert!(lbm_adaptive[2].starts_with("Split("), "{lbm_adaptive:?}");
    }

    #[test]
    fn baseline_json_gates_on_the_triple() {
        let j = baseline_json_checked(&tiny_opts()).expect("complete run passes");
        assert!(j.contains("\"figure\": \"bench_adapt\""), "{j}");
        assert!(j.contains("\"adapt\": {"), "{j}");
        assert!(j.contains("adaptive"), "{j}");
        assert!(!j.contains("\"rows\": []"), "{j}");
    }
}
