//! The `llama` CLI: runs the paper-figure drivers, the layout dumps and
//! the end-to-end XLA path. Hand-rolled argument parsing (no clap in
//! the vendored set).

use crate::bail;
use crate::error::Result;

use super::bench::Opts;
use super::{
    bench_adapt, bench_alloc, bench_serve, bench_wire, fig10_picframe, fig5_nbody, fig6_xla,
    fig7_copy, fig8_lbm, halo, wire_demo, wire_net,
};

const USAGE: &str = "\
llama — LLAMA (Low-Level Abstraction of Memory Access) reproduction

USAGE: llama <COMMAND> [OPTIONS]

COMMANDS:
  nbody       fig 5: n-body CPU update/move across layouts
  xla         fig 6: n-body through the JAX/Pallas AOT + PJRT stack
  copybench   fig 7: layout-changing copy throughput
  lbm         fig 8: D3Q19 lattice-Boltzmann across layouts
  picframe    fig 10: PIConGPU-style particle frames across layouts
  bench-fig5  run fig 5 and write the BENCH_fig5.json baseline
  bench-fig7  run fig 7 and write the BENCH_fig7.json baseline
  adapt       adaptive relayout engine vs best/worst static layout
  bench-adapt run adapt and write the BENCH_adapt.json baseline
  allocbench  blob::pool — pooled vs fresh-zeroed allocation churn
  bench-alloc run allocbench and write the BENCH_alloc.json baseline
  serve       serving engines: epoch-pinned reads vs stop-the-world
  bench-serve run serve and write the BENCH_serve.json baseline
  wire        copy::wire demo: frames exchanged with worker processes
  wire-worker the worker side of `wire` (framed stdin -> stdout loop)
  wire-serve  TCP wire server: serve --n connections on --addr
  wire-connect TCP wire client demo: staged/pipelined/multiplexed
  halo        lbm halo exchange across worker processes over TCP
  halo-worker the worker side of `halo` (one ring member)
  wirebench   copy::wire — compiled pack vs naive element-wise
  bench-wire  run wirebench and write the BENCH_wire.json baseline
  dump        fig 4: write SVG/HTML layout dumps + heatmap
  e2e         end-to-end driver: LLAMA memory -> PJRT n-body steps
  all         run every figure driver (quick mode by default)
  info        platform + artifact inventory

OPTIONS:
  --quick           small problem sizes (CI-friendly)
  --n <N>           problem-size override (meaning depends on command)
  --iters <K>       timed iterations per case (default 5)
  --threads <T>     worker threads for parallel variants
  --artifacts <DIR> artifacts directory (default: artifacts)
  --addr <ADDR>     socket address for wire-serve/wire-connect
  --overlap         halo: split-phase overlapped schedule (default: blocking ring)
  --out-dir <DIR>   output directory for dump/e2e files
  --markdown        print tables as Markdown instead of aligned text
";

#[derive(Debug)]
pub struct Cli {
    pub command: String,
    pub opts: Opts,
    pub out_dir: String,
    pub markdown: bool,
}

pub fn parse(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        bail!("{USAGE}");
    }
    let command = args[0].clone();
    if command == "-h" || command == "--help" {
        bail!("{USAGE}");
    }
    let mut opts = Opts::default();
    let mut out_dir = "artifacts/dumps".to_string();
    let mut markdown = false;
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        let mut take = || -> Result<&String> {
            it.next().ok_or_else(|| crate::anyhow!("{a} needs a value\n\n{USAGE}"))
        };
        match a.as_str() {
            "--quick" => {
                opts.quick = true;
                opts.iters = opts.iters.min(3);
            }
            "--n" => opts.n = Some(take()?.parse()?),
            "--iters" => opts.iters = take()?.parse()?,
            "--threads" => opts.threads = Some(take()?.parse()?),
            "--artifacts" => opts.artifacts = take()?.clone(),
            "--addr" => opts.addr = Some(take()?.clone()),
            "--overlap" => opts.overlap = true,
            "--out-dir" => out_dir = take()?.clone(),
            "--markdown" => markdown = true,
            "-h" | "--help" => bail!("{USAGE}"),
            other => bail!("unknown option {other}\n\n{USAGE}"),
        }
    }
    Ok(Cli { command, opts, out_dir, markdown })
}

fn emit(t: &super::report::Table, markdown: bool) {
    if markdown {
        println!("{}", t.to_markdown());
    } else {
        println!("{}", t.to_text());
    }
}

pub fn run(cli: Cli) -> Result<()> {
    let o = &cli.opts;
    match cli.command.as_str() {
        "nbody" => {
            let (u, m) = fig5_nbody::run(o);
            emit(&u, cli.markdown);
            emit(&m, cli.markdown);
            emit(&fig5_nbody::thread_sweep(o), cli.markdown);
        }
        "xla" => {
            let rel = fig6_xla::verify_against_rust(o)?;
            println!("stack correctness: max rel err XLA vs Rust kernel = {rel:.2e}");
            crate::ensure!(rel < 1e-4, "XLA/Rust mismatch");
            emit(&fig6_xla::run(o)?, cli.markdown);
        }
        "copybench" => emit(&fig7_copy::run(o), cli.markdown),
        "lbm" => {
            for t in fig8_lbm::run(o) {
                emit(&t, cli.markdown);
            }
        }
        "picframe" => emit(&fig10_picframe::run(o), cli.markdown),
        "bench-fig5" => {
            let path = "BENCH_fig5.json";
            // Refuses (non-zero exit) to overwrite the checked-in
            // trajectory with a baseline containing an empty table.
            std::fs::write(path, fig5_nbody::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "bench-fig7" => {
            let path = "BENCH_fig7.json";
            std::fs::write(path, fig7_copy::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "adapt" => emit(&bench_adapt::run(o), cli.markdown),
        "bench-adapt" => {
            let path = "BENCH_adapt.json";
            std::fs::write(path, bench_adapt::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "allocbench" => emit(&bench_alloc::run(o), cli.markdown),
        "bench-alloc" => {
            let path = "BENCH_alloc.json";
            std::fs::write(path, bench_alloc::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "serve" => emit(&bench_serve::run(o), cli.markdown),
        "bench-serve" => {
            let path = "BENCH_serve.json";
            std::fs::write(path, bench_serve::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "wire" => emit(&wire_demo::run(o)?, cli.markdown),
        "wire-worker" => wire_demo::worker_main()?,
        "wire-serve" => wire_net::serve_main(o)?,
        "wire-connect" => emit(&wire_net::run(o)?, cli.markdown),
        "halo" => emit(&halo::run(o)?, cli.markdown),
        "halo-worker" => halo::worker_main()?,
        "wirebench" => {
            emit(&bench_wire::run(o)?, cli.markdown);
            emit(&bench_wire::distributed(o)?, cli.markdown);
        }
        "bench-wire" => {
            let path = "BENCH_wire.json";
            std::fs::write(path, bench_wire::baseline_json_checked(o)?)?;
            println!("wrote {path}");
        }
        "dump" => dump(&cli.out_dir)?,
        "e2e" => e2e(o, &cli.out_dir)?,
        "all" => {
            let o = if o.quick { o.clone() } else { Opts::quick() };
            let (u, m) = fig5_nbody::run(&o);
            emit(&u, cli.markdown);
            emit(&m, cli.markdown);
            emit(&fig7_copy::run(&o), cli.markdown);
            for t in fig8_lbm::run(&o) {
                emit(&t, cli.markdown);
            }
            emit(&fig10_picframe::run(&o), cli.markdown);
            emit(&bench_adapt::run(&o), cli.markdown);
            emit(&bench_alloc::run(&o), cli.markdown);
            emit(&bench_serve::run(&o), cli.markdown);
            emit(&bench_wire::run(&o)?, cli.markdown);
            emit(&wire_demo::run(&o)?, cli.markdown);
            match fig6_xla::run(&o) {
                Ok(t) => emit(&t, cli.markdown),
                Err(e) => println!("fig6 skipped ({e}); run `make artifacts` first"),
            }
        }
        "info" => info(o)?,
        other => bail!("unknown command {other}\n\n{USAGE}"),
    }
    Ok(())
}

/// Fig 4: dump SVG/HTML layout pictures and an access heatmap.
fn dump(out_dir: &str) -> Result<()> {
    use crate::array::ArrayDims;
    use crate::dump::{dump_html, dump_svg, heatmap_ascii};
    use crate::mapping::{AoS, AoSoA, Heatmap, One, SoA, Split};
    use crate::record::RecordCoord;
    use crate::workloads::nbody;

    std::fs::create_dir_all(out_dir)?;
    let d = crate::mapping_demo_dim();
    let dims = ArrayDims::linear(8);
    let write = |name: &str, content: &str| -> Result<()> {
        let path = format!("{out_dir}/{name}");
        std::fs::write(&path, content)?;
        println!("wrote {path}");
        Ok(())
    };
    // fig 4a: packed AoS; fig 4b: AoSoA4; fig 4c: the nested split.
    write("fig4a_aos_packed.svg", &dump_svg(&AoS::packed(&d, dims.clone()), 8, 64))?;
    write("fig4b_aosoa4.svg", &dump_svg(&AoSoA::new(&d, dims.clone(), 4), 8, 64))?;
    let split = Split::new(
        &d,
        dims.clone(),
        RecordCoord::new(vec![1]),
        |sd, ad| SoA::multi_blob(sd, ad),
        |sd, ad| {
            Split::new(
                sd,
                ad,
                RecordCoord::new(vec![1]),
                |s2, a2| One::new(s2, a2),
                |s2, a2| AoS::aligned(s2, a2),
            )
        },
    );
    write("fig4c_split.svg", &dump_svg(&split, 8, 64))?;
    write("fig4_layouts.html", &dump_html(&AoS::aligned(&d, dims.clone()), 4))?;

    // fig 4d: heatmap of one n-body step over an AoS mapping.
    let pd = nbody::particle_dim();
    let n = 64;
    let h = Heatmap::with_granularity(AoS::packed(&pd, ArrayDims::linear(n)), 4);
    let mut view = crate::view::alloc_view(h);
    let s = nbody::init_particles(n, 1);
    nbody::llama_impl::load_state(&mut view, &s);
    nbody::llama_impl::update(&mut view);
    nbody::llama_impl::mv(&mut view);
    write("fig4d_heatmap.txt", &heatmap_ascii(view.mapping(), 112))?;
    let pgm = crate::dump::heatmap_pgm(view.mapping(), 0, 112);
    std::fs::write(format!("{out_dir}/fig4d_heatmap.pgm"), pgm)?;
    println!("wrote {out_dir}/fig4d_heatmap.pgm");
    Ok(())
}

/// End-to-end driver: LLAMA-managed particle memory, layout-aware
/// copies, PJRT-executed JAX/Pallas steps, energy log.
fn e2e(o: &Opts, out_dir: &str) -> Result<()> {
    use crate::runtime::Runtime;

    let mut rt = Runtime::cpu(&o.artifacts)?;
    println!("platform: {}", rt.platform());
    let steps = if o.quick { 3 } else { 10 };

    // Correctness gate first.
    let rel = fig6_xla::verify_against_rust(o)?;
    println!("XLA vs Rust kernel max rel err: {rel:.2e}");
    crate::ensure!(rel < 1e-4, "stack mismatch");

    let exe = rt.load("nbody_step_soa")?;
    let n = exe.meta().n;
    let (mut inputs, _) = fig6_xla::soa_inputs(n, 123);
    println!("running {steps} steps of N={n} n-body through PJRT...");
    let mut energies = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut out = exe.run_f32(&refs)?;
        let energy = out.pop().expect("energy output")[0];
        energies.push(energy);
        inputs = out;
    }
    let dt = t0.elapsed();
    println!(
        "done in {:.1} ms ({:.2} ms/step); kinetic energy trace:",
        dt.as_secs_f64() * 1e3,
        dt.as_secs_f64() * 1e3 / steps as f64
    );
    for (i, e) in energies.iter().enumerate() {
        println!("  step {i:>3}: E_kin = {e:.6}");
    }
    crate::ensure!(
        energies.iter().all(|e| e.is_finite() && *e > 0.0),
        "energies must stay finite/positive"
    );
    crate::ensure!(
        energies.windows(2).all(|w| w[1] >= w[0] * 0.99),
        "all-pairs update should not lose energy this fast"
    );
    std::fs::create_dir_all(out_dir)?;
    let csv = energies
        .iter()
        .enumerate()
        .map(|(i, e)| format!("{i},{e}"))
        .collect::<Vec<_>>()
        .join("\n");
    let path = format!("{out_dir}/e2e_energy.csv");
    std::fs::write(&path, format!("step,kinetic_energy\n{csv}\n"))?;
    println!("wrote {path}");
    Ok(())
}

fn info(o: &Opts) -> Result<()> {
    println!("llama reproduction of DOI 10.1002/spe.3077");
    println!("cores: {}", o.threads());
    println!(
        "simd: compiled={}, dispatch={}",
        crate::view::simd::simd_compiled(),
        crate::view::simd::detect().name()
    );
    match crate::runtime::Manifest::load(&o.artifacts) {
        Ok(m) => {
            println!("artifacts in {}:", o.artifacts);
            for a in &m.artifacts {
                println!(
                    "  {} (n={}, tile={}, layout={}, {} -> {})",
                    a.name, a.n, a.tile, a.layout, a.inputs, a.outputs
                );
            }
        }
        Err(e) => println!("no artifacts ({e}); run `make artifacts`"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_full_command_line() {
        let cli = parse(&args(&[
            "lbm", "--quick", "--n", "12", "--iters", "2", "--threads", "4", "--markdown",
        ]))
        .unwrap();
        assert_eq!(cli.command, "lbm");
        assert!(cli.opts.quick);
        assert_eq!(cli.opts.n, Some(12));
        assert_eq!(cli.opts.iters, 2);
        assert_eq!(cli.opts.threads, Some(4));
        assert!(cli.markdown);
    }

    #[test]
    fn parse_addr_option() {
        let cli = parse(&args(&["wire-serve", "--addr", "127.0.0.1:7070", "--n", "3"])).unwrap();
        assert_eq!(cli.opts.addr.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(cli.opts.n, Some(3));
        assert!(parse(&args(&["wire-serve", "--addr"])).is_err());
    }

    #[test]
    fn parse_overlap_flag() {
        let cli = parse(&args(&["halo", "--quick", "--overlap"])).unwrap();
        assert!(cli.opts.overlap);
        assert!(!parse(&args(&["halo", "--quick"])).unwrap().opts.overlap);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse(&[]).is_err());
        assert!(parse(&args(&["nbody", "--n"])).is_err());
        assert!(parse(&args(&["nbody", "--wat"])).is_err());
        assert!(parse(&args(&["--help"])).is_err()); // usage via Err
    }

    #[test]
    fn unknown_command_is_error() {
        let cli = parse(&args(&["fly"])).unwrap();
        assert!(run(cli).is_err());
    }
}
