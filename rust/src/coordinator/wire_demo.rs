//! The `wire` demo: picframe frames exchanged with worker *processes*
//! over OS pipes — `copy::wire` end to end across a real process
//! boundary, zero dependencies beyond `std::process`.
//!
//! The parent serializes each frame ([`crate::copy::serialize_endian`],
//! alternating byte orders so half the traffic exercises the swap-run
//! pack), frames it onto a worker's stdin ([`crate::copy::write_message`]),
//! and reads back the response frame. Each worker (`llama wire-worker`)
//! is this same binary in a loop: read a message, rebuild the view from
//! the manifest alone, advance the particles one drift step, and reply
//! *in the byte order the request arrived in* — so a cross-endian
//! request gets a cross-endian response, exactly what a heterogeneous
//! peer would want. The parent verifies every response against a
//! locally drifted oracle; the demo fails loudly on any mismatch.

use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};

use super::bench::Opts;
use super::report::Table;
use crate::array::ArrayDims;
use crate::copy::{
    deserialize, deserialize_into, read_message, serialize_endian, views_equal, write_message,
    WireMessage,
};
use crate::error::{Context, Result};
use crate::mapping::SoA;
use crate::runtime::WireEndian;
use crate::view::{alloc_view, View};
use crate::workloads::picframe::{attr_dim, frames::drift_view, CELL_IDX, FRAME_SIZE, LEAVES};
use crate::workloads::rng::SplitMix64;
use crate::{bail, ensure};

/// Time step every worker applies to a received frame.
pub const DRIFT_DT: f32 = 0.5;

/// One worker step: rebuild the view from the wire bytes, drift the
/// particles, and re-serialize in the byte order the request used.
/// A `step=` tag on the request is echoed into the reply, so
/// multiplexed clients can dispatch interleaved responses.
pub fn serve_frame(msg: &WireMessage) -> Result<WireMessage> {
    let (mut v, _) = deserialize(msg)?;
    let n = v.count();
    drift_view(&mut v, n, DRIFT_DT);
    let mut reply = serialize_endian(&v, msg.manifest.endian)?;
    reply.manifest.step = msg.manifest.step;
    Ok(reply)
}

/// The `wire-worker` request/response loop over any byte stream:
/// one framed response per framed request, clean exit at EOF.
pub fn worker_loop<R: BufRead, W: Write>(r: &mut R, w: &mut W) -> Result<()> {
    while let Some(msg) = read_message(r)? {
        write_message(w, &serve_frame(&msg)?)?;
    }
    Ok(())
}

/// Entry point of the `wire-worker` CLI command: the loop over this
/// process's stdin/stdout.
pub fn worker_main() -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    worker_loop(&mut stdin.lock(), &mut stdout.lock())
}

/// A spawned worker process with its pipe endpoints.
struct Worker {
    child: Child,
    stdin: std::process::ChildStdin,
    stdout: BufReader<std::process::ChildStdout>,
}

fn spawn_worker() -> Result<Worker> {
    let exe = std::env::current_exe().context("locating the llama binary")?;
    let mut child = Command::new(exe)
        .arg("wire-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning wire-worker")?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
    Ok(Worker { child, stdin, stdout })
}

/// Deterministic frame contents, distinct per frame (shared with the
/// socket transport demo in `wire_net`).
pub(crate) fn fill_frame<M: crate::mapping::Mapping>(v: &mut View<M, Vec<u8>>, seed: u64) {
    let mut rng = SplitMix64::new(seed ^ 0xF7A3);
    for i in 0..v.count() {
        for leaf in 0..LEAVES {
            if leaf == CELL_IDX {
                v.set::<i32>(i, leaf, (rng.next_u64() % 256) as i32);
            } else {
                v.set::<f32>(i, leaf, (rng.next_u64() % 2048) as f32 / 31.0);
            }
        }
    }
}

/// Run the multi-process frame exchange: spawn `max(2, threads)`
/// workers, round-robin the frames over them with alternating byte
/// orders, and verify every returned frame bit-for-bit against a
/// locally drifted oracle.
pub fn run(o: &Opts) -> Result<Table> {
    let workers = o.threads.unwrap_or(2).max(2);
    let frames = o.n.unwrap_or(if o.quick { 4 } else { 16 }).max(workers);
    let d = attr_dim();
    let dims = ArrayDims::linear(FRAME_SIZE);

    let mut pool = Vec::with_capacity(workers);
    for _ in 0..workers {
        pool.push(spawn_worker()?);
    }

    let mut cross = 0usize;
    let mut payload_bytes = 0usize;
    for f in 0..frames {
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, f as u64);

        // The local oracle: the same drift step the worker applies.
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, FRAME_SIZE, DRIFT_DT);

        let endian =
            if f % 2 == 0 { WireEndian::native() } else { WireEndian::native().swapped() };
        if !endian.is_native() {
            cross += 1;
        }
        let request = serialize_endian(&frame, endian)?;
        payload_bytes += request.payload_len();

        let w = &mut pool[f % workers];
        write_message(&mut w.stdin, &request).context("sending frame to worker")?;
        let Some(response) = read_message(&mut w.stdout).context("reading worker response")?
        else {
            bail!("worker {} closed its pipe before responding to frame {f}", f % workers);
        };
        ensure!(
            response.manifest.endian == endian,
            "worker replied in {:?}, request was {:?}",
            response.manifest.endian,
            endian
        );
        let mut returned = alloc_view(SoA::multi_blob(&d, dims.clone()));
        deserialize_into(&response, &mut returned)?;
        ensure!(
            views_equal(&oracle, &returned),
            "frame {f} came back wrong from worker {}",
            f % workers
        );
    }

    // Closing stdin is the shutdown signal; workers exit at EOF.
    for mut w in pool {
        drop(w.stdin);
        let status = w.child.wait().context("waiting for wire-worker")?;
        ensure!(status.success(), "wire-worker exited with {status}");
    }

    let mut t = Table::new(
        "copy::wire — multi-process picframe frame exchange",
        &["metric", "value"],
    );
    t.row(vec!["worker processes".into(), workers.to_string()]);
    t.row(vec!["frames exchanged".into(), frames.to_string()]);
    t.row(vec!["cross-endian frames".into(), cross.to_string()]);
    t.row(vec!["payload bytes sent".into(), payload_bytes.to_string()]);
    t.row(vec!["round trips verified".into(), format!("{frames}/{frames}")]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::copy::serialize;
    use crate::mapping::AoSoA;

    // The process-spawning path needs the real `llama` binary on the
    // other end of the pipe; `tests/prop_wire.rs` covers it through
    // `CARGO_BIN_EXE_llama`. Here the same protocol runs over
    // in-memory streams.

    #[test]
    fn worker_loop_drifts_and_echoes_the_request_order() {
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 7);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, FRAME_SIZE, DRIFT_DT);

        let mut requests = Vec::new();
        write_message(&mut requests, &serialize(&frame).unwrap()).unwrap();
        write_message(
            &mut requests,
            &serialize_endian(&frame, WireEndian::native().swapped()).unwrap(),
        )
        .unwrap();

        let mut responses = Vec::new();
        worker_loop(&mut std::io::Cursor::new(requests), &mut responses).unwrap();

        let mut r = std::io::Cursor::new(responses);
        let native = read_message(&mut r).unwrap().expect("native response");
        let swapped = read_message(&mut r).unwrap().expect("swapped response");
        assert!(read_message(&mut r).unwrap().is_none(), "worker answered exactly twice");
        assert_eq!(native.manifest.endian, WireEndian::native());
        assert_eq!(swapped.manifest.endian, WireEndian::native().swapped());
        assert_ne!(native.payload, swapped.payload, "orders differ on the wire");
        for resp in [native, swapped] {
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_into(&resp, &mut got).unwrap();
            assert!(views_equal(&oracle, &got));
        }
    }

    #[test]
    fn serve_frame_echoes_the_step_tag() {
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 2);
        let mut req = serialize(&frame).unwrap();
        req.manifest.step = Some(12);
        assert_eq!(serve_frame(&req).unwrap().manifest.step, Some(12));
        req.manifest.step = None;
        assert_eq!(serve_frame(&req).unwrap().manifest.step, None);
    }

    #[test]
    fn serve_frame_accepts_any_source_layout() {
        // The worker rebuilds from the manifest alone, so the sender's
        // in-memory layout is irrelevant — only the wire layout travels.
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut frame = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        fill_frame(&mut frame, 3);
        let resp = serve_frame(&serialize(&frame).unwrap()).unwrap();
        let mut oracle = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, FRAME_SIZE, DRIFT_DT);
        let mut got = alloc_view(AoSoA::new(&d, dims, 16));
        deserialize_into(&resp, &mut got).unwrap();
        assert!(views_equal(&oracle, &got));
    }
}
