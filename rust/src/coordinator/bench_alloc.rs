//! `bench-alloc` driver: allocator-extensibility measurements
//! (EXPERIMENTS.md §Alloc) — the paper's §3.8 claim ("LLAMA is
//! extensible with third-party allocators") quantified.
//!
//! Three cases, each pooled-vs-fresh:
//!
//! * **migration-churn** — repeated AoS ⇄ SoA migrations through the
//!   engine's exact path ([`migrate_with`]): a warm [`BlobPool`]
//!   serves every destination from its free lists (the `fresh allocs
//!   (warm)` column is asserted **0**) while the fresh-zeroed variant
//!   pays one allocation per destination blob per round.
//! * **picframe-reshuffle** — the fig 9 layout exchange over a frame
//!   arena: one compiled program replayed per frame, destinations
//!   pooled vs freshly zeroed.
//! * **soa-move (fig5)** — the fig 5 SoA move kernel on
//!   [`AlignedAlloc::cache_line()`] blobs vs `VecAlloc`: the paper's
//!   aligned-allocator use case on a real kernel.

use super::bench::{bench, black_box, Opts};
use super::report::{fmt_ms, Table};
use crate::array::ArrayDims;
use crate::blob::{AlignedAlloc, BlobMut, BlobPool};
use crate::copy::ProgramCache;
use crate::mapping::{Mapping, Recommendation, SoA};
use crate::view::adapt::migrate_with;
use crate::view::{alloc_view, alloc_view_with, View};
use crate::workloads::picframe::frames::ParticleStore;
use crate::workloads::picframe::{attr_dim, FRAME_SIZE};
use crate::workloads::nbody;

/// Problem sizes (quick = CI smoke).
struct Sizes {
    /// Records per view in the migration-churn case.
    migrate_n: usize,
    /// AoS ⇄ SoA round trips per timed iteration.
    rounds: usize,
    /// Particles per supercell in the reshuffle case.
    per_cell: usize,
    /// Records in the soa-move case.
    move_n: usize,
}

fn sizes(o: &Opts) -> Sizes {
    if o.quick {
        Sizes { migrate_n: o.n.unwrap_or(1 << 12), rounds: 2, per_cell: 150, move_n: 1 << 14 }
    } else {
        Sizes { migrate_n: o.n.unwrap_or(1 << 18), rounds: 4, per_cell: 1000, move_n: 1 << 20 }
    }
}

fn fill_particles<M: Mapping, B: BlobMut>(v: &mut View<M, B>, n: usize) {
    let s = nbody::init_particles(n, 41);
    nbody::llama_impl::load_state(v, &s);
}

/// Repeated AoS ⇄ SoA migration churn through [`migrate_with`] — the
/// adaptive engine's migration body. Returns `(median ns, fresh blob
/// allocations per round after warm-up)` for the pooled variant; the
/// pooled count is asserted to be zero.
fn migration_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(s.migrate_n);
    let aos = Recommendation::Aos.to_mapping(&d, dims.clone());
    let soa = Recommendation::SoaMultiBlob.to_mapping(&d, dims.clone());
    let per_round = aos.blob_count() + soa.blob_count();

    // Pooled: destinations from the pool, retired sources back to it.
    let pool = BlobPool::new();
    let cache = ProgramCache::new();
    let mut v = alloc_view_with(aos.clone(), pool.clone());
    fill_particles(&mut v, s.migrate_n);
    // Warm-up round trip: primes both size classes and the program
    // cache (also what `bench`'s warmup iteration repeats).
    let tmp = migrate_with(&cache, &v, soa.clone(), &pool, Some(1));
    v = migrate_with(&cache, &tmp, aos.clone(), &pool, Some(1));
    drop(tmp);
    let warm_misses = pool.stats().misses;
    let r = bench("alloc migration pooled", 1, o.iters, || {
        for _ in 0..s.rounds {
            let mid = migrate_with(&cache, &v, soa.clone(), &pool, Some(1));
            v = migrate_with(&cache, &mid, aos.clone(), &pool, Some(1));
        }
        black_box(v.blobs());
    });
    let fresh = pool.stats().misses - warm_misses;
    assert_eq!(fresh, 0, "warmed pool allocated {fresh} fresh blobs during migration churn");
    t.row(vec![
        "migration-churn".into(),
        "pooled".into(),
        fmt_ms(r.median_ns),
        fresh.to_string(),
    ]);

    // Fresh-zeroed: every destination is a brand-new zeroed Vec.
    let cache = ProgramCache::new();
    let mut v = alloc_view(aos.clone());
    fill_particles(&mut v, s.migrate_n);
    let r = bench("alloc migration fresh", 1, o.iters, || {
        for _ in 0..s.rounds {
            let mid = migrate_with(&cache, &v, soa.clone(), &crate::blob::VecAlloc, Some(1));
            v = migrate_with(&cache, &mid, aos.clone(), &crate::blob::VecAlloc, Some(1));
        }
        black_box(v.blobs());
    });
    t.row(vec![
        "migration-churn".into(),
        "fresh-zeroed".into(),
        fmt_ms(r.median_ns),
        // Unit-labelled: the pooled row is a measured post-warm-up
        // total; this is the per-round-trip allocation count by
        // construction (VecAlloc keeps no stats).
        format!("{per_round}/round"),
    ]);
}

/// The fig 9 layout exchange (`ParticleStore::reshuffle`) with pooled
/// vs fresh destination frames.
fn reshuffle_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = attr_dim();
    let dims = ArrayDims::linear(FRAME_SIZE);
    let grid = [2usize, 2, 2];

    let pool = BlobPool::new();
    let mut st =
        ParticleStore::with_allocator(SoA::multi_blob(&d, dims.clone()), grid, pool.clone());
    st.populate(s.per_cell, 99);
    // Warm-up: one reshuffle allocates the AoSoA frames, dropping it
    // parks them on the free lists.
    drop(st.reshuffle(crate::mapping::AoSoA::new(&d, dims.clone(), 32)));
    let warm_misses = pool.stats().misses;
    let frames = st.frame_count();
    let r = bench("alloc reshuffle pooled", 1, o.iters, || {
        let shuffled = st.reshuffle(crate::mapping::AoSoA::new(&d, dims.clone(), 32));
        black_box(shuffled.particle_count());
    });
    let fresh = pool.stats().misses - warm_misses;
    assert_eq!(fresh, 0, "warmed pool allocated {fresh} fresh blobs during reshuffle");
    t.row(vec![
        "picframe-reshuffle".into(),
        "pooled".into(),
        fmt_ms(r.median_ns),
        fresh.to_string(),
    ]);

    let mut plain = ParticleStore::new(SoA::multi_blob(&d, dims.clone()), grid);
    plain.populate(s.per_cell, 99);
    let r = bench("alloc reshuffle fresh", 1, o.iters, || {
        let shuffled = plain.reshuffle(crate::mapping::AoSoA::new(&d, dims.clone(), 32));
        black_box(shuffled.particle_count());
    });
    t.row(vec![
        "picframe-reshuffle".into(),
        "fresh-zeroed".into(),
        fmt_ms(r.median_ns),
        // One single-blob AoSoA frame allocation per live frame, per
        // reshuffle (unit-labelled like the migration row).
        format!("{frames}/reshuffle"),
    ]);
}

/// The fig 5 SoA move kernel over cache-line-aligned blobs vs Vec —
/// allocation policy as a kernel-facing property (dense SoA leaves
/// start on cache-line boundaries, the paper's vectorized-load case).
fn move_case(s: &Sizes, o: &Opts, t: &mut Table) {
    let d = nbody::particle_dim();
    let dims = ArrayDims::linear(s.move_n);

    let mut aligned =
        alloc_view_with(SoA::multi_blob(&d, dims.clone()), AlignedAlloc::cache_line());
    fill_particles(&mut aligned, s.move_n);
    let r = bench("alloc move aligned", 1, o.iters, || {
        nbody::llama_impl::mv(&mut aligned);
        black_box(aligned.blobs());
    });
    t.row(vec![
        "soa-move (fig5)".into(),
        "AlignedAlloc::cache_line()".into(),
        fmt_ms(r.median_ns),
        "-".into(),
    ]);

    let mut plain = alloc_view(SoA::multi_blob(&d, dims.clone()));
    fill_particles(&mut plain, s.move_n);
    let r = bench("alloc move vec", 1, o.iters, || {
        nbody::llama_impl::mv(&mut plain);
        black_box(plain.blobs());
    });
    t.row(vec!["soa-move (fig5)".into(), "VecAlloc".into(), fmt_ms(r.median_ns), "-".into()]);
}

/// Run the allocator comparison (pooled vs fresh-zeroed migration and
/// reshuffle churn, aligned vs Vec move kernel).
pub fn run(o: &Opts) -> Table {
    let s = sizes(o);
    let mut t = Table::new(
        format!(
            "blob::pool — pooled vs fresh allocation ({} records, {} round-trips/iter, {})",
            s.migrate_n,
            s.rounds,
            if o.quick { "quick" } else { "full" }
        ),
        &["case", "variant", "ms", "fresh allocs (warm)"],
    );
    migration_case(&s, o, &mut t);
    reshuffle_case(&s, o, &mut t);
    move_case(&s, o, &mut t);
    t
}

/// Serialize a bench-alloc run as the `BENCH_alloc.json` baseline.
/// Refuses structurally to emit a document missing any (case, variant)
/// row or whose pooled rows allocated fresh blobs after warm-up.
pub fn baseline_json_checked(o: &Opts) -> crate::error::Result<String> {
    let t = run(o);
    for (case, variants) in [
        ("migration-churn", &["pooled", "fresh-zeroed"][..]),
        ("picframe-reshuffle", &["pooled", "fresh-zeroed"][..]),
        ("soa-move (fig5)", &["AlignedAlloc::cache_line()", "VecAlloc"][..]),
    ] {
        for variant in variants {
            crate::ensure!(
                t.rows.iter().any(|r| r[0] == case && r[1] == *variant),
                "bench-alloc: missing {case}/{variant} row"
            );
        }
    }
    for r in &t.rows {
        crate::ensure!(
            r[1] != "pooled" || r[3] == "0",
            "bench-alloc: pooled row {} allocated fresh blobs after warm-up ({})",
            r[0],
            r[3]
        );
    }
    Ok(format!(
        "{{\n  \"figure\": \"bench_alloc\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"ms (median)\",\n  \"alloc\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        t.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> Opts {
        let mut o = Opts::quick();
        o.iters = 1;
        o.n = Some(512);
        o
    }

    #[test]
    fn all_cases_produce_both_variants_and_pooled_allocates_zero() {
        let t = run(&tiny_opts());
        assert_eq!(t.rows.len(), 6);
        for r in &t.rows {
            assert_eq!(r.len(), 4, "ragged row {r:?}");
            if r[1] == "pooled" {
                assert_eq!(r[3], "0", "pooled row {} must allocate 0 after warm-up", r[0]);
            }
        }
        assert!(t.rows.iter().any(|r| r[1] == "AlignedAlloc::cache_line()"));
    }

    #[test]
    fn baseline_json_gates_on_rows_and_zero_alloc() {
        let j = baseline_json_checked(&tiny_opts()).expect("complete run passes");
        assert!(j.contains("\"figure\": \"bench_alloc\""), "{j}");
        assert!(j.contains("\"alloc\": {"), "{j}");
        assert!(j.contains("migration-churn"), "{j}");
        assert!(!j.contains("\"rows\": []"), "{j}");
    }
}
