//! Minimal benchmark harness (the vendored crate set has no criterion):
//! warmup + N timed iterations, median/min/mean statistics, and
//! throughput helpers. All figure drivers measure through this.

use std::time::Instant;

/// Statistics of one measured case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub min_ns: f64,
    pub mean_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_s(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// GiB/s given bytes moved per iteration.
    pub fn gib_per_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.median_s() / (1024.0 * 1024.0 * 1024.0)
    }

    /// Million of `unit` per second (e.g. MLUPS for lbm).
    pub fn m_per_s(&self, units: usize) -> f64 {
        units as f64 / self.median_s() / 1e6
    }
}

/// Run `f` `warmup + iters` times, timing the last `iters`.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if iters % 2 == 1 {
        samples[iters / 2]
    } else {
        0.5 * (samples[iters / 2 - 1] + samples[iters / 2])
    };
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        min_ns: samples[0],
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        max_ns: *samples.last().unwrap(),
    }
}

/// Keep a value observably alive (prevent dead-code elimination of the
/// benched computation).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Global knobs every figure driver accepts.
#[derive(Debug, Clone)]
pub struct Opts {
    /// Scale factor: quick (CI) vs full (paper-like) problem sizes.
    pub quick: bool,
    /// Worker threads for parallel variants (None = all cores).
    pub threads: Option<usize>,
    /// Optional problem-size override.
    pub n: Option<usize>,
    /// Timed iterations per case.
    pub iters: usize,
    /// Artifacts directory (fig 6).
    pub artifacts: String,
    /// Socket address for the wire transport commands: the bind
    /// address of `wire-serve`, the server `wire-connect` joins
    /// (None = spawn a private server on an ephemeral port).
    pub addr: Option<String>,
    /// Distributed halo schedule: split-phase overlapped exchange
    /// instead of the blocking ring.
    pub overlap: bool,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            quick: false,
            threads: None,
            n: None,
            iters: 5,
            artifacts: "artifacts".into(),
            addr: None,
            overlap: false,
        }
    }
}

impl Opts {
    pub fn quick() -> Self {
        Opts { quick: true, iters: 3, ..Default::default() }
    }

    pub fn threads(&self) -> usize {
        self.threads
            .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_orders_stats() {
        let mut count = 0usize;
        let r = bench("spin", 1, 5, || {
            count += 1;
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(count, 6); // warmup + iters
        assert_eq!(r.iters, 5);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
        assert!(r.min_ns > 0.0);
    }

    #[test]
    fn throughput_conversions() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 1e9, // 1 s
            min_ns: 1e9,
            mean_ns: 1e9,
            max_ns: 1e9,
        };
        assert!((r.gib_per_s(1 << 30) - 1.0).abs() < 1e-12);
        assert!((r.m_per_s(2_000_000) - 2.0).abs() < 1e-12);
        assert!((r.median_ms() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn opts_defaults() {
        let o = Opts::default();
        assert!(!o.quick);
        assert!(o.threads() >= 1);
        assert!(Opts::quick().quick);
    }
}
