//! Distributed lbm halo exchange over localhost TCP (wire phases 2
//! and 3): the x-slab decomposition of
//! [`crate::workloads::lbm::halo`] spread across real worker
//! *processes*.
//!
//! Topology: the parent spawns `workers` copies of this binary
//! (`llama halo-worker`). Each worker binds an ephemeral port and
//! announces `halo-listening <addr>` on stdout. The parent dials every
//! worker, sends a `halo-parent` hello, a `halo-init` line naming the
//! step count, the exchange mode, and the right neighbour's address,
//! and the worker's initial local lattice (ghost planes included) as
//! one whole-view wire message. Each worker then dials its right
//! neighbour with a `halo-peer` hello, forming a ring: every worker
//! holds one socket it dialed (to its right neighbour) and one it
//! accepted (from its left neighbour). All ring and parent sockets
//! carry [`WIRE_IO_TIMEOUT`] deadlines, so a hung peer fails loudly.
//!
//! Two exchange schedules share the ring:
//!
//! - **Blocking** (`overlap=0`, the phase-2 schedule): every step,
//!   each worker pushes its two boundary planes of the *current*
//!   state on a scoped sender thread while the main thread lands the
//!   two arriving planes on its ghost cells, then runs the unmodified
//!   [`step`] kernel over the whole slab.
//! - **Overlapped** (`overlap=1`, the phase-3 split-phase schedule):
//!   each ring socket is wrapped in a multiplexed
//!   [`PeerLink`]; every step the worker computes its boundary planes
//!   first ([`step_boundary`]), queues them as `step=`-tagged frames,
//!   and computes the interior ([`step_interior`]) while a comm
//!   thread collects the next step's ghosts into a double-buffered
//!   [`GhostArena`] — communication hides behind the interior sweep.
//!
//! After the final step each worker ships its interior back to the
//! parent, which reassembles the global lattice by manifest range.
//! Both schedules are **bit-identical** to the single-process kernel
//! (see the differential tests in `tests/prop_halo.rs`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Command, Stdio};
use std::time::Instant;

use super::bench::Opts;
use super::report::Table;
use super::wire_net::{configure_stream, DeadlineRead, PeerLink, WIRE_IO_TIMEOUT};
use crate::copy::{deserialize, read_message, serialize, write_message};
use crate::error::{Context, Result};
use crate::mapping::{DynMapping, WireRecipe};
use crate::view::{alloc_view, View};
use crate::workloads::lbm::halo::{
    boundary_messages, boundary_messages_tagged, extract_local, interior_message, local_dims,
    partition_x, place_interior, receive_ghost, step_boundary, step_interior, GhostArena,
    GhostSide,
};
use crate::workloads::lbm::step::{init, step};
use crate::workloads::lbm::{cell_dim, Geometry};
use crate::{bail, ensure};

/// The worker's announce line prefix on stdout.
pub const LISTENING_PREFIX: &str = "halo-listening ";

/// Who is on the other end of an accepted connection.
enum Hello {
    Parent,
    Peer,
}

/// Accept a connection and read its one-line hello **unbuffered**
/// (byte at a time off the raw socket), so not a single byte beyond
/// the newline is consumed — the stream can then be handed to a
/// [`PeerLink`] or a fresh `BufReader` without losing frames a fast
/// peer may already have sent.
fn accept_hello(listener: &TcpListener) -> Result<(Hello, TcpStream)> {
    let (stream, _) = listener.accept().context("accepting halo connection")?;
    configure_stream(&stream, WIRE_IO_TIMEOUT)?;
    let mut hello = String::new();
    let mut byte = [0u8; 1];
    loop {
        let n = (&stream).read(&mut byte).context("reading the halo hello line")?;
        ensure!(n == 1, "halo peer closed during its hello");
        if byte[0] == b'\n' {
            break;
        }
        ensure!(hello.len() < 64, "halo hello line too long ({hello:?}…)");
        hello.push(byte[0] as char);
    }
    let kind = match hello.trim() {
        "halo-parent" => Hello::Parent,
        "halo-peer" => Hello::Peer,
        other => bail!("unexpected halo hello {other:?}"),
    };
    Ok((kind, stream))
}

/// Pull `key=value` out of a `halo-init` line, if present.
fn init_field_opt<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|t| t.strip_prefix('=')))
}

/// Pull a required `key=value` out of a `halo-init` line.
fn init_field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    init_field_opt(line, key)
        .with_context(|| format!("halo-init line missing {key}= ({line:?})"))
}

/// The phase-3 split-phase worker loop: boundary planes first, their
/// frames queued on the peer links, the interior swept while a comm
/// thread lands the next step's ghosts in the arena. The arena's
/// ownership rule guarantees no ghost is overwritten before its
/// consumer takes it; the links' tag dispatch guarantees frames match
/// steps no matter how the ring interleaves.
fn worker_steps_overlapped(
    src: &mut View<DynMapping, Vec<u8>>,
    dst: &mut View<DynMapping, Vec<u8>>,
    steps: usize,
    right_stream: TcpStream,
    left_stream: TcpStream,
) -> Result<()> {
    let right = PeerLink::from_stream(right_stream, WIRE_IO_TIMEOUT)?;
    let left = PeerLink::from_stream(left_stream, WIRE_IO_TIMEOUT)?;
    let mut arena = GhostArena::default();
    for k in 0..steps {
        step_boundary(&*src, dst);
        let (first, last) = boundary_messages_tagged(dst, k + 1)?;
        std::thread::scope(|scope| -> Result<()> {
            let comm = scope.spawn(|| -> Result<()> {
                // Queued sends return immediately; the thread's real
                // work is waiting for the inbound step-(k+1) ghosts
                // while the main thread sweeps the interior.
                right.send(last)?;
                left.send(first)?;
                arena.deposit(GhostSide::Left, k + 1, left.recv_step(k + 1)?)?;
                arena.deposit(GhostSide::Right, k + 1, right.recv_step(k + 1)?)?;
                Ok(())
            });
            step_interior(&*src, dst);
            comm.join().expect("halo comm thread panicked")
        })?;
        std::mem::swap(src, dst);
        let lmsg = arena.take(GhostSide::Left, k + 1)?;
        receive_ghost(src, &lmsg, GhostSide::Left)?;
        let rmsg = arena.take(GhostSide::Right, k + 1)?;
        receive_ghost(src, &rmsg, GhostSide::Right)?;
    }
    Ok(())
}

/// The phase-2 blocking worker loop: exchange the *current* state's
/// boundary planes, then step the whole slab.
fn worker_steps_blocking(
    src: &mut View<DynMapping, Vec<u8>>,
    dst: &mut View<DynMapping, Vec<u8>>,
    steps: usize,
    right_stream: TcpStream,
    left_stream: TcpStream,
) -> Result<()> {
    let mut rw = right_stream.try_clone().context("cloning the halo socket")?;
    let mut rr = BufReader::new(DeadlineRead::new(right_stream, WIRE_IO_TIMEOUT));
    let mut lw = left_stream.try_clone().context("cloning the halo socket")?;
    let mut lr = BufReader::new(DeadlineRead::new(left_stream, WIRE_IO_TIMEOUT));
    for _ in 0..steps {
        let (first, last) = boundary_messages(src)?;
        std::thread::scope(|scope| -> Result<()> {
            // Push on a sender thread while the main thread receives:
            // every ring member does both at once, so no step can
            // deadlock on a full socket buffer.
            let sender = scope.spawn(|| -> Result<()> {
                write_message(&mut rw, &last)?;
                write_message(&mut lw, &first)?;
                Ok(())
            });
            let lmsg = read_message(&mut lr)?.context("left neighbour closed")?;
            receive_ghost(src, &lmsg, GhostSide::Left)?;
            let rmsg = read_message(&mut rr)?.context("right neighbour closed")?;
            receive_ghost(src, &rmsg, GhostSide::Right)?;
            sender.join().expect("halo sender panicked")
        })?;
        step(&*src, dst);
        std::mem::swap(src, dst);
    }
    Ok(())
}

/// Entry point of the `halo-worker` CLI command: one ring member.
pub fn worker_main() -> Result<()> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding halo-worker")?;
    let local = listener.local_addr().context("reading the bound address")?;
    println!("{LISTENING_PREFIX}{local}");
    std::io::stdout().flush().context("announcing the halo-worker address")?;

    // The parent usually dials first, but a fast left peer is
    // tolerated: stash it until the parent's hello shows up.
    let mut left = None;
    let parent = loop {
        let (kind, stream) = accept_hello(&listener)?;
        match kind {
            Hello::Parent => break stream,
            Hello::Peer => {
                ensure!(left.is_none(), "two left peers dialed in");
                left = Some(stream);
            }
        }
    };
    let mut pw = parent.try_clone().context("cloning the halo socket")?;
    let mut pr = BufReader::new(DeadlineRead::new(parent, WIRE_IO_TIMEOUT));

    // Read the assignment and the initial lattice BEFORE dialing out,
    // so the parent's sequential init writes never block on a full
    // socket buffer.
    let mut init_line = String::new();
    pr.read_line(&mut init_line).context("reading the halo-init line")?;
    ensure!(init_line.starts_with("halo-init "), "unexpected init line {init_line:?}");
    let steps: usize =
        init_field(&init_line, "steps")?.parse().context("halo-init steps")?;
    let right_addr = init_field(&init_line, "right")?.to_string();
    // Tolerant: a phase-2 parent sends no overlap= field, meaning the
    // blocking schedule.
    let overlap = match init_field_opt(&init_line, "overlap") {
        None | Some("0") => false,
        Some("1") => true,
        Some(other) => bail!("halo-init overlap={other:?} is not 0 or 1"),
    };
    let msg = read_message(&mut pr)?.context("parent closed before sending the lattice")?;
    let (mut src, _) = deserialize(&msg)?;
    let mut dst =
        alloc_view(msg.manifest.recipe.build(&msg.manifest.record, msg.manifest.dims.clone()));

    // Dial the right neighbour. Its listener is already bound and
    // announced, so the TCP backlog holds our hello until it accepts —
    // no ordering constraint even for the two-worker ring.
    let rstream = TcpStream::connect(&right_addr)
        .with_context(|| format!("dialing right neighbour {right_addr}"))?;
    configure_stream(&rstream, WIRE_IO_TIMEOUT)?;
    {
        let mut hello = rstream.try_clone().context("cloning the halo socket")?;
        writeln!(hello, "halo-peer").context("sending the halo hello")?;
        hello.flush().context("flushing the halo hello")?;
    }

    // Wait for the left neighbour's dial if it has not arrived yet.
    let lstream = match left {
        Some(stream) => stream,
        None => loop {
            let (kind, stream) = accept_hello(&listener)?;
            match kind {
                Hello::Peer => break stream,
                Hello::Parent => bail!("second parent dialed in"),
            }
        },
    };

    if overlap {
        worker_steps_overlapped(&mut src, &mut dst, steps, rstream, lstream)?;
    } else {
        worker_steps_blocking(&mut src, &mut dst, steps, rstream, lstream)?;
    }

    write_message(&mut pw, &interior_message(&src)?).context("sending the interior")?;
    pw.flush().context("flushing the interior")?;
    // Linger until the parent closes the socket, keeping shutdown
    // ordering deterministic.
    let mut eof = String::new();
    let _ = pr.read_line(&mut eof);
    Ok(())
}

/// Run `steps` of the decomposed lattice across `workers` real
/// processes over localhost TCP and reassemble the global result.
/// `binary` overrides the worker executable (integration tests pass
/// `CARGO_BIN_EXE_llama`); `None` uses this process's own image.
/// `overlap` selects the split-phase schedule (phase 3) over the
/// blocking ring (phase 2); both reassemble bit-identically.
pub fn run_distributed(
    geo: &Geometry,
    steps: usize,
    workers: usize,
    binary: Option<&Path>,
    overlap: bool,
) -> Result<View<DynMapping, Vec<u8>>> {
    ensure!(workers >= 2, "distributed halo needs at least two workers (got {workers})");
    let g = geo.dims.extents();
    let (nx, ny, nz) = (g[0], g[1], g[2]);
    let slabs = partition_x(nx, workers)?;
    let d = cell_dim();
    let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut global, geo);

    let exe = match binary {
        Some(p) => p.to_path_buf(),
        None => std::env::current_exe().context("locating the llama binary")?,
    };
    let mut children = Vec::with_capacity(workers);
    let mut addrs = Vec::with_capacity(workers);
    for i in 0..workers {
        let mut child = Command::new(&exe)
            .arg("halo-worker")
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning halo-worker {i}"))?;
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .with_context(|| format!("reading halo-worker {i}'s announce line"))?;
        let Some(addr) = line.trim().strip_prefix(LISTENING_PREFIX) else {
            let _ = child.kill();
            bail!("unexpected halo-worker announce line {line:?}");
        };
        addrs.push(addr.to_string());
        children.push(child);
    }

    let mut conns = Vec::with_capacity(workers);
    for (i, &(x0, x1)) in slabs.iter().enumerate() {
        let stream = TcpStream::connect(&addrs[i])
            .with_context(|| format!("dialing halo-worker {i}"))?;
        configure_stream(&stream, WIRE_IO_TIMEOUT)?;
        let mut w = stream.try_clone().context("cloning the halo socket")?;
        let r = BufReader::new(DeadlineRead::new(stream, WIRE_IO_TIMEOUT));
        let right = &addrs[(i + 1) % workers];
        let ov = overlap as usize;
        writeln!(w, "halo-parent").context("sending the parent hello")?;
        writeln!(
            w,
            "halo-init steps={steps} workers={workers} index={i} overlap={ov} right={right}"
        )
        .context("sending the halo-init line")?;
        let mut local =
            alloc_view(WireRecipe::AosPacked.build(&d, local_dims(x0, x1, ny, nz)));
        extract_local(&global, &mut local, x0, x1);
        write_message(&mut w, &serialize(&local)?)?;
        w.flush().context("flushing the worker init")?;
        conns.push((r, w));
    }

    for (i, &(x0, _)) in slabs.iter().enumerate() {
        let msg = read_message(&mut conns[i].0)?
            .with_context(|| format!("halo-worker {i} closed before sending its interior"))?;
        place_interior(&mut global, &msg, x0)?;
    }
    drop(conns); // EOF on the parent sockets is the shutdown signal.
    for (i, mut child) in children.into_iter().enumerate() {
        let status = child.wait().with_context(|| format!("waiting for halo-worker {i}"))?;
        ensure!(status.success(), "halo-worker {i} exited with {status}");
    }
    Ok(global)
}

/// The `halo` demo: run the distributed exchange (blocking ring, or
/// split-phase overlapped with `--overlap`), verify the reassembled
/// lattice bit-for-bit against the single-process ping-pong oracle,
/// and report the exchange shape.
pub fn run(o: &Opts) -> Result<Table> {
    let workers = o.threads.unwrap_or(2).clamp(2, 4);
    let (default_nx, ny, nz) = if o.quick { (8, 6, 6) } else { (16, 12, 12) };
    let nx = o.n.unwrap_or(default_nx).max(workers);
    let steps = o.iters.max(2);
    let geo = Geometry::channel_with_sphere(nx, ny, nz, 11);

    let t0 = Instant::now();
    let got = run_distributed(&geo, steps, workers, None, o.overlap)?;
    let wall = t0.elapsed();

    let d = cell_dim();
    let mut a = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    let mut b = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut a, &geo);
    init(&mut b, &geo);
    for _ in 0..steps {
        step(&a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    ensure!(
        got.blobs() == a.blobs(),
        "distributed lattice diverged from the single-process kernel"
    );

    let plane_bytes = ny * nz * d.packed_size();
    let mut t = Table::new(
        format!("lbm halo exchange — {workers} worker processes over TCP"),
        &["metric", "value"],
    );
    t.row(vec!["lattice".into(), format!("{nx}x{ny}x{nz}")]);
    t.row(vec!["worker processes".into(), workers.to_string()]);
    t.row(vec!["steps".into(), steps.to_string()]);
    t.row(vec![
        "schedule".into(),
        if o.overlap { "overlapped (split-phase)".into() } else { "blocking ring".into() },
    ]);
    t.row(vec!["halo plane bytes".into(), plane_bytes.to_string()]);
    t.row(vec!["wall ms".into(), format!("{:.3}", wall.as_secs_f64() * 1e3)]);
    t.row(vec!["bit-identical to single-process step".into(), "yes".into()]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The process-spawning ring needs the real `llama` binary;
    // `tests/prop_halo.rs` drives it through `CARGO_BIN_EXE_llama`
    // in both schedules. The protocol pieces are unit-testable here.

    #[test]
    fn init_field_parses_and_rejects() {
        let line = "halo-init steps=3 workers=2 index=1 overlap=1 right=127.0.0.1:4040\n";
        assert_eq!(init_field(line, "steps").unwrap(), "3");
        assert_eq!(init_field(line, "overlap").unwrap(), "1");
        assert_eq!(init_field(line, "right").unwrap(), "127.0.0.1:4040");
        assert!(init_field(line, "missing").is_err());
        // A phase-2 line without overlap= still parses — the field is
        // optional and defaults to the blocking schedule.
        let legacy = "halo-init steps=3 workers=2 index=1 right=127.0.0.1:4040\n";
        assert_eq!(init_field_opt(legacy, "overlap"), None);
        assert_eq!(init_field(legacy, "steps").unwrap(), "3");
    }

    #[test]
    fn run_distributed_refuses_a_single_worker() {
        let geo = Geometry::channel_with_sphere(4, 4, 4, 3);
        for overlap in [false, true] {
            let err =
                run_distributed(&geo, 1, 1, None, overlap).unwrap_err().to_string();
            assert!(err.contains("at least two workers"), "{err}");
        }
    }
}
