//! Distributed lbm halo exchange over localhost TCP (wire phase 2):
//! the x-slab decomposition of [`crate::workloads::lbm::halo`] spread
//! across real worker *processes*.
//!
//! Topology: the parent spawns `workers` copies of this binary
//! (`llama halo-worker`). Each worker binds an ephemeral port and
//! announces `halo-listening <addr>` on stdout. The parent dials every
//! worker, sends a `halo-parent` hello, a `halo-init` line naming the
//! step count and the right neighbour's address, and the worker's
//! initial local lattice (ghost planes included) as one whole-view
//! wire message. Each worker then dials its right neighbour with a
//! `halo-peer` hello, forming a ring: every worker holds one socket it
//! dialed (to its right neighbour) and one it accepted (from its left
//! neighbour).
//!
//! Every step, each worker pushes its two boundary planes as
//! range-restricted messages — the *last* interior plane to the right
//! neighbour, the *first* to the left — on a scoped sender thread
//! while the main thread lands the two arriving planes on its ghost
//! cells, then runs the unmodified [`step`] kernel. After the final
//! step each worker ships its interior back to the parent, which
//! reassembles the global lattice by manifest range. The result is
//! **bit-identical** to the single-process kernel (see the
//! differential tests in `tests/prop_halo.rs`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Command, Stdio};
use std::time::Instant;

use super::bench::Opts;
use super::report::Table;
use crate::copy::{deserialize, read_message, serialize, write_message};
use crate::error::{Context, Result};
use crate::mapping::{DynMapping, WireRecipe};
use crate::view::{alloc_view, View};
use crate::workloads::lbm::halo::{
    boundary_messages, extract_local, interior_message, local_dims, partition_x, place_interior,
    receive_ghost, GhostSide,
};
use crate::workloads::lbm::step::{init, step};
use crate::workloads::lbm::{cell_dim, Geometry};
use crate::{bail, ensure};

/// The worker's announce line prefix on stdout.
pub const LISTENING_PREFIX: &str = "halo-listening ";

/// Who is on the other end of an accepted connection.
enum Hello {
    Parent,
    Peer,
}

fn accept_hello(listener: &TcpListener) -> Result<(Hello, BufReader<TcpStream>, TcpStream)> {
    let (stream, _) = listener.accept().context("accepting halo connection")?;
    let w = stream.try_clone().context("cloning the halo socket")?;
    let mut r = BufReader::new(stream);
    let mut hello = String::new();
    r.read_line(&mut hello).context("reading the halo hello line")?;
    let kind = match hello.trim() {
        "halo-parent" => Hello::Parent,
        "halo-peer" => Hello::Peer,
        other => bail!("unexpected halo hello {other:?}"),
    };
    Ok((kind, r, w))
}

/// Pull `key=value` out of a `halo-init` line.
fn init_field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|t| t.strip_prefix('=')))
        .with_context(|| format!("halo-init line missing {key}= ({line:?})"))
}

/// Entry point of the `halo-worker` CLI command: one ring member.
pub fn worker_main() -> Result<()> {
    let listener = TcpListener::bind("127.0.0.1:0").context("binding halo-worker")?;
    let local = listener.local_addr().context("reading the bound address")?;
    println!("{LISTENING_PREFIX}{local}");
    std::io::stdout().flush().context("announcing the halo-worker address")?;

    // The parent usually dials first, but a fast left peer is
    // tolerated: stash it until the parent's hello shows up.
    let mut left = None;
    let (mut pr, mut pw) = loop {
        let (kind, r, w) = accept_hello(&listener)?;
        match kind {
            Hello::Parent => break (r, w),
            Hello::Peer => {
                ensure!(left.is_none(), "two left peers dialed in");
                left = Some((r, w));
            }
        }
    };

    // Read the assignment and the initial lattice BEFORE dialing out,
    // so the parent's sequential init writes never block on a full
    // socket buffer.
    let mut init_line = String::new();
    pr.read_line(&mut init_line).context("reading the halo-init line")?;
    ensure!(init_line.starts_with("halo-init "), "unexpected init line {init_line:?}");
    let steps: usize =
        init_field(&init_line, "steps")?.parse().context("halo-init steps")?;
    let right_addr = init_field(&init_line, "right")?.to_string();
    let msg = read_message(&mut pr)?.context("parent closed before sending the lattice")?;
    let (mut src, _) = deserialize(&msg)?;
    let mut dst =
        alloc_view(msg.manifest.recipe.build(&msg.manifest.record, msg.manifest.dims.clone()));

    // Dial the right neighbour. Its listener is already bound and
    // announced, so the TCP backlog holds our hello until it accepts —
    // no ordering constraint even for the two-worker ring.
    let rstream = TcpStream::connect(&right_addr)
        .with_context(|| format!("dialing right neighbour {right_addr}"))?;
    let mut rw = rstream.try_clone().context("cloning the halo socket")?;
    writeln!(rw, "halo-peer").context("sending the halo hello")?;
    rw.flush().context("flushing the halo hello")?;
    let mut rr = BufReader::new(rstream);

    // Wait for the left neighbour's dial if it has not arrived yet.
    let (mut lr, mut lw) = match left {
        Some(pair) => pair,
        None => loop {
            let (kind, r, w) = accept_hello(&listener)?;
            match kind {
                Hello::Peer => break (r, w),
                Hello::Parent => bail!("second parent dialed in"),
            }
        },
    };

    for _ in 0..steps {
        let (first, last) = boundary_messages(&src)?;
        std::thread::scope(|scope| -> Result<()> {
            // Push on a sender thread while the main thread receives:
            // every ring member does both at once, so no step can
            // deadlock on a full socket buffer.
            let sender = scope.spawn(|| -> Result<()> {
                write_message(&mut rw, &last)?;
                write_message(&mut lw, &first)?;
                Ok(())
            });
            let lmsg = read_message(&mut lr)?.context("left neighbour closed")?;
            receive_ghost(&mut src, &lmsg, GhostSide::Left)?;
            let rmsg = read_message(&mut rr)?.context("right neighbour closed")?;
            receive_ghost(&mut src, &rmsg, GhostSide::Right)?;
            sender.join().expect("halo sender panicked")
        })?;
        step(&src, &mut dst);
        std::mem::swap(&mut src, &mut dst);
    }

    write_message(&mut pw, &interior_message(&src)?).context("sending the interior")?;
    pw.flush().context("flushing the interior")?;
    // Linger until the parent closes the socket, keeping shutdown
    // ordering deterministic.
    let mut eof = String::new();
    let _ = pr.read_line(&mut eof);
    Ok(())
}

/// Run `steps` of the decomposed lattice across `workers` real
/// processes over localhost TCP and reassemble the global result.
/// `binary` overrides the worker executable (integration tests pass
/// `CARGO_BIN_EXE_llama`); `None` uses this process's own image.
pub fn run_distributed(
    geo: &Geometry,
    steps: usize,
    workers: usize,
    binary: Option<&Path>,
) -> Result<View<DynMapping, Vec<u8>>> {
    ensure!(workers >= 2, "distributed halo needs at least two workers (got {workers})");
    let g = geo.dims.extents();
    let (nx, ny, nz) = (g[0], g[1], g[2]);
    let slabs = partition_x(nx, workers)?;
    let d = cell_dim();
    let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut global, geo);

    let exe = match binary {
        Some(p) => p.to_path_buf(),
        None => std::env::current_exe().context("locating the llama binary")?,
    };
    let mut children = Vec::with_capacity(workers);
    let mut addrs = Vec::with_capacity(workers);
    for i in 0..workers {
        let mut child = Command::new(&exe)
            .arg("halo-worker")
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning halo-worker {i}"))?;
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .with_context(|| format!("reading halo-worker {i}'s announce line"))?;
        let Some(addr) = line.trim().strip_prefix(LISTENING_PREFIX) else {
            let _ = child.kill();
            bail!("unexpected halo-worker announce line {line:?}");
        };
        addrs.push(addr.to_string());
        children.push(child);
    }

    let mut conns = Vec::with_capacity(workers);
    for (i, &(x0, x1)) in slabs.iter().enumerate() {
        let stream = TcpStream::connect(&addrs[i])
            .with_context(|| format!("dialing halo-worker {i}"))?;
        let mut w = stream.try_clone().context("cloning the halo socket")?;
        let r = BufReader::new(stream);
        let right = &addrs[(i + 1) % workers];
        writeln!(w, "halo-parent").context("sending the parent hello")?;
        writeln!(w, "halo-init steps={steps} workers={workers} index={i} right={right}")
            .context("sending the halo-init line")?;
        let mut local =
            alloc_view(WireRecipe::AosPacked.build(&d, local_dims(x0, x1, ny, nz)));
        extract_local(&global, &mut local, x0, x1);
        write_message(&mut w, &serialize(&local)?)?;
        w.flush().context("flushing the worker init")?;
        conns.push((r, w));
    }

    for (i, &(x0, _)) in slabs.iter().enumerate() {
        let msg = read_message(&mut conns[i].0)?
            .with_context(|| format!("halo-worker {i} closed before sending its interior"))?;
        place_interior(&mut global, &msg, x0)?;
    }
    drop(conns); // EOF on the parent sockets is the shutdown signal.
    for (i, mut child) in children.into_iter().enumerate() {
        let status = child.wait().with_context(|| format!("waiting for halo-worker {i}"))?;
        ensure!(status.success(), "halo-worker {i} exited with {status}");
    }
    Ok(global)
}

/// The `halo` demo: run the distributed exchange, verify the
/// reassembled lattice bit-for-bit against the single-process
/// ping-pong oracle, and report the exchange shape.
pub fn run(o: &Opts) -> Result<Table> {
    let workers = o.threads.unwrap_or(2).clamp(2, 4);
    let (default_nx, ny, nz) = if o.quick { (8, 6, 6) } else { (16, 12, 12) };
    let nx = o.n.unwrap_or(default_nx).max(workers);
    let steps = o.iters.max(2);
    let geo = Geometry::channel_with_sphere(nx, ny, nz, 11);

    let t0 = Instant::now();
    let got = run_distributed(&geo, steps, workers, None)?;
    let wall = t0.elapsed();

    let d = cell_dim();
    let mut a = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    let mut b = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut a, &geo);
    init(&mut b, &geo);
    for _ in 0..steps {
        step(&a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    ensure!(
        got.blobs() == a.blobs(),
        "distributed lattice diverged from the single-process kernel"
    );

    let plane_bytes = ny * nz * d.packed_size();
    let mut t = Table::new(
        format!("lbm halo exchange — {workers} worker processes over TCP"),
        &["metric", "value"],
    );
    t.row(vec!["lattice".into(), format!("{nx}x{ny}x{nz}")]);
    t.row(vec!["worker processes".into(), workers.to_string()]);
    t.row(vec!["steps".into(), steps.to_string()]);
    t.row(vec!["halo plane bytes".into(), plane_bytes.to_string()]);
    t.row(vec!["wall ms".into(), format!("{:.3}", wall.as_secs_f64() * 1e3)]);
    t.row(vec!["bit-identical to single-process step".into(), "yes".into()]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The process-spawning ring needs the real `llama` binary;
    // `tests/prop_halo.rs` drives it through `CARGO_BIN_EXE_llama`.
    // The protocol pieces are unit-testable here.

    #[test]
    fn init_field_parses_and_rejects() {
        let line = "halo-init steps=3 workers=2 index=1 right=127.0.0.1:4040\n";
        assert_eq!(init_field(line, "steps").unwrap(), "3");
        assert_eq!(init_field(line, "right").unwrap(), "127.0.0.1:4040");
        assert!(init_field(line, "missing").is_err());
    }

    #[test]
    fn run_distributed_refuses_a_single_worker() {
        let geo = Geometry::channel_with_sphere(4, 4, 4, 3);
        let err = run_distributed(&geo, 1, 1, None).unwrap_err().to_string();
        assert!(err.contains("at least two workers"), "{err}");
    }
}
