//! TCP socket transport for `copy::wire` (wire phases 2 and 3): the
//! framed message protocol of [`wire_demo`] lifted from OS pipes onto
//! `std::net` sockets, zero dependencies beyond `std`.
//!
//! `llama wire-serve` binds a listener (`--addr`, default an ephemeral
//! localhost port), announces `wire-listening <addr>` on stdout, and
//! serves `--n` connections — one framed response per framed request,
//! each connection on its own thread. `llama wire-connect` runs the
//! client side as a self-checking demo: whole-view frames over a
//! single connection (staged, then pipelined in shard-aligned chunks
//! via [`crate::copy::write_range_chunked`]), then the same view split
//! by [`crate::copy::serialize_sharded`] and exchanged as interleaved
//! `(step, range)`-tagged frames over ONE persistent [`PeerLink`],
//! every reply verified against a locally drifted oracle. Without
//! `--addr` it spawns its own server process, so `wire-connect
//! --quick` is a self-contained smoke test.
//!
//! Phase 3 adds the overlap machinery this module shares with the
//! distributed halo ring:
//!
//! - [`PeerLink`] — one persistent multiplexed connection per peer: a
//!   per-link send queue drained by a writer thread, and a receive
//!   dispatcher thread that parks out-of-order frames until a
//!   [`PeerLink::recv_step`] / [`PeerLink::recv_tagged`] caller claims
//!   them by manifest tag. This replaces the phase-2
//!   connection-per-sub-range pattern: sub-range concurrency now rides
//!   on frame interleaving, not on socket count.
//! - [`WIRE_IO_TIMEOUT`] / [`DeadlineRead`] — every transport socket
//!   carries read/write deadlines, so a silent peer surfaces as a
//!   clear "timed out" error instead of hanging the exchange forever.
//!
//! Framing is byte-identical to the pipe transport ([`read_message`]
//! and [`write_message`] know nothing about their stream), so a
//! phase-1 peer speaking whole-view messages interoperates unchanged;
//! only `range=`-carrying requests take the slab path of
//! [`serve_slab`], which echoes the request's `step=` tag so
//! multiplexed clients can dispatch replies.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::bench::Opts;
use super::report::Table;
use super::wire_demo::{self, fill_frame, DRIFT_DT};
use crate::array::ArrayDims;
use crate::copy::{
    deserialize_into, deserialize_range_into, deserialize_sharded_into, read_message,
    serialize_endian, serialize_sharded, views_equal, wire_view, write_message,
    write_range_chunked, CopyProgram, WireMessage,
};
use crate::error::{Context, Result};
use crate::mapping::SoA;
use crate::runtime::{WireEndian, WireManifest};
use crate::view::alloc_view;
use crate::workloads::picframe::{attr_dim, frames::drift_view, FRAME_SIZE};
use crate::{bail, ensure};

/// The server's announce line prefix, printed to stdout once bound —
/// parents and tests read `wire-listening <addr>` to learn the
/// ephemeral port.
pub const LISTENING_PREFIX: &str = "wire-listening ";

/// How long a transport socket may sit silent before a read or write
/// fails instead of blocking forever. Generous for real exchanges (a
/// frame arrives or the link is dead), tight enough that a peer which
/// connects and then never speaks — the classic silent-peer hang —
/// turns into a diagnosable error rather than a stuck process.
pub const WIRE_IO_TIMEOUT: Duration = Duration::from_secs(60);

/// Apply the transport deadline to both directions of a socket. Every
/// socket this module reads from or writes to goes through here.
pub fn configure_stream(stream: &TcpStream, timeout: Duration) -> Result<()> {
    stream.set_read_timeout(Some(timeout)).context("setting the socket read timeout")?;
    stream.set_write_timeout(Some(timeout)).context("setting the socket write timeout")?;
    Ok(())
}

/// A `Read` adapter that turns the OS's two timeout flavours
/// (`WouldBlock` on Unix, `TimedOut` on Windows) into one unambiguous
/// `TimedOut` error whose message names the deadline — so a stalled
/// peer surfaces as "socket read timed out after …" in the error
/// chain instead of a bare "Resource temporarily unavailable".
pub struct DeadlineRead<R> {
    inner: R,
    timeout: Duration,
}

impl<R> DeadlineRead<R> {
    pub fn new(inner: R, timeout: Duration) -> Self {
        Self { inner, timeout }
    }
}

impl<R: Read> Read for DeadlineRead<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        use std::io::ErrorKind::{TimedOut, WouldBlock};
        self.inner.read(buf).map_err(|e| match e.kind() {
            WouldBlock | TimedOut => std::io::Error::new(
                TimedOut,
                format!("socket read timed out after {:?}", self.timeout),
            ),
            _ => e,
        })
    }
}

/// The per-link outbound queue: messages park here and the writer
/// thread drains them in FIFO order, so [`PeerLink::send`] never
/// blocks on the socket. The flag tells the writer to exit once the
/// queue drains.
struct SendQueue {
    state: Mutex<(VecDeque<WireMessage>, bool)>,
    ready: Condvar,
}

/// The per-link inbound dispatcher state: frames the reader thread
/// has pulled off the socket but no receiver has claimed yet, plus
/// the terminal condition (clean EOF, timeout, or transport error)
/// that ends every pending and future receive.
#[derive(Default)]
struct InboxState {
    parked: Vec<WireMessage>,
    closed: Option<String>,
}

struct Inbox {
    state: Mutex<InboxState>,
    arrived: Condvar,
}

impl Inbox {
    fn deliver(&self, msg: WireMessage) {
        self.state.lock().expect("peer inbox poisoned").parked.push(msg);
        self.arrived.notify_all();
    }

    fn close(&self, why: String) {
        let mut s = self.state.lock().expect("peer inbox poisoned");
        if s.closed.is_none() {
            s.closed = Some(why);
        }
        drop(s);
        self.arrived.notify_all();
    }
}

/// One persistent, multiplexed connection to a peer.
///
/// A `PeerLink` owns a socket plus two service threads: a writer
/// draining the send queue, and a reader that pulls every inbound
/// frame off the wire and parks it in the inbox. Frames are claimed
/// by manifest tag — [`recv_step`](Self::recv_step) matches on the
/// `step=` key, [`recv_tagged`](Self::recv_tagged) on `(step, range)`
/// — so frames may arrive in any interleaving: an out-of-order frame
/// simply waits in the inbox until its receiver shows up, and a
/// receiver for a frame still in flight blocks until the dispatcher
/// parks it.
///
/// This is the phase-3 replacement for connection-per-sub-range:
/// where phase 2 opened N sockets to move N shards concurrently, a
/// `PeerLink` moves them as N tagged frames on one socket.
pub struct PeerLink {
    queue: Arc<SendQueue>,
    inbox: Arc<Inbox>,
    stream: TcpStream,
    writer: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

impl PeerLink {
    /// Dial `addr` and wrap the socket in a link, with `timeout` as
    /// the silence deadline in both directions.
    pub fn connect(addr: &str, timeout: Duration) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting peer link to {addr}"))?;
        Self::from_stream(stream, timeout)
    }

    /// Wrap an already-established socket (e.g. one side of an
    /// accepted halo-ring connection) in a link.
    pub fn from_stream(stream: TcpStream, timeout: Duration) -> Result<Self> {
        configure_stream(&stream, timeout)?;
        let write_half = stream.try_clone().context("cloning the peer socket for writes")?;
        let read_half = stream.try_clone().context("cloning the peer socket for reads")?;

        let queue = Arc::new(SendQueue {
            state: Mutex::new((VecDeque::new(), false)),
            ready: Condvar::new(),
        });
        let inbox =
            Arc::new(Inbox { state: Mutex::new(InboxState::default()), arrived: Condvar::new() });

        let wq = Arc::clone(&queue);
        let wi = Arc::clone(&inbox);
        let writer = std::thread::Builder::new()
            .name("wire-link-writer".into())
            .spawn(move || {
                let mut w = write_half;
                loop {
                    let msg = {
                        let mut s = wq.state.lock().expect("send queue poisoned");
                        loop {
                            if let Some(m) = s.0.pop_front() {
                                break m;
                            }
                            if s.1 {
                                return;
                            }
                            s = wq.ready.wait(s).expect("send queue poisoned");
                        }
                    };
                    if let Err(e) = write_message(&mut w, &msg) {
                        // A dead socket kills both directions: fail
                        // the inbox so receivers learn why.
                        wi.close(format!("peer link send failed: {e}"));
                        return;
                    }
                }
            })
            .context("spawning the peer link writer")?;

        let ri = Arc::clone(&inbox);
        let reader = std::thread::Builder::new()
            .name("wire-link-reader".into())
            .spawn(move || {
                let mut r = BufReader::new(DeadlineRead::new(read_half, timeout));
                loop {
                    match read_message(&mut r) {
                        Ok(Some(msg)) => ri.deliver(msg),
                        Ok(None) => {
                            ri.close("peer closed the link".into());
                            return;
                        }
                        Err(e) => {
                            ri.close(format!("peer link receive failed: {e}"));
                            return;
                        }
                    }
                }
            })
            .context("spawning the peer link reader")?;

        Ok(Self { queue, inbox, stream, writer: Some(writer), reader: Some(reader) })
    }

    /// Queue a frame for transmission. Returns as soon as the frame is
    /// parked on the send queue — the writer thread owns the socket —
    /// so a compute thread can hand off boundary frames and go
    /// straight back to work.
    pub fn send(&self, msg: WireMessage) -> Result<()> {
        let mut s = self.queue.state.lock().expect("send queue poisoned");
        ensure!(!s.1, "peer link already closed for sending");
        s.0.push_back(msg);
        drop(s);
        self.queue.ready.notify_all();
        Ok(())
    }

    /// Claim the next parked frame matching `pred`, blocking until
    /// the dispatcher parks one or the link dies (whereupon every
    /// pending receive reports the terminal cause — EOF, timeout,
    /// transport error).
    fn recv_where(
        &self,
        pred: impl Fn(&WireManifest) -> bool,
        what: &str,
    ) -> Result<WireMessage> {
        let mut s = self.inbox.state.lock().expect("peer inbox poisoned");
        loop {
            if let Some(i) = s.parked.iter().position(|m| pred(&m.manifest)) {
                return Ok(s.parked.swap_remove(i));
            }
            if let Some(why) = &s.closed {
                bail!("waiting for {what}: {why}");
            }
            s = self.inbox.arrived.wait(s).expect("peer inbox poisoned");
        }
    }

    /// Receive a frame tagged `step=<step>`, regardless of its range.
    pub fn recv_step(&self, step: usize) -> Result<WireMessage> {
        self.recv_where(|m| m.step == Some(step), &format!("a step={step} frame"))
    }

    /// Receive the frame tagged `step=<step>` covering exactly
    /// `range` — the full multiplexing address.
    pub fn recv_tagged(&self, step: usize, range: (usize, usize)) -> Result<WireMessage> {
        self.recv_where(
            |m| m.step == Some(step) && m.range == Some(range),
            &format!("a step={step} range={}..{} frame", range.0, range.1),
        )
    }
}

impl Drop for PeerLink {
    fn drop(&mut self) {
        // Close the queue; the writer drains what's left, then exits.
        {
            let mut s = self.queue.state.lock().expect("send queue poisoned");
            s.1 = true;
        }
        self.queue.ready.notify_all();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        // Shut the socket down so the reader's blocking read returns
        // (EOF at a frame boundary, an error mid-frame — either ends
        // the reader), then reap it.
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// One server step. Whole-view messages take the phase-1 path
/// ([`wire_demo::serve_frame`]). A `range=` slab is rebuilt over the
/// range length alone (the manifest's recipe over `end - begin`
/// records — [`wire_view`] already wraps cross-endian payloads in a
/// byteswap), drifted, and re-serialized under a manifest that names
/// the *original* full-view dims and range — so the reply lands back
/// on the requester's records `begin..end` via
/// [`crate::copy::deserialize_range_into`], and shard replies
/// reassemble by manifest range alone. The request's `step=` tag is
/// echoed into the reply, so multiplexed clients can dispatch replies
/// by `(step, range)` no matter how frames interleave.
pub fn serve_slab(msg: &WireMessage) -> Result<WireMessage> {
    let Some((begin, end)) = msg.manifest.range else {
        return wire_demo::serve_frame(msg);
    };
    let n = end - begin;
    let src = wire_view(msg)?;
    let mut slab =
        alloc_view(msg.manifest.recipe.build(&msg.manifest.record, ArrayDims::linear(n)));
    CopyProgram::compile_slice(src.mapping(), slab.mapping(), 0, 0, n).execute(&src, &mut slab);
    drift_view(&mut slab, n, DRIFT_DT);
    let packed = serialize_endian(&slab, msg.manifest.endian)?;
    let mut manifest = WireManifest::describe_range(
        msg.manifest.record.clone(),
        msg.manifest.dims.clone(),
        msg.manifest.recipe,
        msg.manifest.endian,
        begin,
        end,
    )?;
    manifest.step = msg.manifest.step;
    ensure!(
        manifest.blob_sizes == packed.manifest.blob_sizes,
        "slab reply payload diverged from its manifest"
    );
    Ok(WireMessage { manifest, payload: packed.payload })
}

/// Serve one accepted connection: a framed response per framed
/// request, clean exit at EOF. Shared by `wire-serve` and the loopback
/// servers the bench and tests spin up in-process. The socket carries
/// [`WIRE_IO_TIMEOUT`] in both directions, so a client that connects
/// and goes silent releases the serving thread.
pub fn serve_connection(stream: TcpStream) -> Result<()> {
    configure_stream(&stream, WIRE_IO_TIMEOUT)?;
    let mut w = stream.try_clone().context("cloning the wire socket")?;
    let mut r = BufReader::new(DeadlineRead::new(stream, WIRE_IO_TIMEOUT));
    while let Some(msg) = read_message(&mut r)? {
        write_message(&mut w, &serve_slab(&msg)?)?;
    }
    Ok(())
}

/// Accept-and-serve loop: exactly `conns` connections, one serving
/// thread each. Returns once every accepted connection has drained to
/// EOF — a bounded accept count is the server's shutdown signal.
pub fn serve_connections(listener: &TcpListener, conns: usize) -> Result<()> {
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..conns {
            let (stream, peer) = listener.accept().context("accepting wire connection")?;
            scope.spawn(move || {
                if let Err(e) = serve_connection(stream) {
                    eprintln!("wire-serve: connection {peer}: {e}");
                }
            });
        }
        Ok(())
    })
}

/// Entry point of the `wire-serve` CLI command.
pub fn serve_main(o: &Opts) -> Result<()> {
    let addr = o.addr.clone().unwrap_or_else(|| "127.0.0.1:0".to_string());
    let listener =
        TcpListener::bind(&addr).with_context(|| format!("binding wire-serve to {addr}"))?;
    let local = listener.local_addr().context("reading the bound address")?;
    println!("{LISTENING_PREFIX}{local}");
    std::io::stdout().flush().context("announcing the wire-serve address")?;
    serve_connections(&listener, o.n.unwrap_or(2))
}

/// Spawn `binary wire-serve --n <conns>` and read its announce line.
/// Public so integration tests can pass the `CARGO_BIN_EXE_llama`
/// path; the demo passes its own `current_exe`.
pub fn spawn_server(binary: &Path, conns: usize) -> Result<(Child, String)> {
    let mut child = Command::new(binary)
        .args(["wire-serve", "--n"])
        .arg(conns.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning wire-serve")?;
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().expect("piped stdout"))
        .read_line(&mut line)
        .context("reading the wire-serve announce line")?;
    let Some(addr) = line.trim().strip_prefix(LISTENING_PREFIX) else {
        let _ = child.kill();
        bail!("unexpected wire-serve announce line {line:?}");
    };
    Ok((child, addr.to_string()))
}

/// Dial the server; the pair is (buffered, deadline-classified read
/// half, write half) of one socket, both directions carrying
/// [`WIRE_IO_TIMEOUT`].
fn connect(addr: &str) -> Result<(BufReader<DeadlineRead<TcpStream>>, TcpStream)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to wire server {addr}"))?;
    configure_stream(&stream, WIRE_IO_TIMEOUT)?;
    let w = stream.try_clone().context("cloning the wire socket")?;
    Ok((BufReader::new(DeadlineRead::new(stream, WIRE_IO_TIMEOUT)), w))
}

/// The `wire-connect` demo: exchange `--iters` frames single-stream —
/// first staged (whole payload packed before the first byte moves),
/// then pipelined (the request streamed in shard-aligned chunks, the
/// socket busy while later chunks still pack) — then the same frame
/// split into `--threads` range shards and exchanged as interleaved
/// `(step, range)`-tagged frames over ONE multiplexed [`PeerLink`]
/// (alternating byte orders throughout), verifying every round trip
/// bit-for-bit against a locally drifted oracle. Joins an external
/// server via `--addr`, or spawns its own `wire-serve` child.
pub fn run(o: &Opts) -> Result<Table> {
    let shards = o.threads.unwrap_or(4).clamp(2, 8);
    let n = o.n.unwrap_or(if o.quick { FRAME_SIZE / 4 } else { FRAME_SIZE }).max(shards * 2);
    let iters = o.iters.max(2);

    let d = attr_dim();
    let dims = ArrayDims::linear(n);
    let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
    fill_frame(&mut frame, 0xC0);
    let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
    crate::copy::copy(&frame, &mut oracle);
    drift_view(&mut oracle, n, DRIFT_DT);
    let frame_bytes = serialize_endian(&frame, WireEndian::native())?.payload_len();

    let mut child = None;
    let addr = match &o.addr {
        Some(a) => a.clone(),
        None => {
            let exe = std::env::current_exe().context("locating the llama binary")?;
            let (c, a) = spawn_server(&exe, 3)?;
            child = Some(c);
            a
        }
    };

    // Case 1: whole-view frames over one connection, each payload
    // fully staged before its first byte hits the socket.
    let staged = {
        let (mut r, mut w) = connect(&addr)?;
        let t0 = Instant::now();
        for it in 0..iters {
            let endian = if it % 2 == 0 {
                WireEndian::native()
            } else {
                WireEndian::native().swapped()
            };
            write_message(&mut w, &serialize_endian(&frame, endian)?)?;
            let reply = read_message(&mut r)?.context("server closed mid-exchange")?;
            ensure!(
                reply.manifest.endian == endian,
                "reply byte order {:?}, request was {:?}",
                reply.manifest.endian,
                endian
            );
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_into(&reply, &mut got)?;
            ensure!(views_equal(&oracle, &got), "staged round trip {it} diverged");
        }
        t0.elapsed()
    };

    // Case 2: the same exchange with the request streamed in
    // shard-aligned chunks — wire memory O(chunk), the first bytes on
    // the socket while later chunks still pack. The reply comes back
    // staged with the request's step tag echoed.
    let pipelined = {
        let (mut r, mut w) = connect(&addr)?;
        let chunk = (n / 8).max(1);
        let t0 = Instant::now();
        for it in 0..iters {
            let endian = if it % 2 == 0 {
                WireEndian::native().swapped()
            } else {
                WireEndian::native()
            };
            write_range_chunked(&mut w, &frame, 0, n, endian, Some(it), chunk)?;
            let reply = read_message(&mut r)?.context("server closed mid-pipeline")?;
            ensure!(
                reply.manifest.step == Some(it),
                "pipelined reply step {:?}, request was {it}",
                reply.manifest.step
            );
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_range_into(&reply, &mut got)?;
            ensure!(views_equal(&oracle, &got), "pipelined round trip {it} diverged");
        }
        t0.elapsed()
    };

    // Case 3: the frame split into range shards, every shard an
    // interleaved `(step, range)`-tagged frame on ONE persistent
    // multiplexed link. Replies are claimed by tag — deliberately in
    // reverse send order, exercising the out-of-order parking
    // dispatcher — and reassembled by manifest range.
    let multiplexed = {
        let link = PeerLink::connect(&addr, WIRE_IO_TIMEOUT)?;
        let t0 = Instant::now();
        for it in 0..iters {
            let endian = if it % 2 == 0 {
                WireEndian::native().swapped()
            } else {
                WireEndian::native()
            };
            let mut msgs = serialize_sharded(&frame, endian, shards)?;
            let mut ranges = Vec::with_capacity(msgs.len());
            for m in &mut msgs {
                m.manifest.step = Some(it);
                ranges.push(m.manifest.range.context("sharded frame without a range")?);
            }
            for m in msgs {
                link.send(m)?;
            }
            let mut replies = Vec::with_capacity(ranges.len());
            for &range in ranges.iter().rev() {
                replies.push(link.recv_tagged(it, range)?);
            }
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_sharded_into(&replies, &mut got)?;
            ensure!(views_equal(&oracle, &got), "multiplexed round trip {it} diverged");
        }
        t0.elapsed()
    };

    if let Some(mut c) = child {
        let status = c.wait().context("waiting for wire-serve")?;
        ensure!(status.success(), "wire-serve exited with {status}");
    }

    let mib = |elapsed: Duration| {
        (frame_bytes * iters) as f64 / elapsed.as_secs_f64().max(1e-9) / (1024.0 * 1024.0)
    };
    let mut t = Table::new(
        format!(
            "copy::wire — TCP socket exchange ({n} records, {shards} shards on one multiplexed link)"
        ),
        &["case", "MiB/s", "round trips"],
    );
    t.row(vec![
        "single-stream (staged)".into(),
        format!("{:.1}", mib(staged)),
        format!("{iters}/{iters} verified"),
    ]);
    t.row(vec![
        "single-stream (pipelined)".into(),
        format!("{:.1}", mib(pipelined)),
        format!("{iters}/{iters} verified"),
    ]);
    t.row(vec![
        format!("multiplexed ({shards} shards, 1 conn)"),
        format!("{:.1}", mib(multiplexed)),
        format!("{iters}/{iters} verified"),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::copy::{serialize, serialize_range_endian};
    use crate::workloads::picframe::{CELL_IDX, LEAVES};

    #[test]
    fn serve_slab_drifts_a_range_and_replies_under_the_full_manifest() {
        let d = attr_dim();
        let dims = ArrayDims::linear(96);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 5);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, 96, DRIFT_DT);

        for endian in [WireEndian::native(), WireEndian::native().swapped()] {
            let mut request = serialize_range_endian(&frame, 16, 48, endian).unwrap();
            request.manifest.step = Some(7);
            let reply = serve_slab(&request).unwrap();
            assert_eq!(reply.manifest.range, Some((16, 48)));
            assert_eq!(reply.manifest.endian, endian);
            assert_eq!(reply.manifest.step, Some(7), "step tag must echo into the reply");

            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            crate::copy::copy(&frame, &mut got);
            deserialize_range_into(&reply, &mut got).unwrap();
            for i in 0..96 {
                let want = if (16..48).contains(&i) { &oracle } else { &frame };
                for leaf in 0..LEAVES {
                    if leaf == CELL_IDX {
                        assert_eq!(
                            got.get::<i32>(i, leaf),
                            want.get::<i32>(i, leaf),
                            "record {i} leaf {leaf} ({endian:?})"
                        );
                    } else {
                        assert_eq!(
                            got.get::<f32>(i, leaf).to_bits(),
                            want.get::<f32>(i, leaf).to_bits(),
                            "record {i} leaf {leaf} ({endian:?})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn serve_slab_matches_the_frame_path_on_whole_view_messages() {
        let d = attr_dim();
        let dims = ArrayDims::linear(32);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 9);
        let msg = serialize(&frame).unwrap();
        let a = serve_slab(&msg).unwrap();
        let b = wire_demo::serve_frame(&msg).unwrap();
        assert_eq!(a.manifest.range, None);
        assert_eq!(a.payload, b.payload);
    }

    #[test]
    fn deadline_read_classifies_timeouts_and_passes_other_errors_through() {
        struct Stall(std::io::ErrorKind);
        impl Read for Stall {
            fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(self.0, "low-level detail"))
            }
        }
        for kind in [std::io::ErrorKind::WouldBlock, std::io::ErrorKind::TimedOut] {
            let mut r = DeadlineRead::new(Stall(kind), Duration::from_millis(250));
            let e = r.read(&mut [0u8; 4]).unwrap_err();
            assert_eq!(e.kind(), std::io::ErrorKind::TimedOut);
            assert!(e.to_string().contains("timed out"), "unclassified: {e}");
        }
        let mut r = DeadlineRead::new(
            Stall(std::io::ErrorKind::ConnectionReset),
            Duration::from_millis(250),
        );
        let e = r.read(&mut [0u8; 4]).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::ConnectionReset);
        assert!(e.to_string().contains("low-level detail"));
    }

    #[test]
    fn peer_link_multiplexes_interleaved_steps_over_one_socket() {
        // Real TCP, no child process: ONE connection carrying two
        // steps' worth of shard frames, all queued before a single
        // reply is claimed. Replies are then claimed in reverse order
        // across both steps, so almost every frame parks out-of-order
        // before its receiver shows up.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_connections(&listener, 1).unwrap());

        let d = attr_dim();
        let dims = ArrayDims::linear(200);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 1);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, 200, DRIFT_DT);

        let link = PeerLink::connect(&addr, WIRE_IO_TIMEOUT).unwrap();
        let mut tags = Vec::new();
        for step in [4usize, 9] {
            let endian =
                if step == 4 { WireEndian::native().swapped() } else { WireEndian::native() };
            let mut msgs = serialize_sharded(&frame, endian, 3).unwrap();
            assert_eq!(msgs.len(), 3);
            for m in &mut msgs {
                m.manifest.step = Some(step);
                tags.push((step, m.manifest.range.unwrap()));
            }
            for m in msgs {
                link.send(m).unwrap();
            }
        }
        for &(step, range) in tags.iter().rev() {
            let reply = link.recv_tagged(step, range).unwrap();
            assert_eq!(reply.manifest.step, Some(step));
            assert_eq!(reply.manifest.range, Some(range));
        }
        // A third step claimed by step alone, proving recv_step
        // dispatch and full reassembly of the drifted replies.
        let mut msgs = serialize_sharded(&frame, WireEndian::native(), 3).unwrap();
        for m in &mut msgs {
            m.manifest.step = Some(11);
        }
        for m in msgs {
            link.send(m).unwrap();
        }
        let mut replies = Vec::new();
        for _ in 0..3 {
            replies.push(link.recv_step(11).unwrap());
        }
        drop(link);
        server.join().unwrap();

        let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
        deserialize_sharded_into(&replies, &mut got).unwrap();
        assert!(views_equal(&oracle, &got));
    }

    #[test]
    fn a_silent_peer_times_out_with_a_clear_error() {
        // The peer accepts and then never speaks. A short deadline
        // turns the would-be infinite hang into a diagnosable error
        // naming the timeout.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let link = PeerLink::connect(&addr, Duration::from_millis(150)).unwrap();
        let (silent, _) = listener.accept().unwrap();
        let err = link.recv_step(0).unwrap_err().to_string();
        assert!(err.contains("timed out"), "timeout not classified: {err}");
        drop(silent);
        drop(link);
    }
}
