//! TCP socket transport for `copy::wire` (wire phase 2): the framed
//! message protocol of [`wire_demo`] lifted from OS pipes onto
//! `std::net` sockets, zero dependencies beyond `std`.
//!
//! `llama wire-serve` binds a listener (`--addr`, default an ephemeral
//! localhost port), announces `wire-listening <addr>` on stdout, and
//! serves `--n` connections — one framed response per framed request,
//! each connection on its own thread. `llama wire-connect` runs the
//! client side as a self-checking demo: whole-view frames over a
//! single connection, then the same view split by
//! [`crate::copy::serialize_sharded`] and exchanged shard-parallel
//! over several connections at once, every reply verified against a
//! locally drifted oracle. Without `--addr` it spawns its own server
//! process, so `wire-connect --quick` is a self-contained smoke test.
//!
//! Framing is byte-identical to the pipe transport ([`read_message`]
//! and [`write_message`] know nothing about their stream), so a
//! phase-1 peer speaking whole-view messages interoperates unchanged;
//! only `range=`-carrying requests take the new slab path of
//! [`serve_slab`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::bench::Opts;
use super::report::Table;
use super::wire_demo::{self, fill_frame, DRIFT_DT};
use crate::array::ArrayDims;
use crate::copy::{
    deserialize_into, deserialize_sharded_into, read_message, serialize_endian, serialize_sharded,
    views_equal, wire_view, write_message, CopyProgram, WireMessage,
};
use crate::error::{Context, Result};
use crate::mapping::SoA;
use crate::runtime::{WireEndian, WireManifest};
use crate::view::alloc_view;
use crate::workloads::picframe::{attr_dim, frames::drift_view, FRAME_SIZE};
use crate::{bail, ensure};

/// The server's announce line prefix, printed to stdout once bound —
/// parents and tests read `wire-listening <addr>` to learn the
/// ephemeral port.
pub const LISTENING_PREFIX: &str = "wire-listening ";

/// One server step. Whole-view messages take the phase-1 path
/// ([`wire_demo::serve_frame`]). A `range=` slab is rebuilt over the
/// range length alone (the manifest's recipe over `end - begin`
/// records — [`wire_view`] already wraps cross-endian payloads in a
/// byteswap), drifted, and re-serialized under a manifest that names
/// the *original* full-view dims and range — so the reply lands back
/// on the requester's records `begin..end` via
/// [`crate::copy::deserialize_range_into`], and shard replies
/// reassemble by manifest range alone.
pub fn serve_slab(msg: &WireMessage) -> Result<WireMessage> {
    let Some((begin, end)) = msg.manifest.range else {
        return wire_demo::serve_frame(msg);
    };
    let n = end - begin;
    let src = wire_view(msg)?;
    let mut slab =
        alloc_view(msg.manifest.recipe.build(&msg.manifest.record, ArrayDims::linear(n)));
    CopyProgram::compile_slice(src.mapping(), slab.mapping(), 0, 0, n).execute(&src, &mut slab);
    drift_view(&mut slab, n, DRIFT_DT);
    let packed = serialize_endian(&slab, msg.manifest.endian)?;
    let manifest = WireManifest::describe_range(
        msg.manifest.record.clone(),
        msg.manifest.dims.clone(),
        msg.manifest.recipe,
        msg.manifest.endian,
        begin,
        end,
    )?;
    ensure!(
        manifest.blob_sizes == packed.manifest.blob_sizes,
        "slab reply payload diverged from its manifest"
    );
    Ok(WireMessage { manifest, payload: packed.payload })
}

/// Serve one accepted connection: a framed response per framed
/// request, clean exit at EOF. Shared by `wire-serve` and the loopback
/// servers the bench and tests spin up in-process.
pub fn serve_connection(stream: TcpStream) -> Result<()> {
    let mut w = stream.try_clone().context("cloning the wire socket")?;
    let mut r = BufReader::new(stream);
    while let Some(msg) = read_message(&mut r)? {
        write_message(&mut w, &serve_slab(&msg)?)?;
    }
    Ok(())
}

/// Accept-and-serve loop: exactly `conns` connections, one serving
/// thread each. Returns once every accepted connection has drained to
/// EOF — a bounded accept count is the server's shutdown signal.
pub fn serve_connections(listener: &TcpListener, conns: usize) -> Result<()> {
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..conns {
            let (stream, peer) = listener.accept().context("accepting wire connection")?;
            scope.spawn(move || {
                if let Err(e) = serve_connection(stream) {
                    eprintln!("wire-serve: connection {peer}: {e}");
                }
            });
        }
        Ok(())
    })
}

/// Entry point of the `wire-serve` CLI command.
pub fn serve_main(o: &Opts) -> Result<()> {
    let addr = o.addr.clone().unwrap_or_else(|| "127.0.0.1:0".to_string());
    let listener =
        TcpListener::bind(&addr).with_context(|| format!("binding wire-serve to {addr}"))?;
    let local = listener.local_addr().context("reading the bound address")?;
    println!("{LISTENING_PREFIX}{local}");
    std::io::stdout().flush().context("announcing the wire-serve address")?;
    serve_connections(&listener, o.n.unwrap_or(2))
}

/// Spawn `binary wire-serve --n <conns>` and read its announce line.
/// Public so integration tests can pass the `CARGO_BIN_EXE_llama`
/// path; the demo passes its own `current_exe`.
pub fn spawn_server(binary: &Path, conns: usize) -> Result<(Child, String)> {
    let mut child = Command::new(binary)
        .args(["wire-serve", "--n"])
        .arg(conns.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning wire-serve")?;
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().expect("piped stdout"))
        .read_line(&mut line)
        .context("reading the wire-serve announce line")?;
    let Some(addr) = line.trim().strip_prefix(LISTENING_PREFIX) else {
        let _ = child.kill();
        bail!("unexpected wire-serve announce line {line:?}");
    };
    Ok((child, addr.to_string()))
}

/// Dial the server; the pair is (buffered read half, write half) of
/// one socket.
fn connect(addr: &str) -> Result<(BufReader<TcpStream>, TcpStream)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to wire server {addr}"))?;
    let w = stream.try_clone().context("cloning the wire socket")?;
    Ok((BufReader::new(stream), w))
}

/// The `wire-connect` demo: exchange `--iters` frames single-stream,
/// then the same frame shard-parallel over `--threads` connections
/// (alternating byte orders throughout), verifying every round trip
/// bit-for-bit against a locally drifted oracle. Joins an external
/// server via `--addr`, or spawns its own `wire-serve` child.
pub fn run(o: &Opts) -> Result<Table> {
    let conns = o.threads.unwrap_or(4).clamp(2, 8);
    let n = o.n.unwrap_or(if o.quick { FRAME_SIZE / 4 } else { FRAME_SIZE }).max(conns * 2);
    let iters = o.iters.max(2);

    let d = attr_dim();
    let dims = ArrayDims::linear(n);
    let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
    fill_frame(&mut frame, 0xC0);
    let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
    crate::copy::copy(&frame, &mut oracle);
    drift_view(&mut oracle, n, DRIFT_DT);
    let frame_bytes = serialize_endian(&frame, WireEndian::native())?.payload_len();

    let mut child = None;
    let addr = match &o.addr {
        Some(a) => a.clone(),
        None => {
            let exe = std::env::current_exe().context("locating the llama binary")?;
            let (c, a) = spawn_server(&exe, conns + 1)?;
            child = Some(c);
            a
        }
    };

    // Case 1: whole-view frames over one connection.
    let single = {
        let (mut r, mut w) = connect(&addr)?;
        let t0 = Instant::now();
        for it in 0..iters {
            let endian = if it % 2 == 0 {
                WireEndian::native()
            } else {
                WireEndian::native().swapped()
            };
            write_message(&mut w, &serialize_endian(&frame, endian)?)?;
            let reply = read_message(&mut r)?.context("server closed mid-exchange")?;
            ensure!(
                reply.manifest.endian == endian,
                "reply byte order {:?}, request was {:?}",
                reply.manifest.endian,
                endian
            );
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_into(&reply, &mut got)?;
            ensure!(views_equal(&oracle, &got), "single-stream round trip {it} diverged");
        }
        t0.elapsed()
    };

    // Case 2: the same frame split into per-connection range slabs,
    // all sent and received concurrently, reassembled by manifest
    // range on the way back.
    let mut pairs = Vec::with_capacity(conns);
    for _ in 0..conns {
        pairs.push(connect(&addr)?);
    }
    let sharded = {
        let t0 = Instant::now();
        for it in 0..iters {
            let endian = if it % 2 == 0 {
                WireEndian::native().swapped()
            } else {
                WireEndian::native()
            };
            let msgs = serialize_sharded(&frame, endian, conns)?;
            let replies: Vec<WireMessage> = std::thread::scope(|scope| -> Result<Vec<_>> {
                let handles: Vec<_> = pairs
                    .iter_mut()
                    .zip(&msgs)
                    .map(|((r, w), msg)| {
                        scope.spawn(move || -> Result<WireMessage> {
                            write_message(w, msg)?;
                            read_message(r)?.context("server closed a shard connection")
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard exchange thread panicked"))
                    .collect()
            })?;
            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            deserialize_sharded_into(&replies, &mut got)?;
            ensure!(views_equal(&oracle, &got), "shard-parallel round trip {it} diverged");
        }
        t0.elapsed()
    };
    drop(pairs);

    if let Some(mut c) = child {
        let status = c.wait().context("waiting for wire-serve")?;
        ensure!(status.success(), "wire-serve exited with {status}");
    }

    let mib = |elapsed: Duration| {
        (frame_bytes * iters) as f64 / elapsed.as_secs_f64().max(1e-9) / (1024.0 * 1024.0)
    };
    let mut t = Table::new(
        format!("copy::wire — TCP socket exchange ({n} records, {conns} shard connections)"),
        &["case", "MiB/s", "round trips"],
    );
    t.row(vec![
        "single-stream".into(),
        format!("{:.1}", mib(single)),
        format!("{iters}/{iters} verified"),
    ]);
    t.row(vec![
        format!("shard-parallel ({conns} conns)"),
        format!("{:.1}", mib(sharded)),
        format!("{iters}/{iters} verified"),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::copy::{deserialize_range_into, serialize, serialize_range_endian};
    use crate::workloads::picframe::{CELL_IDX, LEAVES};

    #[test]
    fn serve_slab_drifts_a_range_and_replies_under_the_full_manifest() {
        let d = attr_dim();
        let dims = ArrayDims::linear(96);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 5);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, 96, DRIFT_DT);

        for endian in [WireEndian::native(), WireEndian::native().swapped()] {
            let request = serialize_range_endian(&frame, 16, 48, endian).unwrap();
            let reply = serve_slab(&request).unwrap();
            assert_eq!(reply.manifest.range, Some((16, 48)));
            assert_eq!(reply.manifest.endian, endian);

            let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
            crate::copy::copy(&frame, &mut got);
            deserialize_range_into(&reply, &mut got).unwrap();
            for i in 0..96 {
                let want = if (16..48).contains(&i) { &oracle } else { &frame };
                for leaf in 0..LEAVES {
                    if leaf == CELL_IDX {
                        assert_eq!(
                            got.get::<i32>(i, leaf),
                            want.get::<i32>(i, leaf),
                            "record {i} leaf {leaf} ({endian:?})"
                        );
                    } else {
                        assert_eq!(
                            got.get::<f32>(i, leaf).to_bits(),
                            want.get::<f32>(i, leaf).to_bits(),
                            "record {i} leaf {leaf} ({endian:?})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn serve_slab_matches_the_frame_path_on_whole_view_messages() {
        let d = attr_dim();
        let dims = ArrayDims::linear(32);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 9);
        let msg = serialize(&frame).unwrap();
        let a = serve_slab(&msg).unwrap();
        let b = wire_demo::serve_frame(&msg).unwrap();
        assert_eq!(a.manifest.range, None);
        assert_eq!(a.payload, b.payload);
    }

    #[test]
    fn loopback_socket_round_trips_sharded_frames() {
        // Real TCP, no child process: the serve loop on a thread, three
        // client connections exchanging range slabs concurrently.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_connections(&listener, 3).unwrap());

        let d = attr_dim();
        let dims = ArrayDims::linear(200);
        let mut frame = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_frame(&mut frame, 1);
        let mut oracle = alloc_view(SoA::multi_blob(&d, dims.clone()));
        crate::copy::copy(&frame, &mut oracle);
        drift_view(&mut oracle, 200, DRIFT_DT);

        let msgs = serialize_sharded(&frame, WireEndian::native().swapped(), 3).unwrap();
        assert_eq!(msgs.len(), 3);
        let mut pairs = Vec::new();
        for _ in 0..msgs.len() {
            pairs.push(connect(&addr).unwrap());
        }
        let replies: Vec<WireMessage> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .iter_mut()
                .zip(&msgs)
                .map(|((r, w), msg)| {
                    scope.spawn(move || {
                        write_message(w, msg).unwrap();
                        read_message(r).unwrap().expect("shard reply")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        drop(pairs);
        server.join().unwrap();

        let mut got = alloc_view(SoA::multi_blob(&d, dims.clone()));
        deserialize_sharded_into(&replies, &mut got).unwrap();
        assert!(views_equal(&oracle, &got));
    }
}
