//! Table reporting: aligned console output, Markdown, and CSV — the
//! figure drivers print the same rows/series the paper reports.

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>width$}", width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored Markdown rendering (pasted into EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// JSON rendering (hand-rolled — no serde in the vendored crate
    /// set): `{"title": ..., "headers": [...], "rows": [[...]]}`.
    /// Consumed by `BENCH_fig5.json` and future perf-trajectory tooling.
    pub fn to_json(&self) -> String {
        let esc = |s: &String| {
            let mut out = String::with_capacity(s.len() + 2);
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
            out
        };
        let arr = |cells: &[String]| {
            format!("[{}]", cells.iter().map(esc).collect::<Vec<_>>().join(", "))
        };
        format!(
            "{{\"title\": {}, \"headers\": {}, \"rows\": [{}]}}",
            esc(&self.title),
            arr(&self.headers),
            self.rows.iter().map(|r| arr(r)).collect::<Vec<_>>().join(", ")
        )
    }

    /// CSV rendering.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format nanoseconds human-readably (ms with 3 significant decimals).
pub fn fmt_ms(ns: f64) -> String {
    format!("{:.3}", ns / 1e6)
}

/// Format a throughput in GiB/s.
pub fn fmt_gib(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a ratio relative to a baseline (1.00 = equal).
pub fn fmt_ratio(v: f64, baseline: f64) -> String {
    if baseline > 0.0 {
        format!("{:.3}", v / baseline)
    } else {
        "-".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["layout", "ms", "ratio"]);
        t.row(vec!["AoS".into(), "10.000".into(), "1.000".into()]);
        t.row(vec!["SoA MB".into(), "6.400".into(), "0.640".into()]);
        t
    }

    #[test]
    fn text_is_aligned() {
        let txt = sample().to_text();
        assert!(txt.contains("== demo =="));
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
        // Headers and rows end at the same column.
        assert_eq!(lines[1].len(), lines[4].len());
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("### demo"));
        // header + separator + 2 rows, 4 pipes each.
        assert_eq!(md.matches('|').count(), 4 * 4);
    }

    #[test]
    fn json_shape_and_escapes() {
        let mut t = Table::new("q\"uote", &["a"]);
        t.row(vec!["line\nbreak".into()]);
        let j = t.to_json();
        assert!(j.starts_with("{\"title\": \"q\\\"uote\""), "{j}");
        assert!(j.contains("\"rows\": [[\"line\\nbreak\"]]"), "{j}");
        let j = sample().to_json();
        assert!(j.contains("\"headers\": [\"layout\", \"ms\", \"ratio\"]"), "{j}");
        assert!(j.contains("[\"SoA MB\", \"6.400\", \"0.640\"]"), "{j}");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["with,comma".into()]);
        assert!(t.to_csv().contains("\"with,comma\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ms(1_500_000.0), "1.500");
        assert_eq!(fmt_ratio(5.0, 10.0), "0.500");
        assert_eq!(fmt_ratio(5.0, 0.0), "-");
        assert_eq!(fmt_gib(1.234), "1.23");
    }
}
