//! `bench-wire` driver: program-compiled wire serialization vs naive
//! element-wise packing (EXPERIMENTS.md §Wire) — the claim that
//! `copy::wire` serialization is "just another compiled copy", so it
//! packs at strided-memcpy speed where a bespoke encoder would walk
//! the record field by field.
//!
//! Three cases, each program-vs-naive:
//!
//! * **nbody soa→wire** — a multi-blob SoA particle view packed into
//!   the dense AoS wire layout (per-leaf strided runs).
//! * **picframe aosoa→wire** — an AoSoA(32) frame arena packed into
//!   the wire layout (lane-block chunk moves).
//! * **nbody soa→wire (swapped)** — the same SoA pack targeting an
//!   opposite-endian peer: the program path compiles per-leaf
//!   [`crate::copy::CopyOp::SwapRun`]s; the naive path swaps through
//!   the `Byteswap` accessors one field at a time.
//!
//! Bit-identity between the two packers is asserted before anything is
//! timed — the speedup is only meaningful if the bytes agree.

use super::bench::{bench, black_box, BenchResult, Opts};
use super::report::{fmt_ms, Table};
use crate::array::ArrayDims;
use crate::blob::Blob;
use crate::copy::{copy_naive, deserialize_into, serialize_endian, views_equal, wire_view};
use crate::error::{Context, Result};
use crate::mapping::{AoSoA, Byteswap, DynMapping, Mapping, SoA, WireRecipe};
use crate::runtime::WireEndian;
use crate::view::{alloc_view, View};
use crate::workloads::nbody;
use crate::workloads::picframe::{attr_dim, FRAME_SIZE};
use crate::workloads::rng::SplitMix64;

/// Records per case (quick = CI smoke).
fn records(o: &Opts) -> usize {
    o.n.unwrap_or(if o.quick { 1 << 12 } else { 1 << 18 })
}

/// MiB/s from bytes moved per iteration.
fn fmt_mib_s(bytes: usize, r: &BenchResult) -> String {
    format!("{:.1}", bytes as f64 / r.median_s() / (1024.0 * 1024.0))
}

/// The wire layout the naive packer writes into: the manifest's dense
/// packed AoS, wrapped in [`Byteswap`] when the peer's order differs —
/// the same destination `serialize_endian` compiles against.
fn naive_wire_mapping<M: Mapping>(src_mapping: &M, endian: WireEndian) -> DynMapping {
    let m = WireRecipe::AosPacked.build(&src_mapping.info().dim, src_mapping.dims().clone());
    if endian.is_native() {
        m
    } else {
        Box::new(Byteswap::new(m))
    }
}

/// Element-wise pack: one mapping-accessor read + write per (leaf,
/// element) — what a hand-rolled encoder loop does.
fn naive_pack<M: Mapping, B: Blob>(src: &View<M, B>, endian: WireEndian) -> Vec<u8> {
    let mut dst = alloc_view(naive_wire_mapping(src.mapping(), endian));
    copy_naive(src, &mut dst);
    dst.blobs()[0].as_bytes().to_vec()
}

/// One (case, variant)×2 block: correctness gates, then the program
/// rows and the naive rows.
fn wire_case<M: Mapping + Clone>(
    label: &str,
    src: &View<M, Vec<u8>>,
    endian: WireEndian,
    o: &Opts,
    t: &mut Table,
) -> Result<()> {
    let msg = serialize_endian(src, endian)?;
    let bytes = msg.payload_len();
    let mut back = alloc_view(src.mapping().clone());

    // Correctness before speed: the compiled round trip restores every
    // field, and the naive packer produces the identical wire bytes.
    deserialize_into(&msg, &mut back)?;
    crate::ensure!(views_equal(src, &back), "bench-wire: {label} round trip corrupted data");
    crate::ensure!(
        naive_pack(src, endian) == msg.payload,
        "bench-wire: {label} naive and program packs disagree"
    );

    let pack = bench(&format!("{label} program pack"), 1, o.iters, || {
        black_box(serialize_endian(src, endian).unwrap().payload_len());
    });
    let unpack = bench(&format!("{label} program unpack"), 1, o.iters, || {
        deserialize_into(&msg, &mut back).unwrap();
        black_box(back.count());
    });
    let rt = bench(&format!("{label} program roundtrip"), 1, o.iters, || {
        let m = serialize_endian(src, endian).unwrap();
        deserialize_into(&m, &mut back).unwrap();
        black_box(back.count());
    });
    t.row(vec![
        label.into(),
        "program".into(),
        fmt_mib_s(bytes, &pack),
        fmt_mib_s(bytes, &unpack),
        fmt_ms(rt.median_ns),
    ]);

    let wire_m = naive_wire_mapping(src.mapping(), endian);
    let pack = bench(&format!("{label} naive pack"), 1, o.iters, || {
        let mut dst = alloc_view(&wire_m);
        copy_naive(src, &mut dst);
        black_box(dst.blobs()[0].len());
    });
    let unpack = bench(&format!("{label} naive unpack"), 1, o.iters, || {
        copy_naive(&wire_view(&msg).unwrap(), &mut back);
        black_box(back.count());
    });
    let rt = bench(&format!("{label} naive roundtrip"), 1, o.iters, || {
        let mut dst = alloc_view(&wire_m);
        copy_naive(src, &mut dst);
        copy_naive(&dst, &mut back);
        black_box(back.count());
    });
    t.row(vec![
        label.into(),
        "naive".into(),
        fmt_mib_s(bytes, &pack),
        fmt_mib_s(bytes, &unpack),
        fmt_ms(rt.median_ns),
    ]);
    Ok(())
}

/// Fill a picframe attribute view with deterministic per-particle
/// values (every leaf distinct — the frame arena analogue of
/// `nbody::init_particles`).
fn fill_attrs<M: Mapping>(v: &mut View<M, Vec<u8>>) {
    use crate::workloads::picframe::{CELL_IDX, LEAVES};
    let mut rng = SplitMix64::new(0x17E);
    for i in 0..v.count() {
        for leaf in 0..LEAVES {
            if leaf == CELL_IDX {
                v.set::<i32>(i, leaf, (rng.next_u64() % 256) as i32);
            } else {
                v.set::<f32>(i, leaf, (rng.next_u64() % 4096) as f32 / 17.0);
            }
        }
    }
}

/// Run the wire comparison (program-compiled vs element-wise pack /
/// unpack, native and cross-endian).
pub fn run(o: &Opts) -> Result<Table> {
    let n = records(o);
    let mut t = Table::new(
        format!(
            "copy::wire — compiled pack vs naive element-wise ({n} records, {})",
            if o.quick { "quick" } else { "full" }
        ),
        &["case", "variant", "pack MiB/s", "unpack MiB/s", "round-trip ms"],
    );

    let d = nbody::particle_dim();
    let mut soa = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    let state = nbody::init_particles(n, 41);
    nbody::llama_impl::load_state(&mut soa, &state);
    wire_case("nbody soa→wire", &soa, WireEndian::native(), o, &mut t)?;

    let frames = (n / FRAME_SIZE).max(1) * FRAME_SIZE;
    let mut arena = alloc_view(AoSoA::new(&attr_dim(), ArrayDims::linear(frames), 32));
    fill_attrs(&mut arena);
    wire_case("picframe aosoa→wire", &arena, WireEndian::native(), o, &mut t)?;

    wire_case("nbody soa→wire (swapped)", &soa, WireEndian::native().swapped(), o, &mut t)?;
    Ok(t)
}

/// Distributed transport rows (EXPERIMENTS.md §Wire, distributed
/// methodology): real-socket loopback round trips, every case as a
/// **paired** `(blocking)` / `(overlapped)` row so the overlap win is
/// read directly off the table:
///
/// * **tcp single-stream** — whole-view frames over one connection:
///   blocking stages the whole payload before the first byte moves;
///   overlapped streams it in shard-aligned chunks via
///   [`crate::copy::write_range_chunked`].
/// * **tcp multiplexed** — the view split by
///   [`crate::copy::serialize_sharded`] into `(step, range)`-tagged
///   frames on ONE [`crate::coordinator::wire_net::PeerLink`]:
///   blocking sends and awaits each shard in lockstep; overlapped
///   queues every shard and claims the replies by tag.
/// * **lbm halo exchange** — one step cycle across all in-process
///   workers: blocking is ghost-exchange-then-step; overlapped is the
///   split-phase schedule (`overlapped_step`: boundary planes first,
///   interior swept while ghosts move through the arenas).
///
/// The multi-*process* variants live in the `wire-connect`/`halo`
/// demos and `tests/prop_halo.rs`, where process startup would swamp
/// a median; here the protocol and copy work are what is timed.
pub fn distributed(o: &Opts) -> Result<Table> {
    use std::io::BufReader;
    use std::net::{TcpListener, TcpStream};

    use super::wire_demo::{fill_frame, DRIFT_DT};
    use super::wire_net::{self, PeerLink, WIRE_IO_TIMEOUT};
    use crate::copy::{
        deserialize_range_into, deserialize_sharded_into, read_message, serialize_sharded,
        write_message, write_range_chunked,
    };
    use crate::workloads::lbm::{self, halo};
    use crate::workloads::picframe::frames::drift_view;

    let n = records(o).min(1 << 16);
    let shards = o.threads.unwrap_or(4).clamp(2, 8);
    let mut t = Table::new(
        format!(
            "copy::wire — distributed transport ({n} records, {shards} shards, blocking vs overlapped)"
        ),
        &["case", "MiB/s", "round-trip ms"],
    );

    let ad = attr_dim();
    let dims = ArrayDims::linear(n);
    let mut frame = alloc_view(SoA::multi_blob(&ad, dims.clone()));
    fill_frame(&mut frame, 77);
    let mut oracle = alloc_view(SoA::multi_blob(&ad, dims.clone()));
    crate::copy::copy(&frame, &mut oracle);
    drift_view(&mut oracle, n, DRIFT_DT);
    let frame_bytes = serialize_endian(&frame, WireEndian::native())?.payload_len();

    // Loopback echo-drift server: staged single-stream + pipelined
    // single-stream + one multiplexed link, then it drains and joins.
    let listener = TcpListener::bind("127.0.0.1:0").context("binding the loopback server")?;
    let addr = listener.local_addr().context("reading the bound address")?.to_string();
    let server = std::thread::spawn(move || wire_net::serve_connections(&listener, 3));

    {
        let stream = TcpStream::connect(&addr).context("dialing the loopback server")?;
        let mut w = stream.try_clone().context("cloning the wire socket")?;
        let mut r = BufReader::new(stream);
        let mut got = alloc_view(SoA::multi_blob(&ad, dims.clone()));
        // Correctness gate before timing.
        write_message(&mut w, &serialize_endian(&frame, WireEndian::native())?)?;
        let reply = read_message(&mut r)?.context("loopback server closed")?;
        deserialize_into(&reply, &mut got)?;
        crate::ensure!(
            views_equal(&oracle, &got),
            "bench-wire: loopback round trip corrupted data"
        );
        let single = bench("tcp single-stream (blocking)", 1, o.iters, || {
            let msg = serialize_endian(&frame, WireEndian::native()).unwrap();
            write_message(&mut w, &msg).unwrap();
            let reply = read_message(&mut r).unwrap().expect("loopback reply");
            deserialize_into(&reply, &mut got).unwrap();
            black_box(got.count());
        });
        t.row(vec![
            "tcp single-stream (blocking)".into(),
            fmt_mib_s(frame_bytes, &single),
            fmt_ms(single.median_ns),
        ]);
    }

    {
        let stream = TcpStream::connect(&addr).context("dialing the loopback server")?;
        let mut w = stream.try_clone().context("cloning the wire socket")?;
        let mut r = BufReader::new(stream);
        let mut got = alloc_view(SoA::multi_blob(&ad, dims.clone()));
        let chunk = (n / 8).max(1);
        // Correctness gate: a chunk-streamed request reassembles to
        // the same drifted reply.
        write_range_chunked(&mut w, &frame, 0, n, WireEndian::native(), None, chunk)?;
        let reply = read_message(&mut r)?.context("loopback server closed")?;
        deserialize_range_into(&reply, &mut got)?;
        crate::ensure!(
            views_equal(&oracle, &got),
            "bench-wire: pipelined round trip corrupted data"
        );
        let piped = bench("tcp single-stream (overlapped)", 1, o.iters, || {
            write_range_chunked(&mut w, &frame, 0, n, WireEndian::native(), None, chunk).unwrap();
            let reply = read_message(&mut r).unwrap().expect("loopback reply");
            deserialize_range_into(&reply, &mut got).unwrap();
            black_box(got.count());
        });
        t.row(vec![
            "tcp single-stream (overlapped)".into(),
            fmt_mib_s(frame_bytes, &piped),
            fmt_ms(piped.median_ns),
        ]);
    }

    {
        let link = PeerLink::connect(&addr, WIRE_IO_TIMEOUT)?;
        let mut got = alloc_view(SoA::multi_blob(&ad, dims.clone()));
        let mut step_no = 0usize;
        // Correctness gate: one tagged exchange reassembles.
        {
            let mut msgs = serialize_sharded(&frame, WireEndian::native(), shards)?;
            let mut tags = Vec::new();
            for m in &mut msgs {
                m.manifest.step = Some(step_no);
                tags.push(m.manifest.range.context("sharded frame without a range")?);
            }
            for m in msgs {
                link.send(m)?;
            }
            let mut replies = Vec::new();
            for &range in &tags {
                replies.push(link.recv_tagged(step_no, range)?);
            }
            step_no += 1;
            deserialize_sharded_into(&replies, &mut got)?;
            crate::ensure!(
                views_equal(&oracle, &got),
                "bench-wire: multiplexed reassembly corrupted data"
            );
        }
        // Blocking: one shard in flight at a time — send, await, next.
        let lockstep = bench("tcp multiplexed (blocking)", 1, o.iters, || {
            let mut msgs = serialize_sharded(&frame, WireEndian::native(), shards).unwrap();
            let mut replies = Vec::with_capacity(msgs.len());
            for m in &mut msgs {
                m.manifest.step = Some(step_no);
            }
            for m in msgs {
                let range = m.manifest.range.unwrap();
                link.send(m).unwrap();
                replies.push(link.recv_tagged(step_no, range).unwrap());
            }
            step_no += 1;
            deserialize_sharded_into(&replies, &mut got).unwrap();
            black_box(got.count());
        });
        t.row(vec![
            "tcp multiplexed (blocking)".into(),
            fmt_mib_s(frame_bytes, &lockstep),
            fmt_ms(lockstep.median_ns),
        ]);
        // Overlapped: every shard queued before the first reply is
        // claimed — the frames interleave freely on the one socket.
        let queued = bench("tcp multiplexed (overlapped)", 1, o.iters, || {
            let mut msgs = serialize_sharded(&frame, WireEndian::native(), shards).unwrap();
            let mut tags = Vec::with_capacity(msgs.len());
            for m in &mut msgs {
                m.manifest.step = Some(step_no);
                tags.push(m.manifest.range.unwrap());
            }
            for m in msgs {
                link.send(m).unwrap();
            }
            let mut replies = Vec::with_capacity(tags.len());
            for &range in &tags {
                replies.push(link.recv_tagged(step_no, range).unwrap());
            }
            step_no += 1;
            deserialize_sharded_into(&replies, &mut got).unwrap();
            black_box(got.count());
        });
        crate::ensure!(
            views_equal(&oracle, &got),
            "bench-wire: multiplexed reassembly corrupted data"
        );
        t.row(vec![
            "tcp multiplexed (overlapped)".into(),
            fmt_mib_s(frame_bytes, &queued),
            fmt_ms(queued.median_ns),
        ]);
    }
    server.join().expect("loopback server thread panicked")?;

    // lbm halo exchange: one step cycle across all workers; MiB/s is
    // boundary-plane traffic over the cycle time. Blocking and
    // overlapped run the same number of deterministic cycles from the
    // same initial state, so their final lattices must agree
    // bit-for-bit — asserted below as an embedded differential check.
    let nx = if o.quick { 8 } else { 16 };
    let workers = shards.min(4);
    let geo = lbm::Geometry::channel_with_sphere(nx, 8, 8, 13);
    let d = lbm::cell_dim();
    let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    lbm::step::init(&mut global, &geo);
    let mut locals = halo::split_lattice(&global, workers)?;
    let (first, _) = halo::boundary_messages(&locals[0].src)?;
    let halo_bytes = 2 * workers * first.payload_len();
    let exchange = bench("lbm halo exchange (blocking)", 1, o.iters, || {
        halo::exchange_ghosts(&mut locals).unwrap();
        for w in &mut locals {
            lbm::step::step(&w.src, &mut w.dst);
            std::mem::swap(&mut w.src, &mut w.dst);
        }
        black_box(locals.len());
    });
    t.row(vec![
        "lbm halo exchange (blocking)".into(),
        fmt_mib_s(halo_bytes, &exchange),
        fmt_ms(exchange.median_ns),
    ]);

    let mut locals_ov = halo::split_lattice(&global, workers)?;
    let mut arenas: Vec<halo::GhostArena> =
        (0..workers).map(|_| halo::GhostArena::default()).collect();
    let mut step_no = 0usize;
    let overlapped = bench("lbm halo exchange (overlapped)", 1, o.iters, || {
        halo::overlapped_step(&mut locals_ov, &mut arenas, step_no).unwrap();
        step_no += 1;
        black_box(locals_ov.len());
    });
    for (a, b) in locals.iter().zip(&locals_ov) {
        crate::ensure!(
            a.src.blobs() == b.src.blobs(),
            "bench-wire: overlapped halo diverged from the blocking ring"
        );
    }
    t.row(vec![
        "lbm halo exchange (overlapped)".into(),
        fmt_mib_s(halo_bytes, &overlapped),
        fmt_ms(overlapped.median_ns),
    ]);
    Ok(t)
}

/// The six distributed cases every baseline must carry, as
/// `(blocking, overlapped)` pairs.
const DISTRIBUTED_CASES: [&str; 6] = [
    "tcp single-stream (blocking)",
    "tcp single-stream (overlapped)",
    "tcp multiplexed (blocking)",
    "tcp multiplexed (overlapped)",
    "lbm halo exchange (blocking)",
    "lbm halo exchange (overlapped)",
];

/// Structural gate for the distributed table: all six paired cases
/// present, no `(overlapped)` row without its `(blocking)` partner,
/// every cell a positive number.
fn check_distributed_rows(dist: &Table) -> Result<()> {
    for case in DISTRIBUTED_CASES {
        crate::ensure!(
            dist.rows.iter().any(|r| r[0] == case),
            "bench-wire: missing distributed row {case}"
        );
    }
    for r in &dist.rows {
        if let Some(stem) = r[0].strip_suffix(" (overlapped)") {
            crate::ensure!(
                dist.rows.iter().any(|b| b[0] == format!("{stem} (blocking)")),
                "bench-wire: overlapped row {:?} has no blocking partner",
                r[0]
            );
        }
        for col in [1, 2] {
            let v: f64 = r[col].parse().map_err(|_| {
                crate::error::Error::msg(format!("bench-wire: non-numeric cell {:?}", r[col]))
            })?;
            crate::ensure!(v > 0.0, "bench-wire: non-positive distributed cell in {}", r[0]);
        }
    }
    Ok(())
}

/// Serialize a bench-wire run as the `BENCH_wire.json` baseline.
/// Refuses structurally to emit a document missing any (case, variant)
/// row, any of the six paired distributed rows (an `(overlapped)` row
/// without its `(blocking)` partner is refused outright), or whose
/// throughput cells are not positive numbers.
pub fn baseline_json_checked(o: &Opts) -> Result<String> {
    let t = run(o)?;
    for case in ["nbody soa→wire", "picframe aosoa→wire", "nbody soa→wire (swapped)"] {
        for variant in ["program", "naive"] {
            crate::ensure!(
                t.rows.iter().any(|r| r[0] == case && r[1] == variant),
                "bench-wire: missing {case}/{variant} row"
            );
        }
    }
    for r in &t.rows {
        for col in [2, 3] {
            let v: f64 = r[col].parse().map_err(|_| {
                crate::error::Error::msg(format!("bench-wire: non-numeric cell {:?}", r[col]))
            })?;
            crate::ensure!(v > 0.0, "bench-wire: non-positive throughput in {}/{}", r[0], r[1]);
        }
    }
    let dist = distributed(o)?;
    check_distributed_rows(&dist)?;
    Ok(format!(
        "{{\n  \"figure\": \"bench_wire\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"MiB/s (median)\",\n  \"wire\": {},\n  \"distributed\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        t.to_json(),
        dist.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> Opts {
        let mut o = Opts::quick();
        o.iters = 1;
        o.n = Some(512);
        o
    }

    #[test]
    fn all_cases_produce_both_variants() {
        let t = run(&tiny_opts()).expect("bench-wire run");
        assert_eq!(t.rows.len(), 6);
        for r in &t.rows {
            assert_eq!(r.len(), 5, "ragged row {r:?}");
            assert!(r[2].parse::<f64>().unwrap() > 0.0, "pack MiB/s in {r:?}");
            assert!(r[3].parse::<f64>().unwrap() > 0.0, "unpack MiB/s in {r:?}");
        }
        assert!(t.rows.iter().any(|r| r[0].contains("swapped")));
    }

    #[test]
    fn distributed_rows_cover_all_six_paired_cases() {
        let t = distributed(&tiny_opts()).expect("distributed run");
        assert_eq!(t.rows.len(), 6);
        for r in &t.rows {
            assert_eq!(r.len(), 3, "ragged row {r:?}");
            assert!(r[1].parse::<f64>().unwrap() > 0.0, "MiB/s in {r:?}");
            assert!(r[2].parse::<f64>().unwrap() > 0.0, "round-trip ms in {r:?}");
        }
        for case in DISTRIBUTED_CASES {
            assert!(t.rows.iter().any(|r| r[0] == case), "missing {case}");
        }
        check_distributed_rows(&t).expect("paired table passes the gate");
    }

    #[test]
    fn distributed_gate_refuses_unpaired_and_incomplete_tables() {
        // An overlapped row with no blocking partner is refused even
        // when all six case names are nominally present elsewhere.
        let mut t = Table::new("synthetic", &["case", "MiB/s", "round-trip ms"]);
        for case in DISTRIBUTED_CASES {
            if case != "lbm halo exchange (blocking)" {
                t.row(vec![case.into(), "10.0".into(), "1.0".into()]);
            }
        }
        let err = check_distributed_rows(&t).unwrap_err().to_string();
        assert!(err.contains("lbm halo exchange (blocking)"), "{err}");

        let mut unpaired = Table::new("synthetic", &["case", "MiB/s", "round-trip ms"]);
        for case in DISTRIBUTED_CASES {
            unpaired.row(vec![case.into(), "10.0".into(), "1.0".into()]);
        }
        unpaired.row(vec!["new case (overlapped)".into(), "10.0".into(), "1.0".into()]);
        let err = check_distributed_rows(&unpaired).unwrap_err().to_string();
        assert!(err.contains("no blocking partner"), "{err}");

        let mut bad = Table::new("synthetic", &["case", "MiB/s", "round-trip ms"]);
        for case in DISTRIBUTED_CASES {
            bad.row(vec![case.into(), "0.0".into(), "1.0".into()]);
        }
        let err = check_distributed_rows(&bad).unwrap_err().to_string();
        assert!(err.contains("non-positive"), "{err}");
    }

    #[test]
    fn baseline_json_gates_on_rows_and_throughput() {
        let j = baseline_json_checked(&tiny_opts()).expect("complete run passes");
        assert!(j.contains("\"figure\": \"bench_wire\""), "{j}");
        assert!(j.contains("\"wire\": {"), "{j}");
        assert!(j.contains("\"distributed\": {"), "{j}");
        assert!(j.contains("picframe aosoa→wire"), "{j}");
        assert!(j.contains("tcp multiplexed (overlapped)"), "{j}");
        assert!(j.contains("lbm halo exchange (blocking)"), "{j}");
        assert!(!j.contains("\"rows\": []"), "{j}");
    }
}
