//! `bench-wire` driver: program-compiled wire serialization vs naive
//! element-wise packing (EXPERIMENTS.md §Wire) — the claim that
//! `copy::wire` serialization is "just another compiled copy", so it
//! packs at strided-memcpy speed where a bespoke encoder would walk
//! the record field by field.
//!
//! Three cases, each program-vs-naive:
//!
//! * **nbody soa→wire** — a multi-blob SoA particle view packed into
//!   the dense AoS wire layout (per-leaf strided runs).
//! * **picframe aosoa→wire** — an AoSoA(32) frame arena packed into
//!   the wire layout (lane-block chunk moves).
//! * **nbody soa→wire (swapped)** — the same SoA pack targeting an
//!   opposite-endian peer: the program path compiles per-leaf
//!   [`crate::copy::CopyOp::SwapRun`]s; the naive path swaps through
//!   the `Byteswap` accessors one field at a time.
//!
//! Bit-identity between the two packers is asserted before anything is
//! timed — the speedup is only meaningful if the bytes agree.

use super::bench::{bench, black_box, BenchResult, Opts};
use super::report::{fmt_ms, Table};
use crate::array::ArrayDims;
use crate::blob::Blob;
use crate::copy::{copy_naive, deserialize_into, serialize_endian, views_equal, wire_view};
use crate::error::Result;
use crate::mapping::{AoSoA, Byteswap, DynMapping, Mapping, SoA, WireRecipe};
use crate::runtime::WireEndian;
use crate::view::{alloc_view, View};
use crate::workloads::nbody;
use crate::workloads::picframe::{attr_dim, FRAME_SIZE};
use crate::workloads::rng::SplitMix64;

/// Records per case (quick = CI smoke).
fn records(o: &Opts) -> usize {
    o.n.unwrap_or(if o.quick { 1 << 12 } else { 1 << 18 })
}

/// MiB/s from bytes moved per iteration.
fn fmt_mib_s(bytes: usize, r: &BenchResult) -> String {
    format!("{:.1}", bytes as f64 / r.median_s() / (1024.0 * 1024.0))
}

/// The wire layout the naive packer writes into: the manifest's dense
/// packed AoS, wrapped in [`Byteswap`] when the peer's order differs —
/// the same destination `serialize_endian` compiles against.
fn naive_wire_mapping<M: Mapping>(src_mapping: &M, endian: WireEndian) -> DynMapping {
    let m = WireRecipe::AosPacked.build(&src_mapping.info().dim, src_mapping.dims().clone());
    if endian.is_native() {
        m
    } else {
        Box::new(Byteswap::new(m))
    }
}

/// Element-wise pack: one mapping-accessor read + write per (leaf,
/// element) — what a hand-rolled encoder loop does.
fn naive_pack<M: Mapping, B: Blob>(src: &View<M, B>, endian: WireEndian) -> Vec<u8> {
    let mut dst = alloc_view(naive_wire_mapping(src.mapping(), endian));
    copy_naive(src, &mut dst);
    dst.blobs()[0].as_bytes().to_vec()
}

/// One (case, variant)×2 block: correctness gates, then the program
/// rows and the naive rows.
fn wire_case<M: Mapping + Clone>(
    label: &str,
    src: &View<M, Vec<u8>>,
    endian: WireEndian,
    o: &Opts,
    t: &mut Table,
) -> Result<()> {
    let msg = serialize_endian(src, endian)?;
    let bytes = msg.payload_len();
    let mut back = alloc_view(src.mapping().clone());

    // Correctness before speed: the compiled round trip restores every
    // field, and the naive packer produces the identical wire bytes.
    deserialize_into(&msg, &mut back)?;
    crate::ensure!(views_equal(src, &back), "bench-wire: {label} round trip corrupted data");
    crate::ensure!(
        naive_pack(src, endian) == msg.payload,
        "bench-wire: {label} naive and program packs disagree"
    );

    let pack = bench(&format!("{label} program pack"), 1, o.iters, || {
        black_box(serialize_endian(src, endian).unwrap().payload_len());
    });
    let unpack = bench(&format!("{label} program unpack"), 1, o.iters, || {
        deserialize_into(&msg, &mut back).unwrap();
        black_box(back.count());
    });
    let rt = bench(&format!("{label} program roundtrip"), 1, o.iters, || {
        let m = serialize_endian(src, endian).unwrap();
        deserialize_into(&m, &mut back).unwrap();
        black_box(back.count());
    });
    t.row(vec![
        label.into(),
        "program".into(),
        fmt_mib_s(bytes, &pack),
        fmt_mib_s(bytes, &unpack),
        fmt_ms(rt.median_ns),
    ]);

    let wire_m = naive_wire_mapping(src.mapping(), endian);
    let pack = bench(&format!("{label} naive pack"), 1, o.iters, || {
        let mut dst = alloc_view(&wire_m);
        copy_naive(src, &mut dst);
        black_box(dst.blobs()[0].len());
    });
    let unpack = bench(&format!("{label} naive unpack"), 1, o.iters, || {
        copy_naive(&wire_view(&msg).unwrap(), &mut back);
        black_box(back.count());
    });
    let rt = bench(&format!("{label} naive roundtrip"), 1, o.iters, || {
        let mut dst = alloc_view(&wire_m);
        copy_naive(src, &mut dst);
        copy_naive(&dst, &mut back);
        black_box(back.count());
    });
    t.row(vec![
        label.into(),
        "naive".into(),
        fmt_mib_s(bytes, &pack),
        fmt_mib_s(bytes, &unpack),
        fmt_ms(rt.median_ns),
    ]);
    Ok(())
}

/// Fill a picframe attribute view with deterministic per-particle
/// values (every leaf distinct — the frame arena analogue of
/// `nbody::init_particles`).
fn fill_attrs<M: Mapping>(v: &mut View<M, Vec<u8>>) {
    use crate::workloads::picframe::{CELL_IDX, LEAVES};
    let mut rng = SplitMix64::new(0x17E);
    for i in 0..v.count() {
        for leaf in 0..LEAVES {
            if leaf == CELL_IDX {
                v.set::<i32>(i, leaf, (rng.next_u64() % 256) as i32);
            } else {
                v.set::<f32>(i, leaf, (rng.next_u64() % 4096) as f32 / 17.0);
            }
        }
    }
}

/// Run the wire comparison (program-compiled vs element-wise pack /
/// unpack, native and cross-endian).
pub fn run(o: &Opts) -> Result<Table> {
    let n = records(o);
    let mut t = Table::new(
        format!(
            "copy::wire — compiled pack vs naive element-wise ({n} records, {})",
            if o.quick { "quick" } else { "full" }
        ),
        &["case", "variant", "pack MiB/s", "unpack MiB/s", "round-trip ms"],
    );

    let d = nbody::particle_dim();
    let mut soa = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
    let state = nbody::init_particles(n, 41);
    nbody::llama_impl::load_state(&mut soa, &state);
    wire_case("nbody soa→wire", &soa, WireEndian::native(), o, &mut t)?;

    let frames = (n / FRAME_SIZE).max(1) * FRAME_SIZE;
    let mut arena = alloc_view(AoSoA::new(&attr_dim(), ArrayDims::linear(frames), 32));
    fill_attrs(&mut arena);
    wire_case("picframe aosoa→wire", &arena, WireEndian::native(), o, &mut t)?;

    wire_case("nbody soa→wire (swapped)", &soa, WireEndian::native().swapped(), o, &mut t)?;
    Ok(t)
}

/// Serialize a bench-wire run as the `BENCH_wire.json` baseline.
/// Refuses structurally to emit a document missing any (case, variant)
/// row or whose throughput cells are not positive numbers.
pub fn baseline_json_checked(o: &Opts) -> Result<String> {
    let t = run(o)?;
    for case in ["nbody soa→wire", "picframe aosoa→wire", "nbody soa→wire (swapped)"] {
        for variant in ["program", "naive"] {
            crate::ensure!(
                t.rows.iter().any(|r| r[0] == case && r[1] == variant),
                "bench-wire: missing {case}/{variant} row"
            );
        }
    }
    for r in &t.rows {
        for col in [2, 3] {
            let v: f64 = r[col].parse().map_err(|_| {
                crate::error::Error::msg(format!("bench-wire: non-numeric cell {:?}", r[col]))
            })?;
            crate::ensure!(v > 0.0, "bench-wire: non-positive throughput in {}/{}", r[0], r[1]);
        }
    }
    Ok(format!(
        "{{\n  \"figure\": \"bench_wire\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"MiB/s (median)\",\n  \"wire\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        t.to_json()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> Opts {
        let mut o = Opts::quick();
        o.iters = 1;
        o.n = Some(512);
        o
    }

    #[test]
    fn all_cases_produce_both_variants() {
        let t = run(&tiny_opts()).expect("bench-wire run");
        assert_eq!(t.rows.len(), 6);
        for r in &t.rows {
            assert_eq!(r.len(), 5, "ragged row {r:?}");
            assert!(r[2].parse::<f64>().unwrap() > 0.0, "pack MiB/s in {r:?}");
            assert!(r[3].parse::<f64>().unwrap() > 0.0, "unpack MiB/s in {r:?}");
        }
        assert!(t.rows.iter().any(|r| r[0].contains("swapped")));
    }

    #[test]
    fn baseline_json_gates_on_rows_and_throughput() {
        let j = baseline_json_checked(&tiny_opts()).expect("complete run passes");
        assert!(j.contains("\"figure\": \"bench_wire\""), "{j}");
        assert!(j.contains("\"wire\": {"), "{j}");
        assert!(j.contains("picframe aosoa→wire"), "{j}");
        assert!(!j.contains("\"rows\": []"), "{j}");
    }
}
