//! Fig 5 driver: n-body CPU update & move across memory layouts,
//! manual twins vs LLAMA mappings.
//!
//! Paper's expected shape (i7-7820X / EPYC 7702, single thread):
//! * LLAMA AoS ≈ manual AoS, LLAMA SoA MB ≈ manual SoA (zero overhead);
//! * `move`: SoA ≈ 0.65× AoS runtime (bandwidth use: 64.3% for AoS);
//! * LLAMA AoSoA single-loop is slower than manual AoSoA (the i/L,
//!   i%L split defeats vectorization) — `update_blocked` recovers it.

use super::bench::{bench, black_box, BenchResult, Opts};
use super::report::{fmt_ms, fmt_ratio, Table};
use crate::array::ArrayDims;
use crate::mapping::{AoS, AoSoA, SoA};
use crate::view::alloc_view;
use crate::workloads::nbody::{self, llama_impl, manual};

pub struct Fig5Sizes {
    pub n_update: usize,
    pub n_move: usize,
    pub move_reps: usize,
}

pub fn sizes(o: &Opts) -> Fig5Sizes {
    if o.quick {
        Fig5Sizes { n_update: o.n.unwrap_or(1024), n_move: 1 << 18, move_reps: 8 }
    } else {
        // Paper: update N=16Ki (quadratic); move uses a larger N.
        Fig5Sizes { n_update: o.n.unwrap_or(8 * 1024), n_move: 1 << 22, move_reps: 8 }
    }
}

/// Run the full fig 5 matrix; returns (update table, move table).
pub fn run(o: &Opts) -> (Table, Table) {
    let s = sizes(o);
    let d = nbody::particle_dim();
    let state_u = nbody::init_particles(s.n_update, 42);
    let state_m = nbody::init_particles(s.n_move, 43);
    let w = if o.quick { 1 } else { 2 };

    let mut update = Table::new(
        format!("fig5 update (N={}, single thread)", s.n_update),
        &["impl", "ms", "vs manual AoS"],
    );
    let mut results: Vec<BenchResult> = Vec::new();

    // Manual twins.
    {
        let mut aos = manual::NBodyAoS::from_state(&state_u);
        results.push(bench("manual AoS", w, o.iters, || {
            aos.update();
            black_box(&aos.particles);
        }));
        let mut soa = manual::NBodySoA::from_state(&state_u);
        results.push(bench("manual SoA", w, o.iters, || {
            soa.update();
            black_box(&soa.state);
        }));
        let mut a8 = manual::NBodyAoSoA::<8>::from_state(&state_u);
        results.push(bench("manual AoSoA8", w, o.iters, || {
            a8.update();
            black_box(&a8.blocks);
        }));
        let mut a16 = manual::NBodyAoSoA::<16>::from_state(&state_u);
        results.push(bench("manual AoSoA16", w, o.iters, || {
            a16.update();
            black_box(&a16.blocks);
        }));
    }

    // LLAMA layouts, identical generic kernel.
    let dims = ArrayDims::linear(s.n_update);
    macro_rules! llama_update {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_u);
            results.push(bench($name, w, o.iters, || {
                llama_impl::update(&mut v);
                black_box(v.blobs());
            }));
        }};
    }
    llama_update!("LLAMA AoS (aligned)", AoS::aligned(&d, dims.clone()));
    llama_update!("LLAMA AoS (packed)", AoS::packed(&d, dims.clone()));
    llama_update!("LLAMA SoA SB", SoA::single_blob(&d, dims.clone()));
    llama_update!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_update!("LLAMA AoSoA8", AoSoA::new(&d, dims.clone(), 8));
    llama_update!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));
    // The paper's missing piece: a mapping-aware blocked iteration.
    {
        let mut v = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        llama_impl::load_state(&mut v, &state_u);
        results.push(bench("LLAMA AoSoA16 (blocked)", w, o.iters, || {
            llama_impl::update_blocked(&mut v, 16);
            black_box(v.blobs());
        }));
    }

    let base = results[0].median_ns;
    for r in &results {
        update.row(vec![r.name.clone(), fmt_ms(r.median_ns), fmt_ratio(r.median_ns, base)]);
    }

    // ---- move phase (memory bound) ----
    let mut mv = Table::new(
        format!("fig5 move (N={}, x{} reps, single thread)", s.n_move, s.move_reps),
        &["impl", "ms", "vs manual AoS"],
    );
    let mut results: Vec<BenchResult> = Vec::new();
    {
        let mut aos = manual::NBodyAoS::from_state(&state_m);
        results.push(bench("manual AoS", w, o.iters, || {
            for _ in 0..s.move_reps {
                aos.mv();
            }
            black_box(&aos.particles);
        }));
        let mut soa = manual::NBodySoA::from_state(&state_m);
        results.push(bench("manual SoA", w, o.iters, || {
            for _ in 0..s.move_reps {
                soa.mv();
            }
            black_box(&soa.state);
        }));
        let mut a16 = manual::NBodyAoSoA::<16>::from_state(&state_m);
        results.push(bench("manual AoSoA16", w, o.iters, || {
            for _ in 0..s.move_reps {
                a16.mv();
            }
            black_box(&a16.blocks);
        }));
    }
    let dims = ArrayDims::linear(s.n_move);
    macro_rules! llama_move {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_m);
            results.push(bench($name, w, o.iters, || {
                for _ in 0..s.move_reps {
                    llama_impl::mv(&mut v);
                }
                black_box(v.blobs());
            }));
        }};
    }
    llama_move!("LLAMA AoS (aligned)", AoS::aligned(&d, dims.clone()));
    llama_move!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_move!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));

    let base = results[0].median_ns;
    for r in &results {
        mv.row(vec![r.name.clone(), fmt_ms(r.median_ns), fmt_ratio(r.median_ns, base)]);
    }
    (update, mv)
}

/// Serialize a fig 5 run as the `BENCH_fig5.json` baseline document —
/// the perf trajectory future PRs compare against (regenerate with
/// `cargo run --release -- bench-fig5`).
pub fn baseline_json(o: &Opts) -> String {
    let (update, mv) = run(o);
    format!(
        "{{\n  \"figure\": \"fig5_nbody\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"ms (median)\",\n  \"update\": {},\n  \"move\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        update.to_json(),
        mv.to_json()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_full_tables() {
        let mut o = Opts::quick();
        o.n = Some(256);
        o.iters = 1;
        let (u, m) = run(&o);
        assert_eq!(u.rows.len(), 11);
        assert_eq!(m.rows.len(), 6);
        // Baseline ratio is exactly 1.
        assert_eq!(u.rows[0][2], "1.000");
        let txt = u.to_text();
        assert!(txt.contains("LLAMA SoA MB"));
    }

    #[test]
    fn baseline_json_carries_both_tables() {
        let mut o = Opts::quick();
        o.n = Some(128);
        o.iters = 1;
        let j = baseline_json(&o);
        assert!(j.contains("\"figure\": \"fig5_nbody\""), "{j}");
        assert!(j.contains("\"update\": {"), "{j}");
        assert!(j.contains("\"move\": {"), "{j}");
        assert!(j.contains("LLAMA AoSoA16"), "{j}");
    }
}
