//! Fig 5 driver: n-body CPU update & move across memory layouts,
//! manual twins vs LLAMA mappings.
//!
//! Paper's expected shape (i7-7820X / EPYC 7702, single thread):
//! * LLAMA AoS ≈ manual AoS, LLAMA SoA MB ≈ manual SoA (zero overhead);
//! * `move`: SoA ≈ 0.65× AoS runtime (bandwidth use: 64.3% for AoS);
//! * LLAMA AoSoA single-loop is slower than manual AoSoA (the i/L,
//!   i%L split defeats vectorization) — `update_blocked` recovers it.

use super::bench::{bench, black_box, BenchResult, Opts};
use super::report::{fmt_ms, fmt_ratio, Table};
use crate::array::ArrayDims;
use crate::mapping::{AoS, AoSoA, SoA};
use crate::view::alloc_view;
use crate::view::simd::{detect, simd_compiled};
use crate::workloads::nbody::{self, llama_impl, manual};

pub struct Fig5Sizes {
    pub n_update: usize,
    pub n_move: usize,
    pub move_reps: usize,
}

pub fn sizes(o: &Opts) -> Fig5Sizes {
    if o.quick {
        Fig5Sizes { n_update: o.n.unwrap_or(1024), n_move: 1 << 18, move_reps: 8 }
    } else {
        // Paper: update N=16Ki (quadratic); move uses a larger N.
        Fig5Sizes { n_update: o.n.unwrap_or(8 * 1024), n_move: 1 << 22, move_reps: 8 }
    }
}

/// Run the full fig 5 matrix; returns (update table, move table).
pub fn run(o: &Opts) -> (Table, Table) {
    let s = sizes(o);
    let d = nbody::particle_dim();
    let state_u = nbody::init_particles(s.n_update, 42);
    let state_m = nbody::init_particles(s.n_move, 43);
    let w = if o.quick { 1 } else { 2 };

    let mut update = Table::new(
        format!("fig5 update (N={}, single thread)", s.n_update),
        &["impl", "ms", "vs manual AoS"],
    );
    let mut results: Vec<BenchResult> = Vec::new();

    // Manual twins.
    {
        let mut aos = manual::NBodyAoS::from_state(&state_u);
        results.push(bench("manual AoS", w, o.iters, || {
            aos.update();
            black_box(&aos.particles);
        }));
        let mut soa = manual::NBodySoA::from_state(&state_u);
        results.push(bench("manual SoA", w, o.iters, || {
            soa.update();
            black_box(&soa.state);
        }));
        let mut a8 = manual::NBodyAoSoA::<8>::from_state(&state_u);
        results.push(bench("manual AoSoA8", w, o.iters, || {
            a8.update();
            black_box(&a8.blocks);
        }));
        let mut a16 = manual::NBodyAoSoA::<16>::from_state(&state_u);
        results.push(bench("manual AoSoA16", w, o.iters, || {
            a16.update();
            black_box(&a16.blocks);
        }));
    }

    // LLAMA layouts, identical generic kernel.
    let dims = ArrayDims::linear(s.n_update);
    macro_rules! llama_update {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_u);
            results.push(bench($name, w, o.iters, || {
                llama_impl::update(&mut v);
                black_box(v.blobs());
            }));
        }};
    }
    llama_update!("LLAMA AoS (aligned)", AoS::aligned(&d, dims.clone()));
    llama_update!("LLAMA AoS (packed)", AoS::packed(&d, dims.clone()));
    llama_update!("LLAMA SoA SB", SoA::single_blob(&d, dims.clone()));
    llama_update!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_update!("LLAMA AoSoA8", AoSoA::new(&d, dims.clone(), 8));
    llama_update!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));
    // Scalar-vs-SIMD rows: the same shard kernel on the detected lane
    // path (bit-identical results — `prop_simd`); the row name records
    // which path actually ran, so a baseline can never silently carry
    // scalar numbers as "simd". Packed AoS goes through the same
    // kernel via the batch-cursor gather path.
    let spath = detect();
    let stag = format!(" (simd: {})", spath.name());
    macro_rules! llama_update_simd {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_u);
            results.push(bench(&format!("{}{stag}", $name), w, o.iters, || {
                llama_impl::update_simd_parallel_with(&mut v, 1, spath);
                black_box(v.blobs());
            }));
        }};
    }
    llama_update_simd!("LLAMA AoS (packed)", AoS::packed(&d, dims.clone()));
    llama_update_simd!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_update_simd!("LLAMA AoSoA8", AoSoA::new(&d, dims.clone(), 8));
    llama_update_simd!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));
    // The paper's missing piece: a mapping-aware blocked iteration.
    {
        let mut v = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        llama_impl::load_state(&mut v, &state_u);
        results.push(bench("LLAMA AoSoA16 (blocked)", w, o.iters, || {
            llama_impl::update_blocked(&mut v, 16);
            black_box(v.blobs());
        }));
    }
    // The adaptive engine (EXPERIMENTS.md §Adapt): starts on AoS, the
    // warmup step is the trace epoch, the advisor's layout (SoA for
    // the 4-of-7-leaf j-stream) carries the timed iterations. Measures
    // the steady state the engine converges to.
    {
        use crate::view::adapt::{AdaptiveConfig, AdaptiveView};
        let mut v = alloc_view(AoS::aligned(&d, dims.clone()));
        llama_impl::load_state(&mut v, &state_u);
        let cfg = AdaptiveConfig { steady_steps: 0, ..Default::default() };
        let mut av = AdaptiveView::new(v, cfg);
        let mut kernel = llama_impl::AdaptiveUpdate { threads: 1 };
        results.push(bench("LLAMA adaptive (AoS start)", w.max(1), o.iters, || {
            av.step(&mut kernel);
            black_box(av.count());
        }));
    }

    let base = results[0].median_ns;
    for r in &results {
        update.row(vec![r.name.clone(), fmt_ms(r.median_ns), fmt_ratio(r.median_ns, base)]);
    }

    // ---- move phase (memory bound) ----
    let mut mv = Table::new(
        format!("fig5 move (N={}, x{} reps, single thread)", s.n_move, s.move_reps),
        &["impl", "ms", "vs manual AoS"],
    );
    let mut results: Vec<BenchResult> = Vec::new();
    {
        let mut aos = manual::NBodyAoS::from_state(&state_m);
        results.push(bench("manual AoS", w, o.iters, || {
            for _ in 0..s.move_reps {
                aos.mv();
            }
            black_box(&aos.particles);
        }));
        let mut soa = manual::NBodySoA::from_state(&state_m);
        results.push(bench("manual SoA", w, o.iters, || {
            for _ in 0..s.move_reps {
                soa.mv();
            }
            black_box(&soa.state);
        }));
        let mut a16 = manual::NBodyAoSoA::<16>::from_state(&state_m);
        results.push(bench("manual AoSoA16", w, o.iters, || {
            for _ in 0..s.move_reps {
                a16.mv();
            }
            black_box(&a16.blocks);
        }));
    }
    let dims = ArrayDims::linear(s.n_move);
    macro_rules! llama_move {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_m);
            results.push(bench($name, w, o.iters, || {
                for _ in 0..s.move_reps {
                    llama_impl::mv(&mut v);
                }
                black_box(v.blobs());
            }));
        }};
    }
    llama_move!("LLAMA AoS (aligned)", AoS::aligned(&d, dims.clone()));
    llama_move!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_move!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));
    macro_rules! llama_move_simd {
        ($name:expr, $mapping:expr) => {{
            let mut v = alloc_view($mapping);
            llama_impl::load_state(&mut v, &state_m);
            results.push(bench(&format!("{}{stag}", $name), w, o.iters, || {
                for _ in 0..s.move_reps {
                    llama_impl::mv_simd_parallel_with(&mut v, 1, spath);
                }
                black_box(v.blobs());
            }));
        }};
    }
    llama_move_simd!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    llama_move_simd!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));

    let base = results[0].median_ns;
    for r in &results {
        mv.row(vec![r.name.clone(), fmt_ms(r.median_ns), fmt_ratio(r.median_ns, base)]);
    }
    (update, mv)
}

/// Thread counts measured by the sweep: 1, 2 and the machine/option
/// maximum (deduplicated and capped by `Opts::threads`).
fn sweep_thread_counts(o: &Opts) -> Vec<usize> {
    let max_t = o.threads().max(1);
    let mut counts = vec![1usize];
    if max_t >= 2 {
        counts.push(2);
    }
    if max_t > 2 {
        counts.push(max_t);
    }
    counts
}

/// Thread-sweep table: the `update` kernel across layouts × 1/2/N
/// worker threads through `par_execute` (EXPERIMENTS.md §Parallel).
/// Per layout, the ratio column is against that layout's own 1-thread
/// row, so scaling is read off directly.
pub fn thread_sweep(o: &Opts) -> Table {
    let s = sizes(o);
    let d = nbody::particle_dim();
    let state = nbody::init_particles(s.n_update, 44);
    let dims = ArrayDims::linear(s.n_update);
    let w = if o.quick { 1 } else { 2 };
    let counts = sweep_thread_counts(o);
    let mut t = Table::new(
        format!("fig5 update thread sweep (N={}, shard-parallel)", s.n_update),
        &["layout", "threads", "ms", "vs 1 thread"],
    );
    macro_rules! sweep {
        ($name:expr, $mapping:expr) => {{
            let mut base = 0.0f64;
            for &tc in &counts {
                let mut v = alloc_view($mapping);
                llama_impl::load_state(&mut v, &state);
                let r = bench(&format!("{} x{tc}", $name), w, o.iters, || {
                    llama_impl::update_parallel(&mut v, tc);
                    black_box(v.blobs());
                });
                if tc == 1 {
                    base = r.median_ns;
                }
                t.row(vec![
                    $name.to_string(),
                    tc.to_string(),
                    fmt_ms(r.median_ns),
                    fmt_ratio(r.median_ns, base),
                ]);
            }
        }};
    }
    sweep!("LLAMA AoS (aligned)", AoS::aligned(&d, dims.clone()));
    sweep!("LLAMA SoA MB", SoA::multi_blob(&d, dims.clone()));
    sweep!("LLAMA AoSoA16", AoSoA::new(&d, dims.clone(), 16));
    t
}

fn render_baseline(o: &Opts, update: &Table, mv: &Table, threads: &Table) -> String {
    format!(
        "{{\n  \"figure\": \"fig5_nbody\",\n  \"mode\": \"{}\",\n  \"iters\": {},\n  \
         \"unit\": \"ms (median)\",\n  \
         \"simd\": {{ \"compiled\": {}, \"path\": \"{}\" }},\n  \
         \"update\": {},\n  \"move\": {},\n  \"threads\": {}\n}}\n",
        if o.quick { "quick" } else { "full" },
        o.iters,
        simd_compiled(),
        detect().name(),
        update.to_json(),
        mv.to_json(),
        threads.to_json()
    )
}

/// Serialize a fig 5 run as the `BENCH_fig5.json` baseline document —
/// the perf trajectory future PRs compare against (regenerate with
/// `cargo run --release -- bench-fig5`). Carries the update and move
/// matrices plus the 1/2/N thread sweep, and refuses structurally (on
/// the `Table` values, not the serialized text) to produce a baseline
/// with any empty table — an empty table is a broken run, not a
/// measurement.
pub fn baseline_json_checked(o: &Opts) -> crate::error::Result<String> {
    // A SIMD-capable build whose dispatch resolved to scalar would
    // record scalar numbers in every "(simd: ...)" row — refuse, unless
    // the scalar pin was explicit (`LLAMA_SIMD=scalar` is how a
    // deliberate scalar baseline is recorded on a SIMD host).
    if simd_compiled() {
        crate::ensure!(
            detect().is_vector() || std::env::var("LLAMA_SIMD").is_ok(),
            "bench-fig5: built with `--features simd` but dispatch fell back to scalar on \
             this host; set LLAMA_SIMD=scalar to record a scalar baseline deliberately"
        );
    }
    let (update, mv) = run(o);
    let threads = thread_sweep(o);
    for t in [&update, &mv, &threads] {
        crate::ensure!(!t.rows.is_empty(), "bench-fig5: table '{}' produced no rows", t.title);
    }
    Ok(render_baseline(o, &update, &mv, &threads))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_full_tables() {
        let mut o = Opts::quick();
        o.n = Some(256);
        o.iters = 1;
        let (u, m) = run(&o);
        assert_eq!(u.rows.len(), 16);
        assert_eq!(m.rows.len(), 8);
        // Baseline ratio is exactly 1.
        assert_eq!(u.rows[0][2], "1.000");
        let txt = u.to_text();
        assert!(txt.contains("LLAMA SoA MB"));
        assert!(txt.contains("LLAMA adaptive"));
        // The simd rows record the dispatched path in their name —
        // "(simd: scalar)" on non-SIMD builds, never an unlabeled row.
        assert_eq!(u.rows.iter().filter(|r| r[0].contains("(simd: ")).count(), 4);
        assert_eq!(m.rows.iter().filter(|r| r[0].contains("(simd: ")).count(), 2);
        let tag = format!("(simd: {})", crate::view::simd::detect().name());
        assert!(txt.contains(&tag), "{txt}");
    }

    #[test]
    fn baseline_json_carries_all_tables() {
        let mut o = Opts::quick();
        o.n = Some(128);
        o.iters = 1;
        o.threads = Some(2);
        let j = baseline_json_checked(&o).expect("populated run passes the empty-table gate");
        assert!(j.contains("\"figure\": \"fig5_nbody\""), "{j}");
        assert!(j.contains("\"update\": {"), "{j}");
        assert!(j.contains("\"move\": {"), "{j}");
        assert!(j.contains("\"threads\": {"), "{j}");
        assert!(j.contains("\"simd\": {"), "{j}");
        assert!(j.contains("\"compiled\": "), "{j}");
        assert!(j.contains("\"path\": \""), "{j}");
        assert!(j.contains("(simd: "), "{j}");
        assert!(j.contains("LLAMA AoSoA16"), "{j}");
        assert!(j.contains("thread sweep"), "{j}");
        assert!(!j.contains("\"rows\": []"), "empty table in {j}");
    }

    #[test]
    fn thread_sweep_has_one_row_per_layout_and_count() {
        let mut o = Opts::quick();
        o.n = Some(128);
        o.iters = 1;
        o.threads = Some(2); // counts = [1, 2] regardless of machine
        let t = thread_sweep(&o);
        assert_eq!(t.rows.len(), 3 * 2);
        // Each layout's 1-thread row is its own baseline.
        for row in t.rows.iter().filter(|r| r[1] == "1") {
            assert_eq!(row[3], "1.000");
        }
        assert!(t.to_text().contains("LLAMA AoSoA16"));
    }
}
