//! [`ServingEngine`] / [`AdvisorPool`]: concurrent serving on top of
//! the adaptive relayout engine — epoch-pinned reads during background
//! relayout, with a budgeted multi-store migration scheduler
//! (ARCHITECTURE.md "Layer: serving", EXPERIMENTS.md §Serve).
//!
//! The paper's premise is that layout choice is swappable underneath a
//! running program; [`crate::view::adapt::AdaptiveView`] realizes that
//! for a single `&mut` owner, stopping the world for every sampling
//! epoch and migration. This module removes the stop: readers on any
//! number of threads [`pin`](ServingEngine::pin) an immutable,
//! **generation-swap double-buffered** snapshot while writes, sampling
//! and migration proceed against the head copy.
//!
//! # Generation swap
//!
//! ```text
//!   writers/migrator (head lock)            readers (no head lock)
//!   ───────────────────────────             ──────────────────────
//!   update() ─► AdaptiveView head           pin() ──► Arc<Generation N>
//!   publish():                              get()/view() on pinned blobs
//!     blobs ──copy──► pooled Arc blobs      ...
//!     swap published ptr ── Generation N+1  drop(guard): last unpin of
//!   (old generation floats until             Generation N returns its
//!    its last reader unpins)                 blobs to the pool
//! ```
//!
//! * **Pin** — [`ServingEngine::pin`] clones one `Arc` under a lock
//!   held for O(1); the guard's view reads never synchronize with
//!   anything afterwards.
//! * **Publish** — [`ServingEngine::publish`] copies the head's live
//!   blobs byte-for-byte into destinations drawn from the engine's
//!   recycler ([`crate::blob::BlobRecycler::allocate_covered`]: the
//!   full-length copy is the coverage proof, so no re-zero), wraps
//!   them in `Arc`s, and publishes with a single pointer swap. The
//!   copy reads blob bytes directly — never through the traced
//!   mapping — so publishing mid-epoch cannot pollute sample counts.
//! * **Reclaim** — when the last reader of an old generation unpins,
//!   the `Arc` drops the view and its pooled blobs return to their
//!   size-class free lists. A warm engine therefore publishes and
//!   migrates with **zero** fresh allocations
//!   (`PoolStats`-asserted in `rust/tests/prop_serve.rs`).
//!
//! # Budgeted fleet migration
//!
//! [`AdvisorPool`] manages N independent stores whose engines run in
//! deferred-migration mode ([`crate::view::adapt::AdaptiveView::set_defer`]):
//! each epoch end *parks* its migration decision instead of executing
//! it. A [`cycle`](AdvisorPool::cycle) ranks every parked decision by
//! the cost model's predicted relative gain
//! ([`crate::mapping::migration_gain`]) and applies only the top-k under
//! the global per-cycle budget — the fleet pays for the relayouts that
//! buy the most, and every store keeps serving its current layout in
//! the meantime. All stores share one [`ProgramCache`], so a layout
//! pair migrated anywhere in the fleet compiles exactly once.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::blob::{Blob, BlobMut, BlobRecycler, VecAlloc};
use crate::copy::ProgramCache;
use crate::mapping::{Mapping, RecipeMapping};
use crate::view::adapt::{AdaptiveConfig, AdaptiveKernel, AdaptiveKernel2, AdaptiveView};
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// One published snapshot: an immutable view whose blobs are shared
/// (`Arc`) between the generation and every pinned reader, plus its
/// monotonically increasing number.
struct Generation<B: Blob> {
    view: View<RecipeMapping, Arc<B>>,
    number: u64,
}

/// An epoch-pinned, immutable view of one published generation.
///
/// Cloning is an `Arc` clone (pin the same generation again, cheaply).
/// The guard is `Send + Sync`: readers may be handed across threads,
/// and one guard may serve several. Dropping the last guard of an old
/// generation releases its blobs — with pooled storage they return to
/// the pool's free lists right there.
pub struct ReadGuard<B: Blob> {
    generation: Arc<Generation<B>>,
}

impl<B: Blob> Clone for ReadGuard<B> {
    fn clone(&self) -> Self {
        ReadGuard { generation: Arc::clone(&self.generation) }
    }
}

impl<B: Blob> ReadGuard<B> {
    /// The pinned generation's view — run any read-only kernel over
    /// it; the layout underneath is whatever the advisor had adopted
    /// at publish time.
    pub fn view(&self) -> &View<RecipeMapping, Arc<B>> {
        &self.generation.view
    }

    /// The pinned generation number (monotonic per engine).
    pub fn generation(&self) -> u64 {
        self.generation.number
    }

    /// Read a terminal field at a canonical linear index.
    pub fn get<T: ScalarVal>(&self, lin: usize, leaf: usize) -> T {
        self.generation.view.get(lin, leaf)
    }

    /// Number of records in the pinned data space.
    pub fn count(&self) -> usize {
        self.generation.view.count()
    }

    /// Name of the pinned generation's layout.
    pub fn mapping_name(&self) -> String {
        self.generation.view.mapping().mapping_name()
    }
}

struct EngineShared<R: BlobRecycler> {
    /// The single-writer head: workload steps, writes, sampling and
    /// migration all serialize here. Readers never take this lock.
    head: Mutex<AdaptiveView<R>>,
    /// The reader-visible generation; `pin` clones the `Arc` under a
    /// lock held for O(1), `publish` replaces the pointer in one swap.
    published: Mutex<Arc<Generation<R::Blob>>>,
    generations: AtomicU64,
}

/// A concurrently servable adaptive store: an
/// [`AdaptiveView`](crate::view::adapt::AdaptiveView) head behind
/// generation-swap double buffering.
///
/// The handle is a cheap `Arc` clone — hand clones to reader and
/// writer threads alike. Writers (and the migration path inside
/// [`update`](ServingEngine::update)) serialize on the head; readers
/// [`pin`](ServingEngine::pin) and never block on either.
///
/// ```
/// use llama::prelude::*;
///
/// struct Sweep;
/// impl AdaptiveKernel for Sweep {
///     fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
///         for i in 0..v.count() {
///             let x: f32 = v.get(i, 0);
///             v.set(i, 0, x + 1.0);
///         }
///     }
/// }
///
/// let d = llama::record_dim! { hot: f32, cold: [f64; 6] };
/// let pool = BlobPool::new();
/// let view = alloc_view_with(AoS::aligned(&d, ArrayDims::linear(64)), pool.clone());
/// let engine = ServingEngine::with_recycler(view, AdaptiveConfig::default(), pool);
///
/// let before = engine.pin(); // pins generation 1
/// engine.step_publish(&mut Sweep); // head steps (may migrate), then publishes
/// let after = engine.pin();
/// assert_eq!(before.get::<f32>(3, 0), 0.0); // old generation: untouched
/// assert_eq!(after.get::<f32>(3, 0), 1.0); // new generation: the step's result
/// assert!(after.generation() > before.generation());
/// ```
pub struct ServingEngine<R: BlobRecycler = VecAlloc>
where
    R::Blob: Sync,
{
    shared: Arc<EngineShared<R>>,
}

impl<R: BlobRecycler> Clone for ServingEngine<R>
where
    R::Blob: Sync,
{
    fn clone(&self) -> Self {
        ServingEngine { shared: Arc::clone(&self.shared) }
    }
}

impl ServingEngine<VecAlloc> {
    /// Wrap a `Vec<u8>`-backed view. For the zero-fresh-allocation
    /// serving path use [`ServingEngine::with_recycler`] with a
    /// [`crate::blob::BlobPool`].
    pub fn new<M: Mapping + 'static>(
        view: View<M, Vec<u8>>,
        cfg: AdaptiveConfig,
    ) -> ServingEngine<VecAlloc> {
        Self::from_adaptive(AdaptiveView::new(view, cfg))
    }
}

impl<R: BlobRecycler> ServingEngine<R>
where
    R::Blob: Sync,
{
    /// Wrap a view whose blobs came from `recycler`; every generation
    /// the engine publishes draws its blobs from the same recycler,
    /// and retired generations return there.
    pub fn with_recycler<M: Mapping + 'static>(
        view: View<M, R::Blob>,
        cfg: AdaptiveConfig,
        recycler: R,
    ) -> ServingEngine<R> {
        Self::from_adaptive(AdaptiveView::with_recycler(view, cfg, recycler))
    }

    /// Wrap an existing adaptive engine (the general constructor: the
    /// caller may have pre-configured cost model, deferral, or a
    /// shared cache). Publishes generation 1 immediately, so
    /// [`pin`](ServingEngine::pin) always has a snapshot to serve.
    pub fn from_adaptive(head: AdaptiveView<R>) -> ServingEngine<R> {
        let generation = Arc::new(Self::snapshot(&head, 1));
        ServingEngine {
            shared: Arc::new(EngineShared {
                head: Mutex::new(head),
                published: Mutex::new(generation),
                generations: AtomicU64::new(1),
            }),
        }
    }

    /// [`ServingEngine::from_adaptive`] with the fleet-shared program
    /// cache installed first (see
    /// [`AdaptiveView::share_cache`](crate::view::adapt::AdaptiveView::share_cache)).
    pub fn from_adaptive_shared(
        mut head: AdaptiveView<R>,
        cache: Arc<ProgramCache>,
    ) -> ServingEngine<R> {
        head.share_cache(cache);
        Self::from_adaptive(head)
    }

    /// Copy the head's live blobs into a fresh generation. Bytes are
    /// read directly off the blobs — never through the (possibly
    /// traced) mapping — so a mid-epoch publish is invisible to the
    /// sample counters, and the full-length copy satisfies the
    /// `allocate_covered` overwrite contract.
    fn snapshot(head: &AdaptiveView<R>, number: u64) -> Generation<R::Blob> {
        head.with_live(|recipe, blobs| {
            let copies: Vec<Arc<R::Blob>> = blobs
                .iter()
                .map(|b| {
                    let bytes = b.as_bytes();
                    let mut dst = head.recycler().allocate_covered(bytes.len());
                    dst.as_bytes_mut().copy_from_slice(bytes);
                    Arc::new(dst)
                })
                .collect();
            Generation { view: View::from_blobs(recipe.clone(), copies), number }
        })
    }

    /// Pin the current generation: one `Arc` clone under a lock held
    /// for O(1). The guard (and any clone of it) keeps that
    /// generation's blobs alive; everything published later is
    /// invisible to it.
    pub fn pin(&self) -> ReadGuard<R::Blob> {
        let generation = Arc::clone(&self.shared.published.lock().unwrap());
        ReadGuard { generation }
    }

    /// Publish the head's current state as the next generation (single
    /// pointer swap; readers pinned to older generations are
    /// unaffected). Returns the new generation number.
    pub fn publish(&self) -> u64 {
        let head = self.shared.head.lock().unwrap();
        let number = self.shared.generations.fetch_add(1, Ordering::Relaxed) + 1;
        let generation = Arc::new(Self::snapshot(&head, number));
        // The swap: one pointer store. The old Arc unwinds when its
        // last reader unpins (or right here, if nobody pinned it).
        *self.shared.published.lock().unwrap() = generation;
        number
    }

    /// Run one workload step against the head (sampling, decision and
    /// — unless deferred — migration happen at epoch boundaries inside,
    /// off the readers' path). Not visible to readers until the next
    /// [`publish`](ServingEngine::publish).
    pub fn update<K: AdaptiveKernel>(&self, kernel: &mut K) {
        self.shared.head.lock().unwrap().step(kernel);
    }

    /// [`ServingEngine::update`] for double-buffered kernels.
    pub fn update_zip<K: AdaptiveKernel2>(&self, kernel: &mut K) {
        self.shared.head.lock().unwrap().step_zip(kernel);
    }

    /// One step, then publish: the serving loop's convenience.
    /// Returns the published generation number.
    pub fn step_publish<K: AdaptiveKernel>(&self, kernel: &mut K) -> u64 {
        self.update(kernel);
        self.publish()
    }

    /// Write one terminal field on the head (point writes between
    /// steps — request traffic). Invisible to readers until the next
    /// publish.
    pub fn write<T: ScalarVal>(&self, lin: usize, leaf: usize, v: T) {
        self.shared.head.lock().unwrap().set(lin, leaf, v);
    }

    /// Read one terminal field from the *head* (read-your-writes for
    /// the writer path; readers should [`pin`](ServingEngine::pin)).
    pub fn read_head<T: ScalarVal>(&self, lin: usize, leaf: usize) -> T {
        self.shared.head.lock().unwrap().get(lin, leaf)
    }

    /// The latest published generation number.
    pub fn generation(&self) -> u64 {
        self.shared.generations.load(Ordering::Relaxed)
    }

    /// Migrations the head has performed so far.
    pub fn migrations(&self) -> usize {
        self.shared.head.lock().unwrap().migrations()
    }

    /// Name of the head's current layout (readers may still be pinned
    /// to generations of an older one).
    pub fn mapping_name(&self) -> String {
        self.shared.head.lock().unwrap().mapping_name()
    }

    /// Toggle deferred-migration mode on the head (see
    /// [`AdaptiveView::set_defer`](crate::view::adapt::AdaptiveView::set_defer);
    /// the [`AdvisorPool`] sets this for every store it manages).
    pub fn set_defer(&self, defer: bool) {
        self.shared.head.lock().unwrap().set_defer(defer);
    }

    /// Predicted gain of the head's parked migration decision, if any.
    pub fn pending_gain(&self) -> Option<f64> {
        self.shared.head.lock().unwrap().pending().map(|p| p.gain())
    }

    /// Execute the head's parked migration and publish the result.
    /// Returns `true` if a migration ran.
    pub fn apply_pending(&self) -> bool {
        let applied = self.shared.head.lock().unwrap().apply_pending();
        if applied {
            self.publish();
        }
        applied
    }

    /// Borrow the head under its lock — the escape hatch for anything
    /// the forwarding methods don't cover (cost-model updates,
    /// recycler stats, tests).
    pub fn with_head<T>(&self, f: impl FnOnce(&mut AdaptiveView<R>) -> T) -> T {
        f(&mut self.shared.head.lock().unwrap())
    }
}

/// One store's outcome in an [`AdvisorPool::cycle`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CycleEntry {
    /// Index of the store in the pool (its `add` order).
    pub store: usize,
    /// The parked decision's predicted relative gain.
    pub gain: f64,
}

/// What one budget cycle did: which stores migrated (top-k by gain)
/// and which parked decisions were deferred to a later cycle.
#[derive(Debug, Clone, Default)]
pub struct CycleReport {
    /// Stores migrated this cycle, in descending gain order.
    pub migrated: Vec<CycleEntry>,
    /// Stores with parked decisions left waiting (over budget).
    pub deferred: Vec<CycleEntry>,
}

/// A fleet-level migration scheduler: N independent
/// [`ServingEngine`] stores, one global per-cycle migration budget.
///
/// Every store added runs in deferred-migration mode — its epoch
/// decisions park instead of executing. [`AdvisorPool::cycle`] ranks
/// all parked decisions by predicted gain and applies only the best
/// `budget` of them, so fleet-wide copy bandwidth is spent where the
/// cost model says it buys the most. Stores share this pool's
/// [`ProgramCache`]: a layout pair migrated by any store compiles
/// once for all of them.
pub struct AdvisorPool<R: BlobRecycler = VecAlloc>
where
    R::Blob: Sync,
{
    stores: Vec<ServingEngine<R>>,
    cache: Arc<ProgramCache>,
    budget: usize,
}

impl<R: BlobRecycler> AdvisorPool<R>
where
    R::Blob: Sync,
{
    /// An empty pool migrating at most `budget` stores per cycle.
    pub fn new(budget: usize) -> AdvisorPool<R> {
        AdvisorPool { stores: Vec::new(), cache: Arc::new(ProgramCache::new()), budget }
    }

    /// Adopt a store: switches it to deferred-migration mode and onto
    /// the pool's shared program cache. Returns the store's index.
    pub fn add(&mut self, engine: ServingEngine<R>) -> usize {
        engine.set_defer(true);
        engine.with_head(|head| head.share_cache(Arc::clone(&self.cache)));
        self.stores.push(engine);
        self.stores.len() - 1
    }

    /// The store at `index` (its `add` order).
    pub fn store(&self, index: usize) -> &ServingEngine<R> {
        &self.stores[index]
    }

    /// All managed stores.
    pub fn stores(&self) -> &[ServingEngine<R>] {
        &self.stores
    }

    /// Number of managed stores.
    pub fn len(&self) -> usize {
        self.stores.len()
    }

    /// True when no stores are managed.
    pub fn is_empty(&self) -> bool {
        self.stores.is_empty()
    }

    /// The per-cycle migration budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Replace the per-cycle migration budget.
    pub fn set_budget(&mut self, budget: usize) {
        self.budget = budget;
    }

    /// The fleet-shared program cache.
    pub fn program_cache(&self) -> &ProgramCache {
        &self.cache
    }

    /// One budget cycle: collect every store's parked decision, rank
    /// by predicted gain (descending; first-decision parks rank as
    /// infinite), migrate-and-publish the top `budget`, leave the rest
    /// parked for a later cycle (each store's next epoch refreshes its
    /// own park anyway).
    pub fn cycle(&self) -> CycleReport {
        let mut candidates: Vec<CycleEntry> = self
            .stores
            .iter()
            .enumerate()
            .filter_map(|(store, e)| e.pending_gain().map(|gain| CycleEntry { store, gain }))
            .collect();
        candidates.sort_by(|a, b| b.gain.total_cmp(&a.gain));
        let cut = self.budget.min(candidates.len());
        let (winners, losers) = candidates.split_at(cut);
        let mut report = CycleReport::default();
        for entry in winners {
            if self.stores[entry.store].apply_pending() {
                report.migrated.push(*entry);
            }
        }
        report.deferred = losers.to_vec();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::blob::{BlobPool, PooledBytes};
    use crate::mapping::AoS;
    use crate::view::alloc_view;
    use crate::view::view::alloc_view_with;
    use crate::workloads::nbody::{self, llama_impl};

    struct Move;

    impl AdaptiveKernel for Move {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
            llama_impl::mv(v);
        }
    }

    fn pooled_engine(n: usize, pool: &BlobPool) -> ServingEngine<BlobPool> {
        let d = nbody::particle_dim();
        let mut v = alloc_view_with(AoS::aligned(&d, ArrayDims::linear(n)), pool.clone());
        llama_impl::load_state(&mut v, &nbody::init_particles(n, 5));
        ServingEngine::with_recycler(v, AdaptiveConfig::default(), pool.clone())
    }

    /// Compile-time thread-safety contracts: engine handles and read
    /// guards cross threads; guards are also shareable (one guard, many
    /// reader threads).
    #[test]
    fn serving_types_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ServingEngine<VecAlloc>>();
        assert_send_sync::<ServingEngine<BlobPool>>();
        assert_send_sync::<ReadGuard<Vec<u8>>>();
        assert_send_sync::<ReadGuard<PooledBytes>>();
        assert_send_sync::<AdvisorPool<BlobPool>>();
    }

    #[test]
    fn pinned_generation_is_immutable_under_updates() {
        let pool = BlobPool::new();
        let engine = pooled_engine(64, &pool);
        let g1 = engine.pin();
        assert_eq!(g1.generation(), 1);
        let before: f32 = g1.get(7, 0);
        engine.step_publish(&mut Move); // migrates AoS -> SoA inside
        assert_eq!(engine.migrations(), 1);
        // The old pin still reads the old bytes through the old layout.
        assert_eq!(g1.get::<f32>(7, 0), before);
        assert!(g1.mapping_name().starts_with("AoS("));
        // A new pin sees the stepped state on the migrated layout.
        let g2 = engine.pin();
        assert_eq!(g2.generation(), 2);
        assert!(g2.mapping_name().starts_with("SoA("));
        assert_ne!(g2.get::<f32>(7, 0), before, "move step must advance pos.x");
    }

    #[test]
    fn writes_are_invisible_until_publish() {
        let engine = ServingEngine::new(
            {
                let d = nbody::particle_dim();
                let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(16)));
                llama_impl::load_state(&mut v, &nbody::init_particles(16, 1));
                v
            },
            AdaptiveConfig::default(),
        );
        let g = engine.pin();
        let old: f32 = g.get(3, 6);
        engine.write(3, 6, old + 10.0);
        assert_eq!(engine.read_head::<f32>(3, 6), old + 10.0, "head: read-your-writes");
        assert_eq!(g.get::<f32>(3, 6), old, "pinned reader: unaffected");
        assert_eq!(engine.pin().get::<f32>(3, 6), old, "not yet published");
        engine.publish();
        assert_eq!(engine.pin().get::<f32>(3, 6), old + 10.0);
    }

    /// Readers pinned to the old generation keep its blobs alive; the
    /// last unpin returns them to the pool.
    #[test]
    fn last_unpin_returns_generation_blobs_to_the_pool() {
        let pool = BlobPool::new();
        let engine = pooled_engine(64, &pool);
        let g1 = engine.pin();
        let g1b = g1.clone();
        engine.step_publish(&mut Move);
        let outstanding_while_pinned = pool.stats().outstanding;
        drop(g1);
        assert_eq!(
            pool.stats().outstanding,
            outstanding_while_pinned,
            "a clone still pins generation 1"
        );
        drop(g1b);
        assert!(
            pool.stats().outstanding < outstanding_while_pinned,
            "last unpin must release generation 1's blobs"
        );
    }

    /// Concurrent readers during live head churn: every observation is
    /// a whole generation (the guard's bytes never change while held).
    #[test]
    fn concurrent_pins_observe_frozen_generations() {
        let pool = BlobPool::new();
        let engine = pooled_engine(256, &pool);
        std::thread::scope(|s| {
            let reader = |engine: ServingEngine<BlobPool>| {
                move || {
                    for _ in 0..50 {
                        let g = engine.pin();
                        let a: f32 = g.get(0, 0);
                        let b: f32 = g.get(0, 0);
                        assert_eq!(a, b);
                        // A full re-read through the same guard is
                        // bit-stable even while the head republishes.
                        let sum: f32 = (0..g.count()).map(|i| g.get::<f32>(i, 0)).sum();
                        let again: f32 = (0..g.count()).map(|i| g.get::<f32>(i, 0)).sum();
                        assert_eq!(sum.to_bits(), again.to_bits());
                    }
                }
            };
            for _ in 0..3 {
                s.spawn(reader(engine.clone()));
            }
            for _ in 0..20 {
                engine.step_publish(&mut Move);
            }
        });
        assert!(engine.generation() >= 21);
    }

    #[test]
    fn advisor_pool_migrates_only_the_top_gain_stores() {
        let mut pool = AdvisorPool::<VecAlloc>::new(1);
        let d = nbody::particle_dim();
        for n in [64usize, 64] {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(n)));
            llama_impl::load_state(&mut v, &nbody::init_particles(n, 3));
            let id = pool.add(ServingEngine::new(v, AdaptiveConfig::default()));
            // Park a decision on every store (first decision: gain inf).
            pool.store(id).update(&mut Move);
        }
        assert!(pool.stores().iter().all(|e| e.pending_gain().is_some()));
        let report = pool.cycle();
        assert_eq!(report.migrated.len(), 1, "budget 1 migrates exactly one store");
        assert_eq!(report.deferred.len(), 1);
        let migrated = report.migrated[0].store;
        assert_eq!(pool.store(migrated).migrations(), 1);
        assert!(pool.store(migrated).mapping_name().starts_with("SoA("));
        let waiting = report.deferred[0].store;
        assert_eq!(pool.store(waiting).migrations(), 0);
        assert!(pool.store(waiting).mapping_name().starts_with("AoS("));
        // Next cycle drains the deferred store.
        let report = pool.cycle();
        assert_eq!(report.migrated.len(), 1);
        assert_eq!(report.migrated[0].store, waiting);
        assert!(pool.cycle().migrated.is_empty(), "nothing left parked");
        // Both stores migrated the same layout pair: compiled once.
        assert_eq!(pool.program_cache().entries(), 1);
        assert!(pool.program_cache().hits() >= 1);
    }
}
