//! Plan-aligned view sharding + the shared parallel kernel executor
//! (EXPERIMENTS.md §Parallel).
//!
//! A mapping decouples the algorithm from the memory layout; this
//! module decouples it from the *execution scale*. A [`View`] (or a
//! src/dst pair of views) is split into disjoint [`Shard`]s along the
//! array dimensions, with split points derived from the compiled
//! [`LayoutPlan`]: shard boundaries align to the plan's AoSoA lane
//! count ([`shard_align`], gcd'd across Split children by
//! `LayoutPlan::compose_split`), so every shard's piecewise cursors
//! stay lane-blocked and kernels never pay a partial-block fixup inside
//! the hot loop — only the global tail block can be partial, and only
//! in the last shard.
//!
//! On top of the splitter sits [`par_execute`] (one view) and
//! [`par_execute_zip`] (src/dst views), the plan-driven kernel drivers
//! used by every workload: they compile the plan once, extract
//! whole-range cursors, and fan the shards out over scoped threads
//! (zero dependencies — `std::thread::scope`, mirroring the safety
//! argument of `copy::parallel`). A workload implements [`ShardKernel`]
//! / [`ShardKernel2`] once and runs serial (`threads = 1`, no spawn) or
//! parallel with bit-identical per-record results: each record's
//! computation is self-contained, so sharding changes scheduling, not
//! arithmetic.
//!
//! # Safety argument
//!
//! Distinct linear indices map to disjoint destination byte ranges for
//! every *storage* mapping (the fundamental mapping invariant,
//! property-tested in `rust/tests`), so threads writing disjoint shard
//! ranges never write the same byte. Aliasing mappings are never
//! parallel write targets: [`crate::mapping::Null`] keeps the default
//! generic plan, so the executors decline it and callers fall back to
//! their serial path; [`crate::mapping::One`] compiles to an affine
//! stride-0 plan whose leaves alias every record, which
//! [`plan_aliases`] detects — [`shard_plan`] and the executors then
//! collapse to a single shard, so safe callers cannot race.

use crate::blob::{Blob, BlobMut};
use crate::mapping::plan::AddrPlan;
use crate::mapping::{LayoutPlan, Mapping};
use crate::view::cursor::{
    CursorRead, CursorWrite, LeafCursorMut, PiecewiseCursorMut, PlanCursors, PlanCursorsMut,
};
use crate::view::view::View;

/// One shard: a contiguous, half-open range of canonical linear record
/// indices `start..end`, disjoint from every other shard of its split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// First record of the shard (inclusive).
    pub start: usize,
    /// End of the shard (exclusive).
    pub end: usize,
}

impl Shard {
    /// Number of records in the shard.
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True for a zero-length shard.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }

    /// The shard as a half-open index range — the `begin..end` handed
    /// to range-restricted serialization (`copy::wire::serialize_range`
    /// splits a view into per-connection payloads at these boundaries).
    #[inline]
    pub fn as_range(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple, falling back to `a` on overflow so callers'
/// granularity invariants (boundaries are multiples of `a`) survive.
fn lcm_or_first(a: usize, b: usize) -> usize {
    let g = gcd(a, b);
    if g == 0 {
        return a.max(1);
    }
    (a / g).checked_mul(b).unwrap_or(a)
}

/// Split `count` records into at most `parts` disjoint shards covering
/// `0..count`, every boundary a multiple of `align` (the final end is
/// `count` itself). `align` values at or above `count` collapse the
/// split to a single shard — alignment wins over parallelism, so
/// piecewise cursors are never handed a partial block mid-range.
pub fn shard_range(count: usize, parts: usize, align: usize) -> Vec<Shard> {
    let align = align.max(1);
    let parts = parts.max(1);
    if count == 0 {
        return Vec::new();
    }
    // Records per shard, rounded up to a multiple of `align`; at most
    // `parts` shards because `per >= ceil(count / parts)`.
    let per = count.div_ceil(parts).div_ceil(align) * align;
    let mut out = Vec::with_capacity(count.div_ceil(per));
    let mut start = 0;
    while start < count {
        let end = (start + per).min(count);
        out.push(Shard { start, end });
        start = end;
    }
    out
}

/// The alignment a shard boundary must respect for this plan:
///
/// * lane-blocked addressing ([`AddrPlan::PiecewiseAoSoA`]) → the lane
///   count, so every shard's blocks are full (no partial-block fixup);
/// * otherwise the chunk run length when it is shorter than the array
///   (Split compositions chunk at the gcd of their children's lanes) —
///   whole-array runs (SoA) split freely at any index, and affine
///   addressing is position-independent, so those contribute 1.
pub fn shard_align(plan: &LayoutPlan) -> usize {
    match plan.addr() {
        AddrPlan::PiecewiseAoSoA(p) => p.lanes.max(1),
        _ => match plan.chunk_lanes() {
            Some(l) if l > 0 && l < plan.count().max(1) => l,
            _ => 1,
        },
    }
}

/// True when distinct linear indices can map to the same bytes (e.g.
/// [`crate::mapping::One`]'s stride-0 leaves): such a plan must never
/// be sharded for writing — concurrent shards would race on the
/// aliased bytes even though their lin ranges are disjoint.
pub fn plan_aliases(plan: &LayoutPlan) -> bool {
    if plan.count() <= 1 {
        return false;
    }
    match plan.addr() {
        AddrPlan::Affine(leaves) => leaves.iter().any(|l| l.stride == 0),
        AddrPlan::PiecewiseAoSoA(p) => {
            p.leaves.iter().any(|l| l.lane_stride == 0 || l.block_stride == 0)
        }
        // Generic plans never get cursors, so the executors already
        // decline them.
        AddrPlan::Generic => false,
    }
}

/// Split points derived from one plan: `shard_range` at the plan's
/// record count and [`shard_align`]. Aliasing plans ([`plan_aliases`])
/// collapse to a single shard so safe callers cannot race writes
/// through e.g. a `One` mapping.
///
/// ```
/// use llama::prelude::*;
///
/// let d = llama::record_dim! { x: f32 };
/// let plan = AoSoA::new(&d, ArrayDims::linear(100), 16).plan();
/// let shards = shard_plan(&plan, 3);
/// // Boundaries land on 16-record lane blocks; only the global tail
/// // (records 96..100) is a partial block, and only in the last shard.
/// assert!(shards.iter().all(|s| s.start % 16 == 0));
/// assert_eq!(shards.last().unwrap().end, 100);
/// assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 100);
/// ```
pub fn shard_plan(plan: &LayoutPlan, parts: usize) -> Vec<Shard> {
    let parts = if plan_aliases(plan) { 1 } else { parts };
    shard_range(plan.count(), parts, shard_align(plan))
}

/// Combined boundary alignment for a (src, dst) pair — e.g. the two
/// sides of a layout-changing copy: the lcm of both sides'
/// [`shard_align`], so chunked runs start lane-blocked on *both*
/// layouts (the align-1 splits the old `copy::parallel` chunker could
/// produce straddled AoSoA lane blocks mid-shard).
pub fn pair_align(a: &LayoutPlan, b: &LayoutPlan) -> usize {
    lcm_or_first(shard_align(a), shard_align(b))
}

/// Split points for a (src, dst) copy pair: [`shard_range`] over the
/// source count at [`pair_align`] boundaries, collapsed to a single
/// shard when the destination plan aliases records ([`plan_aliases`])
/// — concurrent shards would race on the aliased bytes. Used by the
/// copy-program sharder (`copy::program::shard_programs`).
pub fn shard_pair(src: &LayoutPlan, dst: &LayoutPlan, parts: usize) -> Vec<Shard> {
    let parts = if plan_aliases(dst) { 1 } else { parts };
    shard_range(src.count(), parts, pair_align(src, dst))
}

/// Run `f` once per shard on scoped worker threads; a single shard runs
/// inline on the caller's thread (the serial path spawns nothing).
pub fn par_shards(shards: &[Shard], f: impl Fn(Shard) + Sync) {
    match shards {
        [] => {}
        [s] => f(*s),
        _ => {
            std::thread::scope(|scope| {
                for &s in shards {
                    let f = &f;
                    scope.spawn(move || f(s));
                }
            });
        }
    }
}

/// Map `f` over the shards on scoped worker threads and collect the
/// per-shard results in shard order (deterministic reductions — e.g.
/// the hep energy sweep sums shard partials in a fixed order).
pub fn par_map_shards<T: Send>(shards: &[Shard], f: impl Fn(Shard) -> T + Sync) -> Vec<T> {
    match shards {
        [] => Vec::new(),
        [s] => vec![f(*s)],
        _ => std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .iter()
                .map(|&s| {
                    let f = &f;
                    scope.spawn(move || f(s))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        }),
    }
}

/// A kernel over one view, executed shard-wise by [`par_execute`].
///
/// The cursors passed to each method cover the *whole* record range
/// (kernels may read any index — e.g. the n-body j-loop); the kernel
/// must **write** only indices inside `shard`. Shape-specific fast
/// paths (dense slices, lane-block slices) override `run_affine` /
/// `run_piecewise`; both default to the uniform [`CursorWrite`] body.
pub trait ShardKernel: Sync {
    /// Uniform kernel body over any cursor shape.
    fn run<C: CursorWrite>(&self, cur: &[C], shard: Shard);

    /// Affine-plan fast path (dense leaves expose real slices).
    fn run_affine(&self, cur: &[LeafCursorMut<'_>], shard: Shard) {
        self.run(cur, shard);
    }

    /// Piecewise-plan fast path (lane-blocked slices). Shard starts are
    /// lane-aligned by construction ([`shard_align`]).
    fn run_piecewise(&self, cur: &[PiecewiseCursorMut<'_>], shard: Shard) {
        self.run(cur, shard);
    }
}

/// A kernel over a (src, dst) view pair, executed shard-wise by
/// [`par_execute_zip`]. Same contract as [`ShardKernel`]: whole-range
/// cursors, writes confined to `shard`.
pub trait ShardKernel2: Sync {
    /// Run the kernel over `shard`, reading `src`, writing `dst`.
    fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], shard: Shard);
}

/// Plan-driven parallel execution over one view: compile the mapping
/// once, shard the record range on plan-aligned boundaries, and run the
/// kernel per shard on scoped threads (`threads = 1` runs inline, no
/// spawn — the serial and parallel paths share one code path and
/// produce bit-identical results).
///
/// Returns `false` without running anything when the plan has no
/// closed-form cursors (generic addressing, non-native representation,
/// or ranges that do not fit the blobs): the caller then runs its own
/// accessor-path fallback, exactly as with
/// [`View::plan_cursors_mut`].
///
/// The executor is generic over the blob storage `B: BlobMut`, so it
/// drives views over **caller-provided memory** too — the PIConGPU
/// integration scenario of paper §4.4, where LLAMA reinterprets a
/// buffer another framework owns:
///
/// ```
/// use llama::prelude::*;
/// use llama::blob::ExternalBytesMut;
///
/// struct Stamp;
/// impl ShardKernel for Stamp {
///     fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
///         for lin in s.start..s.end {
///             // SAFETY: lin < count; shards are disjoint.
///             unsafe { cur[0].write_at::<f32>(lin, lin as f32) };
///         }
///     }
/// }
///
/// let d = llama::record_dim! { x: f32, y: f32 };
/// // Memory owned by "someone else" (here: a stack-local buffer).
/// let mut foreign = vec![0u8; 2 * 4 * 64];
/// {
///     let mapping = SoA::single_blob(&d, ArrayDims::linear(64));
///     let mut view = View::from_blobs(mapping, vec![ExternalBytesMut(&mut foreign)]);
///     assert!(par_execute(&mut view, 4, &Stamp));
/// } // the view borrows; the caller keeps the buffer
/// assert_eq!(f32::from_ne_bytes(foreign[4 * 63..4 * 64].try_into().unwrap()), 63.0);
/// ```
pub fn par_execute<M, B, K>(view: &mut View<M, B>, threads: usize, kernel: &K) -> bool
where
    M: Mapping,
    B: BlobMut,
    K: ShardKernel,
{
    let plan = view.mapping().plan();
    let shards = shard_plan(&plan, threads);
    match view.plan_cursors_mut_with(&plan) {
        PlanCursorsMut::Affine(cur) => {
            par_shards(&shards, |s| kernel.run_affine(&cur, s));
            true
        }
        PlanCursorsMut::Piecewise(cur) => {
            par_shards(&shards, |s| kernel.run_piecewise(&cur, s));
            true
        }
        PlanCursorsMut::Generic => false,
    }
}

/// Plan-driven parallel execution over a (src, dst) view pair — the
/// zip-style entry point (lbm streams `src` into `dst`; copies move
/// bytes between layouts). Both mappings compile once; shard
/// boundaries are multiples of `granularity` (caller structure, e.g.
/// an lbm x-slab of `ny*nz` cells; pass 1 for none) *and* of the
/// destination plan's [`shard_align`], so parallel writes stay
/// lane-blocked.
///
/// Returns `false` when either side's plan has no closed-form cursors.
pub fn par_execute_zip<MS, MD, BS, BD, K>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    threads: usize,
    granularity: usize,
    kernel: &K,
) -> bool
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
    K: ShardKernel2,
{
    let dp = dst.mapping().plan();
    let threads = if plan_aliases(&dp) { 1 } else { threads };
    let align = lcm_or_first(granularity.max(1), shard_align(&dp));
    let shards = shard_range(src.count(), threads, align);
    match src.plan_cursors() {
        PlanCursors::Affine(s) => zip_with_src(&s, dst, &dp, &shards, kernel),
        PlanCursors::Piecewise(s) => zip_with_src(&s, dst, &dp, &shards, kernel),
        PlanCursors::Generic => false,
    }
}

/// Second dispatch stage of [`par_execute_zip`]: source cursors in
/// hand, extract the destination side from its already-compiled plan.
fn zip_with_src<R, MD, BD, K>(
    src: &[R],
    dst: &mut View<MD, BD>,
    dp: &LayoutPlan,
    shards: &[Shard],
    kernel: &K,
) -> bool
where
    R: CursorRead,
    MD: Mapping,
    BD: BlobMut,
    K: ShardKernel2,
{
    match dst.plan_cursors_mut_with(dp) {
        PlanCursorsMut::Affine(d) => {
            par_shards(shards, |s| kernel.run(src, &d, s));
            true
        }
        PlanCursorsMut::Piecewise(d) => {
            par_shards(shards, |s| kernel.run(src, &d, s));
            true
        }
        PlanCursorsMut::Generic => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, One, SoA, Split};
    use crate::record::RecordCoord;
    use crate::view::alloc_view;

    fn check_shards(shards: &[Shard], count: usize, parts: usize, align: usize) {
        assert!(shards.len() <= parts.max(1), "{count}/{parts}/{align}: too many shards");
        let mut expect = 0;
        for s in shards {
            assert_eq!(s.start, expect, "gap or overlap at {s:?}");
            assert!(s.end > s.start, "empty shard {s:?}");
            assert_eq!(s.start % align.max(1), 0, "unaligned start {s:?} (align {align})");
            if s.end != count {
                assert_eq!(s.end % align.max(1), 0, "unaligned end {s:?} (align {align})");
            }
            expect = s.end;
        }
        assert_eq!(expect, count, "shards do not cover 0..{count}");
    }

    #[test]
    fn shard_range_covers_disjointly_and_aligned() {
        for count in [0usize, 1, 5, 13, 64, 100, 257, 4096 + 17] {
            for parts in [1usize, 2, 3, 4, 8, 16] {
                for align in [1usize, 2, 4, 7, 16, 32] {
                    let shards = shard_range(count, parts, align);
                    check_shards(&shards, count, parts, align);
                }
            }
        }
    }

    #[test]
    fn oversized_align_collapses_to_one_shard() {
        let shards = shard_range(100, 8, 256);
        assert_eq!(shards, vec![Shard { start: 0, end: 100 }]);
    }

    #[test]
    fn shard_align_follows_the_plan_family() {
        let d = particle_dim();
        let dims = ArrayDims::linear(100);
        // Affine layouts split anywhere.
        assert_eq!(shard_align(&AoS::aligned(&d, dims.clone()).plan()), 1);
        assert_eq!(shard_align(&AoS::packed(&d, dims.clone()).plan()), 1);
        // SoA's whole-array runs split freely too.
        assert_eq!(shard_align(&SoA::multi_blob(&d, dims.clone()).plan()), 1);
        assert_eq!(shard_align(&One::new(&d, dims.clone()).plan()), 1);
        // Lane-blocked layouts align to their lane count.
        for lanes in [2usize, 4, 8, 16] {
            assert_eq!(shard_align(&AoSoA::new(&d, dims.clone(), lanes).plan()), lanes);
        }
        // Split(AoSoA4, SoA) composes to a 4-lane piecewise plan.
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        );
        assert_eq!(shard_align(&m.plan()), 4);
        // Mismatched-lane Split: generic addressing, gcd chunking.
        let m = Split::new(
            &d,
            dims.clone(),
            RecordCoord::new(vec![1]),
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| AoSoA::new(sd, ad, 6),
        );
        assert_eq!(shard_align(&m.plan()), 2);
    }

    #[test]
    fn pair_align_is_the_lcm_of_both_sides() {
        let d = particle_dim();
        let dims = ArrayDims::linear(96);
        let soa = SoA::multi_blob(&d, dims.clone()).plan();
        let a4 = AoSoA::new(&d, dims.clone(), 4).plan();
        let a6 = AoSoA::new(&d, dims.clone(), 6).plan();
        let a32 = AoSoA::new(&d, dims.clone(), 32).plan();
        assert_eq!(pair_align(&soa, &a32), 32);
        assert_eq!(pair_align(&a4, &a6), 12);
        assert_eq!(pair_align(&a4, &a32), 32);
        assert_eq!(pair_align(&soa, &soa), 1);
    }

    #[test]
    fn shard_pair_aligns_to_both_and_collapses_on_aliasing_dst() {
        let d = particle_dim();
        let dims = ArrayDims::linear(4096 + 17);
        let soa = SoA::multi_blob(&d, dims.clone()).plan();
        let a32 = AoSoA::new(&d, dims.clone(), 32).plan();
        for sh in shard_pair(&soa, &a32, 4) {
            assert_eq!(sh.start % 32, 0);
        }
        let one = One::new(&d, dims).plan();
        assert_eq!(shard_pair(&soa, &one, 8).len(), 1);
        // Aliasing *source* is harmless: reads may overlap.
        assert_eq!(shard_pair(&one, &soa, 4).len(), 4);
    }

    #[test]
    fn par_map_shards_preserves_shard_order() {
        let shards = shard_range(100, 4, 1);
        let got = par_map_shards(&shards, |s| s.start);
        let expect: Vec<usize> = shards.iter().map(|s| s.start).collect();
        assert_eq!(got, expect);
    }

    /// A trivial kernel writing `lin` into the mass leaf — checks the
    /// executor visits every record exactly once, across plan shapes.
    struct StampKernel;

    impl ShardKernel for StampKernel {
        fn run<C: CursorWrite>(&self, cur: &[C], shard: Shard) {
            for lin in shard.start..shard.end {
                // SAFETY: lin < count; shards are disjoint.
                unsafe { cur[4].write_at::<f64>(lin, lin as f64) };
            }
        }
    }

    #[test]
    fn par_execute_visits_every_record_once() {
        let d = particle_dim();
        for threads in [1usize, 2, 5] {
            let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(37), 4));
            assert!(par_execute(&mut v, threads, &StampKernel));
            for lin in 0..37 {
                assert_eq!(v.get::<f64>(lin, 4), lin as f64, "threads {threads} lin {lin}");
            }
            let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(37)));
            assert!(par_execute(&mut v, threads, &StampKernel));
            for lin in 0..37 {
                assert_eq!(v.get::<f64>(lin, 4), lin as f64, "threads {threads} lin {lin}");
            }
        }
    }

    #[test]
    fn aliasing_plans_collapse_to_one_shard() {
        let d = particle_dim();
        let plan = One::new(&d, ArrayDims::linear(64)).plan();
        assert!(plan_aliases(&plan));
        assert_eq!(shard_plan(&plan, 8).len(), 1);
        assert!(!plan_aliases(&AoSoA::new(&d, ArrayDims::linear(64), 4).plan()));
        // Writing through One via the executor stays single-shard and
        // safe: every lin aliases one record, last write wins.
        let mut v = alloc_view(One::new(&d, ArrayDims::linear(64)));
        assert!(par_execute(&mut v, 8, &StampKernel));
        assert_eq!(v.get::<f64>(0, 4), 63.0);
    }

    #[test]
    fn par_execute_declines_generic_plans() {
        use crate::mapping::Byteswap;
        let d = particle_dim();
        let mut v = alloc_view(Byteswap::new(AoS::packed(&d, ArrayDims::linear(8))));
        assert!(!par_execute(&mut v, 4, &StampKernel));
    }

    /// Zip kernel copying the mass leaf — exercises the two-sided
    /// dispatch and the shard discipline of [`par_execute_zip`].
    struct CopyMassKernel;

    impl ShardKernel2 for CopyMassKernel {
        fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], shard: Shard) {
            for lin in shard.start..shard.end {
                // SAFETY: lin < count; shards are disjoint.
                unsafe { dst[4].write_at::<f64>(lin, src[4].read_at::<f64>(lin)) };
            }
        }
    }

    #[test]
    fn par_execute_zip_copies_across_layouts() {
        let d = particle_dim();
        let dims = ArrayDims::linear(50);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        for lin in 0..50 {
            src.set::<f64>(lin, 4, 3.0 + lin as f64);
        }
        let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        assert!(par_execute_zip(&src, &mut dst, 3, 1, &CopyMassKernel));
        for lin in 0..50 {
            assert_eq!(dst.get::<f64>(lin, 4), 3.0 + lin as f64);
        }
    }

    #[test]
    fn empty_views_shard_to_nothing() {
        let d = particle_dim();
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(0), 4));
        assert!(shard_plan(&v.mapping().plan(), 8).is_empty());
        // The executor still reports cursor availability without running.
        assert!(par_execute(&mut v, 8, &StampKernel));
    }
}
