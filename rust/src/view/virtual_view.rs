//! [`VirtualView`]: restricts access to a subspace of the array
//! dimensions (paper §3.2: "Created on top of a View, a VirtualView
//! restricts access to a subspace of the array dimensions").

use crate::array::ArrayDims;
use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// A rectangular window `[offset, offset+extent)` into a view's array
/// dimensions. Indices passed to the accessors are *relative* to the
/// window origin.
#[derive(Debug)]
pub struct VirtualView<'v, M: Mapping, B: Blob> {
    view: &'v View<M, B>,
    offset: Vec<usize>,
    extents: ArrayDims,
}

impl<'v, M: Mapping, B: Blob> VirtualView<'v, M, B> {
    /// Window `[offset, offset+extents)` into `view`'s array dims.
    pub fn new(view: &'v View<M, B>, offset: Vec<usize>, extents: ArrayDims) -> Self {
        let dims = view.mapping().dims();
        assert_eq!(offset.len(), dims.rank());
        assert_eq!(extents.rank(), dims.rank());
        for d in 0..dims.rank() {
            assert!(
                offset[d] + extents.0[d] <= dims.0[d],
                "window exceeds dimension {d}: {}+{} > {}",
                offset[d],
                extents.0[d],
                dims.0[d]
            );
        }
        VirtualView { view, offset, extents }
    }

    /// Extents of the window.
    pub fn extents(&self) -> &ArrayDims {
        &self.extents
    }

    /// Origin of the window in absolute indices.
    pub fn offset(&self) -> &[usize] {
        &self.offset
    }

    fn absolute(&self, rel: &[usize]) -> Vec<usize> {
        debug_assert!(self.extents.contains(rel));
        rel.iter().zip(&self.offset).map(|(r, o)| r + o).collect()
    }

    /// Read at a window-relative index.
    pub fn get_nd<T: ScalarVal>(&self, rel: &[usize], leaf: usize) -> T {
        self.view.get_nd::<T>(&self.absolute(rel), leaf)
    }
}

/// Mutable window.
#[derive(Debug)]
pub struct VirtualViewMut<'v, M: Mapping, B: BlobMut> {
    view: &'v mut View<M, B>,
    offset: Vec<usize>,
    extents: ArrayDims,
}

impl<'v, M: Mapping, B: BlobMut> VirtualViewMut<'v, M, B> {
    /// Mutable window `[offset, offset+extents)` into `view`.
    pub fn new(view: &'v mut View<M, B>, offset: Vec<usize>, extents: ArrayDims) -> Self {
        {
            let dims = view.mapping().dims();
            assert_eq!(offset.len(), dims.rank());
            for d in 0..dims.rank() {
                assert!(offset[d] + extents.0[d] <= dims.0[d], "window exceeds dimension {d}");
            }
        }
        VirtualViewMut { view, offset, extents }
    }

    /// Extents of the window.
    pub fn extents(&self) -> &ArrayDims {
        &self.extents
    }

    fn absolute(&self, rel: &[usize]) -> Vec<usize> {
        debug_assert!(self.extents.contains(rel));
        rel.iter().zip(&self.offset).map(|(r, o)| r + o).collect()
    }

    /// Read at a window-relative index.
    pub fn get_nd<T: ScalarVal>(&self, rel: &[usize], leaf: usize) -> T {
        self.view.get_nd::<T>(&self.absolute(rel), leaf)
    }

    /// Write at a window-relative index.
    pub fn set_nd<T: ScalarVal>(&mut self, rel: &[usize], leaf: usize, v: T) {
        let abs = self.absolute(rel);
        self.view.set_nd::<T>(&abs, leaf, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::SoA;
    use crate::view::view::alloc_view;

    #[test]
    fn window_reads_relative() {
        let dims = ArrayDims::from([4, 4]);
        let mut v = alloc_view(SoA::multi_blob(&particle_dim(), dims));
        for a in 0..4 {
            for b in 0..4 {
                v.set_nd::<f32>(&[a, b], 1, (a * 10 + b) as f32);
            }
        }
        let w = VirtualView::new(&v, vec![1, 2], ArrayDims::from([2, 2]));
        assert_eq!(w.get_nd::<f32>(&[0, 0], 1), 12.0);
        assert_eq!(w.get_nd::<f32>(&[1, 1], 1), 23.0);
    }

    #[test]
    fn mutable_window_writes_through() {
        let dims = ArrayDims::from([4, 4]);
        let mut v = alloc_view(SoA::multi_blob(&particle_dim(), dims));
        {
            let mut w = VirtualViewMut::new(&mut v, vec![2, 0], ArrayDims::from([2, 4]));
            w.set_nd::<f64>(&[0, 3], 4, 5.5);
            assert_eq!(w.get_nd::<f64>(&[0, 3], 4), 5.5);
        }
        assert_eq!(v.get_nd::<f64>(&[2, 3], 4), 5.5);
    }

    #[test]
    #[should_panic(expected = "exceeds dimension")]
    fn oversized_window_panics() {
        let v = alloc_view(SoA::multi_blob(&particle_dim(), ArrayDims::from([4, 4])));
        let _ = VirtualView::new(&v, vec![3, 0], ArrayDims::from([2, 4]));
    }
}
