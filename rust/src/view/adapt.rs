//! [`AdaptiveView`]: the adaptive relayout engine — the first layer
//! that *uses* the whole plan stack (ARCHITECTURE.md, EXPERIMENTS.md
//! §Adapt).
//!
//! The paper's §4.3 derives a hot/cold Split for lbm from Trace counts
//! by hand; the follow-up "Updates on the Low-Level Abstraction of
//! Memory Access" names automatic mapping choice as the next frontier.
//! This module closes the observe → decide → migrate loop with the
//! pieces the repo already has:
//!
//! 1. **Observe** — the view's mapping is wrapped in a
//!    [`Trace`](crate::mapping::Trace) for a *sampling epoch* of
//!    `sample_steps` workload steps; the epoch ends with an
//!    epoch-consistent [`Trace::snapshot`](crate::mapping::Trace)
//!    (counter-vector swap under exclusive access — never a torn
//!    mid-epoch mixture).
//! 2. **Decide** — the counts become
//!    [`FieldStats`](crate::mapping::FieldStats) and, with the
//!    workload's [`AccessPattern`] hint, a
//!    [`Recommendation`](crate::mapping::Recommendation); the
//!    recommendation materializes as a concrete
//!    [`RecipeMapping`](crate::mapping::RecipeMapping) via
//!    `Recommendation::to_mapping`. **Hysteresis**: if the recipe
//!    already matches the live layout, or the cost model's predicted
//!    gain ([`migration_gain`](crate::mapping::migration_gain)) is
//!    below `1 + hysteresis`, the engine stays put — a stable workload
//!    never re-migrates.
//! 3. **Migrate** — the live blobs move into the new layout through a
//!    compiled [`CopyProgram`](crate::copy::CopyProgram) executed on
//!    plan-aligned shards over scoped threads ([`migrate_with`]); the
//!    engine's [`ProgramCache`] is keyed by (src plan, dst plan)
//!    fingerprint, so repeated migrations between the same layouts
//!    compile once.
//!
//! Then the cycle restarts: after `steady_steps` uninstrumented steps
//! the engine re-enters a sampling epoch, so workloads whose access
//! pattern *drifts* (picframe) are re-observed and re-layouted.
//!
//! # Blob storage and the recycling pool (layer 0)
//!
//! The engine is generic over its blob storage: `AdaptiveView<R>`
//! owns a [`BlobRecycler`] `R` (default [`VecAlloc`], i.e. plain
//! `Vec<u8>` blobs) and draws **every** blob it creates — migration
//! destinations and the [`AdaptiveView::step_zip`] ping-pong back
//! buffer — from it. With a [`crate::blob::BlobPool`]
//! ([`AdaptiveView::with_recycler`]), retired blobs return to the
//! pool's size-class free lists when dropped, so a *warm* engine
//! performs **zero** fresh blob allocations per migration. The pool's
//! re-zero is skipped exactly when the compiled program proves full
//! destination byte coverage
//! ([`programs_cover_dst`](crate::copy::programs_cover_dst)) — padding
//! included — so pooled runs stay bit-identical to fresh-zeroed runs
//! (property-tested in `rust/tests/prop_adapt.rs`).
//!
//! Workload kernels plug in through [`AdaptiveKernel`] (one view per
//! step: n-body, picframe drift, hep sweeps) or [`AdaptiveKernel2`]
//! (src/dst ping-pong per step: lbm stream-collide) — the generic
//! method is what lets one kernel body run on every layout *and every
//! blob type* the engine can hold, statically dispatched per
//! [`RecipeMapping`] variant.

use std::sync::Arc;

use crate::blob::{BlobAllocator, BlobMut, BlobRecycler, VecAlloc};
use crate::copy::program::execute_parallel;
use crate::copy::{programs_cover_dst, ProgramCache};
use crate::mapping::{
    migration_gain, recommend_stats, AccessPattern, CostModel, FieldStats, Mapping, RecipeMapping,
    Recommendation, Trace,
};
use crate::record::RecordInfo;
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// Tuning knobs of the [`AdaptiveView`] epoch state machine.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// Access-pattern hint handed to the advisor (the one input the
    /// trace cannot observe: *where* the workload walks, not what).
    pub pattern: AccessPattern,
    /// Workload steps per sampling (traced) epoch; clamped to ≥ 1.
    pub sample_steps: usize,
    /// Uninstrumented steps between sampling epochs; `0` disables
    /// re-sampling (observe once, stay steady forever).
    pub steady_steps: usize,
    /// Minimum predicted relative gain (above 1.0) the cost model must
    /// report before the engine migrates an already-advised layout —
    /// marginal wins never pay the copy.
    pub hysteresis: f64,
    /// Worker threads for the migration copy (plan-aligned shards).
    pub threads: usize,
    /// Cost-model overrides for the gain computation — set
    /// [`CostModel::measured_current`] (e.g. from a
    /// [`crate::mapping::HeatmapSnapshot::bytes_per_record`] epoch) to
    /// replace the modeled current-layout cost with a measurement;
    /// updatable between epochs via [`AdaptiveView::set_cost`].
    pub cost: CostModel,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            pattern: AccessPattern::Streaming,
            sample_steps: 1,
            steady_steps: 32,
            hysteresis: 0.10,
            threads: 1,
            cost: CostModel::default(),
        }
    }
}

/// A workload step over one view — implemented once, generic over the
/// mapping *and* the blob storage, so the engine can run it on
/// whatever layout it currently holds (instrumented during sampling
/// epochs, bare otherwise) over `Vec<u8>`, pooled, aligned or external
/// blobs alike.
pub trait AdaptiveKernel {
    /// Run one step of the workload over `view`.
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>);
}

/// A workload step over a (src, dst) view pair of the *same* mapping —
/// the double-buffered shape (lbm stream-collide). The engine owns the
/// back buffer and swaps after every step; the kernel must write every
/// record of `dst` (the back buffer's prior contents are stale).
pub trait AdaptiveKernel2 {
    /// Run one step, pulling from `src` and writing every record of
    /// `dst`.
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, src: &View<M, B>, dst: &mut View<M, B>);
}

/// A sampling-phase view: the live recipe wrapped in a shared trace
/// (the `Arc` lets a ping-pong back buffer count into the same epoch).
type TracedView<B> = View<Arc<Trace<RecipeMapping>>, B>;

/// The engine's two phases. The front view always holds the live data;
/// the back buffer exists only for [`AdaptiveKernel2`] ping-pong and is
/// allocated lazily per phase.
enum Phase<B: BlobMut> {
    /// Counting epoch: the recipe rides inside an `Arc<Trace<..>>`, so
    /// the optional back buffer shares the *same* counters.
    Sampling {
        front: TracedView<B>,
        back: Option<TracedView<B>>,
        left: usize,
    },
    /// Uninstrumented steady state on the adopted layout.
    Steady {
        front: View<RecipeMapping, B>,
        back: Option<View<RecipeMapping, B>>,
        left: usize,
    },
}

/// The engine's migration body, usable standalone (the `bench-alloc`
/// driver measures exactly this path): compile — or look up — the
/// sharded copy programs for `(src, target)` through `cache`, draw the
/// destination blobs from `recycler`, and execute. The destination
/// skips its re-zero **only** when the program proves full byte
/// coverage ([`programs_cover_dst`]), so recycled memory can never
/// leak stale bytes into padding a fresh-zeroed run would have zeroed.
pub fn migrate_with<MS, MD, R>(
    cache: &ProgramCache,
    src: &View<MS, R::Blob>,
    target: MD,
    recycler: &R,
    threads: Option<usize>,
) -> View<MD, R::Blob>
where
    MS: Mapping,
    MD: Mapping + Clone,
    R: BlobRecycler,
    R::Blob: Sync,
{
    let sizes: Vec<usize> = (0..target.blob_count()).map(|b| target.blob_size(b)).collect();
    cache.with_parallel_programs(src.mapping(), &target, threads, |progs| {
        let covered = programs_cover_dst(progs, &sizes);
        let blobs: Vec<R::Blob> = sizes
            .iter()
            .map(|&s| if covered { recycler.allocate_covered(s) } else { recycler.allocate(s) })
            .collect();
        let mut dst = View::from_blobs(target.clone(), blobs);
        execute_parallel(progs, src, &mut dst);
        dst
    })
}

/// A self-relayouting view: wraps any starting layout, samples access
/// behavior through trace epochs, and migrates the live data to the
/// advisor's recommended layout when the predicted gain clears the
/// hysteresis threshold. See the [module docs](self) for the loop.
///
/// ```
/// use llama::prelude::*;
///
/// struct Sweep;
/// impl AdaptiveKernel for Sweep {
///     fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
///         for i in 0..v.count() {
///             let x: f32 = v.get(i, 0);
///             v.set(i, 0, x + 1.0); // touches only the hot leaf
///         }
///     }
/// }
///
/// let d = llama::record_dim! { hot: f32, cold: [f64; 6] };
/// let view = alloc_view(AoS::aligned(&d, ArrayDims::linear(64)));
/// let mut av = AdaptiveView::new(view, AdaptiveConfig::default());
/// for _ in 0..4 {
///     av.step(&mut Sweep);
/// }
/// // The trace epoch saw 1 hot leaf of 7: the engine adopted the
/// // advisor's hot/cold Split and carried the data across.
/// assert_eq!(av.migrations(), 1);
/// assert!(av.mapping_name().starts_with("Split("));
/// assert_eq!(av.get::<f32>(3, 0), 4.0);
/// ```
///
/// With a [`crate::blob::BlobPool`] as the recycler, every blob the
/// engine creates is drawn from — and returned to — the pool:
///
/// ```
/// use llama::prelude::*;
///
/// # struct Sweep;
/// # impl AdaptiveKernel for Sweep {
/// #     fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
/// #         for i in 0..v.count() {
/// #             let x: f32 = v.get(i, 0);
/// #             v.set(i, 0, x + 1.0);
/// #         }
/// #     }
/// # }
/// let d = llama::record_dim! { hot: f32, cold: [f64; 6] };
/// let pool = BlobPool::new();
/// let view = alloc_view_with(AoS::aligned(&d, ArrayDims::linear(64)), pool.clone());
/// let mut av = AdaptiveView::with_recycler(view, AdaptiveConfig::default(), pool.clone());
/// for _ in 0..4 {
///     av.step(&mut Sweep);
/// }
/// assert_eq!(av.migrations(), 1);
/// // The retired AoS blob went back to the pool when the migration
/// // released it.
/// assert!(pool.free_blocks() > 0);
/// ```
pub struct AdaptiveView<R: BlobRecycler = VecAlloc> {
    cfg: AdaptiveConfig,
    /// `None` only transiently inside phase transitions.
    phase: Option<Phase<R::Blob>>,
    /// Shared by reference so one cache can serve a whole fleet of
    /// engines ([`AdaptiveView::share_cache`]): layout pairs repeated
    /// across stores compile once, fleet-wide.
    cache: Arc<ProgramCache>,
    info: Arc<RecordInfo>,
    migrations: usize,
    /// The recommendation describing the *current* layout, once the
    /// advisor has matched one (the hysteresis baseline).
    advised: Option<Recommendation>,
    /// When set, epoch decisions that clear both hysteresis gates are
    /// *parked* in `pending` instead of migrating inline — the
    /// [`crate::view::serve::AdvisorPool`] budget loop ranks the parked
    /// candidates by gain and applies only the winners.
    defer_migrations: bool,
    pending: Option<PendingMigration>,
    recycler: R,
}

/// A migration decision the engine has made but not executed: the
/// advisor's candidate, the materialized target layout, and the cost
/// model's predicted relative gain — everything a budget scheduler
/// needs to rank it. Produced when [`AdaptiveView::set_defer`] is on;
/// executed (or overwritten by the next epoch) via
/// [`AdaptiveView::apply_pending`].
pub struct PendingMigration {
    candidate: Recommendation,
    target: RecipeMapping,
    /// Predicted relative gain; `f64::INFINITY` for a first decision
    /// (no adopted baseline to compare against — always worth taking).
    gain: f64,
}

impl PendingMigration {
    /// The predicted relative gain the budget scheduler ranks by.
    pub fn gain(&self) -> f64 {
        self.gain
    }

    /// The advisor's recommendation awaiting execution.
    pub fn candidate(&self) -> &Recommendation {
        &self.candidate
    }

    /// Name of the layout this migration would adopt.
    pub fn target_name(&self) -> String {
        self.target.mapping_name()
    }
}

impl AdaptiveView<VecAlloc> {
    /// Wrap an existing `Vec<u8>`-backed view (any mapping, any
    /// starting layout) and begin a sampling epoch. For pooled or
    /// otherwise custom storage use [`AdaptiveView::with_recycler`].
    pub fn new<M: Mapping + 'static>(view: View<M, Vec<u8>>, cfg: AdaptiveConfig) -> AdaptiveView {
        Self::with_recycler(view, cfg, VecAlloc)
    }

    /// Re-host a previously adapted view ([`AdaptiveView::into_view`])
    /// — data and layout carry over, and a fresh observe cycle begins.
    pub fn from_recipe(view: View<RecipeMapping, Vec<u8>>, cfg: AdaptiveConfig) -> AdaptiveView {
        Self::from_recipe_with(view, cfg, VecAlloc)
    }
}

impl<R: BlobRecycler> AdaptiveView<R>
where
    R::Blob: Sync,
{
    /// Wrap an existing view whose blobs came from `recycler` (any
    /// mapping, any starting layout) and begin a sampling epoch. All
    /// future engine allocations — migration destinations, zip back
    /// buffers — are drawn from `recycler`; with a
    /// [`crate::blob::BlobPool`] the retired blobs recycle, so a warm
    /// engine migrates without touching the system allocator.
    pub fn with_recycler<M: Mapping + 'static>(
        view: View<M, R::Blob>,
        cfg: AdaptiveConfig,
        recycler: R,
    ) -> AdaptiveView<R> {
        let (mapping, blobs) = view.into_parts();
        Self::from_parts(RecipeMapping::Other(Arc::new(mapping)), blobs, cfg, recycler)
    }

    /// [`AdaptiveView::from_recipe`] with an explicit recycler.
    pub fn from_recipe_with(
        view: View<RecipeMapping, R::Blob>,
        cfg: AdaptiveConfig,
        recycler: R,
    ) -> AdaptiveView<R> {
        let (recipe, blobs) = view.into_parts();
        Self::from_parts(recipe, blobs, cfg, recycler)
    }

    fn from_parts(
        recipe: RecipeMapping,
        blobs: Vec<R::Blob>,
        cfg: AdaptiveConfig,
        recycler: R,
    ) -> AdaptiveView<R> {
        let info = recipe.info().clone();
        let mut av = AdaptiveView {
            cfg,
            phase: None,
            cache: Arc::new(ProgramCache::new()),
            info,
            migrations: 0,
            advised: None,
            defer_migrations: false,
            pending: None,
            recycler,
        };
        av.phase = Some(av.enter_sampling(recipe, blobs));
        av
    }

    fn enter_sampling(&self, recipe: RecipeMapping, blobs: Vec<R::Blob>) -> Phase<R::Blob> {
        let traced = Arc::new(Trace::new(recipe));
        Phase::Sampling {
            front: View::from_blobs(traced, blobs),
            back: None,
            left: self.cfg.sample_steps.max(1),
        }
    }

    /// A view over `mapping` with every blob drawn (zeroed) from the
    /// engine's recycler — the zip back buffer's allocation path.
    fn alloc_from_recycler<M: Mapping + Clone>(recycler: &R, mapping: &M) -> View<M, R::Blob> {
        crate::view::view::alloc_view_with(mapping.clone(), recycler)
    }

    /// Run one workload step, advancing the epoch state machine: the
    /// step that completes a sampling epoch triggers the decide (and
    /// possibly migrate) transition before returning.
    pub fn step<K: AdaptiveKernel>(&mut self, kernel: &mut K) {
        let phase = self.phase.take().expect("phase present outside transitions");
        self.phase = Some(match phase {
            Phase::Sampling { mut front, back, left } => {
                kernel.run(&mut front);
                if left <= 1 {
                    self.finish_sampling(front, back)
                } else {
                    Phase::Sampling { front, back, left: left - 1 }
                }
            }
            Phase::Steady { mut front, back, left } => {
                kernel.run(&mut front);
                self.advance_steady(front, back, left)
            }
        });
    }

    /// One ping-pong: ensure a back buffer (drawn zeroed from the
    /// recycler, sharing `front`'s mapping — and, while sampling, its
    /// trace counters), run the kernel, swap.
    fn zip_once<M, K>(
        recycler: &R,
        kernel: &mut K,
        front: &mut View<M, R::Blob>,
        back: &mut Option<View<M, R::Blob>>,
    ) where
        M: Mapping + Clone,
        K: AdaptiveKernel2,
    {
        let b = back.get_or_insert_with(|| Self::alloc_from_recycler(recycler, front.mapping()));
        kernel.run(front, b);
        std::mem::swap(front, b);
    }

    /// Run one double-buffered workload step (src → dst, then swap);
    /// same epoch semantics as [`AdaptiveView::step`]. The back buffer
    /// is allocated lazily with the current layout, from the engine's
    /// recycler — during sampling it shares the front buffer's trace
    /// counters, and when a phase ends it returns to the recycler's
    /// pool.
    pub fn step_zip<K: AdaptiveKernel2>(&mut self, kernel: &mut K) {
        let phase = self.phase.take().expect("phase present outside transitions");
        let recycler = &self.recycler;
        self.phase = Some(match phase {
            Phase::Sampling { mut front, mut back, left } => {
                Self::zip_once(recycler, kernel, &mut front, &mut back);
                if left <= 1 {
                    self.finish_sampling(front, back)
                } else {
                    Phase::Sampling { front, back, left: left - 1 }
                }
            }
            Phase::Steady { mut front, mut back, left } => {
                Self::zip_once(recycler, kernel, &mut front, &mut back);
                self.advance_steady(front, back, left)
            }
        });
    }

    /// Steady bookkeeping: count down to the next sampling epoch
    /// (`steady_steps == 0` stays steady forever).
    fn advance_steady(
        &mut self,
        front: View<RecipeMapping, R::Blob>,
        back: Option<View<RecipeMapping, R::Blob>>,
        left: usize,
    ) -> Phase<R::Blob> {
        if self.cfg.steady_steps == 0 || left > 1 {
            let left = if self.cfg.steady_steps == 0 { left } else { left - 1 };
            return Phase::Steady { front, back, left };
        }
        // Re-observe: drop the stale back buffer (its blobs return to
        // the recycler's pool), rewrap the recipe.
        drop(back);
        let (recipe, blobs) = front.into_parts();
        self.enter_sampling(recipe, blobs)
    }

    /// End of a sampling epoch: snapshot → stats → recommendation →
    /// (maybe) migration. The trace wrapper is dissolved here; steady
    /// phases run with zero instrumentation overhead.
    fn finish_sampling(
        &mut self,
        front: TracedView<R::Blob>,
        back: Option<TracedView<R::Blob>>,
    ) -> Phase<R::Blob> {
        drop(back); // releases the back buffer's Arc clone (and blobs)
        let (traced, blobs) = front.into_parts();
        let traced =
            Arc::try_unwrap(traced).expect("trace uniquely owned at the epoch boundary");
        let (recipe, snapshot) = traced.into_inner();
        let stats = FieldStats::from_snapshot(&snapshot, &self.info);
        let candidate = recommend_stats(&stats, &self.info, self.cfg.pattern);
        let target = candidate.to_mapping(&self.info.dim, recipe.dims().clone());

        // Hysteresis gate 1: the live layout already is the recipe —
        // any previously parked decision is obsolete too.
        if target.mapping_name() == recipe.mapping_name() {
            self.advised = Some(candidate);
            self.pending = None;
            return self.steady(View::from_blobs(recipe, blobs));
        }
        // Hysteresis gate 2: an already-advised layout only migrates
        // when the predicted gain clears the threshold. The first
        // decision (arbitrary starting layout, nothing to compare
        // against) always adopts the advisor's choice — modeled as an
        // infinite gain so budget schedulers rank it first.
        let gain = match &self.advised {
            Some(current) => {
                migration_gain(&stats, &self.info, current, &candidate, &self.cfg.cost)
            }
            None => f64::INFINITY,
        };
        if gain < 1.0 + self.cfg.hysteresis {
            self.pending = None;
            return self.steady(View::from_blobs(recipe, blobs));
        }
        // Deferred mode: park the decision for the budget scheduler
        // (each epoch end overwrites it — the ranking always sees the
        // freshest observation) and keep serving the current layout.
        if self.defer_migrations {
            self.pending = Some(PendingMigration { candidate, target, gain });
            return self.steady(View::from_blobs(recipe, blobs));
        }
        self.pending = None;
        self.do_migrate(View::from_blobs(recipe, blobs), target, candidate)
    }

    /// The migration body shared by the inline path and
    /// [`AdaptiveView::apply_pending`]: plan-aligned sharded copy
    /// through the cached program — repeated migrations between the
    /// same layout pair replay the compiled op list, with the
    /// destination drawn from the recycler (re-zero skipped when the
    /// program proves full coverage).
    fn do_migrate(
        &mut self,
        src: View<RecipeMapping, R::Blob>,
        target: RecipeMapping,
        candidate: Recommendation,
    ) -> Phase<R::Blob> {
        let dst =
            migrate_with(&self.cache, &src, target, &self.recycler, Some(self.cfg.threads.max(1)));
        // The old layout's blobs return to the recycler's pool here —
        // the next migration of these shapes allocates nothing fresh.
        drop(src);
        self.migrations += 1;
        self.advised = Some(candidate);
        // A measured cost described the layout that just went away;
        // keeping it would bias every later gain computation on the
        // new layout ([`AdaptiveView::set_cost`] re-arms it).
        self.cfg.cost.measured_current = None;
        self.steady(dst)
    }

    fn steady(&self, front: View<RecipeMapping, R::Blob>) -> Phase<R::Blob> {
        Phase::Steady { front, back: None, left: self.cfg.steady_steps }
    }

    /// Number of records in the data space.
    pub fn count(&self) -> usize {
        match self.phase.as_ref().expect("phase present") {
            Phase::Sampling { front, .. } => front.count(),
            Phase::Steady { front, .. } => front.count(),
        }
    }

    /// Read a terminal field (routed through the current layout; reads
    /// during a sampling epoch are counted like any other access).
    pub fn get<T: ScalarVal>(&self, lin: usize, leaf: usize) -> T {
        match self.phase.as_ref().expect("phase present") {
            Phase::Sampling { front, .. } => front.get(lin, leaf),
            Phase::Steady { front, .. } => front.get(lin, leaf),
        }
    }

    /// Write a terminal field through the current layout.
    pub fn set<T: ScalarVal>(&mut self, lin: usize, leaf: usize, v: T) {
        match self.phase.as_mut().expect("phase present") {
            Phase::Sampling { front, .. } => front.set(lin, leaf, v),
            Phase::Steady { front, .. } => front.set(lin, leaf, v),
        }
    }

    /// Name of the layout currently holding the data (without the
    /// sampling epoch's `Trace(..)` wrapper).
    pub fn mapping_name(&self) -> String {
        match self.phase.as_ref().expect("phase present") {
            Phase::Sampling { front, .. } => front.mapping().inner().mapping_name(),
            Phase::Steady { front, .. } => front.mapping().mapping_name(),
        }
    }

    /// True while a trace epoch is counting.
    pub fn is_sampling(&self) -> bool {
        matches!(self.phase.as_ref().expect("phase present"), Phase::Sampling { .. })
    }

    /// Number of layout migrations performed so far.
    pub fn migrations(&self) -> usize {
        self.migrations
    }

    /// The recommendation describing the current layout, once adopted.
    pub fn advised(&self) -> Option<&Recommendation> {
        self.advised.as_ref()
    }

    /// Replace the cost-model overrides used by subsequent migration
    /// decisions — the hook for feeding a measured bytes-per-record
    /// (e.g. from a `Heatmap` epoch run alongside the workload) into
    /// the gain computation. A measurement describes the *current*
    /// layout only: the engine clears it automatically when a
    /// migration replaces that layout, so re-measure and call this
    /// again afterwards.
    pub fn set_cost(&mut self, cost: CostModel) {
        self.cfg.cost = cost;
    }

    /// Toggle deferred-migration mode: when on, epoch decisions that
    /// clear both hysteresis gates are parked as a
    /// [`PendingMigration`] instead of executing inline — the engine
    /// keeps serving the current layout until
    /// [`AdaptiveView::apply_pending`] is called (the
    /// [`crate::view::serve::AdvisorPool`] budget loop).
    pub fn set_defer(&mut self, defer: bool) {
        self.defer_migrations = defer;
    }

    /// The parked migration decision, if any (deferred mode only).
    pub fn pending(&self) -> Option<&PendingMigration> {
        self.pending.as_ref()
    }

    /// Execute the parked migration decision now. A sampling epoch in
    /// flight ends without a decision (its counts are discarded — the
    /// layout is about to change, so they describe a dead layout).
    /// Returns `true` if a migration ran.
    pub fn apply_pending(&mut self) -> bool {
        let Some(p) = self.pending.take() else { return false };
        let phase = self.phase.take().expect("phase present outside transitions");
        let front = match phase {
            Phase::Sampling { front, back, .. } => {
                drop(back);
                let (traced, blobs) = front.into_parts();
                let traced =
                    Arc::try_unwrap(traced).expect("trace uniquely owned at the epoch boundary");
                let (recipe, _) = traced.into_inner();
                View::from_blobs(recipe, blobs)
            }
            Phase::Steady { front, back, .. } => {
                drop(back);
                front
            }
        };
        self.phase = Some(self.do_migrate(front, p.target, p.candidate));
        true
    }

    /// Expose the live layout and blob bytes to `f` without dissolving
    /// the engine — the serving engine's publish path reads the blobs
    /// byte-for-byte here (never through the traced mapping, so a
    /// publish mid-epoch cannot pollute the sample counters).
    pub fn with_live<T>(&self, f: impl FnOnce(&RecipeMapping, &[R::Blob]) -> T) -> T {
        match self.phase.as_ref().expect("phase present") {
            Phase::Sampling { front, .. } => f(front.mapping().inner(), front.blobs()),
            Phase::Steady { front, .. } => f(front.mapping(), front.blobs()),
        }
    }

    /// The engine's program cache (tests assert repeated migrations
    /// between the same layout pair compile once).
    pub fn program_cache(&self) -> &ProgramCache {
        &self.cache
    }

    /// Replace the engine's program cache with a shared one: every
    /// engine in a fleet pointed at the same `Arc` compiles each
    /// (src plan, dst plan, threads) pair once, fleet-wide. Safe at
    /// any time — the cache is pure memoization.
    pub fn share_cache(&mut self, cache: Arc<ProgramCache>) {
        self.cache = cache;
    }

    /// The recycler every engine-created blob is drawn from (tests
    /// assert a warm pool serves migrations without fresh allocations
    /// via [`crate::blob::BlobRecycler::pool_stats`]).
    pub fn recycler(&self) -> &R {
        &self.recycler
    }

    /// Dissolve the engine, returning the live data as a plain view of
    /// the current layout. A sampling epoch in flight ends without a
    /// decision (its counts are discarded).
    pub fn into_view(mut self) -> View<RecipeMapping, R::Blob> {
        match self.phase.take().expect("phase present") {
            Phase::Sampling { front, back, .. } => {
                drop(back);
                let (traced, blobs) = front.into_parts();
                let traced =
                    Arc::try_unwrap(traced).expect("trace uniquely owned at the epoch boundary");
                let (recipe, _) = traced.into_inner();
                View::from_blobs(recipe, blobs)
            }
            Phase::Steady { front, .. } => front,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::blob::BlobPool;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::view::alloc_view_with;
    use crate::view::alloc_view;
    use crate::workloads::nbody::{self, llama_impl};

    /// A move-phase kernel: streams pos/vel (6 of 7 leaves).
    struct Move;

    impl AdaptiveKernel for Move {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
            llama_impl::mv(v);
        }
    }

    fn nbody_adaptive(start_soa: bool, cfg: AdaptiveConfig) -> AdaptiveView {
        let d = nbody::particle_dim();
        let n = 64;
        let s = nbody::init_particles(n, 5);
        if start_soa {
            let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(n)));
            llama_impl::load_state(&mut v, &s);
            AdaptiveView::new(v, cfg)
        } else {
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(n)));
            llama_impl::load_state(&mut v, &s);
            AdaptiveView::new(v, cfg)
        }
    }

    #[test]
    fn migrates_from_aos_to_soa_and_preserves_data() {
        let mut av = nbody_adaptive(false, AdaptiveConfig::default());
        assert!(av.is_sampling());
        // Reference: the same steps on a fixed layout (bit-identical
        // across layouts by the workload's determinism tests).
        let d = nbody::particle_dim();
        let mut reference = alloc_view(AoS::aligned(&d, ArrayDims::linear(64)));
        llama_impl::load_state(&mut reference, &nbody::init_particles(64, 5));
        for _ in 0..4 {
            av.step(&mut Move);
            llama_impl::mv(&mut reference);
        }
        assert_eq!(av.migrations(), 1);
        assert!(av.mapping_name().starts_with("SoA("), "{}", av.mapping_name());
        assert!(!av.is_sampling());
        for lin in [0usize, 13, 63] {
            for leaf in 0..7 {
                assert_eq!(av.get::<f32>(lin, leaf), reference.get::<f32>(lin, leaf));
            }
        }
    }

    #[test]
    fn already_optimal_layout_never_migrates() {
        let cfg = AdaptiveConfig { steady_steps: 2, ..Default::default() };
        let mut av = nbody_adaptive(true, cfg);
        for _ in 0..12 {
            av.step(&mut Move);
        }
        // Multiple sampling epochs happened (steady_steps = 2), yet the
        // SoA start matches the advice every time: zero migrations.
        assert_eq!(av.migrations(), 0);
        assert!(av.mapping_name().starts_with("SoA("));
    }

    #[test]
    fn stable_workload_migrates_once_despite_resampling() {
        let cfg = AdaptiveConfig { steady_steps: 2, ..Default::default() };
        let mut av = nbody_adaptive(false, cfg);
        for _ in 0..12 {
            av.step(&mut Move);
        }
        // One adoption, then hysteresis holds across every re-sample.
        assert_eq!(av.migrations(), 1);
    }

    /// A zip kernel copying all fields src → dst (layout-preserving
    /// identity step) — exercises the double-buffered path.
    struct CopyAll;

    impl AdaptiveKernel2 for CopyAll {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, src: &View<M, B>, dst: &mut View<M, B>) {
            for lin in 0..src.count() {
                for leaf in 0..7 {
                    let v: f32 = src.get(lin, leaf);
                    dst.set(lin, leaf, v);
                }
            }
        }
    }

    #[test]
    fn zip_steps_ping_pong_and_preserve_data() {
        let mut av = nbody_adaptive(false, AdaptiveConfig::default());
        let want: f32 = av.get(7, 2);
        for _ in 0..3 {
            av.step_zip(&mut CopyAll);
        }
        assert_eq!(av.get::<f32>(7, 2), want);
        assert_eq!(av.migrations(), 1);
    }

    /// Touches every leaf of every record (full-record sweep).
    struct FullTouch;

    impl AdaptiveKernel for FullTouch {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
            for lin in 0..v.count() {
                for leaf in 0..7 {
                    let x: f32 = v.get(lin, leaf);
                    v.set(lin, leaf, x);
                }
            }
        }
    }

    /// Touches only pos.x.
    struct OneLeaf;

    impl AdaptiveKernel for OneLeaf {
        fn run<M: Mapping, B: BlobMut + Sync>(&mut self, v: &mut View<M, B>) {
            for lin in 0..v.count() {
                let x: f32 = v.get(lin, 0);
                v.set(lin, 0, x);
            }
        }
    }

    /// The measured-cost hook gates migration: with a measured
    /// current-layout cost as low as the candidate's, the gain falls
    /// under the hysteresis threshold and the engine stays put; with
    /// the default (modeled) cost the same workload shift migrates.
    #[test]
    fn measured_cost_hook_gates_migration() {
        let run = |cost: crate::mapping::CostModel| {
            let d = nbody::particle_dim();
            let mut v = alloc_view(AoS::aligned(&d, ArrayDims::linear(64)));
            llama_impl::load_state(&mut v, &nbody::init_particles(64, 2));
            let cfg = AdaptiveConfig {
                pattern: AccessPattern::RandomFullRecord,
                steady_steps: 1,
                ..Default::default()
            };
            let mut av = AdaptiveView::new(v, cfg);
            // Epoch 1: full-record random access -> advisor says AoS,
            // name-equal -> advised = Some(Aos), no migration.
            av.step(&mut FullTouch);
            assert_eq!(av.migrations(), 0);
            assert!(av.advised().is_some());
            av.set_cost(cost);
            // Workload narrows: steady step, then a re-sample epoch
            // that recommends a hot/cold Split over the AoS baseline.
            av.step(&mut OneLeaf);
            av.step(&mut OneLeaf);
            av.migrations()
        };
        // Modeled AoS cost (28 aligned bytes vs 4 hot): gain 7 -> move.
        assert_eq!(run(crate::mapping::CostModel::default()), 1);
        // Measured current cost already at the candidate's 4 bytes per
        // record: gain 1.0 < 1.1 -> the hook vetoes the migration.
        let measured = crate::mapping::CostModel { measured_current: Some(4.0) };
        assert_eq!(run(measured), 0);
    }

    #[test]
    fn into_view_returns_the_live_layout() {
        let mut av = nbody_adaptive(false, AdaptiveConfig::default());
        av.step(&mut Move); // completes the sampling epoch
        let v = av.into_view();
        assert!(v.mapping().mapping_name().starts_with("SoA("));
        assert_eq!(v.count(), 64);
        // Dissolving mid-epoch also works (counts discarded).
        let av = nbody_adaptive(false, AdaptiveConfig { sample_steps: 5, ..Default::default() });
        let v = av.into_view();
        assert!(v.mapping().mapping_name().starts_with("AoS("));
    }

    #[test]
    fn from_recipe_rehosts_data_and_layout() {
        let mut av = nbody_adaptive(false, AdaptiveConfig::default());
        av.step(&mut Move); // epoch completes: AoS -> SoA migration
        let want: f32 = av.get(5, 3);
        let mut av2 = AdaptiveView::from_recipe(av.into_view(), AdaptiveConfig::default());
        assert!(av2.is_sampling());
        assert_eq!(av2.get::<f32>(5, 3), want, "re-hosting must carry the data over");
        av2.step(&mut Move);
        // The re-hosted SoA layout matches the advice again: no copy.
        assert_eq!(av2.migrations(), 0);
        assert!(av2.mapping_name().starts_with("SoA("));
    }

    #[test]
    fn arbitrary_starting_layouts_ride_type_erased() {
        let d = nbody::particle_dim();
        let n = 40;
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(n), 8));
        llama_impl::load_state(&mut v, &nbody::init_particles(n, 3));
        let mut av = AdaptiveView::new(v, AdaptiveConfig::default());
        av.step(&mut Move);
        // AoSoA start, streaming 6/7 leaves: advisor says SoA MB.
        assert_eq!(av.migrations(), 1);
        assert!(av.mapping_name().starts_with("SoA("));
    }

    /// The pooled engine behaves exactly like the `Vec<u8>` engine and
    /// draws every blob it creates from the pool — a second engine on
    /// the warmed pool migrates with zero fresh allocations.
    #[test]
    fn pooled_engine_matches_vec_engine_and_recycles() {
        let d = nbody::particle_dim();
        let n = 64;
        let s = nbody::init_particles(n, 5);
        let pool = BlobPool::new();

        let run_round = |pool: &BlobPool| {
            let mut v =
                alloc_view_with(AoS::aligned(&d, ArrayDims::linear(n)), pool.clone());
            llama_impl::load_state(&mut v, &s);
            let mut av =
                AdaptiveView::with_recycler(v, AdaptiveConfig::default(), pool.clone());
            for _ in 0..3 {
                av.step(&mut Move);
            }
            assert_eq!(av.migrations(), 1);
            av.into_view()
        };

        // Round 1 (cold pool): the reference values.
        let pooled = run_round(&pool);
        let mut vec_view = alloc_view(AoS::aligned(&d, ArrayDims::linear(n)));
        llama_impl::load_state(&mut vec_view, &s);
        let mut vec_av = AdaptiveView::new(vec_view, AdaptiveConfig::default());
        for _ in 0..3 {
            vec_av.step(&mut Move);
        }
        let vec_final = vec_av.into_view();
        assert_eq!(pooled.mapping().mapping_name(), vec_final.mapping().mapping_name());
        // Bit-identical storage: SoA destinations are fully covered by
        // the program, so the skipped re-zero cannot be observed.
        for (p, v) in pooled.blobs().iter().zip(vec_final.blobs()) {
            assert_eq!(p, v);
        }

        // Round 2 (warm pool): same migration, zero fresh allocations.
        drop(pooled);
        let before = pool.stats();
        let again = run_round(&pool);
        let after = pool.stats();
        assert_eq!(after.misses, before.misses, "warm engine allocated fresh blobs");
        assert!(after.hits > before.hits);
        for (p, v) in again.blobs().iter().zip(vec_final.blobs()) {
            assert_eq!(p, v);
        }
    }

    /// Deferred mode parks the decision (gain + target visible to a
    /// budget scheduler) and `apply_pending` executes it later, data
    /// intact.
    #[test]
    fn deferred_migration_parks_and_applies() {
        let mut av = nbody_adaptive(false, AdaptiveConfig::default());
        av.set_defer(true);
        av.step(&mut Move); // epoch completes -> decision parked
        assert_eq!(av.migrations(), 0);
        assert!(av.mapping_name().starts_with("AoS("), "{}", av.mapping_name());
        let p = av.pending().expect("decision parked");
        // First decision: no adopted baseline, ranked as infinite gain.
        assert!(p.gain().is_infinite());
        assert!(p.target_name().starts_with("SoA("));
        let want: f32 = av.get(7, 2);
        assert!(av.apply_pending());
        assert_eq!(av.migrations(), 1);
        assert!(av.mapping_name().starts_with("SoA("));
        assert_eq!(av.get::<f32>(7, 2), want, "apply_pending must carry the data across");
        assert!(av.pending().is_none());
        assert!(!av.apply_pending(), "nothing left to apply");
    }

    /// `with_live` peels the trace wrapper during sampling and exposes
    /// the bare recipe + blobs in both phases.
    #[test]
    fn with_live_exposes_layout_and_blobs_in_both_phases() {
        let mut av =
            nbody_adaptive(false, AdaptiveConfig { sample_steps: 2, ..Default::default() });
        av.step(&mut Move);
        assert!(av.is_sampling());
        let (name, nblobs) = av.with_live(|m, b| (m.mapping_name(), b.len()));
        assert!(name.starts_with("AoS("), "{name}");
        assert_eq!(nblobs, 1);
        av.step(&mut Move); // completes the epoch: AoS -> SoA
        let (name, nblobs) = av.with_live(|m, b| (m.mapping_name(), b.len()));
        assert!(name.starts_with("SoA("), "{name}");
        assert_eq!(nblobs, 7);
    }

    /// Two engines pointed at one shared cache compile their common
    /// layout pair once, fleet-wide.
    #[test]
    fn shared_cache_compiles_once_across_engines() {
        let shared = Arc::new(ProgramCache::new());
        for round in 0..2 {
            let mut av = nbody_adaptive(false, AdaptiveConfig::default());
            av.share_cache(Arc::clone(&shared));
            av.step(&mut Move);
            assert_eq!(av.migrations(), 1, "round {round}");
        }
        assert_eq!(shared.entries(), 1, "one AoS->SoA pair, compiled once");
        assert!(shared.hits() >= 1, "second engine must reuse the compiled programs");
    }

    /// Zip back buffers come from the recycler too: after an epoch
    /// ends, the retired buffer's blobs are back on the free lists.
    #[test]
    fn pooled_zip_back_buffer_recycles() {
        let d = nbody::particle_dim();
        let n = 64;
        let pool = BlobPool::new();
        let mut v = alloc_view_with(AoS::aligned(&d, ArrayDims::linear(n)), pool.clone());
        llama_impl::load_state(&mut v, &nbody::init_particles(n, 9));
        let mut av = AdaptiveView::with_recycler(v, AdaptiveConfig::default(), pool.clone());
        for _ in 0..3 {
            av.step_zip(&mut CopyAll);
        }
        assert_eq!(av.migrations(), 1);
        // Live: front + back of the steady phase; everything else
        // (AoS front, traced back, migration source) has returned.
        let stats = pool.stats();
        assert!(stats.outstanding >= 2);
        drop(av);
        assert_eq!(pool.stats().outstanding, 0, "engine must return every blob");
    }
}
