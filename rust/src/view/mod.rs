//! **Views** (paper §3.4–3.6): the user-facing access layer of the data
//! space. A [`View`] combines a mapping with an array of blobs; accesses
//! are built up lazily ([`RecordRef`], the paper's `VirtualRecord`) and
//! the mapping function is only invoked for *terminal* accesses.

pub mod adapt;
pub mod cursor;
pub mod iter;
pub mod one_record;
pub mod scalar;
pub mod serve;
pub mod shard;
pub mod simd;
pub mod view;
pub mod virtual_record;
pub mod virtual_view;

pub use adapt::{
    migrate_with, AdaptiveConfig, AdaptiveKernel, AdaptiveKernel2, AdaptiveView, PendingMigration,
};
pub use cursor::{
    CursorRead, CursorWrite, LeafCursor, LeafCursorMut, PiecewiseCursor, PiecewiseCursorMut,
    PlanCursors, PlanCursorsMut,
};
pub use iter::RecordIter;
pub use one_record::OneRecord;
pub use scalar::ScalarVal;
pub use serve::{AdvisorPool, CycleEntry, CycleReport, ReadGuard, ServingEngine};
pub use shard::{
    pair_align, par_execute, par_execute_zip, par_map_shards, par_shards, plan_aliases,
    shard_align, shard_pair, shard_plan, shard_range, Shard, ShardKernel, ShardKernel2,
};
pub use simd::{simd_compiled, SimdCursorRead, SimdCursorWrite, SimdPath};
pub use view::{alloc_view, alloc_view_with, View};
pub use virtual_record::{RecordRef, RecordRefMut};
pub use virtual_view::VirtualView;
