//! [`RecordRef`]/[`RecordRefMut`]: the paper's `VirtualRecord` (§3.5).
//!
//! A non-terminal access on a view returns a record ref that merely
//! *aggregates index information* (array index + record-tree prefix);
//! the mapping function is invoked only on terminal access — LLAMA's
//! lazy-evaluation design point that distinguishes it from mdspan-style
//! libraries (paper §2.3).

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::record::RecordCoord;
use crate::view::one_record::OneRecord;
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// Immutable virtual record: view + linear index + record-coord prefix.
#[derive(Debug)]
pub struct RecordRef<'v, M: Mapping, B: Blob> {
    view: &'v View<M, B>,
    lin: usize,
    prefix: RecordCoord,
}

impl<'v, M: Mapping, B: Blob> Clone for RecordRef<'v, M, B> {
    fn clone(&self) -> Self {
        RecordRef { view: self.view, lin: self.lin, prefix: self.prefix.clone() }
    }
}

impl<'v, M: Mapping, B: Blob> RecordRef<'v, M, B> {
    pub(crate) fn new(view: &'v View<M, B>, lin: usize) -> Self {
        RecordRef { view, lin, prefix: RecordCoord::root() }
    }

    /// Canonical linear index of the referenced record.
    pub fn lin(&self) -> usize {
        self.lin
    }

    /// Record coordinate this reference has descended to.
    pub fn coord(&self) -> &RecordCoord {
        &self.prefix
    }

    /// Non-terminal access: descend into child `i` of the current
    /// record node. No address computation happens.
    pub fn child(&self, i: usize) -> Self {
        RecordRef { view: self.view, lin: self.lin, prefix: self.prefix.child(i) }
    }

    /// Non-terminal access by field name (one level).
    pub fn at(&self, name: &str) -> Self {
        let idx = child_index(self.view.mapping(), &self.prefix, name)
            .unwrap_or_else(|| panic!("no field '{name}' under {}", self.prefix));
        self.child(idx)
    }

    /// Terminal access: read the leaf at the current prefix (which must
    /// be a leaf) — this is where the mapping finally runs.
    pub fn get<T: ScalarVal>(&self) -> T {
        let leaf = self
            .view
            .mapping()
            .info()
            .leaf_by_coord(&self.prefix)
            .unwrap_or_else(|| panic!("{} is not a terminal field", self.prefix));
        self.view.get::<T>(self.lin, leaf)
    }

    /// Terminal access by relative dotted path, e.g. `"pos.x"`.
    pub fn get_path<T: ScalarVal>(&self, path: &str) -> T {
        let leaf = resolve_path(self.view.mapping(), &self.prefix, path);
        self.view.get::<T>(self.lin, leaf)
    }

    /// Deep-copy the subtree at the current prefix into a stack value
    /// (paper's `llama::One` construction from a virtual record).
    pub fn load(&self) -> OneRecord {
        let info = self.view.mapping().info().clone();
        if self.prefix.is_root() {
            return self.view.load_one(self.lin);
        }
        // Build a sub-record OneRecord of the leaves under the prefix.
        let leaves = info.leaves_under(&self.prefix);
        let mut dim = crate::record::RecordDim::new();
        for &l in &leaves {
            let f = &info.fields[l];
            let rel = f
                .path
                .clone();
            dim = dim.field(rel, crate::record::Type::Scalar(f.scalar));
        }
        let sub = std::sync::Arc::new(crate::record::RecordInfo::new(&dim));
        let mut one = OneRecord::new(sub);
        for (child, &l) in leaves.iter().enumerate() {
            let v = {
                let f = &info.fields[l];
                let (nr, off) = self
                    .view
                    .mapping()
                    .blob_nr_and_offset(l, self.view.mapping().slot_of_lin(self.lin));
                let size = f.size();
                self.view.blobs()[nr].as_bytes()[off..off + size].to_vec()
            };
            one.leaf_bytes_mut(child).copy_from_slice(&v);
            if !self.view.mapping().is_native_representation() {
                one.leaf_bytes_mut(child).reverse();
            }
        }
        one
    }
}

/// Mutable virtual record.
#[derive(Debug)]
pub struct RecordRefMut<'v, M: Mapping, B: BlobMut> {
    view: &'v mut View<M, B>,
    lin: usize,
    prefix: RecordCoord,
}

impl<'v, M: Mapping, B: BlobMut> RecordRefMut<'v, M, B> {
    pub(crate) fn new(view: &'v mut View<M, B>, lin: usize) -> Self {
        RecordRefMut { view, lin, prefix: RecordCoord::root() }
    }

    /// Descend into child `i` (consumes self to keep the borrow unique).
    pub fn child(self, i: usize) -> Self {
        RecordRefMut { view: self.view, lin: self.lin, prefix: self.prefix.child(i) }
    }

    /// Descend by field name.
    pub fn at(self, name: &str) -> Self {
        let idx = child_index(self.view.mapping(), &self.prefix, name)
            .unwrap_or_else(|| panic!("no field '{name}' under {}", self.prefix));
        self.child(idx)
    }

    /// Terminal write at the current prefix.
    pub fn set<T: ScalarVal>(&mut self, v: T) {
        let leaf = self
            .view
            .mapping()
            .info()
            .leaf_by_coord(&self.prefix)
            .unwrap_or_else(|| panic!("{} is not a terminal field", self.prefix));
        self.view.set::<T>(self.lin, leaf, v);
    }

    /// Terminal write by relative dotted path.
    pub fn set_path<T: ScalarVal>(&mut self, path: &str, v: T) {
        let leaf = resolve_path(self.view.mapping(), &self.prefix, path);
        self.view.set::<T>(self.lin, leaf, v);
    }

    /// Read through the mutable ref.
    pub fn get_path<T: ScalarVal>(&self, path: &str) -> T {
        let leaf = resolve_path(self.view.mapping(), &self.prefix, path);
        self.view.get::<T>(self.lin, leaf)
    }

    /// Write-through a whole stack record (reference semantics of the
    /// paper's VirtualRecord assignment).
    pub fn store(&mut self, one: &OneRecord) {
        assert!(self.prefix.is_root(), "store() is only supported at the record root");
        self.view.store_one(self.lin, one);
    }
}

/// Resolve the child index of `name` under `prefix` in the record tree.
fn child_index<M: Mapping>(mapping: &M, prefix: &RecordCoord, name: &str) -> Option<usize> {
    use crate::record::Type;
    let mut fields: &[crate::record::Field] = &mapping.info().dim.fields;
    for &c in &prefix.0 {
        match &fields.get(c)?.ty {
            Type::Record(fs) => fields = fs,
            _ => return None,
        }
    }
    fields.iter().position(|f| f.name == name)
}

/// Resolve a relative dotted path from `prefix` to a flat leaf index.
fn resolve_path<M: Mapping>(mapping: &M, prefix: &RecordCoord, path: &str) -> usize {
    let mut coord = prefix.clone();
    for seg in path.split('.') {
        let idx = child_index(mapping, &coord, seg).unwrap_or_else(|| {
            // Array children are named by their numeric index.
            seg.parse::<usize>().ok().unwrap_or_else(|| panic!("no field '{seg}' under {coord}"))
        });
        coord = coord.child(idx);
    }
    mapping
        .info()
        .leaf_by_coord(&coord)
        .unwrap_or_else(|| panic!("path '{path}' does not name a terminal field"))
}

#[cfg(test)]
mod tests {
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, SoA};
    use crate::view::view::alloc_view;

    #[test]
    fn lazy_descend_then_terminal() {
        // paper listing 4: particle = view(i); pos = particle(Pos);
        // y = pos(Y) — only the last line touches memory.
        let mut v = alloc_view(SoA::multi_blob(&particle_dim(), ArrayDims::linear(4)));
        v.set::<f32>(2, 2, 7.5); // pos.y
        let particle = v.record(2);
        let pos = particle.at("pos");
        let y: f32 = pos.at("y").get();
        assert_eq!(y, 7.5);
        assert_eq!(pos.coord().0, vec![1]);
    }

    #[test]
    fn path_access() {
        let mut v = alloc_view(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        v.record_mut(1).set_path::<f64>("mass", 3.25);
        v.record_mut(1).set_path::<bool>("flags.1", true);
        assert_eq!(v.record(1).get_path::<f64>("mass"), 3.25);
        assert!(v.record(1).get_path::<bool>("flags.1"));
        assert!(!v.record(1).get_path::<bool>("flags.0"));
    }

    #[test]
    fn load_subtree() {
        let mut v = alloc_view(SoA::single_blob(&particle_dim(), ArrayDims::linear(4)));
        v.set::<f32>(3, 1, 1.0);
        v.set::<f32>(3, 2, 2.0);
        v.set::<f32>(3, 3, 3.0);
        let pos = v.record(3).at("pos").load();
        assert_eq!(pos.info().leaf_count(), 3);
        assert_eq!(pos.get::<f32>(0), 1.0);
        assert_eq!(pos.get::<f32>(2), 3.0);
    }

    #[test]
    fn store_whole_record() {
        let mut v = alloc_view(AoS::packed(&particle_dim(), ArrayDims::linear(2)));
        let mut one = v.load_one(0);
        one.set::<f64>(4, 42.0);
        v.record_mut(1).store(&one);
        assert_eq!(v.get::<f64>(1, 4), 42.0);
    }

    #[test]
    #[should_panic(expected = "not a terminal field")]
    fn non_terminal_get_panics() {
        let v = alloc_view(AoS::packed(&particle_dim(), ArrayDims::linear(2)));
        let _: f32 = v.record(0).at("pos").get();
    }

    #[test]
    #[should_panic(expected = "no field")]
    fn unknown_field_panics() {
        let v = alloc_view(AoS::packed(&particle_dim(), ArrayDims::linear(2)));
        let _ = v.record(0).at("nope");
    }
}
