//! View iterators (paper §3.6 / listing 7): iterate records of a view
//! like an STL range. Iterators from views with *different mappings*
//! compose (e.g. a transform from an AoS view into a SoA view), because
//! records interact via the record dimension, not the layout.

use crate::blob::Blob;
use crate::mapping::Mapping;
use crate::view::virtual_record::RecordRef;
use crate::view::view::View;

/// Iterator yielding a [`RecordRef`] per record, canonical order.
#[derive(Debug)]
pub struct RecordIter<'v, M: Mapping, B: Blob> {
    view: &'v View<M, B>,
    next: usize,
    end: usize,
}

impl<'v, M: Mapping, B: Blob> RecordIter<'v, M, B> {
    /// Iterate all records of `view` in canonical order.
    pub fn new(view: &'v View<M, B>) -> Self {
        RecordIter { view, next: 0, end: view.count() }
    }
}

impl<'v, M: Mapping, B: Blob> Iterator for RecordIter<'v, M, B> {
    type Item = RecordRef<'v, M, B>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.end {
            return None;
        }
        let r = self.view.record(self.next);
        self.next += 1;
        Some(r)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.end - self.next;
        (n, Some(n))
    }
}

impl<'v, M: Mapping, B: Blob> ExactSizeIterator for RecordIter<'v, M, B> {}

impl<'v, M: Mapping, B: Blob> IntoIterator for &'v View<M, B> {
    type Item = RecordRef<'v, M, B>;
    type IntoIter = RecordIter<'v, M, B>;

    fn into_iter(self) -> Self::IntoIter {
        RecordIter::new(self)
    }
}

/// Compile-time-style iteration over the record dimension leaves
/// (paper's `forEachLeaf`): calls `f(leaf index, flat field)`.
pub fn for_each_leaf<M: Mapping>(
    mapping: &M,
    mut f: impl FnMut(usize, &crate::record::FlatField),
) {
    for (i, field) in mapping.info().fields.iter().enumerate() {
        f(i, field);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, SoA};
    use crate::view::view::alloc_view;

    #[test]
    fn iterate_all_records() {
        let mut v = alloc_view(AoS::aligned(&particle_dim(), ArrayDims::from([2, 3])));
        for i in 0..6 {
            v.set::<f64>(i, 4, i as f64);
        }
        // paper listing 7: for (auto p : view) p(Mass{}) = 1.0 — read
        // side here.
        let masses: Vec<f64> = (&v).into_iter().map(|p| p.get_path::<f64>("mass")).collect();
        assert_eq!(masses, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!((&v).into_iter().len(), 6);
    }

    #[test]
    fn transform_between_different_mappings() {
        // paper listing 7: std::transform(view, view2) with different
        // layouts.
        let mut src = alloc_view(AoS::packed(&particle_dim(), ArrayDims::linear(5)));
        let mut dst = alloc_view(SoA::multi_blob(&particle_dim(), ArrayDims::linear(5)));
        for i in 0..5 {
            src.set::<f32>(i, 1, i as f32);
        }
        for p in &src {
            let lin = p.lin();
            let doubled = p.get_path::<f32>("pos.x") * 2.0;
            dst.set::<f32>(lin, 1, doubled);
        }
        for i in 0..5 {
            assert_eq!(dst.get::<f32>(i, 1), i as f32 * 2.0);
        }
    }

    #[test]
    fn reduce_like_accumulation() {
        // paper listing 7: std::reduce(view2.begin(), ..., One<Vec>{}).
        let mut v = alloc_view(SoA::single_blob(&particle_dim(), ArrayDims::linear(4)));
        for i in 0..4 {
            v.set::<f32>(i, 1, i as f32); // pos.x = 0,1,2,3
            v.set::<f32>(i, 2, 1.0); // pos.y = 1
        }
        let mut acc = (0.0f32, 0.0f32);
        for p in &v {
            acc.0 += p.get_path::<f32>("pos.x");
            acc.1 += p.get_path::<f32>("pos.y");
        }
        assert_eq!(acc, (6.0, 4.0));
    }

    #[test]
    fn for_each_leaf_visits_all() {
        let v = alloc_view(AoS::packed(&particle_dim(), ArrayDims::linear(1)));
        let mut paths = Vec::new();
        for_each_leaf(v.mapping(), |_, f| paths.push(f.path.clone()));
        assert_eq!(paths.len(), 8);
        assert_eq!(paths[0], "id");
        assert_eq!(paths[7], "flags.2");
    }
}
