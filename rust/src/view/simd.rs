//! Runtime-dispatched SIMD execution layer — the "Updates on LLAMA"
//! extension (arxiv 2302.08251) over this crate's compiled plans.
//!
//! Three pieces, all usable with or without the `simd` cargo feature:
//!
//! * [`SimdPath`] + [`detect`]: which instruction set the vector
//!   kernels dispatch to on this build/host. Without `--features simd`
//!   (or off x86_64) the answer is always [`SimdPath::Scalar`], and
//!   every `*_simd*` entry point in the crate runs the ordinary scalar
//!   kernels — same results, bit for bit.
//! * [`SimdCursorRead`] / [`SimdCursorWrite`]: lane-batch extensions
//!   of [`CursorRead`] / [`CursorWrite`] that move `W` consecutive
//!   records per call. The default implementation is `W` strided
//!   scalar accesses — exactly the gather/scatter path that feeds
//!   packed-AoS layouts into the vector kernels; dense SoA/AoSoA
//!   cursors compile the same loop down to contiguous loads.
//! * [`strided_run`] / [`strided_run_raw`]: the executor for
//!   [`crate::copy::CopyOp::StridedRun`] — the AoS↔SoA transpose
//!   inner loop — with element-size specializations (4/8-byte moves)
//!   and an AVX2 gather fast path on [`SimdPath::Avx2`].
//!
//! # Dispatch
//!
//! ```text
//!               ┌── feature "simd" off, or non-x86_64 ──► Scalar
//! detect() ─────┤
//!               └── x86_64 + feature "simd"
//!                      ├── LLAMA_SIMD=scalar|sse2|avx2 (if usable)
//!                      ├── is_x86_feature_detected!("avx2") ─► Avx2
//!                      └── otherwise (baseline x86_64)     ─► Sse2
//! ```
//!
//! # Bit identity
//!
//! Vector kernels in this crate batch *across* records (the nbody
//! i-particles, lbm cells along z, copy elements) and keep each
//! record's arithmetic in the exact scalar operation order, using only
//! IEEE-exact per-lane operations (add/sub/mul/div/sqrt, no FMA
//! contraction). Partial tail batches — record counts not divisible by
//! the lane width — run the scalar per-record path. Both together make
//! every path produce bit-identical results, which
//! `tests/prop_simd.rs` pins over the full mapping matrix.

use super::cursor::{CursorRead, CursorWrite};
use super::scalar::ScalarVal;

/// The instruction set a vectorized kernel dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPath {
    /// 256-bit AVX2: 8 × f32 / 4 × f64 lanes, integer gather.
    Avx2,
    /// 128-bit SSE2 (x86_64 baseline): 4 × f32 / 2 × f64 lanes.
    Sse2,
    /// The always-compiled scalar kernels (bit-identical by design).
    Scalar,
}

impl SimdPath {
    /// Short lowercase name, recorded verbatim in bench JSON rows so a
    /// baseline documents which path actually executed.
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::Avx2 => "avx2",
            SimdPath::Sse2 => "sse2",
            SimdPath::Scalar => "scalar",
        }
    }

    /// True when kernels dispatched on this path execute vector
    /// instructions in this build on this host (always false for
    /// [`SimdPath::Scalar`]).
    pub fn is_vector(self) -> bool {
        self != SimdPath::Scalar && available(self)
    }
}

/// True when the crate was built with vector kernels compiled in
/// (`--features simd` on an x86_64 target). When false, [`detect`]
/// returns [`SimdPath::Scalar`] and the `*_simd*` entry points run the
/// scalar kernels.
pub const fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Whether `path` can actually execute on this build + host.
fn available(path: SimdPath) -> bool {
    match path {
        SimdPath::Scalar => true,
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Sse2 => std::arch::is_x86_feature_detected!("sse2"),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => false,
    }
}

/// Every path usable on this build + host, best first. Always ends
/// with [`SimdPath::Scalar`]; property tests iterate this to prove the
/// paths bit-identical wherever they can run.
pub fn available_paths() -> Vec<SimdPath> {
    let mut out = Vec::with_capacity(3);
    if available(SimdPath::Avx2) {
        out.push(SimdPath::Avx2);
    }
    if available(SimdPath::Sse2) {
        out.push(SimdPath::Sse2);
    }
    out.push(SimdPath::Scalar);
    out
}

/// The best usable path for this build + host, cached after the first
/// call. The `LLAMA_SIMD` env knob (`scalar`, `sse2`, `avx2`) forces a
/// *usable* path downward for A/B runs; an unusable or unknown value
/// is ignored.
pub fn detect() -> SimdPath {
    static PATH: std::sync::OnceLock<SimdPath> = std::sync::OnceLock::new();
    *PATH.get_or_init(|| {
        let best = *available_paths().first().expect("never empty");
        match std::env::var("LLAMA_SIMD").ok().as_deref() {
            Some("scalar") => SimdPath::Scalar,
            Some("sse2") if available(SimdPath::Sse2) => SimdPath::Sse2,
            Some("avx2") if available(SimdPath::Avx2) => SimdPath::Avx2,
            _ => best,
        }
    })
}

/// Lane-batch read extension of [`CursorRead`]: one call reads the
/// leaf values of `W` consecutive records. The default body is `W`
/// strided scalar reads — the gather path that lets packed AoS (and
/// any other injective layout) feed the same vector kernels as SoA;
/// for dense cursors the compiler collapses it to contiguous loads.
pub trait SimdCursorRead: CursorRead {
    /// Read records `lin..lin + W` of this leaf.
    ///
    /// # Safety
    /// `lin + W <= self.count()`, `W >= 1`, and `T` must match the
    /// leaf's scalar type (same contract as [`CursorRead::read_at`]).
    #[inline(always)]
    unsafe fn read_batch<T: ScalarVal, const W: usize>(&self, lin: usize) -> [T; W] {
        debug_assert!(W >= 1 && lin + W <= self.count());
        let mut out = [self.read_at::<T>(lin); W];
        for k in 1..W {
            out[k] = self.read_at::<T>(lin + k);
        }
        out
    }
}

impl<C: CursorRead> SimdCursorRead for C {}

/// Lane-batch write extension of [`CursorWrite`]; scatter twin of
/// [`SimdCursorRead::read_batch`].
pub trait SimdCursorWrite: CursorWrite {
    /// Write records `lin..lin + W` of this leaf.
    ///
    /// # Safety
    /// `lin + W <= self.count()` and `T` must match the leaf's scalar
    /// type (same contract as [`CursorWrite::write_at`]).
    #[inline(always)]
    unsafe fn write_batch<T: ScalarVal, const W: usize>(&self, lin: usize, v: [T; W]) {
        debug_assert!(lin + W <= self.count());
        for (k, x) in v.into_iter().enumerate() {
            self.write_at::<T>(lin + k, x);
        }
    }
}

impl<C: CursorWrite> SimdCursorWrite for C {}

/// Execute one [`crate::copy::CopyOp::StridedRun`] over byte slices —
/// the bounds-checked site of [`crate::copy::CopyProgram::execute`].
/// `count` elements of `elem` bytes move from `src_off + i*src_stride`
/// to `dst_off + i*dst_stride`; the result is pure byte movement, so
/// every path is trivially bit-identical.
///
/// # Panics
/// If either strided range is out of bounds for its slice.
#[allow(clippy::too_many_arguments)]
pub fn strided_run(
    path: SimdPath,
    src: &[u8],
    src_off: usize,
    src_stride: usize,
    dst: &mut [u8],
    dst_off: usize,
    dst_stride: usize,
    elem: usize,
    count: usize,
) {
    if count == 0 || elem == 0 {
        return;
    }
    let s_end = src_off + (count - 1) * src_stride + elem;
    let d_end = dst_off + (count - 1) * dst_stride + elem;
    assert!(s_end <= src.len(), "strided src range {s_end} out of bounds {}", src.len());
    assert!(d_end <= dst.len(), "strided dst range {d_end} out of bounds {}", dst.len());
    // SAFETY: both strided ranges verified in bounds just above; the
    // &/&mut borrows guarantee the regions do not overlap.
    unsafe {
        strided_run_raw(
            path,
            src.as_ptr().add(src_off),
            src_stride,
            dst.as_mut_ptr().add(dst_off),
            dst_stride,
            elem,
            count,
        );
    }
}

/// Raw-pointer twin of [`strided_run`] for the sharded copy executor
/// (which writes through a pre-validated raw destination).
///
/// Specializations: 4-byte elements move as `u32` (with an AVX2
/// gather + contiguous store when the destination is dense), 8-byte
/// elements as `u64`; anything else is a byte memcpy per element.
///
/// # Safety
/// `src` must be readable and `dst` writable for
/// `(count - 1) * stride + elem` bytes respectively, and the two
/// regions must not overlap.
pub unsafe fn strided_run_raw(
    path: SimdPath,
    src: *const u8,
    src_stride: usize,
    dst: *mut u8,
    dst_stride: usize,
    elem: usize,
    count: usize,
) {
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    let _ = path;
    match elem {
        4 => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            if path == SimdPath::Avx2 && count >= 8 && gather_offsets_fit(src_stride, count) {
                return x86::strided_run_4_avx2(src, src_stride, dst, dst_stride, count);
            }
            for i in 0..count {
                let v = (src.add(i * src_stride) as *const u32).read_unaligned();
                (dst.add(i * dst_stride) as *mut u32).write_unaligned(v);
            }
        }
        8 => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            if path == SimdPath::Avx2 && count >= 4 && gather_offsets_fit(src_stride, count) {
                return x86::strided_run_8_avx2(src, src_stride, dst, dst_stride, count);
            }
            for i in 0..count {
                let v = (src.add(i * src_stride) as *const u64).read_unaligned();
                (dst.add(i * dst_stride) as *mut u64).write_unaligned(v);
            }
        }
        _ => {
            for i in 0..count {
                std::ptr::copy_nonoverlapping(
                    src.add(i * src_stride),
                    dst.add(i * dst_stride),
                    elem,
                );
            }
        }
    }
}

/// AVX2 gathers index with i32 *byte* offsets (scale 1): the whole
/// source span must fit.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn gather_offsets_fit(src_stride: usize, count: usize) -> bool {
    count.checked_mul(src_stride).is_some_and(|span| span <= i32::MAX as usize)
}

/// The `core::arch` kernels behind [`strided_run_raw`]. Only the
/// *source* side gathers; stores use the vector register only when the
/// destination is dense (`dst_stride == elem`) — AVX2 has no scatter.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use core::arch::x86_64::*;

    /// 8 strided u32 elements per iteration via `vpgatherdd`.
    ///
    /// # Safety
    /// AVX2 available; bounds as in [`super::strided_run_raw`]; all
    /// source byte offsets fit in `i32` (checked by the caller).
    #[target_feature(enable = "avx2")]
    pub unsafe fn strided_run_4_avx2(
        src: *const u8,
        src_stride: usize,
        dst: *mut u8,
        dst_stride: usize,
        count: usize,
    ) {
        let mut i = 0;
        if dst_stride == 4 {
            let s = src_stride as i32;
            let mut off = _mm256_setr_epi32(0, s, 2 * s, 3 * s, 4 * s, 5 * s, 6 * s, 7 * s);
            let step = _mm256_set1_epi32(8 * s);
            while i + 8 <= count {
                let v = _mm256_i32gather_epi32::<1>(src as *const i32, off);
                _mm256_storeu_si256(dst.add(i * 4) as *mut __m256i, v);
                off = _mm256_add_epi32(off, step);
                i += 8;
            }
        }
        while i < count {
            let v = (src.add(i * src_stride) as *const u32).read_unaligned();
            (dst.add(i * dst_stride) as *mut u32).write_unaligned(v);
            i += 1;
        }
    }

    /// 4 strided u64 elements per iteration via `vpgatherdq`.
    ///
    /// # Safety
    /// Same contract as [`strided_run_4_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn strided_run_8_avx2(
        src: *const u8,
        src_stride: usize,
        dst: *mut u8,
        dst_stride: usize,
        count: usize,
    ) {
        let mut i = 0;
        if dst_stride == 8 {
            let s = src_stride as i32;
            let mut off = _mm_setr_epi32(0, s, 2 * s, 3 * s);
            let step = _mm_set1_epi32(4 * s);
            while i + 4 <= count {
                let v = _mm256_i32gather_epi64::<1>(src as *const i64, off);
                _mm256_storeu_si256(dst.add(i * 8) as *mut __m256i, v);
                off = _mm_add_epi32(off, step);
                i += 4;
            }
        }
        while i < count {
            let v = (src.add(i * src_stride) as *const u64).read_unaligned();
            (dst.add(i * dst_stride) as *mut u64).write_unaligned(v);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{AoS, AoSoA};
    use crate::view::alloc_view;
    use crate::view::cursor::{PlanCursors, PlanCursorsMut};
    use crate::workloads::nbody::particle_dim;
    use crate::workloads::rng::SplitMix64;

    #[test]
    fn detection_is_consistent() {
        let paths = available_paths();
        assert_eq!(*paths.last().unwrap(), SimdPath::Scalar);
        assert!(paths.contains(&detect()));
        assert!(!SimdPath::Scalar.is_vector());
        if !simd_compiled() {
            assert_eq!(paths, vec![SimdPath::Scalar]);
            assert_eq!(detect(), SimdPath::Scalar);
        }
        let names: Vec<_> = paths.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), {
            let mut u = names.clone();
            u.dedup();
            u.len()
        });
    }

    #[test]
    fn strided_run_matches_naive_for_every_path_and_shape() {
        let mut rng = SplitMix64::new(42);
        for path in available_paths() {
            for &elem in &[1usize, 3, 4, 8, 12] {
                for &(ss, ds) in &[
                    (elem, elem),
                    (elem + 1, elem),
                    (elem, elem + 5),
                    (3 * elem + 2, 2 * elem + 1),
                ] {
                    for &count in &[0usize, 1, 7, 8, 9, 33, 100] {
                        let span = |stride: usize| 4 + count.saturating_sub(1) * stride + elem;
                        let src: Vec<u8> =
                            (0..span(ss)).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                        let mut got = vec![0u8; span(ds)];
                        let mut want = got.clone();
                        strided_run(path, &src, 2, ss, &mut got, 3, ds, elem, count);
                        for i in 0..count {
                            let so = 2 + i * ss;
                            let doff = 3 + i * ds;
                            want[doff..doff + elem].copy_from_slice(&src[so..so + elem]);
                        }
                        assert_eq!(got, want, "path {path:?} elem {elem} s {ss}/{ds} n {count}");
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn strided_run_rejects_out_of_bounds() {
        let src = vec![0u8; 16];
        let mut dst = vec![0u8; 8];
        strided_run(SimdPath::Scalar, &src, 0, 4, &mut dst, 0, 4, 4, 3);
    }

    #[test]
    fn batch_cursors_roundtrip_on_affine_and_piecewise_plans() {
        let d = particle_dim();
        for count in [16usize, 37] {
            // Packed AoS (affine, strided leaves — the gather path) and
            // AoSoA-4 (piecewise, batches crossing lane blocks).
            {
                let dims = crate::array::ArrayDims::linear(count);
                let mut v = alloc_view(AoS::packed(&d, dims));
                for lin in 0..count {
                    v.set::<f32>(lin, 0, lin as f32 + 0.25);
                }
                let PlanCursorsMut::Affine(cur) = v.plan_cursors_mut() else {
                    panic!("packed AoS is affine")
                };
                // SAFETY: lins below stay within count.
                unsafe {
                    let got: [f32; 4] = cur[0].read_batch(count - 4);
                    for (k, g) in got.iter().enumerate() {
                        assert_eq!(*g, cur[0].as_read().read::<f32>(count - 4 + k));
                    }
                    cur[0].write_batch(1, [9.0f32, 8.0, 7.0, 6.0]);
                }
                assert_eq!(v.get::<f32>(2, 0), 8.0);
            }
            {
                let dims = crate::array::ArrayDims::linear(count);
                let mut v = alloc_view(AoSoA::new(&d, dims, 4));
                for lin in 0..count {
                    v.set::<f32>(lin, 0, 100.0 + lin as f32);
                }
                let PlanCursors::Piecewise(cur) = v.plan_cursors() else {
                    panic!("AoSoA is piecewise")
                };
                // SAFETY: 2 + 4 <= count; the batch spans two lane
                // blocks, exercising the strided default path.
                let got: [f32; 4] = unsafe { cur[0].read_batch(2) };
                assert_eq!(got, [102.0, 103.0, 104.0, 105.0]);
            }
        }
    }
}
