//! [`View`]: mapping + blobs = accessible data space (paper §3.4/3.5).

use crate::blob::{Blob, BlobAllocator, BlobMut, VecAlloc};
use crate::mapping::Mapping;
use crate::view::one_record::OneRecord;
use crate::view::scalar::ScalarVal;
use crate::view::virtual_record::{RecordRef, RecordRefMut};

/// The core data structure of LLAMA: provides access to the data space
/// described by `mapping`, stored in `blobs`.
///
/// Hot-path accessors come in checked (`get`/`set`) and unchecked
/// (`get_unchecked`/`set_unchecked`) flavors; call [`View::validate`]
/// once to justify the unchecked ones in kernels.
#[derive(Debug, Clone)]
pub struct View<M: Mapping, B: Blob = Vec<u8>> {
    mapping: M,
    blobs: Vec<B>,
}

/// Allocate a view with the default `Vec<u8>` blob allocator — the
/// paper's `llama::allocView(mapping)`.
pub fn alloc_view<M: Mapping>(mapping: M) -> View<M, Vec<u8>> {
    alloc_view_with(mapping, VecAlloc)
}

/// Allocate a view with a custom blob allocator — the paper's
/// `llama::allocView(mapping, blobAlloc)`.
pub fn alloc_view_with<M: Mapping, A: BlobAllocator>(mapping: M, alloc: A) -> View<M, A::Blob> {
    let blobs = (0..mapping.blob_count()).map(|b| alloc.allocate(mapping.blob_size(b))).collect();
    View { mapping, blobs }
}

impl<M: Mapping, B: Blob> View<M, B> {
    /// Construct a view over caller-provided blobs (paper §3.8:
    /// "passing an array of blobs directly to a view's constructor").
    /// Panics if the blob count or any blob size does not satisfy the
    /// mapping.
    pub fn from_blobs(mapping: M, blobs: Vec<B>) -> Self {
        assert_eq!(
            blobs.len(),
            mapping.blob_count(),
            "blob count mismatch for {}",
            mapping.mapping_name()
        );
        for (nr, b) in blobs.iter().enumerate() {
            assert!(
                b.as_bytes().len() >= mapping.blob_size(nr),
                "blob {nr} too small: {} < {}",
                b.as_bytes().len(),
                mapping.blob_size(nr)
            );
        }
        View { mapping, blobs }
    }

    /// The mapping this view resolves accesses through.
    pub fn mapping(&self) -> &M {
        &self.mapping
    }

    /// Number of records in the array dimensions.
    #[inline]
    pub fn count(&self) -> usize {
        self.mapping.dims().count()
    }

    /// The backing blobs, indexed by the mapping's blob numbers.
    pub fn blobs(&self) -> &[B] {
        &self.blobs
    }

    /// Take the blobs back out (e.g. to hand memory to another API).
    pub fn into_blobs(self) -> Vec<B> {
        self.blobs
    }

    /// Decompose into mapping and blobs — the inverse of
    /// [`View::from_blobs`]. The adaptive engine uses this to rewrap a
    /// view's storage under an instrumented (or freshly recommended)
    /// mapping without copying a byte.
    pub fn into_parts(self) -> (M, Vec<B>) {
        (self.mapping, self.blobs)
    }

    /// Verify every (leaf, slot) access lands inside its blob; after
    /// this, the `*_unchecked` accessors are sound for in-range indices.
    /// Cost: O(leaves × slots) — call once, outside hot loops.
    pub fn validate(&self) -> crate::error::Result<()> {
        let info = self.mapping.info().clone();
        for lin in 0..self.count() {
            let slot = self.mapping.slot_of_lin(lin);
            for leaf in 0..info.leaf_count() {
                let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
                if nr >= self.blobs.len() {
                    crate::bail!("leaf {leaf} lin {lin}: blob {nr} out of range");
                }
                let need = off + info.fields[leaf].size();
                let have = self.blobs[nr].as_bytes().len();
                crate::ensure!(
                    need <= have,
                    "leaf {leaf} lin {lin}: needs {need} bytes in blob {nr}, has {have}"
                );
            }
        }
        Ok(())
    }

    /// Read terminal field `leaf` at canonical linear index `lin`.
    #[inline]
    pub fn get<T: ScalarVal>(&self, lin: usize, leaf: usize) -> T {
        debug_assert_eq!(T::SCALAR, self.mapping.info().fields[leaf].scalar);
        let slot = self.mapping.slot_of_lin(lin);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        let v = T::read_ne(self.blobs[nr].as_bytes(), off);
        if self.mapping.is_native_representation() {
            v
        } else {
            v.swap_bytes_val()
        }
    }

    /// Read at an N-dimensional index.
    #[inline]
    pub fn get_nd<T: ScalarVal>(&self, idx: &[usize], leaf: usize) -> T {
        debug_assert!(self.mapping.dims().contains(idx));
        let slot = self.mapping.slot_of_nd(idx);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        let v = T::read_ne(self.blobs[nr].as_bytes(), off);
        if self.mapping.is_native_representation() {
            v
        } else {
            v.swap_bytes_val()
        }
    }

    /// Unchecked read; sound after [`View::validate`] for `lin <
    /// count()` and `leaf < leaf_count()`.
    ///
    /// # Safety
    /// The mapping must route (leaf, lin) inside the blobs — guaranteed
    /// by a successful `validate()`.
    #[inline]
    pub unsafe fn get_unchecked<T: ScalarVal>(&self, lin: usize, leaf: usize) -> T {
        let slot = self.mapping.slot_of_lin(lin);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        let v = T::read_ne_unchecked(self.blobs.get_unchecked(nr).as_bytes(), off);
        if self.mapping.is_native_representation() {
            v
        } else {
            v.swap_bytes_val()
        }
    }

    /// Lazy accessor for one record (paper's `VirtualRecord`). The
    /// mapping is *not* invoked here — only on terminal access.
    #[inline]
    pub fn record(&self, lin: usize) -> RecordRef<'_, M, B> {
        RecordRef::new(self, lin)
    }

    /// Copy one record out of the view into a stack value (paper's
    /// `llama::One`).
    pub fn load_one(&self, lin: usize) -> OneRecord {
        let info = self.mapping.info().clone();
        let mut one = OneRecord::new(info.clone());
        for leaf in 0..info.leaf_count() {
            let slot = self.mapping.slot_of_lin(lin);
            let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
            let size = info.fields[leaf].size();
            let src = &self.blobs[nr].as_bytes()[off..off + size];
            one.leaf_bytes_mut(leaf).copy_from_slice(src);
            if !self.mapping.is_native_representation() {
                one.leaf_bytes_mut(leaf).reverse();
            }
        }
        one
    }
}

impl<M: Mapping, B: BlobMut> View<M, B> {
    /// Write terminal field `leaf` at canonical linear index `lin`.
    #[inline]
    pub fn set<T: ScalarVal>(&mut self, lin: usize, leaf: usize, v: T) {
        debug_assert_eq!(T::SCALAR, self.mapping.info().fields[leaf].scalar);
        let v = if self.mapping.is_native_representation() { v } else { v.swap_bytes_val() };
        let slot = self.mapping.slot_of_lin(lin);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        T::write_ne(self.blobs[nr].as_bytes_mut(), off, v);
    }

    /// Write at an N-dimensional index.
    #[inline]
    pub fn set_nd<T: ScalarVal>(&mut self, idx: &[usize], leaf: usize, v: T) {
        debug_assert!(self.mapping.dims().contains(idx));
        let v = if self.mapping.is_native_representation() { v } else { v.swap_bytes_val() };
        let slot = self.mapping.slot_of_nd(idx);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        T::write_ne(self.blobs[nr].as_bytes_mut(), off, v);
    }

    /// Unchecked write; see [`View::get_unchecked`] for the contract.
    ///
    /// # Safety
    /// As for `get_unchecked`.
    #[inline]
    pub unsafe fn set_unchecked<T: ScalarVal>(&mut self, lin: usize, leaf: usize, v: T) {
        let v = if self.mapping.is_native_representation() { v } else { v.swap_bytes_val() };
        let slot = self.mapping.slot_of_lin(lin);
        let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
        T::write_ne_unchecked(self.blobs.get_unchecked_mut(nr).as_bytes_mut(), off, v);
    }

    /// Mutable lazy accessor for one record.
    #[inline]
    pub fn record_mut(&mut self, lin: usize) -> RecordRefMut<'_, M, B> {
        RecordRefMut::new(self, lin)
    }

    /// Store a stack record into the view (deep write-through).
    pub fn store_one(&mut self, lin: usize, one: &OneRecord) {
        let info = self.mapping.info().clone();
        assert_eq!(info.leaf_count(), one.info().leaf_count(), "record dim mismatch");
        for leaf in 0..info.leaf_count() {
            let slot = self.mapping.slot_of_lin(lin);
            let (nr, off) = self.mapping.blob_nr_and_offset(leaf, slot);
            let size = info.fields[leaf].size();
            let dst = &mut self.blobs[nr].as_bytes_mut()[off..off + size];
            dst.copy_from_slice(one.leaf_bytes(leaf));
            if !self.mapping.is_native_representation() {
                dst.reverse();
            }
        }
    }

    /// Borrow the mapping and the blobs mutably at once — used by the
    /// copy engine and by code that fills blob bytes directly (e.g.
    /// handing blobs to an external API and reinterpreting them).
    pub fn mapping_and_blobs_mut(&mut self) -> (&M, &mut [B]) {
        (&self.mapping, &mut self.blobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};

    const POS_X: usize = 1;
    const MASS: usize = 4;
    const FLAG0: usize = 5;

    #[test]
    fn roundtrip_aos() {
        let mut v = alloc_view(AoS::aligned(&particle_dim(), ArrayDims::linear(10)));
        for i in 0..10 {
            v.set::<f32>(i, POS_X, i as f32 * 1.5);
            v.set::<f64>(i, MASS, i as f64 + 0.25);
            v.set::<bool>(i, FLAG0, i % 2 == 0);
        }
        for i in 0..10 {
            assert_eq!(v.get::<f32>(i, POS_X), i as f32 * 1.5);
            assert_eq!(v.get::<f64>(i, MASS), i as f64 + 0.25);
            assert_eq!(v.get::<bool>(i, FLAG0), i % 2 == 0);
        }
        assert!(v.validate().is_ok());
    }

    #[test]
    fn roundtrip_soa_and_aosoa_agree_with_aos() {
        let dims = ArrayDims::from([4, 3]);
        let mut aos = alloc_view(AoS::packed(&particle_dim(), dims.clone()));
        let mut soa = alloc_view(SoA::multi_blob(&particle_dim(), dims.clone()));
        let mut aosoa = alloc_view(AoSoA::new(&particle_dim(), dims.clone(), 4));
        for i in 0..12 {
            for (leaf, val) in [(POS_X, i as f32), (2, -(i as f32))] {
                aos.set::<f32>(i, leaf, val);
                soa.set::<f32>(i, leaf, val);
                aosoa.set::<f32>(i, leaf, val);
            }
        }
        for i in 0..12 {
            let a = aos.get::<f32>(i, POS_X);
            assert_eq!(a, soa.get::<f32>(i, POS_X));
            assert_eq!(a, aosoa.get::<f32>(i, POS_X));
        }
    }

    #[test]
    fn nd_access_matches_linear() {
        let dims = ArrayDims::from([3, 4]);
        let mut v = alloc_view(SoA::single_blob(&particle_dim(), dims.clone()));
        for a in 0..3 {
            for b in 0..4 {
                v.set_nd::<f32>(&[a, b], POS_X, (a * 10 + b) as f32);
            }
        }
        for lin in 0..12 {
            let idx = dims.delinearize_row_major(lin);
            assert_eq!(v.get::<f32>(lin, POS_X), (idx[0] * 10 + idx[1]) as f32);
        }
    }

    #[test]
    fn unchecked_matches_checked() {
        let mut v = alloc_view(AoSoA::new(&particle_dim(), ArrayDims::linear(9), 4));
        v.validate().unwrap();
        for i in 0..9 {
            // SAFETY: validated above, i < count.
            unsafe { v.set_unchecked::<f64>(i, MASS, i as f64 * 2.0) };
        }
        for i in 0..9 {
            // SAFETY: as above.
            let u = unsafe { v.get_unchecked::<f64>(i, MASS) };
            assert_eq!(u, v.get::<f64>(i, MASS));
        }
    }

    #[test]
    fn byteswap_view_roundtrips_and_stores_swapped() {
        let mut v = alloc_view(Byteswap::new(AoS::packed(&particle_dim(), ArrayDims::linear(2))));
        v.set::<f32>(0, POS_X, 1.0f32);
        assert_eq!(v.get::<f32>(0, POS_X), 1.0);
        // Raw bytes must hold the opposite-endian representation.
        let raw = &v.blobs()[0][2..6];
        #[cfg(target_endian = "little")]
        assert_eq!(raw, 1.0f32.to_be_bytes());
        #[cfg(target_endian = "big")]
        assert_eq!(raw, 1.0f32.to_le_bytes());
    }

    #[test]
    fn load_store_one() {
        let mut v = alloc_view(SoA::multi_blob(&particle_dim(), ArrayDims::linear(4)));
        v.set::<f64>(2, MASS, 9.5);
        v.set::<u16>(2, 0, 77);
        let one = v.load_one(2);
        assert_eq!(one.get::<f64>(MASS), 9.5);
        assert_eq!(one.get::<u16>(0), 77);
        let mut v2 = alloc_view(AoS::aligned(&particle_dim(), ArrayDims::linear(4)));
        v2.store_one(1, &one);
        assert_eq!(v2.get::<f64>(1, MASS), 9.5);
        assert_eq!(v2.get::<u16>(1, 0), 77);
    }

    #[test]
    #[should_panic(expected = "blob count mismatch")]
    fn from_blobs_wrong_count_panics() {
        let m = SoA::multi_blob(&particle_dim(), ArrayDims::linear(4));
        let _ = View::from_blobs(m, vec![vec![0u8; 8]]);
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn from_blobs_too_small_panics() {
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let _ = View::from_blobs(m, vec![vec![0u8; 10]]);
    }

    #[test]
    fn from_external_blobs() {
        use crate::blob::ExternalBytesMut;
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(2));
        let mut storage = vec![0u8; 50];
        {
            let mut v = View::from_blobs(
                AoS::packed(&particle_dim(), ArrayDims::linear(2)),
                vec![ExternalBytesMut(&mut storage)],
            );
            v.set::<f32>(1, POS_X, 4.0);
        }
        // The write went through to the external buffer.
        let check = View::from_blobs(m, vec![storage]);
        assert_eq!(check.get::<f32>(1, POS_X), 4.0);
    }
}
