//! [`ScalarVal`]: Rust value types that can live in a LLAMA data space,
//! with (un)checked native-endian codecs used by the view accessors.

use crate::record::Scalar;

/// A Rust scalar that corresponds to a [`Scalar`] elemental type.
///
/// # Safety
/// Implementations must read/write exactly `Self::SCALAR.size()` bytes
/// and `SCALAR` must match the type's actual size.
pub unsafe trait ScalarVal: Copy + PartialEq + std::fmt::Debug + Send + Sync + 'static {
    /// The elemental type this Rust scalar stores as.
    const SCALAR: Scalar;

    /// Checked native-endian read at byte offset `off`.
    fn read_ne(bytes: &[u8], off: usize) -> Self;

    /// Checked native-endian write at byte offset `off`.
    fn write_ne(bytes: &mut [u8], off: usize, v: Self);

    /// Unchecked read: caller guarantees `off + size <= bytes.len()`.
    ///
    /// # Safety
    /// `off + SCALAR.size()` must be within `bytes`.
    unsafe fn read_ne_unchecked(bytes: &[u8], off: usize) -> Self;

    /// Unchecked write.
    ///
    /// # Safety
    /// `off + SCALAR.size()` must be within `bytes`.
    unsafe fn write_ne_unchecked(bytes: &mut [u8], off: usize, v: Self);

    /// Reverse the byte order of the value (identity for 1-byte types).
    /// Used by the [`crate::mapping::Byteswap`] representation.
    fn swap_bytes_val(self) -> Self;
}

macro_rules! impl_scalar_val {
    ($t:ty, $scalar:expr, $swap:expr) => {
        unsafe impl ScalarVal for $t {
            const SCALAR: Scalar = $scalar;

            #[inline(always)]
            fn read_ne(bytes: &[u8], off: usize) -> Self {
                const N: usize = std::mem::size_of::<$t>();
                let arr: [u8; N] = bytes[off..off + N].try_into().unwrap();
                <$t>::from_ne_bytes(arr)
            }

            #[inline(always)]
            fn write_ne(bytes: &mut [u8], off: usize, v: Self) {
                const N: usize = std::mem::size_of::<$t>();
                bytes[off..off + N].copy_from_slice(&v.to_ne_bytes());
            }

            #[inline(always)]
            unsafe fn read_ne_unchecked(bytes: &[u8], off: usize) -> Self {
                debug_assert!(off + std::mem::size_of::<$t>() <= bytes.len());
                (bytes.as_ptr().add(off) as *const $t).read_unaligned()
            }

            #[inline(always)]
            unsafe fn write_ne_unchecked(bytes: &mut [u8], off: usize, v: Self) {
                debug_assert!(off + std::mem::size_of::<$t>() <= bytes.len());
                (bytes.as_mut_ptr().add(off) as *mut $t).write_unaligned(v)
            }

            #[inline(always)]
            fn swap_bytes_val(self) -> Self {
                $swap(self)
            }
        }
    };
}

impl_scalar_val!(f32, Scalar::F32, |v: f32| f32::from_bits(v.to_bits().swap_bytes()));
impl_scalar_val!(f64, Scalar::F64, |v: f64| f64::from_bits(v.to_bits().swap_bytes()));
impl_scalar_val!(i8, Scalar::I8, |v: i8| v);
impl_scalar_val!(i16, Scalar::I16, i16::swap_bytes);
impl_scalar_val!(i32, Scalar::I32, i32::swap_bytes);
impl_scalar_val!(i64, Scalar::I64, i64::swap_bytes);
impl_scalar_val!(u8, Scalar::U8, |v: u8| v);
impl_scalar_val!(u16, Scalar::U16, u16::swap_bytes);
impl_scalar_val!(u32, Scalar::U32, u32::swap_bytes);
impl_scalar_val!(u64, Scalar::U64, u64::swap_bytes);

// bool is stored as one byte, 0 or 1.
unsafe impl ScalarVal for bool {
    const SCALAR: Scalar = Scalar::Bool;

    #[inline(always)]
    fn read_ne(bytes: &[u8], off: usize) -> Self {
        bytes[off] != 0
    }

    #[inline(always)]
    fn write_ne(bytes: &mut [u8], off: usize, v: Self) {
        bytes[off] = v as u8;
    }

    #[inline(always)]
    unsafe fn read_ne_unchecked(bytes: &[u8], off: usize) -> Self {
        debug_assert!(off < bytes.len());
        *bytes.get_unchecked(off) != 0
    }

    #[inline(always)]
    unsafe fn write_ne_unchecked(bytes: &mut [u8], off: usize, v: Self) {
        debug_assert!(off < bytes.len());
        *bytes.get_unchecked_mut(off) = v as u8;
    }

    #[inline(always)]
    fn swap_bytes_val(self) -> Self {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut buf = vec![0u8; 16];
        f32::write_ne(&mut buf, 1, 3.5);
        assert_eq!(f32::read_ne(&buf, 1), 3.5);
        f64::write_ne(&mut buf, 8, -1.25);
        assert_eq!(f64::read_ne(&buf, 8), -1.25);
        u16::write_ne(&mut buf, 0, 0xBEEF);
        assert_eq!(u16::read_ne(&buf, 0), 0xBEEF);
        bool::write_ne(&mut buf, 5, true);
        assert!(bool::read_ne(&buf, 5));
    }

    #[test]
    fn unchecked_matches_checked() {
        let mut buf = vec![0u8; 16];
        i64::write_ne(&mut buf, 3, -987654321);
        // SAFETY: 3 + 8 <= 16.
        let v = unsafe { i64::read_ne_unchecked(&buf, 3) };
        assert_eq!(v, i64::read_ne(&buf, 3));
        // SAFETY: in range.
        unsafe { u32::write_ne_unchecked(&mut buf, 12, 0xCAFEBABE) };
        assert_eq!(u32::read_ne(&buf, 12), 0xCAFEBABE);
    }

    #[test]
    fn swap_bytes_values() {
        assert_eq!(0x1234u16.swap_bytes_val(), 0x3412);
        assert_eq!(1.0f32.swap_bytes_val().swap_bytes_val(), 1.0);
        assert_eq!(true.swap_bytes_val(), true);
        assert_eq!((-5i8).swap_bytes_val(), -5);
    }

    #[test]
    #[should_panic]
    fn checked_read_out_of_range_panics() {
        let buf = vec![0u8; 4];
        let _ = f64::read_ne(&buf, 0);
    }

    #[test]
    fn scalar_consts_match_sizes() {
        assert_eq!(<f32 as ScalarVal>::SCALAR.size(), 4);
        assert_eq!(<bool as ScalarVal>::SCALAR.size(), 1);
        assert_eq!(<u64 as ScalarVal>::SCALAR.size(), 8);
    }
}
