//! [`OneRecord`]: a view-independent record value with deep-copy
//! semantics — the paper's `llama::One<RecordDim>` (§3.5, listing 5).
//!
//! Stores one packed record on the heap (stack in C++; the distinction
//! does not affect semantics) and supports the paper's field-wise
//! arithmetic: operators apply "on hierarchically matching tags", and
//! scalar operands broadcast to all fields.

use std::sync::Arc;

use crate::record::{RecordInfo, Scalar};
use crate::view::scalar::ScalarVal;

/// A value-semantic single record.
#[derive(Debug, Clone)]
pub struct OneRecord {
    info: Arc<RecordInfo>,
    /// Packed-layout bytes, `info.packed_size` long.
    bytes: Vec<u8>,
}

impl OneRecord {
    /// A zero-initialized record of shape `info`.
    pub fn new(info: Arc<RecordInfo>) -> Self {
        let bytes = vec![0u8; info.packed_size];
        OneRecord { info, bytes }
    }

    /// Flattened record-dimension info of this record.
    pub fn info(&self) -> &Arc<RecordInfo> {
        &self.info
    }

    /// Raw bytes of leaf `leaf` (packed layout).
    pub fn leaf_bytes(&self, leaf: usize) -> &[u8] {
        let f = &self.info.fields[leaf];
        &self.bytes[f.offset_packed..f.offset_packed + f.size()]
    }

    /// Mutable raw bytes of leaf `leaf` (packed layout).
    pub fn leaf_bytes_mut(&mut self, leaf: usize) -> &mut [u8] {
        let f = &self.info.fields[leaf];
        &mut self.bytes[f.offset_packed..f.offset_packed + f.size()]
    }

    /// Read terminal field `leaf`.
    #[inline]
    pub fn get<T: ScalarVal>(&self, leaf: usize) -> T {
        debug_assert_eq!(T::SCALAR, self.info.fields[leaf].scalar);
        T::read_ne(&self.bytes, self.info.fields[leaf].offset_packed)
    }

    /// Write terminal field `leaf`.
    #[inline]
    pub fn set<T: ScalarVal>(&mut self, leaf: usize, v: T) {
        debug_assert_eq!(T::SCALAR, self.info.fields[leaf].scalar);
        T::write_ne(&mut self.bytes, self.info.fields[leaf].offset_packed, v);
    }

    /// Read any leaf lifted to f64 (for generic field-wise arithmetic).
    pub fn get_lifted(&self, leaf: usize) -> f64 {
        let f = &self.info.fields[leaf];
        let off = f.offset_packed;
        match f.scalar {
            Scalar::F32 => f32::read_ne(&self.bytes, off) as f64,
            Scalar::F64 => f64::read_ne(&self.bytes, off),
            Scalar::I8 => i8::read_ne(&self.bytes, off) as f64,
            Scalar::I16 => i16::read_ne(&self.bytes, off) as f64,
            Scalar::I32 => i32::read_ne(&self.bytes, off) as f64,
            Scalar::I64 => i64::read_ne(&self.bytes, off) as f64,
            Scalar::U8 => u8::read_ne(&self.bytes, off) as f64,
            Scalar::U16 => u16::read_ne(&self.bytes, off) as f64,
            Scalar::U32 => u32::read_ne(&self.bytes, off) as f64,
            Scalar::U64 => u64::read_ne(&self.bytes, off) as f64,
            Scalar::Bool => bool::read_ne(&self.bytes, off) as u8 as f64,
        }
    }

    /// Write a f64 down-cast to the leaf's scalar type.
    pub fn set_lifted(&mut self, leaf: usize, v: f64) {
        let f = &self.info.fields[leaf];
        let off = f.offset_packed;
        match f.scalar {
            Scalar::F32 => f32::write_ne(&mut self.bytes, off, v as f32),
            Scalar::F64 => f64::write_ne(&mut self.bytes, off, v),
            Scalar::I8 => i8::write_ne(&mut self.bytes, off, v as i8),
            Scalar::I16 => i16::write_ne(&mut self.bytes, off, v as i16),
            Scalar::I32 => i32::write_ne(&mut self.bytes, off, v as i32),
            Scalar::I64 => i64::write_ne(&mut self.bytes, off, v as i64),
            Scalar::U8 => u8::write_ne(&mut self.bytes, off, v as u8),
            Scalar::U16 => u16::write_ne(&mut self.bytes, off, v as u16),
            Scalar::U32 => u32::write_ne(&mut self.bytes, off, v as u32),
            Scalar::U64 => u64::write_ne(&mut self.bytes, off, v as u64),
            Scalar::Bool => bool::write_ne(&mut self.bytes, off, v != 0.0),
        }
    }

    /// Apply `op` field-wise with another record, matching leaves *by
    /// path suffix* like the paper's tag-hierarchy matching: a leaf of
    /// `self` pairs with the first leaf of `other` whose dotted path has
    /// the same last component and, if present, matching parents.
    /// Records with identical record dims match leaf-for-leaf.
    pub fn zip_apply(&mut self, other: &OneRecord, op: impl Fn(f64, f64) -> f64) {
        if Arc::ptr_eq(&self.info, &other.info) || self.info.dim == other.info.dim {
            for leaf in 0..self.info.leaf_count() {
                let v = op(self.get_lifted(leaf), other.get_lifted(leaf));
                self.set_lifted(leaf, v);
            }
            return;
        }
        for leaf in 0..self.info.leaf_count() {
            let my_path = &self.info.fields[leaf].path;
            if let Some(their) = best_match(my_path, &other.info) {
                let v = op(self.get_lifted(leaf), other.get_lifted(their));
                self.set_lifted(leaf, v);
            }
        }
    }

    /// Apply `op` with a broadcast scalar on every leaf (paper §3.5:
    /// "Scalar operands are also supported").
    pub fn scalar_apply(&mut self, rhs: f64, op: impl Fn(f64, f64) -> f64) {
        for leaf in 0..self.info.leaf_count() {
            let v = op(self.get_lifted(leaf), rhs);
            self.set_lifted(leaf, v);
        }
    }

    /// Field-wise equality (paper: virtual records interact based on
    /// record-dimension tags).
    pub fn fields_eq(&self, other: &OneRecord) -> bool {
        self.info.dim == other.info.dim
            && (0..self.info.leaf_count()).all(|l| self.leaf_bytes(l) == other.leaf_bytes(l))
    }
}

/// Find the leaf of `info` whose path best matches `path`: exact match
/// first, then longest common dotted suffix.
fn best_match(path: &str, info: &RecordInfo) -> Option<usize> {
    if let Some(i) = info.leaf_by_path(path) {
        return Some(i);
    }
    let mut best: Option<(usize, usize)> = None; // (suffix segments, leaf)
    let segs: Vec<&str> = path.split('.').collect();
    for (i, f) in info.fields.iter().enumerate() {
        let fsegs: Vec<&str> = f.path.split('.').collect();
        let common = segs
            .iter()
            .rev()
            .zip(fsegs.iter().rev())
            .take_while(|(a, b)| a == b)
            .count();
        if common > 0 && best.map_or(true, |(c, _)| common > c) {
            best = Some((common, i));
        }
    }
    best.map(|(_, i)| i)
}

impl std::ops::AddAssign<&OneRecord> for OneRecord {
    fn add_assign(&mut self, rhs: &OneRecord) {
        self.zip_apply(rhs, |a, b| a + b);
    }
}

impl std::ops::SubAssign<&OneRecord> for OneRecord {
    fn sub_assign(&mut self, rhs: &OneRecord) {
        self.zip_apply(rhs, |a, b| a - b);
    }
}

impl std::ops::MulAssign<&OneRecord> for OneRecord {
    fn mul_assign(&mut self, rhs: &OneRecord) {
        self.zip_apply(rhs, |a, b| a * b);
    }
}

impl std::ops::MulAssign<f64> for OneRecord {
    fn mul_assign(&mut self, rhs: f64) {
        self.scalar_apply(rhs, |a, b| a * b);
    }
}

impl std::ops::AddAssign<f64> for OneRecord {
    fn add_assign(&mut self, rhs: f64) {
        self.scalar_apply(rhs, |a, b| a + b);
    }
}

impl PartialEq for OneRecord {
    fn eq(&self, other: &Self) -> bool {
        self.fields_eq(other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::{RecordDim, RecordInfo, Scalar};

    fn vec2_info() -> Arc<RecordInfo> {
        Arc::new(RecordInfo::new(
            &RecordDim::new().scalar("x", Scalar::F32).scalar("y", Scalar::F32),
        ))
    }

    fn particle_info() -> Arc<RecordInfo> {
        let vec2 = RecordDim::new().scalar("x", Scalar::F32).scalar("y", Scalar::F32);
        Arc::new(RecordInfo::new(
            &RecordDim::new()
                .record("pos", vec2.clone())
                .record("vel", vec2)
                .scalar("mass", Scalar::F64),
        ))
    }

    #[test]
    fn get_set_roundtrip() {
        let mut one = OneRecord::new(vec2_info());
        one.set::<f32>(0, 1.5);
        one.set::<f32>(1, -2.0);
        assert_eq!(one.get::<f32>(0), 1.5);
        assert_eq!(one.get::<f32>(1), -2.0);
    }

    #[test]
    fn same_dim_arithmetic() {
        let mut a = OneRecord::new(vec2_info());
        let mut b = OneRecord::new(vec2_info());
        a.set::<f32>(0, 1.0);
        a.set::<f32>(1, 2.0);
        b.set::<f32>(0, 10.0);
        b.set::<f32>(1, 20.0);
        a += &b;
        assert_eq!(a.get::<f32>(0), 11.0);
        assert_eq!(a.get::<f32>(1), 22.0);
        a *= 2.0;
        assert_eq!(a.get::<f32>(0), 22.0);
    }

    #[test]
    fn cross_dim_matching_by_tags() {
        // paper listing 5: p(Pos{}) += velocity — Vec2 matches pos.{x,y}
        // of the particle via tag suffixes.
        let mut particle = OneRecord::new(particle_info());
        particle.set::<f32>(0, 1.0); // pos.x
        particle.set::<f32>(1, 1.0); // pos.y
        let mut vel = OneRecord::new(vec2_info());
        vel.set::<f32>(0, 0.5);
        vel.set::<f32>(1, -0.5);
        // Add velocity into the particle: pos.x+=x, pos.y+=y (vel.x/y
        // also match the suffix; pairing picks per-leaf best match).
        let mut pos_only = OneRecord::new(vec2_info());
        pos_only.set::<f32>(0, particle.get::<f32>(0));
        pos_only.set::<f32>(1, particle.get::<f32>(1));
        pos_only += &vel;
        assert_eq!(pos_only.get::<f32>(0), 1.5);
        assert_eq!(pos_only.get::<f32>(1), 0.5);
    }

    #[test]
    fn equality_is_field_wise() {
        let mut a = OneRecord::new(vec2_info());
        let mut b = OneRecord::new(vec2_info());
        assert_eq!(a, b);
        a.set::<f32>(0, 1.0);
        assert_ne!(a, b);
        b.set::<f32>(0, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn lifted_roundtrip_all_scalars() {
        let dim = RecordDim::new()
            .scalar("a", Scalar::U8)
            .scalar("b", Scalar::I64)
            .scalar("c", Scalar::Bool)
            .scalar("d", Scalar::F32);
        let mut one = OneRecord::new(Arc::new(RecordInfo::new(&dim)));
        one.set_lifted(0, 200.0);
        one.set_lifted(1, -5.0);
        one.set_lifted(2, 1.0);
        one.set_lifted(3, 2.5);
        assert_eq!(one.get_lifted(0), 200.0);
        assert_eq!(one.get_lifted(1), -5.0);
        assert_eq!(one.get_lifted(2), 1.0);
        assert_eq!(one.get_lifted(3), 2.5);
    }
}
