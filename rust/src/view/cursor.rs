//! Affine leaf cursors: the zero-overhead kernel fast path
//! (EXPERIMENTS.md §Perf).
//!
//! `View::get/set` route every access through the mapping object, which
//! lives behind the same reference as the blobs — so LLVM must assume
//! stores to blob bytes can alias the mapping's offset tables, blocking
//! hoisting and vectorization (measured 1.8–4.8× vs the hand-written
//! twins on the fig 5 `move` kernel). A [`LeafCursor`] extracts one
//! leaf's `(pointer, stride)` pair *once*; kernels then address memory
//! with loop-invariant bases, and dense (stride == element size) leaves
//! expose real slices so the autovectorizer sees the same code as the
//! manual SoA implementation.

use std::marker::PhantomData;

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// Read-only affine cursor for one leaf.
#[derive(Debug, Clone, Copy)]
pub struct LeafCursor<'v> {
    ptr: *const u8,
    stride: usize,
    count: usize,
    _view: PhantomData<&'v [u8]>,
}

// SAFETY: read-only pointer into blob bytes borrowed for 'v.
unsafe impl Send for LeafCursor<'_> {}
unsafe impl Sync for LeafCursor<'_> {}

impl<'v> LeafCursor<'v> {
    /// Read the leaf at canonical index `lin`.
    ///
    /// # Safety
    /// `lin < self.count()` (bounds were validated at construction).
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *const T).read_unaligned()
    }

    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Dense view of the leaf as a typed slice (stride == size and
    /// aligned), e.g. an SoA subarray. None for strided layouts.
    pub fn as_slice<T: ScalarVal>(&self) -> Option<&'v [T]> {
        if self.stride == std::mem::size_of::<T>()
            && (self.ptr as usize) % std::mem::align_of::<T>() == 0
        {
            // SAFETY: construction validated [ptr, ptr + count*stride);
            // alignment checked; lifetime tied to the view borrow.
            Some(unsafe { std::slice::from_raw_parts(self.ptr as *const T, self.count) })
        } else {
            None
        }
    }
}

/// Mutable affine cursor for one leaf.
#[derive(Debug, Clone, Copy)]
pub struct LeafCursorMut<'v> {
    ptr: *mut u8,
    stride: usize,
    count: usize,
    _view: PhantomData<&'v mut [u8]>,
}

// SAFETY: points into blob bytes exclusively borrowed for 'v; distinct
// leaves never overlap (mapping invariant), and parallel users split by
// disjoint lin ranges.
unsafe impl Send for LeafCursorMut<'_> {}
unsafe impl Sync for LeafCursorMut<'_> {}

impl<'v> LeafCursorMut<'v> {
    /// # Safety
    /// `lin < self.count()`.
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *const T).read_unaligned()
    }

    /// # Safety
    /// `lin < self.count()`; callers must not write the same (leaf,
    /// lin) concurrently from two threads.
    #[inline(always)]
    pub unsafe fn write<T: ScalarVal>(&self, lin: usize, v: T) {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *mut T).write_unaligned(v)
    }

    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Dense mutable slice (stride == size and aligned).
    ///
    /// # Safety
    /// At most one live slice per leaf; leaves of a valid mapping never
    /// overlap, so slices of *different* leaves may coexist.
    pub unsafe fn as_mut_slice<T: ScalarVal>(&self) -> Option<&'v mut [T]> {
        if self.stride == std::mem::size_of::<T>()
            && (self.ptr as usize) % std::mem::align_of::<T>() == 0
        {
            Some(std::slice::from_raw_parts_mut(self.ptr as *mut T, self.count))
        } else {
            None
        }
    }

    /// Downgrade to a read-only cursor.
    pub fn as_read(&self) -> LeafCursor<'v> {
        LeafCursor { ptr: self.ptr, stride: self.stride, count: self.count, _view: PhantomData }
    }
}

fn affine_ok<M: Mapping>(mapping: &M, leaf_sizes: &[usize]) -> Option<Vec<(usize, usize, usize)>> {
    let leaves = mapping.affine_leaves()?;
    if !mapping.is_native_representation() {
        return None;
    }
    let n = mapping.dims().count();
    let mut out = Vec::with_capacity(leaves.len());
    for (leaf, a) in leaves.iter().enumerate() {
        // Validate the whole range once so cursor reads can be
        // unchecked: base + (n-1)*stride + size <= blob size.
        let need = if n == 0 { 0 } else { a.base + (n - 1) * a.stride + leaf_sizes[leaf] };
        if need > mapping.blob_size(a.blob) {
            return None;
        }
        out.push((a.blob, a.base, a.stride));
    }
    Some(out)
}

impl<M: Mapping, B: Blob> View<M, B> {
    /// Read-only affine cursors, one per leaf, if the mapping is affine
    /// (see [`Mapping::affine_leaves`]).
    pub fn leaf_cursors(&self) -> Option<Vec<LeafCursor<'_>>> {
        let sizes: Vec<usize> = self.mapping().info().fields.iter().map(|f| f.size()).collect();
        let rules = affine_ok(self.mapping(), &sizes)?;
        let n = self.mapping().dims().count();
        Some(
            rules
                .into_iter()
                .map(|(blob, base, stride)| LeafCursor {
                    // SAFETY: range validated in affine_ok.
                    ptr: unsafe { self.blobs()[blob].as_bytes().as_ptr().add(base) },
                    stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }
}

impl<M: Mapping, B: BlobMut> View<M, B> {
    /// Mutable affine cursors, one per leaf.
    pub fn leaf_cursors_mut(&mut self) -> Option<Vec<LeafCursorMut<'_>>> {
        let sizes: Vec<usize> = self.mapping().info().fields.iter().map(|f| f.size()).collect();
        let rules = affine_ok(self.mapping(), &sizes)?;
        let n = self.mapping().dims().count();
        let (_, blobs) = self.mapping_and_blobs_mut();
        // Collect raw base pointers first (one &mut traversal).
        let bases: Vec<*mut u8> = blobs.iter_mut().map(|b| b.as_bytes_mut().as_mut_ptr()).collect();
        Some(
            rules
                .into_iter()
                .map(|(blob, base, stride)| LeafCursorMut {
                    // SAFETY: range validated in affine_ok.
                    ptr: unsafe { bases[blob].add(base) },
                    stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};
    use crate::view::alloc_view;

    #[test]
    fn cursors_agree_with_accessors() {
        let d = particle_dim();
        for_view(alloc_view(AoS::aligned(&d, ArrayDims::linear(9))));
        for_view(alloc_view(AoS::packed(&d, ArrayDims::linear(9))));
        for_view(alloc_view(SoA::multi_blob(&d, ArrayDims::linear(9))));
        for_view(alloc_view(SoA::single_blob(&d, ArrayDims::linear(9))));

        fn for_view<M: crate::mapping::Mapping>(mut v: crate::view::View<M, Vec<u8>>) {
            for i in 0..9 {
                v.set::<f32>(i, 1, i as f32 * 1.5); // pos.x
                v.set::<f64>(i, 4, -(i as f64)); // mass
            }
            let cur = v.leaf_cursors().expect("affine");
            for i in 0..9 {
                // SAFETY: i < count.
                unsafe {
                    assert_eq!(cur[1].read::<f32>(i), i as f32 * 1.5);
                    assert_eq!(cur[4].read::<f64>(i), -(i as f64));
                }
            }
        }
    }

    #[test]
    fn mutable_cursor_write_through() {
        let d = particle_dim();
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(5)));
        {
            let cur = v.leaf_cursors_mut().unwrap();
            for i in 0..5 {
                // SAFETY: i < count.
                unsafe { cur[1].write::<f32>(i, 7.0 + i as f32) };
            }
        }
        for i in 0..5 {
            assert_eq!(v.get::<f32>(i, 1), 7.0 + i as f32);
        }
    }

    #[test]
    fn dense_leaves_expose_slices() {
        let d = particle_dim();
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(8)));
        for i in 0..8 {
            v.set::<f32>(i, 1, i as f32);
        }
        let cur = v.leaf_cursors().unwrap();
        let xs: &[f32] = cur[1].as_slice().expect("SoA leaf is dense");
        assert_eq!(xs, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // AoS leaves are strided: no slice.
        let aos = alloc_view(AoS::packed(&d, ArrayDims::linear(8)));
        let cur = aos.leaf_cursors().unwrap();
        assert!(cur[1].as_slice::<f32>().is_none());
    }

    #[test]
    fn non_affine_views_return_none() {
        let d = particle_dim();
        let v = alloc_view(AoSoA::new(&d, ArrayDims::linear(8), 4));
        assert!(v.leaf_cursors().is_none());
        let v = alloc_view(Byteswap::new(AoS::packed(&d, ArrayDims::linear(8))));
        assert!(v.leaf_cursors().is_none());
    }
}
