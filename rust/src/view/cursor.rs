//! Plan-driven leaf cursors: the zero-overhead kernel fast path
//! (EXPERIMENTS.md §Perf).
//!
//! `View::get/set` route every access through the mapping object, which
//! lives behind the same reference as the blobs — so LLVM must assume
//! stores to blob bytes can alias the mapping's offset tables, blocking
//! hoisting and vectorization (measured 1.8–4.8× vs the hand-written
//! twins on the fig 5 `move` kernel). Cursors extract one leaf's
//! address rule *once* from the mapping's compiled
//! [`LayoutPlan`](crate::mapping::LayoutPlan); kernels then address
//! memory with loop-invariant bases:
//!
//! * [`LeafCursor`] — affine rule `base + lin * stride`; dense leaves
//!   (stride == element size) expose real slices, so the autovectorizer
//!   sees the same code as a manual SoA implementation.
//! * [`PiecewiseCursor`] — lane-block rule for the AoSoA family; full
//!   blocks expose dense length-`L` slices, so a lane-blocked kernel
//!   sees the same inner loop as a manual AoSoA implementation.
//!
//! [`View::plan_cursors`]/[`View::plan_cursors_mut`] compile the
//! mapping once and return the matching cursor set; the [`CursorRead`]/
//! [`CursorWrite`] traits let one generic kernel body serve both shapes
//! (monomorphized — no dynamic dispatch on the hot path).

use std::marker::PhantomData;

use crate::blob::{Blob, BlobMut};
use crate::mapping::plan::{AddrPlan, PiecewiseLeaf};
use crate::mapping::{AffineLeaf, LayoutPlan, Mapping};
use crate::view::scalar::ScalarVal;
use crate::view::view::View;

/// Uniform read access over affine and piecewise cursors.
pub trait CursorRead: Copy + Send + Sync {
    /// Number of records the cursor covers.
    fn count(&self) -> usize;

    /// Read the leaf value at canonical index `lin`.
    ///
    /// # Safety
    /// `lin < self.count()` (ranges were validated at construction).
    unsafe fn read_at<T: ScalarVal>(&self, lin: usize) -> T;
}

/// Uniform write access over affine and piecewise cursors.
pub trait CursorWrite: CursorRead {
    /// Write the leaf value at canonical index `lin`.
    ///
    /// # Safety
    /// `lin < self.count()`; callers must not write the same (leaf,
    /// lin) concurrently from two threads.
    unsafe fn write_at<T: ScalarVal>(&self, lin: usize, v: T);
}

// ---------------------------------------------------------------------
// Affine cursors
// ---------------------------------------------------------------------

/// Read-only affine cursor for one leaf.
#[derive(Debug, Clone, Copy)]
pub struct LeafCursor<'v> {
    ptr: *const u8,
    stride: usize,
    count: usize,
    _view: PhantomData<&'v [u8]>,
}

// SAFETY: read-only pointer into blob bytes borrowed for 'v.
unsafe impl Send for LeafCursor<'_> {}
unsafe impl Sync for LeafCursor<'_> {}

impl<'v> LeafCursor<'v> {
    /// Build one read cursor per leaf from an affine plan over raw blob
    /// `(pointer, length)` pairs, validating every leaf's full access
    /// range once so reads can be unchecked. `None` if the plan is not
    /// affine or a range escapes its blob.
    ///
    /// # Safety
    /// Each pointer must be valid for reads of its stated length for
    /// the lifetime `'v`.
    pub unsafe fn from_plan(
        plan: &LayoutPlan,
        leaf_sizes: &[usize],
        blobs: &[(*const u8, usize)],
    ) -> Option<Vec<LeafCursor<'v>>> {
        let AddrPlan::Affine(leaves) = plan.addr() else {
            return None;
        };
        let n = plan.count();
        validate_affine(leaves, leaf_sizes, n, blobs.iter().map(|&(_, len)| len))?;
        // wrapping_add: for n == 0 the validation is vacuous and `base`
        // may exceed the (empty) allocation — the pointer is then never
        // dereferenced, but plain `add` would already be UB to form.
        Some(
            leaves
                .iter()
                .map(|a| LeafCursor {
                    ptr: blobs[a.blob].0.wrapping_add(a.base),
                    stride: a.stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }

    /// Read the leaf at canonical index `lin`.
    ///
    /// # Safety
    /// `lin < self.count()` (bounds were validated at construction).
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *const T).read_unaligned()
    }

    /// Number of records the cursor covers.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Byte distance between consecutive records' values.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Dense view of the leaf as a typed slice (stride == size and
    /// aligned), e.g. an SoA subarray. None for strided layouts.
    pub fn as_slice<T: ScalarVal>(&self) -> Option<&'v [T]> {
        self.as_slice_range(0, self.count)
    }

    /// Dense subslice covering `start..end` — the shard-local window
    /// used by parallel kernels. None for strided layouts or an
    /// out-of-range window (a safe fn must not mint an out-of-bounds
    /// slice in release builds).
    pub fn as_slice_range<T: ScalarVal>(&self, start: usize, end: usize) -> Option<&'v [T]> {
        debug_assert!(start <= end && end <= self.count);
        if start <= end
            && end <= self.count
            && self.stride == std::mem::size_of::<T>()
            && (self.ptr as usize) % std::mem::align_of::<T>() == 0
        {
            // SAFETY: construction validated [ptr, ptr + count*stride);
            // alignment of ptr + start*size follows from the base;
            // lifetime tied to the view borrow.
            Some(unsafe {
                std::slice::from_raw_parts(
                    self.ptr.add(start * self.stride) as *const T,
                    end - start,
                )
            })
        } else {
            None
        }
    }
}

impl CursorRead for LeafCursor<'_> {
    #[inline]
    fn count(&self) -> usize {
        self.count
    }

    #[inline(always)]
    unsafe fn read_at<T: ScalarVal>(&self, lin: usize) -> T {
        self.read(lin)
    }
}

/// Mutable affine cursor for one leaf.
#[derive(Debug, Clone, Copy)]
pub struct LeafCursorMut<'v> {
    ptr: *mut u8,
    stride: usize,
    count: usize,
    _view: PhantomData<&'v mut [u8]>,
}

// SAFETY: points into blob bytes exclusively borrowed for 'v; distinct
// leaves never overlap (mapping invariant), and parallel users split by
// disjoint lin ranges.
unsafe impl Send for LeafCursorMut<'_> {}
unsafe impl Sync for LeafCursorMut<'_> {}

impl<'v> LeafCursorMut<'v> {
    /// Mutable counterpart of [`LeafCursor::from_plan`].
    ///
    /// # Safety
    /// Each pointer must be valid for reads and writes of its stated
    /// length for `'v`, with no other aliases during `'v`.
    pub unsafe fn from_plan(
        plan: &LayoutPlan,
        leaf_sizes: &[usize],
        blobs: &[(*mut u8, usize)],
    ) -> Option<Vec<LeafCursorMut<'v>>> {
        let AddrPlan::Affine(leaves) = plan.addr() else {
            return None;
        };
        let n = plan.count();
        validate_affine(leaves, leaf_sizes, n, blobs.iter().map(|&(_, len)| len))?;
        Some(
            leaves
                .iter()
                .map(|a| LeafCursorMut {
                    ptr: blobs[a.blob].0.wrapping_add(a.base),
                    stride: a.stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }

    /// # Safety
    /// `lin < self.count()`.
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *const T).read_unaligned()
    }

    /// # Safety
    /// `lin < self.count()`; callers must not write the same (leaf,
    /// lin) concurrently from two threads.
    #[inline(always)]
    pub unsafe fn write<T: ScalarVal>(&self, lin: usize, v: T) {
        debug_assert!(lin < self.count);
        (self.ptr.add(lin * self.stride) as *mut T).write_unaligned(v)
    }

    /// Number of records the cursor covers.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Byte distance between consecutive records' values.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Dense mutable slice (stride == size and aligned).
    ///
    /// # Safety
    /// At most one live slice per leaf; leaves of a valid mapping never
    /// overlap, so slices of *different* leaves may coexist.
    pub unsafe fn as_mut_slice<T: ScalarVal>(&self) -> Option<&'v mut [T]> {
        self.as_mut_slice_range(0, self.count)
    }

    /// Dense mutable subslice covering `start..end` — the shard-local
    /// window used by parallel kernels: disjoint ranges yield disjoint
    /// slices, so concurrent shards may each hold their own.
    ///
    /// # Safety
    /// `start <= end <= self.count()`; live slices of the same leaf
    /// must cover disjoint ranges (leaves of a valid mapping never
    /// overlap, so slices of different leaves always may coexist).
    pub unsafe fn as_mut_slice_range<T: ScalarVal>(
        &self,
        start: usize,
        end: usize,
    ) -> Option<&'v mut [T]> {
        debug_assert!(start <= end && end <= self.count);
        if start <= end
            && end <= self.count
            && self.stride == std::mem::size_of::<T>()
            && (self.ptr as usize) % std::mem::align_of::<T>() == 0
        {
            Some(std::slice::from_raw_parts_mut(
                self.ptr.add(start * self.stride) as *mut T,
                end - start,
            ))
        } else {
            None
        }
    }

    /// Downgrade to a read-only cursor.
    pub fn as_read(&self) -> LeafCursor<'v> {
        LeafCursor { ptr: self.ptr, stride: self.stride, count: self.count, _view: PhantomData }
    }
}

impl CursorRead for LeafCursorMut<'_> {
    #[inline]
    fn count(&self) -> usize {
        self.count
    }

    #[inline(always)]
    unsafe fn read_at<T: ScalarVal>(&self, lin: usize) -> T {
        self.read(lin)
    }
}

impl CursorWrite for LeafCursorMut<'_> {
    #[inline(always)]
    unsafe fn write_at<T: ScalarVal>(&self, lin: usize, v: T) {
        self.write(lin, v)
    }
}

// ---------------------------------------------------------------------
// Piecewise (AoSoA-family) cursors
// ---------------------------------------------------------------------

/// Read-only piecewise cursor for one leaf: addresses
/// `ptr + (lin / lanes) * block_stride + (lin % lanes) * lane_stride`
/// with all four integers loop-invariant (the `i -> (i/L, i%L)` split of
/// paper §4.1, hoisted out of the mapping object).
#[derive(Debug, Clone, Copy)]
pub struct PiecewiseCursor<'v> {
    ptr: *const u8,
    lanes: usize,
    block_stride: usize,
    lane_stride: usize,
    count: usize,
    _view: PhantomData<&'v [u8]>,
}

// SAFETY: read-only pointer into blob bytes borrowed for 'v.
unsafe impl Send for PiecewiseCursor<'_> {}
unsafe impl Sync for PiecewiseCursor<'_> {}

macro_rules! piecewise_shared {
    () => {
        /// Number of records the cursor covers.
        #[inline]
        pub fn count(&self) -> usize {
            self.count
        }

        /// Records per lane-block.
        #[inline]
        pub fn lanes(&self) -> usize {
            self.lanes
        }

        /// Number of lane-blocks covering `0..count`.
        #[inline]
        pub fn blocks(&self) -> usize {
            self.count.div_ceil(self.lanes)
        }

        /// Records in block `block` (== `lanes` except for the tail).
        ///
        /// Caller contract: `block < self.blocks()`.
        #[inline]
        pub fn block_len(&self, block: usize) -> usize {
            (self.count - block * self.lanes).min(self.lanes)
        }

        /// True if every block of this leaf is a dense, aligned `[T]`
        /// run — the precondition of the `block_slice` accessors.
        pub fn is_dense<T: ScalarVal>(&self) -> bool {
            self.lane_stride == std::mem::size_of::<T>()
                && (self.ptr as usize) % std::mem::align_of::<T>() == 0
                && self.block_stride % std::mem::align_of::<T>() == 0
        }
    };
}

impl<'v> PiecewiseCursor<'v> {
    /// Build one read cursor per leaf from a piecewise plan (see
    /// [`LeafCursor::from_plan`] for the contract).
    ///
    /// # Safety
    /// Each pointer must be valid for reads of its stated length for
    /// `'v`.
    pub unsafe fn from_plan(
        plan: &LayoutPlan,
        leaf_sizes: &[usize],
        blobs: &[(*const u8, usize)],
    ) -> Option<Vec<PiecewiseCursor<'v>>> {
        let AddrPlan::PiecewiseAoSoA(p) = plan.addr() else {
            return None;
        };
        let n = plan.count();
        validate_piecewise(&p.leaves, p.lanes, leaf_sizes, n, blobs.iter().map(|&(_, len)| len))?;
        // wrapping_add: see LeafCursor::from_plan (n == 0 case).
        Some(
            p.leaves
                .iter()
                .map(|l| PiecewiseCursor {
                    ptr: blobs[l.blob].0.wrapping_add(l.lane_offset),
                    lanes: p.lanes,
                    block_stride: l.block_stride,
                    lane_stride: l.lane_stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }

    piecewise_shared!();

    /// # Safety
    /// `lin < self.count()`.
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        let addr = (lin / self.lanes) * self.block_stride + (lin % self.lanes) * self.lane_stride;
        (self.ptr.add(addr) as *const T).read_unaligned()
    }

    /// Dense slice of one lane-block (the vectorizable inner-loop unit
    /// of AoSoA kernels).
    ///
    /// # Safety
    /// `block < self.blocks()` and `self.is_dense::<T>()`.
    #[inline(always)]
    pub unsafe fn block_slice<T: ScalarVal>(&self, block: usize) -> &'v [T] {
        debug_assert!(block < self.blocks() && self.is_dense::<T>());
        std::slice::from_raw_parts(
            self.ptr.add(block * self.block_stride) as *const T,
            self.block_len(block),
        )
    }
}

impl CursorRead for PiecewiseCursor<'_> {
    #[inline]
    fn count(&self) -> usize {
        self.count
    }

    #[inline(always)]
    unsafe fn read_at<T: ScalarVal>(&self, lin: usize) -> T {
        self.read(lin)
    }
}

/// Mutable piecewise cursor for one leaf.
#[derive(Debug, Clone, Copy)]
pub struct PiecewiseCursorMut<'v> {
    ptr: *mut u8,
    lanes: usize,
    block_stride: usize,
    lane_stride: usize,
    count: usize,
    _view: PhantomData<&'v mut [u8]>,
}

// SAFETY: as for LeafCursorMut.
unsafe impl Send for PiecewiseCursorMut<'_> {}
unsafe impl Sync for PiecewiseCursorMut<'_> {}

impl<'v> PiecewiseCursorMut<'v> {
    /// Mutable counterpart of [`PiecewiseCursor::from_plan`].
    ///
    /// # Safety
    /// Each pointer must be valid for reads and writes of its stated
    /// length for `'v`, with no other aliases during `'v`.
    pub unsafe fn from_plan(
        plan: &LayoutPlan,
        leaf_sizes: &[usize],
        blobs: &[(*mut u8, usize)],
    ) -> Option<Vec<PiecewiseCursorMut<'v>>> {
        let AddrPlan::PiecewiseAoSoA(p) = plan.addr() else {
            return None;
        };
        let n = plan.count();
        validate_piecewise(&p.leaves, p.lanes, leaf_sizes, n, blobs.iter().map(|&(_, len)| len))?;
        Some(
            p.leaves
                .iter()
                .map(|l| PiecewiseCursorMut {
                    ptr: blobs[l.blob].0.wrapping_add(l.lane_offset),
                    lanes: p.lanes,
                    block_stride: l.block_stride,
                    lane_stride: l.lane_stride,
                    count: n,
                    _view: PhantomData,
                })
                .collect(),
        )
    }

    piecewise_shared!();

    /// # Safety
    /// `lin < self.count()`.
    #[inline(always)]
    pub unsafe fn read<T: ScalarVal>(&self, lin: usize) -> T {
        debug_assert!(lin < self.count);
        let addr = (lin / self.lanes) * self.block_stride + (lin % self.lanes) * self.lane_stride;
        (self.ptr.add(addr) as *const T).read_unaligned()
    }

    /// # Safety
    /// `lin < self.count()`; no concurrent writers to the same slot.
    #[inline(always)]
    pub unsafe fn write<T: ScalarVal>(&self, lin: usize, v: T) {
        debug_assert!(lin < self.count);
        let addr = (lin / self.lanes) * self.block_stride + (lin % self.lanes) * self.lane_stride;
        (self.ptr.add(addr) as *mut T).write_unaligned(v)
    }

    /// Dense read-only slice of one lane-block.
    ///
    /// # Safety
    /// `block < self.blocks()` and `self.is_dense::<T>()`.
    #[inline(always)]
    pub unsafe fn block_slice<T: ScalarVal>(&self, block: usize) -> &'v [T] {
        debug_assert!(block < self.blocks() && self.is_dense::<T>());
        std::slice::from_raw_parts(
            self.ptr.add(block * self.block_stride) as *const T,
            self.block_len(block),
        )
    }

    /// Dense mutable slice of one lane-block.
    ///
    /// # Safety
    /// `block < self.blocks()`, `self.is_dense::<T>()`, and at most one
    /// live slice per (leaf, block); distinct leaves never overlap.
    #[inline(always)]
    pub unsafe fn block_slice_mut<T: ScalarVal>(&self, block: usize) -> &'v mut [T] {
        debug_assert!(block < self.blocks() && self.is_dense::<T>());
        std::slice::from_raw_parts_mut(
            self.ptr.add(block * self.block_stride) as *mut T,
            self.block_len(block),
        )
    }

    /// Downgrade to a read-only cursor.
    pub fn as_read(&self) -> PiecewiseCursor<'v> {
        PiecewiseCursor {
            ptr: self.ptr,
            lanes: self.lanes,
            block_stride: self.block_stride,
            lane_stride: self.lane_stride,
            count: self.count,
            _view: PhantomData,
        }
    }
}

impl CursorRead for PiecewiseCursorMut<'_> {
    #[inline]
    fn count(&self) -> usize {
        self.count
    }

    #[inline(always)]
    unsafe fn read_at<T: ScalarVal>(&self, lin: usize) -> T {
        self.read(lin)
    }
}

impl CursorWrite for PiecewiseCursorMut<'_> {
    #[inline(always)]
    unsafe fn write_at<T: ScalarVal>(&self, lin: usize, v: T) {
        self.write(lin, v)
    }
}

// ---------------------------------------------------------------------
// Validation (runs once per extraction, outside hot loops)
// ---------------------------------------------------------------------

/// `a + b * c` with overflow failing closed (validation then declines
/// the plan and the view keeps the generic accessor path). Overflow
/// must not wrap: `Mapping::plan` is a safe method, and a buggy plan
/// whose range computation wrapped small would hand out-of-bounds
/// cursors to safe callers.
fn acc(a: usize, b: usize, c: usize) -> Option<usize> {
    a.checked_add(b.checked_mul(c)?)
}

/// Per-leaf worst-case byte needs of an affine plan; `None` if any leaf
/// escapes its blob.
fn validate_affine(
    leaves: &[AffineLeaf],
    sizes: &[usize],
    n: usize,
    lens: impl Iterator<Item = usize>,
) -> Option<()> {
    let lens: Vec<usize> = lens.collect();
    for (leaf, a) in leaves.iter().enumerate() {
        let need = if n == 0 {
            0
        } else {
            acc(acc(sizes[leaf], n - 1, a.stride)?, 1, a.base)?
        };
        if a.blob >= lens.len() || need > lens[a.blob] {
            return None;
        }
    }
    Some(())
}

/// Exact worst-case byte needs of a piecewise plan: the maximum offset
/// over `lin in 0..n` is attained in the last (possibly partial) block
/// or at the last lane of the second-to-last (full) block.
fn validate_piecewise(
    leaves: &[PiecewiseLeaf],
    lanes: usize,
    sizes: &[usize],
    n: usize,
    lens: impl Iterator<Item = usize>,
) -> Option<()> {
    if lanes == 0 {
        return None;
    }
    let lens: Vec<usize> = lens.collect();
    let nb = n.div_ceil(lanes);
    for (leaf, l) in leaves.iter().enumerate() {
        let need = if n == 0 {
            0
        } else {
            let base = acc(sizes[leaf], 1, l.lane_offset)?;
            let tail = acc(
                acc(base, (n - 1) % lanes, l.lane_stride)?,
                nb - 1,
                l.block_stride,
            )?;
            let full = if nb >= 2 {
                acc(
                    acc(base, lanes - 1, l.lane_stride)?,
                    nb - 2,
                    l.block_stride,
                )?
            } else {
                0
            };
            tail.max(full)
        };
        if l.blob >= lens.len() || need > lens[l.blob] {
            return None;
        }
    }
    Some(())
}

// ---------------------------------------------------------------------
// View extraction
// ---------------------------------------------------------------------

/// Read cursors compiled from a view's [`LayoutPlan`].
pub enum PlanCursors<'v> {
    /// One affine cursor per leaf.
    Affine(Vec<LeafCursor<'v>>),
    /// One lane-block cursor per leaf.
    Piecewise(Vec<PiecewiseCursor<'v>>),
    /// Non-native representation, generic addressing, or a plan whose
    /// ranges do not fit the actual blobs: keep the accessor path.
    Generic,
}

/// Mutable cursors compiled from a view's [`LayoutPlan`].
pub enum PlanCursorsMut<'v> {
    /// One affine cursor per leaf.
    Affine(Vec<LeafCursorMut<'v>>),
    /// One lane-block cursor per leaf.
    Piecewise(Vec<PiecewiseCursorMut<'v>>),
    /// No closed-form cursors: keep the accessor path.
    Generic,
}

impl<M: Mapping, B: Blob> View<M, B> {
    /// Compile the mapping once and extract read cursors for every leaf.
    pub fn plan_cursors(&self) -> PlanCursors<'_> {
        self.plan_cursors_with(&self.mapping().plan())
    }

    /// [`View::plan_cursors`] over a plan the caller already compiled
    /// (e.g. the shard executor derives split points and cursors from
    /// one compilation).
    pub fn plan_cursors_with(&self, plan: &LayoutPlan) -> PlanCursors<'_> {
        if !plan.native() {
            return PlanCursors::Generic;
        }
        let sizes: Vec<usize> = self.mapping().info().fields.iter().map(|f| f.size()).collect();
        let blobs: Vec<(*const u8, usize)> = self
            .blobs()
            .iter()
            .map(|b| {
                let s = b.as_bytes();
                (s.as_ptr(), s.len())
            })
            .collect();
        // SAFETY: the pointers borrow self's blobs for the returned
        // cursors' lifetime.
        unsafe {
            if let Some(cur) = LeafCursor::from_plan(plan, &sizes, &blobs) {
                return PlanCursors::Affine(cur);
            }
            if let Some(cur) = PiecewiseCursor::from_plan(plan, &sizes, &blobs) {
                return PlanCursors::Piecewise(cur);
            }
        }
        PlanCursors::Generic
    }

    /// Read-only affine cursors, one per leaf, if the mapping compiles
    /// to an affine plan (see [`crate::mapping::Mapping::plan`]).
    pub fn leaf_cursors(&self) -> Option<Vec<LeafCursor<'_>>> {
        match self.plan_cursors() {
            PlanCursors::Affine(cur) => Some(cur),
            _ => None,
        }
    }
}

impl<M: Mapping, B: BlobMut> View<M, B> {
    /// Compile the mapping once and extract mutable cursors for every
    /// leaf.
    pub fn plan_cursors_mut(&mut self) -> PlanCursorsMut<'_> {
        self.plan_cursors_mut_with(&self.mapping().plan())
    }

    /// [`View::plan_cursors_mut`] over a plan the caller already
    /// compiled.
    pub fn plan_cursors_mut_with(&mut self, plan: &LayoutPlan) -> PlanCursorsMut<'_> {
        if !plan.native() {
            return PlanCursorsMut::Generic;
        }
        let sizes: Vec<usize> = self.mapping().info().fields.iter().map(|f| f.size()).collect();
        let (_, blobs) = self.mapping_and_blobs_mut();
        let blobs: Vec<(*mut u8, usize)> = blobs
            .iter_mut()
            .map(|b| {
                let s = b.as_bytes_mut();
                (s.as_mut_ptr(), s.len())
            })
            .collect();
        // SAFETY: the pointers exclusively borrow self's blobs for the
        // returned cursors' lifetime.
        unsafe {
            if let Some(cur) = LeafCursorMut::from_plan(plan, &sizes, &blobs) {
                return PlanCursorsMut::Affine(cur);
            }
            if let Some(cur) = PiecewiseCursorMut::from_plan(plan, &sizes, &blobs) {
                return PlanCursorsMut::Piecewise(cur);
            }
        }
        PlanCursorsMut::Generic
    }

    /// Mutable affine cursors, one per leaf.
    pub fn leaf_cursors_mut(&mut self) -> Option<Vec<LeafCursorMut<'_>>> {
        match self.plan_cursors_mut() {
            PlanCursorsMut::Affine(cur) => Some(cur),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA, Split};
    use crate::record::RecordCoord;
    use crate::view::alloc_view;

    #[test]
    fn cursors_agree_with_accessors() {
        let d = particle_dim();
        for_view(alloc_view(AoS::aligned(&d, ArrayDims::linear(9))));
        for_view(alloc_view(AoS::packed(&d, ArrayDims::linear(9))));
        for_view(alloc_view(SoA::multi_blob(&d, ArrayDims::linear(9))));
        for_view(alloc_view(SoA::single_blob(&d, ArrayDims::linear(9))));

        fn for_view<M: crate::mapping::Mapping>(mut v: crate::view::View<M, Vec<u8>>) {
            for i in 0..9 {
                v.set::<f32>(i, 1, i as f32 * 1.5); // pos.x
                v.set::<f64>(i, 4, -(i as f64)); // mass
            }
            let cur = v.leaf_cursors().expect("affine");
            for i in 0..9 {
                // SAFETY: i < count.
                unsafe {
                    assert_eq!(cur[1].read::<f32>(i), i as f32 * 1.5);
                    assert_eq!(cur[4].read::<f64>(i), -(i as f64));
                }
            }
        }
    }

    #[test]
    fn mutable_cursor_write_through() {
        let d = particle_dim();
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(5)));
        {
            let cur = v.leaf_cursors_mut().unwrap();
            for i in 0..5 {
                // SAFETY: i < count.
                unsafe { cur[1].write::<f32>(i, 7.0 + i as f32) };
            }
        }
        for i in 0..5 {
            assert_eq!(v.get::<f32>(i, 1), 7.0 + i as f32);
        }
    }

    #[test]
    fn dense_leaves_expose_slices() {
        let d = particle_dim();
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(8)));
        for i in 0..8 {
            v.set::<f32>(i, 1, i as f32);
        }
        let cur = v.leaf_cursors().unwrap();
        let xs: &[f32] = cur[1].as_slice().expect("SoA leaf is dense");
        assert_eq!(xs, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // AoS leaves are strided: no slice.
        let aos = alloc_view(AoS::packed(&d, ArrayDims::linear(8)));
        let cur = aos.leaf_cursors().unwrap();
        assert!(cur[1].as_slice::<f32>().is_none());
    }

    #[test]
    fn piecewise_cursors_agree_with_accessors() {
        let d = particle_dim();
        // 13 is not a lane multiple: exercises the tail block.
        for lanes in [2usize, 4, 8, 16] {
            let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(13), lanes));
            for i in 0..13 {
                v.set::<f32>(i, 1, i as f32 * 0.5); // pos.x
                v.set::<f64>(i, 4, -(i as f64)); // mass
            }
            let PlanCursors::Piecewise(cur) = v.plan_cursors() else {
                panic!("AoSoA{lanes} should compile to a piecewise plan");
            };
            assert_eq!(cur[1].lanes(), lanes);
            assert_eq!(cur[1].blocks(), 13usize.div_ceil(lanes));
            for i in 0..13 {
                // SAFETY: i < count.
                unsafe {
                    assert_eq!(cur[1].read::<f32>(i), i as f32 * 0.5, "lanes {lanes} i {i}");
                    assert_eq!(cur[4].read::<f64>(i), -(i as f64));
                }
            }
            // Dense block slices reproduce the same values.
            assert!(cur[1].is_dense::<f32>());
            let mut seen = Vec::new();
            for b in 0..cur[1].blocks() {
                // SAFETY: b < blocks, dense checked.
                seen.extend_from_slice(unsafe { cur[1].block_slice::<f32>(b) });
            }
            let expect: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
            assert_eq!(seen, expect, "lanes {lanes}");
        }
    }

    #[test]
    fn piecewise_mut_cursor_write_through() {
        let d = particle_dim();
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(11), 4));
        {
            let PlanCursorsMut::Piecewise(cur) = v.plan_cursors_mut() else {
                panic!("expected piecewise cursors");
            };
            for i in 0..11 {
                // SAFETY: i < count.
                unsafe { cur[2].write::<f32>(i, 100.0 + i as f32) };
            }
        }
        for i in 0..11 {
            assert_eq!(v.get::<f32>(i, 2), 100.0 + i as f32);
        }
    }

    #[test]
    fn split_of_aosoa_gets_piecewise_cursors() {
        let d = particle_dim();
        let mut v = alloc_view(Split::new(
            &d,
            ArrayDims::linear(10),
            RecordCoord::new(vec![1]), // pos -> AoSoA4, rest -> SoA MB
            |sd, ad| AoSoA::new(sd, ad, 4),
            |sd, ad| SoA::multi_blob(sd, ad),
        ));
        for i in 0..10 {
            v.set::<f32>(i, 1, i as f32); // pos.x (side A)
            v.set::<f64>(i, 4, 2.0 * i as f64); // mass (side B, blob-shifted)
        }
        let PlanCursors::Piecewise(cur) = v.plan_cursors() else {
            panic!("Split(AoSoA, SoA) should compose to a piecewise plan");
        };
        for i in 0..10 {
            // SAFETY: i < count.
            unsafe {
                assert_eq!(cur[1].read::<f32>(i), i as f32);
                assert_eq!(cur[4].read::<f64>(i), 2.0 * i as f64);
            }
        }
    }

    #[test]
    fn empty_views_extract_cursors_without_reads() {
        // n == 0: validation is vacuous and base offsets point past the
        // empty blobs — construction must still be sound (wrapping_add)
        // and kernels see count 0 / blocks 0 and never read.
        let d = particle_dim();
        let v = alloc_view(AoSoA::new(&d, ArrayDims::linear(0), 4));
        let PlanCursors::Piecewise(cur) = v.plan_cursors() else {
            panic!("empty AoSoA still compiles to a piecewise plan");
        };
        assert_eq!(cur.len(), 8);
        assert_eq!(cur[7].count(), 0);
        assert_eq!(cur[7].blocks(), 0);
        let v = alloc_view(SoA::single_blob(&d, ArrayDims::linear(0)));
        let cur = v.leaf_cursors().expect("empty SoA is still affine");
        assert!(cur.iter().all(|c| c.count() == 0));
    }

    #[test]
    fn non_native_views_return_generic() {
        let d = particle_dim();
        let v = alloc_view(AoSoA::new(&d, ArrayDims::linear(8), 4));
        // Piecewise, not affine:
        assert!(v.leaf_cursors().is_none());
        assert!(matches!(v.plan_cursors(), PlanCursors::Piecewise(_)));
        let mut v = alloc_view(Byteswap::new(AoS::packed(&d, ArrayDims::linear(8))));
        assert!(v.leaf_cursors().is_none());
        assert!(matches!(v.plan_cursors(), PlanCursors::Generic));
        assert!(matches!(v.plan_cursors_mut(), PlanCursorsMut::Generic));
    }
}
