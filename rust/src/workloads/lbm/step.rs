//! Layout-generic D3Q19 stream-collide step (pull scheme), serial and
//! multi-threaded — the compute kernel behind fig 8.

use super::{equilibrium, Geometry, E, FLAGS, FLUID, OBSTACLE, OMEGA, OPP, Q};
use crate::blob::BlobMut;
use crate::mapping::Mapping;
use crate::view::adapt::AdaptiveKernel2;
use crate::view::cursor::{CursorRead, CursorWrite};
use crate::view::shard::{par_execute_zip, Shard, ShardKernel2};
use crate::view::View;

/// The stream-collide step as an adaptive-engine kernel
/// ([`crate::view::adapt::AdaptiveView::step_zip`]): this replaces the
/// hand-wired trace → `equal_count_groups` → `build_split4` wiring of
/// the fig 8 driver — the engine's trace epoch observes the same
/// counts (flags read once per pulled direction, so it dominates) and
/// the advisor derives the hot/cold Split automatically.
pub struct AdaptiveStep {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel2 for AdaptiveStep {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, src: &View<M, B>, dst: &mut View<M, B>) {
        step_parallel(src, dst, self.threads.max(1));
    }
}

/// Initialize a view to uniform equilibrium (rho=1, u=0) and write the
/// flag field from the geometry.
pub fn init<M: Mapping, B: BlobMut>(view: &mut View<M, B>, geo: &Geometry) {
    assert_eq!(view.mapping().dims(), &geo.dims);
    let n = geo.dims.count();
    for lin in 0..n {
        for i in 0..Q {
            view.set::<f64>(lin, i, equilibrium(i, 1.0, [0.0; 3]));
        }
        view.set::<f64>(lin, FLAGS, if geo.obstacle[lin] { OBSTACLE } else { FLUID });
    }
}

/// Density+velocity of one cell (diagnostics, mass-conservation tests).
pub fn macroscopic<M: Mapping, B: BlobMut>(view: &View<M, B>, lin: usize) -> (f64, [f64; 3]) {
    let mut rho = 0.0;
    let mut u = [0.0f64; 3];
    for i in 0..Q {
        let f = view.get::<f64>(lin, i);
        rho += f;
        for d in 0..3 {
            u[d] += f * E[i][d] as f64;
        }
    }
    if rho > 0.0 {
        for d in &mut u {
            *d /= rho;
        }
    }
    (rho, u)
}

/// Total mass in the lattice (conserved by the step).
pub fn total_mass<M: Mapping, B: BlobMut>(view: &View<M, B>) -> f64 {
    (0..view.count()).map(|lin| (0..Q).map(|i| view.get::<f64>(lin, i)).sum::<f64>()).sum()
}

/// A small constant body force applied along +x to fluid cells (keeps
/// the flow moving like SPEC lbm's driven channel).
const ACCEL: f64 = 0.0005;

#[inline(always)]
fn wrap(v: i64, n: i64) -> usize {
    // v in [-1, n]; cheap wrap without division.
    if v < 0 {
        (v + n) as usize
    } else if v >= n {
        (v - n) as usize
    } else {
        v as usize
    }
}

/// Plan-cursor slab kernel (EXPERIMENTS.md §Perf): all per-access
/// mapping calls (offset tables, Split routing, the AoSoA `i/L, i%L`
/// split through the mapping object) are replaced by loop-invariant
/// cursors extracted once per step from the mapping's compiled
/// [`crate::mapping::LayoutPlan`]. Generic over the cursor shape, so
/// AoS/SoA/Split (affine) and AoSoA (piecewise) monomorphize to their
/// own tight kernels.
///
/// # Safety
/// Cursors cover `0..nx*ny*nz`; concurrent callers use disjoint slabs.
unsafe fn step_slab_cursors<R: CursorRead, W: CursorWrite>(
    src: &[R],
    dst: &[W],
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
) {
    let (nxi, nyi, nzi) = (nx as i64, ny as i64, nz as i64);
    for x in x0..x1 {
        for y in 0..ny {
            for z in 0..nz {
                let lin = (x * ny + y) * nz + z;
                let flags = src[FLAGS].read_at::<f64>(lin);
                if flags == OBSTACLE {
                    for i in 0..Q {
                        dst[i].write_at::<f64>(lin, src[i].read_at::<f64>(lin));
                    }
                    dst[FLAGS].write_at::<f64>(lin, flags);
                    continue;
                }
                let mut f = [0.0f64; Q];
                let mut rho = 0.0;
                let mut u = [0.0f64; 3];
                for i in 0..Q {
                    let sx = wrap(x as i64 - E[i][0] as i64, nxi);
                    let sy = wrap(y as i64 - E[i][1] as i64, nyi);
                    let sz = wrap(z as i64 - E[i][2] as i64, nzi);
                    let slin = (sx * ny + sy) * nz + sz;
                    let fi = if src[FLAGS].read_at::<f64>(slin) == OBSTACLE {
                        src[OPP[i]].read_at::<f64>(lin)
                    } else {
                        src[i].read_at::<f64>(slin)
                    };
                    f[i] = fi;
                    rho += fi;
                    for d in 0..3 {
                        u[d] += fi * E[i][d] as f64;
                    }
                }
                let inv_rho = 1.0 / rho;
                for d in &mut u {
                    *d *= inv_rho;
                }
                u[0] += ACCEL;
                for i in 0..Q {
                    let feq = equilibrium(i, rho, u);
                    dst[i].write_at::<f64>(lin, f[i] + OMEGA * (feq - f[i]));
                }
                dst[FLAGS].write_at::<f64>(lin, flags);
            }
        }
    }
}

/// One stream-collide step over the x-slab `x0..x1`, pulling from `src`
/// and writing `dst`. The body shared by the serial and parallel
/// drivers.
///
/// # Safety
/// Caller guarantees both views are validated and slabs given to
/// concurrent callers are disjoint (writes only touch `dst` cells in
/// the slab; the mapping invariant keeps their byte ranges disjoint).
unsafe fn step_slab<MS: Mapping, MD: Mapping, B: BlobMut>(
    src: &View<MS, B>,
    dst: *mut View<MD, B>,
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
) {
    let dst = &mut *dst;
    let (nxi, nyi, nzi) = (nx as i64, ny as i64, nz as i64);
    for x in x0..x1 {
        for y in 0..ny {
            for z in 0..nz {
                let lin = (x * ny + y) * nz + z;
                let flags = src.get_unchecked::<f64>(lin, FLAGS);
                if flags == OBSTACLE {
                    // Obstacle cells are inert: keep their state (their
                    // outgoing populations are reflected by the fluid
                    // neighbours below, so nothing is consumed here).
                    for i in 0..Q {
                        let f = src.get_unchecked::<f64>(lin, i);
                        dst.set_unchecked::<f64>(lin, i, f);
                    }
                    dst.set_unchecked::<f64>(lin, FLAGS, flags);
                    continue;
                }
                // Pull: gather f_i from the upwind neighbour; if the
                // neighbour is a wall, take the cell's own opposite
                // population instead (link bounce-back). Every fluid
                // population thus has exactly one consumer per step,
                // conserving mass exactly.
                let mut f = [0.0f64; Q];
                let mut rho = 0.0;
                let mut u = [0.0f64; 3];
                for i in 0..Q {
                    let sx = wrap(x as i64 - E[i][0] as i64, nxi);
                    let sy = wrap(y as i64 - E[i][1] as i64, nyi);
                    let sz = wrap(z as i64 - E[i][2] as i64, nzi);
                    let slin = (sx * ny + sy) * nz + sz;
                    let fi = if src.get_unchecked::<f64>(slin, FLAGS) == OBSTACLE {
                        src.get_unchecked::<f64>(lin, OPP[i])
                    } else {
                        src.get_unchecked::<f64>(slin, i)
                    };
                    f[i] = fi;
                    rho += fi;
                    for d in 0..3 {
                        u[d] += fi * E[i][d] as f64;
                    }
                }
                let inv_rho = 1.0 / rho;
                for d in &mut u {
                    *d *= inv_rho;
                }
                u[0] += ACCEL; // body force
                // BGK collision.
                for i in 0..Q {
                    let feq = equilibrium(i, rho, u);
                    dst.set_unchecked::<f64>(lin, i, f[i] + OMEGA * (feq - f[i]));
                }
                dst.set_unchecked::<f64>(lin, FLAGS, flags);
            }
        }
    }
}

/// Shard-wise stream-collide kernel for the shared executor
/// ([`crate::view::shard::par_execute_zip`]). Shards arrive with
/// boundaries on x-slab granularity (`ny*nz` cells, the `granularity`
/// passed below), so each shard is a whole `x0..x1` slab range.
struct StepKernel {
    nx: usize,
    ny: usize,
    nz: usize,
}

impl ShardKernel2 for StepKernel {
    fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], s: Shard) {
        let plane = self.ny * self.nz;
        debug_assert!(s.start % plane == 0, "shard start {} splits an x-slab", s.start);
        let (x0, x1) = (s.start / plane, s.end.div_ceil(plane));
        // SAFETY: cursors were validated over the full range at
        // extraction; shards are disjoint, so slabs and their written
        // dst bytes are disjoint (mapping invariant).
        unsafe { step_slab_cursors(src, dst, self.nx, self.ny, self.nz, x0, x1) };
    }
}

/// Serial stream-collide step: pull from `src` into `dst` (ping-pong
/// buffers like SPEC lbm). Both views' mappings are compiled to
/// [`crate::mapping::LayoutPlan`]s once; any combination of affine and
/// piecewise plans runs the cursor kernel through the shared shard
/// executor (one shard — runs inline), only generic plans
/// (instrumented/curve layouts) pay per-access translation.
pub fn step<MS: Mapping, MD: Mapping, B: BlobMut>(src: &View<MS, B>, dst: &mut View<MD, B>) {
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    if par_execute_zip(src, dst, 1, ny * nz, &StepKernel { nx, ny, nz }) {
        return;
    }
    debug_assert!(src.validate().is_ok() && dst.validate().is_ok());
    // SAFETY: single caller, whole range.
    unsafe { step_slab(src, dst as *mut _, nx, ny, nz, 0, nx) };
}

/// Multi-threaded step: x-slab shards are distributed over `threads`
/// scoped workers by [`crate::view::shard::par_execute_zip`] (the
/// paper's OpenMP parallelization of 619.lbm_s).
pub fn step_parallel<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>, threads: usize)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    let threads = threads.max(1).min(nx.max(1));
    if threads == 1 {
        step(src, dst);
        return;
    }
    if par_execute_zip(src, dst, threads, ny * nz, &StepKernel { nx, ny, nz }) {
        return;
    }
    step_parallel_generic(src, dst, nx, ny, nz, threads);
}

/// Parallel step through the generic accessor path (plans without
/// closed-form addressing).
fn step_parallel_generic<MS, MD, B>(
    src: &View<MS, B>,
    dst: &mut View<MD, B>,
    nx: usize,
    ny: usize,
    nz: usize,
    threads: usize,
) where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    debug_assert!(src.validate().is_ok() && dst.validate().is_ok());
    struct DstPtr<M: Mapping, B: BlobMut>(*mut View<M, B>);
    // SAFETY: workers write disjoint slabs (disjoint lin ranges →
    // disjoint dst bytes by the mapping invariant).
    unsafe impl<M: Mapping, B: BlobMut> Sync for DstPtr<M, B> {}
    unsafe impl<M: Mapping, B: BlobMut> Send for DstPtr<M, B> {}
    let dst_ptr = DstPtr(dst as *mut _);
    let per = nx.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let x0 = t * per;
            let x1 = ((t + 1) * per).min(nx);
            if x0 >= x1 {
                break;
            }
            let dst_ptr = &dst_ptr;
            scope.spawn(move || {
                // SAFETY: slabs are disjoint; see DstPtr.
                unsafe { step_slab(src, dst_ptr.0, nx, ny, nz, x0, x1) };
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::alloc_view;
    use crate::workloads::lbm::cell_dim;

    fn small_geo() -> Geometry {
        Geometry::channel_with_sphere(8, 8, 8, 1)
    }

    #[test]
    fn mass_is_conserved() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(AoS::aligned(&d, geo.dims.clone()));
        let mut b = alloc_view(AoS::aligned(&d, geo.dims.clone()));
        init(&mut a, &geo);
        init(&mut b, &geo);
        let m0 = total_mass(&a);
        for _ in 0..4 {
            step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        let m1 = total_mass(&a);
        assert!((m0 - m1).abs() / m0 < 1e-9, "mass drift {m0} -> {m1}");
    }

    #[test]
    fn layouts_produce_identical_fields() {
        let geo = small_geo();
        let d = cell_dim();
        fn run<M: Mapping>(m0: M, m1: M, geo: &Geometry) -> Vec<f64> {
            let mut a = alloc_view(m0);
            let mut b = alloc_view(m1);
            init(&mut a, geo);
            init(&mut b, geo);
            for _ in 0..3 {
                step(&a, &mut b);
                std::mem::swap(&mut a, &mut b);
            }
            (0..a.count()).map(|lin| a.get::<f64>(lin, 0)).collect()
        }
        let aos = run(
            AoS::aligned(&d, geo.dims.clone()),
            AoS::aligned(&d, geo.dims.clone()),
            &geo,
        );
        let soa = run(
            SoA::multi_blob(&d, geo.dims.clone()),
            SoA::multi_blob(&d, geo.dims.clone()),
            &geo,
        );
        let aosoa = run(
            AoSoA::new(&d, geo.dims.clone(), 8),
            AoSoA::new(&d, geo.dims.clone(), 8),
            &geo,
        );
        assert_eq!(aos, soa);
        assert_eq!(aos, aosoa);
    }

    #[test]
    fn parallel_matches_serial() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b1 = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b4 = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        init(&mut a, &geo);
        step(&a, &mut b1);
        step_parallel(&a, &mut b4, 4);
        assert_eq!(b1.blobs(), b4.blobs());
    }

    #[test]
    fn parallel_matches_serial_on_piecewise_plans() {
        // AoSoA dst: shard boundaries must respect both the x-slab
        // granularity and the destination's lane blocks.
        let geo = small_geo();
        let d = cell_dim();
        for lanes in [8usize, 32, 256] {
            let mut a = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            let mut b1 = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            let mut bn = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            init(&mut a, &geo);
            step(&a, &mut b1);
            step_parallel(&a, &mut bn, 3);
            assert_eq!(b1.blobs(), bn.blobs(), "lanes {lanes}");
        }
    }

    #[test]
    fn obstacles_are_inert_and_fluid_mass_stays() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(AoS::packed(&d, geo.dims.clone()));
        let mut b = alloc_view(AoS::packed(&d, geo.dims.clone()));
        init(&mut a, &geo);
        step(&a, &mut b);
        let lin = geo.obstacle.iter().position(|&o| o).expect("has obstacle");
        for i in 0..Q {
            assert_eq!(b.get::<f64>(lin, i), a.get::<f64>(lin, i));
        }
        assert_eq!(b.get::<f64>(lin, FLAGS), OBSTACLE);
    }

    #[test]
    fn wall_neighbour_pulls_reflection() {
        // 3x1x1 grid (periodic), cell 1 is a wall: a fluid cell next to
        // the wall must take its own opposite population for the
        // blocked link.
        let dims = crate::array::ArrayDims::from([3, 1, 1]);
        let mut obstacle = vec![false; 3];
        obstacle[1] = true;
        let geo = Geometry { dims: dims.clone(), obstacle };
        let d = cell_dim();
        let mut a = alloc_view(AoS::packed(&d, dims.clone()));
        let mut b = alloc_view(AoS::packed(&d, dims));
        init(&mut a, &geo);
        // Tag cell 2's population so we can watch where it goes.
        a.set::<f64>(2, 1, 0.7); // direction +x of cell 2
        let m0 = total_mass(&a) - {
            // exclude the inert wall cell's mass from the comparison
            (0..Q).map(|i| a.get::<f64>(1, i)).sum::<f64>()
        };
        step(&a, &mut b);
        let m1 = total_mass(&b) - (0..Q).map(|i| b.get::<f64>(1, i)).sum::<f64>();
        assert!((m0 - m1).abs() < 1e-12, "fluid mass {m0} -> {m1}");
    }

    #[test]
    fn flow_develops_along_x() {
        let geo = Geometry {
            dims: crate::array::ArrayDims::from([6, 6, 6]),
            obstacle: vec![false; 216],
        };
        let d = cell_dim();
        let mut a = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        init(&mut a, &geo);
        for _ in 0..10 {
            step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        let (_, u) = macroscopic(&a, 0);
        assert!(u[0] > 0.0, "driven flow should move +x, got {u:?}");
    }
}
