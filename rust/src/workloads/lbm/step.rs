//! Layout-generic D3Q19 stream-collide step (pull scheme), serial and
//! multi-threaded — the compute kernel behind fig 8.

use super::{equilibrium, Geometry, E, FLAGS, FLUID, OBSTACLE, OMEGA, OPP, Q};
use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::view::adapt::AdaptiveKernel2;
use crate::view::cursor::{CursorRead, CursorWrite};
use crate::view::shard::{par_execute_zip, Shard, ShardKernel2};
use crate::view::simd::{detect, SimdPath};
use crate::view::View;

/// The stream-collide step as an adaptive-engine kernel
/// ([`crate::view::adapt::AdaptiveView::step_zip`]): this replaces the
/// hand-wired trace → `equal_count_groups` → `build_split4` wiring of
/// the fig 8 driver — the engine's trace epoch observes the same
/// counts (flags read once per pulled direction, so it dominates) and
/// the advisor derives the hot/cold Split automatically.
pub struct AdaptiveStep {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel2 for AdaptiveStep {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, src: &View<M, B>, dst: &mut View<M, B>) {
        step_parallel(src, dst, self.threads.max(1));
    }
}

/// Initialize a view to uniform equilibrium (rho=1, u=0) and write the
/// flag field from the geometry.
pub fn init<M: Mapping, B: BlobMut>(view: &mut View<M, B>, geo: &Geometry) {
    assert_eq!(view.mapping().dims(), &geo.dims);
    let n = geo.dims.count();
    for lin in 0..n {
        for i in 0..Q {
            view.set::<f64>(lin, i, equilibrium(i, 1.0, [0.0; 3]));
        }
        view.set::<f64>(lin, FLAGS, if geo.obstacle[lin] { OBSTACLE } else { FLUID });
    }
}

/// Density+velocity of one cell (diagnostics, mass-conservation tests).
pub fn macroscopic<M: Mapping, B: Blob>(view: &View<M, B>, lin: usize) -> (f64, [f64; 3]) {
    let mut rho = 0.0;
    let mut u = [0.0f64; 3];
    for i in 0..Q {
        let f = view.get::<f64>(lin, i);
        rho += f;
        for d in 0..3 {
            u[d] += f * E[i][d] as f64;
        }
    }
    if rho > 0.0 {
        for d in &mut u {
            *d /= rho;
        }
    }
    (rho, u)
}

/// Total mass in the lattice (conserved by the step).
pub fn total_mass<M: Mapping, B: Blob>(view: &View<M, B>) -> f64 {
    (0..view.count()).map(|lin| (0..Q).map(|i| view.get::<f64>(lin, i)).sum::<f64>()).sum()
}

/// A small constant body force applied along +x to fluid cells (keeps
/// the flow moving like SPEC lbm's driven channel).
const ACCEL: f64 = 0.0005;

#[inline(always)]
fn wrap(v: i64, n: i64) -> usize {
    // v in [-1, n]; cheap wrap without division.
    if v < 0 {
        (v + n) as usize
    } else if v >= n {
        (v - n) as usize
    } else {
        v as usize
    }
}

/// Plan-cursor slab kernel (EXPERIMENTS.md §Perf): all per-access
/// mapping calls (offset tables, Split routing, the AoSoA `i/L, i%L`
/// split through the mapping object) are replaced by loop-invariant
/// cursors extracted once per step from the mapping's compiled
/// [`crate::mapping::LayoutPlan`]. Generic over the cursor shape, so
/// AoS/SoA/Split (affine) and AoSoA (piecewise) monomorphize to their
/// own tight kernels.
///
/// # Safety
/// Cursors cover `0..nx*ny*nz`; concurrent callers use disjoint slabs.
unsafe fn step_slab_cursors<R: CursorRead, W: CursorWrite>(
    src: &[R],
    dst: &[W],
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
) {
    let (nxi, nyi, nzi) = (nx as i64, ny as i64, nz as i64);
    for x in x0..x1 {
        for y in 0..ny {
            for z in 0..nz {
                step_cell_cursors(src, dst, x, y, z, ny, nz, nxi, nyi, nzi);
            }
        }
    }
}

/// One cell of the cursor stream-collide kernel, extracted so the
/// scalar slab loop and the SIMD driver's divergent cells (batches
/// touching obstacles, z-tails) share a single body.
///
/// # Safety
/// Cursors cover `0..nx*ny*nz` and `(x, y, z)` is in range.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn step_cell_cursors<R: CursorRead, W: CursorWrite>(
    src: &[R],
    dst: &[W],
    x: usize,
    y: usize,
    z: usize,
    ny: usize,
    nz: usize,
    nxi: i64,
    nyi: i64,
    nzi: i64,
) {
    let lin = (x * ny + y) * nz + z;
    let flags = src[FLAGS].read_at::<f64>(lin);
    if flags == OBSTACLE {
        for i in 0..Q {
            dst[i].write_at::<f64>(lin, src[i].read_at::<f64>(lin));
        }
        dst[FLAGS].write_at::<f64>(lin, flags);
        return;
    }
    let mut f = [0.0f64; Q];
    let mut rho = 0.0;
    let mut u = [0.0f64; 3];
    for i in 0..Q {
        let sx = wrap(x as i64 - E[i][0] as i64, nxi);
        let sy = wrap(y as i64 - E[i][1] as i64, nyi);
        let sz = wrap(z as i64 - E[i][2] as i64, nzi);
        let slin = (sx * ny + sy) * nz + sz;
        let fi = if src[FLAGS].read_at::<f64>(slin) == OBSTACLE {
            src[OPP[i]].read_at::<f64>(lin)
        } else {
            src[i].read_at::<f64>(slin)
        };
        f[i] = fi;
        rho += fi;
        for d in 0..3 {
            u[d] += fi * E[i][d] as f64;
        }
    }
    let inv_rho = 1.0 / rho;
    for d in &mut u {
        *d *= inv_rho;
    }
    u[0] += ACCEL;
    for i in 0..Q {
        let feq = equilibrium(i, rho, u);
        dst[i].write_at::<f64>(lin, f[i] + OMEGA * (feq - f[i]));
    }
    dst[FLAGS].write_at::<f64>(lin, flags);
}

/// One stream-collide step over the x-slab `x0..x1`, pulling from `src`
/// and writing `dst`. The body shared by the serial and parallel
/// drivers.
///
/// # Safety
/// Caller guarantees both views are validated and slabs given to
/// concurrent callers are disjoint (writes only touch `dst` cells in
/// the slab; the mapping invariant keeps their byte ranges disjoint).
unsafe fn step_slab<MS: Mapping, MD: Mapping, B: BlobMut>(
    src: &View<MS, B>,
    dst: *mut View<MD, B>,
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
) {
    let dst = &mut *dst;
    let (nxi, nyi, nzi) = (nx as i64, ny as i64, nz as i64);
    for x in x0..x1 {
        for y in 0..ny {
            for z in 0..nz {
                let lin = (x * ny + y) * nz + z;
                let flags = src.get_unchecked::<f64>(lin, FLAGS);
                if flags == OBSTACLE {
                    // Obstacle cells are inert: keep their state (their
                    // outgoing populations are reflected by the fluid
                    // neighbours below, so nothing is consumed here).
                    for i in 0..Q {
                        let f = src.get_unchecked::<f64>(lin, i);
                        dst.set_unchecked::<f64>(lin, i, f);
                    }
                    dst.set_unchecked::<f64>(lin, FLAGS, flags);
                    continue;
                }
                // Pull: gather f_i from the upwind neighbour; if the
                // neighbour is a wall, take the cell's own opposite
                // population instead (link bounce-back). Every fluid
                // population thus has exactly one consumer per step,
                // conserving mass exactly.
                let mut f = [0.0f64; Q];
                let mut rho = 0.0;
                let mut u = [0.0f64; 3];
                for i in 0..Q {
                    let sx = wrap(x as i64 - E[i][0] as i64, nxi);
                    let sy = wrap(y as i64 - E[i][1] as i64, nyi);
                    let sz = wrap(z as i64 - E[i][2] as i64, nzi);
                    let slin = (sx * ny + sy) * nz + sz;
                    let fi = if src.get_unchecked::<f64>(slin, FLAGS) == OBSTACLE {
                        src.get_unchecked::<f64>(lin, OPP[i])
                    } else {
                        src.get_unchecked::<f64>(slin, i)
                    };
                    f[i] = fi;
                    rho += fi;
                    for d in 0..3 {
                        u[d] += fi * E[i][d] as f64;
                    }
                }
                let inv_rho = 1.0 / rho;
                for d in &mut u {
                    *d *= inv_rho;
                }
                u[0] += ACCEL; // body force
                // BGK collision.
                for i in 0..Q {
                    let feq = equilibrium(i, rho, u);
                    dst.set_unchecked::<f64>(lin, i, f[i] + OMEGA * (feq - f[i]));
                }
                dst.set_unchecked::<f64>(lin, FLAGS, flags);
            }
        }
    }
}

/// Shard-wise stream-collide kernel for the shared executor
/// ([`crate::view::shard::par_execute_zip`]). Shards arrive with
/// boundaries on x-slab granularity (`ny*nz` cells, the `granularity`
/// passed below), so each shard is a whole `x0..x1` slab range.
struct StepKernel {
    nx: usize,
    ny: usize,
    nz: usize,
}

impl ShardKernel2 for StepKernel {
    fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], s: Shard) {
        let plane = self.ny * self.nz;
        debug_assert!(s.start % plane == 0, "shard start {} splits an x-slab", s.start);
        let (x0, x1) = (s.start / plane, s.end.div_ceil(plane));
        // SAFETY: cursors were validated over the full range at
        // extraction; shards are disjoint, so slabs and their written
        // dst bytes are disjoint (mapping invariant).
        unsafe { step_slab_cursors(src, dst, self.nx, self.ny, self.nz, x0, x1) };
    }
}

/// Serial stream-collide step: pull from `src` into `dst` (ping-pong
/// buffers like SPEC lbm). Both views' mappings are compiled to
/// [`crate::mapping::LayoutPlan`]s once; any combination of affine and
/// piecewise plans runs the cursor kernel through the shared shard
/// executor (one shard — runs inline), only generic plans
/// (instrumented/curve layouts) pay per-access translation.
pub fn step<MS: Mapping, MD: Mapping, B: BlobMut>(src: &View<MS, B>, dst: &mut View<MD, B>) {
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    if par_execute_zip(src, dst, 1, ny * nz, &StepKernel { nx, ny, nz }) {
        return;
    }
    debug_assert!(src.validate().is_ok() && dst.validate().is_ok());
    // SAFETY: single caller, whole range.
    unsafe { step_slab(src, dst as *mut _, nx, ny, nz, 0, nx) };
}

/// [`StepKernel`] variant for plane-restricted steps: the executor
/// hands it the single whole-range shard (it only runs with one
/// thread) and the kernel steps just the configured `x0..x1` slab —
/// the cursor fast path of [`step_planes`] without a range-restricted
/// executor entry point.
struct PlaneKernel {
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
}

impl ShardKernel2 for PlaneKernel {
    fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], s: Shard) {
        debug_assert_eq!(
            (s.start, s.end),
            (0, self.nx * self.ny * self.nz),
            "PlaneKernel expects the single whole-range shard"
        );
        // SAFETY: cursors were validated over the full range at
        // extraction; the single shard means no concurrent writer.
        unsafe { step_slab_cursors(src, dst, self.nx, self.ny, self.nz, self.x0, self.x1) };
    }
}

/// One stream-collide step restricted to the x-planes `x0..x1`,
/// pulling from `src` and writing only those planes of `dst` — every
/// other `dst` cell is untouched. The split-phase halo schedule steps
/// the two boundary planes first, ships them, then steps the interior
/// while next-step ghosts arrive
/// (`workloads::lbm::halo::{step_boundary, step_interior}`). Plane `x`
/// pulls from planes `x-1..=x+1` (periodic wrap at the lattice edge),
/// and the cell kernel is byte-for-byte the one [`step`] runs — only
/// the x loop bounds differ — so restricted steps compose
/// bit-identically with whole-lattice steps.
pub fn step_planes<MS: Mapping, MD: Mapping, B: BlobMut>(
    src: &View<MS, B>,
    dst: &mut View<MD, B>,
    x0: usize,
    x1: usize,
) {
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    assert!(x0 <= x1 && x1 <= nx, "plane range {x0}..{x1} out of 0..{nx}");
    if x0 == x1 {
        return;
    }
    if par_execute_zip(src, dst, 1, ny * nz, &PlaneKernel { nx, ny, nz, x0, x1 }) {
        return;
    }
    debug_assert!(src.validate().is_ok() && dst.validate().is_ok());
    // SAFETY: single caller, planes x0..x1 only.
    unsafe { step_slab(src, dst as *mut _, nx, ny, nz, x0, x1) };
}

/// Multi-threaded step: x-slab shards are distributed over `threads`
/// scoped workers by [`crate::view::shard::par_execute_zip`] (the
/// paper's OpenMP parallelization of 619.lbm_s).
pub fn step_parallel<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>, threads: usize)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    let threads = threads.max(1).min(nx.max(1));
    if threads == 1 {
        step(src, dst);
        return;
    }
    if par_execute_zip(src, dst, threads, ny * nz, &StepKernel { nx, ny, nz }) {
        return;
    }
    step_parallel_generic(src, dst, nx, ny, nz, threads);
}

/// Parallel step through the generic accessor path (plans without
/// closed-form addressing).
fn step_parallel_generic<MS, MD, B>(
    src: &View<MS, B>,
    dst: &mut View<MD, B>,
    nx: usize,
    ny: usize,
    nz: usize,
    threads: usize,
) where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    debug_assert!(src.validate().is_ok() && dst.validate().is_ok());
    struct DstPtr<M: Mapping, B: BlobMut>(*mut View<M, B>);
    // SAFETY: workers write disjoint slabs (disjoint lin ranges →
    // disjoint dst bytes by the mapping invariant).
    unsafe impl<M: Mapping, B: BlobMut> Sync for DstPtr<M, B> {}
    unsafe impl<M: Mapping, B: BlobMut> Send for DstPtr<M, B> {}
    let dst_ptr = DstPtr(dst as *mut _);
    let per = nx.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let x0 = t * per;
            let x1 = ((t + 1) * per).min(nx);
            if x0 >= x1 {
                break;
            }
            let dst_ptr = &dst_ptr;
            scope.spawn(move || {
                // SAFETY: slabs are disjoint; see DstPtr.
                unsafe { step_slab(src, dst_ptr.0, nx, ny, nz, x0, x1) };
            });
        }
    });
}

/// Lane-batched slab driver (`simd` feature, x86_64): `B` z-consecutive
/// cells advance together. `lin = (x*ny + y)*nz + z`, so the batch is
/// linearly contiguous and batch reads/writes hit the cursors' fast
/// block paths. The divergent parts — periodic wrap and the per-link
/// bounce-back flag choice — stay scalar and fill one `[f64; B]` per
/// direction; only the collision arithmetic runs through `collide`,
/// whose lanes replay the exact scalar operation order. Batches that
/// touch an obstacle cell and the `nz % B` z-tail run the per-cell
/// scalar kernel, so the whole step is bit-identical to
/// [`step_slab_cursors`].
///
/// # Safety
/// Cursors cover `0..nx*ny*nz`; `collide`'s ISA must be available on
/// this host; concurrent callers use disjoint slabs.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
unsafe fn step_slab_cursors_simd<R: CursorRead, Wr: CursorWrite, const B: usize>(
    src: &[R],
    dst: &[Wr],
    nx: usize,
    ny: usize,
    nz: usize,
    x0: usize,
    x1: usize,
    collide: unsafe fn(&mut [[f64; B]; Q]),
) {
    use crate::view::simd::{SimdCursorRead, SimdCursorWrite};
    let (nxi, nyi, nzi) = (nx as i64, ny as i64, nz as i64);
    for x in x0..x1 {
        for y in 0..ny {
            let mut z = 0;
            while z + B <= nz {
                let lin0 = (x * ny + y) * nz + z;
                let flags: [f64; B] = src[FLAGS].read_batch(lin0);
                if flags.iter().any(|&fl| fl == OBSTACLE) {
                    for k in 0..B {
                        step_cell_cursors(src, dst, x, y, z + k, ny, nz, nxi, nyi, nzi);
                    }
                } else {
                    let mut f = [[0.0f64; B]; Q];
                    for (i, fi) in f.iter_mut().enumerate() {
                        for (k, fk) in fi.iter_mut().enumerate() {
                            let sx = wrap(x as i64 - E[i][0] as i64, nxi);
                            let sy = wrap(y as i64 - E[i][1] as i64, nyi);
                            let sz = wrap((z + k) as i64 - E[i][2] as i64, nzi);
                            let slin = (sx * ny + sy) * nz + sz;
                            *fk = if src[FLAGS].read_at::<f64>(slin) == OBSTACLE {
                                src[OPP[i]].read_at::<f64>(lin0 + k)
                            } else {
                                src[i].read_at::<f64>(slin)
                            };
                        }
                    }
                    collide(&mut f);
                    for (i, fi) in f.iter().enumerate() {
                        dst[i].write_batch(lin0, *fi);
                    }
                    dst[FLAGS].write_batch(lin0, flags);
                }
                z += B;
            }
            while z < nz {
                step_cell_cursors(src, dst, x, y, z, ny, nz, nxi, nyi, nzi);
                z += 1;
            }
        }
    }
}

/// Plain `unsafe fn` wrappers (no `#[target_feature]`) so the slab
/// driver can take the collision kernels as ordinary function pointers;
/// the dispatcher only selects them after runtime detection.
///
/// # Safety
/// AVX2 must be available.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
unsafe fn collide4_avx2(f: &mut [[f64; 4]; Q]) {
    x86::collide_block_avx2(f);
}

/// See [`collide4_avx2`].
///
/// # Safety
/// SSE2 must be available (guaranteed on x86_64, dispatched anyway).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
unsafe fn collide2_sse2(f: &mut [[f64; 2]; Q]) {
    x86::collide_block_sse2(f);
}

/// Vectorized BGK collision kernels. Each lane replays the scalar
/// collision bit for bit: rho/u accumulate in the same `i` order,
/// `inv_rho` is the same `1.0 / rho` division, and the equilibrium
/// polynomial uses the exact association of
/// [`crate::workloads::lbm::equilibrium`]. `u2` is hoisted out of the
/// direction loop — the scalar kernel recomputes the identical value
/// per direction, so hoisting preserves bit-identity.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::{ACCEL, E, OMEGA, Q};
    use crate::workloads::lbm::W;
    use core::arch::x86_64::*;

    /// Collide 4 f64 cells per call (AVX2).
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn collide_block_avx2(f: &mut [[f64; 4]; Q]) {
        let mut rho = _mm256_setzero_pd();
        let mut u = [_mm256_setzero_pd(); 3];
        for (i, fi) in f.iter().enumerate() {
            let v = _mm256_loadu_pd(fi.as_ptr());
            rho = _mm256_add_pd(rho, v);
            for (d, ud) in u.iter_mut().enumerate() {
                *ud = _mm256_add_pd(*ud, _mm256_mul_pd(v, _mm256_set1_pd(E[i][d] as f64)));
            }
        }
        let inv_rho = _mm256_div_pd(_mm256_set1_pd(1.0), rho);
        for ud in &mut u {
            *ud = _mm256_mul_pd(*ud, inv_rho);
        }
        u[0] = _mm256_add_pd(u[0], _mm256_set1_pd(ACCEL));
        let u2 = _mm256_add_pd(
            _mm256_add_pd(_mm256_mul_pd(u[0], u[0]), _mm256_mul_pd(u[1], u[1])),
            _mm256_mul_pd(u[2], u[2]),
        );
        for (i, fi) in f.iter_mut().enumerate() {
            let v = _mm256_loadu_pd(fi.as_ptr());
            let eu = _mm256_add_pd(
                _mm256_add_pd(
                    _mm256_mul_pd(_mm256_set1_pd(E[i][0] as f64), u[0]),
                    _mm256_mul_pd(_mm256_set1_pd(E[i][1] as f64), u[1]),
                ),
                _mm256_mul_pd(_mm256_set1_pd(E[i][2] as f64), u[2]),
            );
            // (1 + 3*eu + (4.5*eu)*eu) - 1.5*u2, associated exactly as
            // the scalar `equilibrium`.
            let inner = _mm256_sub_pd(
                _mm256_add_pd(
                    _mm256_add_pd(_mm256_set1_pd(1.0), _mm256_mul_pd(_mm256_set1_pd(3.0), eu)),
                    _mm256_mul_pd(_mm256_mul_pd(_mm256_set1_pd(4.5), eu), eu),
                ),
                _mm256_mul_pd(_mm256_set1_pd(1.5), u2),
            );
            let feq = _mm256_mul_pd(_mm256_mul_pd(_mm256_set1_pd(W[i]), rho), inner);
            let out = _mm256_add_pd(v, _mm256_mul_pd(_mm256_set1_pd(OMEGA), _mm256_sub_pd(feq, v)));
            _mm256_storeu_pd(fi.as_mut_ptr(), out);
        }
    }

    /// Collide 2 f64 cells per call (SSE2, baseline on x86_64).
    ///
    /// # Safety
    /// SSE2 must be available (always true on x86_64).
    #[target_feature(enable = "sse2")]
    pub unsafe fn collide_block_sse2(f: &mut [[f64; 2]; Q]) {
        let mut rho = _mm_setzero_pd();
        let mut u = [_mm_setzero_pd(); 3];
        for (i, fi) in f.iter().enumerate() {
            let v = _mm_loadu_pd(fi.as_ptr());
            rho = _mm_add_pd(rho, v);
            for (d, ud) in u.iter_mut().enumerate() {
                *ud = _mm_add_pd(*ud, _mm_mul_pd(v, _mm_set1_pd(E[i][d] as f64)));
            }
        }
        let inv_rho = _mm_div_pd(_mm_set1_pd(1.0), rho);
        for ud in &mut u {
            *ud = _mm_mul_pd(*ud, inv_rho);
        }
        u[0] = _mm_add_pd(u[0], _mm_set1_pd(ACCEL));
        let u2 = _mm_add_pd(
            _mm_add_pd(_mm_mul_pd(u[0], u[0]), _mm_mul_pd(u[1], u[1])),
            _mm_mul_pd(u[2], u[2]),
        );
        for (i, fi) in f.iter_mut().enumerate() {
            let v = _mm_loadu_pd(fi.as_ptr());
            let eu = _mm_add_pd(
                _mm_add_pd(
                    _mm_mul_pd(_mm_set1_pd(E[i][0] as f64), u[0]),
                    _mm_mul_pd(_mm_set1_pd(E[i][1] as f64), u[1]),
                ),
                _mm_mul_pd(_mm_set1_pd(E[i][2] as f64), u[2]),
            );
            let inner = _mm_sub_pd(
                _mm_add_pd(
                    _mm_add_pd(_mm_set1_pd(1.0), _mm_mul_pd(_mm_set1_pd(3.0), eu)),
                    _mm_mul_pd(_mm_mul_pd(_mm_set1_pd(4.5), eu), eu),
                ),
                _mm_mul_pd(_mm_set1_pd(1.5), u2),
            );
            let feq = _mm_mul_pd(_mm_mul_pd(_mm_set1_pd(W[i]), rho), inner);
            let out = _mm_add_pd(v, _mm_mul_pd(_mm_set1_pd(OMEGA), _mm_sub_pd(feq, v)));
            _mm_storeu_pd(fi.as_mut_ptr(), out);
        }
    }
}

/// [`StepKernel`] twin that routes each shard to the selected SIMD
/// slab driver (or the scalar one for [`SimdPath::Scalar`] / non-SIMD
/// builds).
struct SimdStepKernel {
    nx: usize,
    ny: usize,
    nz: usize,
    path: SimdPath,
}

impl ShardKernel2 for SimdStepKernel {
    fn run<R: CursorRead, W: CursorWrite>(&self, src: &[R], dst: &[W], s: Shard) {
        let plane = self.ny * self.nz;
        debug_assert!(s.start % plane == 0, "shard start {} splits an x-slab", s.start);
        let (x0, x1) = (s.start / plane, s.end.div_ceil(plane));
        // SAFETY (all arms): cursors were validated over the full range
        // at extraction; shards are disjoint; the vector arms only run
        // when the path was detected usable (callers sanitize `path`).
        match self.path {
            SimdPath::Scalar => unsafe {
                step_slab_cursors(src, dst, self.nx, self.ny, self.nz, x0, x1)
            },
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdPath::Avx2 => unsafe {
                step_slab_cursors_simd::<_, _, 4>(
                    src,
                    dst,
                    self.nx,
                    self.ny,
                    self.nz,
                    x0,
                    x1,
                    collide4_avx2,
                )
            },
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdPath::Sse2 => unsafe {
                step_slab_cursors_simd::<_, _, 2>(
                    src,
                    dst,
                    self.nx,
                    self.ny,
                    self.nz,
                    x0,
                    x1,
                    collide2_sse2,
                )
            },
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            SimdPath::Avx2 | SimdPath::Sse2 => unsafe {
                step_slab_cursors(src, dst, self.nx, self.ny, self.nz, x0, x1)
            },
        }
    }
}

/// [`step`] on the best available SIMD path
/// ([`crate::view::simd::detect`]). Bit-identical to [`step`]: lanes
/// replay the exact scalar operation order, and obstacle batches plus
/// z-tails run the scalar per-cell kernel.
pub fn step_simd<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    step_simd_parallel_with(src, dst, 1, detect());
}

/// [`step_parallel`] on the best available SIMD path: x-slab shards are
/// distributed over `threads` scoped workers, each running the
/// vectorized slab driver.
pub fn step_simd_parallel<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>, threads: usize)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    step_simd_parallel_with(src, dst, threads, detect());
}

/// [`step_parallel`] on an explicit [`SimdPath`] (benchmark rows pin
/// the path; tests sweep every available one). Safe for any `path`
/// value: paths that are not usable on this build/host fall back to
/// [`SimdPath::Scalar`], and generic plans (instrumented/curve layouts)
/// take the scalar accessor path regardless of `path`.
pub fn step_simd_parallel_with<MS, MD, B>(
    src: &View<MS, B>,
    dst: &mut View<MD, B>,
    threads: usize,
    path: SimdPath,
) where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut + Sync,
{
    let path = if path.is_vector() { path } else { SimdPath::Scalar };
    let d = src.mapping().dims().extents();
    let (nx, ny, nz) = (d[0], d[1], d[2]);
    let threads = threads.max(1).min(nx.max(1));
    if par_execute_zip(src, dst, threads, ny * nz, &SimdStepKernel { nx, ny, nz, path }) {
        return;
    }
    step_parallel(src, dst, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::alloc_view;
    use crate::workloads::lbm::cell_dim;

    fn small_geo() -> Geometry {
        Geometry::channel_with_sphere(8, 8, 8, 1)
    }

    #[test]
    fn mass_is_conserved() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(AoS::aligned(&d, geo.dims.clone()));
        let mut b = alloc_view(AoS::aligned(&d, geo.dims.clone()));
        init(&mut a, &geo);
        init(&mut b, &geo);
        let m0 = total_mass(&a);
        for _ in 0..4 {
            step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        let m1 = total_mass(&a);
        assert!((m0 - m1).abs() / m0 < 1e-9, "mass drift {m0} -> {m1}");
    }

    #[test]
    fn layouts_produce_identical_fields() {
        let geo = small_geo();
        let d = cell_dim();
        fn run<M: Mapping>(m0: M, m1: M, geo: &Geometry) -> Vec<f64> {
            let mut a = alloc_view(m0);
            let mut b = alloc_view(m1);
            init(&mut a, geo);
            init(&mut b, geo);
            for _ in 0..3 {
                step(&a, &mut b);
                std::mem::swap(&mut a, &mut b);
            }
            (0..a.count()).map(|lin| a.get::<f64>(lin, 0)).collect()
        }
        let aos = run(
            AoS::aligned(&d, geo.dims.clone()),
            AoS::aligned(&d, geo.dims.clone()),
            &geo,
        );
        let soa = run(
            SoA::multi_blob(&d, geo.dims.clone()),
            SoA::multi_blob(&d, geo.dims.clone()),
            &geo,
        );
        let aosoa = run(
            AoSoA::new(&d, geo.dims.clone(), 8),
            AoSoA::new(&d, geo.dims.clone(), 8),
            &geo,
        );
        assert_eq!(aos, soa);
        assert_eq!(aos, aosoa);
    }

    #[test]
    fn parallel_matches_serial() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b1 = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b4 = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        init(&mut a, &geo);
        step(&a, &mut b1);
        step_parallel(&a, &mut b4, 4);
        assert_eq!(b1.blobs(), b4.blobs());
    }

    #[test]
    fn parallel_matches_serial_on_piecewise_plans() {
        // AoSoA dst: shard boundaries must respect both the x-slab
        // granularity and the destination's lane blocks.
        let geo = small_geo();
        let d = cell_dim();
        for lanes in [8usize, 32, 256] {
            let mut a = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            let mut b1 = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            let mut bn = alloc_view(AoSoA::new(&d, geo.dims.clone(), lanes));
            init(&mut a, &geo);
            step(&a, &mut b1);
            step_parallel(&a, &mut bn, 3);
            assert_eq!(b1.blobs(), bn.blobs(), "lanes {lanes}");
        }
    }

    #[test]
    fn plane_restricted_steps_compose_to_the_whole_step() {
        // step_planes over a tiling of 0..nx must be bit-identical to
        // one whole-lattice step — the invariant the split-phase halo
        // schedule (boundary planes first, interior later) rests on.
        let geo = small_geo();
        let d = cell_dim();
        fn check<M: Mapping>(make: impl Fn() -> M, geo: &Geometry, name: &str) {
            let mut a = alloc_view(make());
            init(&mut a, geo);
            let mut whole = alloc_view(make());
            step(&a, &mut whole);
            for cuts in [vec![0usize, 8], vec![0, 1, 7, 8], vec![0, 3, 3, 5, 8]] {
                let mut tiled = alloc_view(make());
                for w in cuts.windows(2) {
                    step_planes(&a, &mut tiled, w[0], w[1]);
                }
                assert_eq!(whole.blobs(), tiled.blobs(), "{name}: cuts {cuts:?}");
            }
        }
        check(|| AoS::packed(&d, geo.dims.clone()), &geo, "AoS packed");
        check(|| SoA::multi_blob(&d, geo.dims.clone()), &geo, "SoA MB");
        check(|| AoSoA::new(&d, geo.dims.clone(), 8), &geo, "AoSoA-8");
    }

    #[test]
    fn obstacles_are_inert_and_fluid_mass_stays() {
        let geo = small_geo();
        let d = cell_dim();
        let mut a = alloc_view(AoS::packed(&d, geo.dims.clone()));
        let mut b = alloc_view(AoS::packed(&d, geo.dims.clone()));
        init(&mut a, &geo);
        step(&a, &mut b);
        let lin = geo.obstacle.iter().position(|&o| o).expect("has obstacle");
        for i in 0..Q {
            assert_eq!(b.get::<f64>(lin, i), a.get::<f64>(lin, i));
        }
        assert_eq!(b.get::<f64>(lin, FLAGS), OBSTACLE);
    }

    #[test]
    fn wall_neighbour_pulls_reflection() {
        // 3x1x1 grid (periodic), cell 1 is a wall: a fluid cell next to
        // the wall must take its own opposite population for the
        // blocked link.
        let dims = crate::array::ArrayDims::from([3, 1, 1]);
        let mut obstacle = vec![false; 3];
        obstacle[1] = true;
        let geo = Geometry { dims: dims.clone(), obstacle };
        let d = cell_dim();
        let mut a = alloc_view(AoS::packed(&d, dims.clone()));
        let mut b = alloc_view(AoS::packed(&d, dims));
        init(&mut a, &geo);
        // Tag cell 2's population so we can watch where it goes.
        a.set::<f64>(2, 1, 0.7); // direction +x of cell 2
        let m0 = total_mass(&a) - {
            // exclude the inert wall cell's mass from the comparison
            (0..Q).map(|i| a.get::<f64>(1, i)).sum::<f64>()
        };
        step(&a, &mut b);
        let m1 = total_mass(&b) - (0..Q).map(|i| b.get::<f64>(1, i)).sum::<f64>();
        assert!((m0 - m1).abs() < 1e-12, "fluid mass {m0} -> {m1}");
    }

    #[test]
    fn simd_paths_are_bit_identical_to_scalar() {
        // nz = 6: AVX2 runs 4-cell batches plus a 2-cell z-tail, SSE2
        // divides evenly; the sphere puts obstacle cells in some
        // batches, exercising the per-cell fallback inside a batch.
        let geo = Geometry::channel_with_sphere(6, 5, 6, 3);
        let d = cell_dim();
        fn check<M: Mapping>(make: impl Fn() -> M, geo: &Geometry, name: &str) {
            let mut a = alloc_view(make());
            let mut b = alloc_view(make());
            init(&mut a, geo);
            init(&mut b, geo);
            for _ in 0..3 {
                step(&a, &mut b);
                std::mem::swap(&mut a, &mut b);
            }
            for path in crate::view::simd::available_paths() {
                for threads in [1usize, 3] {
                    let mut sa = alloc_view(make());
                    let mut sb = alloc_view(make());
                    init(&mut sa, geo);
                    init(&mut sb, geo);
                    for _ in 0..3 {
                        step_simd_parallel_with(&sa, &mut sb, threads, path);
                        std::mem::swap(&mut sa, &mut sb);
                    }
                    assert_eq!(
                        a.blobs(),
                        sa.blobs(),
                        "{name}: path {path:?} x {threads} threads differs from scalar"
                    );
                }
            }
        }
        check(|| AoS::packed(&d, geo.dims.clone()), &geo, "AoS packed");
        check(|| SoA::multi_blob(&d, geo.dims.clone()), &geo, "SoA MB");
        check(|| AoSoA::new(&d, geo.dims.clone(), 8), &geo, "AoSoA-8");
    }

    #[test]
    fn flow_develops_along_x() {
        let geo = Geometry {
            dims: crate::array::ArrayDims::from([6, 6, 6]),
            obstacle: vec![false; 216],
        };
        let d = cell_dim();
        let mut a = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        let mut b = alloc_view(SoA::multi_blob(&d, geo.dims.clone()));
        init(&mut a, &geo);
        for _ in 0..10 {
            step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        let (_, u) = macroscopic(&a, 0);
        assert!(u[0] > 0.0, "driven flow should move +x, got {u:?}");
    }
}
