//! X-slab domain decomposition and halo exchange for the D3Q19 lattice
//! (wire phase 2): the lattice is partitioned into contiguous x-slabs,
//! each worker holds its slab plus one ghost plane per side, and every
//! step exchanges one-plane-deep boundary manifests before running the
//! unmodified [`super::step::step`] kernel over the whole local view.
//!
//! The linearization is `lin = (x*ny + y)*nz + z`, so an x-plane is one
//! *contiguous* record range — exactly what range-restricted
//! serialization ([`crate::copy::serialize_range`]) ships without a
//! gather. Interior cells read neighbours at most one plane away, so
//! after the exchange the stepped interior is **bit-identical** to the
//! single-process kernel: decomposition changes scheduling and
//! transport, never arithmetic. The ghost planes themselves are stepped
//! with locally-wrapped (wrong) neighbours, but their post-step values
//! are dead — the next exchange overwrites them before anything reads
//! them.
//!
//! Wire phase 3 splits the step so communication hides behind
//! computation: [`step_boundary`] computes only the two faces the
//! neighbours need, their step-tagged messages go out immediately, and
//! [`step_interior`] computes everything else while next-step ghosts
//! arrive into a double-buffered [`GhostArena`]. The schedule stays
//! bit-identical to the blocking ring because the boundary planes of
//! state `k+1` are exactly the planes a blocking ring would serialize
//! at the *start* of its round `k+1`, and the interior planes never
//! read ghosts at all (`lin = (x*ny+y)*nz + z` keeps each an x-plane
//! away from the ghost planes).
//!
//! This module is the in-process half: partition arithmetic, local
//! extraction, boundary messages, and [`run_in_process`] /
//! [`run_in_process_overlapped`] — the differential twins the
//! multi-process TCP runner (`coordinator::halo`) is verified against.

use super::step::{init, step, step_planes};
use super::{cell_dim, Geometry};
use crate::array::ArrayDims;
use crate::blob::{Blob, BlobMut};
use crate::copy::{deserialize_range_into_at, serialize_range, CopyProgram, WireMessage};
use crate::error::Result;
use crate::mapping::{DynMapping, Mapping, WireRecipe};
use crate::view::{alloc_view, View};
use crate::{bail, ensure};

/// Split `nx` planes into exactly `workers` contiguous x-slabs
/// `(x0, x1)`, each at least one plane thick (balanced: the first
/// `nx % workers` slabs get the extra plane).
pub fn partition_x(nx: usize, workers: usize) -> Result<Vec<(usize, usize)>> {
    ensure!(workers >= 1, "halo decomposition needs at least one worker");
    ensure!(
        workers <= nx,
        "cannot split {nx} x-planes across {workers} workers (each needs one)"
    );
    let base = nx / workers;
    let rem = nx % workers;
    let mut out = Vec::with_capacity(workers);
    let mut x0 = 0;
    for i in 0..workers {
        let w = base + usize::from(i < rem);
        out.push((x0, x0 + w));
        x0 += w;
    }
    Ok(out)
}

/// The contiguous record range of x-plane `x`:
/// `[x*ny*nz, (x+1)*ny*nz)`.
pub fn plane_records(ny: usize, nz: usize, x: usize) -> (usize, usize) {
    (x * ny * nz, (x + 1) * ny * nz)
}

/// Local lattice extents for slab `x0..x1`: the interior planes plus
/// one ghost plane on each side.
pub fn local_dims(x0: usize, x1: usize, ny: usize, nz: usize) -> ArrayDims {
    ArrayDims::from([x1 - x0 + 2, ny, nz])
}

/// Compiled-slice copy of `len` records from `src_start` of the global
/// view to `dst_start` of the local one (the two views only share the
/// cell record dimension; their extents differ by design).
fn slice_copy<MG, BG, ML, BL>(
    global: &View<MG, BG>,
    local: &mut View<ML, BL>,
    src_start: usize,
    dst_start: usize,
    len: usize,
) where
    MG: Mapping,
    BG: Blob,
    ML: Mapping,
    BL: BlobMut,
{
    CopyProgram::compile_slice(global.mapping(), local.mapping(), src_start, dst_start, len)
        .execute(global, local);
}

/// Fill a worker's local lattice from the global one: interior planes
/// from `x0..x1`, ghost planes from the periodic wrap — after this the
/// local view is ready for its first step with no exchange.
pub fn extract_local<MG, BG, ML, BL>(
    global: &View<MG, BG>,
    local: &mut View<ML, BL>,
    x0: usize,
    x1: usize,
) where
    MG: Mapping,
    BG: Blob,
    ML: Mapping,
    BL: BlobMut,
{
    let g = global.mapping().dims().extents();
    let (nx, ny, nz) = (g[0], g[1], g[2]);
    let plane = ny * nz;
    let local_nx = x1 - x0;
    assert_eq!(
        local.mapping().dims(),
        &local_dims(x0, x1, ny, nz),
        "local lattice extents do not match slab {x0}..{x1}"
    );
    slice_copy(global, local, x0 * plane, plane, local_nx * plane);
    let left = (x0 + nx - 1) % nx;
    let right = x1 % nx;
    slice_copy(global, local, left * plane, 0, plane);
    slice_copy(global, local, right * plane, (local_nx + 1) * plane, plane);
}

/// The two boundary manifests a worker sends each step:
/// `(first, last)` — its first and last *interior* planes,
/// range-serialized from the local view. The `range=` token names
/// local record coordinates; receivers land the slab on their own
/// ghost planes by explicit offset
/// ([`crate::copy::deserialize_range_into_at`]).
pub fn boundary_messages<M, B>(local: &View<M, B>) -> Result<(WireMessage, WireMessage)>
where
    M: Mapping,
    B: Blob,
{
    let e = local.mapping().dims().extents();
    let (local_nx, ny, nz) = (e[0] - 2, e[1], e[2]);
    let plane = ny * nz;
    let first = serialize_range(local, plane, 2 * plane)?;
    let last = serialize_range(local, local_nx * plane, (local_nx + 1) * plane)?;
    Ok((first, last))
}

/// [`boundary_messages`] with both manifests tagged `step=` for a
/// multiplexed peer link: frames for different rounds share one
/// connection and the receiver dispatches them by tag whatever order
/// they arrive in.
pub fn boundary_messages_tagged<M, B>(
    local: &View<M, B>,
    step: usize,
) -> Result<(WireMessage, WireMessage)>
where
    M: Mapping,
    B: Blob,
{
    let (mut first, mut last) = boundary_messages(local)?;
    first.manifest.step = Some(step);
    last.manifest.step = Some(step);
    Ok((first, last))
}

/// Phase 1 of the split-phase schedule: step only the two boundary
/// planes (local planes `1` and `local_nx`) of the next state — the
/// one-plane-deep faces the neighbours need — so their messages can be
/// on the wire while [`step_interior`] runs. Reads the current ghost
/// planes exactly like the whole-lattice [`step`] would.
pub fn step_boundary<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut,
{
    let local_nx = src.mapping().dims().extents()[0] - 2;
    step_planes(src, dst, 1, 2);
    if local_nx > 1 {
        step_planes(src, dst, local_nx, local_nx + 1);
    }
}

/// Phase 2 of the split-phase schedule: step the interior planes
/// `2..local_nx` — every plane [`step_boundary`] did not already
/// compute. These planes pull from planes `1..=local_nx` only, never
/// from a ghost plane, which is why this phase can run while next-step
/// ghosts are still in flight. (The ghost planes themselves are not
/// stepped at all: their post-step values are dead in the blocking
/// schedule too, overwritten by the next exchange before any read.)
pub fn step_interior<MS, MD, B>(src: &View<MS, B>, dst: &mut View<MD, B>)
where
    MS: Mapping,
    MD: Mapping,
    B: BlobMut,
{
    let local_nx = src.mapping().dims().extents()[0] - 2;
    if local_nx > 1 {
        step_planes(src, dst, 2, local_nx);
    }
}

/// Record offset of a ghost plane in a local lattice: `Left` is plane
/// 0, `Right` is plane `local_nx + 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GhostSide {
    Left,
    Right,
}

impl GhostSide {
    fn index(self) -> usize {
        match self {
            GhostSide::Left => 0,
            GhostSide::Right => 1,
        }
    }
}

/// Double-buffered landing slots for in-flight ghost planes: one slot
/// per `(side, step parity)`. The ownership rule: a parity slot is
/// writable only while it is empty — i.e. the ghost two rounds back
/// must have been consumed — and [`GhostArena::deposit`] refuses to
/// overwrite an unconsumed ghost instead of corrupting a round.
///
/// Two slots per side are enough because the schedule's data
/// dependency bounds how far a peer can run ahead: a worker sends its
/// step `k+2` boundary only after landing step `k+1` ghosts, which its
/// neighbour sent only after landing step `k` ghosts — so at most the
/// frames for steps `k+1` and `k+2` (opposite parity) can coexist
/// unconsumed on one side.
#[derive(Debug, Default)]
pub struct GhostArena {
    slots: [[Option<(usize, WireMessage)>; 2]; 2],
}

impl GhostArena {
    /// Park an arrived ghost message for `step`. Errors if the parity
    /// slot still holds an unconsumed ghost (a protocol violation —
    /// the peer ran more than one round ahead, or a tag was wrong).
    pub fn deposit(&mut self, side: GhostSide, step: usize, msg: WireMessage) -> Result<()> {
        let slot = &mut self.slots[side.index()][step % 2];
        if let Some((held, _)) = slot {
            bail!(
                "ghost arena {side:?} slot still holds step {held}: \
                 depositing step {step} would overwrite an unconsumed ghost"
            );
        }
        *slot = Some((step, msg));
        Ok(())
    }

    /// Take the ghost message for `step`, freeing its slot for the
    /// round after next. Errors if the slot is empty or holds a
    /// different step.
    pub fn take(&mut self, side: GhostSide, step: usize) -> Result<WireMessage> {
        let slot = &mut self.slots[side.index()][step % 2];
        match slot {
            Some((held, _)) if *held == step => Ok(slot.take().expect("matched above").1),
            Some((held, _)) => bail!("ghost arena {side:?} holds step {held}, wanted {step}"),
            None => bail!("ghost arena {side:?} has no step {step} ghost"),
        }
    }
}

/// Land a neighbour's boundary-plane message on this worker's ghost
/// plane.
pub fn receive_ghost<M, B>(local: &mut View<M, B>, msg: &WireMessage, side: GhostSide) -> Result<()>
where
    M: Mapping,
    B: BlobMut,
{
    let e = local.mapping().dims().extents();
    let (local_nx, ny, nz) = (e[0] - 2, e[1], e[2]);
    let plane = ny * nz;
    ensure!(
        msg.manifest.payload_records() == plane,
        "ghost message carries {} records, a plane is {plane}",
        msg.manifest.payload_records()
    );
    let at = match side {
        GhostSide::Left => 0,
        GhostSide::Right => (local_nx + 1) * plane,
    };
    deserialize_range_into_at(msg, local, at)?;
    Ok(())
}

/// One worker's slab bounds and ping-pong local lattice pair.
pub struct LocalLattice {
    pub x0: usize,
    pub x1: usize,
    pub src: View<DynMapping, Vec<u8>>,
    pub dst: View<DynMapping, Vec<u8>>,
}

/// Partition the initialized `global` lattice into `workers` local
/// lattices (packed-AoS storage, the wire recipe's layout).
pub fn split_lattice<M, B>(global: &View<M, B>, workers: usize) -> Result<Vec<LocalLattice>>
where
    M: Mapping,
    B: Blob,
{
    let g = global.mapping().dims().extents();
    let (nx, ny, nz) = (g[0], g[1], g[2]);
    let d = cell_dim();
    partition_x(nx, workers)?
        .into_iter()
        .map(|(x0, x1)| {
            let mut src = alloc_view(WireRecipe::AosPacked.build(&d, local_dims(x0, x1, ny, nz)));
            extract_local(global, &mut src, x0, x1);
            let dst = alloc_view(WireRecipe::AosPacked.build(&d, local_dims(x0, x1, ny, nz)));
            Ok(LocalLattice { x0, x1, src, dst })
        })
        .collect()
}

/// One in-process exchange round: every worker's boundary planes are
/// snapshotted into wire messages first, then landed on the neighbours'
/// ghost planes (left neighbour's *last* plane → my left ghost, right
/// neighbour's *first* plane → my right ghost, indices wrapping
/// periodically).
pub fn exchange_ghosts(locals: &mut [LocalLattice]) -> Result<()> {
    let n = locals.len();
    let msgs: Vec<(WireMessage, WireMessage)> =
        locals.iter().map(|w| boundary_messages(&w.src)).collect::<Result<_>>()?;
    for i in 0..n {
        let left = (i + n - 1) % n;
        let right = (i + 1) % n;
        receive_ghost(&mut locals[i].src, &msgs[left].1, GhostSide::Left)?;
        receive_ghost(&mut locals[i].src, &msgs[right].0, GhostSide::Right)?;
    }
    Ok(())
}

/// Serialize a local lattice's interior (planes `1..=local_nx`, one
/// contiguous record range) — the reassembly payload sent to the parent
/// after the final step.
pub fn interior_message<M, B>(local: &View<M, B>) -> Result<WireMessage>
where
    M: Mapping,
    B: Blob,
{
    let e = local.mapping().dims().extents();
    let plane = e[1] * e[2];
    serialize_range(local, plane, (e[0] - 1) * plane)
}

/// Land a worker interior at its global x-offset.
pub fn place_interior<M, B>(global: &mut View<M, B>, msg: &WireMessage, x0: usize) -> Result<()>
where
    M: Mapping,
    B: BlobMut,
{
    let g = global.mapping().dims().extents();
    deserialize_range_into_at(msg, global, x0 * g[1] * g[2])?;
    Ok(())
}

/// One round of the split-phase schedule across all in-process
/// workers, advancing state `k` to state `k+1` (`k = step_no`):
/// boundary planes first, their step-tagged messages deposited into
/// the neighbours' arenas (the in-process stand-in for frames in
/// flight on a peer link), then the interior — the phase the
/// distributed runner overlaps with the wire — then the buffer flip
/// and the ghost landing. Bit-identical to [`exchange_ghosts`] +
/// [`step`]: the boundary planes of state `k+1` are exactly what a
/// blocking ring serializes at the start of its round `k+1`, and the
/// interior never reads ghost planes.
pub fn overlapped_step(
    locals: &mut [LocalLattice],
    arenas: &mut [GhostArena],
    step_no: usize,
) -> Result<()> {
    ensure!(
        locals.len() == arenas.len(),
        "{} workers but {} ghost arenas",
        locals.len(),
        arenas.len()
    );
    let n = locals.len();
    for w in locals.iter_mut() {
        step_boundary(&w.src, &mut w.dst);
    }
    let msgs: Vec<(WireMessage, WireMessage)> = locals
        .iter()
        .map(|w| boundary_messages_tagged(&w.dst, step_no + 1))
        .collect::<Result<_>>()?;
    for (i, arena) in arenas.iter_mut().enumerate() {
        let left = (i + n - 1) % n;
        let right = (i + 1) % n;
        arena.deposit(GhostSide::Left, step_no + 1, msgs[left].1.clone())?;
        arena.deposit(GhostSide::Right, step_no + 1, msgs[right].0.clone())?;
    }
    for w in locals.iter_mut() {
        step_interior(&w.src, &mut w.dst);
    }
    for (w, arena) in locals.iter_mut().zip(arenas.iter_mut()) {
        std::mem::swap(&mut w.src, &mut w.dst);
        let l = arena.take(GhostSide::Left, step_no + 1)?;
        let r = arena.take(GhostSide::Right, step_no + 1)?;
        receive_ghost(&mut w.src, &l, GhostSide::Left)?;
        receive_ghost(&mut w.src, &r, GhostSide::Right)?;
    }
    Ok(())
}

/// [`run_in_process`] on the split-phase schedule: `steps` rounds of
/// [`overlapped_step`], interiors reassembled into the returned global
/// view. The sequential in-process twin of the overlapped distributed
/// runner, and the third leg of the differential oracle — it must be
/// bit-identical to both [`run_in_process`] and the undecomposed
/// kernel.
pub fn run_in_process_overlapped(
    geo: &Geometry,
    workers: usize,
    steps: usize,
) -> Result<View<DynMapping, Vec<u8>>> {
    let d = cell_dim();
    let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut global, geo);
    let mut locals = split_lattice(&global, workers)?;
    let mut arenas: Vec<GhostArena> = locals.iter().map(|_| GhostArena::default()).collect();
    for k in 0..steps {
        overlapped_step(&mut locals, &mut arenas, k)?;
    }
    for w in &locals {
        place_interior(&mut global, &interior_message(&w.src)?, w.x0)?;
    }
    Ok(global)
}

/// Run `steps` of the decomposed lattice fully in-process: `workers`
/// local lattices in one address space, ghosts exchanged through real
/// [`WireMessage`]s before every step, interiors reassembled into the
/// returned global view. Bit-identical to `steps` ping-pong calls of
/// [`step`] on the undecomposed lattice — the differential oracle the
/// multi-process TCP runner is tested against.
pub fn run_in_process(
    geo: &Geometry,
    workers: usize,
    steps: usize,
) -> Result<View<DynMapping, Vec<u8>>> {
    let d = cell_dim();
    let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
    init(&mut global, geo);
    let mut locals = split_lattice(&global, workers)?;
    for _ in 0..steps {
        exchange_ghosts(&mut locals)?;
        for w in &mut locals {
            step(&w.src, &mut w.dst);
            std::mem::swap(&mut w.src, &mut w.dst);
        }
    }
    for w in &locals {
        place_interior(&mut global, &interior_message(&w.src)?, w.x0)?;
    }
    Ok(global)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn global_oracle(geo: &Geometry, steps: usize) -> View<DynMapping, Vec<u8>> {
        let d = cell_dim();
        let mut a = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        let mut b = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut a, geo);
        init(&mut b, geo);
        for _ in 0..steps {
            step(&a, &mut b);
            std::mem::swap(&mut a, &mut b);
        }
        a
    }

    #[test]
    fn partition_is_balanced_and_covers() {
        for nx in [1usize, 2, 3, 7, 8, 16] {
            for workers in 1..=nx.min(5) {
                let slabs = partition_x(nx, workers).unwrap();
                assert_eq!(slabs.len(), workers, "nx={nx} workers={workers}");
                assert_eq!(slabs[0].0, 0);
                assert_eq!(slabs.last().unwrap().1, nx);
                for w in slabs.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap in {slabs:?}");
                }
                let widths: Vec<usize> = slabs.iter().map(|(a, b)| b - a).collect();
                let (min, max) =
                    (widths.iter().min().unwrap(), widths.iter().max().unwrap());
                assert!(*min >= 1 && max - min <= 1, "unbalanced {widths:?}");
            }
        }
        assert!(partition_x(3, 4).is_err());
        assert!(partition_x(3, 0).is_err());
    }

    #[test]
    fn extract_local_wraps_the_ghost_planes() {
        let geo = Geometry::channel_with_sphere(6, 4, 4, 9);
        let d = cell_dim();
        let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut global, &geo);
        // Slab 0..2 of nx=6: left ghost wraps to plane 5, right to 2.
        let mut local = alloc_view(WireRecipe::AosPacked.build(&d, local_dims(0, 2, 4, 4)));
        extract_local(&global, &mut local, 0, 2);
        let plane = 16;
        for p in 0..plane {
            for leaf in 0..super::super::LEAVES {
                assert_eq!(
                    local.get::<f64>(p, leaf),
                    global.get::<f64>(5 * plane + p, leaf),
                    "left ghost p={p} leaf={leaf}"
                );
                assert_eq!(
                    local.get::<f64>(3 * plane + p, leaf),
                    global.get::<f64>(2 * plane + p, leaf),
                    "right ghost p={p} leaf={leaf}"
                );
                assert_eq!(local.get::<f64>(plane + p, leaf), global.get::<f64>(p, leaf));
            }
        }
    }

    #[test]
    fn decomposed_steps_are_bit_identical_to_the_global_kernel() {
        // Obstacle geometry included: the sphere intersects slab
        // boundaries, so bounce-back links cross the halo.
        let geo = Geometry::channel_with_sphere(8, 6, 6, 5);
        let oracle = global_oracle(&geo, 3);
        for workers in [1usize, 2, 3] {
            let got = run_in_process(&geo, workers, 3).unwrap();
            assert_eq!(
                got.blobs(),
                oracle.blobs(),
                "{workers}-worker halo exchange diverged from the global step"
            );
        }
    }

    #[test]
    fn zero_steps_reassembles_the_initial_state() {
        let geo = Geometry::channel_with_sphere(4, 4, 4, 2);
        let d = cell_dim();
        let mut init_view = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut init_view, &geo);
        let got = run_in_process(&geo, 2, 0).unwrap();
        assert_eq!(got.blobs(), init_view.blobs());
    }

    #[test]
    fn overlapped_schedule_is_bit_identical_to_blocking_and_the_oracle() {
        // The split-phase twin against both the blocking in-process
        // ring and the undecomposed kernel — obstacles included, slab
        // widths down to one plane (workers=3 on nx=8 gives 3/3/2;
        // also run nx=4 with 3 workers for a 2/1/1 split where a slab's
        // boundary planes coincide and the interior phase is empty).
        for (geo, max_workers) in [
            (Geometry::channel_with_sphere(8, 6, 6, 5), 3usize),
            (Geometry::channel_with_sphere(4, 4, 4, 2), 3),
        ] {
            for steps in [1usize, 4] {
                let oracle = global_oracle(&geo, steps);
                for workers in 1..=max_workers {
                    let blocking = run_in_process(&geo, workers, steps).unwrap();
                    let overlapped = run_in_process_overlapped(&geo, workers, steps).unwrap();
                    assert_eq!(
                        overlapped.blobs(),
                        blocking.blobs(),
                        "{workers}-worker overlapped schedule diverged from blocking \
                         ({steps} steps)"
                    );
                    assert_eq!(
                        overlapped.blobs(),
                        oracle.blobs(),
                        "{workers}-worker overlapped schedule diverged from the \
                         global kernel ({steps} steps)"
                    );
                }
            }
        }
    }

    #[test]
    fn overlapped_zero_steps_reassembles_the_initial_state() {
        let geo = Geometry::channel_with_sphere(4, 4, 4, 2);
        let d = cell_dim();
        let mut init_view = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut init_view, &geo);
        let got = run_in_process_overlapped(&geo, 2, 0).unwrap();
        assert_eq!(got.blobs(), init_view.blobs());
    }

    #[test]
    fn split_phase_kernels_tile_exactly_one_whole_step() {
        // boundary + interior must together write exactly the planes a
        // whole-lattice step writes to the interior (ghost planes are
        // skipped — their post-step values are dead either way).
        let geo = Geometry::channel_with_sphere(6, 4, 4, 9);
        let d = cell_dim();
        let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut global, &geo);
        for workers in [1usize, 2, 3] {
            for w in split_lattice(&global, workers).unwrap() {
                let e = w.src.mapping().dims().extents();
                let (local_nx, plane) = (e[0] - 2, e[1] * e[2]);
                let local_m = || WireRecipe::AosPacked.build(&d, local_dims(w.x0, w.x1, 4, 4));
                let mut whole = alloc_view(local_m());
                step(&w.src, &mut whole);
                let mut split = alloc_view(local_m());
                step_boundary(&w.src, &mut split);
                step_interior(&w.src, &mut split);
                // Compare the interior planes 1..=local_nx field-wise.
                for lin in plane..(local_nx + 1) * plane {
                    for leaf in 0..super::super::LEAVES {
                        assert_eq!(
                            split.get::<f64>(lin, leaf),
                            whole.get::<f64>(lin, leaf),
                            "workers={workers} slab {}..{} lin={lin} leaf={leaf}",
                            w.x0,
                            w.x1
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ghost_arena_enforces_the_double_buffer_ownership_rule() {
        let geo = Geometry::channel_with_sphere(4, 4, 4, 1);
        let d = cell_dim();
        let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut global, &geo);
        let locals = split_lattice(&global, 2).unwrap();
        let (first, last) = boundary_messages_tagged(&locals[0].src, 1).unwrap();
        assert_eq!(first.manifest.step, Some(1));
        assert_eq!(last.manifest.step, Some(1));

        let mut arena = GhostArena::default();
        arena.deposit(GhostSide::Left, 1, first.clone()).unwrap();
        // Opposite parity may land while step 1 is unconsumed (a peer
        // running one round ahead)...
        arena.deposit(GhostSide::Left, 2, first.clone()).unwrap();
        // ...but same parity may not: step 3 would overwrite step 1.
        assert!(arena.deposit(GhostSide::Left, 3, first.clone()).is_err());
        // The other side is independent.
        arena.deposit(GhostSide::Right, 1, last.clone()).unwrap();
        // Takes must name the held step exactly.
        assert!(arena.take(GhostSide::Left, 3).is_err());
        assert!(arena.take(GhostSide::Right, 2).is_err());
        let got = arena.take(GhostSide::Left, 1).unwrap();
        assert_eq!(got, first);
        // Consuming step 1 frees its parity slot for step 3.
        arena.deposit(GhostSide::Left, 3, first).unwrap();
        // An empty slot cannot be taken twice.
        assert!(arena.take(GhostSide::Right, 1).is_ok());
        assert!(arena.take(GhostSide::Right, 1).is_err());
    }

    #[test]
    fn boundary_messages_carry_one_plane_each() {
        let geo = Geometry::channel_with_sphere(6, 4, 4, 1);
        let d = cell_dim();
        let mut global = alloc_view(WireRecipe::AosPacked.build(&d, geo.dims.clone()));
        init(&mut global, &geo);
        let locals = split_lattice(&global, 2).unwrap();
        let (first, last) = boundary_messages(&locals[0].src).unwrap();
        let plane = 16;
        assert_eq!(first.manifest.payload_records(), plane);
        assert_eq!(last.manifest.payload_records(), plane);
        assert_eq!(first.manifest.range, Some((plane, 2 * plane)));
        // A wrong-sized message is refused before landing.
        let bogus = serialize_range(&locals[0].src, 0, 2 * plane).unwrap();
        let mut l = split_lattice(&global, 2).unwrap().remove(0);
        assert!(receive_ghost(&mut l.src, &bogus, GhostSide::Left).is_err());
    }
}
