//! D3Q19 Lattice-Boltzmann — the stand-in for SPEC CPU® 2017
//! 619.lbm_s (paper §4.3, fig 8).
//!
//! Substitution note (DESIGN.md): SPEC's source is proprietary, but the
//! benchmark's only property the paper exercises is its *central data
//! structure* — a 3D array of 20 doubles (19 D3Q19 distribution values
//! + one used as a flag bitset) — swept by a stream-collide kernel that
//! touches all 20 fields with neighbour offsets. This module implements
//! exactly that: BGK collision, pull-scheme streaming, bounce-back
//! obstacles, periodic boundaries.
//!
//! The record dimension is `{ f: [f64; 19], flags: f64 }` and the whole
//! solver is layout-generic: fig 8's AoS / Split / SoA / AoSoA rows all
//! run this one kernel over different mappings.

pub mod halo;
pub mod split4;
pub mod step;

use crate::array::ArrayDims;
use crate::record::RecordDim;
use crate::workloads::rng::SplitMix64;

/// Flat leaf index of distribution `i` (0..19).
pub const F0: usize = 0;
/// Flat leaf index of the flags field.
pub const FLAGS: usize = 19;
pub const LEAVES: usize = 20;
/// Number of D3Q19 discrete velocities.
pub const Q: usize = 19;

/// Cell flags (stored in a f64, like SPEC lbm's 20th double).
pub const FLUID: f64 = 0.0;
pub const OBSTACLE: f64 = 1.0;

/// BGK relaxation parameter (0 < omega < 2).
pub const OMEGA: f64 = 1.2;

/// D3Q19 velocity set: rest + 6 axis + 12 diagonal directions.
pub const E: [[i32; 3]; Q] = [
    [0, 0, 0],
    [1, 0, 0],
    [-1, 0, 0],
    [0, 1, 0],
    [0, -1, 0],
    [0, 0, 1],
    [0, 0, -1],
    [1, 1, 0],
    [-1, -1, 0],
    [1, -1, 0],
    [-1, 1, 0],
    [1, 0, 1],
    [-1, 0, -1],
    [1, 0, -1],
    [-1, 0, 1],
    [0, 1, 1],
    [0, -1, -1],
    [0, 1, -1],
    [0, -1, 1],
];

/// D3Q19 lattice weights.
pub const W: [f64; Q] = [
    1.0 / 3.0,
    1.0 / 18.0,
    1.0 / 18.0,
    1.0 / 18.0,
    1.0 / 18.0,
    1.0 / 18.0,
    1.0 / 18.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
];

/// Index of the opposite direction of `i` (for bounce-back).
pub const OPP: [usize; Q] = [0, 2, 1, 4, 3, 6, 5, 8, 7, 10, 9, 12, 11, 14, 13, 16, 15, 18, 17];

/// The 20-double cell record of 619.lbm_s.
pub fn cell_dim() -> RecordDim {
    crate::record_dim! {
        f: [f64; 19],
        flags: f64,
    }
}

/// Simulation geometry: grid extents and obstacle mask.
#[derive(Debug, Clone)]
pub struct Geometry {
    pub dims: ArrayDims,
    /// Row-major obstacle mask, one bool per cell.
    pub obstacle: Vec<bool>,
}

impl Geometry {
    /// Procedural obstacle field standing in for SPEC's obstacle file:
    /// a centered sphere plus a few random blockages (deterministic).
    pub fn channel_with_sphere(nx: usize, ny: usize, nz: usize, seed: u64) -> Self {
        let dims = ArrayDims::from([nx, ny, nz]);
        let mut obstacle = vec![false; dims.count()];
        let (cx, cy, cz) = (nx as f64 / 2.0, ny as f64 / 2.0, nz as f64 / 2.0);
        let r = (nx.min(ny).min(nz) as f64) / 5.0;
        let mut rng = SplitMix64::new(seed);
        let mut blockers = Vec::new();
        for _ in 0..4 {
            blockers.push((
                rng.below(nx) as f64,
                rng.below(ny) as f64,
                rng.below(nz) as f64,
                r * 0.4,
            ));
        }
        for x in 0..nx {
            for y in 0..ny {
                for z in 0..nz {
                    let lin = (x * ny + y) * nz + z;
                    let d2 = (x as f64 - cx).powi(2)
                        + (y as f64 - cy).powi(2)
                        + (z as f64 - cz).powi(2);
                    let mut occ = d2 < r * r;
                    for &(bx, by, bz, br) in &blockers {
                        let b2 = (x as f64 - bx).powi(2)
                            + (y as f64 - by).powi(2)
                            + (z as f64 - bz).powi(2);
                        occ |= b2 < br * br;
                    }
                    obstacle[lin] = occ;
                }
            }
        }
        Geometry { dims, obstacle }
    }

    pub fn fluid_cells(&self) -> usize {
        self.obstacle.iter().filter(|&&o| !o).count()
    }
}

/// Equilibrium distribution for density `rho` and velocity `u`.
#[inline(always)]
pub fn equilibrium(i: usize, rho: f64, u: [f64; 3]) -> f64 {
    let eu = E[i][0] as f64 * u[0] + E[i][1] as f64 * u[1] + E[i][2] as f64 * u[2];
    let u2 = u[0] * u[0] + u[1] * u[1] + u[2] * u[2];
    W[i] * rho * (1.0 + 3.0 * eu + 4.5 * eu * eu - 1.5 * u2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn velocity_set_is_consistent() {
        // Opposites really are opposites.
        for i in 0..Q {
            for d in 0..3 {
                assert_eq!(E[i][d], -E[OPP[i]][d], "dir {i}");
            }
        }
        // Weights sum to 1.
        let sum: f64 = W.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        // First moment of the weights is zero.
        for d in 0..3 {
            let m: f64 = (0..Q).map(|i| W[i] * E[i][d] as f64).sum();
            assert!(m.abs() < 1e-12);
        }
    }

    #[test]
    fn equilibrium_recovers_moments() {
        let rho = 1.1;
        let u = [0.02, -0.01, 0.03];
        let rho_sum: f64 = (0..Q).map(|i| equilibrium(i, rho, u)).sum();
        assert!((rho_sum - rho).abs() < 1e-3, "density {rho_sum}");
        for d in 0..3 {
            let mom: f64 = (0..Q).map(|i| equilibrium(i, rho, u) * E[i][d] as f64).sum();
            assert!((mom - rho * u[d]).abs() < 1e-3, "momentum {d}: {mom}");
        }
    }

    #[test]
    fn cell_dim_matches_spec_structure() {
        let d = cell_dim();
        assert_eq!(d.leaf_count(), LEAVES);
        assert_eq!(d.packed_size(), 20 * 8);
        let info = crate::record::RecordInfo::new(&d);
        assert_eq!(info.leaf_by_path("f.0"), Some(F0));
        assert_eq!(info.leaf_by_path("flags"), Some(FLAGS));
    }

    #[test]
    fn geometry_deterministic_with_obstacles() {
        let a = Geometry::channel_with_sphere(16, 16, 16, 5);
        let b = Geometry::channel_with_sphere(16, 16, 16, 5);
        assert_eq!(a.obstacle, b.obstacle);
        let occ = a.obstacle.iter().filter(|&&o| o).count();
        assert!(occ > 0 && occ < a.dims.count());
        assert_eq!(a.fluid_cells(), a.dims.count() - occ);
    }
}
