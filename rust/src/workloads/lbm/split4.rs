//! The Trace-to-Split workflow of paper §4.3: wrap the mapping in
//! [`crate::mapping::Trace`], run the workload, group the fields into 4
//! buckets of roughly equal access count, and build a nested Split
//! mapping of 4 AoS groups — the paper's hot/cold separation that gains
//! ~8–10% over plain AoS.

use crate::array::ArrayDims;
use crate::mapping::{AoS, Split};
use crate::record::{RecordCoord, RecordDim};

/// Nested 4-way split: g0 | (g1 | (g2 | g3)), each group aligned AoS.
pub type Split4Aos = Split<AoS, Split<AoS, Split<AoS, AoS>>>;

/// Given leaf groups (disjoint, covering, in declaration order — e.g.
/// from [`crate::mapping::Trace::equal_count_groups`]), build the
/// nested Split-of-AoS mapping.
///
/// Selector bookkeeping: the Split children are *flat* record dims, so
/// after peeling off group `k`, the coordinates of the remaining leaves
/// shrink to their position among the survivors.
pub fn build_split4(dim: &RecordDim, dims: ArrayDims, groups: &[Vec<usize>]) -> Split4Aos {
    assert_eq!(groups.len(), 4, "need exactly 4 groups");
    let info = crate::record::RecordInfo::new(dim);
    let nleaves = info.leaf_count();
    let all: Vec<usize> = groups.concat();
    {
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..nleaves).collect::<Vec<_>>(), "groups must partition leaves");
    }

    // Positions of group k's leaves among leaves not in groups <k.
    let positions = |k: usize| -> Vec<RecordCoord> {
        let excluded: Vec<usize> = groups[..k].concat();
        let survivors: Vec<usize> =
            (0..nleaves).filter(|l| !excluded.contains(l)).collect();
        groups[k]
            .iter()
            .map(|l| {
                let pos = survivors.iter().position(|s| s == l).expect("leaf routed twice");
                RecordCoord::new(vec![pos])
            })
            .collect()
    };

    // Note: the top-level selectors use coordinates in the *original*
    // record tree; deeper levels use flat child coordinates.
    let sel0: Vec<RecordCoord> = groups[0].iter().map(|&l| info.fields[l].coord.clone()).collect();
    let sel1 = positions(1);
    let sel2_in_rest1: Vec<RecordCoord> = {
        let excluded: Vec<usize> = groups[..2].concat();
        let survivors1: Vec<usize> =
            (0..nleaves).filter(|l| !groups[0].contains(l)).collect();
        let survivors2: Vec<usize> =
            (0..nleaves).filter(|l| !excluded.contains(l)).collect();
        // position of each g2 leaf among survivors2... but selector is
        // evaluated in the child of split1's B side *after* removing g1,
        // i.e. among survivors2. Verify survivors relationship holds.
        let _ = survivors1;
        groups[2]
            .iter()
            .map(|l| {
                let pos = survivors2.iter().position(|s| s == l).expect("leaf routed twice");
                RecordCoord::new(vec![pos])
            })
            .collect()
    };

    Split::by_selectors(
        dim,
        dims,
        sel0,
        |d, ad| AoS::aligned(d, ad),
        move |d, ad| {
            Split::by_selectors(
                d,
                ad,
                sel1,
                |d2, ad2| AoS::aligned(d2, ad2),
                move |d2, ad2| {
                    Split::by_selectors(
                        d2,
                        ad2,
                        sel2_in_rest1,
                        |d3, ad3| AoS::aligned(d3, ad3),
                        |d3, ad3| AoS::aligned(d3, ad3),
                    )
                },
            )
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::test_support::check_mapping_invariants;
    use crate::mapping::{Mapping, Trace};
    use crate::view::alloc_view;
    use crate::workloads::lbm::{cell_dim, Geometry};

    #[test]
    fn split4_partitions_and_roundtrips() {
        let dim = cell_dim();
        let dims = ArrayDims::from([3, 3, 3]);
        let groups = vec![
            vec![0, 1, 2, 3, 4],
            vec![5, 6, 7, 8, 9],
            vec![10, 11, 12, 13, 14],
            vec![15, 16, 17, 18, 19],
        ];
        let m = build_split4(&dim, dims.clone(), &groups);
        assert_eq!(m.blob_count(), 4);
        check_mapping_invariants(&m);
        let mut v = alloc_view(m);
        crate::copy::test_support::fill_distinct(&mut v);
        // Round-trip against a plain AoS copy.
        let mut aos = alloc_view(AoS::aligned(&dim, dims));
        crate::copy::copy_naive(&v, &mut aos);
        assert!(crate::copy::views_equal(&v, &aos));
    }

    #[test]
    fn interleaved_groups_work() {
        // Groups need not be contiguous runs.
        let dim = cell_dim();
        let dims = ArrayDims::from([2, 2, 2]);
        let groups = vec![
            vec![0, 19],
            vec![1, 3, 5],
            vec![2, 4, 6, 8],
            (7..19).filter(|l| *l != 8).collect(),
        ];
        let m = build_split4(&dim, dims, &groups);
        check_mapping_invariants(&m);
    }

    #[test]
    fn trace_to_split_workflow() {
        // The full paper §4.3 loop: trace an lbm step, derive groups,
        // build the split, verify it still runs the solver identically.
        let geo = Geometry::channel_with_sphere(6, 6, 6, 2);
        let dim = cell_dim();
        let traced = Trace::new(AoS::aligned(&dim, geo.dims.clone()));
        let mut a = alloc_view(traced);
        let mut b = alloc_view(AoS::aligned(&dim, geo.dims.clone()));
        crate::workloads::lbm::step::init(&mut a, &geo);
        crate::workloads::lbm::step::step(&a, &mut b);
        let groups = a.mapping().equal_count_groups(4);
        assert_eq!(groups.len(), 4);
        assert_eq!(groups.concat().len(), 20);

        let split = build_split4(&dim, geo.dims.clone(), &groups);
        let mut s0 = alloc_view(split);
        let mut s1 = alloc_view(build_split4(&dim, geo.dims.clone(), &groups));
        crate::workloads::lbm::step::init(&mut s0, &geo);
        crate::workloads::lbm::step::step(&s0, &mut s1);
        // Same field values as the AoS run.
        for lin in 0..geo.dims.count() {
            assert_eq!(b.get::<f64>(lin, 0), s1.get::<f64>(lin, 0));
            assert_eq!(b.get::<f64>(lin, 18), s1.get::<f64>(lin, 18));
        }
    }

    #[test]
    #[should_panic(expected = "partition")]
    fn non_partition_rejected() {
        let dim = cell_dim();
        let groups = vec![vec![0], vec![1], vec![2], vec![3]]; // misses leaves
        let _ = build_split4(&dim, ArrayDims::from([2, 2, 2]), &groups);
    }
}
