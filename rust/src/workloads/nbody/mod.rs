//! All-pairs n-body simulation (paper §4.1, listing 9, figs 5/6).
//!
//! Two phases per timestep:
//! * **update** — each particle's velocity gains the influence of all
//!   other particles (compute-bound, O(N²));
//! * **move** — each particle's position advances by its velocity
//!   (memory-bound, O(N)).
//!
//! The module provides *manually written* AoS / SoA / AoSoA
//! implementations ([`manual`]) — the paper's hand-rolled baselines —
//! and *layout-generic* LLAMA implementations ([`llama_impl`]) that run
//! the identical kernel over any mapping. Fig 5's zero-overhead claim
//! is "LLAMA == manual twin"; the benches assert it.

pub mod llama_impl;
pub mod manual;

use crate::record::RecordDim;
use crate::workloads::rng::SplitMix64;

/// Paper constants (listing 9).
pub const TIMESTEP: f32 = 0.0001;
pub const EPS2: f32 = 0.01;
/// The paper's update problem size (16 Ki particles).
pub const PROBLEM_SIZE: usize = 16 * 1024;

/// Flat leaf indices of the n-body record dimension (declaration
/// order): pos.{x,y,z}, vel.{x,y,z}, mass.
pub const POS_X: usize = 0;
pub const POS_Y: usize = 1;
pub const POS_Z: usize = 2;
pub const VEL_X: usize = 3;
pub const VEL_Y: usize = 4;
pub const VEL_Z: usize = 5;
pub const MASS: usize = 6;
pub const LEAVES: usize = 7;

/// The 7-float particle record dimension of figs 5–7.
pub fn particle_dim() -> RecordDim {
    crate::record_dim! {
        pos: { x: f32, y: f32, z: f32 },
        vel: { x: f32, y: f32, z: f32 },
        mass: f32,
    }
}

/// Plain-array particle state used to seed every implementation
/// identically and to compare results.
#[derive(Debug, Clone, PartialEq)]
pub struct ParticleSoA {
    pub pos: [Vec<f32>; 3],
    pub vel: [Vec<f32>; 3],
    pub mass: Vec<f32>,
}

impl ParticleSoA {
    pub fn n(&self) -> usize {
        self.mass.len()
    }
}

/// Deterministic initial conditions (positions in [-1,1)^3, small
/// velocities, masses around 1).
pub fn init_particles(n: usize, seed: u64) -> ParticleSoA {
    let mut rng = SplitMix64::new(seed);
    let mut p = ParticleSoA {
        pos: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        vel: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        mass: Vec::with_capacity(n),
    };
    for _ in 0..n {
        for d in 0..3 {
            p.pos[d].push(rng.range_f32(-1.0, 1.0));
            p.vel[d].push(rng.range_f32(-0.01, 0.01));
        }
        p.mass.push(rng.range_f32(0.5, 1.5));
    }
    p
}

/// The pairwise interaction of listing 9, shared verbatim by every
/// implementation in this module.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn pp_interaction(
    pix: f32,
    piy: f32,
    piz: f32,
    pjx: f32,
    pjy: f32,
    pjz: f32,
    pjmass: f32,
    vel: &mut [f32; 3],
) {
    let mut dx = pix - pjx;
    let mut dy = piy - pjy;
    let mut dz = piz - pjz;
    dx *= dx;
    dy *= dy;
    dz *= dz;
    let dist_sqr = EPS2 + dx + dy + dz;
    let dist_sixth = dist_sqr * dist_sqr * dist_sqr;
    let inv_dist_cube = 1.0 / dist_sixth.sqrt();
    let sts = pjmass * inv_dist_cube * TIMESTEP;
    vel[0] += dx * sts;
    vel[1] += dy * sts;
    vel[2] += dz * sts;
}

/// Max relative error between two states (for cross-implementation
/// validation; f32 all-pairs sums reorder, so exact equality only holds
/// between identically-structured loops).
pub fn max_rel_error(a: &ParticleSoA, b: &ParticleSoA) -> f64 {
    let mut max = 0.0f64;
    let mut check = |x: &[f32], y: &[f32]| {
        for (u, v) in x.iter().zip(y) {
            let denom = u.abs().max(v.abs()).max(1e-12) as f64;
            let e = (*u as f64 - *v as f64).abs() / denom;
            if e > max {
                max = e;
            }
        }
    };
    for d in 0..3 {
        check(&a.pos[d], &b.pos[d]);
        check(&a.vel[d], &b.vel[d]);
    }
    check(&a.mass, &b.mass);
    max
}

/// Total kinetic energy (diagnostic logged by the examples).
pub fn kinetic_energy(p: &ParticleSoA) -> f64 {
    (0..p.n())
        .map(|i| {
            let v2 = (p.vel[0][i] as f64).powi(2)
                + (p.vel[1][i] as f64).powi(2)
                + (p.vel[2][i] as f64).powi(2);
            0.5 * p.mass[i] as f64 * v2
        })
        .sum()
}

/// [`kinetic_energy`] read directly off a view in any layout — the
/// serving-mode twin. Read-only over any [`crate::blob::Blob`]
/// storage, so it runs against the `Arc`-frozen generations handed out
/// by `ServingEngine::pin` as well as live mutable views.
pub fn kinetic_energy_view<M: crate::mapping::Mapping, B: crate::blob::Blob>(
    view: &crate::view::View<M, B>,
) -> f64 {
    (0..view.count())
        .map(|i| {
            let v2 = (view.get::<f32>(i, VEL_X) as f64).powi(2)
                + (view.get::<f32>(i, VEL_Y) as f64).powi(2)
                + (view.get::<f32>(i, VEL_Z) as f64).powi(2);
            0.5 * view.get::<f32>(i, MASS) as f64 * v2
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_bounded() {
        let a = init_particles(100, 3);
        let b = init_particles(100, 3);
        assert_eq!(a, b);
        assert!(a.pos.iter().flatten().all(|&x| (-1.0..1.0).contains(&x)));
        assert!(a.mass.iter().all(|&m| (0.5..1.5).contains(&m)));
    }

    #[test]
    fn interaction_is_attractive_in_squared_space_and_finite() {
        // Replicates listing 9 semantics: the "dist" added to the
        // velocity is component-wise squared, hence non-negative.
        let mut vel = [0.0f32; 3];
        pp_interaction(1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, &mut vel);
        assert!(vel[0] > 0.0);
        assert_eq!(vel[1], 0.0);
        assert!(vel.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn self_interaction_is_finite_thanks_to_eps() {
        let mut vel = [0.0f32; 3];
        pp_interaction(0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 1.0, &mut vel);
        assert!(vel.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kinetic_energy_view_matches_plain_arrays() {
        use crate::array::ArrayDims;
        use crate::mapping::{AoSoA, SoA};
        use crate::view::alloc_view;
        let s = init_particles(200, 9);
        let expect = kinetic_energy(&s);
        assert!(expect > 0.0);
        let mut soa = alloc_view(SoA::multi_blob(&particle_dim(), ArrayDims::linear(200)));
        llama_impl::load_state(&mut soa, &s);
        assert_eq!(kinetic_energy_view(&soa), expect);
        let mut aosoa = alloc_view(AoSoA::new(&particle_dim(), ArrayDims::linear(200), 8));
        llama_impl::load_state(&mut aosoa, &s);
        assert_eq!(kinetic_energy_view(&aosoa), expect);
    }

    #[test]
    fn record_dim_shape() {
        let d = particle_dim();
        assert_eq!(d.leaf_count(), LEAVES);
        assert_eq!(d.packed_size(), 28);
        let info = crate::record::RecordInfo::new(&d);
        assert_eq!(info.leaf_by_path("pos.x"), Some(POS_X));
        assert_eq!(info.leaf_by_path("vel.z"), Some(VEL_Z));
        assert_eq!(info.leaf_by_path("mass"), Some(MASS));
    }

    #[test]
    fn energy_positive() {
        let p = init_particles(50, 9);
        assert!(kinetic_energy(&p) > 0.0);
    }
}
