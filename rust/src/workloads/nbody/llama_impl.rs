//! Layout-generic n-body over LLAMA views: the *same* kernel source
//! runs on any mapping — switching the layout is one line at the call
//! site, the paper's core usability claim (§4.3 "by changing a single
//! line of code").

use super::{pp_interaction, ParticleSoA, MASS, POS_X, POS_Y, POS_Z, TIMESTEP, VEL_X, VEL_Y, VEL_Z};
use crate::blob::BlobMut;
use crate::mapping::Mapping;
use crate::view::adapt::AdaptiveKernel;
use crate::view::cursor::{CursorWrite, PiecewiseCursorMut};
use crate::view::shard::{par_execute, Shard, ShardKernel};
use crate::view::View;

/// The update phase as an adaptive-engine kernel
/// ([`crate::view::adapt::AdaptiveView`]): the fig 5 `adaptive` row
/// runs this — the engine traces one step, adopts the advisor's layout
/// (SoA for the 4-of-7-leaf j-stream) and keeps stepping on it.
pub struct AdaptiveUpdate {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel for AdaptiveUpdate {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        update_parallel(view, self.threads.max(1));
    }
}

/// The move phase as an adaptive-engine kernel (memory-bound: the
/// sweep where layout choice matters most, used by `bench-adapt`).
pub struct AdaptiveMove {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel for AdaptiveMove {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        mv_parallel(view, self.threads.max(1));
    }
}

/// Load plain-array state into a LLAMA view of any mapping.
pub fn load_state<M: Mapping, B: BlobMut>(view: &mut View<M, B>, s: &ParticleSoA) {
    assert_eq!(view.count(), s.n());
    for i in 0..s.n() {
        view.set::<f32>(i, POS_X, s.pos[0][i]);
        view.set::<f32>(i, POS_Y, s.pos[1][i]);
        view.set::<f32>(i, POS_Z, s.pos[2][i]);
        view.set::<f32>(i, VEL_X, s.vel[0][i]);
        view.set::<f32>(i, VEL_Y, s.vel[1][i]);
        view.set::<f32>(i, VEL_Z, s.vel[2][i]);
        view.set::<f32>(i, MASS, s.mass[i]);
    }
}

/// Extract view contents back into plain arrays.
pub fn store_state<M: Mapping, B: BlobMut>(view: &View<M, B>) -> ParticleSoA {
    let n = view.count();
    let mut s = ParticleSoA {
        pos: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        vel: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        mass: Vec::with_capacity(n),
    };
    for i in 0..n {
        s.pos[0].push(view.get::<f32>(i, POS_X));
        s.pos[1].push(view.get::<f32>(i, POS_Y));
        s.pos[2].push(view.get::<f32>(i, POS_Z));
        s.vel[0].push(view.get::<f32>(i, VEL_X));
        s.vel[1].push(view.get::<f32>(i, VEL_Y));
        s.vel[2].push(view.get::<f32>(i, VEL_Z));
        s.mass.push(view.get::<f32>(i, MASS));
    }
    s
}

/// Shard-wise update kernel: the i-loop is confined to the shard, the
/// j-stream reads the whole range (only velocities are written, only
/// positions/masses are read across records — shards never race).
struct UpdateKernel {
    n: usize,
}

impl ShardKernel for UpdateKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        update_cursors(cur, self.n, s.start, s.end);
    }

    fn run_affine(&self, cur: &[crate::view::LeafCursorMut<'_>], s: Shard) {
        update_affine(cur, self.n, s.start, s.end);
    }

    fn run_piecewise(&self, cur: &[PiecewiseCursorMut<'_>], s: Shard) {
        update_piecewise(cur, self.n, s.start, s.end);
    }
}

/// The update phase over any mapping — single flat loop, exactly the
/// structure of paper listing 9. The mapping's compiled
/// [`LayoutPlan`](crate::mapping::LayoutPlan) selects the kernel:
/// affine cursors (AoS, SoA, affine Splits), piecewise cursors with a
/// lane-blocked inner loop (AoSoA — no per-access `i/L, i%L` through
/// the mapping object), or the generic accessor path (instrumented and
/// curve layouts).
pub fn update<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    update_parallel(view, 1);
}

/// [`update`] over plan-aligned shards on `threads` scoped workers
/// (`threads = 1` runs inline and is bit-identical to the serial
/// kernel; so is every other thread count — each record's arithmetic
/// is self-contained). Generic plans (instrumented/curve layouts) run
/// the accessor path serially.
pub fn update_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    let n = view.count();
    if par_execute(view, threads, &UpdateKernel { n }) {
        return;
    }
    debug_assert!(view.validate().is_ok());
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let pix = view.get_unchecked::<f32>(i, POS_X);
            let piy = view.get_unchecked::<f32>(i, POS_Y);
            let piz = view.get_unchecked::<f32>(i, POS_Z);
            let mut vel = [
                view.get_unchecked::<f32>(i, VEL_X),
                view.get_unchecked::<f32>(i, VEL_Y),
                view.get_unchecked::<f32>(i, VEL_Z),
            ];
            for j in 0..n {
                pp_interaction(
                    pix,
                    piy,
                    piz,
                    view.get_unchecked::<f32>(j, POS_X),
                    view.get_unchecked::<f32>(j, POS_Y),
                    view.get_unchecked::<f32>(j, POS_Z),
                    view.get_unchecked::<f32>(j, MASS),
                    &mut vel,
                );
            }
            view.set_unchecked::<f32>(i, VEL_X, vel[0]);
            view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
            view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
        }
    }
}

/// Affine-cursor update: identical arithmetic, loop-invariant bases.
/// With a dense SoA layout the inner loop compiles to the same packed
/// loads/FMAs as the manual SoA twin (the Rust analogue of the paper's
/// listing 10/11 disassembly identity). The i-loop covers
/// `start..end`; the j-stream always reads `0..n`.
fn update_affine(cur: &[crate::view::LeafCursorMut<'_>], n: usize, start: usize, end: usize) {
    // Dense fast path: slices for the j-stream.
    // SAFETY: read-only slices of distinct leaves.
    let dense = (
        cur[POS_X].as_read().as_slice::<f32>(),
        cur[POS_Y].as_read().as_slice::<f32>(),
        cur[POS_Z].as_read().as_slice::<f32>(),
        cur[MASS].as_read().as_slice::<f32>(),
    );
    if let (Some(xs), Some(ys), Some(zs), Some(ms)) = dense {
        for i in start..end {
            // SAFETY: i < n == cursor count.
            unsafe {
                let pix = cur[POS_X].read::<f32>(i);
                let piy = cur[POS_Y].read::<f32>(i);
                let piz = cur[POS_Z].read::<f32>(i);
                let mut vel = [
                    cur[VEL_X].read::<f32>(i),
                    cur[VEL_Y].read::<f32>(i),
                    cur[VEL_Z].read::<f32>(i),
                ];
                for j in 0..n {
                    pp_interaction(pix, piy, piz, xs[j], ys[j], zs[j], ms[j], &mut vel);
                }
                cur[VEL_X].write::<f32>(i, vel[0]);
                cur[VEL_Y].write::<f32>(i, vel[1]);
                cur[VEL_Z].write::<f32>(i, vel[2]);
            }
        }
        return;
    }
    update_cursors(cur, n, start, end);
}

/// Piecewise-cursor update for AoSoA-family plans: the j-stream walks
/// lane-blocks whose dense slices vectorize like the manual AoSoA twin,
/// with the `(i/L, i%L)` split hoisted per block instead of per access.
fn update_piecewise(cur: &[PiecewiseCursorMut<'_>], n: usize, start: usize, end: usize) {
    let dense = cur[POS_X].is_dense::<f32>()
        && cur[POS_Y].is_dense::<f32>()
        && cur[POS_Z].is_dense::<f32>()
        && cur[MASS].is_dense::<f32>();
    if !dense {
        return update_cursors(cur, n, start, end);
    }
    let blocks = cur[POS_X].blocks();
    for i in start..end {
        // SAFETY: i < n == cursor count; b < blocks with dense leaves
        // checked above. Block-ascending × lane-ascending is exactly the
        // flat j order, so results stay bit-identical to every other
        // layout (asserted in tests).
        unsafe {
            let pix = cur[POS_X].read::<f32>(i);
            let piy = cur[POS_Y].read::<f32>(i);
            let piz = cur[POS_Z].read::<f32>(i);
            let mut vel = [
                cur[VEL_X].read::<f32>(i),
                cur[VEL_Y].read::<f32>(i),
                cur[VEL_Z].read::<f32>(i),
            ];
            for b in 0..blocks {
                let xs = cur[POS_X].block_slice::<f32>(b);
                let ys = cur[POS_Y].block_slice::<f32>(b);
                let zs = cur[POS_Z].block_slice::<f32>(b);
                let ms = cur[MASS].block_slice::<f32>(b);
                for k in 0..xs.len() {
                    pp_interaction(pix, piy, piz, xs[k], ys[k], zs[k], ms[k], &mut vel);
                }
            }
            cur[VEL_X].write::<f32>(i, vel[0]);
            cur[VEL_Y].write::<f32>(i, vel[1]);
            cur[VEL_Z].write::<f32>(i, vel[2]);
        }
    }
}

/// Cursor update shared by the non-dense affine and piecewise paths:
/// loop-invariant bases, flat j-stream.
fn update_cursors<C: CursorWrite>(cur: &[C], n: usize, start: usize, end: usize) {
    for i in start..end {
        // SAFETY: i, j < n == cursor count.
        unsafe {
            let pix = cur[POS_X].read_at::<f32>(i);
            let piy = cur[POS_Y].read_at::<f32>(i);
            let piz = cur[POS_Z].read_at::<f32>(i);
            let mut vel = [
                cur[VEL_X].read_at::<f32>(i),
                cur[VEL_Y].read_at::<f32>(i),
                cur[VEL_Z].read_at::<f32>(i),
            ];
            for j in 0..n {
                pp_interaction(
                    pix,
                    piy,
                    piz,
                    cur[POS_X].read_at::<f32>(j),
                    cur[POS_Y].read_at::<f32>(j),
                    cur[POS_Z].read_at::<f32>(j),
                    cur[MASS].read_at::<f32>(j),
                    &mut vel,
                );
            }
            cur[VEL_X].write_at::<f32>(i, vel[0]);
            cur[VEL_Y].write_at::<f32>(i, vel[1]);
            cur[VEL_Z].write_at::<f32>(i, vel[2]);
        }
    }
}

/// Update with an inner loop blocked by `lanes` — the "dedicated
/// iteration mechanism aware of the mapping's needs" the paper says
/// LLAMA would need for AoSoA (§4.1). With `lanes` = the mapping's
/// AoSoA lane count, the inner trip count is constant and the `i % L`
/// split hoists out of the inner loop.
pub fn update_blocked<M: Mapping, B: BlobMut>(view: &mut View<M, B>, lanes: usize) {
    debug_assert!(view.validate().is_ok());
    let n = view.count();
    let lanes = lanes.max(1);
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let pix = view.get_unchecked::<f32>(i, POS_X);
            let piy = view.get_unchecked::<f32>(i, POS_Y);
            let piz = view.get_unchecked::<f32>(i, POS_Z);
            let mut vel = [
                view.get_unchecked::<f32>(i, VEL_X),
                view.get_unchecked::<f32>(i, VEL_Y),
                view.get_unchecked::<f32>(i, VEL_Z),
            ];
            let mut base = 0usize;
            while base < n {
                let end = (base + lanes).min(n);
                for j in base..end {
                    pp_interaction(
                        pix,
                        piy,
                        piz,
                        view.get_unchecked::<f32>(j, POS_X),
                        view.get_unchecked::<f32>(j, POS_Y),
                        view.get_unchecked::<f32>(j, POS_Z),
                        view.get_unchecked::<f32>(j, MASS),
                        &mut vel,
                    );
                }
                base = end;
            }
            view.set_unchecked::<f32>(i, VEL_X, vel[0]);
            view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
            view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
        }
    }
}

/// Update with j-tiling through a scratch buffer — the CPU analogue of
/// the paper's CUDA shared-memory variant (fig 6 "SM"): stage `tile`
/// particles into a dense local array, then run the inner loop over the
/// stage. On GPUs the stage lives in shared memory; here it models the
/// same working-set blocking (L1-resident tile).
pub fn update_tiled<M: Mapping, B: BlobMut>(view: &mut View<M, B>, tile: usize) {
    debug_assert!(view.validate().is_ok());
    let n = view.count();
    let tile = tile.max(1);
    let mut stage = vec![[0.0f32; 4]; tile];
    for jt in (0..n).step_by(tile) {
        let jend = (jt + tile).min(n);
        let m = jend - jt;
        for (k, s) in stage.iter_mut().take(m).enumerate() {
            let j = jt + k;
            // SAFETY: j < n over a validated view.
            unsafe {
                *s = [
                    view.get_unchecked::<f32>(j, POS_X),
                    view.get_unchecked::<f32>(j, POS_Y),
                    view.get_unchecked::<f32>(j, POS_Z),
                    view.get_unchecked::<f32>(j, MASS),
                ];
            }
        }
        for i in 0..n {
            // SAFETY: i < n over a validated view.
            unsafe {
                let pix = view.get_unchecked::<f32>(i, POS_X);
                let piy = view.get_unchecked::<f32>(i, POS_Y);
                let piz = view.get_unchecked::<f32>(i, POS_Z);
                let mut vel = [
                    view.get_unchecked::<f32>(i, VEL_X),
                    view.get_unchecked::<f32>(i, VEL_Y),
                    view.get_unchecked::<f32>(i, VEL_Z),
                ];
                for s in stage.iter().take(m) {
                    pp_interaction(pix, piy, piz, s[0], s[1], s[2], s[3], &mut vel);
                }
                view.set_unchecked::<f32>(i, VEL_X, vel[0]);
                view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
                view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
            }
        }
    }
}

/// Shard-wise move kernel: pure per-record arithmetic — any sharding
/// is bit-identical to the serial sweep.
struct MoveKernel;

impl ShardKernel for MoveKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        mv_cursors(cur, s.start, s.end);
    }

    fn run_affine(&self, cur: &[crate::view::LeafCursorMut<'_>], s: Shard) {
        mv_affine(cur, s.start, s.end);
    }

    fn run_piecewise(&self, cur: &[PiecewiseCursorMut<'_>], s: Shard) {
        mv_piecewise(cur, s.start, s.end);
    }
}

/// The move phase over any mapping.
///
/// Perf (EXPERIMENTS.md §Perf): the compiled plan selects the kernel.
/// Dense affine (SoA) leaves become real slice loops that LLVM
/// vectorizes exactly like the manual twin; strided affine (AoS, Split)
/// leaves get loop-invariant base pointers; AoSoA plans run lane-block
/// slices — the same vectorizable inner loop as the manual AoSoA twin,
/// with no per-access `blob_nr_and_offset`. Only instrumented/curve
/// layouts keep the generic accessor path.
pub fn mv<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    mv_parallel(view, 1);
}

/// [`mv`] over plan-aligned shards on `threads` scoped workers; see
/// [`update_parallel`] for the identity and fallback contract.
pub fn mv_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    let n = view.count();
    if par_execute(view, threads, &MoveKernel) {
        return;
    }
    debug_assert!(view.validate().is_ok());
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let x = view.get_unchecked::<f32>(i, POS_X)
                + view.get_unchecked::<f32>(i, VEL_X) * TIMESTEP;
            let y = view.get_unchecked::<f32>(i, POS_Y)
                + view.get_unchecked::<f32>(i, VEL_Y) * TIMESTEP;
            let z = view.get_unchecked::<f32>(i, POS_Z)
                + view.get_unchecked::<f32>(i, VEL_Z) * TIMESTEP;
            view.set_unchecked::<f32>(i, POS_X, x);
            view.set_unchecked::<f32>(i, POS_Y, y);
            view.set_unchecked::<f32>(i, POS_Z, z);
        }
    }
}

/// Affine-cursor move: dense leaves as shard-local slices, else
/// strided loop-invariant bases. Sweeps `start..end`.
fn mv_affine(cur: &[crate::view::LeafCursorMut<'_>], start: usize, end: usize) {
    // Dense? (all six position/velocity leaves stride == 4)
    // SAFETY: one mutable slice per distinct leaf *and* shard range —
    // leaves of a valid mapping never overlap, and concurrent shards
    // cover disjoint ranges, so no two live slices alias.
    let dense = unsafe {
        (
            cur[POS_X].as_mut_slice_range::<f32>(start, end),
            cur[POS_Y].as_mut_slice_range::<f32>(start, end),
            cur[POS_Z].as_mut_slice_range::<f32>(start, end),
            cur[VEL_X].as_read().as_slice_range::<f32>(start, end),
            cur[VEL_Y].as_read().as_slice_range::<f32>(start, end),
            cur[VEL_Z].as_read().as_slice_range::<f32>(start, end),
        )
    };
    if let (Some(px), Some(py), Some(pz), Some(vx), Some(vy), Some(vz)) = dense {
        for k in 0..px.len() {
            px[k] += vx[k] * TIMESTEP;
            py[k] += vy[k] * TIMESTEP;
            pz[k] += vz[k] * TIMESTEP;
        }
        return;
    }
    mv_cursors(cur, start, end);
}

/// Piecewise-cursor move: per-lane-block dense slices (the fig 5 AoSoA
/// row — previously the one layout still paying dynamic translation).
/// Shard boundaries are lane-aligned ([`crate::view::shard_align`]),
/// so the block range below is exact: only the global tail block can
/// be partial, and `block_len` caps it.
fn mv_piecewise(cur: &[PiecewiseCursorMut<'_>], start: usize, end: usize) {
    let dense = cur[POS_X].is_dense::<f32>()
        && cur[POS_Y].is_dense::<f32>()
        && cur[POS_Z].is_dense::<f32>()
        && cur[VEL_X].is_dense::<f32>()
        && cur[VEL_Y].is_dense::<f32>()
        && cur[VEL_Z].is_dense::<f32>();
    if !dense {
        return mv_cursors(cur, start, end);
    }
    let lanes = cur[POS_X].lanes();
    debug_assert!(start % lanes == 0, "shard start {start} straddles a {lanes}-lane block");
    let (b0, b1) = (start / lanes, end.div_ceil(lanes));
    for b in b0..b1 {
        // SAFETY: b < blocks, density checked; one mutable slice per
        // distinct leaf — leaves of a valid mapping never overlap.
        unsafe {
            let px = cur[POS_X].block_slice_mut::<f32>(b);
            let py = cur[POS_Y].block_slice_mut::<f32>(b);
            let pz = cur[POS_Z].block_slice_mut::<f32>(b);
            let vx = cur[VEL_X].block_slice::<f32>(b);
            let vy = cur[VEL_Y].block_slice::<f32>(b);
            let vz = cur[VEL_Z].block_slice::<f32>(b);
            for k in 0..px.len() {
                px[k] += vx[k] * TIMESTEP;
                py[k] += vy[k] * TIMESTEP;
                pz[k] += vz[k] * TIMESTEP;
            }
        }
    }
}

/// Cursor move shared by the non-dense affine and piecewise paths.
fn mv_cursors<C: CursorWrite>(cur: &[C], start: usize, end: usize) {
    for i in start..end {
        // SAFETY: i < n == cursor count.
        unsafe {
            let x = cur[POS_X].read_at::<f32>(i) + cur[VEL_X].read_at::<f32>(i) * TIMESTEP;
            let y = cur[POS_Y].read_at::<f32>(i) + cur[VEL_Y].read_at::<f32>(i) * TIMESTEP;
            let z = cur[POS_Z].read_at::<f32>(i) + cur[VEL_Z].read_at::<f32>(i) * TIMESTEP;
            cur[POS_X].write_at::<f32>(i, x);
            cur[POS_Y].write_at::<f32>(i, y);
            cur[POS_Z].write_at::<f32>(i, z);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoS, AoSoA, SoA, Split};
    use crate::record::RecordCoord;
    use crate::view::alloc_view;
    use crate::workloads::nbody::manual::NBodyAoS;
    use crate::workloads::nbody::{init_particles, max_rel_error, particle_dim};

    fn run_llama<M: Mapping>(mapping: M, s: &ParticleSoA, steps: usize) -> ParticleSoA {
        let mut v = alloc_view(mapping);
        load_state(&mut v, s);
        for _ in 0..steps {
            update(&mut v);
            mv(&mut v);
        }
        store_state(&v)
    }

    fn reference(s: &ParticleSoA, steps: usize) -> ParticleSoA {
        let mut aos = NBodyAoS::from_state(s);
        for _ in 0..steps {
            aos.update();
            aos.mv();
        }
        aos.to_state()
    }

    #[test]
    fn llama_matches_manual_on_every_mapping() {
        let s = init_particles(96, 21);
        let expect = reference(&s, 2);
        let d = particle_dim();
        let dims = ArrayDims::linear(96);
        let cases: Vec<(&str, ParticleSoA)> = vec![
            ("aos_aligned", run_llama(AoS::aligned(&d, dims.clone()), &s, 2)),
            ("aos_packed", run_llama(AoS::packed(&d, dims.clone()), &s, 2)),
            ("soa_mb", run_llama(SoA::multi_blob(&d, dims.clone()), &s, 2)),
            ("soa_sb", run_llama(SoA::single_blob(&d, dims.clone()), &s, 2)),
            ("aosoa8", run_llama(AoSoA::new(&d, dims.clone(), 8), &s, 2)),
            // 96 % 7 != 0: the piecewise kernel's tail block.
            ("aosoa7_tail", run_llama(AoSoA::new(&d, dims.clone(), 7), &s, 2)),
            (
                "split_pos",
                run_llama(
                    Split::new(
                        &d,
                        dims.clone(),
                        RecordCoord::new(vec![0]),
                        |sd, ad| SoA::multi_blob(sd, ad),
                        |sd, ad| AoS::aligned(sd, ad),
                    ),
                    &s,
                    2,
                ),
            ),
        ];
        for (name, got) in cases {
            let e = max_rel_error(&expect, &got);
            // Same loop structure, same arithmetic order -> results are
            // bit-identical regardless of layout.
            assert!(e == 0.0, "{name}: rel err {e}");
        }
    }

    #[test]
    fn blocked_and_tiled_variants_agree() {
        let s = init_particles(70, 4);
        let d = particle_dim();
        let dims = ArrayDims::linear(70);
        let expect = reference(&s, 1);

        let mut v = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        load_state(&mut v, &s);
        update_blocked(&mut v, 16);
        mv(&mut v);
        assert_eq!(max_rel_error(&expect, &store_state(&v)), 0.0);

        let mut v = alloc_view(SoA::multi_blob(&d, dims.clone()));
        load_state(&mut v, &s);
        update_tiled(&mut v, 32);
        mv(&mut v);
        // Tiling reorders the j-loop in blocks; same order actually
        // (tiles are processed in ascending j), so still identical.
        assert_eq!(max_rel_error(&expect, &store_state(&v)), 0.0);
    }

    #[test]
    fn parallel_update_and_move_are_bit_identical() {
        // Each record's arithmetic is self-contained, so sharding only
        // changes scheduling: any thread count must reproduce the
        // serial result exactly, including AoSoA tail blocks.
        let s = init_particles(97, 9); // 97: tails at every lane count
        let d = particle_dim();
        let dims = ArrayDims::linear(97);
        fn run_par<M: Mapping>(mapping: M, s: &ParticleSoA, threads: usize) -> ParticleSoA {
            let mut v = alloc_view(mapping);
            load_state(&mut v, s);
            for _ in 0..2 {
                update_parallel(&mut v, threads);
                mv_parallel(&mut v, threads);
            }
            store_state(&v)
        }
        let expect = run_par(AoS::aligned(&d, dims.clone()), &s, 1);
        for threads in [2usize, 3, 8] {
            assert_eq!(expect, run_par(AoS::aligned(&d, dims.clone()), &s, threads));
            assert_eq!(expect, run_par(SoA::multi_blob(&d, dims.clone()), &s, threads));
            assert_eq!(expect, run_par(AoSoA::new(&d, dims.clone(), 8), &s, threads));
            assert_eq!(expect, run_par(AoSoA::new(&d, dims.clone(), 16), &s, threads));
        }
    }

    #[test]
    fn load_store_roundtrip() {
        let s = init_particles(33, 77);
        let d = particle_dim();
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(33), 4));
        load_state(&mut v, &s);
        assert_eq!(store_state(&v), s);
    }
}
