//! Layout-generic n-body over LLAMA views: the *same* kernel source
//! runs on any mapping — switching the layout is one line at the call
//! site, the paper's core usability claim (§4.3 "by changing a single
//! line of code").

use super::{pp_interaction, ParticleSoA, MASS, POS_X, POS_Y, POS_Z, TIMESTEP, VEL_X, VEL_Y, VEL_Z};
use crate::blob::BlobMut;
use crate::mapping::Mapping;
use crate::view::adapt::AdaptiveKernel;
use crate::view::cursor::{CursorWrite, PiecewiseCursorMut};
use crate::view::shard::{par_execute, Shard, ShardKernel};
use crate::view::simd::{detect, SimdPath};
use crate::view::View;

/// The update phase as an adaptive-engine kernel
/// ([`crate::view::adapt::AdaptiveView`]): the fig 5 `adaptive` row
/// runs this — the engine traces one step, adopts the advisor's layout
/// (SoA for the 4-of-7-leaf j-stream) and keeps stepping on it.
pub struct AdaptiveUpdate {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel for AdaptiveUpdate {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        update_parallel(view, self.threads.max(1));
    }
}

/// The move phase as an adaptive-engine kernel (memory-bound: the
/// sweep where layout choice matters most, used by `bench-adapt`).
pub struct AdaptiveMove {
    /// Worker threads per step (1 = serial).
    pub threads: usize,
}

impl AdaptiveKernel for AdaptiveMove {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        mv_parallel(view, self.threads.max(1));
    }
}

/// Load plain-array state into a LLAMA view of any mapping.
pub fn load_state<M: Mapping, B: BlobMut>(view: &mut View<M, B>, s: &ParticleSoA) {
    assert_eq!(view.count(), s.n());
    for i in 0..s.n() {
        view.set::<f32>(i, POS_X, s.pos[0][i]);
        view.set::<f32>(i, POS_Y, s.pos[1][i]);
        view.set::<f32>(i, POS_Z, s.pos[2][i]);
        view.set::<f32>(i, VEL_X, s.vel[0][i]);
        view.set::<f32>(i, VEL_Y, s.vel[1][i]);
        view.set::<f32>(i, VEL_Z, s.vel[2][i]);
        view.set::<f32>(i, MASS, s.mass[i]);
    }
}

/// Extract view contents back into plain arrays.
pub fn store_state<M: Mapping, B: BlobMut>(view: &View<M, B>) -> ParticleSoA {
    let n = view.count();
    let mut s = ParticleSoA {
        pos: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        vel: [Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n)],
        mass: Vec::with_capacity(n),
    };
    for i in 0..n {
        s.pos[0].push(view.get::<f32>(i, POS_X));
        s.pos[1].push(view.get::<f32>(i, POS_Y));
        s.pos[2].push(view.get::<f32>(i, POS_Z));
        s.vel[0].push(view.get::<f32>(i, VEL_X));
        s.vel[1].push(view.get::<f32>(i, VEL_Y));
        s.vel[2].push(view.get::<f32>(i, VEL_Z));
        s.mass.push(view.get::<f32>(i, MASS));
    }
    s
}

/// Shard-wise update kernel: the i-loop is confined to the shard, the
/// j-stream reads the whole range (only velocities are written, only
/// positions/masses are read across records — shards never race).
struct UpdateKernel {
    n: usize,
}

impl ShardKernel for UpdateKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        update_cursors(cur, self.n, s.start, s.end);
    }

    fn run_affine(&self, cur: &[crate::view::LeafCursorMut<'_>], s: Shard) {
        update_affine(cur, self.n, s.start, s.end);
    }

    fn run_piecewise(&self, cur: &[PiecewiseCursorMut<'_>], s: Shard) {
        update_piecewise(cur, self.n, s.start, s.end);
    }
}

/// The update phase over any mapping — single flat loop, exactly the
/// structure of paper listing 9. The mapping's compiled
/// [`LayoutPlan`](crate::mapping::LayoutPlan) selects the kernel:
/// affine cursors (AoS, SoA, affine Splits), piecewise cursors with a
/// lane-blocked inner loop (AoSoA — no per-access `i/L, i%L` through
/// the mapping object), or the generic accessor path (instrumented and
/// curve layouts).
pub fn update<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    update_parallel(view, 1);
}

/// [`update`] over plan-aligned shards on `threads` scoped workers
/// (`threads = 1` runs inline and is bit-identical to the serial
/// kernel; so is every other thread count — each record's arithmetic
/// is self-contained). Generic plans (instrumented/curve layouts) run
/// the accessor path serially.
pub fn update_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    let n = view.count();
    if par_execute(view, threads, &UpdateKernel { n }) {
        return;
    }
    debug_assert!(view.validate().is_ok());
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let pix = view.get_unchecked::<f32>(i, POS_X);
            let piy = view.get_unchecked::<f32>(i, POS_Y);
            let piz = view.get_unchecked::<f32>(i, POS_Z);
            let mut vel = [
                view.get_unchecked::<f32>(i, VEL_X),
                view.get_unchecked::<f32>(i, VEL_Y),
                view.get_unchecked::<f32>(i, VEL_Z),
            ];
            for j in 0..n {
                pp_interaction(
                    pix,
                    piy,
                    piz,
                    view.get_unchecked::<f32>(j, POS_X),
                    view.get_unchecked::<f32>(j, POS_Y),
                    view.get_unchecked::<f32>(j, POS_Z),
                    view.get_unchecked::<f32>(j, MASS),
                    &mut vel,
                );
            }
            view.set_unchecked::<f32>(i, VEL_X, vel[0]);
            view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
            view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
        }
    }
}

/// Affine-cursor update: identical arithmetic, loop-invariant bases.
/// With a dense SoA layout the inner loop compiles to the same packed
/// loads/FMAs as the manual SoA twin (the Rust analogue of the paper's
/// listing 10/11 disassembly identity). The i-loop covers
/// `start..end`; the j-stream always reads `0..n`.
fn update_affine(cur: &[crate::view::LeafCursorMut<'_>], n: usize, start: usize, end: usize) {
    // Dense fast path: slices for the j-stream.
    // SAFETY: read-only slices of distinct leaves.
    let dense = (
        cur[POS_X].as_read().as_slice::<f32>(),
        cur[POS_Y].as_read().as_slice::<f32>(),
        cur[POS_Z].as_read().as_slice::<f32>(),
        cur[MASS].as_read().as_slice::<f32>(),
    );
    if let (Some(xs), Some(ys), Some(zs), Some(ms)) = dense {
        for i in start..end {
            // SAFETY: i < n == cursor count.
            unsafe {
                let pix = cur[POS_X].read::<f32>(i);
                let piy = cur[POS_Y].read::<f32>(i);
                let piz = cur[POS_Z].read::<f32>(i);
                let mut vel = [
                    cur[VEL_X].read::<f32>(i),
                    cur[VEL_Y].read::<f32>(i),
                    cur[VEL_Z].read::<f32>(i),
                ];
                for j in 0..n {
                    pp_interaction(pix, piy, piz, xs[j], ys[j], zs[j], ms[j], &mut vel);
                }
                cur[VEL_X].write::<f32>(i, vel[0]);
                cur[VEL_Y].write::<f32>(i, vel[1]);
                cur[VEL_Z].write::<f32>(i, vel[2]);
            }
        }
        return;
    }
    update_cursors(cur, n, start, end);
}

/// Piecewise-cursor update for AoSoA-family plans: the j-stream walks
/// lane-blocks whose dense slices vectorize like the manual AoSoA twin,
/// with the `(i/L, i%L)` split hoisted per block instead of per access.
fn update_piecewise(cur: &[PiecewiseCursorMut<'_>], n: usize, start: usize, end: usize) {
    let dense = cur[POS_X].is_dense::<f32>()
        && cur[POS_Y].is_dense::<f32>()
        && cur[POS_Z].is_dense::<f32>()
        && cur[MASS].is_dense::<f32>();
    if !dense {
        return update_cursors(cur, n, start, end);
    }
    let blocks = cur[POS_X].blocks();
    for i in start..end {
        // SAFETY: i < n == cursor count; b < blocks with dense leaves
        // checked above. Block-ascending × lane-ascending is exactly the
        // flat j order, so results stay bit-identical to every other
        // layout (asserted in tests).
        unsafe {
            let pix = cur[POS_X].read::<f32>(i);
            let piy = cur[POS_Y].read::<f32>(i);
            let piz = cur[POS_Z].read::<f32>(i);
            let mut vel = [
                cur[VEL_X].read::<f32>(i),
                cur[VEL_Y].read::<f32>(i),
                cur[VEL_Z].read::<f32>(i),
            ];
            for b in 0..blocks {
                let xs = cur[POS_X].block_slice::<f32>(b);
                let ys = cur[POS_Y].block_slice::<f32>(b);
                let zs = cur[POS_Z].block_slice::<f32>(b);
                let ms = cur[MASS].block_slice::<f32>(b);
                for k in 0..xs.len() {
                    pp_interaction(pix, piy, piz, xs[k], ys[k], zs[k], ms[k], &mut vel);
                }
            }
            cur[VEL_X].write::<f32>(i, vel[0]);
            cur[VEL_Y].write::<f32>(i, vel[1]);
            cur[VEL_Z].write::<f32>(i, vel[2]);
        }
    }
}

/// Cursor update shared by the non-dense affine and piecewise paths:
/// loop-invariant bases, flat j-stream.
fn update_cursors<C: CursorWrite>(cur: &[C], n: usize, start: usize, end: usize) {
    for i in start..end {
        // SAFETY: i, j < n == cursor count.
        unsafe {
            let pix = cur[POS_X].read_at::<f32>(i);
            let piy = cur[POS_Y].read_at::<f32>(i);
            let piz = cur[POS_Z].read_at::<f32>(i);
            let mut vel = [
                cur[VEL_X].read_at::<f32>(i),
                cur[VEL_Y].read_at::<f32>(i),
                cur[VEL_Z].read_at::<f32>(i),
            ];
            for j in 0..n {
                pp_interaction(
                    pix,
                    piy,
                    piz,
                    cur[POS_X].read_at::<f32>(j),
                    cur[POS_Y].read_at::<f32>(j),
                    cur[POS_Z].read_at::<f32>(j),
                    cur[MASS].read_at::<f32>(j),
                    &mut vel,
                );
            }
            cur[VEL_X].write_at::<f32>(i, vel[0]);
            cur[VEL_Y].write_at::<f32>(i, vel[1]);
            cur[VEL_Z].write_at::<f32>(i, vel[2]);
        }
    }
}

/// Update with an inner loop blocked by `lanes` — the "dedicated
/// iteration mechanism aware of the mapping's needs" the paper says
/// LLAMA would need for AoSoA (§4.1). With `lanes` = the mapping's
/// AoSoA lane count, the inner trip count is constant and the `i % L`
/// split hoists out of the inner loop.
pub fn update_blocked<M: Mapping, B: BlobMut>(view: &mut View<M, B>, lanes: usize) {
    debug_assert!(view.validate().is_ok());
    let n = view.count();
    let lanes = lanes.max(1);
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let pix = view.get_unchecked::<f32>(i, POS_X);
            let piy = view.get_unchecked::<f32>(i, POS_Y);
            let piz = view.get_unchecked::<f32>(i, POS_Z);
            let mut vel = [
                view.get_unchecked::<f32>(i, VEL_X),
                view.get_unchecked::<f32>(i, VEL_Y),
                view.get_unchecked::<f32>(i, VEL_Z),
            ];
            let mut base = 0usize;
            while base < n {
                let end = (base + lanes).min(n);
                for j in base..end {
                    pp_interaction(
                        pix,
                        piy,
                        piz,
                        view.get_unchecked::<f32>(j, POS_X),
                        view.get_unchecked::<f32>(j, POS_Y),
                        view.get_unchecked::<f32>(j, POS_Z),
                        view.get_unchecked::<f32>(j, MASS),
                        &mut vel,
                    );
                }
                base = end;
            }
            view.set_unchecked::<f32>(i, VEL_X, vel[0]);
            view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
            view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
        }
    }
}

/// Update with j-tiling through a scratch buffer — the CPU analogue of
/// the paper's CUDA shared-memory variant (fig 6 "SM"): stage `tile`
/// particles into a dense local array, then run the inner loop over the
/// stage. On GPUs the stage lives in shared memory; here it models the
/// same working-set blocking (L1-resident tile).
pub fn update_tiled<M: Mapping, B: BlobMut>(view: &mut View<M, B>, tile: usize) {
    debug_assert!(view.validate().is_ok());
    let n = view.count();
    let tile = tile.max(1);
    let mut stage = vec![[0.0f32; 4]; tile];
    for jt in (0..n).step_by(tile) {
        let jend = (jt + tile).min(n);
        let m = jend - jt;
        for (k, s) in stage.iter_mut().take(m).enumerate() {
            let j = jt + k;
            // SAFETY: j < n over a validated view.
            unsafe {
                *s = [
                    view.get_unchecked::<f32>(j, POS_X),
                    view.get_unchecked::<f32>(j, POS_Y),
                    view.get_unchecked::<f32>(j, POS_Z),
                    view.get_unchecked::<f32>(j, MASS),
                ];
            }
        }
        for i in 0..n {
            // SAFETY: i < n over a validated view.
            unsafe {
                let pix = view.get_unchecked::<f32>(i, POS_X);
                let piy = view.get_unchecked::<f32>(i, POS_Y);
                let piz = view.get_unchecked::<f32>(i, POS_Z);
                let mut vel = [
                    view.get_unchecked::<f32>(i, VEL_X),
                    view.get_unchecked::<f32>(i, VEL_Y),
                    view.get_unchecked::<f32>(i, VEL_Z),
                ];
                for s in stage.iter().take(m) {
                    pp_interaction(pix, piy, piz, s[0], s[1], s[2], s[3], &mut vel);
                }
                view.set_unchecked::<f32>(i, VEL_X, vel[0]);
                view.set_unchecked::<f32>(i, VEL_Y, vel[1]);
                view.set_unchecked::<f32>(i, VEL_Z, vel[2]);
            }
        }
    }
}

/// Shard-wise move kernel: pure per-record arithmetic — any sharding
/// is bit-identical to the serial sweep.
struct MoveKernel;

impl ShardKernel for MoveKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        mv_cursors(cur, s.start, s.end);
    }

    fn run_affine(&self, cur: &[crate::view::LeafCursorMut<'_>], s: Shard) {
        mv_affine(cur, s.start, s.end);
    }

    fn run_piecewise(&self, cur: &[PiecewiseCursorMut<'_>], s: Shard) {
        mv_piecewise(cur, s.start, s.end);
    }
}

/// The move phase over any mapping.
///
/// Perf (EXPERIMENTS.md §Perf): the compiled plan selects the kernel.
/// Dense affine (SoA) leaves become real slice loops that LLVM
/// vectorizes exactly like the manual twin; strided affine (AoS, Split)
/// leaves get loop-invariant base pointers; AoSoA plans run lane-block
/// slices — the same vectorizable inner loop as the manual AoSoA twin,
/// with no per-access `blob_nr_and_offset`. Only instrumented/curve
/// layouts keep the generic accessor path.
pub fn mv<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    mv_parallel(view, 1);
}

/// [`mv`] over plan-aligned shards on `threads` scoped workers; see
/// [`update_parallel`] for the identity and fallback contract.
pub fn mv_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    let n = view.count();
    if par_execute(view, threads, &MoveKernel) {
        return;
    }
    debug_assert!(view.validate().is_ok());
    for i in 0..n {
        // SAFETY: indices in 0..n over a validated view.
        unsafe {
            let x = view.get_unchecked::<f32>(i, POS_X)
                + view.get_unchecked::<f32>(i, VEL_X) * TIMESTEP;
            let y = view.get_unchecked::<f32>(i, POS_Y)
                + view.get_unchecked::<f32>(i, VEL_Y) * TIMESTEP;
            let z = view.get_unchecked::<f32>(i, POS_Z)
                + view.get_unchecked::<f32>(i, VEL_Z) * TIMESTEP;
            view.set_unchecked::<f32>(i, POS_X, x);
            view.set_unchecked::<f32>(i, POS_Y, y);
            view.set_unchecked::<f32>(i, POS_Z, z);
        }
    }
}

/// Affine-cursor move: dense leaves as shard-local slices, else
/// strided loop-invariant bases. Sweeps `start..end`.
fn mv_affine(cur: &[crate::view::LeafCursorMut<'_>], start: usize, end: usize) {
    // Dense? (all six position/velocity leaves stride == 4)
    // SAFETY: one mutable slice per distinct leaf *and* shard range —
    // leaves of a valid mapping never overlap, and concurrent shards
    // cover disjoint ranges, so no two live slices alias.
    let dense = unsafe {
        (
            cur[POS_X].as_mut_slice_range::<f32>(start, end),
            cur[POS_Y].as_mut_slice_range::<f32>(start, end),
            cur[POS_Z].as_mut_slice_range::<f32>(start, end),
            cur[VEL_X].as_read().as_slice_range::<f32>(start, end),
            cur[VEL_Y].as_read().as_slice_range::<f32>(start, end),
            cur[VEL_Z].as_read().as_slice_range::<f32>(start, end),
        )
    };
    if let (Some(px), Some(py), Some(pz), Some(vx), Some(vy), Some(vz)) = dense {
        for k in 0..px.len() {
            px[k] += vx[k] * TIMESTEP;
            py[k] += vy[k] * TIMESTEP;
            pz[k] += vz[k] * TIMESTEP;
        }
        return;
    }
    mv_cursors(cur, start, end);
}

/// Piecewise-cursor move: per-lane-block dense slices (the fig 5 AoSoA
/// row — previously the one layout still paying dynamic translation).
/// Shard boundaries are lane-aligned ([`crate::view::shard_align`]),
/// so the block range below is exact: only the global tail block can
/// be partial, and `block_len` caps it.
fn mv_piecewise(cur: &[PiecewiseCursorMut<'_>], start: usize, end: usize) {
    let dense = cur[POS_X].is_dense::<f32>()
        && cur[POS_Y].is_dense::<f32>()
        && cur[POS_Z].is_dense::<f32>()
        && cur[VEL_X].is_dense::<f32>()
        && cur[VEL_Y].is_dense::<f32>()
        && cur[VEL_Z].is_dense::<f32>();
    if !dense {
        return mv_cursors(cur, start, end);
    }
    let lanes = cur[POS_X].lanes();
    debug_assert!(start % lanes == 0, "shard start {start} straddles a {lanes}-lane block");
    let (b0, b1) = (start / lanes, end.div_ceil(lanes));
    for b in b0..b1 {
        // SAFETY: b < blocks, density checked; one mutable slice per
        // distinct leaf — leaves of a valid mapping never overlap.
        unsafe {
            let px = cur[POS_X].block_slice_mut::<f32>(b);
            let py = cur[POS_Y].block_slice_mut::<f32>(b);
            let pz = cur[POS_Z].block_slice_mut::<f32>(b);
            let vx = cur[VEL_X].block_slice::<f32>(b);
            let vy = cur[VEL_Y].block_slice::<f32>(b);
            let vz = cur[VEL_Z].block_slice::<f32>(b);
            for k in 0..px.len() {
                px[k] += vx[k] * TIMESTEP;
                py[k] += vy[k] * TIMESTEP;
                pz[k] += vz[k] * TIMESTEP;
            }
        }
    }
}

/// Cursor move shared by the non-dense affine and piecewise paths.
fn mv_cursors<C: CursorWrite>(cur: &[C], start: usize, end: usize) {
    for i in start..end {
        // SAFETY: i < n == cursor count.
        unsafe {
            let x = cur[POS_X].read_at::<f32>(i) + cur[VEL_X].read_at::<f32>(i) * TIMESTEP;
            let y = cur[POS_Y].read_at::<f32>(i) + cur[VEL_Y].read_at::<f32>(i) * TIMESTEP;
            let z = cur[POS_Z].read_at::<f32>(i) + cur[VEL_Z].read_at::<f32>(i) * TIMESTEP;
            cur[POS_X].write_at::<f32>(i, x);
            cur[POS_Y].write_at::<f32>(i, y);
            cur[POS_Z].write_at::<f32>(i, z);
        }
    }
}

/// Shard-wise lane-batch update kernel ([`crate::view::simd`]): one
/// uniform cursor body for every plan shape — batches gather/scatter
/// through [`crate::view::simd::SimdCursorRead`], which is strided
/// scalar access for packed AoS and contiguous loads for SoA/AoSoA.
struct SimdUpdateKernel {
    n: usize,
    path: SimdPath,
}

impl ShardKernel for SimdUpdateKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        update_cursors_simd(cur, self.n, s.start, s.end, self.path);
    }
}

/// Shard-wise lane-batch move kernel; see [`SimdUpdateKernel`].
struct SimdMoveKernel {
    path: SimdPath,
}

impl ShardKernel for SimdMoveKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        mv_cursors_simd(cur, s.start, s.end, self.path);
    }
}

/// [`update`] on the best available SIMD path (see
/// [`crate::view::simd::detect`]); serial. Bit-identical to [`update`]
/// on every layout: lanes run the exact scalar `pp_interaction`
/// sequence and partial tail batches fall back to the scalar kernel.
pub fn update_simd<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    update_simd_parallel_with(view, 1, detect());
}

/// [`update_parallel`] on the best available SIMD path.
pub fn update_simd_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    update_simd_parallel_with(view, threads, detect());
}

/// Explicit-path lane-batch update (benches and the bit-identity
/// property tests pin the path). A `path` that cannot execute on this
/// build/host — e.g. [`SimdPath::Avx2`] without `--features simd`, or
/// on a non-AVX2 machine — runs [`SimdPath::Scalar`] instead, so this
/// entry point is safe everywhere. Generic plans (instrumented/curve
/// layouts) have no closed-form cursors to batch and run the scalar
/// accessor path on every `path`.
pub fn update_simd_parallel_with<M: Mapping, B: BlobMut>(
    view: &mut View<M, B>,
    threads: usize,
    path: SimdPath,
) {
    let path = if path.is_vector() { path } else { SimdPath::Scalar };
    let n = view.count();
    if par_execute(view, threads, &SimdUpdateKernel { n, path }) {
        return;
    }
    update_parallel(view, threads);
}

/// [`mv`] on the best available SIMD path; serial and bit-identical.
pub fn mv_simd<M: Mapping, B: BlobMut>(view: &mut View<M, B>) {
    mv_simd_parallel_with(view, 1, detect());
}

/// [`mv_parallel`] on the best available SIMD path.
pub fn mv_simd_parallel<M: Mapping, B: BlobMut>(view: &mut View<M, B>, threads: usize) {
    mv_simd_parallel_with(view, threads, detect());
}

/// Explicit-path lane-batch move; same path-sanitizing and fallback
/// contract as [`update_simd_parallel_with`].
pub fn mv_simd_parallel_with<M: Mapping, B: BlobMut>(
    view: &mut View<M, B>,
    threads: usize,
    path: SimdPath,
) {
    let path = if path.is_vector() { path } else { SimdPath::Scalar };
    if par_execute(view, threads, &SimdMoveKernel { path }) {
        return;
    }
    mv_parallel(view, threads);
}

/// Path dispatch for the update kernel. The vector arms only exist
/// when the `simd` feature targets x86_64; everywhere else every path
/// resolves to the scalar kernel.
fn update_cursors_simd<C: CursorWrite>(cur: &[C], n: usize, start: usize, end: usize, p: SimdPath) {
    match p {
        SimdPath::Scalar => update_cursors(cur, n, start, end),
        // SAFETY (both arms): callers sanitize `p` through
        // `SimdPath::is_vector`, so the ISA is present; cursors cover
        // `0..n` (par_execute contract).
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Avx2 => unsafe { simd_x86::update_shard_avx2(cur, n, start, end) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Sse2 => unsafe { simd_x86::update_shard_sse2(cur, n, start, end) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        SimdPath::Avx2 | SimdPath::Sse2 => update_cursors(cur, n, start, end),
    }
}

/// Path dispatch for the move kernel; see [`update_cursors_simd`].
fn mv_cursors_simd<C: CursorWrite>(cur: &[C], start: usize, end: usize, p: SimdPath) {
    match p {
        SimdPath::Scalar => mv_cursors(cur, start, end),
        // SAFETY (both arms): `p` sanitized via `is_vector`; cursors
        // cover the shard range.
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Avx2 => unsafe { simd_x86::mv_shard_avx2(cur, start, end) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdPath::Sse2 => unsafe { simd_x86::mv_shard_sse2(cur, start, end) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        SimdPath::Avx2 | SimdPath::Sse2 => mv_cursors(cur, start, end),
    }
}

/// The `core::arch` lane-batch kernels (compiled only with the `simd`
/// feature on x86_64). Batching is across i-records: each lane runs
/// the exact scalar `pp_interaction` operation sequence with the
/// j-record broadcast, using only IEEE-exact per-lane ops (sub, mul,
/// add, div, sqrt — no FMA contraction), so every lane reproduces the
/// scalar kernel bit for bit. Tail batches (`(end - start) % W != 0`)
/// run the scalar cursor kernel, which is value-identical per record.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_x86 {
    use super::{mv_cursors, update_cursors};
    use crate::view::cursor::CursorWrite;
    use crate::view::simd::{SimdCursorRead, SimdCursorWrite};
    use crate::workloads::nbody::{EPS2, MASS, POS_X, POS_Y, POS_Z, TIMESTEP, VEL_X, VEL_Y, VEL_Z};
    use core::arch::x86_64::*;

    /// Stage the j-stream once per shard: scalar cursor reads (the
    /// gather path for strided layouts) into dense scratch. O(n) setup
    /// against the O(n · shard_len) interaction loop; values are
    /// copied bit-exactly, so staging cannot change results.
    fn stage_j<C: CursorWrite>(cur: &[C], n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        let mut zs = Vec::with_capacity(n);
        let mut ms = Vec::with_capacity(n);
        for j in 0..n {
            // SAFETY: j < n == cursor count.
            unsafe {
                xs.push(cur[POS_X].read_at::<f32>(j));
                ys.push(cur[POS_Y].read_at::<f32>(j));
                zs.push(cur[POS_Z].read_at::<f32>(j));
                ms.push(cur[MASS].read_at::<f32>(j));
            }
        }
        (xs, ys, zs, ms)
    }

    /// AVX2 update over i-records `start..end`, 8 lanes per batch.
    ///
    /// # Safety
    /// AVX2 must be available at runtime; cursors must cover `0..n`.
    pub unsafe fn update_shard_avx2<C: CursorWrite>(cur: &[C], n: usize, start: usize, end: usize) {
        let (xs, ys, zs, ms) = stage_j(cur, n);
        let mut i = start;
        while i + 8 <= end {
            let pix: [f32; 8] = cur[POS_X].read_batch(i);
            let piy: [f32; 8] = cur[POS_Y].read_batch(i);
            let piz: [f32; 8] = cur[POS_Z].read_batch(i);
            let mut vel = [
                cur[VEL_X].read_batch::<f32, 8>(i),
                cur[VEL_Y].read_batch::<f32, 8>(i),
                cur[VEL_Z].read_batch::<f32, 8>(i),
            ];
            update_block_avx2(&pix, &piy, &piz, &mut vel, &xs, &ys, &zs, &ms);
            cur[VEL_X].write_batch(i, vel[0]);
            cur[VEL_Y].write_batch(i, vel[1]);
            cur[VEL_Z].write_batch(i, vel[2]);
            i += 8;
        }
        update_cursors(cur, n, i, end);
    }

    /// SSE2 update (x86_64 baseline), 4 lanes per batch.
    ///
    /// # Safety
    /// Cursors must cover `0..n`.
    pub unsafe fn update_shard_sse2<C: CursorWrite>(cur: &[C], n: usize, start: usize, end: usize) {
        let (xs, ys, zs, ms) = stage_j(cur, n);
        let mut i = start;
        while i + 4 <= end {
            let pix: [f32; 4] = cur[POS_X].read_batch(i);
            let piy: [f32; 4] = cur[POS_Y].read_batch(i);
            let piz: [f32; 4] = cur[POS_Z].read_batch(i);
            let mut vel = [
                cur[VEL_X].read_batch::<f32, 4>(i),
                cur[VEL_Y].read_batch::<f32, 4>(i),
                cur[VEL_Z].read_batch::<f32, 4>(i),
            ];
            update_block_sse2(&pix, &piy, &piz, &mut vel, &xs, &ys, &zs, &ms);
            cur[VEL_X].write_batch(i, vel[0]);
            cur[VEL_Y].write_batch(i, vel[1]);
            cur[VEL_Z].write_batch(i, vel[2]);
            i += 4;
        }
        update_cursors(cur, n, i, end);
    }

    /// One AVX2 i-batch against the whole j-stream; `pp_interaction`
    /// op-for-op per lane.
    ///
    /// # Safety
    /// AVX2 available; the four j-slices have equal length.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn update_block_avx2(
        pix: &[f32; 8],
        piy: &[f32; 8],
        piz: &[f32; 8],
        vel: &mut [[f32; 8]; 3],
        xs: &[f32],
        ys: &[f32],
        zs: &[f32],
        ms: &[f32],
    ) {
        let pix = _mm256_loadu_ps(pix.as_ptr());
        let piy = _mm256_loadu_ps(piy.as_ptr());
        let piz = _mm256_loadu_ps(piz.as_ptr());
        let mut vx = _mm256_loadu_ps(vel[0].as_ptr());
        let mut vy = _mm256_loadu_ps(vel[1].as_ptr());
        let mut vz = _mm256_loadu_ps(vel[2].as_ptr());
        let eps2 = _mm256_set1_ps(EPS2);
        let one = _mm256_set1_ps(1.0);
        let ts = _mm256_set1_ps(TIMESTEP);
        for ((&xj, &yj), (&zj, &mj)) in xs.iter().zip(ys).zip(zs.iter().zip(ms)) {
            let mut dx = _mm256_sub_ps(pix, _mm256_set1_ps(xj));
            let mut dy = _mm256_sub_ps(piy, _mm256_set1_ps(yj));
            let mut dz = _mm256_sub_ps(piz, _mm256_set1_ps(zj));
            dx = _mm256_mul_ps(dx, dx);
            dy = _mm256_mul_ps(dy, dy);
            dz = _mm256_mul_ps(dz, dz);
            let dist_sqr = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(eps2, dx), dy), dz);
            let dist_sixth = _mm256_mul_ps(_mm256_mul_ps(dist_sqr, dist_sqr), dist_sqr);
            let inv_dist_cube = _mm256_div_ps(one, _mm256_sqrt_ps(dist_sixth));
            let sts = _mm256_mul_ps(_mm256_mul_ps(_mm256_set1_ps(mj), inv_dist_cube), ts);
            vx = _mm256_add_ps(vx, _mm256_mul_ps(dx, sts));
            vy = _mm256_add_ps(vy, _mm256_mul_ps(dy, sts));
            vz = _mm256_add_ps(vz, _mm256_mul_ps(dz, sts));
        }
        _mm256_storeu_ps(vel[0].as_mut_ptr(), vx);
        _mm256_storeu_ps(vel[1].as_mut_ptr(), vy);
        _mm256_storeu_ps(vel[2].as_mut_ptr(), vz);
    }

    /// One SSE2 i-batch against the whole j-stream.
    ///
    /// # Safety
    /// The four j-slices have equal length.
    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn update_block_sse2(
        pix: &[f32; 4],
        piy: &[f32; 4],
        piz: &[f32; 4],
        vel: &mut [[f32; 4]; 3],
        xs: &[f32],
        ys: &[f32],
        zs: &[f32],
        ms: &[f32],
    ) {
        let pix = _mm_loadu_ps(pix.as_ptr());
        let piy = _mm_loadu_ps(piy.as_ptr());
        let piz = _mm_loadu_ps(piz.as_ptr());
        let mut vx = _mm_loadu_ps(vel[0].as_ptr());
        let mut vy = _mm_loadu_ps(vel[1].as_ptr());
        let mut vz = _mm_loadu_ps(vel[2].as_ptr());
        let eps2 = _mm_set1_ps(EPS2);
        let one = _mm_set1_ps(1.0);
        let ts = _mm_set1_ps(TIMESTEP);
        for ((&xj, &yj), (&zj, &mj)) in xs.iter().zip(ys).zip(zs.iter().zip(ms)) {
            let mut dx = _mm_sub_ps(pix, _mm_set1_ps(xj));
            let mut dy = _mm_sub_ps(piy, _mm_set1_ps(yj));
            let mut dz = _mm_sub_ps(piz, _mm_set1_ps(zj));
            dx = _mm_mul_ps(dx, dx);
            dy = _mm_mul_ps(dy, dy);
            dz = _mm_mul_ps(dz, dz);
            let dist_sqr = _mm_add_ps(_mm_add_ps(_mm_add_ps(eps2, dx), dy), dz);
            let dist_sixth = _mm_mul_ps(_mm_mul_ps(dist_sqr, dist_sqr), dist_sqr);
            let inv_dist_cube = _mm_div_ps(one, _mm_sqrt_ps(dist_sixth));
            let sts = _mm_mul_ps(_mm_mul_ps(_mm_set1_ps(mj), inv_dist_cube), ts);
            vx = _mm_add_ps(vx, _mm_mul_ps(dx, sts));
            vy = _mm_add_ps(vy, _mm_mul_ps(dy, sts));
            vz = _mm_add_ps(vz, _mm_mul_ps(dz, sts));
        }
        _mm_storeu_ps(vel[0].as_mut_ptr(), vx);
        _mm_storeu_ps(vel[1].as_mut_ptr(), vy);
        _mm_storeu_ps(vel[2].as_mut_ptr(), vz);
    }

    /// AVX2 move over `start..end`, 8 lanes per batch.
    ///
    /// # Safety
    /// AVX2 available; cursors cover the shard range.
    pub unsafe fn mv_shard_avx2<C: CursorWrite>(cur: &[C], start: usize, end: usize) {
        let mut i = start;
        while i + 8 <= end {
            let mut p = [
                cur[POS_X].read_batch::<f32, 8>(i),
                cur[POS_Y].read_batch::<f32, 8>(i),
                cur[POS_Z].read_batch::<f32, 8>(i),
            ];
            let v = [
                cur[VEL_X].read_batch::<f32, 8>(i),
                cur[VEL_Y].read_batch::<f32, 8>(i),
                cur[VEL_Z].read_batch::<f32, 8>(i),
            ];
            mv_block_avx2(&mut p, &v);
            cur[POS_X].write_batch(i, p[0]);
            cur[POS_Y].write_batch(i, p[1]);
            cur[POS_Z].write_batch(i, p[2]);
            i += 8;
        }
        mv_cursors(cur, i, end);
    }

    /// SSE2 move over `start..end`, 4 lanes per batch.
    ///
    /// # Safety
    /// Cursors cover the shard range.
    pub unsafe fn mv_shard_sse2<C: CursorWrite>(cur: &[C], start: usize, end: usize) {
        let mut i = start;
        while i + 4 <= end {
            let mut p = [
                cur[POS_X].read_batch::<f32, 4>(i),
                cur[POS_Y].read_batch::<f32, 4>(i),
                cur[POS_Z].read_batch::<f32, 4>(i),
            ];
            let v = [
                cur[VEL_X].read_batch::<f32, 4>(i),
                cur[VEL_Y].read_batch::<f32, 4>(i),
                cur[VEL_Z].read_batch::<f32, 4>(i),
            ];
            mv_block_sse2(&mut p, &v);
            cur[POS_X].write_batch(i, p[0]);
            cur[POS_Y].write_batch(i, p[1]);
            cur[POS_Z].write_batch(i, p[2]);
            i += 4;
        }
        mv_cursors(cur, i, end);
    }

    /// `pos += vel * TIMESTEP` on 8 lanes.
    ///
    /// # Safety
    /// AVX2 available.
    #[target_feature(enable = "avx2")]
    unsafe fn mv_block_avx2(p: &mut [[f32; 8]; 3], v: &[[f32; 8]; 3]) {
        let ts = _mm256_set1_ps(TIMESTEP);
        for (pd, vd) in p.iter_mut().zip(v) {
            let x = _mm256_loadu_ps(pd.as_ptr());
            let y = _mm256_loadu_ps(vd.as_ptr());
            _mm256_storeu_ps(pd.as_mut_ptr(), _mm256_add_ps(x, _mm256_mul_ps(y, ts)));
        }
    }

    /// `pos += vel * TIMESTEP` on 4 lanes.
    ///
    /// # Safety
    /// SSE2 (x86_64 baseline).
    #[target_feature(enable = "sse2")]
    unsafe fn mv_block_sse2(p: &mut [[f32; 4]; 3], v: &[[f32; 4]; 3]) {
        let ts = _mm_set1_ps(TIMESTEP);
        for (pd, vd) in p.iter_mut().zip(v) {
            let x = _mm_loadu_ps(pd.as_ptr());
            let y = _mm_loadu_ps(vd.as_ptr());
            _mm_storeu_ps(pd.as_mut_ptr(), _mm_add_ps(x, _mm_mul_ps(y, ts)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoS, AoSoA, SoA, Split};
    use crate::record::RecordCoord;
    use crate::view::alloc_view;
    use crate::workloads::nbody::manual::NBodyAoS;
    use crate::workloads::nbody::{init_particles, max_rel_error, particle_dim};

    fn run_llama<M: Mapping>(mapping: M, s: &ParticleSoA, steps: usize) -> ParticleSoA {
        let mut v = alloc_view(mapping);
        load_state(&mut v, s);
        for _ in 0..steps {
            update(&mut v);
            mv(&mut v);
        }
        store_state(&v)
    }

    fn reference(s: &ParticleSoA, steps: usize) -> ParticleSoA {
        let mut aos = NBodyAoS::from_state(s);
        for _ in 0..steps {
            aos.update();
            aos.mv();
        }
        aos.to_state()
    }

    #[test]
    fn llama_matches_manual_on_every_mapping() {
        let s = init_particles(96, 21);
        let expect = reference(&s, 2);
        let d = particle_dim();
        let dims = ArrayDims::linear(96);
        let cases: Vec<(&str, ParticleSoA)> = vec![
            ("aos_aligned", run_llama(AoS::aligned(&d, dims.clone()), &s, 2)),
            ("aos_packed", run_llama(AoS::packed(&d, dims.clone()), &s, 2)),
            ("soa_mb", run_llama(SoA::multi_blob(&d, dims.clone()), &s, 2)),
            ("soa_sb", run_llama(SoA::single_blob(&d, dims.clone()), &s, 2)),
            ("aosoa8", run_llama(AoSoA::new(&d, dims.clone(), 8), &s, 2)),
            // 96 % 7 != 0: the piecewise kernel's tail block.
            ("aosoa7_tail", run_llama(AoSoA::new(&d, dims.clone(), 7), &s, 2)),
            (
                "split_pos",
                run_llama(
                    Split::new(
                        &d,
                        dims.clone(),
                        RecordCoord::new(vec![0]),
                        |sd, ad| SoA::multi_blob(sd, ad),
                        |sd, ad| AoS::aligned(sd, ad),
                    ),
                    &s,
                    2,
                ),
            ),
        ];
        for (name, got) in cases {
            let e = max_rel_error(&expect, &got);
            // Same loop structure, same arithmetic order -> results are
            // bit-identical regardless of layout.
            assert!(e == 0.0, "{name}: rel err {e}");
        }
    }

    #[test]
    fn blocked_and_tiled_variants_agree() {
        let s = init_particles(70, 4);
        let d = particle_dim();
        let dims = ArrayDims::linear(70);
        let expect = reference(&s, 1);

        let mut v = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        load_state(&mut v, &s);
        update_blocked(&mut v, 16);
        mv(&mut v);
        assert_eq!(max_rel_error(&expect, &store_state(&v)), 0.0);

        let mut v = alloc_view(SoA::multi_blob(&d, dims.clone()));
        load_state(&mut v, &s);
        update_tiled(&mut v, 32);
        mv(&mut v);
        // Tiling reorders the j-loop in blocks; same order actually
        // (tiles are processed in ascending j), so still identical.
        assert_eq!(max_rel_error(&expect, &store_state(&v)), 0.0);
    }

    #[test]
    fn parallel_update_and_move_are_bit_identical() {
        // Each record's arithmetic is self-contained, so sharding only
        // changes scheduling: any thread count must reproduce the
        // serial result exactly, including AoSoA tail blocks.
        let s = init_particles(97, 9); // 97: tails at every lane count
        let d = particle_dim();
        let dims = ArrayDims::linear(97);
        fn run_par<M: Mapping>(mapping: M, s: &ParticleSoA, threads: usize) -> ParticleSoA {
            let mut v = alloc_view(mapping);
            load_state(&mut v, s);
            for _ in 0..2 {
                update_parallel(&mut v, threads);
                mv_parallel(&mut v, threads);
            }
            store_state(&v)
        }
        let expect = run_par(AoS::aligned(&d, dims.clone()), &s, 1);
        for threads in [2usize, 3, 8] {
            assert_eq!(expect, run_par(AoS::aligned(&d, dims.clone()), &s, threads));
            assert_eq!(expect, run_par(SoA::multi_blob(&d, dims.clone()), &s, threads));
            assert_eq!(expect, run_par(AoSoA::new(&d, dims.clone(), 8), &s, threads));
            assert_eq!(expect, run_par(AoSoA::new(&d, dims.clone(), 16), &s, threads));
        }
    }

    #[test]
    fn simd_paths_are_bit_identical_to_scalar() {
        // Every dispatchable path (always at least Scalar; Sse2/Avx2
        // when built with --features simd on capable hosts) must
        // reproduce the scalar kernels bit for bit, on every plan
        // shape: strided affine (the packed-AoS gather path), dense
        // affine, and piecewise with tail blocks (97 records).
        let s = init_particles(97, 13);
        let d = particle_dim();
        let dims = ArrayDims::linear(97);
        fn run_simd<M: Mapping>(
            mapping: M,
            s: &ParticleSoA,
            threads: usize,
            path: crate::view::simd::SimdPath,
        ) -> ParticleSoA {
            let mut v = alloc_view(mapping);
            load_state(&mut v, s);
            for _ in 0..2 {
                update_simd_parallel_with(&mut v, threads, path);
                mv_simd_parallel_with(&mut v, threads, path);
            }
            store_state(&v)
        }
        let expect = {
            let mut v = alloc_view(AoS::aligned(&d, dims.clone()));
            load_state(&mut v, &s);
            for _ in 0..2 {
                update(&mut v);
                mv(&mut v);
            }
            store_state(&v)
        };
        for path in crate::view::simd::available_paths() {
            for threads in [1usize, 3] {
                let run = |m: &str, got: ParticleSoA| {
                    assert_eq!(expect, got, "{m} path {path:?} threads {threads}");
                };
                run("aos_aligned", run_simd(AoS::aligned(&d, dims.clone()), &s, threads, path));
                run("aos_packed", run_simd(AoS::packed(&d, dims.clone()), &s, threads, path));
                run("soa_mb", run_simd(SoA::multi_blob(&d, dims.clone()), &s, threads, path));
                run("aosoa4", run_simd(AoSoA::new(&d, dims.clone(), 4), &s, threads, path));
                run("aosoa16", run_simd(AoSoA::new(&d, dims.clone(), 16), &s, threads, path));
            }
        }
    }

    #[test]
    fn load_store_roundtrip() {
        let s = init_particles(33, 77);
        let d = particle_dim();
        let mut v = alloc_view(AoSoA::new(&d, ArrayDims::linear(33), 4));
        load_state(&mut v, &s);
        assert_eq!(store_state(&v), s);
    }
}
