//! Manually written n-body data structures — the paper's hand-rolled
//! baselines in fig 5: AoS (`Vec<Particle>`), SoA (seven `Vec<f32>`),
//! and AoSoA with nested block loops (the loop structure the paper
//! notes is required for vectorizing AoSoA).

use super::{pp_interaction, ParticleSoA, TIMESTEP};

/// Classic array-of-structs particle, 7 f32 fields (packed: 28 B).
#[derive(Debug, Clone, Copy, Default)]
#[repr(C)]
pub struct Particle {
    pub pos: [f32; 3],
    pub vel: [f32; 3],
    pub mass: f32,
}

/// Manual AoS implementation.
#[derive(Debug, Clone)]
pub struct NBodyAoS {
    pub particles: Vec<Particle>,
}

impl NBodyAoS {
    pub fn from_state(s: &ParticleSoA) -> Self {
        let particles = (0..s.n())
            .map(|i| Particle {
                pos: [s.pos[0][i], s.pos[1][i], s.pos[2][i]],
                vel: [s.vel[0][i], s.vel[1][i], s.vel[2][i]],
                mass: s.mass[i],
            })
            .collect();
        NBodyAoS { particles }
    }

    pub fn to_state(&self) -> ParticleSoA {
        let n = self.particles.len();
        let mut s = super::init_particles(0, 0);
        for d in 0..3 {
            s.pos[d] = Vec::with_capacity(n);
            s.vel[d] = Vec::with_capacity(n);
        }
        for p in &self.particles {
            for d in 0..3 {
                s.pos[d].push(p.pos[d]);
                s.vel[d].push(p.vel[d]);
            }
            s.mass.push(p.mass);
        }
        s
    }

    pub fn update(&mut self) {
        let n = self.particles.len();
        for i in 0..n {
            let pi = self.particles[i];
            let mut vel = pi.vel;
            for j in 0..n {
                let pj = &self.particles[j];
                pp_interaction(
                    pi.pos[0], pi.pos[1], pi.pos[2], pj.pos[0], pj.pos[1], pj.pos[2], pj.mass,
                    &mut vel,
                );
            }
            self.particles[i].vel = vel;
        }
    }

    pub fn mv(&mut self) {
        for p in &mut self.particles {
            for d in 0..3 {
                p.pos[d] += p.vel[d] * TIMESTEP;
            }
        }
    }
}

/// Manual SoA implementation (seven separate arrays — the paper's
/// "SoA MB" twin).
#[derive(Debug, Clone)]
pub struct NBodySoA {
    pub state: ParticleSoA,
}

impl NBodySoA {
    pub fn from_state(s: &ParticleSoA) -> Self {
        NBodySoA { state: s.clone() }
    }

    pub fn update(&mut self) {
        let n = self.state.n();
        let (px, py, pz) = (&self.state.pos[0], &self.state.pos[1], &self.state.pos[2]);
        let mass = &self.state.mass;
        for i in 0..n {
            let (pix, piy, piz) = (px[i], py[i], pz[i]);
            let mut vel = [self.state.vel[0][i], self.state.vel[1][i], self.state.vel[2][i]];
            for j in 0..n {
                pp_interaction(pix, piy, piz, px[j], py[j], pz[j], mass[j], &mut vel);
            }
            self.state.vel[0][i] = vel[0];
            self.state.vel[1][i] = vel[1];
            self.state.vel[2][i] = vel[2];
        }
    }

    pub fn mv(&mut self) {
        let n = self.state.n();
        for d in 0..3 {
            let (pos, vel) = {
                // Split borrows of pos[d] / vel[d].
                let s = &mut self.state;
                let pos = s.pos[d].as_mut_ptr();
                let vel = s.vel[d].as_ptr();
                (pos, vel)
            };
            // SAFETY: pos and vel are distinct Vecs; indices < n.
            unsafe {
                for i in 0..n {
                    *pos.add(i) += *vel.add(i) * TIMESTEP;
                }
            }
        }
    }
}

/// One AoSoA block of `L` particles: per-field lane arrays.
#[derive(Debug, Clone)]
pub struct Block<const L: usize> {
    pub pos: [[f32; L]; 3],
    pub vel: [[f32; L]; 3],
    pub mass: [f32; L],
}

impl<const L: usize> Default for Block<L> {
    fn default() -> Self {
        Block { pos: [[0.0; L]; 3], vel: [[0.0; L]; 3], mass: [0.0; L] }
    }
}

/// Manual AoSoA implementation with the two-level loop structure the
/// paper describes (§4.1: "these use two nested loops ... allowing the
/// compiler to fully unroll and vectorize").
#[derive(Debug, Clone)]
pub struct NBodyAoSoA<const L: usize> {
    pub blocks: Vec<Block<L>>,
    pub n: usize,
}

impl<const L: usize> NBodyAoSoA<L> {
    pub fn from_state(s: &ParticleSoA) -> Self {
        let n = s.n();
        let nblocks = n.div_ceil(L);
        let mut blocks = vec![Block::<L>::default(); nblocks];
        for i in 0..n {
            let (b, l) = (i / L, i % L);
            for d in 0..3 {
                blocks[b].pos[d][l] = s.pos[d][i];
                blocks[b].vel[d][l] = s.vel[d][i];
            }
            blocks[b].mass[l] = s.mass[i];
        }
        NBodyAoSoA { blocks, n }
    }

    pub fn to_state(&self) -> ParticleSoA {
        let mut s = super::init_particles(0, 0);
        for i in 0..self.n {
            let (b, l) = (i / L, i % L);
            for d in 0..3 {
                s.pos[d].push(self.blocks[b].pos[d][l]);
                s.vel[d].push(self.blocks[b].vel[d][l]);
            }
            s.mass.push(self.blocks[b].mass[l]);
        }
        s
    }

    pub fn update(&mut self) {
        let nblocks = self.blocks.len();
        // Tail lanes hold mass 0 -> they contribute sts = 0 exactly.
        for bi in 0..nblocks {
            for li in 0..L {
                let i = bi * L + li;
                if i >= self.n {
                    break;
                }
                let pix = self.blocks[bi].pos[0][li];
                let piy = self.blocks[bi].pos[1][li];
                let piz = self.blocks[bi].pos[2][li];
                let mut vel = [
                    self.blocks[bi].vel[0][li],
                    self.blocks[bi].vel[1][li],
                    self.blocks[bi].vel[2][li],
                ];
                for bj in 0..nblocks {
                    let blk = &self.blocks[bj];
                    // Inner loop with compile-time trip count L.
                    for lj in 0..L {
                        pp_interaction(
                            pix,
                            piy,
                            piz,
                            blk.pos[0][lj],
                            blk.pos[1][lj],
                            blk.pos[2][lj],
                            blk.mass[lj],
                            &mut vel,
                        );
                    }
                }
                self.blocks[bi].vel[0][li] = vel[0];
                self.blocks[bi].vel[1][li] = vel[1];
                self.blocks[bi].vel[2][li] = vel[2];
            }
        }
    }

    pub fn mv(&mut self) {
        for blk in &mut self.blocks {
            for d in 0..3 {
                for l in 0..L {
                    blk.pos[d][l] += blk.vel[d][l] * TIMESTEP;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nbody::{init_particles, max_rel_error};

    #[test]
    fn aos_and_soa_agree() {
        let s = init_particles(128, 11);
        let mut aos = NBodyAoS::from_state(&s);
        let mut soa = NBodySoA::from_state(&s);
        for _ in 0..2 {
            aos.update();
            aos.mv();
            soa.update();
            soa.mv();
        }
        let e = max_rel_error(&aos.to_state(), &soa.state);
        assert!(e < 1e-4, "rel err {e}");
    }

    #[test]
    fn aosoa_agrees_with_aos() {
        let s = init_particles(100, 5); // non-multiple of lanes
        let mut aos = NBodyAoS::from_state(&s);
        let mut a8 = NBodyAoSoA::<8>::from_state(&s);
        let mut a16 = NBodyAoSoA::<16>::from_state(&s);
        aos.update();
        aos.mv();
        a8.update();
        a8.mv();
        a16.update();
        a16.mv();
        assert!(max_rel_error(&aos.to_state(), &a8.to_state()) < 1e-4);
        assert!(max_rel_error(&aos.to_state(), &a16.to_state()) < 1e-4);
    }

    #[test]
    fn move_only_changes_positions() {
        let s = init_particles(32, 2);
        let mut aos = NBodyAoS::from_state(&s);
        aos.mv();
        let after = aos.to_state();
        assert_eq!(after.vel, s.vel);
        assert_eq!(after.mass, s.mass);
        assert_ne!(after.pos, s.pos);
    }

    #[test]
    fn roundtrip_state_conversions() {
        let s = init_particles(37, 8);
        assert_eq!(NBodyAoS::from_state(&s).to_state(), s);
        assert_eq!(NBodyAoSoA::<16>::from_state(&s).to_state(), s);
    }
}
