//! SplitMix64: a tiny deterministic PRNG so every workload and test is
//! reproducible without external crates.

/// SplitMix64 generator (Steele, Lea & Flood 2014). Full 2^64 period,
/// passes BigCrush; more than adequate for workload initialization.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn float_ranges() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.range_f32(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&g));
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = SplitMix64::new(123);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
