//! PIConGPU-style particle frame lists (paper §4.4, figs 9/10).
//!
//! Substitution note (DESIGN.md): PIConGPU is a large CUDA code base;
//! the paper swaps the attribute storage *inside its particle frames*
//! for LLAMA views. We reproduce that data structure faithfully:
//! supercells own doubly-linked lists of fixed-size frames (256
//! particles, "configurable but usually 256 to map well to a thread
//! block"); each frame stores the particle attributes behind an
//! exchangeable LLAMA mapping; particles move between frames as they
//! cross supercell borders, and frames are allocated/deallocated on
//! demand — exactly the traversal pattern fig 10 benchmarks.

pub mod frames;

use crate::record::RecordDim;

/// Particles per frame (PIConGPU default).
pub const FRAME_SIZE: usize = 256;

/// Flat leaf indices of the particle attribute record.
pub const POS_X: usize = 0;
pub const POS_Y: usize = 1;
pub const POS_Z: usize = 2;
pub const MOM_X: usize = 3;
pub const MOM_Y: usize = 4;
pub const MOM_Z: usize = 5;
pub const WEIGHTING: usize = 6;
pub const CELL_IDX: usize = 7;
pub const LEAVES: usize = 8;

/// The PIConGPU-like particle attribute set: position (relative to the
/// supercell, in [0,1)³ per cell grid units), momentum, macro-particle
/// weighting, and the in-supercell cell index.
pub fn attr_dim() -> RecordDim {
    crate::record_dim! {
        pos: { x: f32, y: f32, z: f32 },
        mom: { x: f32, y: f32, z: f32 },
        weighting: f32,
        cell_idx: i32,
    }
}

/// Plain value struct for inserting/extracting particles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParticleAttrs {
    pub pos: [f32; 3],
    pub mom: [f32; 3],
    pub weighting: f32,
    pub cell_idx: i32,
}

impl ParticleAttrs {
    pub fn zero() -> Self {
        ParticleAttrs { pos: [0.0; 3], mom: [0.0; 3], weighting: 0.0, cell_idx: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attr_record_shape() {
        let d = attr_dim();
        assert_eq!(d.leaf_count(), LEAVES);
        assert_eq!(d.packed_size(), 7 * 4 + 4);
        let info = crate::record::RecordInfo::new(&d);
        assert_eq!(info.leaf_by_path("mom.y"), Some(MOM_Y));
        assert_eq!(info.leaf_by_path("cell_idx"), Some(CELL_IDX));
    }
}
