//! Supercell frame lists: a slab arena of fixed-size frames, each
//! holding particle attributes behind an exchangeable LLAMA mapping
//! (paper fig 9), plus the drift/exchange sweep benched in fig 10.

use super::{
    ParticleAttrs, CELL_IDX, FRAME_SIZE, MOM_X, MOM_Y, MOM_Z, POS_X, POS_Y, POS_Z, WEIGHTING,
};
use crate::blob::{Blob, BlobAllocator, BlobMut, VecAlloc};
use crate::mapping::Mapping;
use crate::view::cursor::{CursorRead, CursorWrite, PlanCursors};
use crate::view::shard::{par_execute, shard_range, Shard, ShardKernel};
use crate::view::View;
use crate::workloads::rng::SplitMix64;

/// One particle frame: an attribute view over `FRAME_SIZE` slots plus
/// the doubly-linked-list pointers of fig 9. Generic over the blob
/// storage (`Vec<u8>` by default; a pooled store's frames hold
/// [`crate::blob::PooledBytes`]).
#[derive(Debug)]
pub struct Frame<M: Mapping, B: Blob = Vec<u8>> {
    pub view: View<M, B>,
    pub prev: Option<usize>,
    pub next: Option<usize>,
    /// Number of used slots; only the *last* frame of a list may be
    /// partially filled (PIConGPU invariant).
    pub filled: usize,
}

/// A supercell's frame list.
#[derive(Debug, Clone, Default)]
struct CellList {
    head: Option<usize>,
    tail: Option<usize>,
}

/// The particle store: supercells × frame lists over a frame arena.
///
/// `M` must be `Clone` so each new frame instantiates the same mapping
/// (the layout under test). `A` is the blob allocator every frame
/// draws from — with a [`crate::blob::BlobPool`]
/// ([`ParticleStore::with_allocator`]) the arena *recycles*: frames
/// freed by [`ParticleStore::exchange`] return their blobs to the
/// pool's size classes and the next allocated frame pops them back,
/// so steady-state frame churn performs zero fresh allocations.
#[derive(Debug)]
pub struct ParticleStore<M: Mapping + Clone, A: BlobAllocator = VecAlloc> {
    proto: M,
    alloc: A,
    /// Supercell grid extents.
    pub grid: [usize; 3],
    frames: Vec<Option<Frame<M, A::Blob>>>,
    free: Vec<usize>,
    cells: Vec<CellList>,
    particles: usize,
}

impl<M: Mapping + Clone> ParticleStore<M, VecAlloc> {
    /// `proto`: a mapping over `ArrayDims::linear(FRAME_SIZE)` used for
    /// every frame. `grid`: supercell grid extents. Frames hold plain
    /// `Vec<u8>` blobs; see [`ParticleStore::with_allocator`] for
    /// pooled or aligned storage.
    pub fn new(proto: M, grid: [usize; 3]) -> Self {
        Self::with_allocator(proto, grid, VecAlloc)
    }
}

impl<M: Mapping + Clone, A: BlobAllocator> ParticleStore<M, A> {
    /// [`ParticleStore::new`] with an explicit blob allocator for the
    /// frame arena (paper §3.8: `allocView(mapping, blobAlloc)` as a
    /// whole-data-structure property).
    pub fn with_allocator(proto: M, grid: [usize; 3], alloc: A) -> Self {
        assert_eq!(proto.dims().count(), FRAME_SIZE, "frame mapping must cover FRAME_SIZE");
        let ncells = grid[0] * grid[1] * grid[2];
        ParticleStore {
            proto,
            alloc,
            grid,
            frames: Vec::new(),
            free: Vec::new(),
            cells: vec![CellList::default(); ncells],
            particles: 0,
        }
    }

    /// The allocator the frame arena draws from.
    pub fn allocator(&self) -> &A {
        &self.alloc
    }

    pub fn cell_count(&self) -> usize {
        self.cells.len()
    }

    pub fn particle_count(&self) -> usize {
        self.particles
    }

    /// Number of live (allocated) frames.
    pub fn frame_count(&self) -> usize {
        self.frames.iter().filter(|f| f.is_some()).count()
    }

    fn alloc_frame(&mut self) -> usize {
        let frame = Frame {
            view: crate::view::alloc_view_with(self.proto.clone(), &self.alloc),
            prev: None,
            next: None,
            filled: 0,
        };
        if let Some(idx) = self.free.pop() {
            self.frames[idx] = Some(frame);
            idx
        } else {
            self.frames.push(Some(frame));
            self.frames.len() - 1
        }
    }

    fn free_frame(&mut self, idx: usize) {
        self.frames[idx] = None;
        self.free.push(idx);
    }

    fn frame(&self, idx: usize) -> &Frame<M, A::Blob> {
        self.frames[idx].as_ref().expect("stale frame index")
    }

    fn frame_mut(&mut self, idx: usize) -> &mut Frame<M, A::Blob> {
        self.frames[idx].as_mut().expect("stale frame index")
    }

    /// Append a particle to a supercell (fills the tail frame,
    /// allocating a new one when full).
    pub fn push(&mut self, cell: usize, p: ParticleAttrs) {
        let tail = self.cells[cell].tail;
        let frame_idx = match tail {
            Some(t) if self.frame(t).filled < FRAME_SIZE => t,
            _ => {
                let f = self.alloc_frame();
                match tail {
                    Some(t) => {
                        self.frame_mut(t).next = Some(f);
                        self.frame_mut(f).prev = Some(t);
                        self.cells[cell].tail = Some(f);
                    }
                    None => {
                        self.cells[cell].head = Some(f);
                        self.cells[cell].tail = Some(f);
                    }
                }
                f
            }
        };
        let frame = self.frame_mut(frame_idx);
        let slot = frame.filled;
        write_particle(&mut frame.view, slot, &p);
        frame.filled += 1;
        self.particles += 1;
    }

    /// Remove the particle at (frame, slot), keeping the "only the tail
    /// frame is partial" invariant by swapping in the last particle of
    /// the cell's tail frame.
    fn remove(&mut self, cell: usize, frame_idx: usize, slot: usize) {
        let tail = self.cells[cell].tail.expect("cell has no frames");
        let last_slot = self.frame(tail).filled - 1;
        if !(frame_idx == tail && slot == last_slot) {
            let last = read_particle(&self.frame(tail).view, last_slot);
            write_particle(&mut self.frame_mut(frame_idx).view, slot, &last);
        }
        self.frame_mut(tail).filled -= 1;
        self.particles -= 1;
        if self.frame(tail).filled == 0 {
            // Unlink and free the now-empty tail frame.
            let prev = self.frame(tail).prev;
            match prev {
                Some(p) => {
                    self.frame_mut(p).next = None;
                    self.cells[cell].tail = Some(p);
                }
                None => {
                    self.cells[cell].head = None;
                    self.cells[cell].tail = None;
                }
            }
            self.free_frame(tail);
        }
    }

    /// Iterate (frame index, filled) of a cell's frames, head to tail.
    fn frames_of(&self, cell: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut cur = self.cells[cell].head;
        while let Some(idx) = cur {
            let f = self.frame(idx);
            out.push((idx, f.filled));
            cur = f.next;
        }
        out
    }

    /// Collect every particle of a cell (diagnostics/tests).
    pub fn cell_particles(&self, cell: usize) -> Vec<ParticleAttrs> {
        self.frames_of(cell)
            .into_iter()
            .flat_map(|(idx, filled)| {
                let f = self.frame(idx);
                (0..filled).map(|s| read_particle(&f.view, s)).collect::<Vec<_>>()
            })
            .collect()
    }

    /// Populate with `per_cell` random particles in every supercell.
    pub fn populate(&mut self, per_cell: usize, seed: u64) {
        let mut rng = SplitMix64::new(seed);
        for cell in 0..self.cells.len() {
            for _ in 0..per_cell {
                self.push(
                    cell,
                    ParticleAttrs {
                        pos: [rng.next_f32(), rng.next_f32(), rng.next_f32()],
                        mom: [
                            rng.range_f32(-0.3, 0.3),
                            rng.range_f32(-0.3, 0.3),
                            rng.range_f32(-0.3, 0.3),
                        ],
                        weighting: rng.range_f32(0.5, 1.5),
                        cell_idx: rng.below(FRAME_SIZE) as i32,
                    },
                );
            }
        }
    }

    /// The memory-bound attribute sweep of fig 10: advance every
    /// particle's position by its momentum (in-supercell coordinates,
    /// positions may leave [0,1)³ until [`ParticleStore::exchange`]).
    pub fn drift(&mut self, dt: f32)
    where
        A::Blob: Send,
    {
        self.drift_parallel(dt, 1);
    }

    /// [`ParticleStore::drift`] with the frame arena split into
    /// disjoint chunks by the shared shard splitter, one scoped worker
    /// per chunk (frames are small, so the parallel grain is the
    /// arena, not the frame; each frame's sweep still runs through the
    /// plan-driven executor). Any thread count is bit-identical to the
    /// serial sweep: every particle's arithmetic is self-contained.
    pub fn drift_parallel(&mut self, dt: f32, threads: usize)
    where
        A::Blob: Send,
    {
        let shards = shard_range(self.frames.len(), threads, 1);
        if shards.len() <= 1 {
            for f in self.frames.iter_mut().flatten() {
                drift_frame(f, dt);
            }
            return;
        }
        // The splitter's shards are equal-sized except the tail, so
        // `chunks_mut` reproduces the same partition with clean
        // disjoint borrows for the workers.
        let per = shards[0].len();
        std::thread::scope(|scope| {
            for chunk in self.frames.chunks_mut(per) {
                scope.spawn(move || {
                    for f in chunk.iter_mut().flatten() {
                        drift_frame(f, dt);
                    }
                });
            }
        });
    }

    /// A charge-deposit-like reduction: sum weighting per supercell
    /// (read-only sweep over two of eight attributes).
    pub fn deposit(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.cells.len()];
        for (cell, acc) in out.iter_mut().enumerate() {
            let mut sum = 0.0f64;
            for (idx, filled) in self.frames_of(cell) {
                let f = self.frame(idx);
                for s in 0..filled {
                    sum += f.view.get::<f32>(s, WEIGHTING) as f64;
                }
            }
            *acc = sum;
        }
        out
    }

    /// Move particles whose position left [0,1)³ to the neighbouring
    /// supercell (periodic), wrapping their position — the
    /// frame-list-churning phase of the PIConGPU pattern.
    pub fn exchange(&mut self) {
        let ncells = self.cells.len();
        for cell in 0..ncells {
            // Collect movers first (removal swaps particles around).
            let mut movers: Vec<(usize, usize)> = Vec::new();
            for (fidx, filled) in self.frames_of(cell) {
                for s in 0..filled {
                    let f = self.frame(fidx);
                    let px = f.view.get::<f32>(s, POS_X);
                    let py = f.view.get::<f32>(s, POS_Y);
                    let pz = f.view.get::<f32>(s, POS_Z);
                    if !(0.0..1.0).contains(&px)
                        || !(0.0..1.0).contains(&py)
                        || !(0.0..1.0).contains(&pz)
                    {
                        movers.push((fidx, s));
                    }
                }
            }
            // Remove back-to-front so pending (frame, slot) handles stay
            // valid under the swap-with-tail removal.
            movers.sort_by(|a, b| b.cmp(a));
            for (fidx, s) in movers {
                let mut p = read_particle(&self.frame(fidx).view, s);
                self.remove(cell, fidx, s);
                let target = self.neighbour_cell(cell, &mut p.pos);
                self.push(target, p);
            }
        }
    }

    /// Destination supercell for an out-of-bounds position; wraps the
    /// position back into [0,1)³.
    fn neighbour_cell(&self, cell: usize, pos: &mut [f32; 3]) -> usize {
        let [gx, gy, gz] = self.grid;
        let mut c = [cell / (gy * gz), (cell / gz) % gy, cell % gz];
        let dims = [gx, gy, gz];
        for d in 0..3 {
            while pos[d] < 0.0 {
                pos[d] += 1.0;
                c[d] = (c[d] + dims[d] - 1) % dims[d];
            }
            while pos[d] >= 1.0 {
                pos[d] -= 1.0;
                c[d] = (c[d] + 1) % dims[d];
            }
        }
        (c[0] * self.grid[1] + c[1]) * self.grid[2] + c[2]
    }

    /// Exchange the attribute layout of the whole store (paper fig 9:
    /// the frame's mapping is an exchangeable template parameter):
    /// compile the (old proto, new proto) pair into **one**
    /// [`crate::copy::CopyProgram`] and replay it per frame — the
    /// frames all share the same extent and mapping pair, so the chunk
    /// intersection derivation runs once, not once per frame. The new
    /// store shares this store's allocator: with a pooled arena, the
    /// reshuffled frames draw from (and the old store's frames later
    /// return to) the same size-class free lists.
    pub fn reshuffle<M2: Mapping + Clone>(&self, proto: M2) -> ParticleStore<M2, A>
    where
        A: Clone,
    {
        assert_eq!(proto.dims().count(), FRAME_SIZE, "frame mapping must cover FRAME_SIZE");
        let prog = crate::copy::CopyProgram::compile(&self.proto, &proto);
        let frames = self
            .frames
            .iter()
            .map(|slot| {
                slot.as_ref().map(|f| {
                    let mut view = crate::view::alloc_view_with(proto.clone(), &self.alloc);
                    prog.execute(&f.view, &mut view);
                    Frame { view, prev: f.prev, next: f.next, filled: f.filled }
                })
            })
            .collect();
        ParticleStore {
            proto,
            alloc: self.alloc.clone(),
            grid: self.grid,
            frames,
            free: self.free.clone(),
            cells: self.cells.clone(),
            particles: self.particles,
        }
    }

    /// Check all frame-list invariants (tests & failure injection).
    pub fn check_invariants(&self) -> crate::error::Result<()> {
        let mut counted = 0usize;
        for (cell, list) in self.cells.iter().enumerate() {
            let mut cur = list.head;
            let mut prev: Option<usize> = None;
            while let Some(idx) = cur {
                let f = self.frames[idx]
                    .as_ref()
                    .ok_or_else(|| crate::anyhow!("cell {cell}: freed frame linked"))?;
                crate::ensure!(f.prev == prev, "cell {cell}: prev link broken at {idx}");
                crate::ensure!(
                    f.next.is_none() || f.filled == FRAME_SIZE,
                    "cell {cell}: non-tail frame {idx} is partial"
                );
                crate::ensure!(f.filled > 0, "cell {cell}: empty frame {idx} kept");
                counted += f.filled;
                prev = cur;
                cur = f.next;
            }
            crate::ensure!(list.tail == prev, "cell {cell}: tail mismatch");
        }
        crate::ensure!(
            counted == self.particles,
            "particle count {counted} != {}",
            self.particles
        );
        Ok(())
    }
}

/// Shard-wise drift kernel: slots past `filled` are untouched (only
/// the tail frame of a list may be partial).
struct DriftKernel {
    filled: usize,
    dt: f32,
}

impl ShardKernel for DriftKernel {
    fn run<C: CursorWrite>(&self, cur: &[C], s: Shard) {
        drift_cursors(cur, s.start.min(self.filled), s.end.min(self.filled), self.dt);
    }
}

/// Drift one frame: plan fast path (EXPERIMENTS.md §Perf) through the
/// shared executor — loop-invariant cursors, affine or lane-blocked —
/// with the accessor loop as the generic-plan fallback.
fn drift_frame<M: Mapping, B: BlobMut>(frame: &mut Frame<M, B>, dt: f32) {
    drift_view(&mut frame.view, frame.filled, dt);
}

/// The drift sweep over the first `filled` records of any attribute
/// view — the body shared by [`Frame`] sweeps and the adaptive-store
/// kernel ([`AdaptiveDrift`]), generic over mapping and blob storage.
pub fn drift_view<M: Mapping, B: BlobMut>(view: &mut View<M, B>, filled: usize, dt: f32) {
    let n = filled.min(view.count());
    if par_execute(view, 1, &DriftKernel { filled: n, dt }) {
        return;
    }
    debug_assert!(view.validate().is_ok());
    for s in 0..n {
        // SAFETY: s < count over a validated view.
        unsafe {
            let x = view.get_unchecked::<f32>(s, POS_X) + view.get_unchecked::<f32>(s, MOM_X) * dt;
            let y = view.get_unchecked::<f32>(s, POS_Y) + view.get_unchecked::<f32>(s, MOM_Y) * dt;
            let z = view.get_unchecked::<f32>(s, POS_Z) + view.get_unchecked::<f32>(s, MOM_Z) * dt;
            view.set_unchecked::<f32>(s, POS_X, x);
            view.set_unchecked::<f32>(s, POS_Y, y);
            view.set_unchecked::<f32>(s, POS_Z, z);
        }
    }
}

/// The charge-deposit reduction over the first `filled` records of any
/// attribute view: sums the macro-particle `weighting` field — the
/// read-only serving query of the picframe workload. Works over any
/// [`Blob`] storage, including the `Arc`-frozen generations handed out
/// by `ServingEngine::pin`, and takes the plan fast path where the
/// layout admits cursors.
pub fn deposit_view<M: Mapping, B: Blob>(view: &View<M, B>, filled: usize) -> f64 {
    let n = filled.min(view.count());
    let plan = view.mapping().plan();
    match view.plan_cursors_with(&plan) {
        PlanCursors::Affine(cur) => deposit_cursors(&cur, n),
        PlanCursors::Piecewise(cur) => deposit_cursors(&cur, n),
        PlanCursors::Generic => {
            (0..n).map(|s| view.get::<f32>(s, WEIGHTING) as f64).sum()
        }
    }
}

fn deposit_cursors<C: CursorRead>(cur: &[C], n: usize) -> f64 {
    let mut sum = 0.0f64;
    for s in 0..n {
        // SAFETY: s < n <= count.
        unsafe {
            sum += cur[WEIGHTING].read_at::<f32>(s) as f64;
        }
    }
    sum
}

/// The drift sweep as an adaptive-engine kernel: an attribute store
/// wrapped in [`crate::view::adapt::AdaptiveView`] drifts through
/// whatever layout the engine has adopted (pos + mom touch 6 of 8
/// attributes → the advisor steers towards SoA, the layout fig 10
/// measures fastest for the sweep).
pub struct AdaptiveDrift {
    /// Timestep per sweep.
    pub dt: f32,
}

impl crate::view::adapt::AdaptiveKernel for AdaptiveDrift {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut View<M, B>) {
        let n = view.count();
        drift_view(view, n, self.dt);
    }
}

/// One drift sweep over plan cursors (affine or piecewise — the kernel
/// is generic and monomorphizes per plan shape).
fn drift_cursors<C: CursorWrite>(cur: &[C], start: usize, end: usize, dt: f32) {
    for s in start..end {
        // SAFETY: s < filled <= FRAME_SIZE == count.
        unsafe {
            let x = cur[POS_X].read_at::<f32>(s) + cur[MOM_X].read_at::<f32>(s) * dt;
            let y = cur[POS_Y].read_at::<f32>(s) + cur[MOM_Y].read_at::<f32>(s) * dt;
            let z = cur[POS_Z].read_at::<f32>(s) + cur[MOM_Z].read_at::<f32>(s) * dt;
            cur[POS_X].write_at::<f32>(s, x);
            cur[POS_Y].write_at::<f32>(s, y);
            cur[POS_Z].write_at::<f32>(s, z);
        }
    }
}

fn write_particle<M: Mapping, B: BlobMut>(view: &mut View<M, B>, slot: usize, p: &ParticleAttrs) {
    view.set::<f32>(slot, POS_X, p.pos[0]);
    view.set::<f32>(slot, POS_Y, p.pos[1]);
    view.set::<f32>(slot, POS_Z, p.pos[2]);
    view.set::<f32>(slot, MOM_X, p.mom[0]);
    view.set::<f32>(slot, MOM_Y, p.mom[1]);
    view.set::<f32>(slot, MOM_Z, p.mom[2]);
    view.set::<f32>(slot, WEIGHTING, p.weighting);
    view.set::<i32>(slot, CELL_IDX, p.cell_idx);
}

fn read_particle<M: Mapping, B: Blob>(view: &View<M, B>, slot: usize) -> ParticleAttrs {
    ParticleAttrs {
        pos: [
            view.get::<f32>(slot, POS_X),
            view.get::<f32>(slot, POS_Y),
            view.get::<f32>(slot, POS_Z),
        ],
        mom: [
            view.get::<f32>(slot, MOM_X),
            view.get::<f32>(slot, MOM_Y),
            view.get::<f32>(slot, MOM_Z),
        ],
        weighting: view.get::<f32>(slot, WEIGHTING),
        cell_idx: view.get::<i32>(slot, CELL_IDX),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::workloads::picframe::attr_dim;

    fn soa_store(grid: [usize; 3]) -> ParticleStore<SoA> {
        ParticleStore::new(
            SoA::multi_blob(&attr_dim(), ArrayDims::linear(FRAME_SIZE)),
            grid,
        )
    }

    #[test]
    fn deposit_view_agrees_across_layouts_and_respects_filled() {
        use crate::view::alloc_view;
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
        for s in 0..FRAME_SIZE {
            write_particle(
                &mut soa,
                s,
                &ParticleAttrs { weighting: (s + 1) as f32, ..ParticleAttrs::zero() },
            );
        }
        // Sum of 1..=10 = 55; slots past `filled` are ignored.
        assert_eq!(deposit_view(&soa, 10), 55.0);
        let full: f64 = (1..=FRAME_SIZE).map(|w| w as f64).sum();
        assert_eq!(deposit_view(&soa, FRAME_SIZE), full);
        assert_eq!(deposit_view(&soa, FRAME_SIZE + 99), full);

        let mut aosoa = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        let mut aos = alloc_view(AoS::aligned(&d, dims));
        for s in 0..FRAME_SIZE {
            let p = ParticleAttrs { weighting: (s + 1) as f32, ..ParticleAttrs::zero() };
            write_particle(&mut aosoa, s, &p);
            write_particle(&mut aos, s, &p);
        }
        assert_eq!(deposit_view(&aosoa, 10), 55.0);
        assert_eq!(deposit_view(&aos, 10), 55.0);
    }

    #[test]
    fn push_fills_frames_in_order() {
        let mut st = soa_store([1, 1, 1]);
        for i in 0..FRAME_SIZE + 10 {
            st.push(0, ParticleAttrs { cell_idx: i as i32, ..ParticleAttrs::zero() });
        }
        assert_eq!(st.particle_count(), FRAME_SIZE + 10);
        assert_eq!(st.frame_count(), 2);
        st.check_invariants().unwrap();
        let ps = st.cell_particles(0);
        assert_eq!(ps.len(), FRAME_SIZE + 10);
        assert_eq!(ps[0].cell_idx, 0);
        assert_eq!(ps[FRAME_SIZE].cell_idx, FRAME_SIZE as i32);
    }

    #[test]
    fn remove_keeps_invariants_and_frees_frames() {
        let mut st = soa_store([1, 1, 1]);
        st.populate(FRAME_SIZE * 2 + 5, 3);
        st.check_invariants().unwrap();
        // Drain the cell through the public exchange path: give every
        // particle an out-of-range position, same cell wraps to itself
        // in a 1-cell grid.
        st.drift(10.0); // most positions leave [0,1)
        st.exchange();
        st.check_invariants().unwrap();
        assert_eq!(st.particle_count(), FRAME_SIZE * 2 + 5);
    }

    #[test]
    fn drift_moves_positions() {
        let mut st = soa_store([2, 2, 2]);
        st.push(0, ParticleAttrs { pos: [0.5; 3], mom: [0.1, -0.2, 0.0], ..ParticleAttrs::zero() });
        st.drift(1.0);
        let p = st.cell_particles(0)[0];
        assert!((p.pos[0] - 0.6).abs() < 1e-6);
        assert!((p.pos[1] - 0.3).abs() < 1e-6);
        assert_eq!(p.pos[2], 0.5);
    }

    #[test]
    fn exchange_moves_across_cells_periodically() {
        let mut st = soa_store([2, 1, 1]);
        st.push(0, ParticleAttrs { pos: [1.2, 0.5, 0.5], ..ParticleAttrs::zero() });
        st.push(0, ParticleAttrs { pos: [-0.3, 0.5, 0.5], ..ParticleAttrs::zero() });
        st.exchange();
        st.check_invariants().unwrap();
        // +x overflow goes to cell 1; -x underflow wraps to cell 1 too
        // (periodic grid of 2).
        assert_eq!(st.cell_particles(0).len(), 0);
        let c1 = st.cell_particles(1);
        assert_eq!(c1.len(), 2);
        for p in c1 {
            assert!((0.0..1.0).contains(&p.pos[0]), "wrapped pos {:?}", p.pos);
        }
    }

    #[test]
    fn parallel_drift_is_bit_identical() {
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        for threads in [2usize, 4, 7] {
            let mut serial = ParticleStore::new(AoSoA::new(&d, dims.clone(), 32), [3, 3, 3]);
            let mut par = ParticleStore::new(AoSoA::new(&d, dims.clone(), 32), [3, 3, 3]);
            serial.populate(300, 11);
            par.populate(300, 11);
            for _ in 0..3 {
                serial.drift(0.3);
                par.drift_parallel(0.3, threads);
            }
            par.check_invariants().unwrap();
            for cell in 0..serial.cell_count() {
                assert_eq!(
                    serial.cell_particles(cell),
                    par.cell_particles(cell),
                    "threads {threads} cell {cell}"
                );
            }
        }
    }

    #[test]
    fn conservation_under_many_steps() {
        let mut st = soa_store([3, 3, 3]);
        st.populate(100, 17);
        let total = st.particle_count();
        let w0: f64 = st.deposit().iter().sum();
        for _ in 0..5 {
            st.drift(0.7);
            st.exchange();
            st.check_invariants().unwrap();
        }
        assert_eq!(st.particle_count(), total);
        let w1: f64 = st.deposit().iter().sum();
        assert!((w0 - w1).abs() < 1e-6 * w0.abs().max(1.0), "weight not conserved");
    }

    #[test]
    fn layouts_agree_on_deposit() {
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut a = ParticleStore::new(SoA::multi_blob(&d, dims.clone()), [2, 2, 2]);
        let mut b = ParticleStore::new(AoS::aligned(&d, dims.clone()), [2, 2, 2]);
        let mut c = ParticleStore::new(AoSoA::new(&d, dims.clone(), 32), [2, 2, 2]);
        for st_seed in [(0usize, 0u64); 1] {
            let _ = st_seed;
        }
        a.populate(300, 5);
        b.populate(300, 5);
        c.populate(300, 5);
        for _ in 0..3 {
            a.drift(0.4);
            a.exchange();
            b.drift(0.4);
            b.exchange();
            c.drift(0.4);
            c.exchange();
        }
        assert_eq!(a.deposit(), b.deposit());
        assert_eq!(a.deposit(), c.deposit());
    }

    #[test]
    fn reshuffle_preserves_every_particle_across_layouts() {
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let mut st = soa_store([2, 2, 2]);
        st.populate(300, 23);
        st.drift(0.2);
        st.exchange();
        // SoA -> AoSoA32 (chunked program) and SoA -> aligned AoS
        // (strided program): same particles, same list structure.
        let a = st.reshuffle(AoSoA::new(&d, dims.clone(), 32));
        a.check_invariants().unwrap();
        let b = st.reshuffle(AoS::aligned(&d, dims.clone()));
        b.check_invariants().unwrap();
        assert_eq!(a.particle_count(), st.particle_count());
        for cell in 0..st.cell_count() {
            assert_eq!(st.cell_particles(cell), a.cell_particles(cell), "cell {cell}");
            assert_eq!(st.cell_particles(cell), b.cell_particles(cell), "cell {cell}");
        }
        // The reshuffled store keeps working: one more full step.
        let mut a = a;
        a.drift(0.3);
        a.exchange();
        a.check_invariants().unwrap();
    }

    /// A pooled frame arena recycles: frames freed by `exchange`
    /// return their blobs to the pool and later `push`es reuse them.
    /// The arena never holds more than `total/FRAME_SIZE + ncells`
    /// frames (the "only the tail is partial" invariant, removals
    /// precede the matching pushes), so a pool pre-warmed to that
    /// bound serves the whole churn with zero fresh allocations — and
    /// the physics stays identical to the `Vec<u8>` store.
    #[test]
    fn pooled_arena_recycles_frame_churn() {
        use crate::blob::BlobPool;
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let ncells = 2;
        let per_cell = FRAME_SIZE + 40;
        let pool = BlobPool::new();
        let bound = (ncells * per_cell) / FRAME_SIZE + ncells + 1;
        {
            let warm: Vec<_> = (0..bound)
                .map(|_| {
                    crate::view::alloc_view_with(SoA::multi_blob(&d, dims.clone()), pool.clone())
                })
                .collect();
            drop(warm);
        }
        let warm_misses = pool.stats().misses;
        let mut pooled = ParticleStore::with_allocator(
            SoA::multi_blob(&d, dims.clone()),
            [ncells, 1, 1],
            pool.clone(),
        );
        let mut plain = soa_store([ncells, 1, 1]);
        pooled.populate(per_cell, 7);
        plain.populate(per_cell, 7);
        // Drive hard enough that particles cross cells every step
        // (frames free on one side, allocate on the other).
        for _ in 0..4 {
            pooled.drift(5.0);
            pooled.exchange();
            plain.drift(5.0);
            plain.exchange();
        }
        pooled.check_invariants().unwrap();
        assert_eq!(
            pool.stats().misses,
            warm_misses,
            "churn within the frame bound must allocate zero fresh blobs"
        );
        assert!(pool.stats().hits > 0);
        for cell in 0..plain.cell_count() {
            assert_eq!(pooled.cell_particles(cell), plain.cell_particles(cell), "cell {cell}");
        }
    }

    /// `reshuffle` keeps the allocator: a pooled store reshuffles into
    /// pooled frames, and dropping the old store refills the pool.
    #[test]
    fn pooled_reshuffle_round_trips() {
        use crate::blob::BlobPool;
        let d = attr_dim();
        let dims = ArrayDims::linear(FRAME_SIZE);
        let pool = BlobPool::new();
        let mut st = ParticleStore::with_allocator(
            SoA::multi_blob(&d, dims.clone()),
            [2, 2, 1],
            pool.clone(),
        );
        st.populate(300, 23);
        let plain = {
            let mut p = soa_store([2, 2, 1]);
            p.populate(300, 23);
            p.reshuffle(AoSoA::new(&d, dims.clone(), 32))
        };
        let warm = {
            // First reshuffle warms the AoSoA class; drop it again.
            drop(st.reshuffle(AoSoA::new(&d, dims.clone(), 32)));
            pool.stats().misses
        };
        let a = st.reshuffle(AoSoA::new(&d, dims.clone(), 32));
        a.check_invariants().unwrap();
        assert_eq!(pool.stats().misses, warm, "warm reshuffle must reuse pooled frames");
        for cell in 0..plain.cell_count() {
            assert_eq!(a.cell_particles(cell), plain.cell_particles(cell), "cell {cell}");
        }
    }

    #[test]
    #[should_panic(expected = "must cover FRAME_SIZE")]
    fn wrong_frame_extent_rejected() {
        let _ = ParticleStore::new(
            SoA::multi_blob(&attr_dim(), ArrayDims::linear(100)),
            [1, 1, 1],
        );
    }
}
