//! HEP event records (paper §4.2, fig 7): "the first 100 int32s,
//! int64s, floats, bytes and bools as they occur in an internal event
//! dataset from the CMS detector at CERN".
//!
//! Substitution note (DESIGN.md): the CMS dataset is internal; fig 7
//! only depends on the record *shape* — 100 heterogeneous small leaf
//! fields — so we synthesize a record with 20 of each scalar kind in an
//! interleaved declaration order resembling reconstructed-event
//! attribute lists, plus a deterministic value generator.

use crate::blob::{Blob, BlobMut};
use crate::mapping::Mapping;
use crate::record::{RecordDim, Scalar};
use crate::view::cursor::{CursorRead, PlanCursors};
use crate::view::shard::{par_map_shards, shard_plan};
use crate::view::View;
use crate::workloads::rng::SplitMix64;

/// Number of leaf fields in the event record.
pub const FIELDS: usize = 100;

/// The 100-field event record: 20×(i32, i64, f32, u8, bool),
/// interleaved in groups of five like typical reconstructed-object
/// attribute blocks (id, timestamp, energy, quality, isolation).
pub fn event_dim() -> RecordDim {
    let mut dim = RecordDim::new();
    for obj in 0..20 {
        dim = dim
            .scalar(format!("obj{obj}_id"), Scalar::I32)
            .scalar(format!("obj{obj}_time"), Scalar::I64)
            .scalar(format!("obj{obj}_energy"), Scalar::F32)
            .scalar(format!("obj{obj}_quality"), Scalar::U8)
            .scalar(format!("obj{obj}_isolated"), Scalar::Bool);
    }
    dim
}

/// Fill an event view with deterministic pseudo-physics values.
pub fn generate_events<M: Mapping, B: BlobMut>(view: &mut View<M, B>, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    let info = view.mapping().info().clone();
    for lin in 0..view.count() {
        for leaf in 0..info.leaf_count() {
            match info.fields[leaf].scalar {
                Scalar::I32 => view.set::<i32>(lin, leaf, rng.next_u32() as i32 & 0xFFFFF),
                Scalar::I64 => view.set::<i64>(lin, leaf, rng.next_u64() as i64 & 0xFFFFFFFFFF),
                Scalar::F32 => view.set::<f32>(lin, leaf, rng.range_f32(0.0, 500.0)),
                Scalar::U8 => view.set::<u8>(lin, leaf, (rng.next_u32() & 0xFF) as u8),
                Scalar::Bool => view.set::<bool>(lin, leaf, rng.next_bool()),
                other => unreachable!("event record has no {other:?}"),
            }
        }
    }
}

/// Bytes of one packed event record (the per-record payload moved by
/// fig 7's event copies).
pub fn event_packed_size() -> usize {
    event_dim().packed_size()
}

/// A typical analysis sweep: total energy of isolated, good-quality
/// objects — reads 3 of the 100 fields per record, the access shape
/// that makes SoA/AoSoA layouts win on event data. Plan-driven: the
/// mapping compiles to cursors once; only instrumented/curve layouts
/// pay per-access translation.
pub fn isolated_energy<M: Mapping, B: Blob>(view: &View<M, B>, min_quality: u8) -> f64 {
    isolated_energy_parallel(view, min_quality, 1)
}

/// [`isolated_energy`] over plan-aligned shards on `threads` scoped
/// workers: each shard reduces its record range independently and the
/// partials are summed in shard order, so the result is deterministic
/// for a given thread count (`threads = 1` reproduces the serial sum
/// exactly; other counts regroup the floating-point additions).
pub fn isolated_energy_parallel<M: Mapping, B: Blob>(
    view: &View<M, B>,
    min_quality: u8,
    threads: usize,
) -> f64 {
    let info = view.mapping().info().clone();
    let n = view.count();
    let mut leaves = Vec::with_capacity(20);
    for obj in 0..20 {
        let e = info.leaf_by_path(&format!("obj{obj}_energy")).expect("energy leaf");
        let q = info.leaf_by_path(&format!("obj{obj}_quality")).expect("quality leaf");
        let iso = info.leaf_by_path(&format!("obj{obj}_isolated")).expect("isolated leaf");
        leaves.push((e, q, iso));
    }
    let plan = view.mapping().plan();
    let shards = shard_plan(&plan, threads);
    match view.plan_cursors_with(&plan) {
        PlanCursors::Affine(cur) => par_map_shards(&shards, |s| {
            isolated_energy_cursors(&cur, &leaves, s.start, s.end, min_quality)
        })
        .into_iter()
        .sum(),
        PlanCursors::Piecewise(cur) => par_map_shards(&shards, |s| {
            isolated_energy_cursors(&cur, &leaves, s.start, s.end, min_quality)
        })
        .into_iter()
        .sum(),
        PlanCursors::Generic => {
            let mut sum = 0.0f64;
            for lin in 0..n {
                for &(e, q, iso) in &leaves {
                    if view.get::<bool>(lin, iso) && view.get::<u8>(lin, q) >= min_quality {
                        sum += view.get::<f32>(lin, e) as f64;
                    }
                }
            }
            sum
        }
    }
}

/// A serving-style point query with a *drifting* hot set: total energy
/// of good-quality objects inside the window `[obj_lo, obj_lo + width)`
/// (object indices wrap modulo 20). Each request reads 2 of the 100
/// fields per window object, so which leaves are hot follows the
/// window — exactly the traffic drift the serving engine's background
/// relayout (`view::serve`) is built to chase. Read-only: works over
/// any [`Blob`] storage, including the `Arc`-frozen generations handed
/// out by `ServingEngine::pin`.
pub fn energy_window<M: Mapping, B: Blob>(
    view: &View<M, B>,
    obj_lo: usize,
    width: usize,
    min_quality: u8,
) -> f64 {
    let info = view.mapping().info().clone();
    let mut leaves = Vec::with_capacity(width.min(20));
    for k in 0..width.min(20) {
        let obj = (obj_lo + k) % 20;
        let e = info.leaf_by_path(&format!("obj{obj}_energy")).expect("energy leaf");
        let q = info.leaf_by_path(&format!("obj{obj}_quality")).expect("quality leaf");
        leaves.push((e, q));
    }
    let plan = view.mapping().plan();
    let n = view.count();
    match view.plan_cursors_with(&plan) {
        PlanCursors::Affine(cur) => energy_window_cursors(&cur, &leaves, n, min_quality),
        PlanCursors::Piecewise(cur) => energy_window_cursors(&cur, &leaves, n, min_quality),
        PlanCursors::Generic => {
            let mut sum = 0.0f64;
            for lin in 0..n {
                for &(e, q) in &leaves {
                    if view.get::<u8>(lin, q) >= min_quality {
                        sum += view.get::<f32>(lin, e) as f64;
                    }
                }
            }
            sum
        }
    }
}

fn energy_window_cursors<C: CursorRead>(
    cur: &[C],
    leaves: &[(usize, usize)],
    n: usize,
    min_quality: u8,
) -> f64 {
    let mut sum = 0.0f64;
    for lin in 0..n {
        for &(e, q) in leaves {
            // SAFETY: lin < n == cursor count.
            unsafe {
                if cur[q].read_at::<u8>(lin) >= min_quality {
                    sum += cur[e].read_at::<f32>(lin) as f64;
                }
            }
        }
    }
    sum
}

/// The window sweep as an adaptive-engine kernel whose hot fields
/// *drift*: every `steps_per_window` steps the window advances by one
/// object, so successive trace epochs see different hot leaves and the
/// advisor keeps re-splitting — the workload the serving benchmark
/// uses to pit adaptive relayout against stop-the-world and
/// best-static engines.
pub struct AdaptiveWindow {
    /// First object of the current window (wraps modulo 20).
    pub obj_lo: usize,
    /// Objects per window.
    pub width: usize,
    /// Quality threshold of the query.
    pub min_quality: u8,
    /// Steps between one-object window advances (0 = never drift).
    pub steps_per_window: usize,
    /// Steps run so far.
    pub step: usize,
    /// Accumulated energy across steps (checked against static runs).
    pub total: f64,
}

impl AdaptiveWindow {
    /// A fresh sweep starting at object 0.
    pub fn new(width: usize, min_quality: u8, steps_per_window: usize) -> AdaptiveWindow {
        AdaptiveWindow { obj_lo: 0, width, min_quality, steps_per_window, step: 0, total: 0.0 }
    }
}

impl crate::view::adapt::AdaptiveKernel for AdaptiveWindow {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut crate::view::View<M, B>) {
        self.total += energy_window(view, self.obj_lo, self.width, self.min_quality);
        self.step += 1;
        if self.steps_per_window > 0 && self.step % self.steps_per_window == 0 {
            self.obj_lo = (self.obj_lo + 1) % 20;
        }
    }
}

/// The isolation sweep as an adaptive-engine kernel: each step sums
/// [`isolated_energy`] into `total`. The sweep reads at most 3 of 100
/// fields per object, but conditionally: `isolated` always, `quality`
/// only for isolated objects (~half), `energy` only past both gates
/// (~a quarter) — so the trace epoch's hot set (leaves at ≥ half the
/// maximum rate) is the unconditional gate fields, and the advisor's
/// Split keeps *those* dense while the rarely-read payload (energy
/// included) stays in the cold record. That densifies the dominant
/// gate reads; records passing the gates still pull the cold record.
pub struct AdaptiveIsolation {
    /// Quality threshold of the sweep.
    pub min_quality: u8,
    /// Worker threads per sweep (1 = serial).
    pub threads: usize,
    /// Accumulated energy across steps (checked against static runs).
    pub total: f64,
}

impl crate::view::adapt::AdaptiveKernel for AdaptiveIsolation {
    fn run<M: Mapping, B: BlobMut + Sync>(&mut self, view: &mut crate::view::View<M, B>) {
        self.total += isolated_energy_parallel(view, self.min_quality, self.threads.max(1));
    }
}

fn isolated_energy_cursors<C: CursorRead>(
    cur: &[C],
    leaves: &[(usize, usize, usize)],
    start: usize,
    end: usize,
    min_quality: u8,
) -> f64 {
    let mut sum = 0.0f64;
    for lin in start..end {
        for &(e, q, iso) in leaves {
            // SAFETY: lin < n == cursor count. The isolated flag is
            // read as its raw u8 byte and decoded `!= 0` — never as
            // `bool`, which would be undefined behavior for any byte
            // outside {0, 1} written through raw-blob APIs.
            unsafe {
                if cur[iso].read_at::<u8>(lin) != 0 && cur[q].read_at::<u8>(lin) >= min_quality {
                    sum += cur[e].read_at::<f32>(lin) as f64;
                }
            }
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::{AoSoA, SoA};
    use crate::view::alloc_view;

    #[test]
    fn record_shape_matches_paper() {
        let d = event_dim();
        assert_eq!(d.leaf_count(), FIELDS);
        // 20 * (4 + 8 + 4 + 1 + 1) = 360 bytes packed.
        assert_eq!(d.packed_size(), 360);
        let info = crate::record::RecordInfo::new(&d);
        let kinds = |s: Scalar| info.fields.iter().filter(|f| f.scalar == s).count();
        assert_eq!(kinds(Scalar::I32), 20);
        assert_eq!(kinds(Scalar::I64), 20);
        assert_eq!(kinds(Scalar::F32), 20);
        assert_eq!(kinds(Scalar::U8), 20);
        assert_eq!(kinds(Scalar::Bool), 20);
    }

    #[test]
    fn generation_is_deterministic() {
        let d = event_dim();
        let mut a = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(10)));
        let mut b = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(10)));
        generate_events(&mut a, 99);
        generate_events(&mut b, 99);
        assert_eq!(a.blobs(), b.blobs());
        let mut c = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(10)));
        generate_events(&mut c, 100);
        assert_ne!(a.blobs(), c.blobs());
    }

    #[test]
    fn isolated_energy_agrees_across_layouts() {
        use crate::mapping::{AoS, Trace};
        let d = event_dim();
        let dims = ArrayDims::linear(37); // not a lane multiple
        let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
        generate_events(&mut soa, 21);
        let expect = isolated_energy(&soa, 128);
        assert!(expect > 0.0);

        let mut aosoa = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        generate_events(&mut aosoa, 21);
        assert_eq!(isolated_energy(&aosoa, 128), expect);

        let mut aos = alloc_view(AoS::aligned(&d, dims.clone()));
        generate_events(&mut aos, 21);
        assert_eq!(isolated_energy(&aos, 128), expect);

        // Generic plan (instrumented) takes the accessor path, same sum.
        let mut traced = alloc_view(Trace::new(AoS::packed(&d, dims.clone())));
        generate_events(&mut traced, 21);
        assert_eq!(isolated_energy(&traced, 128), expect);
    }

    #[test]
    fn energy_window_agrees_across_layouts_and_wraps() {
        use crate::mapping::{AoS, Trace};
        let d = event_dim();
        let dims = ArrayDims::linear(29);
        let mut soa = alloc_view(SoA::multi_blob(&d, dims.clone()));
        generate_events(&mut soa, 13);
        // Window wraps: objects 18, 19, 0, 1.
        let expect = energy_window(&soa, 18, 4, 64);
        assert!(expect > 0.0);

        let mut aos = alloc_view(AoS::aligned(&d, dims.clone()));
        generate_events(&mut aos, 13);
        assert_eq!(energy_window(&aos, 18, 4, 64), expect);

        // Generic plan (instrumented) takes the accessor path, same sum.
        let mut traced = alloc_view(Trace::new(AoS::packed(&d, dims.clone())));
        generate_events(&mut traced, 13);
        assert_eq!(energy_window(&traced, 18, 4, 64), expect);

        // Width caps at the 20 available objects.
        assert_eq!(energy_window(&soa, 0, 25, 64), energy_window(&soa, 0, 20, 64));
    }

    #[test]
    fn adaptive_window_drifts_on_schedule() {
        use crate::view::adapt::AdaptiveKernel;
        let d = event_dim();
        let mut v = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(8)));
        generate_events(&mut v, 3);
        let mut k = AdaptiveWindow::new(3, 0, 2);
        for _ in 0..4 {
            k.run(&mut v);
        }
        // 4 steps / 2 steps-per-window = 2 advances.
        assert_eq!(k.obj_lo, 2);
        assert_eq!(k.step, 4);
        assert!(k.total > 0.0);
    }

    #[test]
    fn parallel_energy_matches_serial() {
        let d = event_dim();
        let dims = ArrayDims::linear(133); // not a lane multiple
        let mut v = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        generate_events(&mut v, 5);
        let serial = isolated_energy(&v, 100);
        // One shard reproduces the serial summation order exactly.
        assert_eq!(isolated_energy_parallel(&v, 100, 1), serial);
        // More shards regroup the additions deterministically; the
        // value agrees to fp-regrouping precision.
        for threads in [2usize, 4, 7] {
            let par = isolated_energy_parallel(&v, 100, threads);
            let rel = (par - serial).abs() / serial.abs().max(1.0);
            assert!(rel < 1e-9, "threads {threads}: {par} vs {serial}");
        }
    }

    #[test]
    fn copies_between_event_layouts() {
        let d = event_dim();
        let dims = ArrayDims::linear(64);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        generate_events(&mut src, 7);
        let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 32));
        crate::copy::aosoa_copy(&src, &mut dst, crate::copy::ChunkOrder::ReadContiguous);
        assert!(crate::copy::views_equal(&src, &dst));
    }
}
