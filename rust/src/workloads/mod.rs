//! Evaluation workloads (paper §4): every substrate the paper's
//! evaluation depends on, built from scratch.
//!
//! * [`nbody`] — all-pairs n-body (paper §4.1, figs 5/6): manually
//!   written AoS/SoA/AoSoA twins plus layout-generic LLAMA kernels.
//! * [`lbm`] — D3Q19 Lattice-Boltzmann, the stand-in for SPEC CPU®
//!   2017 619.lbm_s (paper §4.3, fig 8).
//! * [`hep`] — CMS-like 100-field event records for the layout-changing
//!   copy benchmark (paper §4.2, fig 7).
//! * [`picframe`] — PIConGPU-style supercell particle frame lists
//!   (paper §4.4, figs 9/10).
//! * [`rng`] — deterministic SplitMix64 PRNG used by all workloads.

pub mod hep;
pub mod lbm;
pub mod nbody;
pub mod picframe;
pub mod rng;
