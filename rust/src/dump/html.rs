//! HTML layout dump (paper §3.7 mentions "a flexible HTML visualization
//! can also be dumped"): a byte-granular table per blob with per-field
//! colors and hover titles.

use super::{layout_cells, leaf_color};
use crate::mapping::Mapping;

/// Render the first `max_records` records as a standalone HTML page.
pub fn dump_html<M: Mapping>(mapping: &M, max_records: usize) -> String {
    let cells = layout_cells(mapping, max_records);
    let info = mapping.info().clone();
    let leaves = info.leaf_count();
    let mut out = String::new();
    out.push_str("<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">\n");
    out.push_str("<style>\n");
    out.push_str(
        ".b{display:inline-block;min-width:3.2em;padding:2px;margin:1px;\
         font:10px monospace;border:1px solid #444;text-align:center}\n",
    );
    out.push_str("h2{font-family:monospace}\n</style></head><body>\n");
    out.push_str(&format!(
        "<h1 style=\"font-family:monospace\">{}</h1>\n",
        html_escape(&mapping.mapping_name())
    ));
    out.push_str(&format!(
        "<p>record dim: {} leaves, packed {} B, aligned {} B; array dims {:?}; {} blob(s)</p>\n",
        leaves,
        info.packed_size,
        info.aligned_size,
        mapping.dims().extents(),
        mapping.blob_count()
    ));
    for blob in 0..mapping.blob_count() {
        out.push_str(&format!(
            "<h2>blob {blob} — {} bytes</h2>\n<div>",
            mapping.blob_size(blob)
        ));
        let mut blob_cells: Vec<_> = cells.iter().filter(|c| c.blob == blob).collect();
        blob_cells.sort_by_key(|c| c.offset);
        let mut cursor = 0usize;
        for c in blob_cells {
            if c.offset > cursor {
                out.push_str(&format!(
                    "<span class=\"b\" style=\"background:#ddd\" title=\"padding\">pad {}</span>",
                    c.offset - cursor
                ));
            }
            out.push_str(&format!(
                "<span class=\"b\" style=\"background:{}\" title=\"bytes {}..{}\">{}[{}]</span>",
                leaf_color(c.leaf, leaves),
                c.offset,
                c.offset + c.size,
                html_escape(&c.path),
                c.lin
            ));
            cursor = c.offset + c.size;
        }
        out.push_str("</div>\n");
    }
    out.push_str("</body></html>\n");
    out
}

fn html_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, SoA};

    #[test]
    fn html_structure() {
        let m = AoS::aligned(&particle_dim(), ArrayDims::linear(2));
        let html = dump_html(&m, 2);
        assert!(html.starts_with("<!DOCTYPE html>"));
        assert!(html.contains("AoS(aligned"));
        assert!(html.contains("mass"));
        // Aligned AoS has padding spans.
        assert!(html.contains("title=\"padding\""));
        assert!(html.trim_end().ends_with("</html>"));
    }

    #[test]
    fn packed_has_no_padding() {
        let m = SoA::single_blob(&particle_dim(), ArrayDims::linear(2));
        let html = dump_html(&m, 2);
        assert!(!html.contains("title=\"padding\""));
    }
}
