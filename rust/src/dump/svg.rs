//! SVG layout dump (paper fig 4a–4c): one rectangle per (field, array
//! index) byte range, laid out as rows of `bytes_per_row` bytes per
//! blob, colored per field.

use super::{layout_cells, leaf_color};
use crate::mapping::Mapping;

const CELL_W: usize = 14;
const CELL_H: usize = 26;
const BLOB_GAP: usize = 40;

/// Render the first `max_records` records of `mapping` as an SVG
/// string, `bytes_per_row` bytes per row (the paper uses 64).
pub fn dump_svg<M: Mapping>(mapping: &M, max_records: usize, bytes_per_row: usize) -> String {
    let cells = layout_cells(mapping, max_records);
    let leaves = mapping.info().leaf_count();
    let mut y_base = 20usize;
    let mut out = String::new();
    let mut body = String::new();

    for blob in 0..mapping.blob_count() {
        let blob_cells: Vec<_> = cells.iter().filter(|c| c.blob == blob).collect();
        let max_off =
            blob_cells.iter().map(|c| c.offset + c.size).max().unwrap_or(0).max(bytes_per_row);
        let rows = max_off.div_ceil(bytes_per_row);
        body.push_str(&format!(
            "<text x=\"0\" y=\"{}\" font-size=\"12\" font-family=\"monospace\">blob {} ({} B)</text>\n",
            y_base - 6,
            blob,
            mapping.blob_size(blob)
        ));
        for c in &blob_cells {
            // A field may straddle a row boundary; emit one rect per
            // row segment.
            let mut off = c.offset;
            let mut remaining = c.size;
            while remaining > 0 {
                let row = off / bytes_per_row;
                let col = off % bytes_per_row;
                let seg = remaining.min(bytes_per_row - col);
                let x = col * CELL_W;
                let y = y_base + row * CELL_H;
                body.push_str(&format!(
                    "<rect x=\"{x}\" y=\"{y}\" width=\"{w}\" height=\"{h}\" fill=\"{fill}\" stroke=\"#333\" stroke-width=\"0.5\"><title>{path} [{lin}] @ blob {blob} +{offset}</title></rect>\n",
                    w = seg * CELL_W,
                    h = CELL_H,
                    fill = leaf_color(c.leaf, leaves),
                    path = c.path,
                    lin = c.lin,
                    blob = c.blob,
                    offset = c.offset,
                ));
                if seg * CELL_W >= 30 {
                    body.push_str(&format!(
                        "<text x=\"{tx}\" y=\"{ty}\" font-size=\"9\" font-family=\"monospace\">{label}</text>\n",
                        tx = x + 2,
                        ty = y + CELL_H / 2 + 3,
                        label = xml_escape(&format!("{}[{}]", c.path, c.lin)),
                    ));
                }
                off += seg;
                remaining -= seg;
            }
        }
        y_base += rows * CELL_H + BLOB_GAP;
    }

    let width = bytes_per_row * CELL_W + 20;
    out.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{width}\" height=\"{y_base}\">\n"
    ));
    out.push_str(&format!(
        "<desc>{}</desc>\n",
        xml_escape(&mapping.mapping_name())
    ));
    out.push_str(&body);
    out.push_str("</svg>\n");
    out
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA, Split};
    use crate::record::RecordCoord;

    #[test]
    fn svg_is_well_formed_and_mentions_fields() {
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let svg = dump_svg(&m, 4, 64);
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert!(svg.contains("pos.x"));
        assert!(svg.contains("blob 0"));
        // At least one rect per (field, record); straddling fields emit
        // an extra segment rect.
        assert!(svg.matches("<rect").count() >= 4 * 8);
    }

    #[test]
    fn multiblob_svg_has_blob_sections() {
        let m = SoA::multi_blob(&particle_dim(), ArrayDims::linear(4));
        let svg = dump_svg(&m, 4, 64);
        for b in 0..8 {
            assert!(svg.contains(&format!("blob {b}")), "missing blob {b}");
        }
    }

    #[test]
    fn aosoa_and_split_render() {
        let dims = ArrayDims::linear(8);
        let svg = dump_svg(&AoSoA::new(&particle_dim(), dims.clone(), 4), 8, 64);
        assert!(svg.contains("</svg>"));
        let split = Split::new(
            &particle_dim(),
            dims,
            RecordCoord::new(vec![1]),
            |d, ad| SoA::multi_blob(d, ad),
            |d, ad| AoS::aligned(d, ad),
        );
        let svg = dump_svg(&split, 8, 32);
        assert!(svg.contains("</svg>"));
        assert!(svg.contains("blob 3"));
    }
}
