//! Render [`crate::mapping::Heatmap`] counters (paper fig 4d) as ASCII
//! art or a binary PGM image (one pixel per granule, log-scaled).

use crate::mapping::{Heatmap, Mapping};

fn log_scale(count: u64, max: u64) -> f64 {
    if max == 0 || count == 0 {
        0.0
    } else {
        ((count as f64).ln_1p()) / ((max as f64).ln_1p())
    }
}

/// ASCII heatmap: one character per granule, `width` granules per row,
/// intensity ramp ` .:-=+*#%@`.
pub fn heatmap_ascii<M: Mapping>(h: &Heatmap<M>, width: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut out = String::new();
    for blob in 0..h.blob_count() {
        let counts = h.blob_counts(blob);
        let max = counts.iter().copied().max().unwrap_or(0);
        out.push_str(&format!(
            "blob {blob} ({} B, granularity {} B, max {} accesses)\n",
            h.blob_size(blob),
            h.granularity(),
            max
        ));
        for row in counts.chunks(width) {
            for &c in row {
                let lvl = (log_scale(c, max) * (RAMP.len() - 1) as f64).round() as usize;
                out.push(RAMP[lvl.min(RAMP.len() - 1)] as char);
            }
            out.push('\n');
        }
        out.push('\n');
    }
    out
}

/// Binary PGM (P5) image of one blob's counters, `width` granules per
/// row. Returns the raw file bytes.
pub fn heatmap_pgm<M: Mapping>(h: &Heatmap<M>, blob: usize, width: usize) -> Vec<u8> {
    let counts = h.blob_counts(blob);
    let max = counts.iter().copied().max().unwrap_or(0);
    let height = counts.len().div_ceil(width).max(1);
    let mut out = format!("P5\n{width} {height}\n255\n").into_bytes();
    for row in 0..height {
        for col in 0..width {
            let idx = row * width + col;
            let v = counts.get(idx).copied().unwrap_or(0);
            out.push((log_scale(v, max) * 255.0).round() as u8);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, Heatmap};

    fn touched_heatmap() -> Heatmap<AoS> {
        let h = Heatmap::new(AoS::packed(&particle_dim(), ArrayDims::linear(4)));
        for slot in 0..4 {
            for _ in 0..(slot + 1) * 3 {
                let _ = h.blob_nr_and_offset(1, slot); // pos.x, increasing heat
            }
        }
        h
    }

    #[test]
    fn ascii_render_shape() {
        let h = touched_heatmap();
        let art = heatmap_ascii(&h, 25);
        assert!(art.contains("blob 0"));
        // 100 bytes at width 25 -> 4 data rows.
        let data_rows =
            art.lines().filter(|l| !l.is_empty() && !l.starts_with("blob")).count();
        assert_eq!(data_rows, 4);
        // Hot bytes render darker than cold ones.
        assert!(art.contains('@'));
        assert!(art.contains(' '));
    }

    #[test]
    fn pgm_header_and_size() {
        let h = touched_heatmap();
        let pgm = heatmap_pgm(&h, 0, 25);
        let text = String::from_utf8_lossy(&pgm[..15]);
        assert!(text.starts_with("P5\n25 4\n255\n"));
        assert_eq!(pgm.len(), 12 + 25 * 4);
    }

    #[test]
    fn untouched_heatmap_is_blank() {
        let h = Heatmap::new(AoS::packed(&particle_dim(), ArrayDims::linear(2)));
        let art = heatmap_ascii(&h, 50);
        assert!(!art.contains('@'));
    }
}
