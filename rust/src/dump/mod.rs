//! Layout visualization (paper §3.7 / fig 4): dump a mapping's memory
//! layout as SVG or HTML, and render heatmap counters as PGM/ASCII.

pub mod heatmap_render;
pub mod html;
pub mod svg;

pub use heatmap_render::{heatmap_ascii, heatmap_pgm};
pub use html::dump_html;
pub use svg::dump_svg;

use crate::mapping::Mapping;

/// One colored cell of a layout picture: a byte range in a blob storing
/// a specific (field, array index) pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayoutCell {
    pub blob: usize,
    pub offset: usize,
    pub size: usize,
    pub leaf: usize,
    pub path: String,
    pub lin: usize,
}

/// Enumerate the layout cells of (up to) the first `max_records` array
/// records — the data both dump formats render.
pub fn layout_cells<M: Mapping>(mapping: &M, max_records: usize) -> Vec<LayoutCell> {
    let info = mapping.info().clone();
    let n = mapping.dims().count().min(max_records);
    let mut cells = Vec::with_capacity(n * info.leaf_count());
    for lin in 0..n {
        let slot = mapping.slot_of_lin(lin);
        for leaf in 0..info.leaf_count() {
            let (blob, offset) = mapping.blob_nr_and_offset(leaf, slot);
            cells.push(LayoutCell {
                blob,
                offset,
                size: info.fields[leaf].size(),
                leaf,
                path: info.fields[leaf].path.clone(),
                lin,
            });
        }
    }
    cells
}

/// Deterministic distinct-ish color per leaf index (HSL spread).
pub(crate) fn leaf_color(leaf: usize, leaves: usize) -> String {
    let hue = (leaf * 360) / leaves.max(1);
    format!("hsl({hue}, 65%, 70%)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, SoA};

    #[test]
    fn cells_cover_all_fields() {
        let m = AoS::packed(&particle_dim(), ArrayDims::linear(4));
        let cells = layout_cells(&m, 100);
        assert_eq!(cells.len(), 4 * 8);
        // Packed AoS: consecutive, no holes.
        let total: usize = cells.iter().map(|c| c.size).sum();
        assert_eq!(total, 4 * 25);
    }

    #[test]
    fn cells_respect_max_records() {
        let m = SoA::multi_blob(&particle_dim(), ArrayDims::linear(1000));
        let cells = layout_cells(&m, 3);
        assert_eq!(cells.len(), 3 * 8);
        assert!(cells.iter().all(|c| c.lin < 3));
    }

    #[test]
    fn colors_are_distinct_for_small_counts() {
        let a = leaf_color(0, 8);
        let b = leaf_color(1, 8);
        assert_ne!(a, b);
        assert!(a.starts_with("hsl("));
    }
}
