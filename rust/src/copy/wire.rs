//! `copy::wire`: layout-aware serialization over process boundaries.
//!
//! A wire message is a self-describing layout manifest line
//! ([`WireManifest`], the whitespace format of `runtime::manifest`)
//! plus the payload blobs concatenated in order. The pack and unpack
//! steps are **compiled copies**, not bespoke encoders: the wire layout
//! is just another mapping (dense packed AoS by default), so
//! [`serialize`] compiles a [`CopyProgram`] whose destination is the
//! wire buffer and [`deserialize_into`] compiles the reverse. Every
//! strategy of the program compiler applies unchanged:
//!
//! * A packed-AoS source serializes as a handful of coalesced memcpys
//!   (`Blobwise`/`AoSoAChunked`).
//! * A cross-endian target ([`serialize_endian`] with the peer's
//!   [`WireEndian`]) wraps the wire mapping in
//!   [`crate::mapping::Byteswap`]; affine pairs then compile to
//!   per-leaf [`super::CopyOp::SwapRun`]s (`SwapProgram`) instead of
//!   degrading to the element gather — and a *byteswapped source* sent
//!   in its own byte order moves verbatim, because equal-representation
//!   pairs stay on the memcpy strategies.
//!
//! The receiving side rebuilds a [`View`] from bytes alone:
//! [`wire_view`] is the zero-copy read view straight over the payload
//! (foreign byte orders read through swapping accessors), and
//! [`deserialize`]/[`deserialize_into`] compile the copy out into a
//! native-layout view. Framing for pipes/sockets is [`write_message`] /
//! [`read_message`]: a `LLAMA-WIRE <manifest_len> <payload_len>`
//! header line, the manifest, then the payload — the manifest is
//! parsed and cross-checked **before** the payload length is trusted,
//! so a corrupted or forged header can never cause an oversized read.
//!
//! Wire buffers come from any [`BlobRecycler`] ([`serialize_with`]):
//! frame exchange loops draw them from a [`crate::blob::BlobPool`],
//! and the zero fill is skipped whenever [`programs_cover_dst`] proves
//! the pack program overwrites every payload byte.
//!
//! Framing has a second, *pipelined* mode ([`write_range_chunked`]):
//! the header carries a trailing `chunked` token and the payload
//! arrives as self-delimiting `LLAMA-CHUNK <len>` sub-frames, each one
//! produced by executing the pack program over a shard-aligned slice
//! of the range and flushed to the stream as it completes — wire
//! memory stays O(chunk) and the first payload byte leaves before the
//! last record is packed. [`read_message`] reassembles both modes into
//! the same [`WireMessage`], so receivers are mode-agnostic.

use std::io::{BufRead, Write};

use crate::blob::{Blob, BlobMut, BlobRecycler, ExternalBytes, ExternalBytesMut, VecAlloc};
use crate::error::{Context, Result};
use crate::mapping::{DynMapping, Mapping, WireRecipe};
use crate::runtime::{WireEndian, WireManifest};
use crate::view::View;
use crate::{bail, ensure};

use super::{programs_cover_dst, same_data_space, CopyMethod, CopyProgram};

/// Framing magic of [`write_message`] header lines.
pub const WIRE_MAGIC: &str = "LLAMA-WIRE";

/// Framing magic of the payload sub-frames in chunked mode
/// ([`write_range_chunked`]): each chunk is a `LLAMA-CHUNK <len>` line
/// followed by `len` payload bytes.
pub const CHUNK_MAGIC: &str = "LLAMA-CHUNK";

/// Upper bound on a framed manifest line. Manifests are one line of
/// text (a record grammar plus a few tokens); anything larger is a
/// corrupt or hostile header, rejected before allocation.
pub const MAX_MANIFEST_BYTES: usize = 1 << 20;

/// Upper bound on a frame *header* line (`LLAMA-WIRE <m> <p>\n`): the
/// magic plus two decimal lengths fits in well under 64 bytes, so the
/// header read never buffers more than this — a newline-free hostile
/// stream errors after [`MAX_HEADER_BYTES`] bytes instead of
/// allocating without bound.
pub const MAX_HEADER_BYTES: u64 = 256;

/// A serialized view: the self-describing manifest plus the payload
/// (all wire blobs concatenated in manifest order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireMessage<P: Blob = Vec<u8>> {
    pub manifest: WireManifest,
    pub payload: P,
}

impl<P: Blob> WireMessage<P> {
    /// Total message size on the wire (header excluded).
    pub fn payload_len(&self) -> usize {
        self.payload.as_bytes().len()
    }
}

/// Split a payload buffer into per-blob slices of the manifest's
/// declared sizes. Panics if the buffer is too short — callers check
/// [`WireManifest::payload_len`] first.
fn split_blobs<'a>(mut bytes: &'a [u8], sizes: &[usize]) -> Vec<ExternalBytes<'a>> {
    let mut out = Vec::with_capacity(sizes.len());
    for &s in sizes {
        let (head, tail) = bytes.split_at(s);
        out.push(ExternalBytes(head));
        bytes = tail;
    }
    out
}

fn split_blobs_mut<'a>(mut bytes: &'a mut [u8], sizes: &[usize]) -> Vec<ExternalBytesMut<'a>> {
    let mut out = Vec::with_capacity(sizes.len());
    for &s in sizes {
        let (head, tail) = bytes.split_at_mut(s);
        out.push(ExternalBytesMut(head));
        bytes = tail;
    }
    out
}

/// Serialize `src` into a dense packed-AoS wire buffer in this
/// process's byte order — the cheapest layout to re-view on an
/// identical-endian peer.
pub fn serialize<M, B>(src: &View<M, B>) -> Result<WireMessage>
where
    M: Mapping,
    B: Blob,
{
    serialize_endian(src, WireEndian::native())
}

/// [`serialize`] with an explicit payload byte order — pass the *peer's*
/// endianness to pre-swap on the sending side (the receiver then reads
/// natively). Cross-endian packing compiles to swap runs, never the
/// element gather.
pub fn serialize_endian<M, B>(src: &View<M, B>, endian: WireEndian) -> Result<WireMessage>
where
    M: Mapping,
    B: Blob,
{
    serialize_with(src, endian, &VecAlloc).map(|(msg, _)| msg)
}

/// The full-control serializer: wire buffers come from `recycler`
/// (e.g. a shared [`crate::blob::BlobPool`] in a frame-exchange loop),
/// and the compiled pack strategy is reported alongside the message.
/// The buffer's zero fill is skipped when [`programs_cover_dst`]
/// proves the pack program writes every payload byte.
pub fn serialize_with<M, B, R>(
    src: &View<M, B>,
    endian: WireEndian,
    recycler: &R,
) -> Result<(WireMessage<R::Blob>, CopyMethod)>
where
    M: Mapping,
    B: Blob,
    R: BlobRecycler,
{
    let manifest = WireManifest::describe(
        src.mapping().info().dim.clone(),
        src.mapping().dims().clone(),
        WireRecipe::AosPacked,
        endian,
    )?;
    // Non-native orders come back wrapped in Byteswap: the pack copy
    // below then compiles to swap runs (or verbatim moves, if the
    // source representation already matches).
    let wire_mapping = manifest.build_mapping()?;
    let prog = CopyProgram::compile(src.mapping(), &wire_mapping);
    let covered = programs_cover_dst(
        std::slice::from_ref(&prog),
        &manifest.blob_sizes,
    );
    let mut payload = if covered {
        recycler.allocate_covered(manifest.payload_len())
    } else {
        recycler.allocate(manifest.payload_len())
    };
    let method = prog.method();
    {
        let blobs = split_blobs_mut(payload.as_bytes_mut(), &manifest.blob_sizes);
        let mut dst = View::from_blobs(&wire_mapping, blobs);
        prog.execute(src, &mut dst);
    }
    Ok((WireMessage { manifest, payload }, method))
}

/// Serialize only the linearized records `begin..end` of `src` into a
/// dense packed-AoS wire buffer (native byte order). The manifest
/// carries a `range=` token so the receiver knows where the slab lands
/// in the full data space — the primitive behind shard-parallel sends
/// and halo exchanges.
pub fn serialize_range<M, B>(src: &View<M, B>, begin: usize, end: usize) -> Result<WireMessage>
where
    M: Mapping,
    B: Blob,
{
    serialize_range_endian(src, begin, end, WireEndian::native())
}

/// [`serialize_range`] with an explicit payload byte order.
pub fn serialize_range_endian<M, B>(
    src: &View<M, B>,
    begin: usize,
    end: usize,
    endian: WireEndian,
) -> Result<WireMessage>
where
    M: Mapping,
    B: Blob,
{
    serialize_range_with(src, begin, end, endian, &VecAlloc).map(|(msg, _)| msg)
}

/// The full-control range serializer: like [`serialize_with`], but the
/// pack is a **slice program** ([`CopyProgram::compile_slice`]) from
/// source records `begin..end` into a dense `end - begin`-record wire
/// buffer. Lane-aligned slab boundaries stay on the closed-form run
/// strategies; only generic source plans fall back to the element
/// gather.
pub fn serialize_range_with<M, B, R>(
    src: &View<M, B>,
    begin: usize,
    end: usize,
    endian: WireEndian,
    recycler: &R,
) -> Result<(WireMessage<R::Blob>, CopyMethod)>
where
    M: Mapping,
    B: Blob,
    R: BlobRecycler,
{
    let manifest = WireManifest::describe_range(
        src.mapping().info().dim.clone(),
        src.mapping().dims().clone(),
        WireRecipe::AosPacked,
        endian,
        begin,
        end,
    )?;
    let wire_mapping = manifest.build_mapping()?;
    let prog = CopyProgram::compile_slice(src.mapping(), &wire_mapping, begin, 0, end - begin);
    let covered = programs_cover_dst(std::slice::from_ref(&prog), &manifest.blob_sizes);
    let mut payload = if covered {
        recycler.allocate_covered(manifest.payload_len())
    } else {
        recycler.allocate(manifest.payload_len())
    };
    let method = prog.method();
    {
        let blobs = split_blobs_mut(payload.as_bytes_mut(), &manifest.blob_sizes);
        let mut dst = View::from_blobs(&wire_mapping, blobs);
        prog.execute(src, &mut dst);
    }
    Ok((WireMessage { manifest, payload }, method))
}

/// Split `src` into up to `parts` lane-aligned record shards
/// ([`crate::view::shard::shard_range`] at the source plan's
/// [`crate::view::shard::shard_align`]) and serialize each as one
/// range-restricted message — the per-connection payloads of a
/// shard-parallel send. Empty tail shards are dropped.
pub fn serialize_sharded<M, B>(
    src: &View<M, B>,
    endian: WireEndian,
    parts: usize,
) -> Result<Vec<WireMessage>>
where
    M: Mapping,
    B: Blob,
{
    ensure!(src.count() > 0, "cannot shard a zero-record view onto the wire");
    let plan = src.mapping().plan();
    let align = crate::view::shard::shard_align(&plan);
    crate::view::shard::shard_range(src.count(), parts.max(1), align)
        .into_iter()
        .filter(|s| !s.is_empty())
        .map(|s| serialize_range_endian(src, s.start, s.end, endian))
        .collect()
}

/// The pipelined range serializer: frame records `begin..end` of `src`
/// straight onto a byte stream in **chunked mode**, executing the pack
/// as one slice [`CopyProgram`] per shard-aligned chunk of at most
/// `chunk_records` records and flushing each chunk sub-frame as it
/// completes. Unlike [`serialize_range_with`] + [`write_message`] —
/// which stage the whole payload before the first byte moves — wire
/// memory stays bounded by one chunk and the receiver can start
/// unpacking while later records are still being packed. Chunk cuts
/// fall on [`crate::view::shard::shard_align`] boundaries of the
/// source plan, so per-chunk programs use the same closed-form
/// strategies the whole-range program would (the concatenated chunks
/// are byte-identical to the staged payload: the packed-AoS wire
/// recipe is a single dense record-major blob, cross-endian included).
/// `step` tags the manifest for multiplexed links. Returns the pack
/// strategy of the first chunk and the number of chunks written.
pub fn write_range_chunked<W, M, B>(
    w: &mut W,
    src: &View<M, B>,
    begin: usize,
    end: usize,
    endian: WireEndian,
    step: Option<usize>,
    chunk_records: usize,
) -> Result<(CopyMethod, usize)>
where
    W: Write,
    M: Mapping,
    B: Blob,
{
    let mut manifest = WireManifest::describe_range(
        src.mapping().info().dim.clone(),
        src.mapping().dims().clone(),
        WireRecipe::AosPacked,
        endian,
        begin,
        end,
    )?;
    manifest.step = step;
    ensure!(
        manifest.blob_sizes.len() == 1,
        "chunked framing needs a single-blob wire recipe, {} has {}",
        manifest.recipe.token(),
        manifest.blob_sizes.len()
    );
    let line = manifest.to_line()?;
    writeln!(w, "{WIRE_MAGIC} {} {} chunked", line.len(), manifest.payload_len())?;
    w.write_all(line.as_bytes())?;
    // Dense packed AoS: every record is the same packed size, so a
    // chunk of n records is exactly n * record_bytes payload bytes.
    let record_bytes = manifest.payload_len() / (end - begin);
    let plan = src.mapping().plan();
    let align = crate::view::shard::shard_align(&plan);
    let chunks = CopyProgram::chunk_slices(begin, end, chunk_records, align);
    let max_chunk = chunks.iter().map(|(b, e)| e - b).max().unwrap_or(0);
    let mut buf = vec![0u8; max_chunk * record_bytes];
    let mut method = CopyMethod::Blobwise;
    for (i, &(b, e)) in chunks.iter().enumerate() {
        let n = e - b;
        let chunk_manifest = WireManifest::describe_range(
            manifest.record.clone(),
            manifest.dims.clone(),
            WireRecipe::AosPacked,
            endian,
            b,
            e,
        )?;
        let wire_mapping = chunk_manifest.build_mapping()?;
        let prog = CopyProgram::compile_slice(src.mapping(), &wire_mapping, b, 0, n);
        if i == 0 {
            method = prog.method();
        }
        let bytes = &mut buf[..n * record_bytes];
        if !programs_cover_dst(std::slice::from_ref(&prog), &chunk_manifest.blob_sizes) {
            bytes.fill(0);
        }
        {
            let blobs = split_blobs_mut(bytes, &chunk_manifest.blob_sizes);
            let mut dst = View::from_blobs(&wire_mapping, blobs);
            prog.execute(src, &mut dst);
        }
        writeln!(w, "{CHUNK_MAGIC} {}", bytes.len())?;
        w.write_all(bytes)?;
        // Flush per chunk: this is the point of the mode — the chunk
        // hits the wire while the next one is still being packed.
        w.flush()?;
    }
    Ok((method, chunks.len()))
}

/// Zero-copy read view straight over a message's payload bytes: the
/// manifest's mapping (wrapped in [`crate::mapping::Byteswap`] for
/// foreign byte orders, so accessors swap on read) over borrowed
/// per-blob slices. No bytes move.
pub fn wire_view<P: Blob>(msg: &WireMessage<P>) -> Result<View<DynMapping, ExternalBytes<'_>>> {
    let mapping = msg.manifest.build_mapping()?;
    let payload = msg.payload.as_bytes();
    ensure!(
        payload.len() == msg.manifest.payload_len(),
        "wire payload is {} bytes, manifest declares {}",
        payload.len(),
        msg.manifest.payload_len()
    );
    let blobs = split_blobs(payload, &msg.manifest.blob_sizes);
    Ok(View::from_blobs(mapping, blobs))
}

/// Deserialize a message into an existing view of the same data space
/// (any layout — the unpack is a compiled copy). Returns the strategy
/// used: native payloads into AoSoA-family layouts unpack as verbatim
/// chunk moves, cross-endian payloads as swap runs.
pub fn deserialize_into<M, B, P>(msg: &WireMessage<P>, dst: &mut View<M, B>) -> Result<CopyMethod>
where
    M: Mapping,
    B: BlobMut,
    P: Blob,
{
    let src = wire_view(msg)?;
    if !same_data_space(src.mapping(), dst.mapping()) {
        bail!(
            "wire message data space ({} records of {:?}) does not match \
             the destination view ({} records)",
            src.count(),
            msg.manifest.dims.extents(),
            dst.count()
        );
    }
    let prog = CopyProgram::compile(src.mapping(), dst.mapping());
    prog.execute(&src, dst);
    Ok(prog.method())
}

/// Deserialize a range-restricted message into the records
/// `begin..end` of an existing view over the **full** data space the
/// manifest names (any layout): the inverse of [`serialize_range`].
/// Records outside the range are untouched. Errors if the message
/// carries no `range=` or the destination's data space differs from
/// the manifest's.
pub fn deserialize_range_into<M, B, P>(
    msg: &WireMessage<P>,
    dst: &mut View<M, B>,
) -> Result<CopyMethod>
where
    M: Mapping,
    B: BlobMut,
    P: Blob,
{
    let (begin, _) = msg
        .manifest
        .range
        .context("wire message carries no range= (use deserialize_into)")?;
    ensure!(
        &msg.manifest.dims == dst.mapping().dims(),
        "wire range message describes a {:?} data space, destination is {:?}",
        msg.manifest.dims.extents(),
        dst.mapping().dims().extents()
    );
    deserialize_range_into_at(msg, dst, begin)
}

/// Deserialize a message's records into an existing view at an
/// explicit destination offset, ignoring where the sender's manifest
/// says the slab came *from*: halo receivers land a neighbour's
/// boundary plane on their own ghost plane, and reassembly loops land
/// worker interiors at their global offsets. Only the record dimension
/// must match; the destination's array extents are its own.
pub fn deserialize_range_into_at<M, B, P>(
    msg: &WireMessage<P>,
    dst: &mut View<M, B>,
    dst_start: usize,
) -> Result<CopyMethod>
where
    M: Mapping,
    B: BlobMut,
    P: Blob,
{
    let src = wire_view(msg)?;
    let n = msg.manifest.payload_records();
    ensure!(
        msg.manifest.record == dst.mapping().info().dim,
        "wire message record dimension does not match the destination view"
    );
    ensure!(
        dst_start.checked_add(n).is_some_and(|e| e <= dst.count()),
        "wire records {dst_start}..{} do not fit the {}-record destination",
        dst_start + n,
        dst.count()
    );
    let prog = CopyProgram::compile_slice(src.mapping(), dst.mapping(), 0, dst_start, n);
    prog.execute(&src, dst);
    Ok(prog.method())
}

/// Reassemble a batch of range-restricted messages (a shard-parallel
/// send, arriving in any order) into one destination view. The ranges
/// must tile the destination exactly — disjoint and complete — and
/// every manifest must name the destination's data space; partial or
/// overlapping deliveries are rejected before any byte lands.
pub fn deserialize_sharded_into<M, B, P>(
    msgs: &[WireMessage<P>],
    dst: &mut View<M, B>,
) -> Result<()>
where
    M: Mapping,
    B: BlobMut,
    P: Blob,
{
    let mut ranges = Vec::with_capacity(msgs.len());
    for msg in msgs {
        let (b, e) = msg
            .manifest
            .range
            .context("sharded reassembly needs range-restricted messages")?;
        ensure!(
            &msg.manifest.dims == dst.mapping().dims(),
            "shard message describes a {:?} data space, destination is {:?}",
            msg.manifest.dims.extents(),
            dst.mapping().dims().extents()
        );
        ranges.push((b, e));
    }
    ranges.sort_unstable();
    let mut covered = 0usize;
    for &(b, e) in &ranges {
        ensure!(
            b == covered,
            "shard ranges {} at record {covered} (got {b}..{e})",
            if b > covered { "leave a gap" } else { "overlap" }
        );
        covered = e;
    }
    ensure!(
        covered == dst.count(),
        "shard ranges cover {covered} of {} records",
        dst.count()
    );
    for msg in msgs {
        let (b, _) = msg.manifest.range.expect("checked above");
        deserialize_range_into_at(msg, dst, b)?;
    }
    Ok(())
}

/// Deserialize a message into a freshly allocated **native** view in
/// the manifest's recipe layout: the round-trip inverse of
/// [`serialize`], independent of the payload's byte order.
pub fn deserialize<P: Blob>(msg: &WireMessage<P>) -> Result<(View<DynMapping, Vec<u8>>, CopyMethod)> {
    let mapping = msg.manifest.recipe.build(&msg.manifest.record, msg.manifest.dims.clone());
    let mut dst = crate::view::alloc_view(mapping);
    let method = deserialize_into(msg, &mut dst)?;
    Ok((dst, method))
}

/// Frame a message onto a byte stream:
///
/// ```text
/// LLAMA-WIRE <manifest_len> <payload_len>\n
/// <manifest line (manifest_len bytes, no trailing newline)>
/// <payload (payload_len bytes)>
/// ```
pub fn write_message<W, P>(w: &mut W, msg: &WireMessage<P>) -> Result<()>
where
    W: Write,
    P: Blob,
{
    let line = msg.manifest.to_line()?;
    let payload = msg.payload.as_bytes();
    ensure!(
        payload.len() == msg.manifest.payload_len(),
        "refusing to frame a message whose payload ({} bytes) disagrees \
         with its manifest ({} bytes)",
        payload.len(),
        msg.manifest.payload_len()
    );
    writeln!(w, "{WIRE_MAGIC} {} {}", line.len(), payload.len())?;
    w.write_all(line.as_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one framed message; `Ok(None)` on a clean end of stream
/// (zero bytes before the next header).
///
/// Validation order matters: the header's manifest length is capped
/// ([`MAX_MANIFEST_BYTES`]), the manifest is parsed and cross-checked
/// against its own rebuilt layout, and only then is the header's
/// payload length compared against the manifest's — so the payload
/// allocation is always bounded by a self-consistent layout, never by
/// an attacker-controlled number alone.
///
/// Headers with a trailing `chunked` token ([`write_range_chunked`])
/// deliver the payload as `LLAMA-CHUNK <len>` sub-frames; they are
/// reassembled here — every chunk must be non-empty and the chunks
/// must sum to exactly the manifest's payload length — so callers see
/// one [`WireMessage`] either way. (A pre-chunking peer rejects the
/// four-token header loudly instead of misreading the stream.)
pub fn read_message<R: BufRead>(r: &mut R) -> Result<Option<WireMessage>> {
    // The header is read through a byte-limited `Read::take`: an
    // uncapped `read_line` on a newline-free hostile stream would
    // buffer (and allocate) without bound before any length check ran.
    let mut header = String::new();
    if (&mut *r).take(MAX_HEADER_BYTES).read_line(&mut header)? == 0 {
        return Ok(None);
    }
    // `Ok(None)` means a clean frame boundary and nothing else: a
    // header cut off by EOF (or by the byte cap) is an error, never a
    // silent end of stream.
    ensure!(
        header.ends_with('\n'),
        "wire header truncated or longer than {MAX_HEADER_BYTES} bytes: {:?}",
        header.trim_end()
    );
    let parts: Vec<&str> = header.split_whitespace().collect();
    let chunked = match parts.as_slice() {
        [magic, _, _] if *magic == WIRE_MAGIC => false,
        [magic, _, _, mode] if *magic == WIRE_MAGIC && *mode == "chunked" => true,
        _ => bail!("bad wire header {:?}", header.trim_end()),
    };
    let manifest_len: usize = parts[1].parse().context("wire header manifest length")?;
    let payload_len: usize = parts[2].parse().context("wire header payload length")?;
    ensure!(
        manifest_len <= MAX_MANIFEST_BYTES,
        "wire manifest length {manifest_len} exceeds the {MAX_MANIFEST_BYTES}-byte cap"
    );
    let mut manifest_bytes = vec![0u8; manifest_len];
    r.read_exact(&mut manifest_bytes)?;
    let line = std::str::from_utf8(&manifest_bytes).context("wire manifest is not UTF-8")?;
    let manifest = WireManifest::parse_line(line)?;
    ensure!(
        payload_len == manifest.payload_len(),
        "wire header declares {payload_len} payload bytes, manifest {}",
        manifest.payload_len()
    );
    let mut payload = vec![0u8; payload_len];
    if chunked {
        let mut filled = 0usize;
        while filled < payload_len {
            let mut chunk_header = String::new();
            ensure!(
                (&mut *r).take(MAX_HEADER_BYTES).read_line(&mut chunk_header)? > 0,
                "wire stream ended after {filled} of {payload_len} chunked payload bytes"
            );
            ensure!(
                chunk_header.ends_with('\n'),
                "wire chunk header truncated or longer than {MAX_HEADER_BYTES} bytes: {:?}",
                chunk_header.trim_end()
            );
            let cp: Vec<&str> = chunk_header.split_whitespace().collect();
            ensure!(
                cp.len() == 2 && cp[0] == CHUNK_MAGIC,
                "bad wire chunk header {:?}",
                chunk_header.trim_end()
            );
            let len: usize = cp[1].parse().context("wire chunk length")?;
            ensure!(len > 0, "zero-length wire chunk at byte {filled}");
            ensure!(
                len <= payload_len - filled,
                "wire chunk of {len} bytes overruns the manifest payload \
                 ({filled} of {payload_len} bytes filled)"
            );
            r.read_exact(&mut payload[filled..filled + len])?;
            filled += len;
        }
    } else {
        r.read_exact(&mut payload)?;
    }
    Ok(Some(WireMessage { manifest, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::fill_distinct;
    use crate::copy::views_equal;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, Byteswap, SoA};
    use crate::view::alloc_view;

    #[test]
    fn round_trip_preserves_every_field() {
        let d = particle_dim();
        let mut src = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(19)));
        fill_distinct(&mut src);
        let msg = serialize(&src).unwrap();
        assert_eq!(msg.payload_len(), msg.manifest.payload_len());
        // Zero-copy wire view reads the payload in place...
        assert!(views_equal(&src, &wire_view(&msg).unwrap()));
        // ...and the compiled unpack lands in any layout.
        let mut dst = alloc_view(AoSoA::new(&d, ArrayDims::linear(19), 4));
        let method = deserialize_into(&msg, &mut dst).unwrap();
        assert_eq!(method, CopyMethod::AoSoAChunked);
        assert!(views_equal(&src, &dst));
    }

    #[test]
    fn native_pack_of_packed_aos_is_verbatim() {
        // Packed AoS → the packed-AoS wire layout is the identical
        // pair: serialization is one memcpy.
        let d = particle_dim();
        let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(8)));
        fill_distinct(&mut src);
        let (msg, method) = serialize_with(&src, WireEndian::native(), &VecAlloc).unwrap();
        assert_eq!(method, CopyMethod::Blobwise);
        assert!(views_equal(&src, &wire_view(&msg).unwrap()));
    }

    #[test]
    fn cross_endian_pack_compiles_swap_runs_not_gather() {
        let d = particle_dim();
        let mut src = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(11)));
        fill_distinct(&mut src);
        let (msg, method) =
            serialize_with(&src, WireEndian::native().swapped(), &VecAlloc).unwrap();
        assert_eq!(method, CopyMethod::SwapProgram);
        // The foreign-order payload still reads correctly through the
        // swapping accessors of the wire view...
        assert!(views_equal(&src, &wire_view(&msg).unwrap()));
        // ...and unpacking back to a native layout swaps again.
        let (back, method) = deserialize(&msg).unwrap();
        assert_eq!(method, CopyMethod::SwapProgram);
        assert!(views_equal(&src, &back));
    }

    #[test]
    fn byteswapped_source_sent_in_its_own_order_moves_verbatim() {
        // A view already holding big-endian bytes (Byteswap mapping on
        // a little-endian host), serialized *as* the foreign order:
        // equal representation on both sides — bytes move verbatim,
        // no per-element swapping.
        let d = particle_dim();
        let mut src =
            alloc_view(Byteswap::new(AoS::packed(&d, ArrayDims::linear(6))));
        fill_distinct(&mut src);
        let (msg, method) =
            serialize_with(&src, WireEndian::native().swapped(), &VecAlloc).unwrap();
        assert_eq!(method, CopyMethod::Blobwise);
        assert!(views_equal(&src, &wire_view(&msg).unwrap()));
    }

    #[test]
    fn pooled_wire_buffers_skip_the_zero_fill_when_covered() {
        use crate::blob::BlobPool;
        let d = particle_dim();
        let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(64)));
        fill_distinct(&mut src);
        let pool = BlobPool::new();
        // Warm the pool, then re-serialize: the pack program covers the
        // dense wire buffer, so the recycled buffer skips its re-zero.
        drop(serialize_with(&src, WireEndian::native(), &pool).unwrap());
        let (msg, _) = serialize_with(&src, WireEndian::native(), &pool).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.hits, 1);
        assert!(stats.zero_skips >= 1, "covered pack must skip the re-zero");
        assert!(views_equal(&src, &wire_view(&msg).unwrap()));
    }

    #[test]
    fn framing_round_trips_over_a_byte_stream() {
        let d = particle_dim();
        let mut src = alloc_view(SoA::multi_blob(&d, ArrayDims::linear(7)));
        fill_distinct(&mut src);
        let mut stream = Vec::new();
        write_message(&mut stream, &serialize(&src).unwrap()).unwrap();
        write_message(
            &mut stream,
            &serialize_endian(&src, WireEndian::native().swapped()).unwrap(),
        )
        .unwrap();
        let mut r = std::io::Cursor::new(stream);
        let first = read_message(&mut r).unwrap().expect("first message");
        let second = read_message(&mut r).unwrap().expect("second message");
        assert!(read_message(&mut r).unwrap().is_none(), "clean EOF");
        assert!(views_equal(&src, &wire_view(&first).unwrap()));
        assert!(views_equal(&src, &wire_view(&second).unwrap()));
        assert_ne!(first.payload, second.payload, "orders differ on the wire");
    }

    #[test]
    fn corrupt_frames_are_rejected_before_the_payload() {
        let d = particle_dim();
        let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(4)));
        fill_distinct(&mut src);
        let mut stream = Vec::new();
        write_message(&mut stream, &serialize(&src).unwrap()).unwrap();
        let text = String::from_utf8_lossy(&stream).into_owned();

        // Wrong magic.
        let bad = text.replacen(WIRE_MAGIC, "LLAMA-EVIL", 1);
        assert!(read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err());
        // Truncated payload: the reader hits EOF mid-read_exact.
        let mut cut = stream.clone();
        cut.truncate(stream.len() - 10);
        assert!(read_message(&mut std::io::Cursor::new(cut)).is_err());
        // A forged header payload length larger than the manifest's is
        // caught before any payload read (4 records × 25 B = 100).
        let forged = text.replacen(" 100\n", " 999999\n", 1);
        assert_ne!(forged, text, "expected the 100-byte payload length in the header");
        assert!(read_message(&mut std::io::Cursor::new(forged.into_bytes())).is_err());
        // Oversized manifest lengths are refused before allocation.
        let huge = format!("{WIRE_MAGIC} {} 0\n", MAX_MANIFEST_BYTES + 1);
        assert!(read_message(&mut std::io::Cursor::new(huge.into_bytes())).is_err());
    }

    #[test]
    fn range_round_trip_restores_only_the_range() {
        let d = particle_dim();
        let dims = ArrayDims::linear(23);
        let mut src = alloc_view(SoA::multi_blob(&d, dims.clone()));
        fill_distinct(&mut src);
        let info = src.mapping().info().clone();
        for endian in [WireEndian::native(), WireEndian::native().swapped()] {
            let msg = serialize_range_endian(&src, 7, 18, endian).unwrap();
            assert_eq!(msg.manifest.range, Some((7, 18)));
            assert_eq!(msg.manifest.payload_records(), 11);
            assert_eq!(msg.payload_len(), msg.manifest.payload_len());
            assert_eq!(wire_view(&msg).unwrap().count(), 11);

            // Unpack into a zeroed 23-record view: records 7..18 carry
            // the source values, everything else stays zero — the
            // oracle is the two-index naive copy over the range alone.
            let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 4));
            deserialize_range_into(&msg, &mut dst).unwrap();
            let mut oracle = alloc_view(AoSoA::new(&d, dims.clone(), 4));
            for lin in 7..18 {
                for leaf in 0..info.leaf_count() {
                    crate::copy::naive::copy_field_between(
                        &src,
                        &mut oracle,
                        leaf,
                        lin,
                        lin,
                        info.fields[leaf].size(),
                    );
                }
            }
            assert_eq!(dst.blobs(), oracle.blobs(), "{endian:?}");

            // Offset landing: the same slab placed at record 0 of an
            // 11-record view with its own extents.
            let mut small = alloc_view(AoS::packed(&d, ArrayDims::linear(11)));
            deserialize_range_into_at(&msg, &mut small, 0).unwrap();
            let mut expect = alloc_view(AoS::packed(&d, ArrayDims::linear(11)));
            for i in 0..11 {
                for leaf in 0..info.leaf_count() {
                    crate::copy::naive::copy_field_between(
                        &src,
                        &mut expect,
                        leaf,
                        7 + i,
                        i,
                        info.fields[leaf].size(),
                    );
                }
            }
            assert_eq!(small.blobs(), expect.blobs(), "{endian:?}");
        }
    }

    #[test]
    fn sharded_messages_reassemble_exactly() {
        let d = particle_dim();
        let dims = ArrayDims::linear(97); // prime: uneven tail shard
        let mut src = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        fill_distinct(&mut src);
        let msgs = serialize_sharded(&src, WireEndian::native(), 4).unwrap();
        assert!(msgs.len() >= 2, "97 records over 4 parts must shard");
        // Shard boundaries are lane-aligned on the AoSoA-8 source.
        for m in &msgs[..msgs.len() - 1] {
            let (b, e) = m.manifest.range.unwrap();
            assert_eq!(b % 8, 0, "shard begin {b} not lane-aligned");
            assert_eq!(e % 8, 0, "shard end {e} not lane-aligned");
        }
        // Reassembly in arrival order and in reversed order both land
        // the exact source bytes.
        for reversed in [false, true] {
            let mut batch: Vec<_> = msgs.clone();
            if reversed {
                batch.reverse();
            }
            let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 8));
            deserialize_sharded_into(&batch, &mut dst).unwrap();
            assert!(views_equal(&src, &dst));
        }
        // A missing shard is a gap, a duplicated one an overlap.
        let mut dst = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        assert!(deserialize_sharded_into(&msgs[1..], &mut dst).is_err());
        let mut doubled = msgs.clone();
        doubled.push(msgs[0].clone());
        assert!(deserialize_sharded_into(&doubled, &mut dst).is_err());
        // Whole-view messages (no range=) are refused.
        let whole = serialize(&src).unwrap();
        assert!(deserialize_sharded_into(&[whole], &mut dst).is_err());
    }

    #[test]
    fn chunked_stream_reassembles_to_the_staged_message() {
        let d = particle_dim();
        let dims = ArrayDims::linear(53);
        let mut src = alloc_view(AoSoA::new(&d, dims.clone(), 8));
        fill_distinct(&mut src);
        for endian in [WireEndian::native(), WireEndian::native().swapped()] {
            // The staged (single-buffer) oracle for the same range.
            let staged = serialize_range_endian(&src, 8, 48, endian).unwrap();
            for chunk_records in [1, 8, 13, 40, 1000] {
                let mut stream = Vec::new();
                let (_, chunks) = write_range_chunked(
                    &mut stream,
                    &src,
                    8,
                    48,
                    endian,
                    Some(3),
                    chunk_records,
                )
                .unwrap();
                if chunk_records < 40 {
                    assert!(chunks > 1, "{chunk_records} records/chunk left one chunk");
                }
                let text = String::from_utf8_lossy(&stream);
                assert!(text.starts_with(WIRE_MAGIC), "{text:.60}");
                assert!(text.lines().next().unwrap().ends_with("chunked"));
                let msg = read_message(&mut std::io::Cursor::new(stream.clone()))
                    .unwrap()
                    .expect("chunked message");
                // Concatenated chunks are byte-identical to the staged
                // payload; the manifest differs only by the step tag.
                assert_eq!(msg.payload, staged.payload, "{endian:?}/{chunk_records}");
                assert_eq!(msg.manifest.step, Some(3));
                assert_eq!(msg.manifest.range, staged.manifest.range);
                assert_eq!(msg.manifest.blob_sizes, staged.manifest.blob_sizes);
                // Back-to-back chunked frames keep a clean boundary.
                let mut two = stream.clone();
                two.extend_from_slice(&stream);
                let mut r = std::io::Cursor::new(two);
                assert!(read_message(&mut r).unwrap().is_some());
                assert!(read_message(&mut r).unwrap().is_some());
                assert!(read_message(&mut r).unwrap().is_none(), "clean EOF");
            }
        }
    }

    #[test]
    fn corrupt_chunked_frames_are_rejected() {
        let d = particle_dim();
        let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(16)));
        fill_distinct(&mut src);
        let mut stream = Vec::new();
        write_range_chunked(&mut stream, &src, 0, 16, WireEndian::native(), None, 4)
            .unwrap();
        let text = String::from_utf8_lossy(&stream).into_owned();
        // 4 records × 25 B/record per chunk.
        assert!(text.contains("LLAMA-CHUNK 100\n"), "{text:.120}");
        // Truncation mid-chunk: EOF inside read_exact.
        let mut cut = stream.clone();
        cut.truncate(stream.len() - 10);
        assert!(read_message(&mut std::io::Cursor::new(cut)).is_err());
        // Truncation at a chunk boundary: the stream ends cleanly but
        // the payload is short — never Ok(None), never a short message.
        let tail = 25 * 4 + "LLAMA-CHUNK 100\n".len();
        let mut cut = stream.clone();
        cut.truncate(stream.len() - tail);
        assert!(read_message(&mut std::io::Cursor::new(cut)).is_err());
        // A corrupted chunk magic is refused.
        let bad = text.replacen(CHUNK_MAGIC, "LLAMA-JUNK", 1);
        assert!(read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err());
        // A chunk overrunning the declared payload is refused before
        // its bytes are read.
        let bad = text.replacen("LLAMA-CHUNK 100\n", "LLAMA-CHUNK 999\n", 1);
        assert!(read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err());
        // Zero-length chunks cannot make progress and are refused.
        let bad = text.replacen("LLAMA-CHUNK 100\n", "LLAMA-CHUNK 0\n", 1);
        assert!(read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err());
        // A chunked token on anything but a 4-token header is refused.
        let bad = text.replacen(" chunked", " chunked extra", 1);
        assert!(read_message(&mut std::io::Cursor::new(bad.into_bytes())).is_err());
    }

    #[test]
    fn newline_free_streams_error_at_the_header_cap() {
        // Regression: an uncapped read_line buffered the whole hostile
        // stream before MAX_MANIFEST_BYTES ever applied. The reader
        // must now give up after MAX_HEADER_BYTES.
        let hostile = vec![b'A'; 4 * 1024 * 1024];
        let mut r = std::io::Cursor::new(hostile);
        assert!(read_message(&mut r).is_err());
        assert!(
            r.position() <= MAX_HEADER_BYTES,
            "reader consumed {} bytes of a newline-free stream",
            r.position()
        );
        // A truncated header (EOF before the newline) is an error too:
        // Ok(None) is reserved for clean frame boundaries.
        let mut r = std::io::Cursor::new(b"LLAMA-WIRE 10".to_vec());
        assert!(read_message(&mut r).is_err());
    }

    #[test]
    fn mismatched_destination_is_an_error_not_a_panic() {
        let d = particle_dim();
        let mut src = alloc_view(AoS::packed(&d, ArrayDims::linear(4)));
        fill_distinct(&mut src);
        let msg = serialize(&src).unwrap();
        let mut wrong = alloc_view(AoS::packed(&d, ArrayDims::linear(5)));
        assert!(deserialize_into(&msg, &mut wrong).is_err());
        // Payload/manifest length mismatches are refused at framing and
        // at viewing time.
        let mut short = msg.clone();
        short.payload.pop();
        assert!(wire_view(&short).is_err());
        assert!(write_message(&mut Vec::new(), &short).is_err());
    }
}
