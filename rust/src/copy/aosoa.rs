//! The layout-aware `aosoa_copy` (paper §4.2): chunked copy between any
//! two AoSoA-family layouts.
//!
//! Within an AoSoA-L layout, each field's values are contiguous in runs
//! of `L` (packed AoS: L = 1; SoA: L = N). Between an AoSoA-N source
//! and AoSoA-M destination, runs intersect in pieces of at least
//! `gcd(N, M)` elements (the paper copies `min(N, M)`, valid for the
//! power-of-two lane counts it uses; run intersection generalizes this
//! to arbitrary lane counts and tail blocks).
//!
//! The traversal can walk chunks in source-storage order
//! ([`ChunkOrder::ReadContiguous`], the paper's "(r)") or in
//! destination-storage order ([`ChunkOrder::WriteContiguous`], "(w)").

use crate::blob::{Blob, BlobMut};
use crate::mapping::{LayoutPlan, Mapping};
use crate::view::View;

/// Traversal order of the chunked copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkOrder {
    /// Walk chunks in the order they appear in the *source* blobs —
    /// contiguous reads, scattered writes.
    ReadContiguous,
    /// Walk chunks in the order they appear in the *destination* blobs
    /// — scattered reads, contiguous writes.
    WriteContiguous,
}

/// Chunked copy between AoSoA-family layouts, driven by the two
/// compiled [`LayoutPlan`]s. Panics if either plan is not in the family
/// (check [`super::aosoa_compatible`] first).
pub fn aosoa_copy<MS, MD, BS, BD>(src: &View<MS, BS>, dst: &mut View<MD, BD>, order: ChunkOrder)
where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    let sp = src.mapping().plan();
    let dp = dst.mapping().plan();
    aosoa_copy_with(src, dst, order, &sp, &dp);
}

/// [`aosoa_copy`] over plans the caller already compiled (the
/// dispatcher compiles each side exactly once per copy). Thin wrapper
/// over the program compiler's chunked strategy — the traversal that
/// used to live here now runs once at compile time and replays as a
/// span list ([`super::program`]).
pub(crate) fn aosoa_copy_with<MS, MD, BS, BD>(
    src: &View<MS, BS>,
    dst: &mut View<MD, BD>,
    order: ChunkOrder,
    sp: &LayoutPlan,
    dp: &LayoutPlan,
) where
    MS: Mapping,
    MD: Mapping,
    BS: Blob,
    BD: BlobMut,
{
    debug_assert!(super::same_data_space(src.mapping(), dst.mapping()));
    sp.chunk_lanes()
        .expect("aosoa_copy: source is not an AoSoA-family layout");
    dp.chunk_lanes()
        .expect("aosoa_copy: destination is not an AoSoA-family layout");
    assert!(
        sp.native() == dp.native(),
        "aosoa_copy requires equal byte representation on both sides \
         (verbatim chunk moves cannot convert)"
    );
    let n = src.count();
    let prog =
        super::program::compile_range_with(src.mapping(), dst.mapping(), sp, dp, order, 0, n);
    prog.execute(src, dst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::ArrayDims;
    use crate::copy::test_support::{check_copy, fill_distinct};
    use crate::copy::views_equal;
    use crate::mapping::test_support::particle_dim;
    use crate::mapping::{AoS, AoSoA, SoA};
    use crate::view::alloc_view;

    #[test]
    fn soa_to_aosoa_and_back() {
        let d = particle_dim();
        let dims = ArrayDims::linear(64);
        for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
            check_copy(
                SoA::multi_blob(&d, dims.clone()),
                AoSoA::new(&d, dims.clone(), 8),
                |s, dst| aosoa_copy(s, dst, order),
            );
            check_copy(
                AoSoA::new(&d, dims.clone(), 8),
                SoA::multi_blob(&d, dims.clone()),
                |s, dst| aosoa_copy(s, dst, order),
            );
        }
    }

    #[test]
    fn different_lane_counts() {
        let d = particle_dim();
        let dims = ArrayDims::linear(48);
        for (a, b) in [(4, 32), (32, 4), (8, 16), (2, 2)] {
            check_copy(
                AoSoA::new(&d, dims.clone(), a),
                AoSoA::new(&d, dims.clone(), b),
                |s, dst| aosoa_copy(s, dst, ChunkOrder::ReadContiguous),
            );
        }
    }

    #[test]
    fn non_pow2_lanes_and_tail() {
        // 10 records, lanes 3 vs 7: runs intersect at gcd-size pieces
        // plus the tail — exercises the generalization past the paper.
        let d = particle_dim();
        let dims = ArrayDims::linear(10);
        for order in [ChunkOrder::ReadContiguous, ChunkOrder::WriteContiguous] {
            check_copy(
                AoSoA::new(&d, dims.clone(), 3),
                AoSoA::new(&d, dims.clone(), 7),
                |s, dst| aosoa_copy(s, dst, order),
            );
        }
    }

    #[test]
    fn packed_aos_participates_as_one_lane() {
        let d = particle_dim();
        let dims = ArrayDims::linear(16);
        check_copy(
            AoS::packed(&d, dims.clone()),
            SoA::single_blob(&d, dims.clone()),
            |s, dst| aosoa_copy(s, dst, ChunkOrder::WriteContiguous),
        );
        check_copy(
            SoA::single_blob(&d, dims.clone()),
            AoS::packed(&d, dims.clone()),
            |s, dst| aosoa_copy(s, dst, ChunkOrder::ReadContiguous),
        );
    }

    #[test]
    fn soa_single_to_soa_multi() {
        // Paper §3.9: same SoA, one with one without blob separation.
        let d = particle_dim();
        let dims = ArrayDims::linear(33);
        check_copy(
            SoA::single_blob(&d, dims.clone()),
            SoA::multi_blob(&d, dims.clone()),
            |s, dst| aosoa_copy(s, dst, ChunkOrder::ReadContiguous),
        );
    }

    #[test]
    fn orders_produce_identical_result() {
        let d = particle_dim();
        let dims = ArrayDims::linear(40);
        let mut src = alloc_view(AoSoA::new(&d, dims.clone(), 4));
        fill_distinct(&mut src);
        let mut r = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        let mut w = alloc_view(AoSoA::new(&d, dims.clone(), 16));
        aosoa_copy(&src, &mut r, ChunkOrder::ReadContiguous);
        aosoa_copy(&src, &mut w, ChunkOrder::WriteContiguous);
        assert_eq!(r.blobs(), w.blobs());
        assert!(views_equal(&src, &r));
    }

    #[test]
    #[should_panic(expected = "not an AoSoA-family layout")]
    fn aligned_aos_rejected() {
        let d = particle_dim();
        let dims = ArrayDims::linear(8);
        let src = alloc_view(AoS::aligned(&d, dims.clone()));
        let mut dst = alloc_view(SoA::multi_blob(&d, dims.clone()));
        aosoa_copy(&src, &mut dst, ChunkOrder::ReadContiguous);
    }
}
